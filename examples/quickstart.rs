//! Quickstart: stand up the MQFQ-Sticky control plane, invoke a few
//! functions through the serving API, and print what happened.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Uses the real-time driver in model mode (no artifacts needed) via
//! the in-process [`Frontend`] API — submit returns a ticket, wait
//! redeems it; see `examples/e2e_serving.rs` for the full
//! PJRT-executing pipeline over TCP.

use std::time::Duration;

use mqfq::api::Frontend;
use mqfq::plane::PlaneConfig;
use mqfq::server::RtServer;
use mqfq::workload::{catalog, Workload};

fn main() -> anyhow::Result<()> {
    // 1. Register a workload: one copy of three catalog functions.
    let mut workload = Workload::default();
    let names = ["isoneural", "fft", "imagenet"];
    for name in names {
        workload.register(catalog::by_name(name).unwrap(), 0, 5.0);
    }

    // 2. Configure the control plane: MQFQ-Sticky, D=2, prefetch+swap.
    let cfg = PlaneConfig::default();

    // 3. Start the real-time driver. Modeled delays (cold boots, PCIe
    //    transfers) are scaled 100× down so the demo finishes fast.
    let server = RtServer::new(workload, cfg, None, 0.01)?;

    // 4. Invoke each function twice: first cold, then warm. Async
    //    tickets let the three submissions overlap.
    for round in 0..2 {
        println!(
            "--- round {} ({}) ---",
            round + 1,
            if round == 0 { "cold" } else { "warm" }
        );
        let tickets: Vec<_> = names
            .iter()
            .map(|name| server.submit(name))
            .collect::<Result<_, _>>()?;
        for ticket in tickets {
            let o = server.wait(ticket, Some(Duration::from_secs(60)))?;
            println!(
                "  {} -> {:>9.1} ms end-to-end  ({} start on gpu{})",
                o.func, o.latency_ms, o.start_kind, o.gpu
            );
        }
    }

    let s = server.stats();
    println!(
        "\n{} invocations, mean latency {:.0} ms, cold ratio {:.0}%",
        s.invocations,
        s.mean_latency_ms,
        s.cold_ratio * 100.0
    );
    Ok(())
}
