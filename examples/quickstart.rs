//! Quickstart: stand up the MQFQ-Sticky control plane, invoke a few
//! functions, and print what happened.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Uses the real-time driver in model mode (no artifacts needed); see
//! `examples/e2e_serving.rs` for the full PJRT-executing pipeline.

use std::time::Duration;

use mqfq::plane::PlaneConfig;
use mqfq::server::RtServer;
use mqfq::types::FuncId;
use mqfq::workload::{catalog, Workload};

fn main() -> anyhow::Result<()> {
    // 1. Register a workload: one copy of three catalog functions.
    let mut workload = Workload::default();
    for name in ["isoneural", "fft", "imagenet"] {
        workload.register(catalog::by_name(name).unwrap(), 0, 5.0);
    }

    // 2. Configure the control plane: MQFQ-Sticky, D=2, prefetch+swap.
    let cfg = PlaneConfig::default();

    // 3. Start the real-time driver. Modeled delays (cold boots, PCIe
    //    transfers) are scaled 100× down so the demo finishes fast.
    let server = RtServer::new(workload, cfg, None, 0.01)?;

    // 4. Invoke each function twice: first cold, then warm.
    for round in 0..2 {
        println!(
            "--- round {} ({}) ---",
            round + 1,
            if round == 0 { "cold" } else { "warm" }
        );
        let rxs: Vec<_> = (0..3).map(|f| server.submit(FuncId(f))).collect();
        for rx in rxs {
            let c = rx.recv_timeout(Duration::from_secs(60))?;
            println!(
                "  f{} -> {:>9.1?} end-to-end  ({} start on gpu{})",
                c.func.0, c.latency, c.start_kind, c.gpu
            );
        }
    }

    let (n, mean_lat, cold) = server.stats();
    println!(
        "\n{n} invocations, mean latency {:.0} ms, cold ratio {:.0}%",
        mean_lat * 1e3,
        cold * 100.0
    );
    Ok(())
}
