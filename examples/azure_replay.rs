//! Replay an Azure-style production trace (Table 3 sample) through the
//! control plane under virtual time and print the paper-style report.
//!
//! ```bash
//! cargo run --release --example azure_replay [trace_id 0..8] [policy]
//! ```

use mqfq::experiments::{run, summary_table};
use mqfq::plane::PlaneConfig;
use mqfq::scheduler::policies::PolicyKind;
use mqfq::util::table::Table;
use mqfq::workload::azure::{self, AzureConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let trace_id: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let policy = args
        .get(1)
        .and_then(|s| PolicyKind::parse(s))
        .unwrap_or(PolicyKind::Mqfq);

    let (workload, trace) = azure::generate(&AzureConfig {
        trace_id,
        duration_s: 600.0,
        load_scale: 1.0,
    });
    println!(
        "Azure sample {trace_id}: {} functions, {} invocations, {:.2} req/s",
        workload.len(),
        trace.len(),
        trace.req_per_sec()
    );

    let cfg = PlaneConfig {
        policy,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let (summary, result) = run(
        &format!("{} trace{trace_id}", policy.name()),
        workload,
        &trace,
        cfg,
    );
    println!(
        "replayed {} virtual seconds in {:.1?}\n",
        summary.makespan_s.round(),
        t0.elapsed()
    );
    print!("{}", summary_table(std::slice::from_ref(&summary)).render());

    // Per-function breakdown (Fig 6b style).
    let mut t = Table::new(&[
        "function",
        "inv",
        "mean-lat(s)",
        "sd(s)",
        "cold",
        "host-warm",
        "gpu-warm",
    ]);
    let w = result.plane.workload().clone();
    for agg in result.recorder().per_function() {
        t.row(&[
            w.func(agg.func).name.clone(),
            agg.invocations.to_string(),
            format!("{:.2}", agg.mean_latency_s),
            format!("{:.2}", agg.var_latency.sqrt()),
            agg.cold.to_string(),
            agg.host_warm.to_string(),
            agg.gpu_warm.to_string(),
        ]);
    }
    print!("\n{}", t.render());
}
