//! **End-to-end serving driver** — proves all the layers compose, now
//! over the real wire:
//!
//! Layer 1/2 (build time): Pallas kernels inside JAX function bodies,
//! AOT-lowered to `artifacts/*.hlo.txt` by `make artifacts`.
//! Layer 3 (this binary): the MQFQ-Sticky control plane under a wall
//! clock behind the protocol-v1 TCP frontend; an [`ApiClient`] submits
//! an open-loop batch of *async* invocations (tickets) and redeems
//! them, so the requests traverse the same JSON-lines protocol any
//! external client would use; every dispatched invocation *executes
//! its real HLO artifact* on the PJRT CPU client.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serving
//! ```
//!
//! Reports per-function and aggregate latency/throughput; the run is
//! recorded in EXPERIMENTS.md §End-to-end.

use std::time::{Duration, Instant};

use mqfq::api::{ApiClient, Ticket};
use mqfq::plane::PlaneConfig;
use mqfq::server::RtServer;
use mqfq::types::StartKind;
use mqfq::util::stats::percentiles;
use mqfq::util::table::Table;
use mqfq::workload::{catalog, Workload};

const FUNCS: [&str; 4] = ["isoneural", "cupy", "srad", "fft"];
const REQUESTS_PER_FUNC: usize = 25;
/// Modeled (control-plane) delays are scaled down 50×; PJRT execution is
/// real wall time.
const SCALE: f64 = 0.02;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.txt").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(2);
    }

    let mut workload = Workload::default();
    for name in FUNCS {
        workload.register(catalog::by_name(name).unwrap(), 0, 1.0);
    }
    let cfg = PlaneConfig::default();
    println!(
        "starting control plane: policy=mqfq-sticky D={} mem=prefetch+swap, \
         PJRT artifacts from {}",
        cfg.d,
        artifacts.display()
    );
    let server = RtServer::new(workload, cfg, Some(&artifacts), SCALE)?;
    let addr = server.serve("127.0.0.1:0")?;
    let mut client = ApiClient::connect(addr)?;
    let described = client.describe()?;
    println!(
        "connected to {} at {addr}, protocol v{}, functions: {:?}",
        described.server,
        client.proto(),
        described.functions
    );

    // Open-loop: one async request every 20 ms round-robin across
    // functions; tickets are redeemed after the submission window.
    let t0 = Instant::now();
    let mut pending: Vec<(usize, Ticket)> = Vec::new();
    for i in 0..REQUESTS_PER_FUNC * FUNCS.len() {
        let fi = i % FUNCS.len();
        pending.push((fi, client.invoke_async(FUNCS[fi])?));
        std::thread::sleep(Duration::from_millis(20));
    }
    let submit_wall = t0.elapsed();

    let mut lat_by_func: Vec<Vec<f64>> = vec![Vec::new(); FUNCS.len()];
    let mut exec_by_func: Vec<Vec<f64>> = vec![Vec::new(); FUNCS.len()];
    let mut colds = 0usize;
    for (fi, ticket) in pending {
        let o = client.wait(ticket, Some(120_000))?;
        lat_by_func[fi].push(o.latency_ms / 1e3);
        exec_by_func[fi].push(o.exec_ms / 1e3);
        if o.start_kind == StartKind::Cold {
            colds += 1;
        }
    }
    let total_wall = t0.elapsed();

    let mut table = Table::new(&[
        "function",
        "requests",
        "p50-lat(ms)",
        "p99-lat(ms)",
        "mean-exec(ms)",
    ]);
    let mut all: Vec<f64> = Vec::new();
    for (i, name) in FUNCS.iter().enumerate() {
        let ps = percentiles(&lat_by_func[i], &[50.0, 99.0]);
        let mean_exec =
            exec_by_func[i].iter().sum::<f64>() / exec_by_func[i].len() as f64;
        table.row(&[
            name.to_string(),
            lat_by_func[i].len().to_string(),
            format!("{:.1}", ps[0] * 1e3),
            format!("{:.1}", ps[1] * 1e3),
            format!("{:.2}", mean_exec * 1e3),
        ]);
        all.extend(&lat_by_func[i]);
    }
    print!("{}", table.render());

    let n = all.len();
    let ps = percentiles(&all, &[50.0, 95.0, 99.0]);
    println!(
        "\n{n} requests served in {total_wall:.2?} (submission window {submit_wall:.2?})"
    );
    println!(
        "throughput {:.1} req/s | p50 {:.1} ms p95 {:.1} ms p99 {:.1} ms | \
         {} cold starts",
        n as f64 / total_wall.as_secs_f64(),
        ps[0] * 1e3,
        ps[1] * 1e3,
        ps[2] * 1e3,
        colds
    );
    let stats = client.stats()?;
    println!(
        "server stats: {} invocations, mean latency {:.1} ms, cold ratio {:.3}",
        stats.invocations, stats.mean_latency_ms, stats.cold_ratio
    );
    client.quit();
    println!(
        "all layers composed: JAX/Pallas HLO executed via PJRT behind \
         MQFQ-Sticky, over protocol v1"
    );
    Ok(())
}
