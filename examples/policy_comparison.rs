//! Head-to-head queueing-policy comparison on one workload — the §6.2
//! experiment as a runnable example.
//!
//! ```bash
//! cargo run --release --example policy_comparison [zipf|azure] [D]
//! ```

use mqfq::experiments::{run, summary_table};
use mqfq::plane::PlaneConfig;
use mqfq::scheduler::policies::PolicyKind;
use mqfq::workload::azure::{self, AzureConfig};
use mqfq::workload::zipf::{self, ZipfConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let kind = args.first().map(|s| s.as_str()).unwrap_or("azure");
    let d: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);

    let make = || match kind {
        "zipf" => zipf::generate(&ZipfConfig {
            total_rate: 2.0,
            duration_s: 600.0,
            seed: 1,
            ..Default::default()
        }),
        _ => azure::generate(&AzureConfig::default()),
    };

    let mut rows = Vec::new();
    for policy in [
        PolicyKind::Fcfs,
        PolicyKind::Batch,
        PolicyKind::PaellaSjf,
        PolicyKind::Eevdf,
        PolicyKind::Sfq,
        PolicyKind::Mqfq,
    ] {
        let (w, t) = make();
        let cfg = PlaneConfig {
            policy,
            d,
            ..Default::default()
        };
        rows.push(run(&format!("{} D={d}", policy.name()), w, &t, cfg).0);
    }
    println!("== policy comparison on the {kind} workload ==");
    print!("{}", summary_table(&rows).render());
    let best = rows
        .iter()
        .min_by(|a, b| a.wavg_latency_s.partial_cmp(&b.wavg_latency_s).unwrap())
        .unwrap();
    println!("\nbest policy: {}", best.label);
}
