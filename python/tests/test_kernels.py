"""Layer-1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes, block sizes and kernel parameters; fixed cases
pin the exact shapes the AOT catalog uses.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    matmul,
    diffusion,
    diffusion_step,
    block_sum,
    l2_norm,
    video_filter,
)
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

settings.register_profile("kernels", deadline=None, max_examples=20)
settings.load_profile("kernels")


def rand(seed, shape, lo=-0.5, hi=0.5):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(np.float32))


# ---------------------------------------------------------------- matmul ---

MULT8 = st.integers(1, 8).map(lambda k: 8 * k)


@given(m=MULT8, k=MULT8, n=MULT8, seed=st.integers(0, 2**32 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    x, y = rand(seed, (m, k)), rand(seed + 1, (k, n))
    got = matmul(x, y, block=(8, 8, 8))
    np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "m,k,n,block",
    [
        (8, 256, 512, (128, 128, 128)),   # imagenet layer-1 shape
        (64, 256, 256, (128, 128, 128)),  # roberta projections
        (128, 128, 128, (128, 128, 128)), # cupy / rnn
        (256, 128, 256, (64, 64, 64)),
        (128, 128, 128, (32, 128, 64)),   # non-square blocks
    ],
)
def test_matmul_catalog_shapes(m, k, n, block):
    x, y = rand(m * 31 + k, (m, k)), rand(n * 17 + k, (k, n))
    got = matmul(x, y, block=block)
    np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)


def test_matmul_rejects_indivisible():
    with pytest.raises(AssertionError):
        matmul(rand(0, (9, 8)), rand(1, (8, 8)), block=(8, 8, 8))


def test_matmul_identity():
    x = rand(5, (16, 16))
    eye = jnp.eye(16, dtype=jnp.float32)
    np.testing.assert_allclose(matmul(x, eye, block=(8, 8, 8)), x, atol=1e-6)


# --------------------------------------------------------------- stencil ---


@given(
    rows_blocks=st.integers(1, 6),
    block_rows=st.sampled_from([4, 8, 16]),
    cols=st.sampled_from([8, 16, 128]),
    coeff=st.floats(0.05, 0.9),
    seed=st.integers(0, 2**32 - 1),
)
def test_diffusion_step_matches_ref(rows_blocks, block_rows, cols, coeff, seed):
    rows = rows_blocks * block_rows
    x = rand(seed, (rows, cols))
    got = diffusion_step(x, coeff=float(coeff), block_rows=block_rows)
    np.testing.assert_allclose(
        got, ref.diffusion_step_ref(x, float(coeff)), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("iters", [1, 2, 8])
def test_diffusion_iterated(iters):
    x = rand(11, (128, 128), lo=0.0, hi=1.0)
    got = diffusion(x, iters=iters, coeff=0.2)
    np.testing.assert_allclose(
        got, ref.diffusion_ref(x, iters, 0.2), rtol=1e-4, atol=1e-5
    )


def test_diffusion_single_block_grid():
    """Whole field in one block: both halo paths take the clamped branch."""
    x = rand(13, (16, 32))
    got = diffusion_step(x, coeff=0.3, block_rows=16)
    np.testing.assert_allclose(
        got, ref.diffusion_step_ref(x, 0.3), rtol=1e-5, atol=1e-6
    )


def test_diffusion_conserves_constant_field():
    """Clamp-to-edge diffusion must leave a constant field unchanged."""
    x = jnp.full((64, 64), 0.42, dtype=jnp.float32)
    got = diffusion_step(x, coeff=0.2, block_rows=16)
    np.testing.assert_allclose(got, x, rtol=1e-6)


# ---------------------------------------------------------------- reduce ---


@given(
    rows_blocks=st.integers(1, 8),
    block_rows=st.sampled_from([4, 16, 64]),
    cols=st.sampled_from([8, 128]),
    seed=st.integers(0, 2**32 - 1),
)
def test_block_sum_matches_ref(rows_blocks, block_rows, cols, seed):
    rows = rows_blocks * block_rows
    x = rand(seed, (rows, cols))
    got = block_sum(x, block_rows=block_rows)
    np.testing.assert_allclose(
        got, ref.block_sum_ref(x), rtol=1e-4, atol=1e-4
    )


@given(seed=st.integers(0, 2**32 - 1))
def test_l2_norm_matches_ref(seed):
    x = rand(seed, (128, 128))
    np.testing.assert_allclose(
        l2_norm(x), ref.l2_norm_ref(x), rtol=1e-5, atol=1e-6
    )


def test_block_sum_zeros():
    x = jnp.zeros((64, 128), dtype=jnp.float32)
    assert float(jnp.abs(block_sum(x)).max()) == 0.0


# ------------------------------------------------------------- pointwise ---


@given(
    levels=st.integers(2, 64),
    gamma=st.floats(0.5, 3.0),
    contrast=st.floats(0.5, 2.0),
    seed=st.integers(0, 2**32 - 1),
)
def test_video_filter_matches_ref(levels, gamma, contrast, seed):
    x = rand(seed, (64, 128), lo=0.0, hi=1.0)
    got = video_filter(
        x, levels=levels, gamma=float(gamma), contrast=float(contrast),
        block=(16, 64),
    )
    want = ref.video_filter_ref(x, levels, float(gamma), float(contrast))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_video_filter_output_range():
    x = rand(3, (256, 256), lo=0.0, hi=1.0)
    y = np.asarray(video_filter(x))
    assert (y >= 0.0).all() and (y <= 1.0).all()


def test_video_filter_catalog_shape():
    x = rand(4, (256, 256), lo=0.0, hi=1.0)
    got = video_filter(x)
    np.testing.assert_allclose(
        got, ref.video_filter_ref(x), rtol=1e-4, atol=1e-5
    )
