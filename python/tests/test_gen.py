"""Known-answer tests for the deterministic input generator.

These vectors are duplicated in rust/src/runtime/goldgen.rs — the Rust
runtime regenerates identical inputs when validating artifacts, so any
drift between the two implementations must fail loudly on both sides.
"""

import numpy as np

from compile.gen import SplitMix64, fill, fnv1a


def test_splitmix64_known_answers():
    r = SplitMix64(1)
    assert [r.next_u64() for _ in range(4)] == [
        0x910A2DEC89025CC1,
        0xBEEB8DA1658EEC67,
        0xF893A2EEFB32555E,
        0x71C18690EE42C90B,
    ]


def test_fill_unit_known_answers():
    got = fill(42, (4,), "unit")
    np.testing.assert_allclose(
        got, [0.74156487, 0.15991038, 0.2786011, 0.34419066], rtol=1e-7
    )
    assert got.dtype == np.float32


def test_fill_sym_is_unit_minus_half():
    unit = fill(7, (16,), "unit")
    sym = fill(7, (16,), "sym")
    np.testing.assert_allclose(sym, unit - 0.5, rtol=0, atol=0)


def test_fill_range():
    a = fill(3, (1024,), "unit")
    assert (a >= 0.0).all() and (a < 1.0).all()
    s = fill(3, (1024,), "sym")
    assert (s >= -0.5).all() and (s < 0.5).all()


def test_fnv1a_known_answer():
    assert fnv1a("imagenet") == 0x2EA43BCC8F83E79D


def test_fill_deterministic():
    np.testing.assert_array_equal(fill(9, (8, 8)), fill(9, (8, 8)))


def test_different_seeds_differ():
    assert not np.array_equal(fill(1, (64,)), fill(2, (64,)))
