"""Layer-2 model tests: shapes, finiteness, determinism, and semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import gen
from compile.model import REGISTRY, pathfinder, needle, lud, fft

jax.config.update("jax_platform_name", "cpu")


def inputs_for(name):
    _, specs = REGISTRY[name]
    seed = gen.fnv1a(name)
    return [
        gen.fill(seed + i, shape, kind) for i, (shape, kind) in enumerate(specs)
    ]


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_model_runs_and_is_finite(name):
    fn, _ = REGISTRY[name]
    outs = fn(*inputs_for(name))
    assert isinstance(outs, tuple) and len(outs) >= 1
    for o in outs:
        arr = np.asarray(o)
        assert arr.dtype == np.float32, f"{name} output dtype {arr.dtype}"
        assert np.isfinite(arr).all(), f"{name} produced non-finite values"


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_model_deterministic(name):
    fn, _ = REGISTRY[name]
    a = fn(*inputs_for(name))
    b = fn(*inputs_for(name))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_imagenet_softmax_rows_sum_to_one():
    fn, _ = REGISTRY["imagenet"]
    (probs,) = fn(*inputs_for("imagenet"))
    np.testing.assert_allclose(np.asarray(probs).sum(axis=-1), 1.0, rtol=1e-5)


def test_pathfinder_matches_naive_dp():
    rng = np.random.default_rng(0)
    grid = rng.uniform(0, 1, size=(16, 32)).astype(np.float32)
    (got,) = pathfinder(jnp.asarray(grid))
    dp = grid[0].copy()
    for r in range(1, 16):
        left = np.concatenate([dp[:1], dp[:-1]])
        right = np.concatenate([dp[1:], dp[-1:]])
        dp = grid[r] + np.minimum(dp, np.minimum(left, right))
    np.testing.assert_allclose(got, dp, rtol=1e-5, atol=1e-6)


def test_needle_rows_monotone_along_scan():
    """The cumulative-max column scan makes each DP row non-decreasing."""
    (final, last_row) = needle(inputs_for("needle")[0])
    arr = np.asarray(final)
    assert (np.diff(arr) >= -1e-6).all()


def test_lud_schur_shape_and_scale():
    (schur,) = lud(*inputs_for("lud"))
    assert schur.shape == (128, 128)
    # Regularized Newton–Schulz inverse keeps the update bounded.
    assert float(np.abs(np.asarray(schur)).max()) < 1e3


def test_fft_lowpass_removes_high_frequencies():
    n = 16384
    t = np.arange(n, dtype=np.float32)
    low = np.sin(2 * np.pi * 5 * t / n).astype(np.float32)
    high = np.sin(2 * np.pi * 6000 * t / n).astype(np.float32)
    (filt, _) = fft(jnp.asarray(low + high))
    # keep = n//2//4 ≈ 2048 bins: the 6 kHz-bin component must be gone.
    np.testing.assert_allclose(np.asarray(filt), low, atol=5e-2)


def test_registry_shapes_match_manifest_conventions():
    for name, (fn, specs) in REGISTRY.items():
        for shape, kind in specs:
            assert kind in ("unit", "sym"), (name, kind)
            assert all(d > 0 for d in shape), (name, shape)
