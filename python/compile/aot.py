"""AOT pipeline: lower every catalog function to HLO text + golden manifest.

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (behind the
Rust `xla` 0.1.6 crate) rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  <name>.hlo.txt     one per catalog function, lowered with return_tuple=True
  manifest.txt       plain-text manifest the Rust runtime parses:
                         fn <name>
                         in <d0>x<d1>... <unit|sym>
                         out <idx> <d0>x<d1>... l2=<f> first=<f,f,f,f>
                         end

Golden outputs are computed here with the same deterministic inputs the
Rust side regenerates (gen.py / goldgen.rs), so `cargo test` can validate
every artifact end-to-end without binary tensor files.

Usage:  cd python && python -m compile.aot [--out-dir ../artifacts] [--only fn]
"""

import argparse
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import gen
from .model import REGISTRY

jax.config.update("jax_platform_name", "cpu")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def example_inputs(name: str, specs):
    seed = gen.fnv1a(name)
    return [
        gen.fill(seed + i, shape, kind) for i, (shape, kind) in enumerate(specs)
    ]


def shape_str(shape) -> str:
    return "x".join(str(d) for d in shape)


def lower_one(name: str, out_dir: str, manifest_lines: list) -> None:
    fn, specs = REGISTRY[name]
    inputs = example_inputs(name, specs)
    lowered = jax.jit(fn).lower(*inputs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)

    outputs = fn(*[np.asarray(a) for a in inputs])
    manifest_lines.append(f"fn {name}")
    for (shape, kind) in specs:
        manifest_lines.append(f"in {shape_str(shape)} {kind}")
    for idx, out in enumerate(outputs):
        arr = np.asarray(out, dtype=np.float32).reshape(-1)
        l2 = float(np.sqrt(np.sum(arr.astype(np.float64) ** 2)))
        first = ",".join(f"{v:.8e}" for v in arr[:4])
        manifest_lines.append(
            f"out {idx} {shape_str(np.asarray(out).shape)} l2={l2:.8e} first={first}"
        )
    manifest_lines.append("end")
    print(f"  {name}: {len(text)} chars, {len(outputs)} output(s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--only", default=None, help="lower a single function")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = [args.only] if args.only else list(REGISTRY)
    manifest_lines: list = []
    for name in names:
        lower_one(name, args.out_dir, manifest_lines)

    if not args.only:
        with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
            f.write("\n".join(manifest_lines) + "\n")
        print(f"wrote {len(names)} artifacts + manifest.txt to {args.out_dir}")


if __name__ == "__main__":
    main()
