"""Deterministic input generation shared (by construction) with Rust.

The Rust runtime regenerates the exact same f32 inputs when validating
artifacts against the golden manifest, so no binary tensor interchange is
needed.  Both sides implement:

    splitmix64(state):  state += 0x9E3779B97F4A7C15
                        z = state
                        z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9
                        z = (z ^ (z >> 27)) * 0x94D049BB133111EB
                        return z ^ (z >> 31)

    to_unit_f32(u64):   ((u >> 40) as f32) / 2^24          in [0, 1)
    sym:                unit - 0.5                          in [-0.5, 0.5)

The Rust twin lives in rust/src/runtime/goldgen.rs — keep in sync.
"""

import numpy as np

MASK = (1 << 64) - 1


class SplitMix64:
    def __init__(self, seed: int):
        self.state = seed & MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return (z ^ (z >> 31)) & MASK


def fill(seed: int, shape, kind: str = "sym") -> np.ndarray:
    """Deterministic f32 array; kind is 'unit' ([0,1)) or 'sym' ([-0.5,0.5))."""
    rng = SplitMix64(seed)
    n = int(np.prod(shape))
    out = np.empty(n, dtype=np.float32)
    for i in range(n):
        out[i] = np.float32(rng.next_u64() >> 40) / np.float32(1 << 24)
    if kind == "sym":
        out -= np.float32(0.5)
    elif kind != "unit":
        raise ValueError(f"unknown kind {kind}")
    return out.reshape(shape)


def fnv1a(name: str) -> int:
    """Stable per-function seed (FNV-1a 64 of the function name)."""
    h = 0xCBF29CE484222325
    for b in name.encode():
        h = ((h ^ b) * 0x100000001B3) & MASK
    return h
