"""Layer-2 JAX compute graphs: the serverless function catalog.

One entry per function class from the paper's Table 1 (plus ``cupy``,
``rnn`` and ``srad`` which appear in Figures 3, 5a and 7b).  These are the
*bodies* of the black-box functions that MQFQ-Sticky schedules: in the
paper they are TensorFlow / ffmpeg / Rodinia binaries inside CUDA
containers; here they are JAX graphs whose hot-spots are the Layer-1
Pallas kernels, AOT-lowered to HLO text by aot.py and executed by the
Rust runtime via PJRT.

Every function takes a fixed tuple of f32 arrays and returns a tuple of
f32 arrays (complex intermediates are kept internal), which keeps the Rust
literal handling uniform.
"""

import jax
import jax.numpy as jnp

from .kernels import (
    matmul,
    diffusion,
    block_sum,
    l2_norm,
    video_filter,
)

# ---------------------------------------------------------------------------
# Function bodies
# ---------------------------------------------------------------------------


def imagenet(x, w1, w2, w3):
    """CNN-classifier proxy: 3-layer MLP + softmax over 1000-ish classes."""
    h = jax.nn.relu(matmul(x, w1))
    h = jax.nn.relu(matmul(h, w2))
    logits = matmul(h, w3)
    return (jax.nn.softmax(logits, axis=-1),)


def roberta(x, wq, wk, wv, wo, wf1, wf2):
    """Transformer-encoder-layer proxy: self-attention + GeLU FFN."""
    q = matmul(x, wq)
    k = matmul(x, wk)
    v = matmul(x, wv)
    scores = jnp.einsum("sd,td->st", q, k) / jnp.sqrt(jnp.float32(q.shape[-1]))
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = matmul(attn.astype(x.dtype), v) if attn.shape[-1] % 8 == 0 else attn @ v
    y = matmul(ctx, wo) + x
    h = jax.nn.gelu(matmul(y, wf1))
    out = matmul(h, wf2) + y
    return (out,)


def ffmpeg(frame):
    """Video-transcode proxy: fused filter pass + per-frame luma stats."""
    filtered = video_filter(frame)
    stats = block_sum(filtered) / jnp.float32(frame.shape[0])
    return (filtered, stats)


def fft(signal):
    """HPC FFT proxy: low-pass in the frequency domain + spectral energy."""
    n = signal.shape[0]
    spec = jnp.fft.rfft(signal)
    keep = spec.shape[0] // 4
    mask = (jnp.arange(spec.shape[0]) < keep).astype(spec.dtype)
    filtered = jnp.fft.irfft(spec * mask, n=n).astype(jnp.float32)
    mag = jnp.abs(spec).astype(jnp.float32)[: (spec.shape[0] // 128) * 128]
    energy = l2_norm(mag.reshape(-1, 128))
    return (filtered, energy.reshape(1))


def isoneural(x, w1, w2):
    """Small-inference proxy (the paper's fastest GPU function)."""
    h = jnp.tanh(matmul(x, w1))
    y = matmul(h, w2)
    stats = block_sum(y)
    return (y, stats)


def lud(a):
    """Rodinia LU-decomposition proxy: blocked Schur-complement updates.

    The Rodinia kernel's hot-spot is the trailing-submatrix update
    A22 -= A21 @ A12 — exactly an MXU matmul — iterated over diagonal
    blocks.  We run the update sweep with the Pallas matmul.
    """
    n = a.shape[0]
    b = n // 2
    a11, a12 = a[:b, :b], a[:b, b:]
    a21, a22 = a[b:, :b], a[b:, b:]
    # One level of blocked elimination (regularized to stay well-conditioned).
    d = a11 + 2.0 * jnp.eye(b, dtype=a.dtype)
    schur = a22 - matmul(matmul(a21, _inv_approx(d)), a12)
    return (schur,)


def _inv_approx(d, iters=6):
    """Newton–Schulz inverse (keeps everything as matmuls for the MXU)."""
    norm = jnp.sum(jnp.abs(d), axis=1).max()
    x = d.T / (norm * norm)
    eye2 = 2.0 * jnp.eye(d.shape[0], dtype=d.dtype)
    for _ in range(iters):
        x = matmul(x, eye2 - matmul(d, x))
    return x


def needle(seq_scores):
    """Needleman–Wunsch proxy: anti-diagonal DP over a similarity matrix."""
    n = seq_scores.shape[0]
    gap = jnp.float32(-0.33)

    def row_step(prev_row, sim_row):
        # DP recurrence vectorized along the row; the column scan is a
        # cumulative max that lax handles natively.
        up = prev_row + gap
        diag = jnp.concatenate([prev_row[:1] + gap, prev_row[:-1]]) + sim_row
        best = jnp.maximum(up, diag)
        best = jax.lax.associative_scan(jnp.maximum, best)
        return best, best

    init = jnp.arange(n, dtype=jnp.float32) * gap
    final, rows = jax.lax.scan(row_step, init, seq_scores)
    return (final, rows[-1:, :])


def pathfinder(grid):
    """Rodinia pathfinder proxy: bottom-up min-path DP over a cost grid."""
    def step(carry, row):
        left = jnp.concatenate([carry[:1], carry[:-1]])
        right = jnp.concatenate([carry[1:], carry[-1:]])
        carry = row + jnp.minimum(carry, jnp.minimum(left, right))
        return carry, ()

    out, _ = jax.lax.scan(step, grid[0], grid[1:])
    return (out,)


def cupy(x, y):
    """Generic dense-compute proxy used in the Fig-5a fairness experiment."""
    z = matmul(x, y)
    return (jnp.tanh(z),)


def rnn(xs, wx, wh):
    """Sequence-model proxy (Fig 7b): scan of matmul recurrences."""
    def step(h, x_t):
        h = jnp.tanh(matmul(x_t, wx) + matmul(h, wh))
        return h, h

    h0 = jnp.zeros((xs.shape[1], wh.shape[0]), dtype=xs.dtype)
    h_final, _ = jax.lax.scan(step, h0, xs)
    return (h_final,)


def srad(img):
    """SRAD despeckling proxy (Figs 3/7b): iterated diffusion stencil."""
    return (diffusion(img, iters=8, coeff=0.2),)


# ---------------------------------------------------------------------------
# Registry: name -> (fn, [(shape, kind), ...])
# kind: 'unit' -> U[0,1), 'sym' -> U[-0.5,0.5)   (see gen.py)
# ---------------------------------------------------------------------------

REGISTRY = {
    "imagenet": (
        imagenet,
        [((8, 256), "sym"), ((256, 512), "sym"), ((512, 512), "sym"),
         ((512, 256), "sym")],
    ),
    "roberta": (
        roberta,
        [((64, 256), "sym")] + [((256, 256), "sym")] * 4
        + [((256, 512), "sym"), ((512, 256), "sym")],
    ),
    "ffmpeg": (ffmpeg, [((256, 256), "unit")]),
    "fft": (fft, [((16384,), "sym")]),
    "isoneural": (
        isoneural,
        [((64, 128), "sym"), ((128, 128), "sym"), ((128, 128), "sym")],
    ),
    "lud": (lud, [((256, 256), "sym")]),
    "needle": (needle, [((128, 128), "sym")]),
    "pathfinder": (pathfinder, [((128, 256), "unit")]),
    "cupy": (cupy, [((128, 128), "sym"), ((128, 128), "sym")]),
    "rnn": (
        rnn,
        [((16, 64, 128), "sym"), ((128, 128), "sym"), ((128, 128), "sym")],
    ),
    "srad": (srad, [((128, 128), "unit")]),
}
