"""Grid-strided block reduction — the HPC post-processing hot-spot.

TPU adaptation of the warp-shuffle tree reductions in the paper's HPC
functions (``fft`` magnitude/energy, ``isoneural``): instead of warp
shuffles, each grid step reduces one (bm, cols) VMEM block into a single
(1, cols) accumulator block that stays resident across the whole grid
(constant output index map), i.e. a grid-strided partial reduction.  The
final cross-column fold is a cheap jnp op in the caller.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_sum_kernel(x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(x_ref[...], axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def block_sum(x: jax.Array, *, block_rows: int = 64) -> jax.Array:
    """Column-wise sum of a 2-D array via a grid-strided Pallas reduction.

    Returns a (1, cols) array; callers fold columns as needed.
    """
    rows, cols = x.shape
    bm = min(block_rows, rows)
    assert rows % bm == 0, f"{rows} rows not divisible by block {bm}"
    return pl.pallas_call(
        _block_sum_kernel,
        grid=(rows // bm,),
        in_specs=[pl.BlockSpec((bm, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, cols), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, cols), x.dtype),
        interpret=True,
    )(x)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def l2_norm(x: jax.Array, *, block_rows: int = 64) -> jax.Array:
    """Scalar L2 norm computed through the block_sum kernel."""
    partial = block_sum(x * x, block_rows=block_rows)
    return jnp.sqrt(jnp.sum(partial))
