"""Row-blocked 5-point diffusion stencil — the SRAD/pathfinder hot-spot.

TPU adaptation of the Rodinia CUDA stencils (Table 1 ``pathfinder`` and the
Fig-3 ``srad`` function): the CUDA version exchanges halos through
threadblock shared memory; here each grid step owns a (bm, N) row block in
VMEM and the halo rows arrive as *extra BlockSpecs over the same input*
with clamped index maps (prev / cur / next row block).  Boundary rows are
handled with clamp-to-edge semantics inside the kernel, matching the
oracle in ref.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _diffusion_kernel(prev_ref, cur_ref, next_ref, o_ref, *, coeff, rows):
    """out = (1-c)*x + c/4 * (up + down + left + right), clamp-to-edge."""
    i = pl.program_id(0)
    ni = pl.num_programs(0)
    x = cur_ref[...]
    bm = x.shape[0]

    # Row shifted up by one (row r reads r-1).  The first row of the block
    # comes from the previous block's last row; for the global first block
    # clamp to the block's own first row.
    up_inner = jnp.concatenate([prev_ref[-1:, :], x[:-1, :]], axis=0)
    up_first = jnp.concatenate([x[:1, :], x[:-1, :]], axis=0)
    up = jnp.where(i == 0, up_first, up_inner)

    # Row shifted down by one (row r reads r+1); symmetric at the last block.
    down_inner = jnp.concatenate([x[1:, :], next_ref[:1, :]], axis=0)
    down_last = jnp.concatenate([x[1:, :], x[-1:, :]], axis=0)
    down = jnp.where(i == ni - 1, down_last, down_inner)

    # Columns clamp to edge within the full row (blocks span all columns).
    left = jnp.concatenate([x[:, :1], x[:, :-1]], axis=1)
    right = jnp.concatenate([x[:, 1:], x[:, -1:]], axis=1)

    o_ref[...] = (1.0 - coeff) * x + (coeff / 4.0) * (up + down + left + right)


@functools.partial(jax.jit, static_argnames=("coeff", "block_rows"))
def diffusion_step(x: jax.Array, *, coeff: float = 0.2, block_rows: int = 32):
    """One diffusion step over a 2-D f32 field."""
    rows, cols = x.shape
    bm = min(block_rows, rows)
    assert rows % bm == 0, f"{rows} rows not divisible by block {bm}"
    grid = (rows // bm,)

    def clamped(delta):
        def index_map(i):
            j = i + delta
            return (jnp.clip(j, 0, grid[0] - 1), 0)

        return pl.BlockSpec((bm, cols), index_map)

    kernel = functools.partial(_diffusion_kernel, coeff=coeff, rows=rows)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[clamped(-1), clamped(0), clamped(+1)],
        out_specs=pl.BlockSpec((bm, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=True,
    )(x, x, x)


@functools.partial(jax.jit, static_argnames=("iters", "coeff", "block_rows"))
def diffusion(x: jax.Array, *, iters: int = 4, coeff: float = 0.2,
              block_rows: int = 32):
    """``iters`` diffusion steps via lax.fori_loop (keeps the HLO small)."""
    def body(_, v):
        return diffusion_step(v, coeff=coeff, block_rows=block_rows)

    return jax.lax.fori_loop(0, iters, body, x)
