"""Fused elementwise video filter — the ffmpeg-function hot-spot.

TPU adaptation of the paper's ``ffmpeg`` video function (Table 1): the GPU
version leans on NVENC + CUDA elementwise passes; the transcoding-adjacent
arithmetic (gamma correction, levels quantization, contrast) is modelled as
one fused VPU pass over (bm, bn) VMEM tiles.  Fusing all three stages into
a single kernel is exactly the optimization the CUDA version gets from
kernel fusion — one HBM round-trip instead of three.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _video_kernel(x_ref, o_ref, *, levels, gamma, contrast):
    x = x_ref[...]
    # Gamma correction on [0, 1] pixels (exp/log on the VPU).
    g = jnp.exp(jnp.log(jnp.maximum(x, 1e-6)) * gamma)
    # Levels quantization to `levels` bands (posterize).
    q = jnp.round(g * (levels - 1)) / (levels - 1)
    # Contrast stretch around mid-gray, saturated back to [0, 1].
    c = (q - 0.5) * contrast + 0.5
    o_ref[...] = jnp.clip(c, 0.0, 1.0)


@functools.partial(
    jax.jit, static_argnames=("levels", "gamma", "contrast", "block")
)
def video_filter(
    x: jax.Array,
    *,
    levels: int = 16,
    gamma: float = 1.8,
    contrast: float = 1.2,
    block=(64, 128),
) -> jax.Array:
    """Fused gamma -> posterize -> contrast over a 2-D frame in [0, 1]."""
    rows, cols = x.shape
    bm, bn = min(block[0], rows), min(block[1], cols)
    assert rows % bm == 0 and cols % bn == 0, (
        f"frame {(rows, cols)} not divisible by block {(bm, bn)}"
    )
    kernel = functools.partial(
        _video_kernel, levels=levels, gamma=gamma, contrast=contrast
    )
    return pl.pallas_call(
        kernel,
        grid=(rows // bm, cols // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=True,
    )(x)
