"""Pure-jnp oracles for every Layer-1 Pallas kernel.

These are the correctness ground truth: small, obviously-correct jnp
implementations with no Pallas, no blocking, no grids.  The pytest +
hypothesis suite asserts kernel == oracle across shape/dtype/parameter
sweeps (python/tests/test_kernels.py).
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    return jnp.dot(x, y, preferred_element_type=x.dtype)


def diffusion_step_ref(x, coeff=0.2):
    """5-point diffusion with clamp-to-edge boundaries."""
    up = jnp.concatenate([x[:1, :], x[:-1, :]], axis=0)
    down = jnp.concatenate([x[1:, :], x[-1:, :]], axis=0)
    left = jnp.concatenate([x[:, :1], x[:, :-1]], axis=1)
    right = jnp.concatenate([x[:, 1:], x[:, -1:]], axis=1)
    return (1.0 - coeff) * x + (coeff / 4.0) * (up + down + left + right)


def diffusion_ref(x, iters=4, coeff=0.2):
    for _ in range(iters):
        x = diffusion_step_ref(x, coeff)
    return x


def block_sum_ref(x):
    return jnp.sum(x, axis=0, keepdims=True)


def l2_norm_ref(x):
    return jnp.sqrt(jnp.sum(x * x))


def video_filter_ref(x, levels=16, gamma=1.8, contrast=1.2):
    g = jnp.exp(jnp.log(jnp.maximum(x, 1e-6)) * gamma)
    q = jnp.round(g * (levels - 1)) / (levels - 1)
    c = (q - 0.5) * contrast + 0.5
    return jnp.clip(c, 0.0, 1.0)
