"""Layer-1 Pallas kernels for the MQFQ-Sticky function catalog.

Each kernel is the compute hot-spot of one serverless function class from
the paper's Table 1 (ML inference, HPC, stencil, video).  Kernels are
written for the TPU execution model (VMEM blocks via BlockSpec, MXU-shaped
matmul tiles, VPU-friendly elementwise tiles) but lowered with
``interpret=True`` so the resulting HLO runs on the CPU PJRT client that
the Rust runtime embeds.  ``ref.py`` holds the pure-jnp oracles used by the
pytest/hypothesis correctness suite.
"""

from .matmul import matmul, DEFAULT_BLOCK as MATMUL_DEFAULT_BLOCK
from .stencil import diffusion_step, diffusion
from .reduce import block_sum, l2_norm
from .pointwise import video_filter

__all__ = [
    "matmul",
    "MATMUL_DEFAULT_BLOCK",
    "diffusion_step",
    "diffusion",
    "block_sum",
    "l2_norm",
    "video_filter",
]
