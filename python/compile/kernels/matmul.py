"""Tiled Pallas matmul — the MXU hot-spot of the ML-inference functions.

TPU adaptation of the CUDA kernels behind the paper's ``imagenet`` /
``roberta`` functions (Table 1): instead of threadblock shared-memory
tiling, the HBM->VMEM schedule is expressed with a 3-D grid and BlockSpecs.
The K axis is the innermost (fastest-varying) grid dimension, so each
(i, j) output tile stays resident in VMEM while partial products are
accumulated across K — the canonical MXU-friendly schedule.

VMEM footprint per step with the default 128x128x128 f32 blocks:
    x-tile 64 KiB + y-tile 64 KiB + o-tile 64 KiB = 192 KiB  (<< ~16 MiB VMEM)
MXU utilization estimate: each step issues a 128x128x128 contraction =
2^21 MACs, fully MXU-shaped; estimated >= 80% of the matmul roofline for
M, N, K >= 512 (see DESIGN.md section 7).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# (bm, bk, bn) — MXU-shaped default tile.
DEFAULT_BLOCK = (128, 128, 128)


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (i, j, k) grid step: accumulate x_tile @ y_tile into o_tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block",))
def matmul(x: jax.Array, y: jax.Array, *, block=DEFAULT_BLOCK) -> jax.Array:
    """Blocked ``x @ y`` via Pallas.

    Dimensions must be divisible by the block shape; the L2 models pick
    shapes that are (padding is a model-level concern, mirroring how the
    paper's functions feed fixed-shape tensors to their kernels).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    bm, bk, bn = block
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (
        f"shape {(m, k, n)} not divisible by block {(bm, bk, bn)}"
    )

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, y)
