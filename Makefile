# Convenience targets; see ROADMAP.md for the tier-1 verify.

.PHONY: check test smoke bench-perf bench-cluster bench-hetero bench-serving bench-elastic bench-anticipate bench-faults artifacts

# Build + test + clippy-clean + serving smoke (the full local gate).
check:
	bash scripts/check.sh
	bash scripts/serve_smoke.sh

test:
	cargo test -q

# End-to-end serving smoke: `serve --shards 4 --router sticky` driven
# by a python3 protocol-v1 client (sync, async tickets, errors, legacy).
smoke:
	bash scripts/serve_smoke.sh

# Regenerate the §Perf hot-path numbers and BENCH_perf.json.
bench-perf:
	cargo bench --bench perf_hot_paths

# Regenerate the cluster scaling sweep and BENCH_cluster.json.
# Compare against a previous run: scripts/bench_diff.sh OLD.json BENCH_cluster.json
bench-cluster:
	cargo bench --bench fig9_cluster_scaling

# Regenerate the heterogeneous-fleet sweep and BENCH_hetero.json.
# Compare against a previous run: scripts/bench_diff.sh OLD.json BENCH_hetero.json
bench-hetero:
	cargo bench --bench fig10_heterogeneous

# Regenerate the serving-path throughput sweep and BENCH_serving.json
# (closed/open-loop load generators over loopback TCP). Quick smoke:
# SERVING_QUICK=1 make bench-serving.
# Compare against a previous run: scripts/bench_diff.sh OLD.json BENCH_serving.json
bench-serving:
	cargo bench --bench serving_throughput

# Regenerate the elastic-membership storm (sim + TCP kill storm) and
# BENCH_elastic.json. Quick smoke: ELASTIC_QUICK=1 make bench-elastic.
# Compare against a previous run: scripts/bench_diff.sh OLD.json BENCH_elastic.json
bench-elastic:
	cargo bench --bench elastic_membership

# Regenerate the anticipatory-scheduling ablation (grace x batch x
# estimator on the bursty and Azure traces) and BENCH_anticipate.json.
# Quick smoke: ANTICIPATE_QUICK=1 make bench-anticipate.
# Compare against a previous run: scripts/bench_diff.sh OLD.json BENCH_anticipate.json
bench-anticipate:
	cargo bench --bench anticipate_ablation

# Regenerate the fault-tolerance storm (device failure/recovery,
# transient retries, poison-tenant breaker, overload shedding — sim +
# TCP) and BENCH_faults.json. Quick smoke: FAULTS_QUICK=1 make bench-faults.
# Compare against a previous run: scripts/bench_diff.sh OLD.json BENCH_faults.json
bench-faults:
	cargo bench --bench fault_storm

# AOT-lower the python/JAX function bodies to HLO artifacts where the
# rust runtime (rust/artifacts/) looks for them.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts
