//! Property tests for the heterogeneous-fleet refactor's equivalence
//! contract: building a fleet from *identical* `DeviceSpec`s must be
//! event-for-event indistinguishable from the pre-refactor uniform
//! construction (now the `uniform`/`PlaneConfig::uniform` conveniences,
//! which transcribe the old `(n, profile, mode)` rule verbatim), across
//! all policies and routers — full `InvRecord`-stream equality. Plus:
//! the capacity-weighted StickyCh ring with equal shard capacities must
//! be bit-identical to the capacity-blind ablation, and genuinely mixed
//! clusters must still conserve and drain every invocation.

use mqfq::cluster::{ClusterConfig, RouterKind, ALL_ROUTERS};
use mqfq::gpu::{uniform_fleet, DeviceSpec, MultiplexMode, A30, V100};
use mqfq::plane::PlaneConfig;
use mqfq::scheduler::policies::PolicyKind;
use mqfq::scheduler::MqfqConfig;
use mqfq::sim::{replay, replay_cluster};
use mqfq::types::{secs, FuncId};
use mqfq::util::prop::{assert_prop, Gen};
use mqfq::workload::catalog::CATALOG;
use mqfq::workload::trace::{Trace, TraceEvent, Workload};

const ALL_POLICIES: [PolicyKind; 6] = [
    PolicyKind::Fcfs,
    PolicyKind::Batch,
    PolicyKind::PaellaSjf,
    PolicyKind::Eevdf,
    PolicyKind::Sfq,
    PolicyKind::Mqfq,
];

/// Random workload + open-loop trace (mirrors prop_cluster's shape).
fn gen_scenario(g: &mut Gen) -> (Workload, Trace) {
    let n_funcs = g.int(1, 10);
    let mut w = Workload::default();
    for i in 0..n_funcs {
        let class = &CATALOG[g.int(0, CATALOG.len() - 1)];
        w.register(class, i, g.f64(0.5, 20.0));
    }
    let n_events = g.int(1, 100);
    let horizon = g.f64(10.0, 240.0);
    let mut t = Trace::default();
    for _ in 0..n_events {
        t.events.push(TraceEvent {
            at: secs(g.f64(0.0, horizon)),
            func: FuncId(g.int(0, n_funcs - 1) as u32),
        });
    }
    t.sort();
    (w, t)
}

fn gen_uniform_spec(g: &mut Gen) -> DeviceSpec {
    let profile = *g.choose(&[V100, A30]);
    let mode = *g.choose(&[
        MultiplexMode::Plain,
        MultiplexMode::Mps,
        MultiplexMode::Mig(2),
    ]);
    let mut spec = DeviceSpec::new(profile, mode);
    if g.bool(0.3) {
        spec = spec.with_d(g.int(1, 3));
    }
    spec
}

fn base_plane(g: &mut Gen, policy: PolicyKind) -> PlaneConfig {
    PlaneConfig {
        policy,
        d: g.int(1, 3),
        pool_size: g.int(2, 32),
        mqfq: MqfqConfig {
            t: g.f64(0.0, 20.0),
            ttl_alpha: g.f64(0.0, 4.0),
            ..Default::default()
        },
        ..Default::default()
    }
}

/// Plane level: the pre-refactor uniform construction (plane-level D,
/// no overrides — `PlaneConfig::uniform`'s shape) replays
/// byte-identically to a fleet of explicitly repeated identical specs
/// that pin the *same* D per device while the plane-level `d` field is
/// set to an unrelated value. Non-vacuous: the two configs differ (the
/// override path must fully shadow the plane-level D in slot math,
/// `policy_d`, and `check_invariants`), yet every policy must produce
/// the same record stream, makespan, events, pool stats, and
/// utilization integral.
#[test]
fn prop_identical_specs_match_uniform_plane() {
    assert_prop("identical-spec plane equivalence", 36, |g| {
        let (w, t) = gen_scenario(g);
        let policy = *g.choose(&ALL_POLICIES);
        let profile = *g.choose(&[V100, A30]);
        let mode = *g.choose(&[
            MultiplexMode::Plain,
            MultiplexMode::Mps,
            MultiplexMode::Mig(2),
        ]);
        let n = g.int(1, 3);
        let plane_d = g.int(1, 3);

        // Old shape: uniform fleet, concurrency from the plane-level D.
        let mut uniform_cfg = base_plane(g, policy);
        uniform_cfg.d = plane_d;
        uniform_cfg.devices = uniform_fleet(n, profile, mode);
        // New shape: the same D pinned per device; the plane-level `d`
        // is deliberately different and must be fully shadowed.
        let mut explicit_cfg = uniform_cfg.clone();
        explicit_cfg.d = g.int(1, 4);
        let spec = DeviceSpec::new(profile, mode).with_d(plane_d);
        explicit_cfg.devices = (0..n).map(|_| spec).collect();

        let a = replay(w.clone(), &t, uniform_cfg);
        let b = replay(w, &t, explicit_cfg);
        let ctx = format!(
            "policy={} n={n} profile={} mode={mode:?} d={plane_d}",
            policy.name(),
            profile.name,
        );
        if a.events != b.events || a.makespan != b.makespan {
            return Err(format!("{ctx}: events/makespan diverged"));
        }
        if a.recorder().records != b.recorder().records {
            return Err(format!("{ctx}: record streams diverged"));
        }
        if a.plane.pool_stats() != b.plane.pool_stats() {
            return Err(format!("{ctx}: pool stats diverged"));
        }
        if (a.mean_util - b.mean_util).abs() > 1e-12 {
            return Err(format!("{ctx}: mean util diverged"));
        }
        Ok(())
    });
}

/// Cluster level: explicit per-shard plane configs, all identical, must
/// replay byte-identically to the shared-plane construction under every
/// router (including the capacity-blind sticky ablation) — the
/// shard-capacity plumbing and weighted ring must vanish when shards
/// are equal.
#[test]
fn prop_identical_shard_planes_match_shared_plane() {
    let routers: Vec<RouterKind> = ALL_ROUTERS
        .into_iter()
        .chain([RouterKind::StickyChBlind])
        .collect();
    assert_prop("identical shard-plane equivalence", 30, |g| {
        let (w, t) = gen_scenario(g);
        let policy = *g.choose(&ALL_POLICIES);
        let mut plane = base_plane(g, policy);
        let spec = gen_uniform_spec(g);
        plane.devices = (0..g.int(1, 2)).map(|_| spec).collect();
        let n_shards = g.int(1, 6);
        let router = *g.choose(&routers);
        let seed = g.int(0, 1 << 20) as u64;
        let load_factor = g.f64(1.0, 3.0);

        let shared = ClusterConfig {
            n_shards,
            router,
            plane: plane.clone(),
            shard_planes: Vec::new(),
            load_factor,
            seed,
            ..Default::default()
        };
        let explicit = ClusterConfig {
            shard_planes: vec![plane.clone(); n_shards],
            ..shared.clone()
        };
        let a = replay_cluster(w.clone(), &t, shared);
        let b = replay_cluster(w, &t, explicit);
        let ctx = format!(
            "router={} policy={} shards={n_shards}",
            router.name(),
            policy.name()
        );
        if a.events != b.events || a.makespan != b.makespan {
            return Err(format!("{ctx}: events/makespan diverged"));
        }
        if a.cluster.routed != b.cluster.routed {
            return Err(format!(
                "{ctx}: routing diverged {:?} vs {:?}",
                a.cluster.routed, b.cluster.routed
            ));
        }
        if a.cluster.spills() != b.cluster.spills() {
            return Err(format!("{ctx}: spill counts diverged"));
        }
        if a.recorder().records != b.recorder().records {
            return Err(format!("{ctx}: record streams diverged"));
        }
        Ok(())
    });
}

/// Uniform capacities make the weighted StickyCh ring identical to the
/// blind one: full replay equality between the two router kinds on any
/// uniform cluster.
#[test]
fn prop_weighted_sticky_equals_blind_on_uniform_clusters() {
    assert_prop("weighted≡blind sticky on uniform fleets", 24, |g| {
        let (w, t) = gen_scenario(g);
        let mut plane = base_plane(g, *g.choose(&ALL_POLICIES));
        let spec = gen_uniform_spec(g);
        plane.devices = (0..g.int(1, 2)).map(|_| spec).collect();
        let base = ClusterConfig {
            n_shards: g.int(1, 8),
            router: RouterKind::StickyCh,
            plane,
            shard_planes: Vec::new(),
            load_factor: g.f64(1.0, 3.0),
            seed: g.int(0, 1 << 20) as u64,
            ..Default::default()
        };
        let blind_cfg = ClusterConfig {
            router: RouterKind::StickyChBlind,
            ..base.clone()
        };
        let a = replay_cluster(w.clone(), &t, base.clone());
        let b = replay_cluster(w, &t, blind_cfg);
        let ctx = format!("shards={}", base.n_shards);
        if a.cluster.routed != b.cluster.routed {
            return Err(format!(
                "{ctx}: routing diverged {:?} vs {:?}",
                a.cluster.routed, b.cluster.routed
            ));
        }
        if a.cluster.spills() != b.cluster.spills() {
            return Err(format!("{ctx}: spill counts diverged"));
        }
        if a.recorder().records != b.recorder().records {
            return Err(format!("{ctx}: record streams diverged"));
        }
        Ok(())
    });
}

/// Genuinely mixed clusters (random per-shard fleets, including MIG
/// slices and D overrides) conserve work: every arrival completes
/// exactly once and the cluster fully drains, under every router.
#[test]
fn prop_mixed_clusters_conserve_invocations() {
    let routers: Vec<RouterKind> = ALL_ROUTERS
        .into_iter()
        .chain([RouterKind::StickyChBlind])
        .collect();
    assert_prop("mixed-fleet conservation", 24, |g| {
        let (w, t) = gen_scenario(g);
        let n = t.len();
        let n_shards = g.int(2, 5);
        let shard_planes: Vec<PlaneConfig> = (0..n_shards)
            .map(|_| {
                let mut p = base_plane(g, *g.choose(&ALL_POLICIES));
                let n_gpus = g.int(1, 2);
                p.devices = (0..n_gpus).map(|_| gen_uniform_spec(g)).collect();
                p
            })
            .collect();
        let cfg = ClusterConfig {
            n_shards,
            router: *g.choose(&routers),
            plane: PlaneConfig::default(),
            shard_planes,
            load_factor: g.f64(1.0, 3.0),
            seed: g.int(0, 1 << 20) as u64,
            ..Default::default()
        };
        let ctx = format!("shards={n_shards} router={}", cfg.router.name());
        let r = replay_cluster(w, &t, cfg);
        if r.recorder().len() != n {
            return Err(format!(
                "{ctx}: {n} arrivals but {} completions",
                r.recorder().len()
            ));
        }
        if r.cluster.pending() != 0 || r.cluster.in_flight() != 0 {
            return Err(format!(
                "{ctx}: not drained ({} pending, {} in flight)",
                r.cluster.pending(),
                r.cluster.in_flight()
            ));
        }
        for (s, shard) in r.cluster.shards.iter().enumerate() {
            if let Err(e) = shard.check_invariants() {
                return Err(format!("{ctx}: shard {s} invariants: {e}"));
            }
        }
        Ok(())
    });
}
