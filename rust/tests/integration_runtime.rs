//! End-to-end artifact validation: every HLO artifact produced by the
//! python AOT pipeline must load, compile, execute on the PJRT CPU
//! client, and reproduce the golden outputs recorded in the manifest.
//!
//! Requires `make artifacts` to have run (skipped with a message if not).

use mqfq::runtime::{manifest, PjrtRuntime};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first ({} missing)", dir.display());
        None
    }
}

#[test]
fn all_artifacts_validate_against_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = PjrtRuntime::new(&dir).unwrap();
    let names = rt.load_all().unwrap();
    assert!(names.len() >= 11, "expected full catalog, got {names:?}");
    for name in &names {
        let report = rt
            .validate(name)
            .unwrap_or_else(|e| panic!("golden validation failed: {e:#}"));
        assert!(!report.outputs.is_empty());
        eprintln!(
            "  {name}: {} output(s), exec {:?}",
            report.outputs.len(),
            report.elapsed
        );
    }
}

#[test]
fn manifest_covers_table1_catalog() {
    let Some(dir) = artifacts_dir() else { return };
    let specs = manifest::load(dir.join("manifest.txt")).unwrap();
    let names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
    for expect in [
        "imagenet", "roberta", "ffmpeg", "fft", "isoneural", "lud", "needle",
        "pathfinder", "cupy", "rnn", "srad",
    ] {
        assert!(names.contains(&expect), "{expect} missing from manifest");
    }
}

#[test]
fn execute_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = PjrtRuntime::new(&dir).unwrap();
    rt.load_function("cupy").unwrap();
    let a = rt.execute("cupy").unwrap();
    let b = rt.execute("cupy").unwrap();
    assert_eq!(a.outputs, b.outputs, "same staged inputs must give same outputs");
}

#[test]
fn repeated_execution_is_fast_after_compile() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = PjrtRuntime::new(&dir).unwrap();
    rt.load_function("isoneural").unwrap();
    rt.execute("isoneural").unwrap(); // warm
    let t0 = std::time::Instant::now();
    for _ in 0..10 {
        rt.execute("isoneural").unwrap();
    }
    let per = t0.elapsed() / 10;
    assert!(
        per < std::time::Duration::from_millis(100),
        "isoneural exec too slow: {per:?}"
    );
}
