//! Protocol-v1 conformance suite: hello/version negotiation, sync and
//! async invoke round-trips, the structured error taxonomy, deadline
//! handling, legacy line-protocol aliases, the connection-drop
//! regression (a disconnecting client must not shut the server down),
//! and RtServer ≡ RtCluster(1 shard) behavioral equivalence over the
//! same wire. The event-loop front end adds: pipelined id-tagged
//! requests with out-of-order replies, the push-completion lifecycle
//! (including subscriber disconnect before completion), slow-client
//! disconnection at the outbound high-water mark, and mixed
//! legacy/tagged-v1 traffic on one connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use mqfq::api::{ApiClient, ApiError, Frontend, PROTOCOL_VERSION};
use mqfq::cluster::{ClusterConfig, RouterKind};
use mqfq::plane::PlaneConfig;
use mqfq::server::{RtCluster, RtServer};
use mqfq::types::{StartKind, MS};
use mqfq::workload::catalog::by_name;
use mqfq::workload::Workload;

fn workload() -> Workload {
    let mut w = Workload::default();
    w.register(by_name("isoneural").unwrap(), 0, 1.0);
    w.register(by_name("fft").unwrap(), 0, 1.0);
    w
}

fn fast_cfg() -> PlaneConfig {
    PlaneConfig {
        monitor_period: 20 * MS,
        ..Default::default()
    }
}

fn server() -> (RtServer, SocketAddr) {
    let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
    let addr = srv.serve("127.0.0.1:0").unwrap();
    (srv, addr)
}

fn cluster(n: usize, router: RouterKind) -> (RtCluster, SocketAddr) {
    let cfg = ClusterConfig {
        n_shards: n,
        router,
        plane: fast_cfg(),
        ..Default::default()
    };
    let srv = RtCluster::new(workload(), cfg, None, 0.001).unwrap();
    let addr = srv.serve("127.0.0.1:0").unwrap();
    (srv, addr)
}

/// Raw line round-trip (bypasses ApiClient to pin the wire bytes).
fn raw_call(conn: &mut TcpStream, line: &str) -> String {
    conn.write_all((line.to_string() + "\n").as_bytes()).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut buf = String::new();
    reader.read_line(&mut buf).unwrap();
    buf.trim().to_string()
}

#[test]
fn hello_negotiates_and_rejects_unknown_versions() {
    let (_srv, addr) = server();
    let mut conn = TcpStream::connect(addr).unwrap();
    // Current version accepted.
    let ok = raw_call(&mut conn, r#"{"cmd":"hello","v":1}"#);
    assert!(ok.contains(r#""ok":true"#), "{ok}");
    assert!(ok.contains(r#""type":"hello""#), "{ok}");
    assert!(ok.contains(r#""proto":1"#), "{ok}");
    assert!(ok.contains(r#""server":"rt-server""#), "{ok}");
    // Future version rejected with the structured taxonomy...
    let err = raw_call(&mut conn, r#"{"cmd":"hello","v":99}"#);
    assert!(err.contains(r#""ok":false"#), "{err}");
    assert!(err.contains(r#""error":"unsupported-version""#), "{err}");
    // ...but the connection survives for a retry at a spoken version.
    let retry = raw_call(&mut conn, r#"{"cmd":"hello","v":1}"#);
    assert!(retry.contains(r#""proto":1"#), "{retry}");
    // v0 is not a protocol.
    let zero = raw_call(&mut conn, r#"{"cmd":"hello","v":0}"#);
    assert!(zero.contains("unsupported-version"), "{zero}");
    // Malformed versions must not silently negotiate to the default...
    for bad in [r#"{"cmd":"hello","v":"2"}"#, r#"{"cmd":"hello","v":1.5}"#] {
        let reply = raw_call(&mut conn, bad);
        assert!(reply.contains(r#""error":"bad-request""#), "{bad} → {reply}");
    }
    // ...and huge versions must not truncate into an accepted one.
    let huge = raw_call(&mut conn, r#"{"cmd":"hello","v":4294967297}"#);
    assert!(huge.contains("unsupported-version"), "{huge}");
    // Malformed \u escapes (even ones clipping multibyte UTF-8) are a
    // structured decode error, not a dead connection.
    let clipped = raw_call(&mut conn, "{\"cmd\":\"hello\",\"s\":\"\\u000é\"}");
    assert!(clipped.contains(r#""error":"bad-request""#), "{clipped}");
    let alive = raw_call(&mut conn, r#"{"cmd":"hello","v":1}"#);
    assert!(alive.contains(r#""proto":1"#), "{alive}");
}

#[test]
fn client_connect_performs_handshake() {
    let (_srv, addr) = server();
    let client = ApiClient::connect(addr).unwrap();
    assert_eq!(client.proto(), PROTOCOL_VERSION);
}

#[test]
fn describe_reports_functions_policy_and_shape() {
    let (_srv, addr) = cluster(3, RouterKind::StickyCh);
    let mut client = ApiClient::connect(addr).unwrap();
    let d = client.describe().unwrap();
    assert_eq!(d.proto, PROTOCOL_VERSION);
    assert_eq!(d.server, "rt-cluster");
    assert_eq!(d.shards, 3);
    assert_eq!(d.router, "sticky-ch");
    assert_eq!(d.policy, "mqfq-sticky");
    assert_eq!(d.functions, vec!["isoneural-0", "fft-0"]);
}

#[test]
fn sync_invoke_roundtrip() {
    let (_srv, addr) = server();
    let mut client = ApiClient::connect(addr).unwrap();
    let o = client.invoke("isoneural-0", Some(30_000)).unwrap();
    assert_eq!(o.func, "isoneural-0");
    assert_eq!(o.shard, 0);
    assert_eq!(o.start_kind, StartKind::Cold);
    assert!(o.latency_ms > 0.0);
    let s = client.stats().unwrap();
    assert_eq!(s.invocations, 1);
    assert!((s.cold_ratio - 1.0).abs() < 1e-9);
    assert_eq!(s.pending, 0);
    assert_eq!(s.in_flight, 0);
}

#[test]
fn async_invoke_ticket_poll_wait_lifecycle() {
    let (_srv, addr) = server();
    let mut client = ApiClient::connect(addr).unwrap();
    let t = client.invoke_async("fft-0").unwrap();
    // Still booting (seconds of model time, ms of wall time).
    assert_eq!(client.poll(t).unwrap(), None);
    let o = client.wait(t, Some(30_000)).unwrap();
    assert_eq!(o.ticket, t);
    assert_eq!(o.func, "fft-0");
    // Redeemed tickets are reclaimed.
    let err = client.wait(t, Some(1_000)).unwrap_err();
    assert_eq!(err.code(), "unknown-ticket");
    let err = client.poll(t).unwrap_err();
    assert_eq!(err.code(), "unknown-ticket");
}

#[test]
fn tickets_outlive_their_connection() {
    let (_srv, addr) = server();
    let mut a = ApiClient::connect(addr).unwrap();
    let t = a.invoke_async("fft-0").unwrap();
    a.quit();
    // Tickets are frontend-scoped: a second connection redeems them.
    let mut b = ApiClient::connect(addr).unwrap();
    let o = b.wait(t, Some(30_000)).unwrap();
    assert_eq!(o.ticket, t);
}

#[test]
fn error_taxonomy_over_the_wire() {
    let (srv, addr) = server();
    let mut client = ApiClient::connect(addr).unwrap();
    assert_eq!(
        client.invoke("ghost", None).unwrap_err().code(),
        "unknown-function"
    );
    assert_eq!(
        client
            .wait(mqfq::api::Ticket(404), Some(1_000))
            .unwrap_err()
            .code(),
        "unknown-ticket"
    );
    // Malformed requests.
    let mut conn = TcpStream::connect(addr).unwrap();
    for bad in [
        "{not json",
        r#"{"cmd":"warp"}"#,
        r#"{"cmd":"invoke"}"#,
        r#"{"cmd":"invoke","func":"f","mode":"batch"}"#,
    ] {
        let reply = raw_call(&mut conn, bad);
        assert!(reply.contains(r#""error":"bad-request""#), "{bad} → {reply}");
    }
    // Backpressure: D=2 dispatches two, the third queues, the fourth
    // submit sees pending >= limit.
    srv.set_max_pending(1);
    let mut tickets = Vec::new();
    let mut overloaded = false;
    for _ in 0..4 {
        match client.invoke_async("fft-0") {
            Ok(t) => tickets.push(t),
            Err(e) => {
                assert_eq!(e.code(), "overloaded");
                overloaded = true;
                break;
            }
        }
    }
    assert!(overloaded, "4th submit must hit the backpressure bound");
    for t in tickets {
        client.wait(t, Some(30_000)).unwrap();
    }
}

#[test]
fn deadline_exceeded_then_recoverable() {
    let (_srv, addr) = server();
    let mut client = ApiClient::connect(addr).unwrap();
    // fft's modeled cold start dwarfs a 1 ms deadline.
    let err = client.invoke("fft-0", Some(1)).unwrap_err();
    assert_eq!(err.code(), "deadline-exceeded");
    // Run-to-completion: the error carries the still-running
    // invocation's ticket, so even a sync invoke stays redeemable.
    let ApiError::DeadlineExceeded {
        ticket: Some(t), ..
    } = err
    else {
        panic!("deadline error must carry the ticket: {err}");
    };
    let o = client.wait(t, Some(30_000)).unwrap();
    assert_eq!(o.func, "fft-0");
    assert_eq!(client.stats().unwrap().invocations, 1);
}

#[test]
fn legacy_aliases_still_speak_the_old_lines() {
    let (_srv, addr) = server();
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(b"invoke isoneural-0\nstats\nquit\n").unwrap();
    let mut lines = BufReader::new(conn.try_clone().unwrap()).lines();
    let first = lines.next().unwrap().unwrap();
    assert!(first.starts_with("ok "), "{first}");
    assert!(first.contains("gpu0"), "{first}");
    assert!(first.contains("cold"), "{first}");
    let second = lines.next().unwrap().unwrap();
    assert!(second.contains("invocations=1"), "{second}");
    assert!(second.contains("cold_ratio="), "{second}");
    // quit closes the stream.
    assert!(lines.next().is_none());
}

#[test]
fn legacy_unknown_function_and_command() {
    let (_srv, addr) = server();
    let mut conn = TcpStream::connect(addr).unwrap();
    assert_eq!(raw_call(&mut conn, "invoke ghost"), "err unknown function");
    assert_eq!(raw_call(&mut conn, "warp 9"), "err unknown command warp");
}

#[test]
fn legacy_and_v1_share_one_port_and_one_state() {
    let (_srv, addr) = server();
    let mut conn = TcpStream::connect(addr).unwrap();
    let legacy = raw_call(&mut conn, "invoke isoneural-0");
    assert!(legacy.starts_with("ok "), "{legacy}");
    // The same connection switches to v1 mid-stream; the v1 stats see
    // the legacy invocation.
    let stats = raw_call(&mut conn, r#"{"cmd":"stats"}"#);
    assert!(stats.contains(r#""invocations":1"#), "{stats}");
}

#[test]
fn disconnecting_client_does_not_kill_the_server() {
    // Regression: per-connection guard clones used to run
    // Drop::drop → shutdown() on first disconnect, storing running=false
    // and killing the monitor + accept loop for every later client.
    let (srv, addr) = server();
    {
        let mut first = ApiClient::connect(addr).unwrap();
        first.invoke("isoneural-0", Some(30_000)).unwrap();
        first.quit(); // graceful disconnect (server sees EOF after bye)
    }
    {
        // Ungraceful disconnect too: just drop the socket.
        let _ = TcpStream::connect(addr).unwrap();
    }
    // A later, fully separate connection must still be served — accept
    // loop alive, monitor alive, admission open.
    let mut second = ApiClient::connect(addr).unwrap();
    let o = second.invoke("isoneural-0", Some(30_000)).unwrap();
    assert_ne!(o.start_kind, StartKind::Cold, "warm pool must survive");
    assert_eq!(second.stats().unwrap().invocations, 2);
    // Only the guard shuts down.
    srv.stop();
    assert_eq!(
        second.invoke("isoneural-0", None).unwrap_err().code(),
        "shutting-down"
    );
}

#[test]
fn one_shard_cluster_behaves_like_the_server() {
    let (_a, server_addr) = server();
    let (_b, cluster_addr) = cluster(1, RouterKind::StickyCh);
    let mut outcomes = Vec::new();
    for addr in [server_addr, cluster_addr] {
        let mut client = ApiClient::connect(addr).unwrap();
        let o1 = client.invoke("fft-0", Some(30_000)).unwrap();
        let o2 = client.invoke("fft-0", Some(30_000)).unwrap();
        let s = client.stats().unwrap();
        outcomes.push((
            o1.shard,
            o1.start_kind == StartKind::Cold,
            o2.start_kind == StartKind::Cold,
            s.invocations,
        ));
    }
    // Same observable behavior on both frontends: everything on shard
    // 0, cold then warm, two served.
    assert_eq!(outcomes[0], (0, true, false, 2));
    assert_eq!(outcomes[0], outcomes[1]);
}

#[test]
fn four_shard_cluster_serves_real_traffic_through_the_router() {
    // load_factor is plumbed to the live router: a huge bound never
    // spills, so sticky locality holds even for an async burst.
    let cfg = ClusterConfig {
        n_shards: 4,
        router: RouterKind::StickyCh,
        plane: fast_cfg(),
        load_factor: 100.0,
        ..Default::default()
    };
    let srv = RtCluster::new(workload(), cfg, None, 0.001).unwrap();
    let addr = srv.serve("127.0.0.1:0").unwrap();
    let mut client = ApiClient::connect(addr).unwrap();
    // Async burst across both functions, all redeemed.
    let tickets: Vec<_> = (0..8)
        .map(|i| {
            client
                .invoke_async(["isoneural-0", "fft-0"][i % 2])
                .unwrap()
        })
        .collect();
    let mut shards_by_func =
        [std::collections::HashSet::new(), std::collections::HashSet::new()];
    for (i, t) in tickets.into_iter().enumerate() {
        let o = client.wait(t, Some(30_000)).unwrap();
        assert!(o.shard < 4);
        shards_by_func[i % 2].insert(o.shard);
    }
    // Sticky locality: each function concentrates on its home shard.
    assert_eq!(shards_by_func[0].len(), 1);
    assert_eq!(shards_by_func[1].len(), 1);
    assert_eq!(client.stats().unwrap().invocations, 8);
}

#[test]
fn frontend_shutdown_surfaces_via_the_wire() {
    let (srv, addr) = server();
    let mut client = ApiClient::connect(addr).unwrap();
    Frontend::shutdown(&srv); // trait-level: admission closes
    let err = client.invoke("isoneural-0", None).unwrap_err();
    assert_eq!(err.code(), "shutting-down");
    assert!(matches!(err, ApiError::ShuttingDown));
}

#[test]
fn concurrent_clients_conserve_every_invocation() {
    // N client threads hammer mixed sync / async+wait / async+poll /
    // stats against a 4-shard sticky cluster over real loopback TCP.
    // Conservation: every submitted invoke is claimed exactly once (a
    // second claim sees unknown-ticket), no tickets strand, and the
    // aggregate stats match the offered total with nothing left queued.
    let cfg = ClusterConfig {
        n_shards: 4,
        router: RouterKind::StickyCh,
        plane: fast_cfg(),
        ..Default::default()
    };
    let srv = RtCluster::new(workload(), cfg, None, 0.0002).unwrap();
    let addr = srv.serve("127.0.0.1:0").unwrap();
    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 30;
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            std::thread::spawn(move || {
                let mut cl = ApiClient::connect(addr).unwrap();
                let names = ["isoneural-0", "fft-0"];
                let mut claimed = 0usize;
                for i in 0..PER_CLIENT {
                    let func = names[(c + i) % 2];
                    match i % 3 {
                        0 => {
                            let o = cl.invoke(func, Some(30_000)).unwrap();
                            assert!(o.shard < 4);
                            claimed += 1;
                        }
                        1 => {
                            let t = cl.invoke_async(func).unwrap();
                            let o = loop {
                                match cl.poll(t).unwrap() {
                                    Some(o) => break o,
                                    None => std::thread::sleep(
                                        Duration::from_micros(200),
                                    ),
                                }
                            };
                            assert_eq!(o.ticket, t);
                            claimed += 1;
                            // Claimed exactly once: re-claim must fail.
                            assert_eq!(
                                cl.poll(t).unwrap_err().code(),
                                "unknown-ticket"
                            );
                        }
                        _ => {
                            let t = cl.invoke_async(func).unwrap();
                            let o = cl.wait(t, Some(30_000)).unwrap();
                            assert_eq!(o.ticket, t);
                            claimed += 1;
                            assert_eq!(
                                cl.wait(t, Some(1_000)).unwrap_err().code(),
                                "unknown-ticket"
                            );
                        }
                    }
                    if i % 7 == 0 {
                        // Interleaved stats reads never lock a plane and
                        // must not wedge the submit path.
                        let _ = cl.stats().unwrap();
                    }
                }
                claimed
            })
        })
        .collect();
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, CLIENTS * PER_CLIENT, "every invoke claimed exactly once");
    let mut client = ApiClient::connect(addr).unwrap();
    let s = client.stats().unwrap();
    assert_eq!(s.invocations, CLIENTS * PER_CLIENT, "stats totals conserve");
    assert_eq!(s.pending, 0, "no stranded queue entries");
    assert_eq!(s.in_flight, 0, "no stranded in-flight work");
    assert!(s.mean_latency_ms > 0.0);
}

// ---------------------------------------------------------------------
// Event-loop front end: pipelining, push completions, slow clients.
// ---------------------------------------------------------------------

use mqfq::api::wire;
use mqfq::api::{InvokeMode, MetricsFormat, Request, Response};

/// Encode one id-tagged request line into `batch`.
fn tag_line(batch: &mut String, req: &Request, id: u64) {
    wire::encode_request_tagged_into(req, id, batch);
    batch.push('\n');
}

#[test]
fn pipelined_tagged_replies_return_out_of_order() {
    // One flush carries a blocking `wait` (id 7) on a still-running
    // ticket followed by `stats` (id 9). The event loop must answer
    // stats immediately and deliver the wait completion later — replies
    // arrive out of submission order, reassembled by id.
    let (_srv, addr) = server();
    let mut conn = TcpStream::connect(addr).unwrap();
    raw_call(&mut conn, r#"{"cmd":"hello","v":1}"#);
    let accepted = raw_call(&mut conn, r#"{"cmd":"invoke","func":"fft-0","mode":"async"}"#);
    let (_, resp) = wire::decode_response_tagged(&accepted).unwrap();
    let Response::Accepted { ticket } = resp else {
        panic!("async submit must be accepted: {accepted}");
    };
    let mut batch = String::new();
    tag_line(
        &mut batch,
        &Request::Wait {
            ticket,
            deadline_ms: Some(30_000),
        },
        7,
    );
    tag_line(&mut batch, &Request::Stats, 9);
    conn.write_all(batch.as_bytes()).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut read_tagged = || {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        wire::decode_response_tagged(line.trim()).unwrap()
    };
    let (first_id, first) = read_tagged();
    assert_eq!(first_id, Some(9), "stats must overtake the blocked wait");
    assert!(matches!(first, Response::Stats(_)), "{first:?}");
    let (second_id, second) = read_tagged();
    assert_eq!(second_id, Some(7));
    let Response::Done(o) = second else {
        panic!("wait completion expected: {second:?}");
    };
    assert_eq!(o.ticket, ticket);
}

#[test]
fn pipeline_client_reassembles_a_burst() {
    let (_srv, addr) = server();
    let mut client = ApiClient::connect(addr).unwrap();
    let funcs = ["isoneural-0", "fft-0", "isoneural-0", "fft-0"];
    let tickets = client.pipeline_invoke_async(&funcs).unwrap();
    assert_eq!(tickets.len(), 4);
    let unique: std::collections::HashSet<_> = tickets.iter().collect();
    assert_eq!(unique.len(), 4, "tickets must be distinct");
    for (t, f) in tickets.iter().zip(funcs) {
        let o = client.wait(*t, Some(30_000)).unwrap();
        assert_eq!(o.ticket, *t);
        assert_eq!(o.func, f);
    }
}

#[test]
fn pipeline_surfaces_first_error_after_draining_the_batch() {
    let (_srv, addr) = server();
    let mut client = ApiClient::connect(addr).unwrap();
    let err = client
        .pipeline_invoke_async(&["isoneural-0", "ghost", "fft-0"])
        .unwrap_err();
    assert_eq!(err.code(), "unknown-function");
    // The whole batch was drained — the connection is still lockstep-
    // clean and usable (no stray replies poison the next call).
    let o = client.invoke("isoneural-0", Some(30_000)).unwrap();
    assert_eq!(o.func, "isoneural-0");
    // The two valid submits did run.
    assert!(client.stats().unwrap().invocations >= 1);
}

#[test]
fn push_lifecycle_claims_on_delivery() {
    // Same observable behavior on RtServer and a 1-shard RtCluster:
    // submit-with-subscribe, completion arrives as a push, and delivery
    // claims the ticket (a later wait sees unknown-ticket).
    let (_a, server_addr) = server();
    let (_b, cluster_addr) = cluster(1, RouterKind::StickyCh);
    for addr in [server_addr, cluster_addr] {
        let mut client = ApiClient::connect(addr).unwrap();
        let t = client.invoke_push("fft-0").unwrap();
        let o = client.wait_push(t).unwrap();
        assert_eq!(o.ticket, t);
        assert_eq!(o.func, "fft-0");
        assert_eq!(
            client.wait(t, Some(1_000)).unwrap_err().code(),
            "unknown-ticket",
            "push delivery must claim the ticket"
        );
        // Push counters surface through the metrics verb.
        let body = client.metrics(MetricsFormat::Json).unwrap();
        assert!(body.contains("\"push_subscriptions\": 1"), "{body}");
        assert!(body.contains("\"push_notifications\": 1"), "{body}");
    }
}

#[test]
fn push_interleaves_with_pipelined_lockstep_traffic() {
    // A push subscription on a connection that keeps doing ordinary
    // lockstep calls: the unsolicited push line lands between paired
    // replies and is parked, not confused with them.
    let (_srv, addr) = server();
    let mut client = ApiClient::connect(addr).unwrap();
    let t = client.invoke_push("fft-0").unwrap();
    // Lockstep traffic while the push is in flight (cold fft takes ms
    // of wall time at this scale).
    for _ in 0..20 {
        client.stats().unwrap();
    }
    let o = client.wait_push(t).unwrap();
    assert_eq!(o.ticket, t);
}

#[test]
fn push_subscriber_disconnect_leaves_ticket_redeemable() {
    // The subscriber vanishes before its invocation completes: the
    // completion must NOT be claimed on behalf of the dead connection —
    // a second client still redeems the ticket (parity with the
    // wait-then-disconnect and redeem-after-deadline guarantees).
    let (_srv, addr) = server();
    let ticket = {
        let mut conn = TcpStream::connect(addr).unwrap();
        raw_call(&mut conn, r#"{"cmd":"hello","v":1}"#);
        let accepted = raw_call(
            &mut conn,
            r#"{"cmd":"invoke","func":"fft-0","mode":"async","push":true}"#,
        );
        let (_, resp) = wire::decode_response_tagged(&accepted).unwrap();
        let Response::Accepted { ticket } = resp else {
            panic!("push submit must be accepted: {accepted}");
        };
        ticket
        // Socket drops here — microseconds after accept, milliseconds
        // before the modeled cold start finishes.
    };
    let mut second = ApiClient::connect(addr).unwrap();
    let o = second.wait(ticket, Some(30_000)).unwrap();
    assert_eq!(o.ticket, ticket);
    // The undeliverable push is counted, not silently lost. The drop is
    // recorded by the poller a beat after the executor resolves the
    // ticket, so poll briefly rather than racing it.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let body = second.metrics(MetricsFormat::Json).unwrap();
        if body.contains("\"push_dropped\": 1") {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "push_dropped never counted: {body}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn slow_client_is_disconnected_at_the_outbound_high_water_mark() {
    // A client that requests far more reply bytes than it reads must be
    // disconnected once its outbound queue passes the (tiny, for the
    // test) high-water mark — not buffer the server into the ground.
    let srv = RtServer::new(workload(), fast_cfg(), None, 0.001).unwrap();
    let addr = srv
        .serve_cfg(
            "127.0.0.1:0",
            mqfq::server::event_loop::LoopConfig {
                max_outbound: 8 * 1024,
                ..Default::default()
            },
        )
        .unwrap();
    let mut conn = TcpStream::connect(addr).unwrap();
    raw_call(&mut conn, r#"{"cmd":"hello","v":1}"#);
    // Each metrics reply is KBs; twenty thousand of them are far beyond
    // any kernel socket buffering + an 8 KiB queue cap. The client
    // never reads, so the server's flushes stall and the queue fills.
    const REQUESTS: usize = 20_000;
    let mut line = String::new();
    wire::encode_request_into(&Request::Metrics { format: MetricsFormat::Prom }, &mut line);
    line.push('\n');
    let mut write_failed = false;
    for _ in 0..REQUESTS {
        if conn.write_all(line.as_bytes()).is_err() {
            write_failed = true; // server already hung up on us
            break;
        }
    }
    // Drain whatever was delivered: the stream must end (EOF or reset)
    // long before all replies arrive, with the structured slow-consumer
    // error as the last complete line if it made it out.
    let mut replies = 0usize;
    let mut saw_slow_consumer = false;
    let mut reader = BufReader::new(conn);
    loop {
        let mut buf = String::new();
        match reader.read_line(&mut buf) {
            Ok(0) => break,
            Ok(_) => {
                if buf.contains(r#""error":"slow-consumer""#) {
                    saw_slow_consumer = true;
                }
                replies += 1;
            }
            Err(_) => break, // reset counts as disconnection too
        }
    }
    assert!(
        replies < REQUESTS,
        "server must cut a slow client off, got all {replies} replies"
    );
    assert!(
        write_failed || saw_slow_consumer || replies < REQUESTS,
        "disconnection must be observable"
    );
    // The server survives and counts the disconnect.
    let mut healthy = ApiClient::connect(addr).unwrap();
    healthy.invoke("isoneural-0", Some(30_000)).unwrap();
    let body = healthy.metrics(MetricsFormat::Json).unwrap();
    assert!(body.contains("\"slow_client_disconnects\": 1"), "{body}");
}

#[test]
fn mixed_legacy_and_tagged_v1_on_one_event_loop_connection() {
    // Legacy lines and id-tagged v1 requests interleave on a single
    // connection; legacy replies stay byte-shaped exactly as before.
    let (_srv, addr) = server();
    let mut conn = TcpStream::connect(addr).unwrap();
    let legacy = raw_call(&mut conn, "invoke isoneural-0");
    assert!(legacy.starts_with("ok "), "{legacy}");
    assert!(legacy.contains("cold"), "{legacy}");
    let mut batch = String::new();
    tag_line(&mut batch, &Request::Stats, 3);
    conn.write_all(batch.as_bytes()).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let (id, resp) = wire::decode_response_tagged(line.trim()).unwrap();
    assert_eq!(id, Some(3));
    let Response::Stats(s) = resp else {
        panic!("tagged stats reply expected: {line}");
    };
    assert_eq!(s.invocations, 1, "v1 stats see the legacy invocation");
    let legacy_stats = raw_call(&mut conn, "stats");
    assert!(legacy_stats.contains("invocations=1"), "{legacy_stats}");
    assert_eq!(raw_call(&mut conn, "warp 9"), "err unknown command warp");
}

#[test]
fn untagged_invoke_still_speaks_push_false_semantics() {
    // A v1 request without `push` behaves exactly as before the
    // extension: accepted, no unsolicited lines ever appear, ticket
    // redeemable by wait.
    let (_srv, addr) = server();
    let mut conn = TcpStream::connect(addr).unwrap();
    raw_call(&mut conn, r#"{"cmd":"hello","v":1}"#);
    let accepted = raw_call(&mut conn, r#"{"cmd":"invoke","func":"isoneural-0","mode":"async"}"#);
    let (_, resp) = wire::decode_response_tagged(&accepted).unwrap();
    let Response::Accepted { ticket } = resp else {
        panic!("{accepted}");
    };
    // The very next reply line is the wait outcome — no push slipped in.
    let req = format!("{{\"cmd\":\"wait\",\"ticket\":{},\"deadline_ms\":30000}}", ticket.0);
    let done = raw_call(&mut conn, &req);
    assert!(done.contains(r#""ok":true"#), "{done}");
    assert!(!done.contains(r#""type":"push""#), "{done}");
}

#[test]
fn invoke_mode_vocabulary_is_unchanged() {
    // `push` rides on async submits only; a sync submit with push set
    // is a structured bad-request, not a silent downgrade.
    let (_srv, addr) = server();
    let mut conn = TcpStream::connect(addr).unwrap();
    raw_call(&mut conn, r#"{"cmd":"hello","v":1}"#);
    let reply = raw_call(
        &mut conn,
        r#"{"cmd":"invoke","func":"isoneural-0","mode":"sync","push":true}"#,
    );
    assert!(reply.contains(r#""error":"bad-request""#), "{reply}");
    // Round-trip sanity on the typed encoder: async+push encodes and
    // decodes to itself.
    let req = Request::Invoke {
        func: "fft-0".into(),
        mode: InvokeMode::Async,
        deadline_ms: None,
        push: true,
    };
    let mut line = String::new();
    wire::encode_request_into(&req, &mut line);
    assert_eq!(wire::decode_request(&line).unwrap(), req);
}

#[test]
fn executor_thread_count_is_config_not_load_under_burst() {
    // The serving path must not spawn per dispatch: executor-side
    // thread count is shards × workers + 1 (timer), before and after a
    // 1k-invoke burst far beyond the pool size.
    let cfg = ClusterConfig {
        n_shards: 4,
        router: RouterKind::StickyCh,
        plane: fast_cfg(),
        ..Default::default()
    };
    let srv = RtCluster::with_workers(workload(), cfg, None, 0.0002, 2).unwrap();
    let before = srv.exec_threads();
    assert_eq!(before, 4 * 2 + 1, "shards × pool_size + timer");
    let tickets: Vec<_> = (0..1000)
        .map(|i| srv.submit(["isoneural-0", "fft-0"][i % 2]).unwrap())
        .collect();
    for t in tickets {
        srv.wait(t, Some(Duration::from_secs(60))).unwrap();
    }
    assert_eq!(
        srv.exec_threads(),
        before,
        "burst must not change executor thread count"
    );
    assert_eq!(srv.stats().invocations, 1000);
    assert_eq!(srv.stats().pending, 0);
}
