//! Property tests for the cluster subsystem: the 1-shard equivalence
//! contract (a single-shard cluster replays event-for-event identically
//! to the plain single-plane engine, under *any* router) and cluster
//! conservation/determinism on randomized traces.

use mqfq::cluster::{ClusterConfig, ALL_ROUTERS};
use mqfq::gpu::{uniform_fleet, MultiplexMode, V100};
use mqfq::plane::PlaneConfig;
use mqfq::scheduler::policies::PolicyKind;
use mqfq::scheduler::MqfqConfig;
use mqfq::sim::{replay, replay_cluster};
use mqfq::types::{secs, FuncId};
use mqfq::util::prop::{assert_prop, Gen};
use mqfq::workload::catalog::CATALOG;
use mqfq::workload::trace::{Trace, TraceEvent, Workload};

/// Random workload + open-loop trace (mirrors prop_scheduler's shape).
fn gen_scenario(g: &mut Gen) -> (Workload, Trace) {
    let n_funcs = g.int(1, 10);
    let mut w = Workload::default();
    for i in 0..n_funcs {
        let class = &CATALOG[g.int(0, CATALOG.len() - 1)];
        w.register(class, i, g.f64(0.5, 20.0));
    }
    let n_events = g.int(1, 100);
    let horizon = g.f64(10.0, 240.0);
    let mut t = Trace::default();
    for _ in 0..n_events {
        t.events.push(TraceEvent {
            at: secs(g.f64(0.0, horizon)),
            func: FuncId(g.int(0, n_funcs - 1) as u32),
        });
    }
    t.sort();
    (w, t)
}

fn gen_plane_config(g: &mut Gen) -> PlaneConfig {
    PlaneConfig {
        policy: *g.choose(&[
            PolicyKind::Fcfs,
            PolicyKind::Batch,
            PolicyKind::PaellaSjf,
            PolicyKind::Eevdf,
            PolicyKind::Sfq,
            PolicyKind::Mqfq,
        ]),
        devices: uniform_fleet(g.int(1, 2), V100, MultiplexMode::Plain),
        d: g.int(1, 3),
        pool_size: g.int(2, 32),
        mqfq: MqfqConfig {
            t: g.f64(0.0, 20.0),
            ttl_alpha: g.f64(0.0, 4.0),
            ..Default::default()
        },
        ..Default::default()
    }
}

/// The acceptance criterion: a 1-shard cluster — whichever router
/// fronts it — replays event-for-event identically to `sim::replay`
/// (full per-invocation record stream, makespan, event count, pool
/// stats, utilization integral).
#[test]
fn prop_single_shard_cluster_matches_plain_replay() {
    assert_prop("single-shard equivalence", 40, |g| {
        let (w, t) = gen_scenario(g);
        let plane_cfg = gen_plane_config(g);
        let router = *g.choose(&ALL_ROUTERS);
        let seed = g.int(0, 1 << 20) as u64;

        let plain = replay(w.clone(), &t, plane_cfg.clone());
        let one = replay_cluster(
            w,
            &t,
            ClusterConfig {
                n_shards: 1,
                router,
                plane: plane_cfg.clone(),
                shard_planes: Vec::new(),
                load_factor: g.f64(1.0, 4.0),
                seed,
                ..Default::default()
            },
        );

        let ctx = format!(
            "router={} policy={} d={} gpus={} pool={}",
            router.name(),
            plane_cfg.policy.name(),
            plane_cfg.d,
            plane_cfg.n_devices(),
            plane_cfg.pool_size
        );
        if one.events != plain.events {
            return Err(format!(
                "{ctx}: events {} != {}",
                one.events, plain.events
            ));
        }
        if one.makespan != plain.makespan {
            return Err(format!(
                "{ctx}: makespan {} != {}",
                one.makespan, plain.makespan
            ));
        }
        let merged = one.recorder();
        if merged.records != plain.recorder().records {
            return Err(format!(
                "{ctx}: record streams diverge ({} vs {} records)",
                merged.len(),
                plain.recorder().len()
            ));
        }
        if one.cluster.pool_stats() != plain.plane.pool_stats() {
            return Err(format!(
                "{ctx}: pool stats {:?} != {:?}",
                one.cluster.pool_stats(),
                plain.plane.pool_stats()
            ));
        }
        if (one.mean_util - plain.mean_util).abs() > 1e-12 {
            return Err(format!(
                "{ctx}: mean util {} != {}",
                one.mean_util, plain.mean_util
            ));
        }
        Ok(())
    });
}

/// Multi-shard conservation: every arrival completes exactly once,
/// whichever shard it was routed to, and the cluster fully drains.
#[test]
fn prop_cluster_conserves_invocations() {
    assert_prop("cluster conservation", 30, |g| {
        let (w, t) = gen_scenario(g);
        let n = t.len();
        let cfg = ClusterConfig {
            n_shards: g.int(1, 8),
            router: *g.choose(&ALL_ROUTERS),
            plane: gen_plane_config(g),
            shard_planes: Vec::new(),
            load_factor: g.f64(1.0, 3.0),
            seed: g.int(0, 1 << 20) as u64,
            ..Default::default()
        };
        let ctx = format!("shards={} router={}", cfg.n_shards, cfg.router.name());
        let r = replay_cluster(w, &t, cfg);
        if r.recorder().len() != n {
            return Err(format!(
                "{ctx}: {} arrivals but {} completions",
                n,
                r.recorder().len()
            ));
        }
        if r.cluster.pending() != 0 || r.cluster.in_flight() != 0 {
            return Err(format!(
                "{ctx}: not drained ({} pending, {} in flight)",
                r.cluster.pending(),
                r.cluster.in_flight()
            ));
        }
        let routed: u64 = r.cluster.routed.iter().sum();
        if routed != n as u64 {
            return Err(format!("{ctx}: routed {routed} != {n} arrivals"));
        }
        Ok(())
    });
}

/// Multi-shard determinism: identical seeds ⇒ identical dispatch
/// sequences and metrics, across every router.
#[test]
fn prop_cluster_replay_is_deterministic() {
    assert_prop("cluster determinism", 20, |g| {
        let (w, t) = gen_scenario(g);
        let cfg = ClusterConfig {
            n_shards: g.int(2, 8),
            router: *g.choose(&ALL_ROUTERS),
            plane: gen_plane_config(g),
            shard_planes: Vec::new(),
            load_factor: g.f64(1.0, 3.0),
            seed: g.int(0, 1 << 20) as u64,
            ..Default::default()
        };
        let a = replay_cluster(w.clone(), &t, cfg.clone());
        let b = replay_cluster(w, &t, cfg.clone());
        let ctx = format!("shards={} router={}", cfg.n_shards, cfg.router.name());
        if a.events != b.events || a.makespan != b.makespan {
            return Err(format!("{ctx}: event/makespan mismatch"));
        }
        if a.cluster.routed != b.cluster.routed {
            return Err(format!(
                "{ctx}: routing diverged {:?} vs {:?}",
                a.cluster.routed, b.cluster.routed
            ));
        }
        if a.recorder().records != b.recorder().records {
            return Err(format!("{ctx}: record streams diverge"));
        }
        Ok(())
    });
}
