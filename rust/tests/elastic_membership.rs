//! Elastic-membership robustness suite: randomized drain/rejoin and
//! kill conservation properties on the sim [`Cluster`] (every router),
//! plus the wall-clock kill-storm regression over real loopback TCP —
//! no waiter may block past its deadline window, and ticket fates must
//! conserve at quiescence.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::thread;
use std::time::{Duration, Instant};

use mqfq::api::{ApiClient, ApiError, ShardHealth, Ticket};
use mqfq::cluster::{Cluster, ClusterConfig, ALL_ROUTERS};
use mqfq::plane::PlaneConfig;
use mqfq::server::RtCluster;
use mqfq::types::{secs, FuncId, InvocationId, Nanos, MS};
use mqfq::util::prop::{assert_prop, Gen};
use mqfq::workload::catalog::CATALOG;
use mqfq::workload::Workload;

// ---------------------------------------------------------------------
// A minimal virtual-time driver over the public Cluster API: completion
// events are epoch-stamped (the wall-clock server's timer contract), so
// a kill's parked events drop as stale instead of resurrecting work.
// ---------------------------------------------------------------------

struct Driver {
    c: Cluster,
    heap: BinaryHeap<Reverse<(Nanos, u64, usize, InvocationId, u64)>>,
    seq: u64,
    now: Nanos,
    completed: usize,
}

impl Driver {
    fn new(c: Cluster) -> Self {
        Driver { c, heap: BinaryHeap::new(), seq: 0, now: 0, completed: 0 }
    }

    fn push(&mut self, ds: Vec<mqfq::sim::ShardDispatch>) {
        for sd in ds {
            let epoch = self.c.shard_epoch(sd.shard);
            self.seq += 1;
            self.heap
                .push(Reverse((sd.dispatch.complete_at, self.seq, sd.shard, sd.dispatch.inv, epoch)));
        }
    }

    fn drain_until(&mut self, t: Nanos) {
        loop {
            match self.heap.peek() {
                Some(Reverse(ev)) if ev.0 <= t => {}
                _ => break,
            }
            let Reverse((due, _, shard, inv, epoch)) = self.heap.pop().unwrap();
            self.now = self.now.max(due);
            if self.c.shard_epoch(shard) != epoch {
                continue; // stale: the shard died after scheduling this
            }
            let (rec, ds) = self.c.on_complete(shard, inv, due);
            if rec.is_some() {
                self.completed += 1;
            }
            self.push(ds);
        }
    }

    fn arrive(&mut self, func: usize) {
        let (_, _, ds) = self.c.on_arrival(FuncId(func as u32), self.now);
        self.push(ds);
    }

    /// Run the cluster dry (bounded; returns false on a stall, which a
    /// conservation property then reports with context).
    fn drain_all(&mut self) -> bool {
        let mut guard = 0;
        while self.c.pending() + self.c.in_flight() > 0 {
            guard += 1;
            if guard > 500_000 {
                return false;
            }
            if let Some(due) = self.heap.peek().map(|Reverse(ev)| ev.0) {
                self.drain_until(due);
            } else {
                self.now += 200 * MS;
                let ds = self.c.on_monitor_tick(self.now);
                self.push(ds);
            }
        }
        true
    }
}

fn gen_workload(g: &mut Gen) -> (Workload, usize) {
    let n_funcs = g.int(1, 8);
    let mut w = Workload::default();
    for i in 0..n_funcs {
        let class = &CATALOG[g.int(0, CATALOG.len() - 1)];
        w.register(class, i, g.f64(0.5, 20.0));
    }
    (w, n_funcs)
}

/// Drain-then-rejoin conservation, every router: a shard that leaves
/// and comes back mid-traffic never loses or duplicates an invocation.
#[test]
fn prop_drain_rejoin_conserves_across_routers() {
    assert_prop("drain/rejoin conservation", 25, |g| {
        let (w, n_funcs) = gen_workload(g);
        let n_shards = g.int(2, 6);
        let router = *g.choose(&ALL_ROUTERS);
        let cfg = ClusterConfig {
            n_shards,
            router,
            plane: PlaneConfig::default(),
            ..Default::default()
        };
        let ctx = format!("shards={n_shards} router={}", router.name());
        let mut d = Driver::new(Cluster::new(w, cfg));
        let victim = g.int(0, n_shards - 1);
        let per_phase = g.int(5, 60);
        let mut arrivals = 0usize;
        for phase in 0..3 {
            match phase {
                1 => d.c.drain_shard(victim).map_err(|e| format!("{ctx}: {e}"))?,
                2 => d.c.join_shard(victim).map_err(|e| format!("{ctx}: {e}"))?,
                _ => {}
            }
            for i in 0..per_phase {
                d.now += secs(g.f64(0.001, 0.5));
                d.drain_until(d.now);
                d.arrive(i % n_funcs);
                arrivals += 1;
            }
        }
        if !d.drain_all() {
            return Err(format!("{ctx}: failed to drain"));
        }
        if d.completed != arrivals {
            return Err(format!(
                "{ctx}: {arrivals} arrivals but {} completions",
                d.completed
            ));
        }
        if d.c.merged_recorder().len() != arrivals {
            return Err(format!(
                "{ctx}: recorder holds {} records for {arrivals} arrivals",
                d.c.merged_recorder().len()
            ));
        }
        Ok(())
    });
}

/// Kill conservation, every router: after an abrupt shard failure,
/// every arrival is either completed or reported lost by the kill —
/// exactly one fate each, and the graveyard keeps the dead shard's
/// finished work in the merged recorder.
#[test]
fn prop_kill_reports_every_lost_invocation() {
    assert_prop("kill-storm conservation", 25, |g| {
        let (w, n_funcs) = gen_workload(g);
        let n_shards = g.int(2, 6);
        let router = *g.choose(&ALL_ROUTERS);
        let cfg = ClusterConfig {
            n_shards,
            router,
            plane: PlaneConfig::default(),
            ..Default::default()
        };
        let ctx = format!("shards={n_shards} router={}", router.name());
        let mut d = Driver::new(Cluster::new(w, cfg));
        let per_phase = g.int(10, 80);
        let mut arrivals = 0usize;
        let mut lost = 0usize;
        let rejoin = g.bool(0.5);
        for phase in 0..3 {
            if phase == 1 {
                // Kill a random still-Up shard (keep one live).
                let up: Vec<usize> = (0..n_shards)
                    .filter(|&s| d.c.shard_health(s) == ShardHealth::Up)
                    .collect();
                if up.len() > 1 {
                    let victim = *g.choose(&up);
                    lost += d.c.kill_shard(victim).map_err(|e| format!("{ctx}: {e}"))?;
                    if rejoin {
                        d.c.join_shard(victim).map_err(|e| format!("{ctx}: {e}"))?;
                    }
                }
            }
            for i in 0..per_phase {
                d.now += secs(g.f64(0.001, 0.5));
                d.drain_until(d.now);
                d.arrive(i % n_funcs);
                arrivals += 1;
            }
        }
        if !d.drain_all() {
            return Err(format!("{ctx}: failed to drain"));
        }
        if d.completed + lost != arrivals {
            return Err(format!(
                "{ctx}: {arrivals} arrivals != {} completed + {lost} lost",
                d.completed
            ));
        }
        if d.c.merged_recorder().len() != d.completed {
            return Err(format!(
                "{ctx}: recorder holds {} records for {} completions",
                d.c.merged_recorder().len(),
                d.completed
            ));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Wall-clock kill-storm regression over real TCP.
// ---------------------------------------------------------------------

fn storm_workload() -> Workload {
    let mut w = Workload::default();
    // fft's modeled cold boot (~2.4 s × scale) keeps the burst in
    // flight when the kill lands.
    w.register(
        mqfq::workload::catalog::by_name("fft").unwrap(),
        0,
        1.0,
    );
    w
}

/// Kill one of four shards under concurrently-blocked waiters: every
/// ticket resolves (completed or `shard-lost`) well inside one deadline
/// window — zero hung waiters — and the membership counters conserve at
/// quiescence.
#[test]
fn kill_storm_every_waiter_resolves_within_deadline() {
    const DEADLINE_MS: u64 = 30_000;
    let cfg = ClusterConfig {
        n_shards: 4,
        router: mqfq::cluster::RouterKind::RoundRobin,
        plane: PlaneConfig::default(),
        ..Default::default()
    };
    let srv = RtCluster::new(storm_workload(), cfg, None, 0.02).unwrap();
    let addr = srv.serve("127.0.0.1:0").unwrap();
    let mut sub = ApiClient::connect(addr).unwrap();
    let n = 32usize;
    let tickets: Vec<Ticket> = (0..n).map(|_| sub.invoke_async("fft-0").unwrap()).collect();
    // Waiters park on every ticket *before* the kill — about a quarter
    // of them are blocked on the doomed shard.
    let waiters: Vec<_> = tickets
        .chunks(8)
        .map(|chunk| {
            let chunk = chunk.to_vec();
            thread::spawn(move || {
                let mut w = ApiClient::connect(addr).unwrap();
                let mut fates = Vec::new();
                for t in chunk {
                    let s = Instant::now();
                    let r = w.wait(t, Some(DEADLINE_MS));
                    fates.push((r, s.elapsed()));
                }
                fates
            })
        })
        .collect();
    thread::sleep(Duration::from_millis(10));
    let m = sub.kill(1).expect("kill shard 1");
    assert_eq!(m.shards[1].health, ShardHealth::Dead);

    let (mut done, mut lost) = (0usize, 0usize);
    for h in waiters {
        for (r, elapsed) in h.join().expect("waiter panicked") {
            // Zero hung waiters: nothing rides out the deadline window
            // (shard-lost waiters must wake at the kill, not at expiry).
            assert!(
                elapsed < Duration::from_millis(DEADLINE_MS),
                "a waiter consumed its full deadline window ({elapsed:?})"
            );
            match r {
                Ok(_) => done += 1,
                Err(ApiError::ShardLost { shard, .. }) => {
                    assert_eq!(shard, 1, "lost ticket blamed the wrong shard");
                    lost += 1;
                }
                Err(e) => panic!("unexpected ticket fate: {e:?}"),
            }
        }
    }
    assert_eq!(done + lost, n, "a ticket vanished without a fate");
    assert!(lost > 0, "the kill stranded nothing — no in-flight work?");

    // Fates conserve once the survivors drain.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let m = sub.membership().expect("membership");
        if m.conserved_at_quiescence() {
            assert_eq!(m.accepted, n as u64);
            assert_eq!(m.completed, done as u64);
            assert_eq!(m.failed, lost as u64);
            break;
        }
        assert!(
            Instant::now() < deadline,
            "cluster never quiesced: {m:?}"
        );
        thread::sleep(Duration::from_millis(10));
    }
    sub.quit();
}
