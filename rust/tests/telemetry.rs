//! Telemetry integration suite: the deterministic-trace property (two
//! sim replays of the same trace render byte-identical JSONL), ring
//! overflow semantics (drop-oldest with an exact `dropped_events`
//! counter), and the live wire surface — a 4-shard cluster over real
//! TCP answering `metrics` (both formats) and `trace`, with the same
//! lifecycle vocabulary the simulator emits and counter conservation
//! against the per-shard `stats` breakdown.

use std::collections::HashSet;
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Duration;

use mqfq::api::{ApiClient, Frontend, MetricsFormat};
use mqfq::cluster::{ClusterConfig, RouterKind};
use mqfq::plane::PlaneConfig;
use mqfq::server::RtCluster;
use mqfq::sim::replay_traced;
use mqfq::telemetry::{self, EventKind, Telemetry, TraceEvent};
use mqfq::types::MS;
use mqfq::workload::catalog::by_name;
use mqfq::workload::zipf::{self, ZipfConfig};
use mqfq::workload::Workload;

fn zipf_pair() -> (Workload, mqfq::workload::Trace) {
    zipf::generate(&ZipfConfig {
        n_funcs: 6,
        total_rate: 1.5,
        duration_s: 120.0,
        seed: 11,
        ..Default::default()
    })
}

fn render_all(tel: &Telemetry) -> String {
    let mut out = String::new();
    for ev in tel.trace.drain(usize::MAX) {
        ev.render_jsonl_into(&mut out);
        out.push('\n');
    }
    out
}

fn run_traced_jsonl() -> String {
    let (w, t) = zipf_pair();
    let cfg = PlaneConfig::default();
    let (classes, _) = telemetry::workload_classes(&w);
    let tel = Arc::new(Telemetry::with_ring_capacity(
        &[cfg.n_devices()],
        &classes,
        1 << 20,
    ));
    let r = replay_traced(w, &t, cfg, Some(tel.clone()));
    assert!(r.events > 0);
    assert_eq!(tel.dropped_events(), 0, "ring sized to hold the full run");
    render_all(&tel)
}

#[test]
fn sim_trace_is_deterministic_and_well_formed() {
    let a = run_traced_jsonl();
    let b = run_traced_jsonl();
    assert!(!a.is_empty());
    assert_eq!(a, b, "same trace + config must render byte-identically");
    // Well-formed JSONL: every line is one event object with the
    // stable leading fields, and the lifecycle kinds all appear.
    let mut kinds = HashSet::new();
    for line in a.lines() {
        assert!(line.starts_with("{\"seq\":"), "{line}");
        assert!(line.ends_with('}'), "{line}");
        let kind = line
            .split("\"kind\":\"")
            .nth(1)
            .and_then(|s| s.split('"').next())
            .unwrap_or_default();
        assert!(EventKind::parse(kind).is_some(), "unknown kind in {line}");
        kinds.insert(kind.to_string());
    }
    for k in ["submit", "enqueue", "dispatch", "exec_start", "complete"] {
        assert!(kinds.contains(k), "lifecycle kind {k} missing from trace");
    }
    // Sequence numbers are the push order: strictly increasing.
    let seqs: Vec<u64> = a
        .lines()
        .map(|l| {
            l.strip_prefix("{\"seq\":")
                .and_then(|s| s.split(',').next())
                .and_then(|s| s.parse().ok())
                .unwrap()
        })
        .collect();
    assert!(seqs.windows(2).all(|w| w[1] > w[0]));
}

#[test]
fn ring_overflow_drops_oldest_and_counts_exactly() {
    let tel = Telemetry::with_ring_capacity(&[1], &["fft".to_string()], 8);
    for i in 0..20u64 {
        tel.emit(TraceEvent::new(i, EventKind::Submit, 0));
    }
    assert_eq!(tel.dropped_events(), 12);
    let events = tel.trace.drain(usize::MAX);
    assert_eq!(events.len(), 8);
    // Oldest dropped: the survivors are exactly the last 8 pushes, in
    // order, with their original sequence stamps.
    let seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
    assert_eq!(seqs, (12..20).collect::<Vec<u64>>());
}

fn live_cluster() -> (RtCluster, SocketAddr) {
    let mut w = Workload::default();
    w.register(by_name("isoneural").unwrap(), 0, 1.0);
    w.register(by_name("fft").unwrap(), 0, 1.0);
    let cfg = ClusterConfig {
        n_shards: 4,
        router: RouterKind::RoundRobin,
        plane: PlaneConfig {
            monitor_period: 20 * MS,
            ..Default::default()
        },
        ..Default::default()
    };
    let srv = RtCluster::new(w, cfg, None, 0.001).unwrap();
    let addr = srv.serve("127.0.0.1:0").unwrap();
    (srv, addr)
}

#[test]
fn live_cluster_exports_metrics_and_trace_over_the_wire() {
    let (srv, addr) = live_cluster();
    let mut client = ApiClient::connect(addr).unwrap();
    const N: usize = 8;
    for _ in 0..N {
        client
            .invoke("isoneural-0", Some(30_000))
            .expect("invoke over the wire");
    }

    // Per-shard stats breakdown: 4 rows, counts conserving against the
    // aggregate, every shard Up at epoch 0.
    let s = client.stats().unwrap();
    assert_eq!(s.invocations, N);
    assert_eq!(s.shards.len(), 4);
    assert_eq!(s.shards.iter().map(|r| r.completed).sum::<u64>(), N as u64);
    for (i, row) in s.shards.iter().enumerate() {
        assert_eq!(row.shard, i);
        assert_eq!(row.epoch, 0);
    }
    // Round-robin over 4 shards: all of them saw work.
    assert!(s.shards.iter().all(|r| r.completed == 2));

    // Prometheus text: typed families, and the registry's completion
    // counters conserve against the stats aggregate.
    let prom = client.metrics(MetricsFormat::Prom).unwrap();
    assert!(prom.contains("# TYPE"), "{prom}");
    assert!(prom.contains("mqfq_completed_total"), "{prom}");
    assert!(prom.contains("mqfq_trace_dropped_events_total"), "{prom}");

    // JSON document: versioned schema.
    let json = client.metrics(MetricsFormat::Json).unwrap();
    assert!(json.contains("mqfq-metrics/v1"), "{json}");
    assert!(json.contains("\"shards\""), "{json}");

    // Trace: the wire path speaks the simulator's lifecycle vocabulary,
    // plus the serving-only route event — one per accepted submit.
    let (dropped, events) = client.trace(1 << 20).unwrap();
    assert_eq!(dropped, 0);
    let kinds: HashSet<EventKind> = events.iter().map(|e| e.kind).collect();
    for k in [
        EventKind::Route,
        EventKind::Submit,
        EventKind::Enqueue,
        EventKind::Dispatch,
        EventKind::ExecStart,
        EventKind::Complete,
    ] {
        assert!(kinds.contains(&k), "missing {k:?} on the wire path");
    }
    assert_eq!(
        events.iter().filter(|e| e.kind == EventKind::Route).count(),
        N
    );
    assert_eq!(
        events
            .iter()
            .filter(|e| e.kind == EventKind::Complete)
            .count(),
        N
    );
    // Events cover all four shards.
    let shards: HashSet<u32> = events.iter().map(|e| e.shard).collect();
    assert_eq!(shards.len(), 4);

    // Paging: the ring was drained above; a fresh invocation produces a
    // fresh, small batch (`max` caps the page size).
    client.invoke("isoneural-0", Some(30_000)).unwrap();
    std::thread::sleep(Duration::from_millis(50));
    let (_, page) = client.trace(2).unwrap();
    assert!(page.len() <= 2);
    assert!(!page.is_empty());

    client.quit();
    drop(srv);
}

#[test]
fn kill_emits_epoch_event_and_stats_row_reflects_it() {
    let (srv, addr) = live_cluster();
    let mut client = ApiClient::connect(addr).unwrap();
    client.invoke("isoneural-0", Some(30_000)).unwrap();
    client.trace(1 << 20).unwrap(); // clear the ring
    client.kill(2).unwrap();
    let (_, events) = client.trace(1 << 20).unwrap();
    let epoch_ev = events
        .iter()
        .find(|e| e.kind == EventKind::Epoch)
        .expect("kill emits an epoch event");
    assert_eq!(epoch_ev.shard, 2);
    assert_eq!(epoch_ev.a, 1, "first kill bumps shard 2 to epoch 1");
    let s = client.stats().unwrap();
    assert_eq!(s.shards[2].epoch, 1);
    assert_eq!(s.shards[2].health, mqfq::api::ShardHealth::Dead);
    // The rebuilt plane keeps observing: work routed after a rejoin
    // still lands in the registry and the per-shard row.
    client.join(2).unwrap();
    for _ in 0..8 {
        client.invoke("isoneural-0", Some(30_000)).unwrap();
    }
    let s = client.stats().unwrap();
    assert!(s.shards[2].completed >= 1, "{:?}", s.shards[2]);
    client.quit();
    drop(srv);
}
