//! Property tests for the fault-tolerance layer: exactly-once ticket
//! fate under arbitrary seeded fault storms (every arrival resolves to
//! exactly one completion or one terminal retry-exhausted fate, across
//! policies and routers, with devices failing mid-run), and the
//! neutral-plan bit-identity contract (a present-but-empty fault plan
//! takes the fault branches yet replays bit-identically to `faults:
//! None`).

use mqfq::cluster::{ClusterConfig, ALL_ROUTERS};
use mqfq::fault::FaultConfig;
use mqfq::gpu::{uniform_fleet, MultiplexMode, V100};
use mqfq::plane::PlaneConfig;
use mqfq::scheduler::policies::PolicyKind;
use mqfq::scheduler::MqfqConfig;
use mqfq::sim::{replay, replay_cluster};
use mqfq::types::{secs, FuncId, GpuId};
use mqfq::util::prop::{assert_prop, Gen};
use mqfq::workload::catalog::CATALOG;
use mqfq::workload::trace::{Trace, TraceEvent, Workload};

/// Random workload + open-loop trace (prop_cluster's shape).
fn gen_scenario(g: &mut Gen) -> (Workload, Trace) {
    let n_funcs = g.int(1, 10);
    let mut w = Workload::default();
    for i in 0..n_funcs {
        let class = &CATALOG[g.int(0, CATALOG.len() - 1)];
        w.register(class, i, g.f64(0.5, 20.0));
    }
    let n_events = g.int(1, 100);
    let horizon = g.f64(10.0, 240.0);
    let mut t = Trace::default();
    for _ in 0..n_events {
        t.events.push(TraceEvent {
            at: secs(g.f64(0.0, horizon)),
            func: FuncId(g.int(0, n_funcs - 1) as u32),
        });
    }
    t.sort();
    (w, t)
}

fn gen_plane_config(g: &mut Gen) -> PlaneConfig {
    PlaneConfig {
        policy: *g.choose(&[
            PolicyKind::Fcfs,
            PolicyKind::Batch,
            PolicyKind::PaellaSjf,
            PolicyKind::Eevdf,
            PolicyKind::Sfq,
            PolicyKind::Mqfq,
        ]),
        // >= 2 GPUs so a mid-run device failure always leaves a live
        // device to evacuate to (recovery is also always scheduled).
        devices: uniform_fleet(2, V100, MultiplexMode::Plain),
        d: g.int(1, 3),
        pool_size: g.int(2, 32),
        mqfq: MqfqConfig {
            t: g.f64(0.0, 20.0),
            ttl_alpha: g.f64(0.0, 4.0),
            ..Default::default()
        },
        ..Default::default()
    }
}

/// Arbitrary seeded storm: transient faults, stragglers, sometimes a
/// poison tenant, sometimes a device failure (always with a recovery).
fn gen_fault_config(g: &mut Gen, n_funcs: usize, horizon: f64) -> FaultConfig {
    let mut fc = FaultConfig {
        seed: g.int(0, 1 << 20) as u64,
        transient_rate: g.f64(0.0, 0.5),
        straggler_rate: g.f64(0.0, 0.2),
        straggler_k: g.f64(1.5, 5.0),
        retry_budget: g.int(1, 4) as u32,
        ..Default::default()
    };
    if g.bool(0.3) {
        fc.poison
            .push((FuncId(g.int(0, n_funcs - 1) as u32), g.f64(0.5, 1.0)));
    }
    if g.bool(0.5) {
        let fail_at = g.f64(0.05, horizon * 0.5);
        let heal_at = fail_at + g.f64(0.1, horizon * 0.4);
        fc.device_failures.push((secs(fail_at), GpuId(0)));
        fc.device_recoveries.push((secs(heal_at), GpuId(0)));
    }
    if g.bool(0.3) {
        fc.max_faults = g.int(1, 50) as u64;
    }
    fc
}

/// Exactly-once across arbitrary storms and policies: every arrival is
/// either one completion record or one terminal retry-exhausted fate —
/// never both, never neither — and the plane fully drains with the
/// fault plan (and a possibly-failed device) in play.
#[test]
fn prop_faulty_replay_conserves_every_invocation() {
    assert_prop("fault-storm exactly-once", 40, |g| {
        let (w, t) = gen_scenario(g);
        let n = t.len();
        let n_funcs = w.funcs.len();
        let horizon = 240.0;
        let mut cfg = gen_plane_config(g);
        let fc = gen_fault_config(g, n_funcs, horizon);
        let failed_device = !fc.device_failures.is_empty();
        cfg.faults = Some(fc.clone());
        let ctx = format!(
            "policy={} seed={} rate={:.2} straggle={:.2} budget={} poison={} devfail={}",
            cfg.policy.name(),
            fc.seed,
            fc.transient_rate,
            fc.straggler_rate,
            fc.retry_budget,
            fc.poison.len(),
            failed_device,
        );
        let mut r = replay(w, &t, cfg);
        let fates = r.plane.drain_fault_fates();
        let completed = r.recorder().len();
        if completed + fates.len() != n {
            return Err(format!(
                "{ctx}: {n} arrivals != {completed} completions + {} fates",
                fates.len()
            ));
        }
        if r.plane.pending() != 0 || r.plane.in_flight() != 0 {
            return Err(format!(
                "{ctx}: not drained ({} pending, {} in flight)",
                r.plane.pending(),
                r.plane.in_flight()
            ));
        }
        // Each fate burned its full budget, and each inv appears once
        // across both resolution sets.
        for f in &fates {
            if f.attempts != fc.retry_budget {
                return Err(format!(
                    "{ctx}: fate {:?} resolved at {} attempts (budget {})",
                    f.inv, f.attempts, fc.retry_budget
                ));
            }
            if r.recorder().records.iter().any(|rec| rec.inv == f.inv) {
                return Err(format!("{ctx}: {:?} both completed and fated", f.inv));
            }
        }
        let stats = r.plane.fault_stats();
        if stats.retry_exhausted != fates.len() as u64 {
            return Err(format!(
                "{ctx}: stats.retry_exhausted {} != {} drained fates",
                stats.retry_exhausted,
                fates.len()
            ));
        }
        // No failure injected => the fleet never shrank.
        if !failed_device && r.plane.live_devices() != 2 {
            return Err(format!(
                "{ctx}: {} live devices with no failure injected",
                r.plane.live_devices()
            ));
        }
        Ok(())
    });
}

/// Cluster-level exactly-once: the merged recorder plus the per-shard
/// fate sum conserves arrivals under every router, with each shard
/// running the same seeded storm.
#[test]
fn prop_faulty_cluster_conserves_across_routers() {
    assert_prop("cluster fault conservation", 30, |g| {
        let (w, t) = gen_scenario(g);
        let n = t.len();
        let n_funcs = w.funcs.len();
        let mut plane = gen_plane_config(g);
        plane.faults = Some(gen_fault_config(g, n_funcs, 240.0));
        let cfg = ClusterConfig {
            n_shards: g.int(1, 6),
            router: *g.choose(&ALL_ROUTERS),
            plane,
            shard_planes: Vec::new(),
            load_factor: g.f64(1.0, 3.0),
            seed: g.int(0, 1 << 20) as u64,
            ..Default::default()
        };
        let ctx = format!("shards={} router={}", cfg.n_shards, cfg.router.name());
        let mut r = replay_cluster(w, &t, cfg);
        let fates = r.cluster.drain_fault_fates();
        let completed = r.recorder().len();
        if completed + fates.len() != n {
            return Err(format!(
                "{ctx}: {n} arrivals != {completed} completions + {} fates",
                fates.len()
            ));
        }
        if r.cluster.pending() != 0 || r.cluster.in_flight() != 0 {
            return Err(format!(
                "{ctx}: not drained ({} pending, {} in flight)",
                r.cluster.pending(),
                r.cluster.in_flight()
            ));
        }
        let stats = r.cluster.fault_stats();
        if stats.retry_exhausted != fates.len() as u64 {
            return Err(format!(
                "{ctx}: summed retry_exhausted {} != {} fates",
                stats.retry_exhausted,
                fates.len()
            ));
        }
        Ok(())
    });
}

/// Neutral-plan bit-identity: `faults: Some(FaultConfig::default())`
/// (a plan with nothing to inject) must replay bit-identically to
/// `faults: None` — same records, makespan, and event count — proving
/// the fault branches are pure overlays on the scheduling core.
#[test]
fn prop_zero_fault_plan_is_bit_identical() {
    assert_prop("zero-fault plan identity", 30, |g| {
        let (w, t) = gen_scenario(g);
        let base = gen_plane_config(g);
        let mut armed = base.clone();
        armed.faults = Some(FaultConfig::default());

        let a = replay(w.clone(), &t, base.clone());
        let mut b = replay(w, &t, armed);
        let ctx = format!("policy={} d={}", base.policy.name(), base.d);
        if a.events != b.events {
            return Err(format!("{ctx}: events {} != {}", a.events, b.events));
        }
        if a.makespan != b.makespan {
            return Err(format!(
                "{ctx}: makespan {} != {}",
                a.makespan, b.makespan
            ));
        }
        if a.recorder().records != b.recorder().records {
            return Err(format!("{ctx}: record streams diverge"));
        }
        let fates = b.plane.drain_fault_fates();
        if !fates.is_empty() {
            return Err(format!("{ctx}: empty plan produced {} fates", fates.len()));
        }
        let stats = b.plane.fault_stats();
        if stats != Default::default() {
            return Err(format!("{ctx}: empty plan moved fault stats: {stats:?}"));
        }
        Ok(())
    });
}
