//! Integration tests across the full control plane: sim replays in
//! every hardware mode, the real-time TCP server, trace file IO, and
//! failure/edge scenarios.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use mqfq::gpu::{MultiplexMode, A30, V100};
use mqfq::memory::MemPolicy;
use mqfq::plane::PlaneConfig;
use mqfq::scheduler::policies::PolicyKind;
use mqfq::server::RtServer;
use mqfq::sim::replay;
use mqfq::types::{secs, FuncId, MS};
use mqfq::workload::catalog::{by_name, CATALOG};
use mqfq::workload::trace::{Trace, TraceEvent, Workload};
use mqfq::workload::zipf::{self, ZipfConfig};

fn zipf_small() -> (Workload, Trace) {
    zipf::generate(&ZipfConfig {
        n_funcs: 8,
        total_rate: 1.0,
        duration_s: 120.0,
        seed: 42,
        ..Default::default()
    })
}

#[test]
fn every_mode_replays_cleanly() {
    for (mode, profile) in [
        (MultiplexMode::Plain, V100),
        (MultiplexMode::Mps, A30),
        (MultiplexMode::Mig(2), A30),
        (MultiplexMode::Mig(4), A30),
    ] {
        let (w, t) = zipf_small();
        let n = t.len();
        let cfg = PlaneConfig {
            mode,
            profile,
            ..Default::default()
        };
        let r = replay(w, &t, cfg);
        assert_eq!(r.recorder().len(), n, "{mode:?}");
        r.plane.check_invariants().unwrap();
    }
}

#[test]
fn every_policy_and_mem_policy_composes() {
    for policy in [
        PolicyKind::Fcfs,
        PolicyKind::Batch,
        PolicyKind::PaellaSjf,
        PolicyKind::Eevdf,
        PolicyKind::Sfq,
        PolicyKind::Mqfq,
    ] {
        for mem in [
            MemPolicy::StockUvm,
            MemPolicy::Madvise,
            MemPolicy::PrefetchOnly,
            MemPolicy::PrefetchSwap,
        ] {
            let (w, t) = zipf_small();
            let n = t.len();
            let cfg = PlaneConfig {
                policy,
                mem_policy: mem,
                ..Default::default()
            };
            let r = replay(w, &t, cfg);
            assert_eq!(r.recorder().len(), n, "{} + {}", policy.name(), mem.name());
        }
    }
}

#[test]
fn multi_gpu_beats_single_gpu_under_load() {
    let mk = || zipf::generate(&ZipfConfig {
        n_funcs: 12,
        total_rate: 3.0,
        duration_s: 300.0,
        seed: 7,
        ..Default::default()
    });
    let (w1, t1) = mk();
    let one = replay(w1, &t1, PlaneConfig::uniform(1, mqfq::gpu::V100, mqfq::gpu::MultiplexMode::Plain));
    let (w2, t2) = mk();
    let two = replay(w2, &t2, PlaneConfig::uniform(2, mqfq::gpu::V100, mqfq::gpu::MultiplexMode::Plain));
    assert!(
        two.recorder().weighted_avg_latency_s() < one.recorder().weighted_avg_latency_s(),
        "2 GPUs {:.2}s vs 1 GPU {:.2}s",
        two.recorder().weighted_avg_latency_s(),
        one.recorder().weighted_avg_latency_s()
    );
}

#[test]
fn dynamic_d_stays_within_bounds_and_drains() {
    let (w, t) = zipf_small();
    let n = t.len();
    let cfg = PlaneConfig {
        dynamic_d: Some((4, 0.9)),
        ..Default::default()
    };
    let r = replay(w, &t, cfg);
    assert_eq!(r.recorder().len(), n);
    for (_, d) in &r.recorder().d_timeline {
        assert!(*d >= 1 && *d <= 4);
    }
}

#[test]
fn burst_of_one_function_respects_d_and_completes() {
    let mut w = Workload::default();
    let f = w.register(by_name("roberta").unwrap(), 0, 0.1);
    let mut t = Trace::default();
    for i in 0..50 {
        t.events.push(TraceEvent {
            at: i * MS,
            func: f,
        });
    }
    let cfg = PlaneConfig {
        d: 2,
        ..Default::default()
    };
    let r = replay(w, &t, cfg);
    assert_eq!(r.recorder().len(), 50);
    // At most two containers should ever have been created: stickiness
    // avoids concurrent same-function cold starts beyond the D level.
    assert!(r.plane.pool_stats().cold <= 2, "{:?}", r.plane.pool_stats());
}

#[test]
fn tiny_pool_still_makes_progress() {
    let (w, t) = zipf_small();
    let n = t.len();
    let cfg = PlaneConfig {
        pool_size: 2,
        d: 2,
        ..Default::default()
    };
    let r = replay(w, &t, cfg);
    assert_eq!(r.recorder().len(), n);
    // Pool of 2 over 8 functions: constant churn, mostly cold starts.
    assert!(r.recorder().cold_ratio() > 0.3);
}

#[test]
fn empty_trace_is_a_noop() {
    let mut w = Workload::default();
    w.register(by_name("fft").unwrap(), 0, 1.0);
    let r = replay(w, &Trace::default(), PlaneConfig::default());
    assert_eq!(r.recorder().len(), 0);
    assert_eq!(r.makespan, 0);
}

#[test]
fn single_invocation_of_every_class() {
    let mut w = Workload::default();
    let mut t = Trace::default();
    for (i, class) in CATALOG.iter().enumerate() {
        let f = w.register(class, 0, 60.0);
        t.events.push(TraceEvent {
            at: secs(i as f64 * 40.0),
            func: f,
        });
    }
    let r = replay(w, &t, PlaneConfig::default());
    assert_eq!(r.recorder().len(), CATALOG.len());
    // Spaced-out single invocations are all cold.
    assert_eq!(r.plane.pool_stats().cold as usize, CATALOG.len());
}

#[test]
fn trace_file_roundtrip_replays_identically() {
    let (w, t) = zipf_small();
    let dir = std::env::temp_dir().join("mqfq_int_trace");
    let path = dir.join("w.trace");
    t.save(&w, &path).unwrap();
    let (w2, t2) = Trace::load(&path).unwrap();
    let a = replay(w, &t, PlaneConfig::default());
    let b = replay(w2, &t2, PlaneConfig::default());
    assert_eq!(a.recorder().len(), b.recorder().len());
    assert!(
        (a.recorder().weighted_avg_latency_s() - b.recorder().weighted_avg_latency_s()).abs()
            < 1e-9
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn tcp_server_serves_invocations_and_stats() {
    let mut w = Workload::default();
    w.register(by_name("isoneural").unwrap(), 0, 1.0);
    let cfg = PlaneConfig {
        monitor_period: 20 * MS,
        ..Default::default()
    };
    let srv = RtServer::new(w, cfg, None, 0.001).unwrap();
    let addr = srv.serve("127.0.0.1:0").unwrap();

    let mut conn = TcpStream::connect(addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    conn.write_all(b"invoke isoneural-0\ninvoke isoneural-0\nstats\nquit\n")
        .unwrap();
    let reader = BufReader::new(conn.try_clone().unwrap());
    let lines: Vec<String> = reader.lines().map_while(Result::ok).collect();
    assert_eq!(lines.len(), 3, "{lines:?}");
    assert!(lines[0].starts_with("ok "));
    assert!(lines[1].starts_with("ok "));
    assert!(lines[2].contains("invocations=2"), "{}", lines[2]);
    // Second invocation must have been warm (same container).
    assert!(lines[1].contains("warm"), "{}", lines[1]);
}

#[test]
fn naive_mode_destroys_containers() {
    let mut w = Workload::default();
    let f = w.register(by_name("fft").unwrap(), 0, 1.0);
    let mut t = Trace::default();
    for i in 0..5 {
        t.events.push(TraceEvent {
            at: secs(i as f64 * 30.0),
            func: f,
        });
    }
    let cfg = PlaneConfig {
        keep_warm: false,
        ..Default::default()
    };
    let r = replay(w, &t, cfg);
    assert_eq!(r.recorder().cold_ratio(), 1.0, "naive mode must be all-cold");
}
