//! Property tests for the anticipatory scheduling subsystem
//! (estimator, grace periods, batch dispatch, adaptive D — see
//! `scheduler::mqfq` §Anticipatory scheduling).
//!
//! The load-bearing property: with every anticipation knob at its
//! neutral setting (grace 0, batch-max 1, estimator off, static D) the
//! scheduler is bit-identical to the pre-anticipation dispatch core —
//! full `InvRecord` streams, across all policies. The knobs are pure
//! extensions, not behavior drift.

use mqfq::estimator::AnticipateConfig;
use mqfq::gpu::{uniform_fleet, MultiplexMode};
use mqfq::memory::MemPolicy;
use mqfq::plane::PlaneConfig;
use mqfq::scheduler::policies::PolicyKind;
use mqfq::scheduler::{Invocation, MqfqConfig, MqfqSticky, Policy, PolicyCtx};
use mqfq::sim::replay;
use mqfq::types::{secs, FuncId, InvocationId};
use mqfq::util::prop::{assert_prop, Gen};
use mqfq::workload::catalog::CATALOG;
use mqfq::workload::trace::{Trace, TraceEvent, Workload};

const POLICIES: [PolicyKind; 6] = [
    PolicyKind::Fcfs,
    PolicyKind::Batch,
    PolicyKind::PaellaSjf,
    PolicyKind::Eevdf,
    PolicyKind::Sfq,
    PolicyKind::Mqfq,
];

/// Random workload + open-loop trace (bursty enough that grace windows
/// and batch opportunities actually arise).
fn gen_scenario(g: &mut Gen) -> (Workload, Trace) {
    let n_funcs = g.int(1, 10);
    let mut w = Workload::default();
    for i in 0..n_funcs {
        let class = &CATALOG[g.int(0, CATALOG.len() - 1)];
        w.register(class, i, g.f64(0.5, 20.0));
    }
    let n_events = g.int(1, 140);
    let horizon = g.f64(10.0, 240.0);
    let mut t = Trace::default();
    for _ in 0..n_events {
        // Half the events land inside short bursts so same-flow
        // back-to-back arrivals (the batching substrate) are common.
        let at = if g.bool(0.5) {
            g.f64(0.0, horizon)
        } else {
            g.f64(0.0, horizon / 8.0)
        };
        t.events.push(TraceEvent {
            at: secs(at),
            func: FuncId(g.int(0, n_funcs - 1) as u32),
        });
    }
    t.sort();
    (w, t)
}

fn gen_config(g: &mut Gen) -> PlaneConfig {
    PlaneConfig {
        policy: *g.choose(&POLICIES),
        devices: uniform_fleet(
            g.int(1, 2),
            mqfq::gpu::V100,
            *g.choose(&[MultiplexMode::Plain, MultiplexMode::Mps, MultiplexMode::Mig(2)]),
        ),
        mem_policy: *g.choose(&[MemPolicy::StockUvm, MemPolicy::Madvise]),
        d: g.int(1, 4),
        pool_size: g.int(2, 24),
        mqfq: MqfqConfig {
            t: g.f64(0.0, 20.0),
            ttl_alpha: g.f64(0.0, 4.0),
            vt_wall_time: g.bool(0.8),
            sticky: g.bool(0.8),
            ..Default::default()
        },
        ..Default::default()
    }
}

/// Anticipation disabled ≡ the scheduler that shipped before it
/// existed: the default config, an explicitly-neutral AnticipateConfig
/// (with a varied — and therefore provably inert — batch_marginal),
/// and an adaptive-D controller pinned to MIN = MAX = D all replay to
/// bit-identical `InvRecord` streams, under every policy.
#[test]
fn prop_neutral_anticipation_is_bit_identical() {
    assert_prop("neutral-anticipation-identity", 50, |g| {
        let (w, t) = gen_scenario(g);
        let base = gen_config(g);
        let mut neutral = base.clone();
        neutral.mqfq.anticipate = AnticipateConfig {
            grace_alpha: 0.0,
            batch_max: 1,
            batch_marginal: g.f64(0.0, 2.0), // inert when batch_max = 1
            estimator: false,
        };
        let mut pinned_d = base.clone();
        pinned_d.adaptive_d = Some((base.d, base.d));
        let label = format!("{} d={}", base.policy.name(), base.d);
        let reference = replay(w.clone(), &t, base).recorder().records.clone();
        for (name, cfg) in [("neutral", neutral), ("pinned-D", pinned_d)] {
            let records = replay(w.clone(), &t, cfg).recorder().records.clone();
            if records != reference {
                return Err(format!(
                    "{label}: {name} config diverged from the default \
                     ({} vs {} records)",
                    records.len(),
                    reference.len()
                ));
            }
        }
        Ok(())
    });
}

/// `dispatch_batch` under a neutral config is the single-dispatch code
/// path: two MqfqSticky instances fed identical arrival/completion
/// streams — one driven through `dispatch()`, the other through
/// `dispatch_batch()` — make identical decisions at every step, and the
/// batch-driven one never reports an anticipation event.
#[test]
fn prop_batch_path_equals_serial_path_when_neutral() {
    assert_prop("neutral-batch-path-identity", 60, |g| {
        let n_funcs = g.int(1, 8);
        let cfg = MqfqConfig {
            t: g.f64(0.0, 10.0),
            ttl_alpha: g.f64(0.0, 4.0),
            vt_wall_time: g.bool(0.8),
            sticky: g.bool(0.8),
            ..Default::default()
        };
        assert!(!cfg.anticipate.enabled(), "default must be neutral");
        let mut a = MqfqSticky::new(n_funcs, cfg.clone());
        let mut b = MqfqSticky::new(n_funcs, cfg);
        let d = g.int(1, 3);
        let mut in_flight = vec![0usize; n_funcs];
        let mut outstanding: Vec<Invocation> = Vec::new();
        let mut buf = Vec::new();
        let (mut id, mut now) = (0u64, 0u64);
        for step in 0..g.int(10, 200) {
            now += secs(g.f64(0.0, 2.0));
            match g.int(0, 2) {
                0 => {
                    let inv = Invocation {
                        id: InvocationId(id),
                        func: FuncId(g.int(0, n_funcs - 1) as u32),
                        arrived: now,
                    };
                    id += 1;
                    a.enqueue(inv, now);
                    b.enqueue(inv, now);
                }
                1 => {
                    let ctx = PolicyCtx {
                        in_flight: &in_flight,
                        d,
                    };
                    let serial = a.dispatch(now, &ctx);
                    buf.clear();
                    b.dispatch_batch(now, &ctx, &mut buf);
                    if buf.len() > 1 {
                        return Err(format!(
                            "step {step}: neutral config coalesced {} invocations",
                            buf.len()
                        ));
                    }
                    if serial != buf.first().copied() {
                        return Err(format!(
                            "step {step}: dispatch()={serial:?} but \
                             dispatch_batch()={:?}",
                            buf.first()
                        ));
                    }
                    if let Some(inv) = serial {
                        in_flight[inv.func.0 as usize] += 1;
                        outstanding.push(inv);
                    }
                }
                _ => {
                    if !outstanding.is_empty() {
                        let k = g.int(0, outstanding.len() - 1);
                        let inv = outstanding.swap_remove(k);
                        in_flight[inv.func.0 as usize] -= 1;
                        let service = secs(g.f64(0.01, 3.0));
                        // Different completion entry points on purpose:
                        // the provenance-carrying hook must not change
                        // neutral scheduling either.
                        a.on_complete(inv.func, service, now);
                        b.on_complete_info(inv.func, service, None, 0, now);
                    }
                }
            }
            if a.pending() != b.pending() {
                return Err(format!(
                    "step {step}: pending diverged {} vs {}",
                    a.pending(),
                    b.pending()
                ));
            }
            if a.drain_state_changes() != b.drain_state_changes() {
                return Err(format!("step {step}: state transitions diverged"));
            }
            if !b.drain_anticipation().is_empty() {
                return Err(format!(
                    "step {step}: anticipation events under a neutral config"
                ));
            }
        }
        for f in 0..n_funcs {
            let func = FuncId(f as u32);
            if a.queue_vt(func) != b.queue_vt(func) {
                return Err(format!("flow {f}: virtual time diverged"));
            }
        }
        Ok(())
    });
}

/// Conservation under full anticipation: grace windows, coalesced batch
/// dispatch, the estimator, and adaptive D never lose, duplicate, or
/// reorder-in-time an invocation — every arrival completes exactly
/// once, causally, and the plane's deep invariants hold drained.
#[test]
fn prop_batched_completions_conserved() {
    assert_prop("anticipation-conservation", 50, |g| {
        let (w, t) = gen_scenario(g);
        let n = t.len();
        let mut cfg = gen_config(g);
        cfg.mqfq.anticipate = AnticipateConfig {
            grace_alpha: g.f64(0.5, 4.0),
            batch_max: g.int(2, 5),
            batch_marginal: g.f64(0.2, 0.9),
            estimator: g.bool(0.5),
        };
        if g.bool(0.5) {
            cfg.adaptive_d = Some((1, g.int(1, 4)));
        }
        let label = format!(
            "{} grace={:.1} batch={} est={} adaptive={:?}",
            cfg.policy.name(),
            cfg.mqfq.anticipate.grace_alpha,
            cfg.mqfq.anticipate.batch_max,
            cfg.mqfq.anticipate.estimator,
            cfg.adaptive_d,
        );
        let r = replay(w, &t, cfg);
        if r.recorder().len() != n {
            return Err(format!(
                "{label}: {n} arrivals but {} completions",
                r.recorder().len()
            ));
        }
        let mut seen = std::collections::HashSet::new();
        for rec in &r.recorder().records {
            if !seen.insert(rec.inv) {
                return Err(format!("{label}: duplicate completion {:?}", rec.inv));
            }
            if rec.dispatched < rec.arrived || rec.completed <= rec.dispatched {
                return Err(format!("{label}: non-causal record {rec:?}"));
            }
        }
        if r.plane.in_flight() != 0 || r.plane.pending() != 0 {
            return Err(format!("{label}: undrained plane"));
        }
        r.plane
            .check_invariants()
            .map_err(|e| format!("{label}: {e}"))
    });
}

/// The estimator is deterministic under replay: the same trace and the
/// same fully-anticipating config produce byte-identical record streams
/// on repeated runs (EWMA state is a pure function of the event
/// sequence — no wall clocks, no ambient randomness).
#[test]
fn prop_estimator_replay_deterministic() {
    assert_prop("estimator-determinism", 30, |g| {
        let (w, t) = gen_scenario(g);
        let mut cfg = gen_config(g);
        cfg.policy = *g.choose(&[PolicyKind::Mqfq, PolicyKind::Sfq]);
        cfg.mqfq.anticipate = AnticipateConfig {
            grace_alpha: g.f64(0.5, 3.0),
            batch_max: g.int(2, 4),
            batch_marginal: g.f64(0.2, 0.9),
            estimator: true,
        };
        cfg.adaptive_d = Some((1, 4));
        let first = replay(w.clone(), &t, cfg.clone()).recorder().records.clone();
        let second = replay(w, &t, cfg).recorder().records.clone();
        if first != second {
            return Err("two replays of one trace+config diverged".into());
        }
        Ok(())
    });
}
