//! Wire-path allocation-churn gates: the serving loop's encode/parse
//! primitives must stop allocating once warm (the PR-4 loop built a
//! `String`-keyed `Json::Obj` tree per message and a fresh `String`
//! per line).
//!
//! A counting global allocator measures heap events (alloc/realloc)
//! around each primitive. This binary intentionally holds exactly ONE
//! `#[test]` so no concurrent test can pollute the global counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use mqfq::api::types::{InvokeOutcome, Response, StatsSnapshot, Ticket};
use mqfq::api::wire;
use mqfq::telemetry::{EventKind, Telemetry, TraceEvent};
use mqfq::types::StartKind;
use mqfq::util::json::Json;

struct CountingAlloc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs_during<R>(f: impl FnOnce() -> R) -> (u64, R) {
    let before = ALLOC_EVENTS.load(Ordering::SeqCst);
    let r = f();
    (ALLOC_EVENTS.load(Ordering::SeqCst) - before, r)
}

#[test]
fn wire_path_steady_state_allocation_churn() {
    const ITERS: u64 = 100;

    // -- 1. Writer-based response encoding into a warmed buffer: the
    // steady-state serving reply path performs ZERO heap events.
    let done = Response::Done(InvokeOutcome {
        ticket: Ticket(42),
        func: "fft-0".to_string(),
        shard: 3,
        gpu: 1,
        start_kind: StartKind::GpuWarm,
        latency_ms: 12.375,
        exec_ms: 9.0625,
    });
    let stats = Response::Stats(StatsSnapshot {
        invocations: 123456,
        mean_latency_ms: 3.25,
        cold_ratio: 0.125,
        pending: 7,
        in_flight: 5,
        shards: Vec::new(),
    });
    let mut out = String::with_capacity(512);
    wire::encode_response_into(&done, &mut out); // warm the buffer
    let (n, _) = allocs_during(|| {
        for _ in 0..ITERS {
            out.clear();
            wire::encode_response_into(&done, &mut out);
            out.clear();
            wire::encode_response_into(&stats, &mut out);
        }
    });
    assert_eq!(
        n, 0,
        "writer-based response encoding must not allocate into a warm buffer"
    );

    // -- 2. render_compact_into on a prebuilt tree reuses the caller's
    // buffer: zero heap events once warm.
    let tree = Json::Obj(vec![
        ("ok".into(), Json::Bool(true)),
        ("type".into(), Json::str("stats")),
        ("invocations".into(), Json::Int(99)),
        ("mean_latency_ms".into(), Json::Num(1.5)),
    ]);
    let mut buf = String::with_capacity(512);
    tree.render_compact_into(&mut buf);
    let (n, _) = allocs_during(|| {
        for _ in 0..ITERS {
            buf.clear();
            tree.render_compact_into(&mut buf);
        }
    });
    assert_eq!(n, 0, "render_compact_into must reuse the warm buffer");

    // -- 3. The borrowed request parse allocates strictly less than the
    // owned tree parse: escape-free strings stay slices of the line, so
    // only the object's field vector touches the heap.
    let line = r#"{"cmd":"invoke","func":"fft-0","mode":"sync","deadline_ms":5000}"#;
    let (owned, _) = allocs_during(|| {
        for _ in 0..ITERS {
            std::hint::black_box(wire::parse_json(line).unwrap());
        }
    });
    let (borrowed, _) = allocs_during(|| {
        for _ in 0..ITERS {
            std::hint::black_box(wire::parse_jval(line).unwrap());
        }
    });
    assert!(
        borrowed < owned,
        "borrowed parse ({borrowed} heap events) must undercut the owned parse ({owned})"
    );
    // Field-vector growth only: well under one heap event per field,
    // and nothing per string (4 keys + 3 string values stay borrowed).
    assert!(
        borrowed <= ITERS * 5,
        "borrowed parse churns too much: {borrowed} heap events over {ITERS} parses"
    );

    // -- 4. Telemetry record path: steady-state metric recording and
    // ring-buffered event tracing perform ZERO heap events — counters,
    // gauges, histograms, and the trace ring (including the drop-oldest
    // overflow path, which the small capacity forces) are all
    // preallocated at construction.
    let tel = Telemetry::with_ring_capacity(&[2], &["fft".to_string()], 64);
    let m = tel.registry.shard(0);
    tel.emit(TraceEvent::new(0, EventKind::Submit, 0)); // warm (no-op: ring is prebuilt)
    let (n, _) = allocs_during(|| {
        for i in 0..ITERS {
            m.submitted.inc();
            m.completed.inc();
            m.d_tokens.set(2);
            m.global_vt_ns.set(i as i64);
            m.queue_wait_ns.record(1_000 * i);
            m.exec_ns.record(1_000_000);
            m.e2e_ns.record(1_001_000);
            tel.registry.device(0, 0).unwrap().dispatches.inc();
            tel.registry.class(0).unwrap().completed.inc();
            tel.emit(
                TraceEvent::new(i, EventKind::Dispatch, 0)
                    .inv(i)
                    .func(0)
                    .a(1)
                    .b(2),
            );
            tel.emit(TraceEvent::new(i, EventKind::Complete, 0).inv(i).func(0));
            // Serving-front-end family: recorded from the event loop's
            // accept/dispatch/push paths, same zero-alloc guarantee.
            let sv = tel.registry.serving();
            sv.accepted_connections.inc();
            sv.open_connections.set(i as i64);
            sv.pipeline_depth.record(1 + i % 16);
            sv.push_subscriptions.inc();
            sv.push_notifications.inc();
            sv.push_dropped.inc();
            sv.slow_client_disconnects.inc();
            // Fault-tolerance family: injection, retry, breaker, and
            // shed paths record through the same preallocated registry
            // and ring — a fault storm must not churn the heap either.
            m.faults_device.inc();
            m.faults_transient.inc();
            m.faults_straggler.inc();
            m.retries.inc();
            m.retry_exhausted.inc();
            m.breaker_trips.inc();
            m.breaker_probes.inc();
            m.shed.inc();
            tel.emit(TraceEvent::new(i, EventKind::Fault, 0).inv(i).func(0).a(1));
            tel.emit(TraceEvent::new(i, EventKind::Requeue, 0).inv(i).func(0).a(2));
            tel.emit(TraceEvent::new(i, EventKind::BreakerState, 0).func(0).a(1));
            tel.emit(TraceEvent::new(i, EventKind::Shed, 0).func(0).a(3).b(250));
        }
    });
    assert_eq!(
        n, 0,
        "telemetry record path must not allocate in steady state"
    );
    // The loop overflowed the 64-slot ring (2 events x 100 iters + warm).
    assert!(tel.dropped_events() > 0, "overflow path was exercised");

    // -- 5. End-to-end line handling sanity: the borrowed value really
    // borrows (no silent fallback to owned strings).
    let v = wire::parse_jval(line).unwrap();
    assert_eq!(v.get_str("cmd"), Some("invoke"));
    assert_eq!(v.get_str("func"), Some("fft-0"));
    assert!(matches!(
        v.get("func"),
        Some(wire::JVal::Str(std::borrow::Cow::Borrowed("fft-0")))
    ));
}
