//! Property-based tests over the coordinator's invariants (DESIGN.md
//! deliverable c): randomized workloads, traces and configurations,
//! checked against structural and algorithmic properties.
//!
//! Uses the in-repo `util::prop` mini-framework (proptest is not in the
//! offline vendor set); python-side property testing uses hypothesis.

use mqfq::gpu::{uniform_fleet, MultiplexMode};
use mqfq::memory::MemPolicy;
use mqfq::plane::PlaneConfig;
use mqfq::scheduler::policies::PolicyKind;
use mqfq::scheduler::{Invocation, MqfqConfig, MqfqSticky, Policy, PolicyCtx};
use mqfq::sim::replay;
use mqfq::types::{secs, FuncId, InvocationId, SEC};
use mqfq::util::prop::{assert_prop, Gen};
use mqfq::workload::catalog::CATALOG;
use mqfq::workload::trace::{Trace, TraceEvent, Workload};

/// Random workload + open-loop trace.
fn gen_scenario(g: &mut Gen) -> (Workload, Trace) {
    let n_funcs = g.int(1, 12);
    let mut w = Workload::default();
    for i in 0..n_funcs {
        let class = &CATALOG[g.int(0, CATALOG.len() - 1)];
        w.register(class, i, g.f64(0.5, 20.0));
    }
    let n_events = g.int(1, 120);
    let horizon = g.f64(10.0, 300.0);
    let mut t = Trace::default();
    for _ in 0..n_events {
        t.events.push(TraceEvent {
            at: secs(g.f64(0.0, horizon)),
            func: FuncId(g.int(0, n_funcs - 1) as u32),
        });
    }
    t.sort();
    (w, t)
}

fn gen_config(g: &mut Gen) -> PlaneConfig {
    let policy = *g.choose(&[
        PolicyKind::Fcfs,
        PolicyKind::Batch,
        PolicyKind::PaellaSjf,
        PolicyKind::Eevdf,
        PolicyKind::Sfq,
        PolicyKind::Mqfq,
    ]);
    let mode = *g.choose(&[
        MultiplexMode::Plain,
        MultiplexMode::Mps,
        MultiplexMode::Mig(2),
    ]);
    PlaneConfig {
        policy,
        devices: uniform_fleet(g.int(1, 2), mqfq::gpu::V100, mode),
        mem_policy: *g.choose(&[
            MemPolicy::StockUvm,
            MemPolicy::Madvise,
            MemPolicy::PrefetchOnly,
            MemPolicy::PrefetchSwap,
        ]),
        d: g.int(1, 4),
        pool_size: g.int(2, 32),
        mqfq: MqfqConfig {
            t: g.f64(0.0, 20.0),
            ttl_alpha: g.f64(0.0, 4.0),
            vt_wall_time: g.bool(0.8),
            sticky: g.bool(0.8),
            ..Default::default()
        },
        ..Default::default()
    }
}

/// Every arrival eventually completes, exactly once, causally ordered —
/// across random policies, memory managers, modes and D levels. The
/// plane's deep invariants (ledger consistency, token limits) are also
/// asserted at every monitor tick in debug builds.
#[test]
fn prop_no_invocation_lost_or_duplicated() {
    assert_prop("conservation", 60, |g| {
        let (w, t) = gen_scenario(g);
        let n = t.len();
        let cfg = gen_config(g);
        let label = format!("{} d={} pool={}", cfg.policy.name(), cfg.d, cfg.pool_size);
        let r = replay(w, &t, cfg);
        if r.recorder().len() != n {
            return Err(format!(
                "{label}: {} arrivals but {} completions",
                n,
                r.recorder().len()
            ));
        }
        let mut seen = std::collections::HashSet::new();
        for rec in &r.recorder().records {
            if !seen.insert(rec.inv) {
                return Err(format!("{label}: duplicate completion {:?}", rec.inv));
            }
            if rec.dispatched < rec.arrived || rec.completed <= rec.dispatched {
                return Err(format!("{label}: non-causal record {rec:?}"));
            }
        }
        if r.plane.in_flight() != 0 || r.plane.pending() != 0 {
            return Err(format!("{label}: undrained plane"));
        }
        r.plane
            .check_invariants()
            .map_err(|e| format!("{label}: {e}"))
    });
}

/// MQFQ-Sticky's over-run bound: a flow is never dispatched when its VT
/// exceeds Global_VT + T, so VT spreads among backlogged flows stay
/// within T + τ_max of each other.
#[test]
fn prop_mqfq_overrun_bounded() {
    assert_prop("overrun-bound", 80, |g| {
        let n_flows = g.int(2, 10);
        let t_overrun = g.f64(0.0, 10.0);
        let mut p = MqfqSticky::new(
            n_flows,
            MqfqConfig {
                t: t_overrun,
                vt_wall_time: true,
                sticky: g.bool(0.5),
                ..Default::default()
            },
        );
        let in_flight = vec![0usize; n_flows];
        let mut id = 0u64;
        let mut services: Vec<f64> = (0..n_flows).map(|_| g.f64(0.1, 5.0)).collect();
        services.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let tau_max = services[n_flows - 1];
        // Backlog every flow.
        for f in 0..n_flows {
            for _ in 0..g.int(1, 8) {
                p.enqueue(
                    Invocation {
                        id: InvocationId(id),
                        func: FuncId(f as u32),
                        arrived: 0,
                    },
                    0,
                );
                id += 1;
            }
        }
        let steps = g.int(5, 60);
        for step in 0..steps {
            let now = step as u64 * SEC;
            let ctx = PolicyCtx {
                in_flight: &in_flight,
                d: 2,
            };
            let Some(inv) = p.dispatch(now, &ctx) else {
                break;
            };
            let backlogged: Vec<f64> = (0..n_flows)
                .filter(|&i| !p.flow(FuncId(i as u32)).is_empty())
                .map(|i| p.queue_vt(FuncId(i as u32)).unwrap())
                .collect();
            if backlogged.len() >= 2 {
                let max = backlogged.iter().cloned().fold(f64::MIN, f64::max);
                let min = backlogged.iter().cloned().fold(f64::MAX, f64::min);
                // Chosen flow had vt ≤ global+T pre-dispatch; its VT then
                // advanced by at most τ_max (EMA of observed services
                // never exceeds the largest single service time; 1.0 is
                // the black-box default before feedback).
                let bound = t_overrun + tau_max.max(1.0) + 1e-6;
                if max - min > bound {
                    return Err(format!(
                        "VT spread {:.3} > bound {:.3} (T={t_overrun:.2})",
                        max - min,
                        bound
                    ));
                }
            }
            p.on_complete(
                inv.func,
                secs(services[inv.func.0 as usize % services.len()]),
                now,
            );
        }
        Ok(())
    });
}

/// `Policy::pending()` is an O(1) counter in every policy; this checks
/// the counter against externally-tracked conservation (enqueued −
/// dispatched) through arbitrary interleavings of arrivals, dispatches,
/// and completions, across all five policies.
#[test]
fn prop_pending_counter_is_conserved() {
    assert_prop("pending-o1-conservation", 40, |g| {
        let n_funcs = g.int(1, 10);
        let kind = *g.choose(&[
            PolicyKind::Fcfs,
            PolicyKind::Batch,
            PolicyKind::PaellaSjf,
            PolicyKind::Eevdf,
            PolicyKind::Sfq,
            PolicyKind::Mqfq,
        ]);
        let d = g.int(1, 4);
        let mut p = kind.build(n_funcs);
        let mut in_flight = vec![0usize; n_funcs];
        let mut outstanding: Vec<Invocation> = Vec::new();
        let mut queued = 0usize;
        let mut id = 0u64;
        let mut now = 0u64;
        for step in 0..g.int(5, 150) {
            now += secs(g.f64(0.0, 2.0));
            match g.int(0, 2) {
                0 => {
                    let inv = Invocation {
                        id: InvocationId(id),
                        func: FuncId(g.int(0, n_funcs - 1) as u32),
                        arrived: now,
                    };
                    id += 1;
                    p.enqueue(inv, now);
                    queued += 1;
                }
                1 => {
                    let ctx = PolicyCtx {
                        in_flight: &in_flight,
                        d,
                    };
                    if let Some(inv) = p.dispatch(now, &ctx) {
                        queued -= 1;
                        in_flight[inv.func.0 as usize] += 1;
                        outstanding.push(inv);
                    }
                }
                _ => {
                    if !outstanding.is_empty() {
                        let k = g.int(0, outstanding.len() - 1);
                        let inv = outstanding.swap_remove(k);
                        in_flight[inv.func.0 as usize] -= 1;
                        p.on_complete(inv.func, secs(g.f64(0.01, 3.0)), now);
                    }
                }
            }
            if p.pending() != queued {
                return Err(format!(
                    "{} step {step}: pending()={} but {} queued",
                    kind.name(),
                    p.pending(),
                    queued
                ));
            }
        }
        Ok(())
    });
}

/// FIFO within each flow: invocations of one function dispatch in
/// arrival order under every policy.
#[test]
fn prop_fifo_within_function() {
    assert_prop("per-flow-fifo", 60, |g| {
        let (w, t) = gen_scenario(g);
        let cfg = gen_config(g);
        let r = replay(w, &t, cfg);
        let mut recs = r.plane.recorder.records.clone();
        recs.sort_by_key(|rec| (rec.dispatched, rec.inv.0));
        let mut last_arrival: std::collections::HashMap<u32, u64> =
            std::collections::HashMap::new();
        for rec in &recs {
            let e = last_arrival.entry(rec.func.0).or_insert(0);
            if rec.arrived < *e {
                return Err(format!(
                    "{} dispatched after a later arrival of the same flow",
                    rec.inv
                ));
            }
            *e = rec.arrived;
        }
        Ok(())
    });
}

/// The container pool never exceeds capacity; acquisition stats are
/// conserved.
#[test]
fn prop_pool_accounting() {
    assert_prop("pool-capacity", 40, |g| {
        let (w, t) = gen_scenario(g);
        let n = t.len() as u64;
        let cfg = gen_config(g);
        let r = replay(w, &t, cfg);
        let stats = r.plane.pool_stats();
        if stats.total() != n {
            return Err(format!(
                "{} acquisitions vs {n} invocations",
                stats.total()
            ));
        }
        if stats.cold == 0 && n > 0 {
            return Err("first start of every function must be cold".into());
        }
        Ok(())
    });
}

/// Fairness (Eq 1): under MQFQ, continuously backlogged same-τ functions'
/// service gap stays below the theoretical bound in every window.
#[test]
fn prop_fairness_gap_below_bound() {
    assert_prop("eq1-bound", 25, |g| {
        let n_funcs = g.int(2, 8);
        let mut w = Workload::default();
        // Same class for all copies: τ_i = τ_j, tight bound (D-1)(2T).
        let class = &CATALOG[g.int(0, CATALOG.len() - 1)];
        for i in 0..n_funcs {
            w.register(class, i, 1.0);
        }
        let mut t = Trace::default();
        // Saturating load so flows stay continuously backlogged.
        let horizon = 120.0;
        let per_fn = g.int(30, 80);
        for f in 0..n_funcs {
            for k in 0..per_fn {
                t.events.push(TraceEvent {
                    at: secs(k as f64 * horizon / per_fn as f64),
                    func: FuncId(f as u32),
                });
            }
        }
        t.sort();
        let d = g.int(1, 3);
        let t_overrun = g.f64(1.0, 10.0);
        let cfg = PlaneConfig {
            policy: PolicyKind::Mqfq,
            d,
            mqfq: MqfqConfig {
                t: t_overrun,
                ..Default::default()
            },
            ..Default::default()
        };
        let r = replay(w, &t, cfg);
        let windows = mqfq::metrics::service_windows(
            &r.recorder().records,
            n_funcs,
            30 * SEC,
            r.makespan,
        );
        // Same-τ flows: Eq-1 bound = (D-1)·2T, plus the service quantum
        // slack (executions straddle window edges, and interference can
        // stretch a single service by the congestion factor).
        let quantum = 2.0 * (class.gpu_warm_s * 3.0 + 1.0);
        let bound =
            mqfq::metrics::fairness_bound_eq1(d, t_overrun, 0.0, 0.0) + quantum;
        for win in &windows {
            let gap = win.max_gap_s();
            if gap > bound {
                return Err(format!(
                    "gap {gap:.2} > bound {bound:.2} (D={d}, T={t_overrun:.1}, τ={})",
                    class.gpu_warm_s
                ));
            }
        }
        Ok(())
    });
}
