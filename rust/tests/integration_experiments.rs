//! Integration over the experiment harness: the fast experiments run
//! end-to-end and leave their CSV artifacts under results/.

use std::path::Path;

#[test]
fn table1_and_fig1_write_csvs() {
    mqfq::experiments::table1::main();
    mqfq::experiments::fig1::main();
    assert!(Path::new("results/table1.csv").exists());
    assert!(Path::new("results/fig1.csv").exists());
    let table1 = std::fs::read_to_string("results/table1.csv").unwrap();
    assert_eq!(table1.lines().count(), 9, "header + 8 functions");
    assert!(table1.contains("imagenet"));
}

#[test]
fn fig4_rows_cover_all_policies() {
    let rows = mqfq::experiments::fig4::rows();
    assert_eq!(rows.len(), 4);
    let names: Vec<&str> = rows.iter().map(|r| r.policy).collect();
    assert!(names.contains(&"stock-uvm"));
    assert!(names.contains(&"prefetch+swap"));
    for r in &rows {
        assert!(r.total_s > 0.0 && r.total_s < 10.0, "{r:?}");
    }
}

#[test]
fn fig7b_covers_whole_catalog() {
    let rows = mqfq::experiments::fig7::fig7b_rows();
    assert_eq!(rows.len(), mqfq::workload::catalog::CATALOG.len());
    for (name, slow) in &rows {
        assert!(*slow >= 1.0, "{name}: {slow}");
    }
}

#[test]
fn cli_exp_dispatcher_knows_every_experiment() {
    for (name, _) in mqfq::experiments::ALL {
        assert!(
            mqfq::experiments::by_name(name).is_some(),
            "{name} not dispatchable"
        );
    }
}

#[test]
fn summary_csv_roundtrip() {
    let (w, t) = mqfq::workload::zipf::generate(&mqfq::workload::zipf::ZipfConfig {
        n_funcs: 4,
        total_rate: 0.5,
        duration_s: 60.0,
        seed: 3,
        ..Default::default()
    });
    let (s, _) = mqfq::experiments::run(
        "itest",
        w,
        &t,
        mqfq::plane::PlaneConfig::default(),
    );
    mqfq::experiments::write_summary_csv("itest_summary", std::slice::from_ref(&s)).unwrap();
    let text = std::fs::read_to_string("results/itest_summary.csv").unwrap();
    assert!(text.lines().count() == 2);
    assert!(text.contains("itest"));
    std::fs::remove_file("results/itest_summary.csv").ok();
}
