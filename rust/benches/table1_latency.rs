//! `cargo bench --bench table1_latency` — regenerates the paper's Table 1 (warm/cold GPU/CPU latencies).
//! Thin wrapper over `mqfq::experiments::table1::main` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::table1::main();
    println!("[bench table1_latency completed in {:.2?}]", t0.elapsed());
}
