//! `cargo bench --bench fig6b_perfn_latency` — regenerates the paper's Figure 6b (per-function latency).
//! Thin wrapper over `mqfq::experiments::fig6::fig6b` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::fig6::fig6b();
    println!("[bench fig6b_perfn_latency completed in {:.2?}]", t0.elapsed());
}
