//! `cargo bench --bench fig6c_utilization` — regenerates the paper's Figure 6c (utilization timeline).
//! Thin wrapper over `mqfq::experiments::fig6::fig6c` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::fig6::fig6c();
    println!("[bench fig6c_utilization completed in {:.2?}]", t0.elapsed());
}
