//! Fault-tolerance storm bench: GPU failure + recovery, transient
//! retries, poison-tenant circuit breaking, and overload shedding,
//! in virtual time and over real TCP. `FAULTS_QUICK=1` for a smoke
//! run. Emits `BENCH_faults.json` (diff with `scripts/bench_diff.sh`).

use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    mqfq::experiments::faults::main();
    println!("[bench fault_storm completed in {:.2?}]", t0.elapsed());
}
