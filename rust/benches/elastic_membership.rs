//! `cargo bench --bench elastic_membership` — the §Elastic membership
//! storm: a deterministic sim drain/kill/join script plus the
//! wall-clock kill-one-of-four storm over real loopback TCP, emitting
//! `BENCH_elastic.json` and holding the ticket-fate and recovery gates.
//! Thin wrapper over `mqfq::experiments::elastic::main` (also:
//! `mqfq-sticky exp elastic`; `ELASTIC_QUICK=1` for a smoke run).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::elastic::main();
    println!("[bench elastic_membership completed in {:.2?}]", t0.elapsed());
}
