//! `cargo bench --bench fig8c_pool_missrate` — regenerates the paper's Figure 8c (pool-size miss rates).
//! Thin wrapper over `mqfq::experiments::fig8::fig8c` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::fig8::fig8c();
    println!("[bench fig8c_pool_missrate completed in {:.2?}]", t0.elapsed());
}
