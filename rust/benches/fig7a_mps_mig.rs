//! `cargo bench --bench fig7a_mps_mig` — regenerates the paper's Figure 7a (MPS/MIG comparison).
//! Thin wrapper over `mqfq::experiments::fig7::fig7a` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::fig7::fig7a();
    println!("[bench fig7a_mps_mig completed in {:.2?}]", t0.elapsed());
}
