//! `cargo bench --bench table3_traces` — regenerates the paper's Table 3 (Azure trace samples).
//! Thin wrapper over `mqfq::experiments::table3::main` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::table3::main();
    println!("[bench table3_traces completed in {:.2?}]", t0.elapsed());
}
