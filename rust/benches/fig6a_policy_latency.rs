//! `cargo bench --bench fig6a_policy_latency` — regenerates the paper's Figure 6a (policy x D latency).
//! Thin wrapper over `mqfq::experiments::fig6::fig6a` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::fig6::fig6a();
    println!("[bench fig6a_policy_latency completed in {:.2?}]", t0.elapsed());
}
