//! `cargo bench --bench fig7b_mig_slowdown` — regenerates the paper's Figure 7b (MIG slice slowdown).
//! Thin wrapper over `mqfq::experiments::fig7::fig7b` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::fig7::fig7b();
    println!("[bench fig7b_mig_slowdown completed in {:.2?}]", t0.elapsed());
}
