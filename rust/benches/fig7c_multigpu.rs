//! `cargo bench --bench fig7c_multigpu` — regenerates the paper's Figure 7c (multi-GPU scaling).
//! Thin wrapper over `mqfq::experiments::fig7::fig7c` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::fig7::fig7c();
    println!("[bench fig7c_multigpu completed in {:.2?}]", t0.elapsed());
}
