//! `cargo bench --bench fig5c_latency_vs_load` — regenerates the paper's Figure 5c (latency vs load).
//! Thin wrapper over `mqfq::experiments::fig5::fig5c` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::fig5::fig5c();
    println!("[bench fig5c_latency_vs_load completed in {:.2?}]", t0.elapsed());
}
