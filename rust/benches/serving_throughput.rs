//! `cargo bench --bench serving_throughput` — the §Serving wall-clock
//! serving-path sweep: closed-loop + open-loop load generators over
//! real loopback TCP (1-shard and 4-shard sticky; sync, async-ticket,
//! and push-completion mixes; a 100 → 1k → 10k connection-scaling
//! axis on the epoll front end), emitting `BENCH_serving.json` and
//! holding the scaling, connection-flatness, push-p99, and
//! thread-bound gates. Thin wrapper over
//! `mqfq::experiments::serving::main` (also: `mqfq-sticky exp
//! serving`; `SERVING_QUICK=1` for a smoke run).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::serving::main();
    println!("[bench serving_throughput completed in {:.2?}]", t0.elapsed());
}
