//! `cargo bench --bench fig4_memory_policies` — regenerates the paper's Figure 4 (memory-policy comparison).
//! Thin wrapper over `mqfq::experiments::fig4::main` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::fig4::main();
    println!("[bench fig4_memory_policies completed in {:.2?}]", t0.elapsed());
}
