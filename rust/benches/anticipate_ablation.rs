//! `cargo bench --bench anticipate_ablation` — the §Anticipate
//! ablation: grace periods × same-flow batch dispatch × the online
//! characteristics estimator, swept over the bursty Zipf stressor and
//! the Azure realism trace, emitting `BENCH_anticipate.json` and
//! holding the p50-improvement / Jain-fairness release gates.
//! Thin wrapper over `mqfq::experiments::anticipate::main` (also:
//! `mqfq-sticky exp anticipate`; `ANTICIPATE_QUICK=1` for a smoke run).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::anticipate::main();
    println!("[bench anticipate_ablation completed in {:.2?}]", t0.elapsed());
}
