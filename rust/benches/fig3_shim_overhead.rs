//! `cargo bench --bench fig3_shim_overhead` — regenerates the paper's Figure 3 (UVM shim overhead).
//! Thin wrapper over `mqfq::experiments::fig3::main` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::fig3::main();
    println!("[bench fig3_shim_overhead completed in {:.2?}]", t0.elapsed());
}
