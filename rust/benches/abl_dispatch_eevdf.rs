//! `cargo bench --bench abl_dispatch_eevdf` — regenerates the paper's §6.4 ablations (sticky dispatch, EEVDF).
//! Thin wrapper over `mqfq::experiments::ablation::main` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::ablation::main();
    println!("[bench abl_dispatch_eevdf completed in {:.2?}]", t0.elapsed());
}
