//! `cargo bench --bench fig10_heterogeneous` — the heterogeneous-fleet sweep (fleet × router).
//! Thin wrapper over `mqfq::experiments::hetero::main` (also: `mqfq-sticky hetero`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::hetero::main();
    println!("[bench fig10_heterogeneous completed in {:.2?}]", t0.elapsed());
}
