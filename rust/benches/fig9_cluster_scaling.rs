//! `cargo bench --bench fig9_cluster_scaling` — the cluster scaling sweep (shards × router).
//! Thin wrapper over `mqfq::experiments::cluster::main` (also: `mqfq-sticky exp cluster`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::cluster::main();
    println!("[bench fig9_cluster_scaling completed in {:.2?}]", t0.elapsed());
}
