//! `cargo bench --bench fig8a_overrun_sweep` — regenerates the paper's Figure 8a (queue over-run sweep).
//! Thin wrapper over `mqfq::experiments::fig8::fig8a` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::fig8::fig8a();
    println!("[bench fig8a_overrun_sweep completed in {:.2?}]", t0.elapsed());
}
