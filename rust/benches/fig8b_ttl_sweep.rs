//! `cargo bench --bench fig8b_ttl_sweep` — regenerates the paper's Figure 8b (anticipatory TTL sweep).
//! Thin wrapper over `mqfq::experiments::fig8::fig8b` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::fig8::fig8b();
    println!("[bench fig8b_ttl_sweep completed in {:.2?}]", t0.elapsed());
}
