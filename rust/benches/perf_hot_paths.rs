//! `cargo bench --bench perf_hot_paths` — regenerates the paper's §Perf hot-path microbenchmarks.
//! Thin wrapper over `mqfq::experiments::perf::main` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::perf::main();
    println!("[bench perf_hot_paths completed in {:.2?}]", t0.elapsed());
}
