//! `cargo bench --bench fig5b_fairness_bound` — regenerates the paper's Figure 5b (gap vs Eq-1 bound).
//! Thin wrapper over `mqfq::experiments::fig5::fig5b` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::fig5::fig5b();
    println!("[bench fig5b_fairness_bound completed in {:.2?}]", t0.elapsed());
}
