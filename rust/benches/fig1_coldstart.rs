//! `cargo bench --bench fig1_coldstart` — regenerates the paper's Figure 1 (cold-start phase timeline).
//! Thin wrapper over `mqfq::experiments::fig1::main` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::fig1::main();
    println!("[bench fig1_coldstart completed in {:.2?}]", t0.elapsed());
}
