//! `cargo bench --bench fig5a_fairness_timeline` — regenerates the paper's Figure 5a (service-time fairness).
//! Thin wrapper over `mqfq::experiments::fig5::fig5a` (also: `mqfq-sticky exp`).

fn main() {
    let t0 = std::time::Instant::now();
    mqfq::experiments::fig5::fig5a();
    println!("[bench fig5a_fairness_timeline completed in {:.2?}]", t0.elapsed());
}
