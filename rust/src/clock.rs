//! Dual-clock abstraction: the same control plane runs under a virtual
//! discrete-event clock (trace replay, experiment harness) and a
//! wall-clock driver (examples, invocation server).
//!
//! Algorithm 1's `Date.Now()` becomes `clock.now()` throughout.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::types::Nanos;

/// Time source used by every component of the control plane.
pub trait Clock: Send + Sync {
    /// Nanoseconds since experiment start.
    fn now(&self) -> Nanos;
}

/// Wall clock anchored at construction time.
pub struct RealClock {
    start: Instant,
}

impl RealClock {
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
        }
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now(&self) -> Nanos {
        self.start.elapsed().as_nanos() as Nanos
    }
}

/// Virtual clock advanced explicitly by the discrete-event engine.
/// Cloneable handle (Arc inside) so components can hold a reference.
#[derive(Clone)]
pub struct SimClock {
    now: Arc<AtomicU64>,
}

impl SimClock {
    pub fn new() -> Self {
        Self {
            now: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Advance to `t`. Time never runs backwards; a stale set is ignored.
    pub fn advance_to(&self, t: Nanos) {
        self.now.fetch_max(t, Ordering::SeqCst);
    }
}

impl Default for SimClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SimClock {
    fn now(&self) -> Nanos {
        self.now.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_advances_monotonically() {
        let c = SimClock::new();
        assert_eq!(c.now(), 0);
        c.advance_to(100);
        assert_eq!(c.now(), 100);
        c.advance_to(50); // ignored
        assert_eq!(c.now(), 100);
        c.advance_to(200);
        assert_eq!(c.now(), 200);
    }

    #[test]
    fn sim_clock_handles_share_state() {
        let a = SimClock::new();
        let b = a.clone();
        a.advance_to(42);
        assert_eq!(b.now(), 42);
    }

    #[test]
    fn real_clock_moves_forward() {
        let c = RealClock::new();
        let t0 = c.now();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(c.now() > t0);
    }
}
