//! Multi-GPU device pool with sticky, late-binding placement (§5).
//!
//! The paper keeps a single dispatcher per server which late-binds each
//! chosen invocation to a GPU: "sticky" load balancing prefers the GPU
//! the function last ran on (warm data locality), falling back to the
//! least-loaded device. Under MIG, every slice is a separate vGPU here.

use std::collections::HashMap;

use crate::types::{FuncId, GpuId, InvocationId, Nanos};
use crate::workload::catalog::FuncClass;

use super::{Device, GpuProfile, MultiplexMode};

/// A set of schedulable devices on one server.
#[derive(Debug, Clone)]
pub struct DevicePool {
    devices: Vec<Device>,
    /// Last GPU each function ran on (stickiness).
    sticky: HashMap<FuncId, GpuId>,
    /// Where each in-flight invocation is running, and as what function
    /// (kept here so completion never scans a device's running set).
    placements: HashMap<InvocationId, (GpuId, FuncId)>,
    /// Aggregate in-flight counters, maintained by [`Self::begin`] /
    /// [`Self::complete`] so the dispatch path never scans devices:
    /// [`Self::in_flight`] and [`Self::in_flight_of`] are O(1).
    total_in_flight: usize,
    per_func_in_flight: HashMap<FuncId, usize>,
}

impl DevicePool {
    /// `n` physical GPUs of `profile` in `mode`. Under `Mig(s)`, each
    /// physical GPU contributes `s` vGPU slices.
    pub fn new(n: usize, profile: GpuProfile, mode: MultiplexMode) -> Self {
        let mut devices = Vec::new();
        match mode {
            MultiplexMode::Mig(slices) => {
                for _ in 0..n {
                    for _ in 0..slices {
                        let id = GpuId(devices.len() as u32);
                        devices.push(Device::mig_slice(id, profile, slices));
                    }
                }
            }
            _ => {
                for i in 0..n {
                    devices.push(Device::new(GpuId(i as u32), profile, mode));
                }
            }
        }
        Self {
            devices,
            sticky: HashMap::new(),
            placements: HashMap::new(),
            total_in_flight: 0,
            per_func_in_flight: HashMap::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    pub fn device(&self, id: GpuId) -> &Device {
        &self.devices[id.0 as usize]
    }

    pub fn device_mut(&mut self, id: GpuId) -> &mut Device {
        &mut self.devices[id.0 as usize]
    }

    /// Total in-flight invocations across devices. O(1).
    pub fn in_flight(&self) -> usize {
        self.total_in_flight
    }

    /// In-flight invocations of one function across devices. O(1).
    pub fn in_flight_of(&self, func: FuncId) -> usize {
        self.per_func_in_flight.get(&func).copied().unwrap_or(0)
    }

    /// Pick a device for `func`, bounded by `per_gpu_limit` concurrent
    /// invocations per device (the D level under the current controller
    /// setting; MIG slices are implicitly limit-1 per §4.2, enforced by
    /// the caller passing 1).
    ///
    /// Placement preference (§5 "sticky load balancing among GPUs"):
    /// 1. the sticky device, if it has a slot;
    /// 2. otherwise the least-loaded device with a slot.
    pub fn pick(&self, func: FuncId, per_gpu_limit: usize) -> Option<GpuId> {
        let has_slot = |d: &Device| d.in_flight() < per_gpu_limit;
        if let Some(&g) = self.sticky.get(&func) {
            if has_slot(&self.devices[g.0 as usize]) {
                return Some(g);
            }
        }
        self.devices
            .iter()
            .filter(|d| has_slot(d))
            .min_by(|a, b| a.load().partial_cmp(&b.load()).unwrap())
            .map(|d| d.id)
    }

    /// Begin an invocation on `gpu` (updates stickiness + placement).
    pub fn begin(
        &mut self,
        gpu: GpuId,
        inv: InvocationId,
        func: FuncId,
        class: &FuncClass,
        now: Nanos,
    ) {
        self.devices[gpu.0 as usize].begin(inv, func, class, now);
        self.sticky.insert(func, gpu);
        self.placements.insert(inv, (gpu, func));
        self.total_in_flight += 1;
        *self.per_func_in_flight.entry(func).or_insert(0) += 1;
    }

    /// Complete an invocation; returns the device it ran on.
    pub fn complete(&mut self, inv: InvocationId, now: Nanos) -> Option<GpuId> {
        let (gpu, func) = self.placements.remove(&inv)?;
        self.devices[gpu.0 as usize].complete(inv, now);
        self.total_in_flight -= 1;
        if let Some(n) = self.per_func_in_flight.get_mut(&func) {
            *n -= 1;
            if *n == 0 {
                self.per_func_in_flight.remove(&func);
            }
        }
        Some(gpu)
    }

    pub fn placement(&self, inv: InvocationId) -> Option<GpuId> {
        self.placements.get(&inv).map(|(g, _)| *g)
    }

    pub fn sticky_gpu(&self, func: FuncId) -> Option<GpuId> {
        self.sticky.get(&func).copied()
    }

    /// Mean utilization across devices at `now` (exact integral).
    pub fn mean_utilization(&mut self, now: Nanos) -> f64 {
        if self.devices.is_empty() {
            return 0.0;
        }
        let sum: f64 = self
            .devices
            .iter_mut()
            .map(|d| d.mean_utilization(now))
            .sum();
        sum / self.devices.len() as f64
    }

    /// Instantaneous utilization across devices (NVML-style sample).
    pub fn utilization(&self) -> f64 {
        if self.devices.is_empty() {
            return 0.0;
        }
        self.devices.iter().map(|d| d.utilization()).sum::<f64>() / self.devices.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::V100;
    use crate::workload::catalog::by_name;

    #[test]
    fn mig_pool_exposes_slices_as_vgpus() {
        let pool = DevicePool::new(1, crate::gpu::A30, MultiplexMode::Mig(2));
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.device(GpuId(0)).vram_mb, crate::gpu::A30.vram_mb / 2);
    }

    #[test]
    fn pick_prefers_sticky_gpu() {
        let mut pool = DevicePool::new(2, V100, MultiplexMode::Plain);
        let f = FuncId(0);
        let c = by_name("fft").unwrap();
        // First placement: least-loaded (gpu0), then sticky.
        let g = pool.pick(f, 2).unwrap();
        pool.begin(g, InvocationId(1), f, c, 0);
        pool.complete(InvocationId(1), 10);
        // Load gpu0 with another function; sticky should still win while
        // it has a slot.
        pool.begin(g, InvocationId(2), FuncId(9), c, 10);
        assert_eq!(pool.pick(f, 2), Some(g));
        // Fill it: falls over to the other device.
        pool.begin(g, InvocationId(3), FuncId(9), c, 10);
        let other = pool.pick(f, 2).unwrap();
        assert_ne!(other, g);
    }

    #[test]
    fn pick_none_when_all_full() {
        let mut pool = DevicePool::new(1, V100, MultiplexMode::Plain);
        let c = by_name("fft").unwrap();
        pool.begin(GpuId(0), InvocationId(1), FuncId(0), c, 0);
        assert_eq!(pool.pick(FuncId(1), 1), None);
        assert_eq!(pool.in_flight(), 1);
    }

    #[test]
    fn complete_clears_placement() {
        let mut pool = DevicePool::new(2, V100, MultiplexMode::Plain);
        let c = by_name("lud").unwrap();
        pool.begin(GpuId(1), InvocationId(7), FuncId(2), c, 0);
        assert_eq!(pool.placement(InvocationId(7)), Some(GpuId(1)));
        assert_eq!(pool.complete(InvocationId(7), 5), Some(GpuId(1)));
        assert_eq!(pool.placement(InvocationId(7)), None);
        assert_eq!(pool.complete(InvocationId(7), 5), None);
    }

    #[test]
    fn aggregate_counters_track_per_device_sums() {
        // Random begin/complete interleaving: the O(1) counters must
        // match a full per-device scan after every operation.
        let mut pool = DevicePool::new(3, V100, MultiplexMode::Plain);
        let c = by_name("fft").unwrap();
        let mut rng = crate::util::rng::Rng::new(0xC0);
        let mut live: Vec<(InvocationId, FuncId)> = Vec::new();
        let mut next = 0u64;
        for _ in 0..400 {
            if live.is_empty() || rng.f64() < 0.55 {
                let inv = InvocationId(next);
                let func = FuncId(rng.below(5) as u32);
                next += 1;
                let gpu = GpuId(rng.below(3) as u32);
                pool.begin(gpu, inv, func, c, next);
                live.push((inv, func));
            } else {
                let (inv, _) = live.swap_remove(rng.below(live.len()));
                assert!(pool.complete(inv, next).is_some());
            }
            let scan_total: usize = pool.devices().iter().map(|d| d.in_flight()).sum();
            assert_eq!(pool.in_flight(), scan_total);
            for f in 0..5 {
                let scan: usize = pool
                    .devices()
                    .iter()
                    .map(|d| d.in_flight_of(FuncId(f)))
                    .sum();
                assert_eq!(pool.in_flight_of(FuncId(f)), scan, "func {f}");
            }
        }
        // Unknown invocations/functions stay O(1) no-ops.
        assert_eq!(pool.complete(InvocationId(u64::MAX), 0), None);
        assert_eq!(pool.in_flight_of(FuncId(99)), 0);
    }

    #[test]
    fn least_loaded_balances() {
        let mut pool = DevicePool::new(2, V100, MultiplexMode::Plain);
        let c = by_name("ffmpeg").unwrap(); // intensity 0.7
        pool.begin(GpuId(0), InvocationId(1), FuncId(0), c, 0);
        // New function (no stickiness) goes to the idle device.
        assert_eq!(pool.pick(FuncId(5), 2), Some(GpuId(1)));
    }
}
