//! Multi-GPU device pool with sticky, late-binding, cost-aware
//! placement (§5, extended to heterogeneous fleets).
//!
//! The paper keeps a single dispatcher per server which late-binds each
//! chosen invocation to a GPU. On a *uniform* fleet the placement rule
//! is the paper's verbatim: "sticky" load balancing prefers the GPU the
//! function last ran on (warm data locality), falling back to the
//! least-loaded device (ties to the lowest [`GpuId`]). On a *mixed*
//! fleet (any two [`DeviceSpec`]s differing) blind stickiness is wrong —
//! a warm slot on a half-MIG slice can lose to a cold full-speed device
//! — so [`DevicePool::pick`] scores every candidate by estimated
//! completion: modeled execution time on that device (speed, MIG slice
//! fraction, current interference) plus a warm-locality migration
//! penalty (the function's footprint re-crossing PCIe) when leaving the
//! sticky device. With all specs equal the scored path is bypassed
//! entirely, keeping uniform-fleet behavior bit-identical to the
//! classic rule (property-tested in `rust/tests/prop_hetero.rs`).

use std::collections::HashMap;

use crate::types::{secs, FuncId, GpuId, InvocationId, Nanos};
use crate::workload::catalog::FuncClass;

use super::{uniform_fleet, Device, DeviceSpec, GpuProfile, MultiplexMode};

/// A set of schedulable devices on one server.
#[derive(Debug, Clone)]
pub struct DevicePool {
    devices: Vec<Device>,
    /// All specs identical ⇒ the classic sticky-then-least-loaded rule
    /// applies verbatim; otherwise picks are cost-scored.
    uniform: bool,
    /// Last GPU each function ran on (stickiness).
    sticky: HashMap<FuncId, GpuId>,
    /// Where each in-flight invocation is running, and as what function
    /// (kept here so completion never scans a device's running set).
    placements: HashMap<InvocationId, (GpuId, FuncId)>,
    /// Aggregate in-flight counters, maintained by [`Self::begin`] /
    /// [`Self::complete`] so the dispatch path never scans devices:
    /// [`Self::in_flight`] and [`Self::in_flight_of`] are O(1).
    total_in_flight: usize,
    per_func_in_flight: HashMap<FuncId, usize>,
}

impl DevicePool {
    /// Build the pool from a fleet description — one [`DeviceSpec`] per
    /// physical GPU. A `Mig(s)` spec contributes `s` vGPU slices;
    /// everything else contributes one device.
    pub fn new(specs: Vec<DeviceSpec>) -> Self {
        let uniform = specs.windows(2).all(|w| w[0] == w[1]);
        let mut devices = Vec::new();
        for spec in &specs {
            devices.extend(spec.expand(devices.len() as u32));
        }
        Self {
            devices,
            uniform,
            sticky: HashMap::new(),
            placements: HashMap::new(),
            total_in_flight: 0,
            per_func_in_flight: HashMap::new(),
        }
    }

    /// `n` physical GPUs of `profile` in `mode` — the pre-heterogeneity
    /// constructor, kept so uniform call sites stay one-liners.
    pub fn uniform(n: usize, profile: GpuProfile, mode: MultiplexMode) -> Self {
        Self::new(uniform_fleet(n, profile, mode))
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    pub fn device(&self, id: GpuId) -> &Device {
        &self.devices[id.0 as usize]
    }

    pub fn device_mut(&mut self, id: GpuId) -> &mut Device {
        &mut self.devices[id.0 as usize]
    }

    /// Total in-flight invocations across devices. O(1).
    pub fn in_flight(&self) -> usize {
        self.total_in_flight
    }

    /// In-flight invocations of one function across devices. O(1).
    pub fn in_flight_of(&self, func: FuncId) -> usize {
        self.per_func_in_flight.get(&func).copied().unwrap_or(0)
    }

    /// Any live device with a free slot under the plane-level `plane_d`
    /// (each device applies its own [`Device::limit`])?
    pub fn has_free_slot(&self, plane_d: usize) -> bool {
        self.devices
            .iter()
            .any(|d| !d.is_failed() && d.in_flight() < d.limit(plane_d))
    }

    /// Most permissive per-device concurrency limit among live devices
    /// under `plane_d` — what the policy layer should treat as "the D
    /// level" on a mixed fleet (uniform fleets: exactly the shared
    /// limit).
    pub fn max_limit(&self, plane_d: usize) -> usize {
        self.devices
            .iter()
            .filter(|d| !d.is_failed())
            .map(|d| d.limit(plane_d))
            .max()
            .unwrap_or(plane_d)
    }

    /// Total concurrency slots across live devices — the capacity term
    /// of the overload-shedding wait predictor.
    pub fn live_slots(&self, plane_d: usize) -> usize {
        self.devices
            .iter()
            .filter(|d| !d.is_failed())
            .map(|d| d.limit(plane_d))
            .sum()
    }

    /// Live (non-failed) device count.
    pub fn live_devices(&self) -> usize {
        self.devices.iter().filter(|d| !d.is_failed()).count()
    }

    /// A device drops out of the pool mid-flight: evacuate its running
    /// set (returned so the plane settles each victim attempt exactly
    /// once), clear their placements and aggregate counters, and drop
    /// every sticky placement pointing at the dead device so no future
    /// pick lands there on locality grounds.
    pub fn fail_device(&mut self, gpu: GpuId, now: Nanos) -> Vec<Running> {
        let victims = self.devices[gpu.0 as usize].fail(now);
        for r in &victims {
            if self.placements.remove(&r.inv).is_some() {
                self.total_in_flight -= 1;
                if let Some(n) = self.per_func_in_flight.get_mut(&r.func) {
                    *n -= 1;
                    if *n == 0 {
                        self.per_func_in_flight.remove(&r.func);
                    }
                }
            }
        }
        self.sticky.retain(|_, g| *g != gpu);
        victims
    }

    /// A failed device rejoins the pool, empty and cold.
    pub fn heal_device(&mut self, gpu: GpuId, now: Nanos) {
        self.devices[gpu.0 as usize].heal(now);
    }

    /// Pick a device for one invocation of `func` (of class `class`),
    /// each device bounded by its own [`Device::limit`] under the
    /// plane-level `plane_d`.
    ///
    /// Uniform fleet — §5 "sticky load balancing among GPUs", verbatim:
    /// 1. the sticky device, if it has a slot;
    /// 2. otherwise the least-loaded device with a slot (ties to the
    ///    lowest [`GpuId`]).
    ///
    /// Mixed fleet — cost-aware: every device with a slot is scored by
    /// estimated completion, `exec_time(class)` (speed × MIG fraction ×
    /// current interference, see [`Device::exec_time`]) plus a
    /// warm-locality migration penalty when the candidate is not the
    /// sticky device (the function's footprint must re-cross PCIe via
    /// host memory — see `ContainerPool::acquire`). Lowest score wins,
    /// ties to the lowest id — so a fast cold device beats the slow
    /// warm one exactly when its speed advantage outweighs the
    /// transfer.
    pub fn pick(
        &self,
        func: FuncId,
        class: &FuncClass,
        plane_d: usize,
        shim: bool,
    ) -> Option<GpuId> {
        let has_slot = |d: &Device| !d.is_failed() && d.in_flight() < d.limit(plane_d);
        let sticky = self.sticky.get(&func).copied();
        if self.uniform {
            if let Some(g) = sticky {
                if has_slot(&self.devices[g.0 as usize]) {
                    return Some(g);
                }
            }
            return self
                .devices
                .iter()
                .filter(|d| has_slot(d))
                .min_by(|a, b| a.load().total_cmp(&b.load()).then(a.id.cmp(&b.id)))
                .map(|d| d.id);
        }
        self.devices
            .iter()
            .filter(|d| has_slot(d))
            .map(|d| {
                let mut cost = d.exec_time(class, shim);
                if sticky.is_some() && sticky != Some(d.id) {
                    cost += migrate_penalty(class, d);
                }
                (cost, d.id)
            })
            .min() // (cost, id) lexicographic: lowest id breaks ties
            .map(|(_, id)| id)
    }

    /// Begin an invocation on `gpu` (updates stickiness + placement).
    pub fn begin(
        &mut self,
        gpu: GpuId,
        inv: InvocationId,
        func: FuncId,
        class: &FuncClass,
        now: Nanos,
    ) {
        self.devices[gpu.0 as usize].begin(inv, func, class, now);
        self.sticky.insert(func, gpu);
        self.placements.insert(inv, (gpu, func));
        self.total_in_flight += 1;
        *self.per_func_in_flight.entry(func).or_insert(0) += 1;
    }

    /// Complete an invocation; returns the device it ran on.
    pub fn complete(&mut self, inv: InvocationId, now: Nanos) -> Option<GpuId> {
        let (gpu, func) = self.placements.remove(&inv)?;
        self.devices[gpu.0 as usize].complete(inv, now);
        self.total_in_flight -= 1;
        if let Some(n) = self.per_func_in_flight.get_mut(&func) {
            *n -= 1;
            if *n == 0 {
                self.per_func_in_flight.remove(&func);
            }
        }
        Some(gpu)
    }

    pub fn placement(&self, inv: InvocationId) -> Option<GpuId> {
        self.placements.get(&inv).map(|(g, _)| *g)
    }

    pub fn sticky_gpu(&self, func: FuncId) -> Option<GpuId> {
        self.sticky.get(&func).copied()
    }

    /// Drain every device's Little's-law completion window and average
    /// the per-device concurrency demands (see
    /// [`Device::littles_demand`]). `None` when no device completed
    /// anything this window — the adaptive-D controller holds.
    pub fn littles_demand(&mut self, now: Nanos) -> Option<f64> {
        let mut sum = 0.0;
        let mut n = 0usize;
        for d in &mut self.devices {
            if let Some(demand) = d.littles_demand(now) {
                sum += demand;
                n += 1;
            }
        }
        if n == 0 {
            None
        } else {
            Some(sum / n as f64)
        }
    }

    /// Mean utilization across devices at `now` (exact integral).
    pub fn mean_utilization(&mut self, now: Nanos) -> f64 {
        if self.devices.is_empty() {
            return 0.0;
        }
        let sum: f64 = self
            .devices
            .iter_mut()
            .map(|d| d.mean_utilization(now))
            .sum();
        sum / self.devices.len() as f64
    }

    /// Instantaneous utilization across devices (NVML-style sample).
    pub fn utilization(&self) -> f64 {
        if self.devices.is_empty() {
            return 0.0;
        }
        self.devices.iter().map(|d| d.utilization()).sum::<f64>() / self.devices.len() as f64
    }

    /// Per-device `(class label, mean utilization)` at `now` — the raw
    /// rows the heterogeneity sweep aggregates into per-class
    /// utilization imbalance.
    pub fn device_utilizations(&mut self, now: Nanos) -> Vec<(String, f64)> {
        self.devices
            .iter_mut()
            .map(|d| (d.class_label(), d.mean_utilization(now)))
            .collect()
    }
}

/// Warm-locality migration cost of placing `class` away from its sticky
/// device: its device-memory footprint travels through host memory and
/// back over the destination's PCIe link (bulk-prefetch bandwidth).
fn migrate_penalty(class: &FuncClass, to: &Device) -> u64 {
    secs((class.mem_mb as f64 / 1024.0) / to.profile.pcie_gbps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::V100;
    use crate::workload::catalog::by_name;

    #[test]
    fn mig_pool_exposes_slices_as_vgpus() {
        let pool = DevicePool::uniform(1, crate::gpu::A30, MultiplexMode::Mig(2));
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.device(GpuId(0)).vram_mb, crate::gpu::A30.vram_mb / 2);
    }

    #[test]
    fn pick_prefers_sticky_gpu() {
        let mut pool = DevicePool::uniform(2, V100, MultiplexMode::Plain);
        let f = FuncId(0);
        let c = by_name("fft").unwrap();
        // First placement: least-loaded (gpu0), then sticky.
        let g = pool.pick(f, c, 2, true).unwrap();
        pool.begin(g, InvocationId(1), f, c, 0);
        pool.complete(InvocationId(1), 10);
        // Load gpu0 with another function; sticky should still win while
        // it has a slot.
        pool.begin(g, InvocationId(2), FuncId(9), c, 10);
        assert_eq!(pool.pick(f, c, 2, true), Some(g));
        // Fill it: falls over to the other device.
        pool.begin(g, InvocationId(3), FuncId(9), c, 10);
        let other = pool.pick(f, c, 2, true).unwrap();
        assert_ne!(other, g);
    }

    #[test]
    fn pick_none_when_all_full() {
        let mut pool = DevicePool::uniform(1, V100, MultiplexMode::Plain);
        let c = by_name("fft").unwrap();
        pool.begin(GpuId(0), InvocationId(1), FuncId(0), c, 0);
        assert_eq!(pool.pick(FuncId(1), c, 1, true), None);
        assert_eq!(pool.in_flight(), 1);
    }

    #[test]
    fn complete_clears_placement() {
        let mut pool = DevicePool::uniform(2, V100, MultiplexMode::Plain);
        let c = by_name("lud").unwrap();
        pool.begin(GpuId(1), InvocationId(7), FuncId(2), c, 0);
        assert_eq!(pool.placement(InvocationId(7)), Some(GpuId(1)));
        assert_eq!(pool.complete(InvocationId(7), 5), Some(GpuId(1)));
        assert_eq!(pool.placement(InvocationId(7)), None);
        assert_eq!(pool.complete(InvocationId(7), 5), None);
    }

    #[test]
    fn aggregate_counters_track_per_device_sums() {
        // Random begin/complete interleaving: the O(1) counters must
        // match a full per-device scan after every operation.
        let mut pool = DevicePool::uniform(3, V100, MultiplexMode::Plain);
        let c = by_name("fft").unwrap();
        let mut rng = crate::util::rng::Rng::new(0xC0);
        let mut live: Vec<(InvocationId, FuncId)> = Vec::new();
        let mut next = 0u64;
        for _ in 0..400 {
            if live.is_empty() || rng.f64() < 0.55 {
                let inv = InvocationId(next);
                let func = FuncId(rng.below(5) as u32);
                next += 1;
                let gpu = GpuId(rng.below(3) as u32);
                pool.begin(gpu, inv, func, c, next);
                live.push((inv, func));
            } else {
                let (inv, _) = live.swap_remove(rng.below(live.len()));
                assert!(pool.complete(inv, next).is_some());
            }
            let scan_total: usize = pool.devices().iter().map(|d| d.in_flight()).sum();
            assert_eq!(pool.in_flight(), scan_total);
            for f in 0..5 {
                let scan: usize = pool
                    .devices()
                    .iter()
                    .map(|d| d.in_flight_of(FuncId(f)))
                    .sum();
                assert_eq!(pool.in_flight_of(FuncId(f)), scan, "func {f}");
            }
        }
        // Unknown invocations/functions stay O(1) no-ops.
        assert_eq!(pool.complete(InvocationId(u64::MAX), 0), None);
        assert_eq!(pool.in_flight_of(FuncId(99)), 0);
    }

    #[test]
    fn least_loaded_balances() {
        let mut pool = DevicePool::uniform(2, V100, MultiplexMode::Plain);
        let c = by_name("ffmpeg").unwrap(); // intensity 0.7
        pool.begin(GpuId(0), InvocationId(1), FuncId(0), c, 0);
        // New function (no stickiness) goes to the idle device.
        assert_eq!(pool.pick(FuncId(5), c, 2, true), Some(GpuId(1)));
    }

    #[test]
    fn equal_load_ties_break_to_lowest_gpu_id() {
        // Regression: the least-loaded fallback must be deterministic on
        // equal loads — lowest GpuId wins, under total_cmp (no unwrap
        // on partial_cmp).
        let mut pool = DevicePool::uniform(3, V100, MultiplexMode::Plain);
        let c = by_name("fft").unwrap();
        assert_eq!(pool.pick(FuncId(0), c, 2, true), Some(GpuId(0)));
        pool.begin(GpuId(0), InvocationId(1), FuncId(7), c, 0);
        // gpu1 and gpu2 now tie at zero load: lowest id wins.
        assert_eq!(pool.pick(FuncId(0), c, 2, true), Some(GpuId(1)));
        pool.begin(GpuId(1), InvocationId(2), FuncId(8), c, 0);
        assert_eq!(pool.pick(FuncId(0), c, 2, true), Some(GpuId(2)));
        // All equally loaded again: back to gpu0.
        pool.begin(GpuId(2), InvocationId(3), FuncId(9), c, 0);
        assert_eq!(pool.pick(FuncId(0), c, 2, true), Some(GpuId(0)));
    }

    #[test]
    fn per_device_limits_gate_slots() {
        // A D=1-pinned device next to an unconstrained one: mixed
        // limits on a single pool.
        let specs = vec![
            DeviceSpec::new(V100, MultiplexMode::Plain).with_d(1),
            DeviceSpec::new(V100, MultiplexMode::Plain),
        ];
        let mut pool = DevicePool::new(specs);
        assert_eq!(pool.max_limit(3), 3);
        let c = by_name("fft").unwrap();
        pool.begin(GpuId(0), InvocationId(1), FuncId(0), c, 0);
        pool.begin(GpuId(1), InvocationId(2), FuncId(1), c, 0);
        // gpu0 is full at its override (1); gpu1 still has plane slots.
        assert!(pool.has_free_slot(3));
        assert_eq!(pool.pick(FuncId(0), c, 3, true), Some(GpuId(1)));
        pool.begin(GpuId(1), InvocationId(3), FuncId(2), c, 0);
        pool.begin(GpuId(1), InvocationId(4), FuncId(3), c, 0);
        assert!(!pool.has_free_slot(3));
        assert_eq!(pool.pick(FuncId(0), c, 3, true), None);
    }

    #[test]
    fn fail_device_evacuates_and_untangles_pool_state() {
        let mut pool = DevicePool::uniform(2, V100, MultiplexMode::Plain);
        let c = by_name("fft").unwrap();
        let f = FuncId(0);
        pool.begin(GpuId(0), InvocationId(1), f, c, 0);
        pool.begin(GpuId(0), InvocationId(2), FuncId(1), c, 0);
        pool.begin(GpuId(1), InvocationId(3), FuncId(2), c, 0);
        assert_eq!(pool.sticky_gpu(f), Some(GpuId(0)));
        let victims = pool.fail_device(GpuId(0), 100);
        assert_eq!(victims.len(), 2);
        // Counters and placements shrink to the survivor only.
        assert_eq!(pool.in_flight(), 1);
        assert_eq!(pool.in_flight_of(f), 0);
        assert_eq!(pool.placement(InvocationId(1)), None);
        assert_eq!(pool.placement(InvocationId(3)), Some(GpuId(1)));
        // Stickiness to the dead device is gone; picks avoid it.
        assert_eq!(pool.sticky_gpu(f), None);
        assert_eq!(pool.pick(f, c, 2, true), Some(GpuId(1)));
        assert_eq!(pool.live_devices(), 1);
        assert_eq!(pool.live_slots(2), 2);
        // With the survivor full, the pool is out of slots even though
        // the dead device "has room".
        pool.begin(GpuId(1), InvocationId(4), FuncId(3), c, 100);
        assert!(!pool.has_free_slot(2));
        assert_eq!(pool.pick(f, c, 2, true), None);
        // A completion for an evacuated invocation is a no-op.
        assert_eq!(pool.complete(InvocationId(1), 200), None);
        // Healing re-admits the device, cold.
        pool.heal_device(GpuId(0), 300);
        assert_eq!(pool.live_devices(), 2);
        assert!(pool.has_free_slot(2));
        assert_eq!(pool.pick(f, c, 2, true), Some(GpuId(0)));
    }

    #[test]
    fn hetero_pick_prefers_faster_idle_device() {
        // V100 (speed 1.0) next to A30 (speed 0.92): with no warm data
        // anywhere, the cost-aware pick lands on the faster A30.
        let specs = vec![
            DeviceSpec::new(V100, MultiplexMode::Plain),
            DeviceSpec::new(crate::gpu::A30, MultiplexMode::Plain),
        ];
        let pool = DevicePool::new(specs);
        let c = by_name("ffmpeg").unwrap(); // long-running: speed dominates
        assert_eq!(pool.pick(FuncId(0), c, 2, true), Some(GpuId(1)));
    }

    #[test]
    fn hetero_pick_weighs_warm_locality_against_speed() {
        // Warm home on a half-MIG A30 slice vs an idle full V100: the
        // slice's MIG slowdown on fft (1.9× on top of congestion) far
        // exceeds the ~128 ms PCIe migration penalty, so the cold full
        // GPU wins — "the fast cold device beats the slow warm one".
        let specs = vec![
            DeviceSpec::new(crate::gpu::A30, MultiplexMode::Mig(2)),
            DeviceSpec::new(V100, MultiplexMode::Plain),
        ];
        let mut pool = DevicePool::new(specs);
        let c = by_name("fft").unwrap();
        let f = FuncId(0);
        // Make slice gpu0 the warm home.
        pool.begin(GpuId(0), InvocationId(1), f, c, 0);
        pool.complete(InvocationId(1), 10);
        assert_eq!(pool.sticky_gpu(f), Some(GpuId(0)));
        assert_eq!(pool.pick(f, c, 2, true), Some(GpuId(2)));

        // Converse: near-identical speeds (plain A30 home vs V100
        // alternative) — the migration penalty dominates and the warm
        // home keeps the work.
        let specs = vec![
            DeviceSpec::new(crate::gpu::A30, MultiplexMode::Plain),
            DeviceSpec::new(V100, MultiplexMode::Plain),
        ];
        let mut pool = DevicePool::new(specs);
        pool.begin(GpuId(1), InvocationId(1), f, c, 0);
        pool.complete(InvocationId(1), 10);
        assert_eq!(pool.pick(f, c, 2, true), Some(GpuId(1)));
    }

    #[test]
    fn identical_specs_take_the_uniform_path() {
        // A pool built from explicitly identical specs must behave
        // exactly like the uniform convenience constructor: sticky wins
        // regardless of relative load (the classic §5 rule, which the
        // cost-scored path would not guarantee).
        let spec = DeviceSpec::new(V100, MultiplexMode::Plain);
        let mut pool = DevicePool::new(vec![spec, spec]);
        let c = by_name("ffmpeg").unwrap();
        let f = FuncId(0);
        pool.begin(GpuId(0), InvocationId(1), f, c, 0);
        pool.complete(InvocationId(1), 5);
        // Load the sticky device heavily; device 1 stays idle. Uniform
        // rule: sticky still wins while it has a slot.
        pool.begin(GpuId(0), InvocationId(2), FuncId(9), c, 5);
        assert_eq!(pool.pick(f, c, 2, true), Some(GpuId(0)));
    }
}
