//! GPU device model — the hardware substitution substrate (DESIGN.md §1).
//!
//! The scheduler only ever observes a GPU through (a) utilization
//! samples (the paper polls NVML every 200 ms), (b) device-memory
//! headroom (tracked via the interposition shim), and (c) completion
//! latencies. This module produces all three for the paper's two
//! testbeds (V100 16 GB, A30 24 GB) under the three multiplexing regimes
//! (plain concurrent dispatch, MPS, MIG slices) and for multi-GPU
//! servers.
//!
//! # Heterogeneous fleets
//!
//! Real clusters mix device generations, MIG slices, and MPS-shared
//! parts, so nothing here assumes a uniform fleet: a server's hardware
//! is a `Vec<`[`DeviceSpec`]`>` — one spec per *physical* GPU (profile +
//! multiplex mode + optional per-device concurrency override). A MIG
//! spec expands into one schedulable [`Device`] per slice; everything
//! else expands 1:1. [`uniform_fleet`] recreates the classic
//! `(n, profile, mode)` shape as a one-liner, and
//! [`DevicePool::uniform`] keeps old call sites short. Placement over a
//! mixed fleet is cost-aware (see [`pool`]): candidates are scored by
//! estimated completion — warm locality against raw speed and current
//! interference — instead of blindly trusting stickiness.
//!
//! # Failure model
//!
//! A device can *fail* mid-flight ([`Device::fail`], driven by the
//! control plane's [`crate::fault`] layer): its running set is
//! evacuated for re-queue, its resident-memory ledger zeroes (device
//! memory dies with the device), and the pool stops routing placements
//! to it — [`DevicePool::pick`] skips failed devices, sticky
//! placements pointing at one are dropped, and
//! [`DevicePool::has_free_slot`] counts only live devices. An optional
//! scheduled recovery ([`Device::heal`]) re-admits the device empty
//! and cold; nothing from before the failure survives. The pool keeps
//! per-device failure state rather than removing entries so `GpuId`s
//! stay stable for telemetry and placement history.

pub mod pool;

pub use pool::DevicePool;

use crate::types::{DurNanos, FuncId, GpuId, InvocationId, Nanos};
use crate::workload::catalog::FuncClass;

/// Hardware multiplexing regime (§4.2 "Architecture").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MultiplexMode {
    /// No hardware support: the scheduler dispatches concurrent
    /// invocations and the driver time-slices them (the V100 testbed).
    Plain,
    /// NVIDIA MPS: kernel-level sharing, much lower interference.
    Mps,
    /// MIG: the physical GPU is split into `n` isolated slices; each is
    /// exposed as a vGPU with D=1 (handled in [`pool`]).
    Mig(u32),
}

/// Static hardware profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuProfile {
    pub name: &'static str,
    pub vram_mb: u64,
    /// Execution-time multiplier relative to the V100 baseline the
    /// catalog was calibrated on (A30 is slightly faster on most of the
    /// catalog's kernels).
    pub speed: f64,
    /// Bulk host↔device copy bandwidth (cuMemPrefetchAsync), GB/s.
    pub pcie_gbps: f64,
    /// Effective on-demand UVM page-fault migration bandwidth, GB/s.
    /// An order of magnitude below bulk prefetch: each fault stalls the
    /// SM, migrates 2 MB chunks, and serializes on the fault handler —
    /// this is what makes "stock UVM" 40% slower in Fig 4.
    pub uvm_fault_gbps: f64,
    /// Interference coefficient for concurrent plain dispatch.
    pub interference_coef: f64,
    /// Interference coefficient under MPS (kernel-level scheduling).
    pub mps_interference_coef: f64,
}

/// The paper's first testbed: NVIDIA V100 16 GB (no MIG, broken MPS).
pub const V100: GpuProfile = GpuProfile {
    name: "v100",
    vram_mb: 16_384,
    speed: 1.0,
    pcie_gbps: 12.0,
    uvm_fault_gbps: 2.2,
    interference_coef: 0.45,
    mps_interference_coef: 0.07,
};

/// The paper's second testbed: NVIDIA A30 24 GB (MPS + MIG capable).
pub const A30: GpuProfile = GpuProfile {
    name: "a30",
    vram_mb: 24_576,
    speed: 0.92,
    pcie_gbps: 16.0,
    uvm_fault_gbps: 2.9,
    interference_coef: 0.40,
    mps_interference_coef: 0.06,
};

/// Description of one *physical* GPU in a fleet: hardware profile,
/// multiplexing regime, and an optional per-device concurrency (D)
/// override. The unit of heterogeneity — a server is a
/// `Vec<DeviceSpec>`, threaded from [`crate::plane::PlaneConfig`]
/// through [`DevicePool::new`] down to each [`Device`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceSpec {
    pub profile: GpuProfile,
    pub mode: MultiplexMode,
    /// Per-device D override. `None` defers to the plane-level fixed D
    /// or dynamic controller. Ignored under MIG, where every slice pins
    /// D = 1 (§4.2).
    pub d: Option<usize>,
}

impl DeviceSpec {
    pub const fn new(profile: GpuProfile, mode: MultiplexMode) -> Self {
        Self {
            profile,
            mode,
            d: None,
        }
    }

    /// Same spec with a fixed per-device concurrency limit.
    pub const fn with_d(mut self, d: usize) -> Self {
        self.d = Some(d);
        self
    }

    /// Schedulable devices this physical GPU contributes (MIG: one per
    /// slice; otherwise one).
    pub fn n_vgpus(&self) -> usize {
        match self.mode {
            MultiplexMode::Mig(s) => s as usize,
            _ => 1,
        }
    }

    /// Relative service capacity in V100-equivalents: the reciprocal of
    /// the profile's execution-time multiplier. A first-order weight for
    /// capacity-aware routing — MIG slices of one GPU jointly count as
    /// the whole GPU, and concurrency effects are deliberately ignored
    /// (they are workload-dependent; the router only needs a static
    /// relative weight).
    pub fn capacity(&self) -> f64 {
        1.0 / self.profile.speed
    }

    /// Expand into schedulable devices with ids starting at `first_id`.
    pub fn expand(&self, first_id: u32) -> Vec<Device> {
        (0..self.n_vgpus() as u32)
            .map(|i| Device::new(GpuId(first_id + i), *self))
            .collect()
    }
}

/// `n` identical physical GPUs — the old `(n, profile, mode)`
/// constructor shape expressed as a fleet description.
pub fn uniform_fleet(n: usize, profile: GpuProfile, mode: MultiplexMode) -> Vec<DeviceSpec> {
    vec![DeviceSpec::new(profile, mode); n]
}

/// An invocation currently executing on the device.
#[derive(Debug, Clone, Copy)]
pub struct Running {
    pub inv: InvocationId,
    pub func: FuncId,
    pub intensity: f64,
    pub started: Nanos,
}

/// One schedulable device: a physical GPU, or one MIG slice (vGPU).
#[derive(Debug, Clone)]
pub struct Device {
    pub id: GpuId,
    pub profile: GpuProfile,
    pub mode: MultiplexMode,
    /// Fraction of the physical GPU's compute this device owns
    /// (1.0, or 1/slices for a MIG vGPU).
    pub compute_frac: f64,
    /// VRAM owned by this device (sliced under MIG), MB.
    pub vram_mb: u64,
    /// Per-device D override from the spec (None ⇒ plane-level D).
    d_override: Option<usize>,
    /// Dropped out of the pool (fault injection); no placements until
    /// healed.
    failed: bool,
    running: Vec<Running>,
    /// Device memory currently resident (shim ledger roll-up), MB.
    resident_mb: u64,
    // Exact utilization integral: Σ min(1, load) dt over state changes.
    busy_integral_ns: f64,
    last_change: Nanos,
    // Little's-law completion window (adaptive D): completions and
    // their total service time since the window opened.
    window_start: Nanos,
    window_completions: u64,
    window_service_ns: f64,
}

impl Device {
    /// Build one schedulable device from a spec. Under `Mig(s)` every
    /// schedulable device *is* one slice, so this yields a vGPU with
    /// 1/s of the compute and VRAM; [`DeviceSpec::expand`] calls it
    /// once per slice.
    pub fn new(id: GpuId, spec: DeviceSpec) -> Self {
        let (compute_frac, vram_mb) = match spec.mode {
            MultiplexMode::Mig(slices) => {
                (1.0 / slices as f64, spec.profile.vram_mb / slices as u64)
            }
            _ => (1.0, spec.profile.vram_mb),
        };
        Self {
            id,
            profile: spec.profile,
            mode: spec.mode,
            compute_frac,
            vram_mb,
            d_override: spec.d,
            failed: false,
            running: Vec::new(),
            resident_mb: 0,
            busy_integral_ns: 0.0,
            last_change: 0,
            window_start: 0,
            window_completions: 0,
            window_service_ns: 0.0,
        }
    }

    /// Concurrency limit of *this* device under the plane-level setting
    /// `plane_d`: MIG slices pin 1 (§4.2), a spec override wins next,
    /// otherwise the plane's fixed/dynamic D applies. On a mixed plane
    /// (a MIG slice next to an MPS device) each device holds its own
    /// limit.
    pub fn limit(&self, plane_d: usize) -> usize {
        match self.mode {
            MultiplexMode::Mig(_) => 1,
            _ => self.d_override.unwrap_or(plane_d),
        }
    }

    /// Device-class label for per-class reporting: profile name plus
    /// the multiplex regime (e.g. `v100`, `a30+mps`, `a30/mig2`).
    pub fn class_label(&self) -> String {
        match self.mode {
            MultiplexMode::Plain => self.profile.name.to_string(),
            MultiplexMode::Mps => format!("{}+mps", self.profile.name),
            MultiplexMode::Mig(s) => format!("{}/mig{s}", self.profile.name),
        }
    }

    pub fn in_flight(&self) -> usize {
        self.running.len()
    }

    pub fn running(&self) -> &[Running] {
        &self.running
    }

    /// Invocations of `func` currently executing here.
    pub fn in_flight_of(&self, func: FuncId) -> usize {
        self.running.iter().filter(|r| r.func == func).count()
    }

    /// Instantaneous compute load: Σ intensity / compute_frac, uncapped.
    pub fn load(&self) -> f64 {
        let total: f64 = self.running.iter().map(|r| r.intensity).sum();
        total / self.compute_frac
    }

    /// Instantaneous utilization in [0, 1] — what NVML reports: the
    /// fraction of time *any* kernel is resident on the device, not an
    /// SM-occupancy average. Busy ⇒ 1.0, idle ⇒ 0.0 (the 200 ms monitor
    /// then averages samples into the paper's "GPU Util %").
    pub fn utilization(&self) -> f64 {
        if self.running.is_empty() {
            0.0
        } else {
            1.0
        }
    }

    pub fn resident_mb(&self) -> u64 {
        self.resident_mb
    }

    /// Free device memory, MB.
    pub fn free_mb(&self) -> u64 {
        self.vram_mb.saturating_sub(self.resident_mb)
    }

    /// Memory pressure: resident / vram (can exceed 1.0 under UVM
    /// oversubscription).
    pub fn pressure(&self) -> f64 {
        self.resident_mb as f64 / self.vram_mb as f64
    }

    /// Adjust the resident-memory ledger (called by the shim/memory
    /// manager as regions prefetch in and swap out).
    pub fn add_resident(&mut self, mb: u64) {
        self.resident_mb += mb;
    }

    pub fn sub_resident(&mut self, mb: u64) {
        self.resident_mb = self.resident_mb.saturating_sub(mb);
    }

    fn integrate(&mut self, now: Nanos) {
        if now > self.last_change {
            self.busy_integral_ns += (now - self.last_change) as f64 * self.utilization();
            self.last_change = now;
        }
    }

    /// Begin executing an invocation here.
    pub fn begin(&mut self, inv: InvocationId, func: FuncId, class: &FuncClass, now: Nanos) {
        self.integrate(now);
        self.running.push(Running {
            inv,
            func,
            intensity: class.intensity,
            started: now,
        });
    }

    /// Complete an invocation; returns false if it wasn't running here.
    pub fn complete(&mut self, inv: InvocationId, now: Nanos) -> bool {
        self.integrate(now);
        match self.running.iter().position(|r| r.inv == inv) {
            Some(pos) => {
                let r = self.running.swap_remove(pos);
                self.window_completions += 1;
                self.window_service_ns += now.saturating_sub(r.started) as f64;
                true
            }
            None => false,
        }
    }

    pub fn is_failed(&self) -> bool {
        self.failed
    }

    /// The device drops out mid-flight: every running invocation is
    /// evacuated (returned so the plane can settle each attempt), the
    /// resident-memory ledger zeroes (device memory dies with the
    /// device), and no further placements land here until [`Self::heal`].
    pub fn fail(&mut self, now: Nanos) -> Vec<Running> {
        self.integrate(now);
        self.failed = true;
        self.resident_mb = 0;
        std::mem::take(&mut self.running)
    }

    /// The device rejoins the pool — empty and cold, with a fresh
    /// Little's-law window. Nothing from before the failure survives.
    pub fn heal(&mut self, now: Nanos) {
        self.integrate(now);
        self.failed = false;
        self.window_start = now;
        self.window_completions = 0;
        self.window_service_ns = 0.0;
    }

    /// Drain the Little's-law completion window: the mean concurrency
    /// this device *needed* over the window to sustain its observed
    /// throughput, L = λ·W = (total service time of completions) /
    /// (window duration). `None` when the window saw no completions —
    /// no evidence, so the D controller holds. Resets the window.
    pub fn littles_demand(&mut self, now: Nanos) -> Option<f64> {
        let window = now.saturating_sub(self.window_start);
        let (completions, service) = (self.window_completions, self.window_service_ns);
        self.window_start = now;
        self.window_completions = 0;
        self.window_service_ns = 0.0;
        if window == 0 || completions == 0 {
            return None;
        }
        Some(service / window as f64)
    }

    /// Mean utilization over [0, now] from the exact integral.
    pub fn mean_utilization(&mut self, now: Nanos) -> f64 {
        self.integrate(now);
        if now == 0 {
            0.0
        } else {
            self.busy_integral_ns / now as f64
        }
    }

    /// Execution-time model for one invocation of `class` dispatched now
    /// (DESIGN.md §1): warm time × device speed × MIG slowdown ×
    /// capacity congestion × interference overhead × shim overhead.
    ///
    /// The factor is frozen at dispatch time from the current running
    /// set — a standard discrete-event approximation (documented in
    /// DESIGN.md §8).
    pub fn exec_time(&self, class: &FuncClass, shim_enabled: bool) -> DurNanos {
        let base = class.gpu_warm_s * self.profile.speed;
        let mig = match self.mode {
            MultiplexMode::Mig(_) => {
                // Fig 7b calibrates the half-GPU slice; scale the extra
                // slowdown linearly with the lost fraction.
                let half_extra = class.mig_slowdown - 1.0;
                1.0 + half_extra * (1.0 - self.compute_frac) / 0.5
            }
            _ => 1.0,
        };
        // Concurrency effects: the new invocation sees the *current*
        // running set as contenders.
        let others: f64 = self.running.iter().map(|r| r.intensity).sum::<f64>() / self.compute_frac;
        let total = others + class.intensity / self.compute_frac;
        let congestion = total.max(1.0);
        let coef = match self.mode {
            MultiplexMode::Plain => self.profile.interference_coef,
            MultiplexMode::Mps => self.profile.mps_interference_coef,
            MultiplexMode::Mig(_) => 0.0, // isolated slices
        };
        // Superlinear in co-runner intensity: two heavy co-runners
        // thrash caches/DRAM far worse than one (the Fig-6a D=3
        // degradation: "the device cannot handle the higher
        // concurrency").
        let overhead = 1.0 + coef * others.powf(2.0);
        let shim = if shim_enabled {
            1.0 + class.shim_overhead
        } else {
            1.0
        };
        crate::types::secs(base * mig * congestion * overhead * shim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::catalog::by_name;

    fn dev() -> Device {
        Device::new(GpuId(0), DeviceSpec::new(V100, MultiplexMode::Plain))
    }

    #[test]
    fn empty_device_runs_at_warm_speed() {
        let d = dev();
        let fft = by_name("fft").unwrap();
        let t = d.exec_time(fft, false);
        assert_eq!(t, crate::types::secs(0.897));
    }

    #[test]
    fn shim_overhead_applies() {
        let d = dev();
        let srad = by_name("srad").unwrap();
        let plain = d.exec_time(srad, false) as f64;
        let shimmed = d.exec_time(srad, true) as f64;
        assert!((shimmed / plain - 1.30).abs() < 1e-6);
    }

    #[test]
    fn interference_grows_with_concurrency() {
        let mut d = dev();
        let lud = by_name("lud").unwrap();
        let solo = d.exec_time(lud, true);
        d.begin(InvocationId(1), FuncId(0), by_name("ffmpeg").unwrap(), 0);
        let with_one = d.exec_time(lud, true);
        d.begin(InvocationId(2), FuncId(1), by_name("needle").unwrap(), 0);
        let with_two = d.exec_time(lud, true);
        assert!(with_one > solo);
        assert!(with_two > with_one);
        // D=3 with heavy functions must degrade sharply (Fig 6a shape):
        // total intensity 0.70+0.70+0.75 > 2 ⇒ >2× slowdown.
        assert!(with_two as f64 / solo as f64 > 1.8);
    }

    #[test]
    fn mps_interferes_less_than_plain() {
        let mut plain = Device::new(GpuId(0), DeviceSpec::new(A30, MultiplexMode::Plain));
        let mut mps = Device::new(GpuId(1), DeviceSpec::new(A30, MultiplexMode::Mps));
        let fft = by_name("fft").unwrap();
        for d in [&mut plain, &mut mps] {
            d.begin(InvocationId(1), FuncId(0), by_name("ffmpeg").unwrap(), 0);
        }
        assert!(mps.exec_time(fft, true) < plain.exec_time(fft, true));
    }

    #[test]
    fn mig_slice_slows_down_per_fig7b() {
        let slice = Device::new(GpuId(0), DeviceSpec::new(A30, MultiplexMode::Mig(2)));
        assert_eq!(slice.vram_mb, A30.vram_mb / 2);
        let rnn = by_name("rnn").unwrap();
        let full = Device::new(GpuId(1), DeviceSpec::new(A30, MultiplexMode::Plain));
        let ratio =
            slice.exec_time(rnn, false) as f64 / full.exec_time(rnn, false) as f64;
        assert!((ratio - 2.60).abs() < 0.01, "rnn on half-slice: {ratio}");
    }

    #[test]
    fn utilization_is_busy_fraction() {
        let mut d = dev();
        assert_eq!(d.utilization(), 0.0);
        d.begin(InvocationId(1), FuncId(0), by_name("lud").unwrap(), 0);
        // NVML-style: any resident kernel ⇒ 100% busy.
        assert_eq!(d.utilization(), 1.0);
        d.begin(InvocationId(2), FuncId(1), by_name("needle").unwrap(), 0);
        assert_eq!(d.utilization(), 1.0);
        assert!(d.load() > 1.0); // compute load is intensity-weighted
        assert!(d.complete(InvocationId(1), 100));
        assert!(!d.complete(InvocationId(1), 100));
        assert_eq!(d.utilization(), 1.0);
        assert!(d.complete(InvocationId(2), 200));
        assert_eq!(d.utilization(), 0.0);
    }

    #[test]
    fn mean_utilization_integral() {
        let mut d = dev();
        let lud = by_name("lud").unwrap();
        d.begin(InvocationId(1), FuncId(0), lud, 0);
        d.complete(InvocationId(1), 1000);
        // busy for [0,1000], idle for [1000,2000] ⇒ 50%.
        let mu = d.mean_utilization(2000);
        assert!((mu - 0.5).abs() < 1e-9, "{mu}");
    }

    #[test]
    fn littles_window_measures_concurrency_demand() {
        let mut d = dev();
        let c = by_name("fft").unwrap();
        assert_eq!(d.littles_demand(1000), None, "empty window holds");
        d.begin(InvocationId(1), FuncId(0), c, 1000);
        d.begin(InvocationId(2), FuncId(1), c, 1000);
        d.complete(InvocationId(1), 2000);
        d.complete(InvocationId(2), 3000);
        // Window [1000, 3000]: completed service 1000 + 2000 over a
        // 2000 ns window ⇒ demand 1.5 concurrent slots.
        let demand = d.littles_demand(3000).unwrap();
        assert!((demand - 1.5).abs() < 1e-9, "{demand}");
        // Draining resets the window.
        assert_eq!(d.littles_demand(4000), None);
    }

    #[test]
    fn memory_ledger_saturates() {
        let mut d = dev();
        d.add_resident(10_000);
        assert_eq!(d.free_mb(), 6_384);
        d.sub_resident(20_000);
        assert_eq!(d.resident_mb(), 0);
        assert!(d.pressure() < 1e-12);
    }

    #[test]
    fn in_flight_of_counts_per_function() {
        let mut d = dev();
        let c = by_name("fft").unwrap();
        d.begin(InvocationId(1), FuncId(3), c, 0);
        d.begin(InvocationId(2), FuncId(3), c, 0);
        d.begin(InvocationId(3), FuncId(5), c, 0);
        assert_eq!(d.in_flight_of(FuncId(3)), 2);
        assert_eq!(d.in_flight_of(FuncId(5)), 1);
        assert_eq!(d.in_flight(), 3);
    }

    #[test]
    fn fail_evacuates_and_heal_rejoins_cold() {
        let mut d = dev();
        let c = by_name("fft").unwrap();
        d.begin(InvocationId(1), FuncId(0), c, 0);
        d.begin(InvocationId(2), FuncId(1), c, 0);
        d.add_resident(4_000);
        let evicted = d.fail(1000);
        assert_eq!(evicted.len(), 2);
        assert!(d.is_failed());
        assert_eq!(d.in_flight(), 0);
        assert_eq!(d.resident_mb(), 0, "device memory dies with the device");
        assert!(!d.complete(InvocationId(1), 2000), "nothing left to complete");
        d.heal(5000);
        assert!(!d.is_failed());
        assert_eq!(d.littles_demand(6000), None, "window restarts empty");
        d.begin(InvocationId(3), FuncId(0), c, 6000);
        assert_eq!(d.in_flight(), 1);
    }

    #[test]
    fn spec_expansion_and_limits() {
        // Plain spec: one device, plane-level D.
        let plain = DeviceSpec::new(V100, MultiplexMode::Plain);
        let devs = plain.expand(0);
        assert_eq!(devs.len(), 1);
        assert_eq!(devs[0].limit(3), 3);
        assert_eq!(devs[0].class_label(), "v100");
        // Override pins the device regardless of the plane setting.
        let pinned = DeviceSpec::new(V100, MultiplexMode::Mps).with_d(1);
        let d = &pinned.expand(5)[0];
        assert_eq!(d.id, GpuId(5));
        assert_eq!(d.limit(4), 1);
        assert_eq!(d.class_label(), "v100+mps");
        // MIG spec: one device per slice, D pinned to 1, sliced VRAM.
        let mig = DeviceSpec::new(A30, MultiplexMode::Mig(2)).with_d(4);
        let slices = mig.expand(2);
        assert_eq!(slices.len(), 2);
        assert_eq!(slices[1].id, GpuId(3));
        for s in &slices {
            assert_eq!(s.limit(4), 1, "MIG slices ignore overrides");
            assert_eq!(s.vram_mb, A30.vram_mb / 2);
            assert!((s.compute_frac - 0.5).abs() < 1e-12);
            assert_eq!(s.class_label(), "a30/mig2");
        }
    }

    #[test]
    fn fleet_capacity_is_speed_weighted() {
        let fleet = uniform_fleet(2, V100, MultiplexMode::Plain);
        assert_eq!(fleet.len(), 2);
        assert!((fleet.iter().map(|s| s.capacity()).sum::<f64>() - 2.0).abs() < 1e-12);
        // A30 is slightly faster than the V100 baseline (speed 0.92).
        let a30 = DeviceSpec::new(A30, MultiplexMode::Plain);
        assert!(a30.capacity() > 1.0);
        // MIG slices jointly weigh as the whole physical GPU.
        let mig = DeviceSpec::new(A30, MultiplexMode::Mig(2));
        assert!((mig.capacity() - a30.capacity()).abs() < 1e-12);
        assert_eq!(mig.n_vgpus(), 2);
    }
}
