//! The GPU container warm pool (§4.2 "Container Warm-pool", §4.4).
//!
//! "Creating a GPU context uses physical memory we can't control, so the
//! monitor only allows a fixed number of containers to exist at one
//! time." Idle containers are kept warm for reuse (temporal locality)
//! and evicted in LRU order when the pool is full.

use std::collections::HashMap;

use crate::types::{ContainerId, FuncId, GpuId, Nanos, StartKind};
use crate::workload::catalog::FuncClass;

use super::{ColdPhases, Container, CtrState};

/// Result of acquiring a container for one dispatch.
#[derive(Debug)]
pub struct Acquired {
    pub id: ContainerId,
    pub kind: StartKind,
    /// Cold-boot time to pay before execution (0 for warm starts).
    pub boot_ns: u64,
    /// Phase breakdown when `kind == Cold`.
    pub phases: Option<ColdPhases>,
    /// Containers destroyed to make room: (gpu, resident MB freed).
    /// The caller must credit these back to the device memory ledgers.
    pub evicted: Vec<(GpuId, u64)>,
}

/// Start-kind counters (drives the Fig-8c cold-hit/miss-rate curves).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub gpu_warm: u64,
    pub host_warm: u64,
    pub cold: u64,
}

impl PoolStats {
    pub fn total(&self) -> u64 {
        self.gpu_warm + self.host_warm + self.cold
    }

    /// Fraction of acquisitions that were cold (the paper's "cold-hit %").
    pub fn cold_ratio(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.cold as f64 / self.total() as f64
        }
    }
}

/// Fixed-capacity warm pool with LRU eviction.
#[derive(Debug)]
pub struct ContainerPool {
    max_size: usize,
    next_id: u64,
    containers: HashMap<ContainerId, Container>,
    stats: PoolStats,
}

impl ContainerPool {
    pub fn new(max_size: usize) -> Self {
        assert!(max_size >= 1);
        Self {
            max_size,
            next_id: 0,
            containers: HashMap::new(),
            stats: PoolStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.containers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.containers.is_empty()
    }

    pub fn max_size(&self) -> usize {
        self.max_size
    }

    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    pub fn get(&self, id: ContainerId) -> Option<&Container> {
        self.containers.get(&id)
    }

    pub fn get_mut(&mut self, id: ContainerId) -> Option<&mut Container> {
        self.containers.get_mut(&id)
    }

    /// Iterate all containers (metrics / memory-manager scans).
    pub fn iter(&self) -> impl Iterator<Item = &Container> {
        self.containers.values()
    }

    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut Container> {
        self.containers.values_mut()
    }

    /// Idle warm containers of `func`, most-resident first.
    fn best_idle(&self, func: FuncId, prefer_gpu: Option<GpuId>, now: Nanos) -> Option<ContainerId> {
        self.containers
            .values()
            .filter(|c| c.func == func && c.is_idle(now))
            .max_by_key(|c| {
                let gpu_match = prefer_gpu.map(|g| c.gpu == g).unwrap_or(false);
                (gpu_match, c.resident_mb(), std::cmp::Reverse(c.id.0))
            })
            .map(|c| c.id)
    }

    /// Eviction victim: containers of throttled/inactive queues (marked
    /// for eviction, §4.3) first, then LRU among idle.
    fn lru_idle(&self, now: Nanos) -> Option<ContainerId> {
        self.containers
            .values()
            .filter(|c| c.is_idle(now))
            .min_by_key(|c| (!c.marked_evict, c.last_used, c.id.0))
            .map(|c| c.id)
    }

    /// Acquire a container for one invocation of `func` placed on `gpu`.
    ///
    /// Reuses an idle warm container when possible (GPU-warm if its data
    /// is resident, host-warm otherwise); otherwise creates a cold one,
    /// evicting the LRU idle container first if the pool is full.
    /// Returns `None` if the pool is full of busy containers.
    pub fn acquire(
        &mut self,
        func: FuncId,
        class: &'static FuncClass,
        gpu: GpuId,
        now: Nanos,
    ) -> Option<Acquired> {
        if let Some(id) = self.best_idle(func, Some(gpu), now) {
            let c = self.containers.get_mut(&id).unwrap();
            let kind = if c.gpu_warm() && c.gpu == gpu {
                StartKind::GpuWarm
            } else {
                StartKind::HostWarm
            };
            c.state = CtrState::Busy;
            c.marked_evict = false;
            c.last_used = now;
            // A reused container's memory may live on another GPU (or
            // MIG slice); it must travel through host memory — evict its
            // regions there and credit the old device's ledger.
            let mut evicted = Vec::new();
            if c.gpu != gpu {
                let moved = c.ledger.evict_all();
                c.prefetch_done = None;
                if moved > 0 {
                    evicted.push((c.gpu, moved));
                }
                c.gpu = gpu;
            }
            match kind {
                StartKind::GpuWarm => self.stats.gpu_warm += 1,
                StartKind::HostWarm => self.stats.host_warm += 1,
                StartKind::Cold => unreachable!(),
            }
            return Some(Acquired {
                id,
                kind,
                boot_ns: 0,
                phases: None,
                evicted,
            });
        }

        // Cold path: make room, then create. Verify enough idle victims
        // exist *before* destroying any, so a failed acquire never loses
        // device-ledger credits.
        let needed_evictions = (self.containers.len() + 1).saturating_sub(self.max_size);
        if needed_evictions > 0 {
            let idle = self.containers.values().filter(|c| c.is_idle(now)).count();
            if idle < needed_evictions {
                return None; // pool saturated with busy containers
            }
        }
        let mut evicted = Vec::new();
        while self.containers.len() >= self.max_size {
            let victim = self.lru_idle(now).expect("idle victims pre-checked");
            let c = self.containers.remove(&victim).unwrap();
            evicted.push((c.gpu, c.resident_mb()));
        }
        let phases = ColdPhases::for_class(class);
        let boot_ns = phases.total();
        let id = ContainerId(self.next_id);
        self.next_id += 1;
        let mut c = Container::new(id, func, class, gpu, now, boot_ns);
        c.state = CtrState::Busy; // owned by the acquiring invocation
        self.containers.insert(id, c);
        self.stats.cold += 1;
        Some(Acquired {
            id,
            kind: StartKind::Cold,
            boot_ns,
            phases: Some(phases),
            evicted,
        })
    }

    /// Return a container to the pool after its invocation completes.
    pub fn release(&mut self, id: ContainerId, now: Nanos) {
        if let Some(c) = self.containers.get_mut(&id) {
            c.state = CtrState::Idle;
            c.last_used = now;
        }
    }

    /// Mark every idle container of `func` for asynchronous eviction
    /// (queue throttled/inactive, §4.3).
    pub fn mark_evict(&mut self, func: FuncId) {
        for c in self.containers.values_mut() {
            if c.func == func && c.state != CtrState::Busy {
                c.marked_evict = true;
            }
        }
    }

    /// Clear eviction marks for `func` (queue became active again).
    pub fn unmark_evict(&mut self, func: FuncId) {
        for c in self.containers.values_mut() {
            if c.func == func {
                c.marked_evict = false;
            }
        }
    }

    /// Destroy a specific container (memory-manager directed); returns
    /// (gpu, resident MB) the caller must credit back to the device.
    pub fn destroy(&mut self, id: ContainerId) -> Option<(GpuId, u64)> {
        self.containers.remove(&id).map(|c| (c.gpu, c.resident_mb()))
    }

    /// Destroy every container homed on `gpu` — busy or idle — when the
    /// device drops out of the pool (its contexts and memory are gone).
    /// Returns the number destroyed; no ledger credit is due because
    /// the device's resident accounting was zeroed by [`crate::gpu::Device::fail`].
    pub fn destroy_on_gpu(&mut self, gpu: GpuId) -> usize {
        let before = self.containers.len();
        self.containers.retain(|_, c| c.gpu != gpu);
        before - self.containers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::catalog::by_name;

    fn class() -> &'static FuncClass {
        by_name("fft").unwrap()
    }

    #[test]
    fn first_acquire_is_cold_then_warm() {
        let mut p = ContainerPool::new(4);
        let a = p.acquire(FuncId(0), class(), GpuId(0), 0).unwrap();
        assert_eq!(a.kind, StartKind::Cold);
        assert!(a.boot_ns > 0);
        p.release(a.id, 100);
        // Data not resident yet → host-warm.
        let b = p.acquire(FuncId(0), class(), GpuId(0), 200).unwrap();
        assert_eq!(b.kind, StartKind::HostWarm);
        assert_eq!(b.id, a.id);
        // Make resident → gpu-warm next time.
        p.get_mut(b.id).unwrap().ledger.page_in(u64::MAX);
        p.release(b.id, 300);
        let c = p.acquire(FuncId(0), class(), GpuId(0), 400).unwrap();
        assert_eq!(c.kind, StartKind::GpuWarm);
        let s = p.stats();
        assert_eq!((s.cold, s.host_warm, s.gpu_warm), (1, 1, 1));
    }

    #[test]
    fn lru_eviction_when_full() {
        let mut p = ContainerPool::new(2);
        let a = p.acquire(FuncId(0), class(), GpuId(0), 0).unwrap();
        p.release(a.id, 10);
        let b = p.acquire(FuncId(1), class(), GpuId(0), 20).unwrap();
        p.release(b.id, 30);
        // Pool full; acquiring a third function evicts FuncId(0) (LRU).
        let c = p.acquire(FuncId(2), class(), GpuId(0), 40).unwrap();
        assert_eq!(c.kind, StartKind::Cold);
        assert_eq!(c.evicted.len(), 1);
        assert!(p.get(a.id).is_none(), "LRU victim should be destroyed");
        assert!(p.get(b.id).is_some());
    }

    #[test]
    fn acquire_fails_when_all_busy() {
        let mut p = ContainerPool::new(1);
        let _a = p.acquire(FuncId(0), class(), GpuId(0), 0).unwrap();
        assert!(p.acquire(FuncId(1), class(), GpuId(0), 1).is_none());
    }

    #[test]
    fn busy_containers_not_reused() {
        let mut p = ContainerPool::new(4);
        let a = p.acquire(FuncId(0), class(), GpuId(0), 0).unwrap();
        // Same function again while busy → new cold container.
        let b = p.acquire(FuncId(0), class(), GpuId(0), 1).unwrap();
        assert_eq!(b.kind, StartKind::Cold);
        assert_ne!(a.id, b.id);
    }

    #[test]
    fn booting_container_not_idle_until_done() {
        let mut p = ContainerPool::new(4);
        let a = p.acquire(FuncId(0), class(), GpuId(0), 0).unwrap();
        p.release(a.id, 1); // released before boot finished (not typical, but safe)
        let c = p.get(a.id).unwrap();
        assert_eq!(c.state, CtrState::Idle);
    }

    #[test]
    fn mark_and_unmark_evict() {
        let mut p = ContainerPool::new(4);
        let a = p.acquire(FuncId(0), class(), GpuId(0), 0).unwrap();
        p.release(a.id, 10);
        p.mark_evict(FuncId(0));
        assert!(p.get(a.id).unwrap().marked_evict);
        p.unmark_evict(FuncId(0));
        assert!(!p.get(a.id).unwrap().marked_evict);
    }

    #[test]
    fn destroy_on_gpu_removes_busy_and_idle_alike() {
        let mut p = ContainerPool::new(8);
        let a = p.acquire(FuncId(0), class(), GpuId(0), 0).unwrap(); // busy on gpu0
        let b = p.acquire(FuncId(1), class(), GpuId(0), 1).unwrap();
        p.release(b.id, 10); // idle on gpu0
        let c = p.acquire(FuncId(2), class(), GpuId(1), 2).unwrap(); // gpu1 survivor
        assert_eq!(p.destroy_on_gpu(GpuId(0)), 2);
        assert!(p.get(a.id).is_none());
        assert!(p.get(b.id).is_none());
        assert!(p.get(c.id).is_some());
        assert_eq!(p.destroy_on_gpu(GpuId(0)), 0);
    }

    #[test]
    fn prefers_gpu_matching_container() {
        let mut p = ContainerPool::new(4);
        let a = p.acquire(FuncId(0), class(), GpuId(0), 0).unwrap();
        p.release(a.id, 10);
        let b = p.acquire(FuncId(0), class(), GpuId(1), 20).unwrap();
        p.release(b.id, 30);
        // Two idle containers on different GPUs; ask for gpu1.
        let c = p.acquire(FuncId(0), class(), GpuId(1), 40).unwrap();
        assert_eq!(c.id, b.id);
    }
}
