//! Container lifecycle: cold-start phase model (Fig 1) + warm pool (§4.2).

pub mod pool;

pub use pool::{Acquired, ContainerPool};

use crate::shim::AllocLedger;
use crate::types::{secs, ContainerId, DurNanos, FuncId, GpuId, Nanos};
use crate::workload::catalog::FuncClass;

/// Cold-start phase breakdown for a GPU container (Figure 1):
/// docker/sandbox creation, the NVIDIA container-toolkit hook attaching
/// the GPU, and user code loading its GPU libraries + initializing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ColdPhases {
    pub docker_s: f64,
    pub nvidia_hook_s: f64,
    pub user_init_s: f64,
}

impl ColdPhases {
    pub fn total_s(&self) -> f64 {
        self.docker_s + self.nvidia_hook_s + self.user_init_s
    }

    pub fn total(&self) -> DurNanos {
        secs(self.total_s())
    }

    /// Split a function's Table-1 GPU cold-extra into Fig-1 phases.
    ///
    /// Framework-heavy functions (TensorFlow et al., extra ≥ 3 s) pay
    /// the fixed docker (~0.6 s) + nvidia hook (~1.6 s) costs with the
    /// remainder in user init ("more than 1.5 seconds" each in Fig 1).
    /// Lightweight binaries (Rodinia, ffmpeg) have sub-second extras
    /// split proportionally.
    pub fn for_class(class: &FuncClass) -> Self {
        let extra = class.gpu_cold_extra_s;
        if extra >= 3.0 {
            Self {
                docker_s: 0.6,
                nvidia_hook_s: 1.6,
                user_init_s: extra - 2.2,
            }
        } else {
            Self {
                docker_s: 0.2 * extra,
                nvidia_hook_s: 0.5 * extra,
                user_init_s: 0.3 * extra,
            }
        }
    }

    /// CPU containers skip the hook; split the CPU cold-extra.
    pub fn for_class_cpu(class: &FuncClass) -> Self {
        let extra = class.cpu_cold_extra_s.max(0.0);
        Self {
            docker_s: 0.4 * extra,
            nvidia_hook_s: 0.0,
            user_init_s: 0.6 * extra,
        }
    }
}

/// Runtime state of a pooled container.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CtrState {
    /// Cold init in progress until the stored time.
    Booting(Nanos),
    /// Initialized and idle.
    Idle,
    /// Currently executing an invocation.
    Busy,
}

/// One GPU container in the warm pool.
#[derive(Debug, Clone)]
pub struct Container {
    pub id: ContainerId,
    pub func: FuncId,
    pub class: &'static FuncClass,
    /// Device the container's GPU context + memory belong to.
    pub gpu: GpuId,
    pub state: CtrState,
    /// Intercepted allocations (shim ledger).
    pub ledger: AllocLedger,
    pub last_used: Nanos,
    /// When a pending async prefetch completes (None = no prefetch in
    /// flight).
    pub prefetch_done: Option<Nanos>,
    /// Marked for asynchronous eviction (queue throttled/inactive, §4.3).
    pub marked_evict: bool,
}

impl Container {
    pub fn new(
        id: ContainerId,
        func: FuncId,
        class: &'static FuncClass,
        gpu: GpuId,
        now: Nanos,
        boot: DurNanos,
    ) -> Self {
        let mut ledger = AllocLedger::default();
        // User init performs the function's cuMemAlloc calls, which the
        // shim converts to UVM allocations (not yet resident).
        ledger.alloc(class.mem_mb);
        Self {
            id,
            func,
            class,
            gpu,
            state: if boot == 0 {
                CtrState::Idle
            } else {
                CtrState::Booting(now + boot)
            },
            ledger,
            last_used: now,
            prefetch_done: None,
            marked_evict: false,
        }
    }

    pub fn footprint_mb(&self) -> u64 {
        self.ledger.footprint_mb()
    }

    pub fn resident_mb(&self) -> u64 {
        self.ledger.resident_mb()
    }

    /// Is all of the container's data on device (a "GPU-warm" start)?
    pub fn gpu_warm(&self) -> bool {
        self.ledger.nonresident_mb() == 0
    }

    pub fn is_idle(&self, now: Nanos) -> bool {
        match self.state {
            CtrState::Idle => true,
            CtrState::Booting(t) => now >= t,
            CtrState::Busy => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::catalog::by_name;

    #[test]
    fn phases_sum_to_table1_extra() {
        for name in ["imagenet", "roberta", "ffmpeg", "isoneural", "lud"] {
            let c = by_name(name).unwrap();
            let p = ColdPhases::for_class(c);
            assert!(
                (p.total_s() - c.gpu_cold_extra_s).abs() < 1e-9,
                "{name}: {} vs {}",
                p.total_s(),
                c.gpu_cold_extra_s
            );
        }
    }

    #[test]
    fn framework_functions_pay_fixed_hook() {
        let img = ColdPhases::for_class(by_name("imagenet").unwrap());
        assert_eq!(img.nvidia_hook_s, 1.6);
        assert_eq!(img.docker_s, 0.6);
        assert!(img.user_init_s > 1.5); // Fig 1: "1.5 additional seconds"
        let ffm = ColdPhases::for_class(by_name("ffmpeg").unwrap());
        assert!(ffm.nvidia_hook_s < 0.1);
    }

    #[test]
    fn cpu_phases_have_no_hook() {
        let p = ColdPhases::for_class_cpu(by_name("imagenet").unwrap());
        assert_eq!(p.nvidia_hook_s, 0.0);
        assert!((p.total_s() - 4.626).abs() < 1e-9);
    }

    #[test]
    fn container_boots_then_idles() {
        let class = by_name("fft").unwrap();
        let c = Container::new(ContainerId(1), FuncId(0), class, GpuId(0), 100, 50);
        assert!(!c.is_idle(120));
        assert!(c.is_idle(150));
        assert_eq!(c.footprint_mb(), class.mem_mb);
        assert!(!c.gpu_warm()); // fresh UVM allocations not resident
    }
}
