//! Fairness accounting (Figs 5a/5b): per-function GPU service over
//! 30-second windows and the Eq-1 theoretical bound.

use crate::types::{to_secs, DurNanos, Nanos};

use super::InvRecord;

/// Service received per function within one time window (Fig 5a series).
#[derive(Debug, Clone)]
pub struct FairnessWindow {
    pub start: Nanos,
    pub end: Nanos,
    /// GPU service seconds per function id (dense, indexed by FuncId).
    pub service_s: Vec<f64>,
    /// Functions that were backlogged (had queued or running work) at
    /// any point during the window.
    pub backlogged: Vec<bool>,
}

impl FairnessWindow {
    /// Max−min service gap among backlogged functions (Fig 5b metric).
    pub fn max_gap_s(&self) -> f64 {
        let vals: Vec<f64> = (0..self.service_s.len())
            .filter(|&i| self.backlogged[i])
            .map(|i| self.service_s[i])
            .collect();
        if vals.len() < 2 {
            return 0.0;
        }
        let max = vals.iter().cloned().fold(f64::MIN, f64::max);
        let min = vals.iter().cloned().fold(f64::MAX, f64::min);
        max - min
    }
}

/// Slice execution records into fixed windows, attributing each record's
/// on-device service time proportionally to overlapping windows.
///
/// Backlog attribution follows the fairness theorem's premise: a
/// function counts as backlogged in a window only if its [arrived,
/// completed] spans cover (nearly) the *whole* window — Eq 1 bounds the
/// service gap between *continuously* backlogged functions, and a
/// function with work during only a sliver of the window would make the
/// measured gap meaningless.
pub fn service_windows(
    records: &[InvRecord],
    n_funcs: usize,
    window: DurNanos,
    horizon: Nanos,
) -> Vec<FairnessWindow> {
    assert!(window > 0);
    let n_windows = horizon.div_ceil(window) as usize;
    let mut out: Vec<FairnessWindow> = (0..n_windows)
        .map(|w| FairnessWindow {
            start: w as Nanos * window,
            end: (w as Nanos + 1) * window,
            service_s: vec![0.0; n_funcs],
            backlogged: vec![false; n_funcs],
        })
        .collect();
    // Per (window, func): coverage extent of [arrived, completed] spans.
    let mut cover: Vec<Vec<Option<(Nanos, Nanos)>>> = vec![vec![None; n_funcs]; n_windows];
    for r in records {
        let f = r.func.0 as usize;
        if f >= n_funcs {
            continue;
        }
        // Service attribution over [exec_start, completed].
        let exec_start = r.completed.saturating_sub(r.exec);
        let (mut w, last) = (
            (exec_start / window) as usize,
            (r.completed.saturating_sub(1) / window) as usize,
        );
        while w <= last && w < n_windows {
            let ws = out[w].start.max(exec_start);
            let we = out[w].end.min(r.completed);
            if we > ws {
                out[w].service_s[f] += to_secs(we - ws);
            }
            w += 1;
        }
        // Backlog-coverage extents over [arrived, completed].
        let (mut w, last) = (
            (r.arrived / window) as usize,
            (r.completed.saturating_sub(1) / window) as usize,
        );
        while w <= last && w < n_windows {
            let ws = out[w].start.max(r.arrived);
            let we = out[w].end.min(r.completed);
            let e = &mut cover[w][f];
            *e = match *e {
                None => Some((ws, we)),
                Some((a, b)) => Some((a.min(ws), b.max(we))),
            };
            w += 1;
        }
    }
    // Continuously backlogged ⇔ coverage extends over ≥90% of the window
    // on both ends.
    for (w, win) in out.iter_mut().enumerate() {
        let slack = window / 20;
        for f in 0..n_funcs {
            if let Some((a, b)) = cover[w][f] {
                win.backlogged[f] = a <= win.start + slack && b >= win.end - slack;
            }
        }
    }
    out
}

/// Jain's fairness index over a set of per-function values (e.g. mean
/// latencies in the fig9 cluster sweep): `(Σx)² / (n·Σx²)`, in
/// `(0, 1]` — 1.0 when every function fares identically, → 1/n when one
/// function takes everything. Empty/degenerate inputs report 1.0
/// (nothing to be unfair about).
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq <= 0.0 {
        return 1.0;
    }
    sum * sum / (xs.len() as f64 * sq)
}

/// The Eq-1 fairness upper bound (w=1 for all functions):
/// |S_i − S_j| ≤ (D−1)(2T + τ_i − τ_j) — evaluated with the catalog's
/// extreme τ values to get the workload-level bound the paper plots as
/// the horizontal line in Fig 5b.
pub fn fairness_bound_eq1(d: usize, t_s: f64, tau_max_s: f64, tau_min_s: f64) -> f64 {
    // At D=1 classic fair queueing's bound degenerates; the paper's plot
    // uses the configured D. Guard the subtraction for safety.
    let d_term = (d as f64 - 1.0).max(1.0);
    d_term * (2.0 * t_s + (tau_max_s - tau_min_s).max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{FuncId, GpuId, InvocationId, StartKind, SEC};

    fn rec(func: u32, arrived: Nanos, disp: Nanos, done: Nanos) -> InvRecord {
        InvRecord {
            inv: InvocationId(arrived + func as u64),
            func: FuncId(func),
            gpu: GpuId(0),
            arrived,
            dispatched: disp,
            completed: done,
            start_kind: StartKind::GpuWarm,
            boot: 0,
            blocking: 0,
            exec: done - disp,
        }
    }

    #[test]
    fn service_attributed_to_windows() {
        // One execution spanning both 3 s windows fully ([arrived=0,
        // completed=6s]): continuously backlogged in both.
        let records = [rec(0, 0, SEC, 6 * SEC)];
        let ws = service_windows(&records, 1, 3 * SEC, 6 * SEC);
        assert_eq!(ws.len(), 2);
        assert!((ws[0].service_s[0] - 2.0).abs() < 1e-9);
        assert!((ws[1].service_s[0] - 3.0).abs() < 1e-9);
        assert!(ws[0].backlogged[0] && ws[1].backlogged[0]);
    }

    #[test]
    fn max_gap_over_backlogged_only() {
        let records = [
            rec(0, 0, 0, 10 * SEC),     // 10 s service, covers the window
            rec(1, 0, 3 * SEC, 10 * SEC), // 7 s service, covers the window
        ];
        let ws = service_windows(&records, 3, 10 * SEC, 10 * SEC);
        // Function 2 never appears: excluded from the gap.
        assert!((ws[0].max_gap_s() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn sliver_of_backlog_does_not_count() {
        // Work only in the first 20% of the window ⇒ not continuously
        // backlogged ⇒ excluded from the fairness gap.
        let records = [rec(0, 0, 0, 2 * SEC)];
        let ws = service_windows(&records, 1, 10 * SEC, 10 * SEC);
        assert!(!ws[0].backlogged[0]);
        assert!(ws[0].service_s[0] > 0.0); // service still attributed
    }

    #[test]
    fn bound_matches_paper_magnitude() {
        // Paper §6.1: D=2, T=10, catalog τ spread ≈ 4.5 s ⇒ bound ≈ 24.5;
        // their Fig-5b line is 411 for their exact workload — the shape
        // check is that measured gaps stay far below the bound.
        let b = fairness_bound_eq1(2, 10.0, 4.5, 0.026);
        assert!(b > 20.0 && b < 30.0, "{b}");
    }

    #[test]
    fn jain_index_bounds() {
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
        assert!((jain_index(&[2.0, 2.0, 2.0]) - 1.0).abs() < 1e-12);
        // One function hogging everything → 1/n.
        assert!((jain_index(&[9.0, 0.0, 0.0]) - 1.0 / 3.0).abs() < 1e-12);
        let mixed = jain_index(&[1.0, 2.0, 3.0]);
        assert!(mixed > 1.0 / 3.0 && mixed < 1.0, "{mixed}");
    }

    #[test]
    fn single_function_has_zero_gap() {
        let records = [rec(0, 0, 0, SEC)];
        let ws = service_windows(&records, 1, SEC, SEC);
        assert_eq!(ws[0].max_gap_s(), 0.0);
    }
}
