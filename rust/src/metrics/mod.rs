//! Metrics: per-invocation records, per-function aggregates, fairness
//! windows (Fig 5a/5b), and utilization timelines (Fig 6c).

pub mod fairness;

pub use fairness::{fairness_bound_eq1, jain_index, service_windows, FairnessWindow};

use std::collections::HashMap;

use crate::types::{to_secs, DurNanos, FuncId, GpuId, InvocationId, Nanos, StartKind};
use crate::util::stats::{variance, Welford};

/// Full life-cycle record of one completed invocation.
///
/// `PartialEq`/`Eq` compare every field — the cluster equivalence
/// property ("a 1-shard cluster replays event-for-event like a plain
/// plane") is checked by comparing whole record streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InvRecord {
    pub inv: InvocationId,
    pub func: FuncId,
    pub gpu: GpuId,
    pub arrived: Nanos,
    pub dispatched: Nanos,
    pub completed: Nanos,
    pub start_kind: StartKind,
    /// Cold-boot time paid (0 for warm starts).
    pub boot: DurNanos,
    /// Shim blocking before the kernel started (prefetch/madvise).
    pub blocking: DurNanos,
    /// On-device service time (incl. interference + UVM faults).
    pub exec: DurNanos,
}

impl InvRecord {
    /// End-to-end latency (queueing + overheads + service), seconds.
    pub fn latency_s(&self) -> f64 {
        to_secs(self.completed - self.arrived)
    }

    /// Queue waiting time, seconds.
    pub fn queue_s(&self) -> f64 {
        to_secs(self.dispatched - self.arrived)
    }

    pub fn exec_s(&self) -> f64 {
        to_secs(self.exec)
    }

    /// Fig-4 "in-shim" time, seconds.
    pub fn in_shim_s(&self) -> f64 {
        to_secs(self.blocking)
    }
}

/// Per-function aggregate (Fig 6b rows).
#[derive(Debug, Clone)]
pub struct FuncAgg {
    pub func: FuncId,
    pub invocations: u64,
    pub mean_latency_s: f64,
    pub var_latency: f64,
    pub mean_exec_s: f64,
    pub mean_queue_s: f64,
    pub cold: u64,
    pub host_warm: u64,
    pub gpu_warm: u64,
}

/// Collects invocation records + utilization samples during a run.
#[derive(Debug, Default, Clone)]
pub struct Recorder {
    pub records: Vec<InvRecord>,
    /// (time, instantaneous device utilization) at monitor ticks.
    pub util_timeline: Vec<(Nanos, f64)>,
    /// (time, current D level) at monitor ticks.
    pub d_timeline: Vec<(Nanos, usize)>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, r: InvRecord) {
        self.records.push(r);
    }

    pub fn sample_util(&mut self, now: Nanos, util: f64, d: usize) {
        self.util_timeline.push((now, util));
        self.d_timeline.push((now, d));
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Weighted average latency (§6.1): Σ N_i L_i / Σ N_i — i.e. the
    /// plain mean over all invocations.
    pub fn weighted_avg_latency_s(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.latency_s()).sum::<f64>() / self.records.len() as f64
    }

    pub fn mean_exec_s(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.exec_s()).sum::<f64>() / self.records.len() as f64
    }

    /// All latencies (seconds), for percentile reporting.
    pub fn latencies_s(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.latency_s()).collect()
    }

    /// Cold-start fraction (Fig 8c "cold-hit %").
    pub fn cold_ratio(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        let cold = self
            .records
            .iter()
            .filter(|r| r.start_kind == StartKind::Cold)
            .count();
        cold as f64 / self.records.len() as f64
    }

    /// Per-function aggregates, sorted by FuncId.
    pub fn per_function(&self) -> Vec<FuncAgg> {
        let mut map: HashMap<FuncId, (Welford, Welford, Welford, [u64; 3])> = HashMap::new();
        for r in &self.records {
            let e = map
                .entry(r.func)
                .or_insert_with(|| (Welford::new(), Welford::new(), Welford::new(), [0; 3]));
            e.0.push(r.latency_s());
            e.1.push(r.exec_s());
            e.2.push(r.queue_s());
            match r.start_kind {
                StartKind::Cold => e.3[0] += 1,
                StartKind::HostWarm => e.3[1] += 1,
                StartKind::GpuWarm => e.3[2] += 1,
            }
        }
        let mut out: Vec<FuncAgg> = map
            .into_iter()
            .map(|(func, (lat, exec, queue, kinds))| FuncAgg {
                func,
                invocations: lat.count(),
                mean_latency_s: lat.mean(),
                var_latency: lat.variance(),
                mean_exec_s: exec.mean(),
                mean_queue_s: queue.mean(),
                cold: kinds[0],
                host_warm: kinds[1],
                gpu_warm: kinds[2],
            })
            .collect();
        out.sort_by_key(|a| a.func);
        out
    }

    /// Variance of the per-function mean latencies — the paper's
    /// "global inter-function latency variance" (Fig 6b).
    pub fn inter_function_variance(&self) -> f64 {
        let means: Vec<f64> = self.per_function().iter().map(|a| a.mean_latency_s).collect();
        variance(&means)
    }

    /// Append every sample from `other` (cluster-level aggregation:
    /// shard recorders merge into one). Call [`Self::sort_by_time`]
    /// after the last merge to restore the completion-time order the
    /// percentile/fairness reports assume.
    pub fn merge(&mut self, other: &Recorder) {
        self.records.extend_from_slice(&other.records);
        self.util_timeline.extend_from_slice(&other.util_timeline);
        self.d_timeline.extend_from_slice(&other.d_timeline);
    }

    /// Re-sort records and timelines by time (stable: same-instant ties
    /// keep merge order, so merged output is deterministic).
    pub fn sort_by_time(&mut self) {
        self.records.sort_by_key(|r| r.completed);
        self.util_timeline.sort_by_key(|(t, _)| *t);
        self.d_timeline.sort_by_key(|(t, _)| *t);
    }

    /// Mean utilization over the sampled timeline.
    pub fn mean_util(&self) -> f64 {
        if self.util_timeline.is_empty() {
            return 0.0;
        }
        self.util_timeline.iter().map(|(_, u)| u).sum::<f64>()
            / self.util_timeline.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SEC;

    fn rec(func: u32, arrived: Nanos, disp: Nanos, done: Nanos, kind: StartKind) -> InvRecord {
        InvRecord {
            inv: InvocationId(arrived),
            func: FuncId(func),
            gpu: GpuId(0),
            arrived,
            dispatched: disp,
            completed: done,
            start_kind: kind,
            boot: 0,
            blocking: 0,
            exec: done - disp,
        }
    }

    #[test]
    fn weighted_avg_is_mean_over_invocations() {
        let mut m = Recorder::new();
        m.record(rec(0, 0, SEC, 2 * SEC, StartKind::GpuWarm)); // 2 s
        m.record(rec(0, 0, SEC, 4 * SEC, StartKind::GpuWarm)); // 4 s
        m.record(rec(1, 0, SEC, 6 * SEC, StartKind::Cold)); // 6 s
        assert!((m.weighted_avg_latency_s() - 4.0).abs() < 1e-9);
        assert!((m.cold_ratio() - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn per_function_aggregates() {
        let mut m = Recorder::new();
        m.record(rec(0, 0, SEC, 2 * SEC, StartKind::Cold));
        m.record(rec(0, 0, SEC, 4 * SEC, StartKind::GpuWarm));
        m.record(rec(2, 0, 2 * SEC, 3 * SEC, StartKind::HostWarm));
        let aggs = m.per_function();
        assert_eq!(aggs.len(), 2);
        assert_eq!(aggs[0].func, FuncId(0));
        assert_eq!(aggs[0].invocations, 2);
        assert!((aggs[0].mean_latency_s - 3.0).abs() < 1e-9);
        assert_eq!(aggs[0].cold, 1);
        assert_eq!(aggs[0].gpu_warm, 1);
        assert_eq!(aggs[1].func, FuncId(2));
        assert!((aggs[1].mean_queue_s - 2.0).abs() < 1e-9);
    }

    #[test]
    fn inter_function_variance_zero_for_identical() {
        let mut m = Recorder::new();
        m.record(rec(0, 0, SEC, 2 * SEC, StartKind::GpuWarm));
        m.record(rec(1, 0, SEC, 2 * SEC, StartKind::GpuWarm));
        assert_eq!(m.inter_function_variance(), 0.0);
    }

    #[test]
    fn merge_concatenates_and_sorts() {
        let mut a = Recorder::new();
        a.record(rec(0, 0, SEC, 4 * SEC, StartKind::GpuWarm));
        a.sample_util(2 * SEC, 0.5, 2);
        let mut b = Recorder::new();
        b.record(rec(1, 0, SEC, 2 * SEC, StartKind::Cold));
        b.sample_util(SEC, 1.0, 2);
        a.merge(&b);
        a.sort_by_time();
        assert_eq!(a.len(), 2);
        // Sorted by completion time: b's record (2 s) comes first.
        assert_eq!(a.records[0].func, FuncId(1));
        assert_eq!(a.util_timeline[0].0, SEC);
        assert!((a.weighted_avg_latency_s() - 3.0).abs() < 1e-9);
        assert!((a.cold_ratio() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn util_timeline_mean() {
        let mut m = Recorder::new();
        m.sample_util(0, 0.5, 2);
        m.sample_util(SEC, 0.7, 2);
        assert!((m.mean_util() - 0.6).abs() < 1e-12);
        assert_eq!(m.d_timeline.len(), 2);
    }
}
