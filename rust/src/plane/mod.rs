//! The per-server control plane: policy + device pool + container pool
//! + memory manager + concurrency controller, composed exactly as §5
//! describes (a dedicated dispatch loop notified on arrivals,
//! completions, and 200 ms monitor ticks).
//!
//! The plane is clock-agnostic: every entry point takes `now`. The
//! discrete-event engine ([`crate::sim`]) passes virtual time and
//! schedules the returned [`Dispatch`] records; the real-time driver
//! ([`crate::server`], examples) passes wall time and executes the
//! dispatched function on the PJRT runtime instead.
//!
//! # Failure model
//!
//! With a [`FaultConfig`] installed ([`PlaneConfig::faults`]) the plane
//! absorbs three fault kinds (see [`crate::fault`]): device loss
//! ([`ControlPlane::fail_device`] evacuates and re-queues everything in
//! flight on the GPU, forced cold), transient exec faults (detected at
//! what would have been the completion; the attempt's service is
//! discarded), and stragglers (the completion never arrives; the
//! monitor-tick watchdog evacuates after `straggler_k`× the expected
//! exec time). Every attempt is stamped into its [`Dispatch::attempt`]
//! and completions are matched against the live attempt
//! ([`ControlPlane::on_complete_attempt`]), so a late completion from a
//! superseded attempt is dropped — each invocation resolves exactly
//! once: a success, or a terminal [`FaultFate`] drained by the serving
//! layer once the retry budget is spent. Failed attempts re-queue at
//! the *head* of their flow and the failed attempt's virtual-time
//! charge stands (no double F-advance; the retry pays its own τ).
//! Admission-side protection — poison-function circuit breakers and
//! deadline-aware overload shedding — gates [`ControlPlane::try_admit`].
//! Without a plan (`faults: None`) every fault branch is untaken and
//! the plane is bit-identical to one compiled before this layer
//! existed (property-tested against a neutral plan).

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use crate::container::ContainerPool;
use crate::fault::{
    AdmitError, BreakerAdmit, BreakerState, FaultConfig, FaultFate, FaultKind, FaultState,
    FaultStats,
};
use crate::gpu::{uniform_fleet, DevicePool, DeviceSpec, GpuProfile, MultiplexMode};
use crate::memory::{MemPolicy, MemoryManager};
use crate::metrics::{InvRecord, Recorder};
use crate::scheduler::policies::PolicyKind;
use crate::scheduler::{
    AnticipationEvent, ConcurrencyController, Invocation, MqfqConfig, Policy, PolicyCtx, QState,
};
use crate::telemetry::{self, EventKind, ShardSink, Telemetry};
use crate::types::{ContainerId, DurNanos, FuncId, GpuId, InvocationId, Nanos, StartKind, MS};
use crate::workload::Workload;

/// Control-plane configuration for one experiment/server.
#[derive(Clone)]
pub struct PlaneConfig {
    pub policy: PolicyKind,
    pub mqfq: MqfqConfig,
    pub mem_policy: MemPolicy,
    /// The server's fleet: one [`DeviceSpec`] per physical GPU (MIG
    /// specs expand into slices). Replaces the old uniform
    /// `n_gpus/profile/mode` triple — [`PlaneConfig::uniform`] and
    /// [`uniform_fleet`] re-express that shape in one line, and mixed
    /// fleets (V100 beside a MIG-sliced A30, per-device D pins) are
    /// now first-class.
    pub devices: Vec<DeviceSpec>,
    /// Fixed plane-level D (per device without a spec override).
    /// Ignored if `dynamic_d` or `adaptive_d` is set.
    pub d: usize,
    /// Dynamic D: (max_d, utilization threshold) — §4.4.
    pub dynamic_d: Option<(usize, f64)>,
    /// Adaptive D from the Little's-law completion tracker:
    /// `(min_d, max_d)`. Each monitor tick drains the per-device
    /// completion windows into a concurrency-demand estimate and steps
    /// D one level toward it. Takes precedence over `dynamic_d`.
    pub adaptive_d: Option<(usize, usize)>,
    /// Warm-pool capacity (paper default: 32).
    pub pool_size: usize,
    /// CUDA interposition shim enabled (Fig 3 toggles this off).
    pub shim: bool,
    /// NVML polling cadence (paper: 200 ms).
    pub monitor_period: DurNanos,
    /// When false, containers are destroyed after each invocation — the
    /// "FCFS Naïve" nvidia-docker baseline of §6.2 (no container pool,
    /// every start cold, ~300× latency overhead).
    pub keep_warm: bool,
    /// Fault-injection / fault-tolerance plan. `None` (the default)
    /// keeps every fault path untouched: the plane with no plan is
    /// bit-identical to one with a neutral plan (property-tested).
    pub faults: Option<FaultConfig>,
}

impl Default for PlaneConfig {
    fn default() -> Self {
        Self {
            policy: PolicyKind::Mqfq,
            mqfq: MqfqConfig::default(),
            mem_policy: MemPolicy::PrefetchSwap,
            devices: uniform_fleet(1, crate::gpu::V100, MultiplexMode::Plain),
            d: 2,
            dynamic_d: None,
            adaptive_d: None,
            pool_size: 32,
            shim: true,
            monitor_period: 200 * MS,
            keep_warm: true,
            faults: None,
        }
    }
}

impl PlaneConfig {
    /// Uniform fleet of `n` × `profile` in `mode` — the shape the old
    /// `n_gpus/profile/mode` fields described.
    pub fn uniform(n: usize, profile: GpuProfile, mode: MultiplexMode) -> Self {
        Self {
            devices: uniform_fleet(n, profile, mode),
            ..Default::default()
        }
    }

    /// Aggregate service capacity of the fleet in V100-equivalents
    /// (Σ [`DeviceSpec::capacity`]) — the weight capacity-aware cluster
    /// routing normalizes shard depth by.
    pub fn fleet_capacity(&self) -> f64 {
        self.devices.iter().map(|s| s.capacity()).sum()
    }

    /// Schedulable devices (vGPUs) this fleet expands to.
    pub fn n_devices(&self) -> usize {
        self.devices.iter().map(|s| s.n_vgpus()).sum()
    }
}

/// One dispatch decision with its modeled timeline.
#[derive(Debug, Clone, Copy)]
pub struct Dispatch {
    pub inv: InvocationId,
    pub func: FuncId,
    pub gpu: GpuId,
    pub ctr: ContainerId,
    /// Decision time.
    pub at: Nanos,
    /// When the kernel actually starts (after boot + blocking).
    pub exec_start: Nanos,
    /// Modeled completion time (sim mode schedules this; real mode
    /// replaces it with the measured completion).
    pub complete_at: Nanos,
    pub start_kind: StartKind,
    pub boot: DurNanos,
    pub blocking: DurNanos,
    /// Modeled on-device service (incl. interference + UVM faults).
    pub exec: DurNanos,
    /// Retry attempt this dispatch runs as (0 = first try). Completions
    /// are attempt-stamped so a late completion from a superseded
    /// attempt is dropped, never double-counted (exactly-once).
    pub attempt: u32,
}

struct InFlight {
    func: FuncId,
    ctr: ContainerId,
    arrived: Nanos,
    dispatch: Dispatch,
    /// Whether this invocation owns its device slot and container. A
    /// same-flow batch occupies ONE slot/container, registered under
    /// the last item of the chained timeline (it completes last and
    /// frees both); the earlier items are riders (`false`).
    device_bound: bool,
}

/// The control plane.
pub struct ControlPlane {
    pub cfg: PlaneConfig,
    workload: Workload,
    policy: Box<dyn Policy>,
    gpus: DevicePool,
    ctrs: ContainerPool,
    mem: MemoryManager,
    dctl: ConcurrencyController,
    pub recorder: Recorder,
    in_flight_per_func: Vec<usize>,
    in_flight: HashMap<InvocationId, InFlight>,
    /// Invocations popped from the policy that could not be placed
    /// (container pool saturated); retried before the policy.
    stash: VecDeque<Invocation>,
    /// Reused batch scratch for the dispatch loop (no per-pass alloc).
    batch_buf: Vec<Invocation>,
    /// In-flight riders: batched invocations that hold no device slot
    /// of their own (their batch's anchor does).
    batch_riders: usize,
    riders_per_func: Vec<usize>,
    next_inv: u64,
    /// §Observability: shard-scoped telemetry sink (None = detached,
    /// one branch per site). Pure observation — nothing here feeds back
    /// into scheduling, so instrumented and bare runs are behaviorally
    /// identical (the indexed-vs-naive property oracle stays valid).
    tel: Option<ShardSink>,
    /// Last Global_VT / D-token occupancy emitted, so the trace carries
    /// one event per change rather than one per probe.
    last_global_vt: f64,
    last_d_tokens: i64,
    /// Fault-injection + fault-tolerance state (None = no plan; every
    /// fault-path branch sits behind this option so the neutral run is
    /// bit-identical to an unconfigured one).
    faults: Option<FaultState>,
}

impl ControlPlane {
    pub fn new(workload: Workload, cfg: PlaneConfig) -> Self {
        let n_funcs = workload.len();
        let policy = cfg.policy.build_mqfq(n_funcs, cfg.mqfq.clone());
        let gpus = DevicePool::new(cfg.devices.clone());
        let dctl = match (cfg.adaptive_d, cfg.dynamic_d) {
            (Some((min_d, max_d)), _) => ConcurrencyController::littles(min_d, max_d),
            (None, Some((max_d, thr))) => ConcurrencyController::dynamic(max_d, thr),
            (None, None) => ConcurrencyController::fixed(cfg.d),
        };
        Self {
            ctrs: ContainerPool::new(cfg.pool_size),
            mem: MemoryManager::new(cfg.mem_policy),
            dctl,
            recorder: Recorder::new(),
            in_flight_per_func: vec![0; n_funcs],
            in_flight: HashMap::new(),
            stash: VecDeque::new(),
            batch_buf: Vec::new(),
            batch_riders: 0,
            riders_per_func: vec![0; n_funcs],
            next_inv: 0,
            tel: None,
            last_global_vt: 0.0,
            last_d_tokens: 0,
            faults: cfg.faults.clone().map(FaultState::new),
            policy,
            gpus,
            workload,
            cfg,
        }
    }

    /// Attach the shared telemetry subsystem, scoped to `shard`. The
    /// sink resolves this shard's metric slots and the workload's
    /// function→class map once, so the hot path records with plain
    /// indexed atomic adds.
    pub fn attach_telemetry(&mut self, tel: Arc<Telemetry>, shard: u32) {
        let (_, class_of) = telemetry::workload_classes(&self.workload);
        self.tel = Some(ShardSink::new(tel, shard, class_of));
    }

    pub fn telemetry(&self) -> Option<&Arc<Telemetry>> {
        self.tel.as_ref().map(|s| s.telemetry())
    }

    pub fn workload(&self) -> &Workload {
        &self.workload
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn pending(&self) -> usize {
        self.policy.pending() + self.stash.len()
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight.len()
    }

    pub fn pool_stats(&self) -> crate::container::pool::PoolStats {
        self.ctrs.stats()
    }

    pub fn current_d(&self) -> usize {
        self.dctl.limit()
    }

    pub fn mean_utilization(&mut self, now: Nanos) -> f64 {
        self.gpus.mean_utilization(now)
    }

    /// Per-device `(class label, mean utilization)` rows at `now` (the
    /// heterogeneity sweep's per-class imbalance input).
    pub fn device_utilizations(&mut self, now: Nanos) -> Vec<(String, f64)> {
        self.gpus.device_utilizations(now)
    }

    /// The concurrency level the *policy layer* should assume. Limits
    /// are per-device on a mixed fleet (MIG slices pin 1 per §4.2, spec
    /// overrides pin their device, everything else follows the
    /// controller); the policy's token math uses the most permissive of
    /// them — on a uniform fleet exactly the old per-GPU limit.
    fn policy_d(&self) -> usize {
        self.gpus.max_limit(self.dctl.limit())
    }

    /// A new invocation of `func` arrived (open-loop trace or server).
    /// Returns its id and any dispatches it unlocked.
    pub fn on_arrival(&mut self, func: FuncId, now: Nanos) -> (InvocationId, Vec<Dispatch>) {
        let id = InvocationId(self.next_inv);
        self.next_inv += 1;
        if let Some(tel) = &self.tel {
            tel.metrics().submitted.inc();
            tel.emit(tel.event(now, EventKind::Submit).inv(id.0).func(func.0));
        }
        self.policy.enqueue(
            Invocation {
                id,
                func,
                arrived: now,
            },
            now,
        );
        if let Some(tel) = &self.tel {
            let vt_ns = self.policy.queue_vt(func).map_or(0, |v| (v * 1e9) as i64);
            let gvt_ns = self.policy.global_vt().map_or(0, |v| (v * 1e9) as i64);
            tel.emit(
                tel.event(now, EventKind::Enqueue)
                    .inv(id.0)
                    .func(func.0)
                    .a(vt_ns)
                    .b(gvt_ns),
            );
        }
        self.apply_state_changes(now);
        (id, self.try_dispatch(now))
    }

    /// An invocation finished at `now` (modeled or measured). Frees its
    /// slot, updates the policy's service estimate, records metrics, and
    /// dispatches any unlocked work.
    ///
    /// Returns the completed invocation's own [`InvRecord`] (None for an
    /// unknown id) alongside the unlocked dispatches, so wall-clock
    /// drivers can hand the completion to the matching waiter directly
    /// instead of guessing from `recorder.records.last()` — under
    /// concurrent completions "last" may belong to someone else, which
    /// used to strand the original submitter forever.
    pub fn on_complete(
        &mut self,
        inv: InvocationId,
        now: Nanos,
    ) -> (Option<InvRecord>, Vec<Dispatch>) {
        let Some(att) = self.in_flight.get(&inv).map(|f| f.dispatch.attempt) else {
            return (None, Vec::new());
        };
        self.on_complete_attempt(inv, att, now)
    }

    /// Attempt-stamped completion: the exactly-once form. A completion
    /// whose attempt does not match the live in-flight attempt is a
    /// leftover from a superseded (faulted, re-queued) attempt and is
    /// dropped. With a fault plan, a pending transient fault turns the
    /// completion into a failed-attempt settlement, and a pending
    /// straggler swallows it (the execution "hangs" until the watchdog
    /// evacuates it).
    pub fn on_complete_attempt(
        &mut self,
        inv: InvocationId,
        attempt: u32,
        now: Nanos,
    ) -> (Option<InvRecord>, Vec<Dispatch>) {
        match self.in_flight.get(&inv) {
            Some(f) if f.dispatch.attempt == attempt => {}
            _ => return (None, Vec::new()),
        }
        match self.faults.as_ref().and_then(|fs| fs.pending_kind(inv)) {
            Some(FaultKind::Straggler) => return (None, Vec::new()),
            Some(kind) => {
                self.settle_failed_attempt(inv, kind, now, false);
                self.apply_state_changes(now);
                return (None, self.try_dispatch(now));
            }
            None => {}
        }
        let fli = self.in_flight.remove(&inv).unwrap();
        if let Some(fs) = &mut self.faults {
            fs.on_success(inv);
            let tr = fs.breaker_record(fli.func, false, now);
            if let (Some(state), Some(tel)) = (tr, &self.tel) {
                tel.emit(
                    tel.event(now, EventKind::BreakerState)
                        .func(fli.func.0)
                        .a(state.code()),
                );
            }
        }
        if fli.device_bound {
            self.gpus.complete(inv, now);
            if self.cfg.keep_warm {
                self.ctrs.release(fli.ctr, now);
            } else if let Some((g, mb)) = self.ctrs.destroy(fli.ctr) {
                self.gpus.device_mut(g).sub_resident(mb);
            }
        } else {
            // Rider: its batch anchor owns the slot and container.
            self.batch_riders -= 1;
            self.riders_per_func[fli.func.0 as usize] -= 1;
        }
        self.in_flight_per_func[fli.func.0 as usize] -= 1;
        // Observed service = time since the kernel started (real mode
        // feeds measured time; sim mode reproduces the model).
        let service = now.saturating_sub(fli.dispatch.exec_start);
        // Estimator accuracy is judged against the prediction *before*
        // this completion updates it.
        let predicted = self.policy.estimated_exec_s(fli.func);
        self.policy.on_complete_info(
            fli.func,
            service,
            Some(fli.dispatch.start_kind),
            fli.dispatch.boot,
            now,
        );
        let rec = InvRecord {
            inv,
            func: fli.func,
            gpu: fli.dispatch.gpu,
            arrived: fli.arrived,
            dispatched: fli.dispatch.at,
            completed: now,
            start_kind: fli.dispatch.start_kind,
            boot: fli.dispatch.boot,
            blocking: fli.dispatch.blocking,
            exec: service,
        };
        self.recorder.record(rec);
        if let Some(tel) = &self.tel {
            let m = tel.metrics();
            let e2e = now.saturating_sub(fli.arrived);
            let queue_wait = fli.dispatch.at.saturating_sub(fli.arrived);
            m.completed.inc();
            m.queue_wait_ns.record(queue_wait);
            m.exec_ns.record(service);
            m.e2e_ns.record(e2e);
            if let Some(c) = tel.class(fli.func.0) {
                c.completed.inc();
                c.exec_ns.record(service);
            }
            tel.emit(
                tel.event(now, EventKind::Complete)
                    .inv(inv.0)
                    .func(fli.func.0)
                    .a(e2e as i64)
                    .b(service as i64)
                    .c(fli.dispatch.gpu.0 as i64),
            );
            if let Some(pred_s) = predicted {
                let pred_ns = (pred_s * 1e9) as i64;
                m.est_abs_error_ns.record((pred_ns - service as i64).unsigned_abs());
                m.est_last_exec_ns.set(pred_ns);
                tel.emit(
                    tel.event(now, EventKind::Estimate)
                        .inv(inv.0)
                        .func(fli.func.0)
                        .a(pred_ns)
                        .b(service as i64)
                        .c(fli.dispatch.gpu.0 as i64),
                );
            }
        }
        self.apply_state_changes(now);
        (Some(rec), self.try_dispatch(now))
    }

    /// Settle one failed attempt: release its device / container /
    /// ledger accounting (skipped when the device-failure path already
    /// cleaned up), count + trace the fault, feed the function's
    /// breaker, and either re-queue at the head of its flow (retry
    /// budget remaining — the policy releases the slot without learning
    /// an exec sample and without re-advancing VT) or record the
    /// terminal [`FaultFate`]. Returns whether the invocation
    /// re-queued; callers run the dispatch loop afterwards.
    fn settle_failed_attempt(
        &mut self,
        inv: InvocationId,
        kind: FaultKind,
        now: Nanos,
        device_cleaned: bool,
    ) -> bool {
        let Some(fli) = self.in_flight.remove(&inv) else {
            return false;
        };
        if fli.device_bound {
            if !device_cleaned {
                self.gpus.complete(inv, now);
                // The attempt crashed or hung inside its sandbox:
                // destroy it (forcing a cold restart) instead of
                // returning it to the warm pool.
                if let Some((g, mb)) = self.ctrs.destroy(fli.ctr) {
                    self.gpus.device_mut(g).sub_resident(mb);
                }
            }
        } else {
            // Rider: its batch anchor owns the slot and container.
            self.batch_riders -= 1;
            self.riders_per_func[fli.func.0 as usize] -= 1;
        }
        self.in_flight_per_func[fli.func.0 as usize] -= 1;
        let attempts_done = fli.dispatch.attempt + 1;
        let fs = self.faults.as_mut().expect("fault settle without a plan");
        match kind {
            FaultKind::Device => fs.stats.faults_device += 1,
            FaultKind::Transient => fs.stats.faults_transient += 1,
            FaultKind::Straggler => fs.stats.faults_straggler += 1,
        }
        let requeue = fs.on_attempt_failed(inv, fli.func, attempts_done);
        let breaker_tr = fs.breaker_record(fli.func, true, now);
        if let Some(tel) = &self.tel {
            let m = tel.metrics();
            match kind {
                FaultKind::Device => m.faults_device.inc(),
                FaultKind::Transient => m.faults_transient.inc(),
                FaultKind::Straggler => m.faults_straggler.inc(),
            }
            tel.emit(
                tel.event(now, EventKind::Fault)
                    .inv(inv.0)
                    .func(fli.func.0)
                    .a(kind.code())
                    .b(fli.dispatch.attempt as i64)
                    .c(fli.dispatch.gpu.0 as i64),
            );
            if requeue {
                m.retries.inc();
                tel.emit(
                    tel.event(now, EventKind::Requeue)
                        .inv(inv.0)
                        .func(fli.func.0)
                        .a(attempts_done as i64),
                );
            } else {
                m.retry_exhausted.inc();
            }
            if let Some(state) = breaker_tr {
                if state == BreakerState::Open {
                    m.breaker_trips.inc();
                }
                tel.emit(
                    tel.event(now, EventKind::BreakerState)
                        .func(fli.func.0)
                        .a(state.code()),
                );
            }
        }
        self.policy.on_fault(
            Invocation {
                id: inv,
                func: fli.func,
                arrived: fli.arrived,
            },
            now,
            requeue,
        );
        requeue
    }

    /// Evacuate a dropped GPU: the pool marks it failed (untangling
    /// placements and sticky routes), its containers are destroyed
    /// (their device state died with it), and every in-flight attempt
    /// on it — anchors *and* batch riders — settles as a
    /// [`FaultKind::Device`] fault.
    fn apply_device_failure(&mut self, gpu: GpuId, now: Nanos) {
        let _evacuated = self.gpus.fail_device(gpu, now);
        self.ctrs.destroy_on_gpu(gpu);
        let stranded: Vec<InvocationId> = self
            .in_flight
            .iter()
            .filter(|(_, f)| f.dispatch.gpu == gpu)
            .map(|(id, _)| *id)
            .collect();
        for inv in stranded {
            self.settle_failed_attempt(inv, FaultKind::Device, now, true);
        }
    }

    /// A GPU dropped out (scheduled injection or an external signal):
    /// evacuate it and dispatch the re-queued work onto the surviving
    /// fleet. Requires a fault plan (the retry bookkeeping lives
    /// there).
    pub fn fail_device(&mut self, gpu: GpuId, now: Nanos) -> Vec<Dispatch> {
        self.apply_device_failure(gpu, now);
        self.apply_state_changes(now);
        self.try_dispatch(now)
    }

    /// A failed GPU rejoins the pool, empty and cold.
    pub fn heal_device(&mut self, gpu: GpuId, now: Nanos) -> Vec<Dispatch> {
        self.gpus.heal_device(gpu, now);
        self.try_dispatch(now)
    }

    /// Fault maintenance, run each monitor tick: fire scheduled device
    /// failures / recoveries and evacuate hung attempts whose watchdog
    /// deadline (`straggler_k × max(estimated, modeled) exec`) passed.
    fn fault_maintenance(&mut self, now: Nanos) {
        let Some(fs) = &mut self.faults else { return };
        let failures = fs.due_device_failures(now);
        let recoveries = fs.due_device_recoveries(now);
        let mut hung: Vec<InvocationId> = Vec::new();
        for (id, f) in &self.in_flight {
            if fs.pending_kind(*id) == Some(FaultKind::Straggler) {
                let est = self
                    .policy
                    .estimated_exec_s(f.func)
                    .map(crate::types::secs)
                    .unwrap_or(0);
                let base = f.dispatch.exec.max(est);
                if now >= fs.straggler_deadline(f.dispatch.exec_start, base) {
                    hung.push(*id);
                }
            }
        }
        for gpu in failures {
            self.apply_device_failure(gpu, now);
        }
        for gpu in recoveries {
            self.gpus.heal_device(gpu, now);
        }
        for inv in hung {
            self.settle_failed_attempt(inv, FaultKind::Straggler, now, false);
        }
    }

    /// Admission gate for the serving layer: the function's circuit
    /// breaker first, then deadline-aware overload shedding (predicted
    /// wait = backlog × estimated service / live device slots, with
    /// enter/exit hysteresis). Always admits without a fault plan, and
    /// touches nothing on that path.
    pub fn try_admit(&mut self, func: FuncId, now: Nanos) -> Result<(), AdmitError> {
        if self.faults.is_none() {
            return Ok(());
        }
        let est_s = self.policy.estimated_exec_s(func).unwrap_or(1.0);
        let backlog = (self.pending() + self.in_flight.len()) as f64;
        let slots = self.gpus.live_slots(self.dctl.limit()).max(1) as f64;
        let predicted_wait_s = backlog * est_s / slots;
        let fs = self.faults.as_mut().unwrap();
        let (admit, transition) = fs.breaker_admit(func, now);
        if let (Some(state), Some(tel)) = (transition, &self.tel) {
            tel.emit(
                tel.event(now, EventKind::BreakerState)
                    .func(func.0)
                    .a(state.code()),
            );
        }
        if let BreakerAdmit::Rejected { retry_after_ms } = admit {
            return Err(AdmitError::Quarantined { retry_after_ms });
        }
        if let Some(err) = fs.shed_eval(predicted_wait_s) {
            let AdmitError::Overloaded { retry_after_ms } = err else {
                unreachable!("shed_eval only sheds");
            };
            if let Some(tel) = &self.tel {
                tel.metrics().shed.inc();
                tel.emit(
                    tel.event(now, EventKind::Shed)
                        .func(func.0)
                        .a((predicted_wait_s * 1e9) as i64)
                        .b(retry_after_ms as i64),
                );
            }
            return Err(err);
        }
        Ok(())
    }

    /// Terminal retry-exhausted fates since the last drain. The serving
    /// layer fails the tickets (`exec-failed`); sim harnesses count
    /// them for exactly-once conservation.
    pub fn drain_fault_fates(&mut self) -> Vec<FaultFate> {
        match &mut self.faults {
            Some(fs) => fs.drain_fates(),
            None => Vec::new(),
        }
    }

    /// Fault-layer counters (all zero when no plan is configured).
    pub fn fault_stats(&self) -> FaultStats {
        self.faults.as_ref().map(|f| f.stats).unwrap_or_default()
    }

    /// Live (non-failed) schedulable devices.
    pub fn live_devices(&self) -> usize {
        self.gpus.live_devices()
    }

    /// 200 ms monitor tick (§4.4/§5 "Utilization monitoring"): sample
    /// utilization, adjust D, expire idle queues, dispatch.
    pub fn on_monitor_tick(&mut self, now: Nanos) -> Vec<Dispatch> {
        let util = self.gpus.utilization();
        self.dctl.on_sample(util);
        if self.dctl.littles {
            // Adaptive D: drain the per-device completion windows into
            // a Little's-law concurrency-demand estimate and step D.
            let demand = self.gpus.littles_demand(now);
            if let Some(old) = self.dctl.on_littles_estimate(demand) {
                if let Some(tel) = &self.tel {
                    tel.metrics().d_resizes.inc();
                    tel.emit(
                        tel.event(now, EventKind::DResize)
                            .a(self.dctl.limit() as i64)
                            .b(old as i64)
                            .c((demand.unwrap_or(0.0) * 1e3) as i64),
                    );
                }
            }
        }
        self.recorder.sample_util(now, util, self.dctl.limit());
        // Fault layer (no-op without a plan): scheduled device
        // failures/recoveries and the straggler watchdog.
        if self.faults.is_some() {
            self.fault_maintenance(now);
            self.apply_state_changes(now);
        }
        // Background memory maintenance: async swap-out of marked/LRU
        // regions keeps headroom for upcoming prefetches (§4.3).
        self.mem.maintain(&mut self.ctrs, &mut self.gpus, now);
        #[cfg(debug_assertions)]
        if let Err(e) = self.check_invariants() {
            panic!("control-plane invariant violated at t={now}: {e}");
        }
        let d = self.try_dispatch(now);
        // try_dispatch runs the policy's update_state pass, which may
        // expire queues; propagate to memory management.
        self.apply_state_changes(now);
        d
    }

    /// Exact utilization-integral touch (sim engine, at exec starts).
    pub fn touch(&mut self, now: Nanos) {
        self.gpus.mean_utilization(now);
    }

    /// Deep structural invariants, used by the property-test suite and
    /// asserted at monitor ticks in debug builds:
    /// 1. per-device in-flight ≤ the current per-GPU limit;
    /// 2. every device's resident-memory ledger equals the sum of its
    ///    containers' resident regions (shim/device consistency);
    /// 3. container-pool size within capacity;
    /// 4. per-function in-flight counters match the device pool;
    /// 5. the device pool's O(1) in-flight aggregates match the plane's
    ///    own ledgers.
    pub fn check_invariants(&self) -> Result<(), String> {
        // Run-to-completion: a dynamic-D reduction never preempts, so
        // the hard bound is the controller's ceiling, not its current
        // setting. The ceiling is *per device*: MIG slices are a
        // constant 1 and spec overrides pin their own device, so a
        // mixed plane holds mixed limits side by side.
        let plane_ceiling = if let Some((_, max_d)) = self.cfg.adaptive_d {
            max_d
        } else {
            match self.cfg.dynamic_d {
                Some((max_d, _)) => max_d,
                None => self.cfg.d,
            }
        };
        for d in self.gpus.devices() {
            let limit = d.limit(plane_ceiling);
            if d.in_flight() > limit {
                return Err(format!(
                    "{}: {} in flight exceeds limit {limit}",
                    d.id,
                    d.in_flight()
                ));
            }
            let ctr_resident: u64 = self
                .ctrs
                .iter()
                .filter(|c| c.gpu == d.id)
                .map(|c| c.resident_mb())
                .sum();
            if ctr_resident != d.resident_mb() {
                return Err(format!(
                    "{}: device ledger {} != container ledgers {}",
                    d.id,
                    d.resident_mb(),
                    ctr_resident
                ));
            }
        }
        if self.ctrs.len() > self.cfg.pool_size {
            return Err(format!(
                "pool {} exceeds capacity {}",
                self.ctrs.len(),
                self.cfg.pool_size
            ));
        }
        // Batched riders are invisible to the device pool (their batch
        // anchor holds the slot), so the plane's ledgers exceed the
        // pool's by exactly the rider counts.
        let mut per_func = vec![0usize; self.in_flight_per_func.len()];
        for d in self.gpus.devices() {
            for r in d.running() {
                per_func[r.func.0 as usize] += 1;
            }
        }
        for (f, n) in per_func.iter_mut().enumerate() {
            *n += self.riders_per_func[f];
        }
        if per_func != self.in_flight_per_func {
            return Err("per-function in-flight counters out of sync".into());
        }
        // 5. the device pool's O(1) aggregates agree with the plane's
        //    own ledgers (they are maintained independently — begin/
        //    complete vs the in-flight map — so drift is detectable).
        if self.gpus.in_flight() + self.batch_riders != self.in_flight.len() {
            return Err(format!(
                "device-pool in-flight {} + riders {} != plane in-flight {}",
                self.gpus.in_flight(),
                self.batch_riders,
                self.in_flight.len()
            ));
        }
        for (f, &n) in per_func.iter().enumerate() {
            let pool_n = self.gpus.in_flight_of(FuncId(f as u32)) + self.riders_per_func[f];
            if pool_n != n {
                return Err(format!(
                    "device-pool in-flight-of f{f} (+riders) = {pool_n}, devices say {n}"
                ));
            }
        }
        Ok(())
    }

    fn apply_state_changes(&mut self, now: Nanos) {
        for (func, state) in self.policy.drain_state_changes() {
            if let Some(tel) = &self.tel {
                let m = tel.metrics();
                match state {
                    QState::Active => m.flow_activations.inc(),
                    QState::Throttled => m.flow_throttles.inc(),
                    QState::Inactive => m.flow_deactivations.inc(),
                }
                tel.emit(
                    tel.event(now, EventKind::FlowState)
                        .func(func.0)
                        .a(telemetry::qstate_code(state)),
                );
            }
            match state {
                QState::Active => {
                    self.mem
                        .on_queue_active(func, &mut self.ctrs, &mut self.gpus, now)
                }
                QState::Throttled | QState::Inactive => self.mem.on_queue_deactivate(
                    func,
                    &mut self.ctrs,
                    &mut self.gpus,
                    now,
                ),
            }
        }
    }

    /// The dispatch loop: while a device slot is free and the policy
    /// yields work, place it (Algorithm 1's token check + §5 late
    /// binding to a GPU).
    pub fn try_dispatch(&mut self, now: Nanos) -> Vec<Dispatch> {
        let mut out = Vec::new();
        let mut batch = std::mem::take(&mut self.batch_buf);
        loop {
            let plane_d = self.dctl.limit();
            // Token check: any device with a free slot (per-device
            // limits on a mixed fleet)?
            if !self.gpus.has_free_slot(plane_d) {
                break;
            }
            batch.clear();
            // Stash (placement-failed invocations) takes priority.
            if let Some(i) = self.stash.pop_front() {
                batch.push(i);
            } else {
                let ctx = PolicyCtx {
                    in_flight: &self.in_flight_per_func,
                    d: self.policy_d(),
                };
                self.policy.dispatch_batch(now, &ctx, &mut batch);
                if batch.is_empty() {
                    break;
                }
            }
            if !self.place_batch(&batch, now, &mut out) {
                // Container pool saturated with busy containers; park
                // the invocations and stop dispatching.
                for i in batch.drain(..) {
                    self.stash.push_back(i);
                }
                break;
            }
        }
        batch.clear();
        self.batch_buf = batch;
        if !out.is_empty() {
            self.apply_state_changes(now);
        }
        self.probe_scheduler_telemetry(now);
        out
    }

    /// §Observability: emit scheduler-internal facts that changed since
    /// the last probe — Global_VT advancement and D-token occupancy.
    /// Called after every dispatch pass; a cheap no-op when detached or
    /// when nothing moved.
    fn probe_scheduler_telemetry(&mut self, now: Nanos) {
        // Drain anticipation events even when detached so they can't
        // accumulate (a take of an empty Vec performs no allocation).
        let anticipation = self.policy.drain_anticipation();
        let Some(tel) = &self.tel else { return };
        for ev in anticipation {
            match ev {
                AnticipationEvent::Grace {
                    func,
                    window,
                    predicted_iat,
                } => {
                    tel.metrics().grace_holds.inc();
                    tel.emit(
                        tel.event(now, EventKind::Grace)
                            .func(func.0)
                            .a(window as i64)
                            .b(predicted_iat as i64),
                    );
                }
                AnticipationEvent::Batch {
                    func,
                    size,
                    vt_advance,
                } => {
                    let m = tel.metrics();
                    m.batch_dispatches.inc();
                    m.batched_invocations.add(size as u64);
                    tel.emit(
                        tel.event(now, EventKind::Batch)
                            .func(func.0)
                            .a(size as i64)
                            .b(vt_advance as i64),
                    );
                }
            }
        }
        if let Some(vt) = self.policy.global_vt() {
            if vt.to_bits() != self.last_global_vt.to_bits() {
                self.last_global_vt = vt;
                let ns = (vt * 1e9) as i64;
                tel.metrics().global_vt_ns.set(ns);
                tel.emit(tel.event(now, EventKind::GlobalVt).a(ns));
            }
        }
        let occ = self.in_flight.len() as i64;
        if occ != self.last_d_tokens {
            self.last_d_tokens = occ;
            tel.metrics().d_tokens.set(occ);
            tel.emit(
                tel.event(now, EventKind::DTokens)
                    .a(occ)
                    .b(self.dctl.limit() as i64),
            );
        }
    }

    /// Place one same-flow batch (usually a singleton): pick a GPU,
    /// acquire ONE container, settle memory, and model a chained
    /// execution timeline — the head runs the full modeled service,
    /// each rider starts when its predecessor finishes and runs the
    /// `batch_marginal` fraction (warm weights, no boot, no blocking).
    /// The device slot and container are registered under the LAST
    /// item, which the chained timeline completes last. Returns false
    /// (placing nothing) when the container pool is saturated.
    fn place_batch(&mut self, batch: &[Invocation], now: Nanos, out: &mut Vec<Dispatch>) -> bool {
        let head = batch[0];
        let class = self.workload.func(head.func).class;
        let Some(gpu) = self
            .gpus
            .pick(head.func, class, self.dctl.limit(), self.cfg.shim)
        else {
            return false;
        };

        let Some(acq) = self.ctrs.acquire(head.func, class, gpu, now) else {
            return false;
        };
        // Destroyed LRU victims free their device memory.
        for (g, mb) in &acq.evicted {
            self.gpus.device_mut(*g).sub_resident(*mb);
            if let Some(tel) = &self.tel {
                let m = tel.metrics();
                m.evictions.inc();
                m.evicted_mb.add(*mb);
                if let Some(d) = tel.device(g.0) {
                    d.evictions.inc();
                }
                tel.emit(tel.event(now, EventKind::Evict).a(*mb as i64).c(g.0 as i64));
            }
        }

        // Memory: prefetch/fault per policy; cold boot hides transfers.
        let mem_cost = self
            .mem
            .before_exec(acq.id, &mut self.ctrs, &mut self.gpus, now, acq.boot_ns);

        // Execution model: frozen at dispatch from the current device
        // state (see gpu::Device::exec_time).
        let exec_model = self.gpus.device(gpu).exec_time(class, self.cfg.shim);
        let head_exec = exec_model + mem_cost.fault;
        let rider_exec =
            (self.cfg.mqfq.anticipate.batch_marginal * head_exec as f64).max(0.0) as DurNanos;
        let anchor = batch[batch.len() - 1].id;
        self.gpus.begin(gpu, anchor, head.func, class, now);

        let mut exec_start = now + acq.boot_ns + mem_cost.blocking;
        for (i, inv) in batch.iter().enumerate() {
            let is_head = i == 0;
            let (start_kind, boot, blocking, exec) = if is_head {
                (acq.kind, acq.boot_ns, mem_cost.blocking, head_exec)
            } else {
                (StartKind::GpuWarm, 0, 0, rider_exec)
            };
            let complete_at = exec_start + exec;
            self.in_flight_per_func[inv.func.0 as usize] += 1;
            if inv.id != anchor {
                self.batch_riders += 1;
                self.riders_per_func[inv.func.0 as usize] += 1;
            }
            // Attempt stamping + fault planning (deterministic oracle;
            // no-ops without a plan, so `attempt` stays 0).
            let attempt = match &mut self.faults {
                Some(fs) => {
                    let a = fs.attempt_of(inv.id);
                    fs.plan_attempt(inv.id, inv.func, a);
                    a
                }
                None => 0,
            };
            let dispatch = Dispatch {
                inv: inv.id,
                func: inv.func,
                gpu,
                ctr: acq.id,
                at: now,
                exec_start,
                complete_at,
                start_kind,
                boot,
                blocking,
                exec,
                attempt,
            };
            self.in_flight.insert(
                inv.id,
                InFlight {
                    func: inv.func,
                    ctr: acq.id,
                    arrived: inv.arrived,
                    dispatch,
                    device_bound: inv.id == anchor,
                },
            );
            if let Some(tel) = &self.tel {
                let m = tel.metrics();
                match start_kind {
                    StartKind::Cold => m.cold_starts.inc(),
                    StartKind::HostWarm => m.host_warm_starts.inc(),
                    StartKind::GpuWarm => m.gpu_warm_starts.inc(),
                }
                if let Some(d) = tel.device(gpu.0) {
                    d.dispatches.inc();
                    if start_kind == StartKind::Cold {
                        d.cold_starts.inc();
                    }
                }
                tel.emit(
                    tel.event(now, EventKind::Dispatch)
                        .inv(inv.id.0)
                        .func(inv.func.0)
                        .a(telemetry::start_kind_code(start_kind))
                        .b(boot as i64)
                        .c(gpu.0 as i64),
                );
                tel.emit(
                    tel.event(exec_start, EventKind::ExecStart)
                        .inv(inv.id.0)
                        .func(inv.func.0)
                        .a(blocking as i64)
                        .c(gpu.0 as i64),
                );
            }
            out.push(dispatch);
            exec_start = complete_at;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::{BreakerConfig, ShedConfig};
    use crate::types::SEC;
    use crate::workload::catalog::by_name;

    fn workload2() -> Workload {
        let mut w = Workload::default();
        w.register(by_name("fft").unwrap(), 0, 1.0);
        w.register(by_name("imagenet").unwrap(), 0, 2.0);
        w
    }

    fn plane(cfg: PlaneConfig) -> ControlPlane {
        ControlPlane::new(workload2(), cfg)
    }

    #[test]
    fn first_arrival_dispatches_cold() {
        let mut p = plane(PlaneConfig::default());
        let (id, ds) = p.on_arrival(FuncId(0), 0);
        assert_eq!(ds.len(), 1);
        let d = ds[0];
        assert_eq!(d.inv, id);
        assert_eq!(d.start_kind, StartKind::Cold);
        assert!(d.boot > 2 * SEC); // fft cold extra ≈ 2.425 s
        assert!(d.exec >= crate::types::secs(0.897));
        assert_eq!(p.in_flight(), 1);
    }

    #[test]
    fn warm_start_after_completion() {
        let mut p = plane(PlaneConfig::default());
        let (_, ds) = p.on_arrival(FuncId(0), 0);
        let done = ds[0].complete_at;
        let (rec, more) = p.on_complete(ds[0].inv, done);
        assert!(more.is_empty());
        assert_eq!(p.recorder.len(), 1);
        // The returned record is the completed invocation's own.
        let rec = rec.unwrap();
        assert_eq!(rec.inv, ds[0].inv);
        assert_eq!(rec.completed, done);
        assert_eq!(Some(&rec), p.recorder.records.last());
        // Unknown ids report nothing (idempotent completion).
        assert_eq!(p.on_complete(ds[0].inv, done).0, None);
        // Second arrival shortly after: warm container, no boot.
        let (_, ds2) = p.on_arrival(FuncId(0), done + SEC);
        assert_eq!(ds2.len(), 1);
        assert_ne!(ds2[0].start_kind, StartKind::Cold);
        assert_eq!(ds2[0].boot, 0);
        assert!(ds2[0].complete_at - ds2[0].at < ds[0].complete_at - ds[0].at);
    }

    #[test]
    fn d_limits_concurrency() {
        let cfg = PlaneConfig {
            d: 2,
            ..Default::default()
        };
        let mut p = plane(cfg);
        let mut dispatched = 0;
        for i in 0..5 {
            let (_, ds) = p.on_arrival(FuncId(0), i);
            dispatched += ds.len();
        }
        assert_eq!(dispatched, 2, "D=2 must cap in-flight work");
        assert_eq!(p.in_flight(), 2);
        assert_eq!(p.pending(), 3);
    }

    #[test]
    fn completion_unlocks_queued_work() {
        let cfg = PlaneConfig {
            d: 1,
            ..Default::default()
        };
        let mut p = plane(cfg);
        let (_, ds1) = p.on_arrival(FuncId(0), 0);
        let (_, ds2) = p.on_arrival(FuncId(1), 1);
        assert_eq!(ds1.len(), 1);
        assert!(ds2.is_empty());
        let (_, more) = p.on_complete(ds1[0].inv, ds1[0].complete_at);
        assert_eq!(more.len(), 1);
        assert_eq!(more[0].func, FuncId(1));
    }

    #[test]
    fn mig_mode_caps_slices_at_one() {
        let cfg = PlaneConfig {
            devices: uniform_fleet(1, crate::gpu::A30, MultiplexMode::Mig(2)),
            d: 4, // ignored under MIG
            ..Default::default()
        };
        let mut p = plane(cfg);
        let mut total = 0;
        for i in 0..4 {
            let (_, ds) = p.on_arrival(FuncId(0), i);
            total += ds.len();
        }
        // Two slices × one invocation each.
        assert_eq!(total, 2);
    }

    #[test]
    fn mixed_fleet_holds_mixed_limits() {
        // A D-pinned device and a MIG pair beside an unconstrained
        // V100 on one plane: slot math and invariants are per-device.
        let cfg = PlaneConfig {
            devices: vec![
                DeviceSpec::new(crate::gpu::V100, MultiplexMode::Plain).with_d(1),
                DeviceSpec::new(crate::gpu::A30, MultiplexMode::Mig(2)),
                DeviceSpec::new(crate::gpu::V100, MultiplexMode::Plain),
            ],
            d: 2,
            ..Default::default()
        };
        let mut p = plane(cfg);
        let mut dispatched = 0;
        for i in 0..8 {
            let (_, ds) = p.on_arrival(FuncId(i % 2), i as u64);
            dispatched += ds.len();
        }
        // Capacity: 1 (pinned) + 1 + 1 (slices) + 2 (plane D) = 5.
        assert_eq!(dispatched, 5);
        assert_eq!(p.in_flight(), 5);
        p.check_invariants().unwrap();
    }

    #[test]
    fn monitor_tick_records_util() {
        let mut p = plane(PlaneConfig::default());
        p.on_arrival(FuncId(0), 0);
        p.on_monitor_tick(200 * MS);
        assert_eq!(p.recorder.util_timeline.len(), 1);
        assert!(p.recorder.util_timeline[0].1 > 0.0);
    }

    #[test]
    fn pool_saturation_stashes_instead_of_dropping() {
        let cfg = PlaneConfig {
            d: 4,
            pool_size: 1,
            ..Default::default()
        };
        let mut p = plane(cfg);
        let (_, d1) = p.on_arrival(FuncId(0), 0);
        assert_eq!(d1.len(), 1);
        // Second function can't get a container (pool=1, busy).
        let (_, d2) = p.on_arrival(FuncId(1), 1);
        assert!(d2.is_empty());
        assert_eq!(p.pending(), 1);
        // Frees up on completion.
        let (_, more) = p.on_complete(d1[0].inv, d1[0].complete_at);
        assert_eq!(more.len(), 1);
        assert_eq!(more[0].func, FuncId(1));
    }

    #[test]
    fn telemetry_observes_the_full_lifecycle() {
        let w = workload2();
        let (classes, _) = crate::telemetry::workload_classes(&w);
        let cfg = PlaneConfig::default();
        let tel = Arc::new(Telemetry::new(&[cfg.n_devices()], &classes));
        let mut p = ControlPlane::new(w, cfg);
        p.attach_telemetry(tel.clone(), 0);
        let (_, ds) = p.on_arrival(FuncId(0), 0);
        p.on_complete(ds[0].inv, ds[0].complete_at);
        let m = tel.registry.shard(0);
        assert_eq!(m.submitted.get(), 1);
        assert_eq!(m.completed.get(), 1);
        assert_eq!(m.cold_starts.get(), 1);
        assert_eq!(m.e2e_ns.count(), 1);
        assert_eq!(m.exec_ns.count(), 1);
        assert!(m.d_tokens.get() == 0, "token gauge returns to idle");
        // Per-class and per-device series hit the right slots.
        assert_eq!(tel.registry.class(0).unwrap().completed.get(), 1);
        assert_eq!(tel.registry.device(0, 0).unwrap().dispatches.get(), 1);
        let kinds: Vec<EventKind> =
            tel.trace.drain(10_000).iter().map(|e| e.kind).collect();
        for k in [
            EventKind::Submit,
            EventKind::Enqueue,
            EventKind::Dispatch,
            EventKind::ExecStart,
            EventKind::Complete,
            EventKind::DTokens,
        ] {
            assert!(kinds.contains(&k), "missing {k:?} in {kinds:?}");
        }
        assert_eq!(tel.dropped_events(), 0);
    }

    #[test]
    fn batch_dispatch_chains_same_flow_on_one_slot() {
        let mut mqfq = MqfqConfig {
            t: 100.0,
            ..Default::default()
        };
        mqfq.anticipate.batch_max = 3;
        mqfq.anticipate.batch_marginal = 0.5;
        let w = workload2();
        let (classes, _) = crate::telemetry::workload_classes(&w);
        let cfg = PlaneConfig {
            mqfq,
            d: 1,
            ..Default::default()
        };
        let tel = Arc::new(Telemetry::new(&[cfg.n_devices()], &classes));
        let mut p = ControlPlane::new(w, cfg);
        p.attach_telemetry(tel.clone(), 0);
        let (_, head) = p.on_arrival(FuncId(0), 0);
        assert_eq!(head.len(), 1);
        for t in 1..4 {
            let (_, ds) = p.on_arrival(FuncId(0), t);
            assert!(ds.is_empty(), "D=1: queue behind the head");
        }
        // Completing the head frees the slot; one decision coalesces
        // the three queued invocations into a chained batch.
        let (_, batch) = p.on_complete(head[0].inv, head[0].complete_at);
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[1].exec_start, batch[0].complete_at);
        assert_eq!(batch[2].exec_start, batch[1].complete_at);
        for d in &batch[1..] {
            assert_eq!(d.start_kind, StartKind::GpuWarm);
            assert_eq!(d.boot, 0);
            assert_eq!(d.blocking, 0);
            assert_eq!(d.exec, batch[0].exec / 2);
            assert_eq!(d.ctr, batch[0].ctr);
            assert_eq!(d.gpu, batch[0].gpu);
        }
        assert_eq!(p.in_flight(), 3);
        p.check_invariants().unwrap();
        // Riders drain in order without freeing the slot; the anchor
        // (last item) releases the device and the container.
        for (i, d) in batch.iter().enumerate() {
            let (rec, _) = p.on_complete(d.inv, d.complete_at);
            assert!(rec.is_some());
            p.check_invariants().unwrap();
            assert_eq!(p.in_flight(), 2 - i);
        }
        let m = tel.registry.shard(0);
        assert_eq!(m.batch_dispatches.get(), 1);
        assert_eq!(m.batched_invocations.get(), 3);
        let kinds: Vec<EventKind> =
            tel.trace.drain(100_000).iter().map(|e| e.kind).collect();
        assert!(kinds.contains(&EventKind::Batch), "{kinds:?}");
    }

    #[test]
    fn adaptive_d_grows_with_littles_demand() {
        let w = workload2();
        let (classes, _) = crate::telemetry::workload_classes(&w);
        let cfg = PlaneConfig {
            adaptive_d: Some((1, 4)),
            ..Default::default()
        };
        let tel = Arc::new(Telemetry::new(&[cfg.n_devices()], &classes));
        let mut p = ControlPlane::new(w, cfg);
        p.attach_telemetry(tel.clone(), 0);
        assert_eq!(p.current_d(), 1, "adaptive D starts at min_d");
        let (_, ds) = p.on_arrival(FuncId(0), 0);
        let mut d = ds[0];
        for _ in 0..5 {
            let done = d.complete_at;
            // Tick just before the completion so the next window is
            // tiny relative to the ~1 s service: demand ≫ 1.
            p.on_monitor_tick(done - MS);
            p.on_complete(d.inv, done);
            let (_, ds) = p.on_arrival(FuncId(0), done);
            d = ds[0];
            p.on_monitor_tick(done + MS);
        }
        assert_eq!(p.current_d(), 4, "demand-driven steps reach max_d");
        assert!(tel.registry.shard(0).d_resizes.get() >= 3);
        let kinds: Vec<EventKind> =
            tel.trace.drain(100_000).iter().map(|e| e.kind).collect();
        assert!(kinds.contains(&EventKind::DResize), "{kinds:?}");
    }

    #[test]
    fn transient_fault_requeues_and_retries_cold() {
        let cfg = PlaneConfig {
            faults: Some(FaultConfig {
                poison: vec![(FuncId(0), 1.0)],
                max_faults: 1,
                ..Default::default()
            }),
            ..Default::default()
        };
        let mut p = plane(cfg);
        let (id, ds) = p.on_arrival(FuncId(0), 0);
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].attempt, 0);
        // The faulted attempt's "completion" becomes a retry dispatch.
        let (rec, retry) = p.on_complete_attempt(id, 0, ds[0].complete_at);
        assert!(rec.is_none());
        assert_eq!(retry.len(), 1);
        assert_eq!(retry[0].inv, id);
        assert_eq!(retry[0].attempt, 1);
        assert_eq!(
            retry[0].start_kind,
            StartKind::Cold,
            "crashed sandbox destroyed: retry is forced cold"
        );
        let st = p.fault_stats();
        assert_eq!(st.faults_transient, 1);
        assert_eq!(st.retries, 1);
        // A late completion stamped with the superseded attempt drops.
        assert!(p.on_complete_attempt(id, 0, retry[0].complete_at).0.is_none());
        assert_eq!(p.in_flight(), 1, "stale completion must not free the slot");
        // The retry (fault cap spent) completes normally, exactly once.
        let (rec, _) = p.on_complete_attempt(id, 1, retry[0].complete_at);
        assert!(rec.is_some());
        assert!(p.drain_fault_fates().is_empty());
        assert_eq!(p.in_flight(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn retry_budget_exhaustion_resolves_with_a_fate() {
        let cfg = PlaneConfig {
            faults: Some(FaultConfig {
                poison: vec![(FuncId(0), 1.0)],
                retry_budget: 2,
                ..Default::default()
            }),
            ..Default::default()
        };
        let mut p = plane(cfg);
        let (id, ds) = p.on_arrival(FuncId(0), 0);
        let (_, r1) = p.on_complete_attempt(id, 0, ds[0].complete_at);
        assert_eq!(r1.len(), 1, "first failure retries");
        let (rec, r2) = p.on_complete_attempt(id, 1, r1[0].complete_at);
        assert!(rec.is_none());
        assert!(r2.is_empty(), "budget spent: no further retry");
        let fates = p.drain_fault_fates();
        assert_eq!(fates.len(), 1);
        assert_eq!(fates[0].inv, id);
        assert_eq!(fates[0].attempts, 2);
        let st = p.fault_stats();
        assert_eq!((st.retries, st.retry_exhausted), (1, 1));
        assert_eq!(p.in_flight(), 0);
        assert_eq!(p.pending(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn device_failure_evacuates_and_requeues_on_survivors() {
        let cfg = PlaneConfig {
            devices: uniform_fleet(2, crate::gpu::V100, MultiplexMode::Plain),
            d: 1,
            faults: Some(FaultConfig::default()),
            ..Default::default()
        };
        let mut p = plane(cfg);
        let (a, da) = p.on_arrival(FuncId(0), 0);
        let (b, db) = p.on_arrival(FuncId(1), 1);
        assert_eq!((da.len(), db.len()), (1, 1));
        assert_ne!(da[0].gpu, db[0].gpu);
        let dead = da[0].gpu;
        let retry = p.fail_device(dead, 10 * MS);
        // `a` re-queued but the survivor's slot is occupied by `b`.
        assert!(retry.is_empty());
        assert_eq!(p.pending(), 1);
        assert_eq!(p.in_flight(), 1);
        assert_eq!(p.fault_stats().faults_device, 1);
        assert_eq!(p.live_devices(), 1);
        p.check_invariants().unwrap();
        let (_, more) = p.on_complete(b, db[0].complete_at);
        assert_eq!(more.len(), 1);
        assert_eq!(more[0].inv, a);
        assert_ne!(more[0].gpu, dead, "retry avoids the failed device");
        assert_eq!(more[0].attempt, 1);
        assert_eq!(
            more[0].start_kind,
            StartKind::Cold,
            "containers died with the device"
        );
        // Heal: the device takes placements again.
        p.heal_device(dead, 20 * SEC);
        assert_eq!(p.live_devices(), 2);
        let (_, ds) = p.on_arrival(FuncId(0), 20 * SEC);
        assert_eq!(ds.len(), 1);
        p.check_invariants().unwrap();
    }

    #[test]
    fn straggler_watchdog_evacuates_hung_attempts() {
        let cfg = PlaneConfig {
            faults: Some(FaultConfig {
                straggler_rate: 1.0,
                straggler_k: 2.0,
                max_faults: 1,
                ..Default::default()
            }),
            ..Default::default()
        };
        let mut p = plane(cfg);
        let (id, ds) = p.on_arrival(FuncId(0), 0);
        let d = ds[0];
        // The modeled completion is swallowed: the execution hangs.
        let (rec, more) = p.on_complete_attempt(id, 0, d.complete_at);
        assert!(rec.is_none() && more.is_empty());
        assert_eq!(p.in_flight(), 1, "hung attempt keeps its slot burned");
        // Before the k× deadline the watchdog leaves it alone.
        p.on_monitor_tick(d.exec_start + d.exec);
        assert_eq!(p.in_flight(), 1);
        // Past the deadline it evacuates and the retry dispatches.
        let retry = p.on_monitor_tick(d.exec_start + 3 * d.exec);
        assert_eq!(p.fault_stats().faults_straggler, 1);
        assert_eq!(retry.len(), 1);
        assert_eq!(retry[0].inv, id);
        assert_eq!(retry[0].attempt, 1);
        let (rec, _) = p.on_complete_attempt(id, 1, retry[0].complete_at);
        assert!(rec.is_some());
        p.check_invariants().unwrap();
    }

    #[test]
    fn breaker_quarantines_poison_then_probes_recover() {
        let cfg = PlaneConfig {
            d: 4,
            faults: Some(FaultConfig {
                poison: vec![(FuncId(0), 1.0)],
                max_faults: 2,
                retry_budget: 1,
                breaker: Some(BreakerConfig {
                    window: 8,
                    trip_threshold: 0.5,
                    min_samples: 2,
                    cooldown: SEC,
                    probes: 1,
                }),
                ..Default::default()
            }),
            ..Default::default()
        };
        let mut p = plane(cfg);
        assert!(p.try_admit(FuncId(0), 0).is_ok(), "closed breaker admits");
        let mut last = 0;
        for t in 0..2u64 {
            let (id, ds) = p.on_arrival(FuncId(0), t);
            let d = *ds.iter().find(|d| d.inv == id).unwrap();
            p.on_complete_attempt(id, 0, d.complete_at);
            last = last.max(d.complete_at);
        }
        assert_eq!(p.fault_stats().breaker_trips, 1);
        assert_eq!(p.drain_fault_fates().len(), 2, "budget 1: both terminal");
        assert!(matches!(
            p.try_admit(FuncId(0), last),
            Err(AdmitError::Quarantined { .. })
        ));
        assert_eq!(p.fault_stats().quarantined, 1);
        // Other functions are unaffected.
        assert!(p.try_admit(FuncId(1), last).is_ok());
        // Cooldown elapsed: one half-open probe slot.
        assert!(p.try_admit(FuncId(0), last + 2 * SEC).is_ok());
        assert_eq!(p.fault_stats().breaker_probes, 1);
        assert!(
            matches!(
                p.try_admit(FuncId(0), last + 2 * SEC),
                Err(AdmitError::Quarantined { .. })
            ),
            "probe slots bounded"
        );
        // The probe runs clean (fault cap spent) and closes the breaker.
        let (id, ds) = p.on_arrival(FuncId(0), last + 2 * SEC);
        let d = *ds.iter().find(|d| d.inv == id).unwrap();
        let (rec, _) = p.on_complete_attempt(id, 0, d.complete_at);
        assert!(rec.is_some());
        assert!(
            p.try_admit(FuncId(0), d.complete_at).is_ok(),
            "breaker closed after the probe success"
        );
        p.check_invariants().unwrap();
    }

    #[test]
    fn overload_shedding_rejects_with_hysteresis() {
        let cfg = PlaneConfig {
            d: 1,
            faults: Some(FaultConfig {
                shed: Some(ShedConfig {
                    deadline_s: 2.0,
                    enter: 1.0,
                    exit: 0.25,
                    retry_after_ms: 123,
                }),
                ..Default::default()
            }),
            ..Default::default()
        };
        let mut p = plane(cfg);
        assert!(p.try_admit(FuncId(0), 0).is_ok(), "idle plane admits");
        let mut head = None;
        for t in 0..4 {
            let (_, ds) = p.on_arrival(FuncId(0), t);
            if let Some(d) = ds.first() {
                head = Some(*d);
            }
        }
        // Backlog of 4 × ~1 s against one slot ≫ the 2 s deadline.
        match p.try_admit(FuncId(0), 5) {
            Err(AdmitError::Overloaded { retry_after_ms }) => assert_eq!(retry_after_ms, 123),
            other => panic!("expected shed, got {other:?}"),
        }
        assert_eq!(p.fault_stats().shed, 1);
        // Drain the backlog; below the exit bound admission resumes.
        let mut d = head.unwrap();
        loop {
            let (_, more) = p.on_complete(d.inv, d.complete_at);
            match more.first() {
                Some(n) => d = *n,
                None => break,
            }
        }
        assert_eq!(p.in_flight(), 0);
        assert!(p.try_admit(FuncId(0), 60 * SEC).is_ok());
        assert_eq!(p.fault_stats().shed, 1);
    }

    #[test]
    fn neutral_fault_plan_is_bit_identical_to_none() {
        let run = |faults: Option<FaultConfig>| {
            let mut p = plane(PlaneConfig {
                faults,
                ..Default::default()
            });
            let mut log = Vec::new();
            let mut due: Vec<Dispatch> = Vec::new();
            let mut push =
                |log: &mut Vec<(InvocationId, GpuId, Nanos, Nanos, u32)>, ds: &[Dispatch]| {
                    log.extend(ds.iter().map(|d| (d.inv, d.gpu, d.at, d.complete_at, d.attempt)));
                };
            for t in 0..20u64 {
                let now = t * 100 * MS;
                assert!(p.try_admit(FuncId((t % 2) as u32), now).is_ok());
                let (_, ds) = p.on_arrival(FuncId((t % 2) as u32), now);
                push(&mut log, &ds);
                due.extend(ds);
                let tick = p.on_monitor_tick(now + 50 * MS);
                push(&mut log, &tick);
                due.extend(tick);
                due.sort_by_key(|d| d.complete_at);
                while let Some(d) = due.first().copied() {
                    if d.complete_at > now {
                        break;
                    }
                    due.remove(0);
                    let (_, more) = p.on_complete(d.inv, d.complete_at);
                    push(&mut log, &more);
                    due.extend(more);
                    due.sort_by_key(|d| d.complete_at);
                }
            }
            assert!(p.drain_fault_fates().is_empty());
            log
        };
        let bare = run(None);
        let neutral = run(Some(FaultConfig::default()));
        assert!(!bare.is_empty());
        assert_eq!(bare, neutral, "neutral plan must not perturb dispatch");
    }

    #[test]
    fn dynamic_d_reacts_to_utilization() {
        let cfg = PlaneConfig {
            dynamic_d: Some((4, 0.9)),
            ..Default::default()
        };
        let mut p = plane(cfg);
        let d0 = p.current_d();
        // Saturate the device, then tick repeatedly.
        for i in 0..8 {
            p.on_arrival(FuncId(1), i);
        }
        for t in 1..6 {
            p.on_monitor_tick(t * 200 * MS);
        }
        assert!(p.current_d() <= d0);
    }
}
