//! Minimal CSV writer (results/ artifacts for every experiment).

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::Path;

/// A CSV file under construction; commas/quotes in cells are escaped.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    /// Create (truncating) `path`, writing `header` as the first row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        let mut w = Self {
            out: BufWriter::new(File::create(path)?),
            cols: header.len(),
        };
        w.row(header)?;
        Ok(w)
    }

    /// Write a row of string cells.
    pub fn row(&mut self, cells: &[&str]) -> std::io::Result<()> {
        assert_eq!(cells.len(), self.cols, "column count mismatch");
        let mut line = String::new();
        for (i, cell) in cells.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            if cell.contains([',', '"', '\n']) {
                line.push('"');
                line.push_str(&cell.replace('"', "\"\""));
                line.push('"');
            } else {
                line.push_str(cell);
            }
        }
        line.push('\n');
        self.out.write_all(line.as_bytes())
    }

    /// Write a row of mixed display values.
    pub fn rowv(&mut self, cells: &[String]) -> std::io::Result<()> {
        let refs: Vec<&str> = cells.iter().map(|s| s.as_str()).collect();
        self.row(&refs)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_escapes() {
        let dir = std::env::temp_dir().join("mqfq_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["1", "plain"]).unwrap();
            w.row(&["2", "has,comma"]).unwrap();
            w.row(&["3", "has\"quote"]).unwrap();
            w.flush().unwrap();
        }
        let got = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            got,
            "a,b\n1,plain\n2,\"has,comma\"\n3,\"has\"\"quote\"\n"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn rejects_bad_row() {
        let dir = std::env::temp_dir().join("mqfq_csv_test2");
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        let _ = w.row(&["only-one"]);
    }
}
