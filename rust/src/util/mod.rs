//! Small self-contained utilities (no external deps — offline build).

pub mod bench;
pub mod csv;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
