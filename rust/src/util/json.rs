//! Minimal JSON writer (serde is not in the offline vendor set) — used
//! for machine-readable benchmark artifacts like `BENCH_perf.json` so
//! the perf trajectory can be tracked across PRs.

use std::fmt::Write as _;
use std::path::Path;

/// A JSON value under construction. Numbers are split into integer and
/// float variants so counters render without a fractional part.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    /// Render with 2-space indentation and a trailing newline.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out.push('\n');
        out
    }

    /// Single-line rendering, no trailing newline — the JSON-lines wire
    /// framing ([`crate::api::wire`]) needs exactly one document per
    /// line (string escapes keep embedded newlines off the wire).
    pub fn render_compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    /// Writer-based [`Self::render_compact`]: append the single-line
    /// rendering to `out` instead of allocating a fresh `String`. The
    /// serving wire loop renders every reply through this into a
    /// per-connection buffer, so steady-state responses reuse one
    /// allocation instead of churning one per message.
    pub fn render_compact_into(&self, out: &mut String) {
        self.write_compact(out);
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null | Json::Bool(_) | Json::Int(_) | Json::Num(_) | Json::Str(_) => {
                self.write(out, 0)
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => write_f64(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                if xs.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent + 1);
                    x.write(out, indent + 1);
                }
                newline_indent(out, indent);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                newline_indent(out, indent);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: usize) {
    out.push('\n');
    for _ in 0..indent {
        out.push_str("  ");
    }
}

/// Append `x` using the JSON number rules shared by [`Json::Num`]
/// rendering and the direct response writers in [`crate::api::wire`]
/// (JSON has no NaN/Infinity literals — they render as `null`).
pub(crate) fn write_f64(out: &mut String, x: f64) {
    if x.is_finite() {
        let _ = write!(out, "{x}");
    } else {
        out.push_str("null");
    }
}

/// Append `s` as a quoted, escaped JSON string. Shared with the direct
/// wire writers so their bytes match tree-based rendering exactly.
pub(crate) fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Write a rendered JSON document to `path`, creating parent dirs.
pub fn write_file<P: AsRef<Path>>(path: P, value: &Json) -> std::io::Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, value.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_documents() {
        let doc = Json::Obj(vec![
            ("name".into(), Json::str("perf")),
            ("ok".into(), Json::Bool(true)),
            ("events".into(), Json::Int(12000)),
            ("mean_ns".into(), Json::Num(1234.5)),
            (
                "rows".into(),
                Json::Arr(vec![Json::Int(1), Json::Int(2)]),
            ),
            ("empty".into(), Json::Arr(vec![])),
        ]);
        let got = doc.render();
        assert!(got.contains("\"name\": \"perf\""));
        assert!(got.contains("\"mean_ns\": 1234.5"));
        assert!(got.contains("\"events\": 12000"));
        assert!(got.contains("\"empty\": []"));
        assert!(got.ends_with("}\n"));
    }

    #[test]
    fn compact_rendering_is_one_line() {
        let doc = Json::Obj(vec![
            ("a".into(), Json::Int(1)),
            ("b".into(), Json::Arr(vec![Json::str("x\ny"), Json::Null])),
            ("c".into(), Json::Obj(vec![])),
        ]);
        let got = doc.render_compact();
        assert_eq!(got, "{\"a\":1,\"b\":[\"x\\ny\",null],\"c\":{}}");
        assert!(!got.contains('\n'));
    }

    #[test]
    fn render_compact_into_appends_and_reuses_the_buffer() {
        let doc = Json::Obj(vec![("a".into(), Json::Int(1))]);
        let mut out = String::with_capacity(64);
        doc.render_compact_into(&mut out);
        assert_eq!(out, "{\"a\":1}");
        let cap = out.capacity();
        for _ in 0..100 {
            out.clear();
            doc.render_compact_into(&mut out);
        }
        assert_eq!(out, "{\"a\":1}");
        // Steady state: the warmed buffer is never regrown.
        assert_eq!(out.capacity(), cap);
    }

    #[test]
    fn escapes_strings_and_maps_non_finite_to_null() {
        let doc = Json::Obj(vec![
            ("s".into(), Json::str("a\"b\\c\nd")),
            ("nan".into(), Json::Num(f64::NAN)),
        ]);
        let got = doc.render();
        assert!(got.contains(r#""a\"b\\c\nd""#));
        assert!(got.contains("\"nan\": null"));
    }

    #[test]
    fn write_file_creates_parents() {
        let dir = std::env::temp_dir().join("mqfq_json_test");
        let path = dir.join("sub").join("x.json");
        write_file(&path, &Json::Obj(vec![("a".into(), Json::Int(1))])).unwrap();
        let got = std::fs::read_to_string(&path).unwrap();
        assert!(got.contains("\"a\": 1"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
