//! Deterministic PRNGs: SplitMix64 (twin of python/compile/gen.py) and
//! xoshiro256** for workload generation, plus the distributions the
//! trace generators need (uniform, exponential, zipf, log-normal, pareto).

/// SplitMix64 — keep bit-for-bit in sync with `python/compile/gen.py`.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// f32 in [0, 1) — exactly `((u >> 40) as f32) / 2^24` like the python twin.
    #[inline]
    pub fn next_unit_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u32 << 24) as f32
    }
}

/// FNV-1a 64 of a name — twin of `gen.fnv1a` (per-function input seeds).
pub fn fnv1a(name: &str) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// xoshiro256** — fast, high-quality generator for workload synthesis.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Seed the state from SplitMix64 per the xoshiro reference.
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Exponential with the given mean (inter-arrival times).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given log-scale parameters.
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pareto (heavy tail) with scale `xm` and shape `alpha`.
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        let u = 1.0 - self.f64();
        xm / u.powf(1.0 / alpha)
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Zipf-distributed popularity ranks: weight(rank k) ∝ 1 / k^s.
/// Returns normalized weights for `n` ranks (rank 1 most popular).
pub fn zipf_weights(n: usize, s: f64) -> Vec<f64> {
    let mut w: Vec<f64> = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).collect();
    let sum: f64 = w.iter().sum();
    for x in &mut w {
        *x /= sum;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Known-answer vectors shared with python/tests/test_gen.py.
    #[test]
    fn splitmix64_twin_of_python() {
        let mut r = SplitMix64::new(1);
        assert_eq!(r.next_u64(), 0x910A_2DEC_8902_5CC1);
        assert_eq!(r.next_u64(), 0xBEEB_8DA1_658E_EC67);
        assert_eq!(r.next_u64(), 0xF893_A2EE_FB32_555E);
        assert_eq!(r.next_u64(), 0x71C1_8690_EE42_C90B);
    }

    #[test]
    fn unit_f32_twin_of_python() {
        let mut r = SplitMix64::new(42);
        let got: Vec<f32> = (0..4).map(|_| r.next_unit_f32()).collect();
        let want = [0.741_564_87, 0.159_910_38, 0.278_601_1, 0.344_190_66];
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-7, "{g} vs {w}");
        }
    }

    #[test]
    fn fnv1a_twin_of_python() {
        assert_eq!(fnv1a("imagenet"), 0x2EA4_3BCC_8F83_E79D);
    }

    #[test]
    fn rng_uniform_moments() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.5).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn zipf_weights_normalized_and_monotone() {
        let w = zipf_weights(24, 1.5);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        for i in 1..w.len() {
            assert!(w[i] < w[i - 1]);
        }
    }

    #[test]
    fn pareto_is_heavy_tailed() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..50_000).map(|_| r.pareto(1.0, 1.2)).collect();
        let max = xs.iter().cloned().fold(f64::MIN, f64::max);
        assert!(max > 100.0, "pareto tail too light: max {max}");
        assert!(xs.iter().all(|&x| x >= 1.0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
