//! Minimal property-testing framework (proptest is not in the offline
//! vendor set). Provides seeded case generation with failure reporting
//! and a shrink-lite loop: on failure, the failing seed is re-run with
//! progressively "smaller" size hints to find a more minimal case.
//!
//! Used by `rust/tests/prop_scheduler.rs` for the coordinator invariants
//! (fairness bound, token conservation, memory-ledger safety, ...).

use crate::util::rng::Rng;

/// Context handed to each property case: a seeded RNG plus a size hint
/// the generator should respect (smaller size => simpler case).
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Self {
            rng: Rng::new(seed),
            size,
        }
    }

    /// Integer in [lo, hi], biased toward the low end as size shrinks.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = (hi - lo + 1).min(self.size.max(1));
        lo + self.rng.below(span)
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range(lo, hi)
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.rng.f64() < p_true
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Outcome of a property check over many cases.
#[derive(Debug)]
pub struct PropResult {
    pub cases: usize,
    pub failure: Option<String>,
}

/// Run `check` over `cases` generated cases. `check` returns
/// `Err(description)` on a violated property. On failure we retry the
/// same seed with smaller sizes to report the smallest reproduction.
pub fn run_prop<F>(name: &str, cases: usize, mut check: F) -> PropResult
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    const BASE_SEED: u64 = 0x5EED_0000;
    for case in 0..cases {
        let seed = BASE_SEED + case as u64;
        let mut g = Gen::new(seed, 64);
        if let Err(msg) = check(&mut g) {
            // Shrink-lite: re-run the same seed at smaller sizes.
            let mut best = (64usize, msg);
            for size in [32usize, 16, 8, 4, 2].iter() {
                let mut g = Gen::new(seed, *size);
                if let Err(msg) = check(&mut g) {
                    best = (*size, msg);
                }
            }
            return PropResult {
                cases: case + 1,
                failure: Some(format!(
                    "property '{name}' failed (seed={seed:#x}, size={}): {}",
                    best.0, best.1
                )),
            };
        }
    }
    PropResult {
        cases,
        failure: None,
    }
}

/// Assert wrapper: panics with the failure report.
pub fn assert_prop<F>(name: &str, cases: usize, check: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let r = run_prop(name, cases, check);
    if let Some(f) = r.failure {
        panic!("{f}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let r = run_prop("add-commutes", 100, |g| {
            let a = g.int(0, 1000) as u64;
            let b = g.int(0, 1000) as u64;
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
        assert!(r.failure.is_none());
        assert_eq!(r.cases, 100);
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = run_prop("always-small", 100, |g| {
            let x = g.int(0, 100);
            if x < 5 {
                Ok(())
            } else {
                Err(format!("x={x}"))
            }
        });
        let f = r.failure.expect("should fail");
        assert!(f.contains("seed="), "{f}");
    }

    #[test]
    fn gen_int_respects_bounds() {
        let mut g = Gen::new(1, 64);
        for _ in 0..1000 {
            let x = g.int(3, 9);
            assert!((3..=9).contains(&x));
        }
    }
}
