//! Streaming and batch statistics used by the metrics stack.

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n;
        self.mean += d * other.n as f64 / n;
        self.n += other.n;
    }
}

/// Percentile of a sample (linear interpolation, p in [0, 100]).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Sort a copy and take percentiles; convenience for report code.
pub fn percentiles(xs: &[f64], ps: &[f64]) -> Vec<f64> {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ps.iter().map(|&p| percentile(&sorted, p)).collect()
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Exponential moving average with configurable smoothing.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> f64 {
        self.value.unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.variance() - variance(&xs)).abs() < 1e-12);
    }

    #[test]
    fn welford_merge_matches_single_stream() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut a = Welford::new();
        let mut b = Welford::new();
        let mut all = Welford::new();
        for (i, &x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.push(x);
            } else {
                b.push(x);
            }
            all.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_empty_and_single() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..64 {
            e.push(10.0);
        }
        assert!((e.get() - 10.0).abs() < 1e-6);
    }
}
