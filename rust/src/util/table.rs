//! Fixed-width text tables — the benches print paper-style rows with these.

/// Accumulates rows and renders an aligned ASCII table.
#[derive(Debug, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                let cell = &cells[i];
                // Right-align numeric-looking cells, left-align text.
                let numeric = cell
                    .chars()
                    .all(|c| c.is_ascii_digit() || ".-+eE%x".contains(c))
                    && !cell.is_empty();
                if numeric {
                    line.push_str(&format!("{:>w$}", cell, w = widths[i]));
                } else {
                    line.push_str(&format!("{:<w$}", cell, w = widths[i]));
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Format seconds with adaptive precision (µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "lat"]);
        t.row(&["imagenet".into(), "2.253".into()]);
        t.row(&["fft".into(), "0.897".into()]);
        let s = t.render();
        assert!(s.contains("imagenet"));
        assert!(s.lines().count() == 4);
        // header, separator, two rows — all same width
        let lens: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert_eq!(lens[0], lens[2]);
    }

    #[test]
    fn fmt_secs_scales() {
        assert_eq!(fmt_secs(0.0000005), "0.5us");
        assert_eq!(fmt_secs(0.005), "5.00ms");
        assert_eq!(fmt_secs(2.5), "2.500s");
    }
}
