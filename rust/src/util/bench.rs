//! Tiny benchmark timer used by the `harness = false` bench binaries
//! (criterion is not in the offline vendor set).

use std::time::Instant;

/// Result of one timed measurement series.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10} iters  mean {:>12}  min {:>12}  max {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.min_ns),
            fmt_ns(self.max_ns)
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Time `f` adaptively: warm up, then run batches until ~`budget_ms` of
/// wall time or `max_iters` is reached. Returns per-iteration stats.
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchResult {
    // Warmup.
    for _ in 0..3 {
        f();
    }
    let budget = std::time::Duration::from_millis(budget_ms);
    let start = Instant::now();
    let mut iters: u64 = 0;
    let mut min = f64::MAX;
    let mut max: f64 = 0.0;
    let mut total: f64 = 0.0;
    while start.elapsed() < budget && iters < 1_000_000 {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_nanos() as f64;
        min = min.min(dt);
        max = max.max(dt);
        total += dt;
        iters += 1;
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: total / iters.max(1) as f64,
        min_ns: if iters == 0 { 0.0 } else { min },
        max_ns: max,
    }
}

/// Prevent the optimizer from eliding a value (std::hint::black_box shim).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = bench("noop", 10, || {
            black_box(1 + 1);
        });
        assert!(r.iters > 0);
        assert!(r.mean_ns >= 0.0);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.200s");
    }
}
