//! `mqfq-sticky` — leader binary: experiments, trace tooling, replay,
//! real-time serving, artifact validation. See `mqfq-sticky help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(mqfq::cli::run(argv));
}
