//! Core identifier and time types shared across the control plane.

use std::fmt;

/// Virtual or wall-clock time in nanoseconds since experiment start.
pub type Nanos = u64;

/// Duration in nanoseconds.
pub type DurNanos = u64;

/// One second in [`Nanos`].
pub const SEC: Nanos = 1_000_000_000;
/// One millisecond in [`Nanos`].
pub const MS: Nanos = 1_000_000;
/// One microsecond in [`Nanos`].
pub const US: Nanos = 1_000;

/// Convert seconds (f64) to nanoseconds, saturating at zero.
#[inline]
pub fn secs(s: f64) -> Nanos {
    if s <= 0.0 {
        0
    } else {
        (s * 1e9).round() as Nanos
    }
}

/// Convert nanoseconds to seconds (f64).
#[inline]
pub fn to_secs(ns: Nanos) -> f64 {
    ns as f64 / 1e9
}

/// Index into the registered function catalog for one workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FuncId(pub u32);

impl fmt::Display for FuncId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "f{}", self.0)
    }
}

/// Unique id of a single invocation (request).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct InvocationId(pub u64);

impl fmt::Display for InvocationId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "inv{}", self.0)
    }
}

/// Physical (or MIG-virtual) GPU identifier on one server.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GpuId(pub u32);

impl fmt::Display for GpuId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "gpu{}", self.0)
    }
}

/// Identifier of a container instance in the warm pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ContainerId(pub u64);

impl fmt::Display for ContainerId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ctr{}", self.0)
    }
}

/// How an invocation's sandbox was provisioned — the paper's three start
/// classes (§4.3) plus the CPU paths used for Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StartKind {
    /// Container existed and its memory was resident on device.
    GpuWarm,
    /// Container existed but its device regions were swapped to host
    /// ("GPU-cold but host-warm", §4.3).
    HostWarm,
    /// Full sandbox creation: docker + nvidia hook + user code init.
    Cold,
}

impl fmt::Display for StartKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            StartKind::GpuWarm => "gpu-warm",
            StartKind::HostWarm => "host-warm",
            StartKind::Cold => "cold",
        };
        f.write_str(s)
    }
}

impl StartKind {
    /// Inverse of `Display` — wire-protocol decode ([`crate::api`]).
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "gpu-warm" => StartKind::GpuWarm,
            "host-warm" => StartKind::HostWarm,
            "cold" => StartKind::Cold,
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn secs_roundtrip() {
        assert_eq!(secs(1.0), SEC);
        assert_eq!(secs(0.0), 0);
        assert_eq!(secs(-3.0), 0);
        assert!((to_secs(secs(2.253)) - 2.253).abs() < 1e-9);
    }

    #[test]
    fn display_forms() {
        assert_eq!(FuncId(3).to_string(), "f3");
        assert_eq!(InvocationId(9).to_string(), "inv9");
        assert_eq!(GpuId(0).to_string(), "gpu0");
        assert_eq!(ContainerId(1).to_string(), "ctr1");
        assert_eq!(StartKind::HostWarm.to_string(), "host-warm");
    }

    #[test]
    fn start_kind_parse_is_display_inverse() {
        for k in [StartKind::GpuWarm, StartKind::HostWarm, StartKind::Cold] {
            assert_eq!(StartKind::parse(&k.to_string()), Some(k));
        }
        assert_eq!(StartKind::parse("lukewarm"), None);
    }
}
