//! Integrated memory management (§4.3, §5.2) — the four Fig-4 policies.
//!
//! Queue states drive memory movement: queues becoming *active* have
//! their containers' CUDA regions prefetched onto the device in
//! anticipation of use; *throttled/inactive* queues have their regions
//! marked and asynchronously swapped back to host memory (LRU order).
//!
//! Policies (Figure 4):
//! * [`MemPolicy::StockUvm`] — no placement control; every non-resident
//!   page faults in on demand during kernel execution (+40% exec).
//! * [`MemPolicy::Madvise`] — stock UVM + cuMemAdvise directives, which
//!   cost driver time and move nothing ("slightly worse", Fig 4).
//! * [`MemPolicy::PrefetchOnly`] — async `cuMemPrefetchAsync` on queue
//!   activation, but no proactive swap-out: under pressure the prefetch
//!   stalls on the UVM driver reclaiming other containers' pages.
//! * [`MemPolicy::PrefetchSwap`] — the paper's default: async prefetch
//!   *and* async swap-out of deactivated queues, so prefetch finds free
//!   space and execution is GPU-warm.

use crate::container::ContainerPool;
use crate::gpu::DevicePool;
use crate::shim;
use crate::types::{ContainerId, DurNanos, FuncId, Nanos, MS};

/// Memory management policy under evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemPolicy {
    StockUvm,
    Madvise,
    PrefetchOnly,
    PrefetchSwap,
}

impl MemPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            MemPolicy::StockUvm => "stock-uvm",
            MemPolicy::Madvise => "madvise",
            MemPolicy::PrefetchOnly => "prefetch-only",
            MemPolicy::PrefetchSwap => "prefetch+swap",
        }
    }

    pub fn prefetches(&self) -> bool {
        matches!(self, MemPolicy::PrefetchOnly | MemPolicy::PrefetchSwap)
    }
}

/// Per-dispatch memory cost: the Fig-4 "in-shim" time.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemCost {
    /// Time spent blocked before the kernel can start (remaining
    /// prefetch, synchronous eviction, madvise directives).
    pub blocking: DurNanos,
    /// Extra execution time from on-demand page faults during the run.
    pub fault: DurNanos,
}

impl MemCost {
    pub fn total(&self) -> DurNanos {
        self.blocking + self.fault
    }
}

/// The memory manager: applies the policy over the container pool and
/// device ledgers. Stateless besides configuration; all state lives in
/// the container ledgers and device resident counters.
#[derive(Debug, Clone)]
pub struct MemoryManager {
    pub policy: MemPolicy,
    /// Control-plane marshaling time that async prefetch overlaps with
    /// ("overlap prefetching with the control plane marshaling
    /// invocation arguments", §5.2).
    pub marshal_ns: DurNanos,
}

impl MemoryManager {
    pub fn new(policy: MemPolicy) -> Self {
        Self {
            policy,
            // Ilúvatar-class control planes add single-digit ms per
            // invocation (§5: "lower overheads … without additional
            // system noise").
            marshal_ns: 3 * MS,
        }
    }

    /// Background maintenance (monitor tick): under PrefetchSwap, keep
    /// device pressure below the watermark by asynchronously swapping
    /// out marked-for-eviction (then LRU idle) containers — "eviction
    /// done asynchronously using LRU order" (§4.3). This is what makes
    /// later prefetches find free space instead of paying synchronous
    /// reclaim on the critical path.
    pub fn maintain(
        &self,
        ctrs: &mut ContainerPool,
        gpus: &mut DevicePool,
        now: Nanos,
    ) {
        if self.policy != MemPolicy::PrefetchSwap {
            return;
        }
        const WATERMARK: f64 = 0.85;
        for gi in 0..gpus.len() {
            let gpu = gpus.devices()[gi].id;
            let vram = gpus.device(gpu).vram_mb;
            let target = (vram as f64 * WATERMARK) as u64;
            if gpus.device(gpu).resident_mb() <= target {
                continue;
            }
            let mut need = gpus.device(gpu).resident_mb() - target;
            // Victims: marked first, then LRU idle.
            let mut victims: Vec<(bool, Nanos, ContainerId)> = ctrs
                .iter()
                .filter(|c| {
                    c.gpu == gpu
                        && c.resident_mb() > 0
                        && c.state != crate::container::CtrState::Busy
                        && c.prefetch_done.map(|t| t <= now).unwrap_or(true)
                })
                .map(|c| (!c.marked_evict, c.last_used, c.id))
                .collect();
            victims.sort_unstable();
            for (unmarked, _, id) in victims {
                if need == 0 {
                    break;
                }
                // Only unmarked containers are swapped under pressure;
                // marked ones always go.
                if unmarked && gpus.device(gpu).resident_mb() <= target {
                    break;
                }
                let c = ctrs.get_mut(id).unwrap();
                let moved = c.ledger.page_out(need);
                c.prefetch_done = None;
                need = need.saturating_sub(moved);
                gpus.device_mut(gpu).sub_resident(moved);
            }
        }
    }

    /// Queue became active: prefetch its idle containers' regions
    /// (Prefetch* policies), clearing any eviction marks.
    pub fn on_queue_active(
        &self,
        func: FuncId,
        ctrs: &mut ContainerPool,
        gpus: &mut DevicePool,
        now: Nanos,
    ) {
        ctrs.unmark_evict(func);
        if !self.policy.prefetches() {
            return;
        }
        let ids: Vec<ContainerId> = ctrs
            .iter()
            .filter(|c| c.func == func && c.state != crate::container::CtrState::Busy)
            .map(|c| c.id)
            .collect();
        for id in ids {
            self.start_prefetch(id, ctrs, gpus, now);
        }
    }

    /// Queue throttled or expired: mark containers for eviction; under
    /// PrefetchSwap also swap their regions out asynchronously (§4.3).
    pub fn on_queue_deactivate(
        &self,
        func: FuncId,
        ctrs: &mut ContainerPool,
        gpus: &mut DevicePool,
        _now: Nanos,
    ) {
        ctrs.mark_evict(func);
        if self.policy != MemPolicy::PrefetchSwap {
            return;
        }
        let ids: Vec<ContainerId> = ctrs
            .iter()
            .filter(|c| {
                c.func == func
                    && c.state != crate::container::CtrState::Busy
                    && c.resident_mb() > 0
            })
            .map(|c| c.id)
            .collect();
        for id in ids {
            let c = ctrs.get_mut(id).unwrap();
            let gpu = c.gpu;
            let moved = c.ledger.evict_all();
            c.prefetch_done = None;
            gpus.device_mut(gpu).sub_resident(moved);
        }
    }

    /// Start (or restart) an async prefetch of a container's regions.
    /// Updates ledgers immediately (space is reserved) and records the
    /// completion timestamp on the container.
    fn start_prefetch(
        &self,
        id: ContainerId,
        ctrs: &mut ContainerPool,
        gpus: &mut DevicePool,
        now: Nanos,
    ) {
        let (gpu, needed) = {
            let c = ctrs.get(id).unwrap();
            (c.gpu, c.ledger.nonresident_mb())
        };
        if needed == 0 {
            return;
        }
        let profile = gpus.device(gpu).profile;
        // Make room first. Under PrefetchSwap deactivated queues usually
        // swapped out already (free), so this mostly no-ops; under
        // PrefetchOnly the UVM driver must reclaim pages — slower, and
        // the stall serializes with the prefetch itself.
        let free = gpus.device(gpu).free_mb();
        let overage = needed.saturating_sub(free);
        let reclaim_ns = if overage > 0 {
            let directed = self.policy == MemPolicy::PrefetchSwap;
            let freed = evict_lru(overage, id, ctrs, gpus, now, !directed);
            if directed {
                // Directed swap-out rides PCIe at full bandwidth.
                shim::prefetch_time(freed, &profile)
            } else {
                // UVM reclaim: driver-paced page-out, slower.
                shim::fault_time(freed, &profile)
            }
        } else {
            0
        };
        let xfer_ns = shim::prefetch_time(needed, &profile);
        // Eviction and the inbound copy pipeline on the copy engines;
        // the prefetch completes when the slower leg does.
        let total_ns = reclaim_ns.max(xfer_ns);
        let c = ctrs.get_mut(id).unwrap();
        let moved = c.ledger.page_in(needed);
        c.prefetch_done = Some(now + total_ns);
        gpus.device_mut(gpu).add_resident(moved);
    }

    /// Compute the memory cost of executing in container `id` now.
    /// `overlap` is time that elapses before the kernel could start
    /// anyway (cold boot), which async transfers hide behind.
    pub fn before_exec(
        &self,
        id: ContainerId,
        ctrs: &mut ContainerPool,
        gpus: &mut DevicePool,
        now: Nanos,
        overlap: DurNanos,
    ) -> MemCost {
        let (gpu, needed, prefetch_done) = {
            let c = ctrs.get(id).unwrap();
            (c.gpu, c.ledger.nonresident_mb(), c.prefetch_done)
        };
        let profile = gpus.device(gpu).profile;
        match self.policy {
            MemPolicy::StockUvm | MemPolicy::Madvise => {
                // Pages fault in on demand during execution. If the
                // device is oversubscribed the fault handler also pages
                // out victims, amplifying the stall (thrash factor).
                let free = gpus.device(gpu).free_mb();
                let overage = needed.saturating_sub(free);
                if overage > 0 {
                    // UVM reclaims transparently: page-granularity
                    // global LRU spreads the loss across containers.
                    evict_lru(overage, id, ctrs, gpus, now, true);
                }
                let pressure_after = {
                    let d = gpus.device(gpu);
                    (d.resident_mb() + needed) as f64 / d.vram_mb as f64
                };
                let thrash = 1.0 + 2.0 * (pressure_after - 1.0).max(0.0);
                let fault = (shim::fault_time(needed, &profile) as f64 * thrash) as DurNanos;
                let c = ctrs.get_mut(id).unwrap();
                let moved = c.ledger.page_in(needed);
                gpus.device_mut(gpu).add_resident(moved);
                let blocking = self.marshal_ns
                    + if self.policy == MemPolicy::Madvise {
                        shim::madvise_overhead(c.footprint_mb())
                    } else {
                        0
                    };
                MemCost { blocking, fault }
            }
            MemPolicy::PrefetchOnly | MemPolicy::PrefetchSwap => {
                // Ensure a prefetch is in flight (queue activation should
                // have started one; cold containers start here).
                if needed > 0 && prefetch_done.is_none() {
                    self.start_prefetch(id, ctrs, gpus, now);
                }
                let done = ctrs.get(id).unwrap().prefetch_done.unwrap_or(now);
                // Marshaling and the remaining transfer run concurrently
                // (§5.2: prefetch overlaps with argument marshaling); a
                // cold boot (`overlap`) hides the transfer too. The
                // kernel starts when the slowest of them finishes.
                let remaining = done.saturating_sub(now).saturating_sub(overlap);
                let blocking = self.marshal_ns.max(remaining);
                let c = ctrs.get_mut(id).unwrap();
                c.prefetch_done = None;
                MemCost {
                    blocking,
                    fault: 0,
                }
            }
        }
    }
}

/// Page out other containers' resident regions until `needed` MB are
/// freed on `protect`'s device (never touching `protect` itself or busy
/// containers). Returns MB actually freed.
///
/// * `proportional = true` models the UVM driver's page-granularity
///   global LRU: every victim loses a proportional slice of its resident
///   set, so at steady state each container keeps ~vram/total resident
///   (this is what keeps "stock UVM" at the paper's +40%, not +130%).
/// * `proportional = false` is the directed whole-container swap-out of
///   PrefetchSwap (marked victims first, then LRU).
fn evict_lru(
    needed: u64,
    protect: ContainerId,
    ctrs: &mut ContainerPool,
    gpus: &mut DevicePool,
    now: Nanos,
    proportional: bool,
) -> u64 {
    let gpu = ctrs.get(protect).unwrap().gpu;
    let mut victims: Vec<(bool, Nanos, ContainerId)> = ctrs
        .iter()
        .filter(|c| {
            c.id != protect
                && c.gpu == gpu
                && c.resident_mb() > 0
                && c.state != crate::container::CtrState::Busy
        })
        .map(|c| (!c.marked_evict, c.last_used, c.id))
        .collect();
    victims.sort_unstable();
    let mut freed = 0;
    if proportional && !victims.is_empty() {
        let total_resident: u64 = victims
            .iter()
            .map(|(_, _, id)| ctrs.get(*id).unwrap().resident_mb())
            .sum();
        if total_resident == 0 {
            return 0;
        }
        for (_, _, id) in &victims {
            let c = ctrs.get_mut(*id).unwrap();
            let share = (needed as f64 * c.resident_mb() as f64 / total_resident as f64)
                .ceil() as u64;
            let take = c.ledger.page_out(share.min(needed - freed));
            freed += take;
            gpus.device_mut(gpu).sub_resident(take);
            if freed >= needed {
                break;
            }
        }
    }
    for (_, _, id) in victims {
        if freed >= needed {
            break;
        }
        let c = ctrs.get_mut(id).unwrap();
        let take = c.ledger.page_out(needed - freed);
        if c.is_idle(now) && c.resident_mb() == 0 {
            c.prefetch_done = None;
        }
        freed += take;
        gpus.device_mut(gpu).sub_resident(take);
    }
    freed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{DevicePool, MultiplexMode, V100};
    use crate::types::{GpuId, SEC};
    use crate::workload::catalog::by_name;

    fn setup() -> (ContainerPool, DevicePool, MemoryManager) {
        (
            ContainerPool::new(32),
            DevicePool::uniform(1, V100, MultiplexMode::Plain),
            MemoryManager::new(MemPolicy::PrefetchSwap),
        )
    }

    fn acquire_release(
        ctrs: &mut ContainerPool,
        func: u32,
        now: Nanos,
    ) -> ContainerId {
        let class = by_name("fft").unwrap();
        let a = ctrs
            .acquire(crate::types::FuncId(func), class, GpuId(0), now)
            .unwrap();
        ctrs.release(a.id, now);
        a.id
    }

    #[test]
    fn prefetch_makes_container_gpu_warm() {
        let (mut ctrs, mut gpus, mm) = setup();
        let id = acquire_release(&mut ctrs, 0, 0);
        assert!(!ctrs.get(id).unwrap().gpu_warm());
        mm.on_queue_active(crate::types::FuncId(0), &mut ctrs, &mut gpus, SEC);
        assert!(ctrs.get(id).unwrap().gpu_warm());
        assert_eq!(gpus.device(GpuId(0)).resident_mb(), 1500);
        // Prefetch completion recorded for blocking computation.
        assert!(ctrs.get(id).unwrap().prefetch_done.unwrap() > SEC);
    }

    #[test]
    fn prefetch_swap_deactivation_swaps_out() {
        let (mut ctrs, mut gpus, mm) = setup();
        let id = acquire_release(&mut ctrs, 0, 0);
        mm.on_queue_active(crate::types::FuncId(0), &mut ctrs, &mut gpus, SEC);
        mm.on_queue_deactivate(crate::types::FuncId(0), &mut ctrs, &mut gpus, 2 * SEC);
        assert_eq!(ctrs.get(id).unwrap().resident_mb(), 0);
        assert_eq!(gpus.device(GpuId(0)).resident_mb(), 0);
        assert!(ctrs.get(id).unwrap().marked_evict);
    }

    #[test]
    fn before_exec_blocks_only_on_remaining_transfer() {
        let (mut ctrs, mut gpus, mm) = setup();
        let id = acquire_release(&mut ctrs, 0, 0);
        mm.on_queue_active(crate::types::FuncId(0), &mut ctrs, &mut gpus, 0);
        // Long after the transfer finished: only the marshal floor.
        let cost = mm.before_exec(id, &mut ctrs, &mut gpus, 10 * SEC, 0);
        assert_eq!(cost.blocking, mm.marshal_ns);
        assert_eq!(cost.fault, 0);
    }

    #[test]
    fn before_exec_immediately_after_activation_blocks() {
        let (mut ctrs, mut gpus, mm) = setup();
        let id = acquire_release(&mut ctrs, 0, 0);
        mm.on_queue_active(crate::types::FuncId(0), &mut ctrs, &mut gpus, 0);
        // Dispatch at t=0: the 1.5 GB / 12 GB/s ≈ 122 ms transfer is
        // still in flight; marshal hides 25 ms of it.
        let cost = mm.before_exec(id, &mut ctrs, &mut gpus, 0, 0);
        let expect_remaining =
            shim::prefetch_time(1500, &V100) - mm.marshal_ns;
        assert_eq!(cost.blocking, mm.marshal_ns + expect_remaining);
    }

    #[test]
    fn stock_uvm_faults_during_exec() {
        let (mut ctrs, mut gpus, _) = setup();
        let mm = MemoryManager::new(MemPolicy::StockUvm);
        let id = acquire_release(&mut ctrs, 0, 0);
        let cost = mm.before_exec(id, &mut ctrs, &mut gpus, SEC, 0);
        assert_eq!(cost.fault, shim::fault_time(1500, &V100));
        assert!(ctrs.get(id).unwrap().gpu_warm());
    }

    #[test]
    fn madvise_adds_directive_overhead() {
        let (mut ctrs, mut gpus, _) = setup();
        let mm = MemoryManager::new(MemPolicy::Madvise);
        let id = acquire_release(&mut ctrs, 0, 0);
        let cost = mm.before_exec(id, &mut ctrs, &mut gpus, SEC, 0);
        assert!(cost.blocking > mm.marshal_ns);
        assert!(cost.fault > 0);
    }

    #[test]
    fn oversubscription_triggers_lru_reclaim() {
        let (mut ctrs, mut gpus, mm) = setup();
        // Fill the 16 GB device with 11 × 1.5 GB containers (16.5 GB).
        for f in 0..11 {
            let id = acquire_release(&mut ctrs, f, f as Nanos);
            mm.on_queue_active(crate::types::FuncId(f), &mut ctrs, &mut gpus, f as Nanos);
            // Some space must have been reclaimed from earlier (LRU)
            // containers once the device filled up.
            let _ = id;
        }
        let d = gpus.device(GpuId(0));
        assert!(d.resident_mb() <= d.vram_mb, "ledger overflow: {}", d.resident_mb());
    }

    #[test]
    fn cold_boot_overlap_hides_prefetch() {
        let (mut ctrs, mut gpus, mm) = setup();
        let class = by_name("fft").unwrap();
        let a = ctrs
            .acquire(crate::types::FuncId(0), class, GpuId(0), 0)
            .unwrap();
        // Cold boot (≈2.4 s) fully hides the 122 ms prefetch.
        let cost = mm.before_exec(a.id, &mut ctrs, &mut gpus, 0, a.boot_ns);
        assert_eq!(cost.blocking, mm.marshal_ns);
    }
}
