//! Deterministic input generation — Rust twin of `python/compile/gen.py`.
//!
//! The AOT pipeline computes golden outputs from inputs produced by
//! SplitMix64 streams seeded with `fnv1a(fn_name) + input_index`; this
//! module regenerates bit-identical f32 inputs so artifact validation
//! needs no binary tensor interchange. Keep in sync with gen.py.

use crate::util::rng::{fnv1a, SplitMix64};

/// Input value distribution, matching the manifest `unit` / `sym` kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// U[0, 1)
    Unit,
    /// U[-0.5, 0.5)
    Sym,
}

impl Kind {
    pub fn parse(s: &str) -> Option<Kind> {
        match s {
            "unit" => Some(Kind::Unit),
            "sym" => Some(Kind::Sym),
            _ => None,
        }
    }
}

/// Generate the full f32 buffer for one input tensor.
pub fn fill(seed: u64, len: usize, kind: Kind) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        let v = rng.next_unit_f32();
        out.push(match kind {
            Kind::Unit => v,
            Kind::Sym => v - 0.5,
        });
    }
    out
}

/// Seed for input `index` of function `name` (twin of aot.py's
/// `gen.fnv1a(name) + i`).
pub fn input_seed(name: &str, index: usize) -> u64 {
    fnv1a(name).wrapping_add(index as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_unit_matches_python_vectors() {
        // Same vector as python/tests/test_gen.py::test_fill_unit_known_answers
        let got = fill(42, 4, Kind::Unit);
        let want = [0.741_564_87_f32, 0.159_910_38, 0.278_601_1, 0.344_190_66];
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-7, "{g} vs {w}");
        }
    }

    #[test]
    fn sym_is_unit_minus_half() {
        let u = fill(7, 16, Kind::Unit);
        let s = fill(7, 16, Kind::Sym);
        for (a, b) in u.iter().zip(s.iter()) {
            assert_eq!(a - 0.5, *b);
        }
    }

    #[test]
    fn input_seed_offsets_by_index() {
        assert_eq!(input_seed("imagenet", 0), fnv1a("imagenet"));
        assert_eq!(input_seed("imagenet", 3), fnv1a("imagenet") + 3);
    }

    #[test]
    fn kind_parse() {
        assert_eq!(Kind::parse("unit"), Some(Kind::Unit));
        assert_eq!(Kind::parse("sym"), Some(Kind::Sym));
        assert_eq!(Kind::parse("weird"), None);
    }
}
