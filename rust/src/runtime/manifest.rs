//! Parser for `artifacts/manifest.txt` written by `python/compile/aot.py`.
//!
//! Format (plain text, one record per catalog function):
//! ```text
//! fn imagenet
//! in 8x256 sym
//! in 256x512 sym
//! out 0 8x256 l2=2.74148041e+00 first=0.0,6.0e-18,4.2e-06,1.8e-35
//! end
//! ```

use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

use super::goldgen::Kind;

/// Declared input tensor: shape + generation kind.
#[derive(Debug, Clone)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub kind: Kind,
}

impl InputSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Golden record for one output tensor.
#[derive(Debug, Clone)]
pub struct GoldenOutput {
    pub index: usize,
    pub shape: Vec<usize>,
    /// L2 norm of the flattened output (f64 accumulation on python side).
    pub l2: f64,
    /// First up-to-4 elements.
    pub first: Vec<f64>,
}

/// One catalog function's artifact contract.
#[derive(Debug, Clone)]
pub struct FunctionSpec {
    pub name: String,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<GoldenOutput>,
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    s.split('x')
        .map(|d| d.parse::<usize>().context("bad dim"))
        .collect()
}

/// Parse the manifest text into function specs (order preserved).
pub fn parse(text: &str) -> Result<Vec<FunctionSpec>> {
    let mut specs = Vec::new();
    let mut cur: Option<FunctionSpec> = None;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let tag = parts.next().unwrap();
        let ctx = || format!("manifest line {}: {raw}", lineno + 1);
        match tag {
            "fn" => {
                if cur.is_some() {
                    bail!("{}: nested fn", ctx());
                }
                let name = parts.next().ok_or_else(|| anyhow!("{}: no name", ctx()))?;
                cur = Some(FunctionSpec {
                    name: name.to_string(),
                    inputs: Vec::new(),
                    outputs: Vec::new(),
                });
            }
            "in" => {
                let spec = cur.as_mut().ok_or_else(|| anyhow!("{}: in outside fn", ctx()))?;
                let shape = parse_shape(parts.next().ok_or_else(|| anyhow!("{}: no shape", ctx()))?)?;
                let kind_s = parts.next().ok_or_else(|| anyhow!("{}: no kind", ctx()))?;
                let kind = Kind::parse(kind_s)
                    .ok_or_else(|| anyhow!("{}: bad kind {kind_s}", ctx()))?;
                spec.inputs.push(InputSpec { shape, kind });
            }
            "out" => {
                let spec = cur.as_mut().ok_or_else(|| anyhow!("{}: out outside fn", ctx()))?;
                let index: usize = parts
                    .next()
                    .ok_or_else(|| anyhow!("{}: no index", ctx()))?
                    .parse()?;
                let shape = parse_shape(parts.next().ok_or_else(|| anyhow!("{}: no shape", ctx()))?)?;
                let mut l2 = None;
                let mut first = Vec::new();
                for kv in parts {
                    if let Some(v) = kv.strip_prefix("l2=") {
                        l2 = Some(v.parse::<f64>()?);
                    } else if let Some(v) = kv.strip_prefix("first=") {
                        for x in v.split(',') {
                            first.push(x.parse::<f64>()?);
                        }
                    }
                }
                spec.outputs.push(GoldenOutput {
                    index,
                    shape,
                    l2: l2.ok_or_else(|| anyhow!("{}: missing l2", ctx()))?,
                    first,
                });
            }
            "end" => {
                let spec = cur.take().ok_or_else(|| anyhow!("{}: end outside fn", ctx()))?;
                specs.push(spec);
            }
            other => bail!("{}: unknown tag {other}", ctx()),
        }
    }
    if cur.is_some() {
        bail!("manifest truncated: missing final 'end'");
    }
    Ok(specs)
}

/// Read and parse a manifest file.
pub fn load<P: AsRef<Path>>(path: P) -> Result<Vec<FunctionSpec>> {
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    parse(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
fn demo
in 8x256 sym
in 256 unit
out 0 8x256 l2=2.74148041e+00 first=1.0,2.0
end
fn other
in 4 sym
out 0 4 l2=1.0e+00 first=0.5
end
";

    #[test]
    fn parses_two_functions() {
        let specs = parse(SAMPLE).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name, "demo");
        assert_eq!(specs[0].inputs.len(), 2);
        assert_eq!(specs[0].inputs[0].shape, vec![8, 256]);
        assert_eq!(specs[0].inputs[0].kind, Kind::Sym);
        assert_eq!(specs[0].inputs[1].kind, Kind::Unit);
        assert_eq!(specs[0].outputs[0].first, vec![1.0, 2.0]);
        assert!((specs[0].outputs[0].l2 - 2.74148041).abs() < 1e-9);
        assert_eq!(specs[1].inputs[0].len(), 4);
    }

    #[test]
    fn rejects_truncated() {
        assert!(parse("fn demo\nin 4 sym\n").is_err());
    }

    #[test]
    fn rejects_unknown_tag() {
        assert!(parse("fn a\nbogus 1\nend\n").is_err());
    }

    #[test]
    fn rejects_orphan_records() {
        assert!(parse("in 4 sym\n").is_err());
        assert!(parse("out 0 4 l2=1.0 first=1.0\n").is_err());
        assert!(parse("end\n").is_err());
    }

    #[test]
    fn rejects_missing_l2() {
        assert!(parse("fn a\nout 0 4 first=1.0\nend\n").is_err());
    }
}
