//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them on the request path.
//!
//! This is the only place the `xla` crate is touched. Interchange is HLO
//! *text* — jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md and DESIGN.md).
//!
//! Inputs are staged **once** per function as device buffers
//! ([`PjRtBuffer`]) at load time — the serving hot path then calls
//! `execute_b` with the staged buffers, paying no host→device transfer
//! per invocation (the paper's functions likewise hold their weights
//! resident; per-request payloads are small).

pub mod goldgen;
pub mod manifest;

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use manifest::FunctionSpec;

/// A loaded, compiled function artifact with pre-staged inputs.
pub struct LoadedFunction {
    pub spec: FunctionSpec,
    exe: xla::PjRtLoadedExecutable,
    staged: Vec<xla::PjRtBuffer>,
}

/// Summary of one execution.
#[derive(Debug, Clone)]
pub struct ExecReport {
    pub name: String,
    /// Wall-clock execution time (compile excluded).
    pub elapsed: std::time::Duration,
    /// Flattened f32 outputs.
    pub outputs: Vec<Vec<f32>>,
}

/// The PJRT runtime: one CPU client + a cache of compiled executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    functions: HashMap<String, LoadedFunction>,
    dir: PathBuf,
}

impl PjrtRuntime {
    /// Create a runtime rooted at an artifacts directory (does not load
    /// anything yet; see [`Self::load_all`] / [`Self::load_function`]).
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self {
            client,
            functions: HashMap::new(),
            dir: artifacts_dir.as_ref().to_path_buf(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Parse the manifest and load + compile every artifact in it.
    pub fn load_all(&mut self) -> Result<Vec<String>> {
        let specs = manifest::load(self.dir.join("manifest.txt"))?;
        let mut names = Vec::new();
        for spec in specs {
            names.push(spec.name.clone());
            self.load_spec(spec)?;
        }
        Ok(names)
    }

    /// Load + compile a single artifact described by `spec`.
    pub fn load_spec(&mut self, spec: FunctionSpec) -> Result<()> {
        let path = self.dir.join(format!("{}.hlo.txt", spec.name));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not UTF-8")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", spec.name))?;

        // Stage the deterministic inputs as device buffers once.
        let device = self
            .client
            .addressable_devices()
            .into_iter()
            .next()
            .context("no addressable PJRT device")?;
        let mut staged = Vec::with_capacity(spec.inputs.len());
        for (i, input) in spec.inputs.iter().enumerate() {
            let data = goldgen::fill(
                goldgen::input_seed(&spec.name, i),
                input.len(),
                input.kind,
            );
            let dims: Vec<usize> = input.shape.clone();
            let buf = self
                .client
                .buffer_from_host_buffer(&data, &dims, Some(&device))
                .map_err(|e| anyhow!("staging input {i} of {}: {e:?}", spec.name))?;
            staged.push(buf);
        }
        self.functions
            .insert(spec.name.clone(), LoadedFunction { spec, exe, staged });
        Ok(())
    }

    /// Load one function by name (reads the manifest for its spec).
    pub fn load_function(&mut self, name: &str) -> Result<()> {
        let specs = manifest::load(self.dir.join("manifest.txt"))?;
        let spec = specs
            .into_iter()
            .find(|s| s.name == name)
            .ok_or_else(|| anyhow!("{name} not in manifest"))?;
        self.load_spec(spec)
    }

    pub fn loaded(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.functions.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.functions.contains_key(name)
    }

    pub fn spec(&self, name: &str) -> Option<&FunctionSpec> {
        self.functions.get(name).map(|f| &f.spec)
    }

    /// Execute `name` with its staged inputs; returns flattened outputs.
    pub fn execute(&self, name: &str) -> Result<ExecReport> {
        let f = self
            .functions
            .get(name)
            .ok_or_else(|| anyhow!("{name} not loaded"))?;
        let start = Instant::now();
        let result = f
            .exe
            .execute_b(&f.staged)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        let elapsed = start.elapsed();

        // aot.py lowers with return_tuple=True: always a tuple literal.
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("untupling result of {name}: {e:?}"))?;
        let mut outputs = Vec::with_capacity(parts.len());
        for p in parts {
            outputs.push(
                p.to_vec::<f32>()
                    .map_err(|e| anyhow!("reading output of {name}: {e:?}"))?,
            );
        }
        Ok(ExecReport {
            name: name.to_string(),
            elapsed,
            outputs,
        })
    }

    /// Execute and check outputs against the golden manifest records.
    /// Returns the report on success, an error naming the first mismatch
    /// otherwise.
    pub fn validate(&self, name: &str) -> Result<ExecReport> {
        let report = self.execute(name)?;
        let spec = &self.functions[name].spec;
        if report.outputs.len() != spec.outputs.len() {
            return Err(anyhow!(
                "{name}: output arity {} != manifest {}",
                report.outputs.len(),
                spec.outputs.len()
            ));
        }
        for golden in &spec.outputs {
            let got = &report.outputs[golden.index];
            let want_len: usize = golden.shape.iter().product();
            if got.len() != want_len {
                return Err(anyhow!(
                    "{name} out{}: len {} != {}",
                    golden.index,
                    got.len(),
                    want_len
                ));
            }
            let l2 = got.iter().map(|&x| x as f64 * x as f64).sum::<f64>().sqrt();
            let tol = 1e-3 * golden.l2.abs().max(1e-6);
            if (l2 - golden.l2).abs() > tol {
                return Err(anyhow!(
                    "{name} out{}: l2 {l2:.6e} != golden {:.6e}",
                    golden.index,
                    golden.l2
                ));
            }
            for (i, want) in golden.first.iter().enumerate() {
                let got_v = got[i] as f64;
                let tol = 1e-3 * want.abs() + 1e-5 * golden.l2.abs().max(1e-6);
                if (got_v - want).abs() > tol {
                    return Err(anyhow!(
                        "{name} out{idx}[{i}]: {got_v:.6e} != golden {want:.6e}",
                        idx = golden.index
                    ));
                }
            }
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Runtime construction should succeed even with a bogus directory —
    /// loading is lazy.
    #[test]
    fn new_does_not_touch_disk() {
        let rt = PjrtRuntime::new("/definitely/not/here");
        assert!(rt.is_ok());
        let rt = rt.unwrap();
        assert_eq!(rt.loaded().len(), 0);
        assert!(!rt.is_loaded("imagenet"));
    }

    #[test]
    fn execute_unknown_errors() {
        let rt = PjrtRuntime::new("/nope").unwrap();
        assert!(rt.execute("ghost").is_err());
    }
}
