//! CUDA interposition shim model (§5.1).
//!
//! The paper injects ~500 LoC of C via LD_PRELOAD into every container:
//! `cuMemAlloc` is intercepted and converted to a UVM
//! (`cuMemAllocManaged`) allocation, allocation metadata is recorded,
//! and the control plane directs `cuMemPrefetchAsync` to move regions
//! host↔device. This module models exactly that contract:
//!
//! * an **allocation ledger** per container (sizes + residency),
//! * **cost helpers** for bulk prefetch (PCIe bandwidth), on-demand UVM
//!   page-fault migration (an order of magnitude slower — the Fig-4
//!   "stock UVM" penalty), and madvise directive overhead,
//! * the per-function **interception overhead** of running under UVM at
//!   all (Fig 3; applied in the device execution model).

use crate::gpu::GpuProfile;
use crate::types::{secs, DurNanos};

/// One intercepted allocation region (coarse: MB granularity).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Region {
    pub size_mb: u64,
    /// MB of this region currently resident on device.
    pub resident_mb: u64,
}

/// Allocation ledger of one container, as reported by its shim
/// ("a report of memory allocations still held by the function", §5).
#[derive(Debug, Clone, Default)]
pub struct AllocLedger {
    regions: Vec<Region>,
}

impl AllocLedger {
    /// Record an intercepted cuMemAlloc → cuMemAllocManaged of `mb`.
    /// Fresh UVM allocations are not resident until first touch/prefetch.
    pub fn alloc(&mut self, mb: u64) {
        self.regions.push(Region {
            size_mb: mb,
            resident_mb: 0,
        });
    }

    pub fn footprint_mb(&self) -> u64 {
        self.regions.iter().map(|r| r.size_mb).sum()
    }

    pub fn resident_mb(&self) -> u64 {
        self.regions.iter().map(|r| r.resident_mb).sum()
    }

    pub fn nonresident_mb(&self) -> u64 {
        self.footprint_mb() - self.resident_mb()
    }

    /// Make `mb` more MB resident (prefetch/fault-in); returns how much
    /// actually moved (bounded by what was non-resident).
    pub fn page_in(&mut self, mut mb: u64) -> u64 {
        let mut moved = 0;
        for r in &mut self.regions {
            if mb == 0 {
                break;
            }
            let take = (r.size_mb - r.resident_mb).min(mb);
            r.resident_mb += take;
            mb -= take;
            moved += take;
        }
        moved
    }

    /// Evict `mb` MB to host (swap-out/UVM reclaim); returns how much
    /// actually moved.
    pub fn page_out(&mut self, mut mb: u64) -> u64 {
        let mut moved = 0;
        for r in &mut self.regions {
            if mb == 0 {
                break;
            }
            let take = r.resident_mb.min(mb);
            r.resident_mb -= take;
            mb -= take;
            moved += take;
        }
        moved
    }

    pub fn evict_all(&mut self) -> u64 {
        self.page_out(u64::MAX)
    }
}

// ---------------------------------------------------------------------------
// Cost helpers (used by the memory manager).
// ---------------------------------------------------------------------------

/// Time to bulk-move `mb` MB with cuMemPrefetchAsync at PCIe bandwidth.
pub fn prefetch_time(mb: u64, profile: &GpuProfile) -> DurNanos {
    secs(mb as f64 / 1024.0 / profile.pcie_gbps)
}

/// Time lost to on-demand UVM page faults migrating `mb` MB during
/// kernel execution. Each fault stalls the SM and serializes on the
/// driver's fault handler, so effective bandwidth is ~10× below bulk
/// prefetch (Fig 4's +40% for "stock UVM" calibrates this).
pub fn fault_time(mb: u64, profile: &GpuProfile) -> DurNanos {
    secs(mb as f64 / 1024.0 / profile.uvm_fault_gbps)
}

/// Overhead of issuing cuMemAdvise directives for a footprint. The paper
/// (Fig 4): "Madvise doesn't move any memory and wastes time sending
/// memory directives, with no benefit" — a per-MB driver call cost.
pub fn madvise_overhead(mb: u64) -> DurNanos {
    // ~60 µs per 2 MB managed range.
    secs(mb as f64 / 2.0 * 60e-6)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::V100;

    #[test]
    fn ledger_alloc_and_residency() {
        let mut l = AllocLedger::default();
        l.alloc(1000);
        l.alloc(500);
        assert_eq!(l.footprint_mb(), 1500);
        assert_eq!(l.resident_mb(), 0);
        assert_eq!(l.page_in(600), 600);
        assert_eq!(l.resident_mb(), 600);
        assert_eq!(l.nonresident_mb(), 900);
        assert_eq!(l.page_in(10_000), 900); // bounded
        assert_eq!(l.resident_mb(), 1500);
    }

    #[test]
    fn ledger_page_out_bounded() {
        let mut l = AllocLedger::default();
        l.alloc(800);
        l.page_in(800);
        assert_eq!(l.page_out(300), 300);
        assert_eq!(l.resident_mb(), 500);
        assert_eq!(l.evict_all(), 500);
        assert_eq!(l.resident_mb(), 0);
        assert_eq!(l.page_out(10), 0);
    }

    #[test]
    fn fault_is_order_of_magnitude_slower_than_prefetch() {
        let p = prefetch_time(1500, &V100);
        let f = fault_time(1500, &V100);
        assert!(f > 4 * p, "fault {f} vs prefetch {p}");
        // 1.5 GB over 12 GB/s ≈ 122 ms.
        assert!((p as f64 / 1e6 - 122.0).abs() < 5.0);
    }

    #[test]
    fn madvise_cost_scales_with_footprint() {
        assert!(madvise_overhead(3000) > madvise_overhead(300));
        // 1.5 GB ≈ 750 ranges ≈ 45 ms of directives.
        let ms = madvise_overhead(1500) as f64 / 1e6;
        assert!((ms - 45.0).abs() < 1.0, "{ms}");
    }
}
