//! Front-end routing policies: which shard (server) gets the next
//! invocation of a function.
//!
//! The cluster-level analog of the paper's per-GPU sticky placement
//! (§5 "sticky load balancing among GPUs"): warm locality is worth
//! orders of magnitude in start latency, so the router that keeps a
//! function on its *home shard* ([`StickyCh`]) preserves the container
//! warm pool's hit rate, while spray routers ([`RoundRobin`],
//! [`Random`]) re-pay the cold start on every shard a function touches.
//!
//! Every router is deterministic given its construction seed, which is
//! what makes multi-shard replays reproducible (see
//! [`crate::sim::replay_cluster`]).

use crate::types::FuncId;
use crate::util::rng::{Rng, SplitMix64};

/// Instantaneous queue depth of one shard, as visible to the front end.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardLoad {
    /// Invocations queued (not yet dispatched) on the shard.
    pub pending: usize,
    /// Invocations currently executing on the shard's devices.
    pub in_flight: usize,
}

impl ShardLoad {
    /// Total outstanding work: the `pending() + in_flight()` depth the
    /// load-aware routers balance on.
    pub fn depth(&self) -> usize {
        self.pending + self.in_flight
    }
}

/// A routing policy: picks the shard for each arriving invocation.
///
/// Routers see only front-end state (per-shard queue depths) — never
/// shard internals — mirroring what a real load balancer can observe
/// cheaply. They may keep mutable state (round-robin cursor, RNG), but
/// must be deterministic for a fixed seed and call sequence.
pub trait Router: Send {
    fn name(&self) -> &'static str;

    /// Shard index in `0..loads.len()` for the next invocation of `func`.
    fn route(&mut self, func: FuncId, loads: &[ShardLoad]) -> usize;

    /// Invocations routed off their locality-preferred shard (only
    /// meaningful for [`StickyCh`]; 0 for load-blind routers).
    fn spills(&self) -> u64 {
        0
    }
}

/// Router selector used by the CLI / experiment harness.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterKind {
    RoundRobin,
    Random,
    LeastLoaded,
    StickyCh,
}

/// Every router, in the order the fig9 sweep reports them.
pub const ALL_ROUTERS: [RouterKind; 4] = [
    RouterKind::RoundRobin,
    RouterKind::Random,
    RouterKind::LeastLoaded,
    RouterKind::StickyCh,
];

impl RouterKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "rr" | "round-robin" => RouterKind::RoundRobin,
            "random" => RouterKind::Random,
            "least" | "least-loaded" => RouterKind::LeastLoaded,
            "sticky" | "sticky-ch" => RouterKind::StickyCh,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RouterKind::RoundRobin => "round-robin",
            RouterKind::Random => "random",
            RouterKind::LeastLoaded => "least-loaded",
            RouterKind::StickyCh => "sticky-ch",
        }
    }

    /// Instantiate for `n_shards`. `load_factor` and `seed` are used by
    /// [`StickyCh`] (spill bound, ring layout); `seed` also drives
    /// [`Random`].
    pub fn build(&self, n_shards: usize, load_factor: f64, seed: u64) -> Box<dyn Router> {
        assert!(n_shards >= 1, "cluster needs at least one shard");
        match self {
            RouterKind::RoundRobin => Box::new(RoundRobin { next: 0 }),
            RouterKind::Random => Box::new(Random {
                rng: Rng::new(seed ^ 0x5A5A_0001),
            }),
            RouterKind::LeastLoaded => Box::new(LeastLoaded),
            RouterKind::StickyCh => Box::new(StickyCh::new(n_shards, load_factor, seed)),
        }
    }
}

/// Cycle through shards regardless of function or load.
pub struct RoundRobin {
    next: usize,
}

impl Router for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&mut self, _func: FuncId, loads: &[ShardLoad]) -> usize {
        let s = self.next % loads.len();
        self.next = self.next.wrapping_add(1);
        s
    }
}

/// Uniform random shard (seeded, deterministic).
pub struct Random {
    rng: Rng,
}

impl Router for Random {
    fn name(&self) -> &'static str {
        "random"
    }

    fn route(&mut self, _func: FuncId, loads: &[ShardLoad]) -> usize {
        self.rng.below(loads.len())
    }
}

/// Smallest `pending + in_flight` depth; ties go to the lowest index.
pub struct LeastLoaded;

impl Router for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn route(&mut self, _func: FuncId, loads: &[ShardLoad]) -> usize {
        let mut best = 0;
        for (s, l) in loads.iter().enumerate().skip(1) {
            if l.depth() < loads[best].depth() {
                best = s;
            }
        }
        best
    }
}

/// Consistent hashing with a bounded-load spill factor.
///
/// Each shard owns [`StickyCh::VNODES`] points on a `u64` ring; a
/// function's *home shard* is the owner of the first ring point at or
/// after `hash(func)`. Home assignment never changes with load, so a
/// function's warm containers concentrate on one shard (the cluster
/// analog of §5's per-GPU stickiness).
///
/// Spill rule (consistent hashing with bounded loads): an invocation
/// stays home only while the home's depth is below the capacity bound
///
/// ```text
/// cap = ceil(load_factor × (total_depth + 1) / n_shards)
/// ```
///
/// i.e. `load_factor ×` the cluster-mean depth counting the new
/// arrival. When the home is at/over the bound, the invocation walks
/// the ring clockwise to the next *distinct* shard below the bound
/// (deterministic spill order per function). If every shard is at the
/// bound (uniform overload), it stays home — spilling could not help
/// and would only shred locality.
pub struct StickyCh {
    /// (ring point, shard), sorted by point.
    ring: Vec<(u64, usize)>,
    n_shards: usize,
    load_factor: f64,
    /// Spills observed (diagnostics; exposed via [`StickyCh::spills`]).
    spills: u64,
}

impl StickyCh {
    /// Virtual nodes per shard: enough to even out ring arcs at 16
    /// shards without making the ring walk expensive.
    pub const VNODES: usize = 32;

    pub fn new(n_shards: usize, load_factor: f64, seed: u64) -> Self {
        assert!(load_factor > 0.0, "load_factor must be positive");
        assert!(n_shards <= 128, "spill bitset covers up to 128 shards");
        let mut ring = Vec::with_capacity(n_shards * Self::VNODES);
        for shard in 0..n_shards {
            for v in 0..Self::VNODES {
                ring.push((mix(seed, (shard * Self::VNODES + v) as u64), shard));
            }
        }
        ring.sort_unstable();
        Self {
            ring,
            n_shards,
            load_factor,
            spills: 0,
        }
    }

    /// Ring position of `func`: (index of its first ring point, owning
    /// shard). The single source of truth for "home" — [`Self::home`]
    /// and [`Router::route`] must agree or spills are miscounted.
    fn ring_start(&self, func: FuncId) -> (usize, usize) {
        let key = mix(0xF00D_F00D, func.0 as u64);
        let start = self.ring.partition_point(|(p, _)| *p < key);
        (start, self.ring[start % self.ring.len()].1)
    }

    /// The load-independent home shard of `func`.
    pub fn home(&self, func: FuncId) -> usize {
        self.ring_start(func).1
    }
}

impl Router for StickyCh {
    fn name(&self) -> &'static str {
        "sticky-ch"
    }

    fn spills(&self) -> u64 {
        self.spills
    }

    fn route(&mut self, func: FuncId, loads: &[ShardLoad]) -> usize {
        debug_assert_eq!(loads.len(), self.n_shards);
        let (start, home) = self.ring_start(func);
        let total: usize = loads.iter().map(|l| l.depth()).sum();
        let cap = (self.load_factor * (total as f64 + 1.0) / self.n_shards as f64).ceil();
        let mut visited: u128 = 0;
        let mut seen = 0usize;
        for i in 0..self.ring.len() {
            let shard = self.ring[(start + i) % self.ring.len()].1;
            if visited & (1 << shard) != 0 {
                continue;
            }
            visited |= 1 << shard;
            seen += 1;
            if (loads[shard].depth() as f64) < cap {
                if shard != home {
                    self.spills += 1;
                }
                return shard;
            }
            if seen == self.n_shards {
                break;
            }
        }
        home // uniform overload: locality beats a futile spill
    }
}

/// Keyed hash of (seed, x) — ring points and function keys. One
/// SplitMix64 step over a seed-offset state; for a fixed `seed` this is
/// injective in `x`, so ring points never collide.
fn mix(seed: u64, x: u64) -> u64 {
    SplitMix64::new(seed.rotate_left(32).wrapping_add(x)).next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(depths: &[usize]) -> Vec<ShardLoad> {
        depths
            .iter()
            .map(|&d| ShardLoad {
                pending: d,
                in_flight: 0,
            })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = RouterKind::RoundRobin.build(3, 1.25, 0);
        let l = loads(&[0, 0, 0]);
        let picks: Vec<usize> = (0..6).map(|_| r.route(FuncId(0), &l)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn random_is_deterministic_and_in_range() {
        let l = loads(&[0; 5]);
        let mut a = RouterKind::Random.build(5, 1.25, 9);
        let mut b = RouterKind::Random.build(5, 1.25, 9);
        for i in 0..100 {
            let pa = a.route(FuncId(i), &l);
            assert_eq!(pa, b.route(FuncId(i), &l));
            assert!(pa < 5);
        }
    }

    #[test]
    fn least_loaded_picks_min_with_low_index_ties() {
        let mut r = RouterKind::LeastLoaded.build(4, 1.25, 0);
        assert_eq!(r.route(FuncId(0), &loads(&[3, 1, 2, 1])), 1);
        assert_eq!(r.route(FuncId(0), &loads(&[0, 0, 0, 0])), 0);
    }

    #[test]
    fn sticky_home_is_stable_and_spread() {
        let s = StickyCh::new(8, 1.25, 7);
        // Stability: the home does not depend on load.
        for f in 0..32 {
            assert_eq!(s.home(FuncId(f)), s.home(FuncId(f)));
        }
        // Spread: 256 functions should not all hash to one shard.
        let mut hit = [false; 8];
        for f in 0..256 {
            hit[s.home(FuncId(f))] = true;
        }
        assert!(hit.iter().all(|&h| h), "some shard owns no functions");
    }

    #[test]
    fn sticky_routes_home_when_under_capacity() {
        let mut s = StickyCh::new(4, 2.0, 3);
        let home = s.home(FuncId(5));
        let l = loads(&[0, 0, 0, 0]);
        assert_eq!(s.route(FuncId(5), &l), home);
        assert_eq!(s.spills(), 0);
    }

    #[test]
    fn sticky_spills_when_home_overloaded() {
        let mut s = StickyCh::new(4, 1.25, 3);
        let home = s.home(FuncId(5));
        // Home far above the mean; everyone else empty.
        let mut d = vec![0usize; 4];
        d[home] = 40;
        let picked = s.route(FuncId(5), &loads(&d));
        assert_ne!(picked, home, "should spill off the hot home shard");
        assert_eq!(s.spills(), 1);
        // Spill target is deterministic.
        let mut s2 = StickyCh::new(4, 1.25, 3);
        assert_eq!(s2.route(FuncId(5), &loads(&d)), picked);
    }

    #[test]
    fn sticky_stays_home_under_uniform_overload() {
        let mut s = StickyCh::new(4, 1.25, 3);
        let home = s.home(FuncId(5));
        // Every shard equally deep: cap < depth everywhere ⇒ stay home.
        assert_eq!(s.route(FuncId(5), &loads(&[50, 50, 50, 50])), home);
    }

    #[test]
    fn router_kind_parse_roundtrip() {
        for k in ALL_ROUTERS {
            assert_eq!(RouterKind::parse(k.name()), Some(k));
        }
        assert_eq!(RouterKind::parse("rr"), Some(RouterKind::RoundRobin));
        assert_eq!(RouterKind::parse("sticky"), Some(RouterKind::StickyCh));
        assert_eq!(RouterKind::parse("nope"), None);
    }

    #[test]
    fn single_shard_routers_all_pick_zero() {
        let l = loads(&[3]);
        for k in ALL_ROUTERS {
            let mut r = k.build(1, 1.25, 11);
            for f in 0..8 {
                assert_eq!(r.route(FuncId(f), &l), 0, "{}", k.name());
            }
        }
    }
}
