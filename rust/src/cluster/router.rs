//! Front-end routing policies: which shard (server) gets the next
//! invocation of a function.
//!
//! The cluster-level analog of the paper's per-GPU sticky placement
//! (§5 "sticky load balancing among GPUs"): warm locality is worth
//! orders of magnitude in start latency, so the router that keeps a
//! function on its *home shard* ([`StickyCh`]) preserves the container
//! warm pool's hit rate, while spray routers ([`RoundRobin`],
//! [`Random`]) re-pay the cold start on every shard a function touches.
//!
//! # Heterogeneous shards
//!
//! Shards are not assumed identical: every [`ShardLoad`] carries the
//! shard's static service `capacity` (V100-equivalents of its fleet,
//! see [`crate::plane::PlaneConfig::fleet_capacity`]). [`LeastLoaded`]
//! balances *normalized* depth (depth ÷ capacity), and [`StickyCh`] is
//! a capacity-**weighted** ring: a shard's virtual-node count and its
//! bounded-load share both scale with its capacity, so a 4×-GPU shard
//! owns ~4× the functions and absorbs ~4× the depth before spilling —
//! and because fat shards own proportionally more ring points, the
//! deterministic clockwise spill walk reaches them sooner, making the
//! spill order itself speed-aware. [`RouterKind::StickyChBlind`] keeps
//! the capacity-*blind* ring (uniform vnodes + mean-depth bound) as the
//! ablation baseline the fig10 heterogeneity gate compares against.
//! With equal capacities the weighted and blind rings are constructed
//! identically, so uniform clusters behave exactly as before
//! (property-tested in `rust/tests/prop_hetero.rs`).
//!
//! Every router is deterministic given its construction seed, which is
//! what makes multi-shard replays reproducible (see
//! [`crate::sim::replay_cluster`]).
//!
//! # Concurrency
//!
//! [`Router::route`] takes `&self`: the wall-clock serving path
//! ([`crate::server`]) routes concurrent submits without an exclusive
//! lock, so router-internal state is interior-mutable — an atomic
//! cursor for [`RoundRobin`], an atomic spill counter for [`StickyCh`]
//! (whose ring is immutable after construction), and a small mutex
//! around [`Random`]'s generator (the only truly sequential state).
//! Under a single caller (the sim engine) the call sequence — and
//! therefore the decision stream — is bit-identical to the old
//! `&mut self` design.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::types::FuncId;
use crate::util::rng::{Rng, SplitMix64};

/// Instantaneous queue depth of one shard, as visible to the front end.
#[derive(Debug, Clone, Copy)]
pub struct ShardLoad {
    /// Invocations queued (not yet dispatched) on the shard.
    pub pending: usize,
    /// Invocations currently executing on the shard's devices.
    pub in_flight: usize,
    /// Static service capacity of the shard's fleet in V100-equivalents
    /// (1.0 for a single baseline GPU). Strictly positive.
    pub capacity: f64,
    /// Whether the shard may receive *new* work. Draining and dead
    /// shards publish `false`; every router skips them. Defaults to
    /// `true` so a fixed fleet never has to think about membership.
    pub routable: bool,
}

impl Default for ShardLoad {
    fn default() -> Self {
        Self {
            pending: 0,
            in_flight: 0,
            capacity: 1.0,
            routable: true,
        }
    }
}

impl ShardLoad {
    /// Total outstanding work: the `pending() + in_flight()` depth the
    /// load-aware routers balance on.
    pub fn depth(&self) -> usize {
        self.pending + self.in_flight
    }
}

/// A routing policy: picks the shard for each arriving invocation.
///
/// Routers see only front-end state (per-shard queue depths) — never
/// shard internals — mirroring what a real load balancer can observe
/// cheaply. They may keep mutable state (round-robin cursor, RNG)
/// behind interior mutability, but must be deterministic for a fixed
/// seed and call sequence.
pub trait Router: Send + Sync {
    fn name(&self) -> &'static str;

    /// Shard index in `0..loads.len()` for the next invocation of `func`.
    fn route(&self, func: FuncId, loads: &[ShardLoad]) -> usize;

    /// Invocations routed off their locality-preferred shard (only
    /// meaningful for [`StickyCh`]; 0 for load-blind routers).
    fn spills(&self) -> u64 {
        0
    }

    /// Membership change: `shard` left the routable set (drain or
    /// kill). Stateless routers need nothing beyond the per-route
    /// [`ShardLoad::routable`] flag; [`StickyCh`] removes the shard's
    /// virtual nodes so its ring segment re-homes deterministically.
    /// Called under the serving path's exclusive router lock.
    fn on_shard_removed(&mut self, _shard: usize) {}

    /// Membership change: `shard` (re)joined. [`StickyCh`] reinserts
    /// exactly the vnodes removed at departure, so every function homed
    /// elsewhere keeps its home — the consistent-hashing guarantee.
    fn on_shard_added(&mut self, _shard: usize) {}
}

/// Router selector used by the CLI / experiment harness.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterKind {
    RoundRobin,
    Random,
    LeastLoaded,
    StickyCh,
    /// [`StickyCh`] with capacities ignored (uniform ring + mean-depth
    /// bound) — the ablation baseline for heterogeneous fleets.
    StickyChBlind,
}

/// The fig9 sweep's router set, in reporting order. (The capacity-blind
/// sticky ablation is omitted: on the uniform fleets fig9 sweeps it is
/// identical to [`RouterKind::StickyCh`] by construction.)
pub const ALL_ROUTERS: [RouterKind; 4] = [
    RouterKind::RoundRobin,
    RouterKind::Random,
    RouterKind::LeastLoaded,
    RouterKind::StickyCh,
];

impl RouterKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "rr" | "round-robin" => RouterKind::RoundRobin,
            "random" => RouterKind::Random,
            "least" | "least-loaded" => RouterKind::LeastLoaded,
            "sticky" | "sticky-ch" => RouterKind::StickyCh,
            "sticky-blind" | "blind" => RouterKind::StickyChBlind,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RouterKind::RoundRobin => "round-robin",
            RouterKind::Random => "random",
            RouterKind::LeastLoaded => "least-loaded",
            RouterKind::StickyCh => "sticky-ch",
            RouterKind::StickyChBlind => "sticky-blind",
        }
    }

    /// Instantiate for `n_shards`. `load_factor` and `seed` are used by
    /// [`StickyCh`] (spill bound, ring layout); `seed` also drives
    /// [`Random`]. `capacities` (one entry per shard, or empty for a
    /// uniform cluster) weights the [`RouterKind::StickyCh`] ring;
    /// [`RouterKind::StickyChBlind`] deliberately drops it.
    pub fn build(
        &self,
        n_shards: usize,
        load_factor: f64,
        seed: u64,
        capacities: &[f64],
    ) -> Box<dyn Router> {
        assert!(n_shards >= 1, "cluster needs at least one shard");
        assert!(
            capacities.is_empty() || capacities.len() == n_shards,
            "capacities must be empty or one per shard"
        );
        match self {
            RouterKind::RoundRobin => Box::new(RoundRobin {
                next: AtomicUsize::new(0),
            }),
            RouterKind::Random => Box::new(Random {
                rng: Mutex::new(Rng::new(seed ^ 0x5A5A_0001)),
            }),
            RouterKind::LeastLoaded => Box::new(LeastLoaded),
            RouterKind::StickyCh => Box::new(StickyCh::weighted(
                n_shards,
                load_factor,
                seed,
                capacities,
            )),
            RouterKind::StickyChBlind => {
                let mut r = StickyCh::new(n_shards, load_factor, seed);
                r.name = "sticky-blind";
                Box::new(r)
            }
        }
    }
}

/// Cycle through shards regardless of function or load. The cursor is
/// a lone atomic, so concurrent submitters cycle without locking.
pub struct RoundRobin {
    next: AtomicUsize,
}

impl Router for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&self, _func: FuncId, loads: &[ShardLoad]) -> usize {
        let k = self.next.fetch_add(1, Ordering::Relaxed);
        // Walk past drained/dead shards; with a fully routable fleet the
        // first probe hits, reproducing the plain modulo cycle exactly.
        for i in 0..loads.len() {
            let s = (k + i) % loads.len();
            if loads[s].routable {
                return s;
            }
        }
        k % loads.len()
    }
}

/// Uniform random shard (seeded, deterministic). The xoshiro state is
/// inherently sequential, so it sits behind a short mutex — the spray
/// baseline, not the production router.
pub struct Random {
    rng: Mutex<Rng>,
}

impl Router for Random {
    fn name(&self) -> &'static str {
        "random"
    }

    fn route(&self, _func: FuncId, loads: &[ShardLoad]) -> usize {
        let mut rng = self.rng.lock().unwrap();
        let routable = loads.iter().filter(|l| l.routable).count();
        if routable == 0 || routable == loads.len() {
            // Fully routable fleet: one draw over all shards, exactly
            // the pre-membership decision stream.
            return rng.below(loads.len());
        }
        let mut k = rng.below(routable);
        for (s, l) in loads.iter().enumerate() {
            if l.routable {
                if k == 0 {
                    return s;
                }
                k -= 1;
            }
        }
        unreachable!("counted routable shards above")
    }
}

/// Smallest capacity-normalized depth (`(pending + in_flight) /
/// capacity`); ties go to the lowest index. On a uniform cluster the
/// normalization cancels and this is the plain least-depth rule.
pub struct LeastLoaded;

impl Router for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn route(&self, _func: FuncId, loads: &[ShardLoad]) -> usize {
        let mut best: Option<usize> = None;
        for (s, l) in loads.iter().enumerate() {
            if !l.routable {
                continue;
            }
            // depth/capacity comparison, cross-multiplied so equal
            // capacities reduce to the exact integer depth comparison.
            best = Some(match best {
                None => s,
                Some(b)
                    if (l.depth() as f64) * loads[b].capacity
                        < (loads[b].depth() as f64) * l.capacity =>
                {
                    s
                }
                Some(b) => b,
            });
        }
        best.unwrap_or(0)
    }
}

/// Consistent hashing with a bounded-load spill factor, optionally
/// capacity-weighted for heterogeneous shards.
///
/// Each shard owns a number of points on a `u64` ring — a uniform
/// [`StickyCh::VNODES`] when capacity-blind, or a count proportional to
/// its fleet capacity when weighted (a 4×-GPU shard owns ~4× the arc,
/// and therefore homes ~4× the functions). A function's *home shard* is
/// the owner of the first ring point at or after `hash(func)`. Home
/// assignment never changes with load, so a function's warm containers
/// concentrate on one shard (the cluster analog of §5's per-GPU
/// stickiness).
///
/// Spill rule (consistent hashing with bounded loads): an invocation
/// stays home only while the home's depth is below its capacity share
/// of the bound
///
/// ```text
/// bound(s) = ceil(load_factor × (total_depth + 1) × share(s))
/// ```
///
/// where `share(s)` is the shard's fraction of cluster capacity (`1/n`
/// when blind/uniform — exactly the classic mean-depth bound). When the
/// home is at/over its bound, the invocation walks the ring clockwise
/// to the next *distinct* shard below its own bound (deterministic
/// spill order per function; on a weighted ring fat shards own more
/// points, so the walk reaches them sooner — the spill order itself is
/// speed-aware). If every shard is at its bound (uniform overload), it
/// stays home — spilling could not help and would only shred locality.
pub struct StickyCh {
    /// (ring point, shard), sorted by point. Contains only *live*
    /// shards' points; membership changes rebuild it from the fixed
    /// per-shard layout below.
    ring: Vec<(u64, usize)>,
    n_shards: usize,
    load_factor: f64,
    /// Per-shard fraction of the bounded-load budget (sums to 1 over
    /// live shards; 0 for departed shards).
    shares: Vec<f64>,
    /// Ring-layout seed, kept so heals reproduce construction points.
    seed: u64,
    /// Capacity-weighted vnode count per shard, fixed at construction.
    /// Removal deletes exactly these points; rejoin reinserts exactly
    /// them — every *other* function's home is untouched (the
    /// consistent-hashing guarantee under membership change).
    vnodes: Vec<usize>,
    /// Capacity fraction of the full fleet (sums to 1 over all shards);
    /// live shares are these weights renormalized over the live set.
    weights: Vec<f64>,
    /// Membership: shards currently owning ring points.
    live: Vec<bool>,
    /// Reported router name ("sticky-ch", or "sticky-blind" for the
    /// capacity-ignoring ablation).
    name: &'static str,
    /// Spills observed (diagnostics; exposed via [`StickyCh::spills`]).
    /// Atomic so concurrent routes only touch the counter, never a lock
    /// — the ring is immutable between membership changes, which the
    /// serving path applies under its exclusive router lock.
    spills: AtomicU64,
}

impl StickyCh {
    /// Virtual nodes per unit-capacity shard: enough to even out ring
    /// arcs at 16 shards without making the ring walk expensive.
    pub const VNODES: usize = 32;
    /// Hard cap on one shard's vnodes (bounds ring size under extreme
    /// capacity skew).
    const MAX_VNODES: usize = 1024;
    /// Salt for vnodes beyond the base [`Self::VNODES`] layout, so the
    /// weighted ring's extra points can never collide with (or reorder)
    /// the uniform layout's points.
    const EXTRA_SALT: u64 = 0x9E37_79B9_7F4A_7C15;

    /// Capacity-blind ring: every shard owns [`Self::VNODES`] points
    /// and a `1/n` share of the bounded-load budget.
    pub fn new(n_shards: usize, load_factor: f64, seed: u64) -> Self {
        Self::weighted(n_shards, load_factor, seed, &[])
    }

    /// Capacity-weighted ring. `capacities` holds one positive weight
    /// per shard; empty — or all-equal — degenerates to the blind
    /// layout *exactly* (same ring points, same `1/n` shares), which is
    /// what keeps uniform clusters byte-identical to the
    /// pre-heterogeneity router.
    pub fn weighted(n_shards: usize, load_factor: f64, seed: u64, capacities: &[f64]) -> Self {
        assert!(load_factor > 0.0, "load_factor must be positive");
        assert!(n_shards <= 128, "spill bitset covers up to 128 shards");
        assert!(
            capacities.is_empty() || capacities.len() == n_shards,
            "capacities must be empty or one per shard"
        );
        let uniform = capacities.is_empty()
            || capacities.windows(2).all(|w| w[0] == w[1]);
        let (vnodes, shares): (Vec<usize>, Vec<f64>) = if uniform {
            (
                vec![Self::VNODES; n_shards],
                vec![1.0 / n_shards as f64; n_shards],
            )
        } else {
            assert!(
                capacities.iter().all(|&c| c > 0.0 && c.is_finite()),
                "shard capacities must be positive"
            );
            let total: f64 = capacities.iter().sum();
            let mean = total / n_shards as f64;
            let vnodes = capacities
                .iter()
                .map(|&c| {
                    ((Self::VNODES as f64 * c / mean).round() as usize)
                        .clamp(1, Self::MAX_VNODES)
                })
                .collect();
            let shares = capacities.iter().map(|&c| c / total).collect();
            (vnodes, shares)
        };
        let live = vec![true; n_shards];
        let ring = Self::build_ring(seed, &vnodes, &live);
        Self {
            ring,
            n_shards,
            load_factor,
            weights: shares.clone(),
            shares,
            seed,
            vnodes,
            live,
            name: "sticky-ch",
            spills: AtomicU64::new(0),
        }
    }

    /// Construct the sorted ring from the fixed per-shard vnode layout,
    /// placing points only for live shards. With all shards live this
    /// reproduces the construction ring bit-for-bit, which is what makes
    /// a departed-then-rejoined shard restore the exact original homes.
    fn build_ring(seed: u64, vnodes: &[usize], live: &[bool]) -> Vec<(u64, usize)> {
        let mut ring = Vec::with_capacity(vnodes.iter().sum());
        for (shard, &n) in vnodes.iter().enumerate() {
            if !live[shard] {
                continue;
            }
            for v in 0..n.min(Self::VNODES) {
                ring.push((mix(seed, (shard * Self::VNODES + v) as u64), shard));
            }
            for v in Self::VNODES..n {
                ring.push((
                    mix(seed ^ Self::EXTRA_SALT, (shard * Self::MAX_VNODES + v) as u64),
                    shard,
                ));
            }
        }
        ring.sort_unstable();
        ring
    }

    /// Re-derive ring + shares after a membership flip: departed shards
    /// lose their points, and the bounded-load budget renormalizes over
    /// the live capacity (a 3-of-4 uniform cluster gives each survivor a
    /// 1/3 share, keeping the spill bound meaningful mid-heal).
    fn rebuild(&mut self) {
        self.ring = Self::build_ring(self.seed, &self.vnodes, &self.live);
        let live_weight: f64 = self
            .weights
            .iter()
            .zip(&self.live)
            .filter(|(_, &l)| l)
            .map(|(w, _)| w)
            .sum();
        for s in 0..self.n_shards {
            self.shares[s] = if self.live[s] && live_weight > 0.0 {
                self.weights[s] / live_weight
            } else {
                0.0
            };
        }
    }

    /// Ring position of `func`: (index of its first ring point, owning
    /// shard). The single source of truth for "home" — [`Self::home`]
    /// and [`Router::route`] must agree or spills are miscounted.
    fn ring_start(&self, func: FuncId) -> (usize, usize) {
        let key = mix(0xF00D_F00D, func.0 as u64);
        let start = self.ring.partition_point(|(p, _)| *p < key);
        (start, self.ring[start % self.ring.len()].1)
    }

    /// The load-independent home shard of `func`.
    pub fn home(&self, func: FuncId) -> usize {
        self.ring_start(func).1
    }
}

impl Router for StickyCh {
    fn name(&self) -> &'static str {
        self.name
    }

    fn spills(&self) -> u64 {
        self.spills.load(Ordering::Relaxed)
    }

    fn route(&self, func: FuncId, loads: &[ShardLoad]) -> usize {
        debug_assert_eq!(loads.len(), self.n_shards);
        if self.ring.is_empty() {
            // Degenerate: every shard departed. The cluster layers
            // refuse to remove the last live shard, so this only guards
            // direct misuse; any routable shard (or 0) will do.
            return loads.iter().position(|l| l.routable).unwrap_or(0);
        }
        let (start, home) = self.ring_start(func);
        let total: usize = loads.iter().map(|l| l.depth()).sum();
        let budget = self.load_factor * (total as f64 + 1.0);
        let mut visited: u128 = 0;
        let mut seen = 0usize;
        for i in 0..self.ring.len() {
            let shard = self.ring[(start + i) % self.ring.len()].1;
            if visited & (1 << shard) != 0 {
                continue;
            }
            visited |= 1 << shard;
            seen += 1;
            // A shard can sit on the ring yet be momentarily
            // unroutable (drain observed before the heal rebuilt the
            // ring): the walk treats it like an over-bound shard.
            if loads[shard].routable {
                // Each shard absorbs its capacity share of the
                // bounded-load budget (1/n when blind/uniform).
                let bound = (budget * self.shares[shard]).ceil();
                if (loads[shard].depth() as f64) < bound {
                    if shard != home {
                        self.spills.fetch_add(1, Ordering::Relaxed);
                    }
                    return shard;
                }
            }
            if seen == self.n_shards {
                break;
            }
        }
        home // uniform overload: locality beats a futile spill
    }

    fn on_shard_removed(&mut self, shard: usize) {
        if self.live[shard] {
            self.live[shard] = false;
            self.rebuild();
        }
    }

    fn on_shard_added(&mut self, shard: usize) {
        if !self.live[shard] {
            self.live[shard] = true;
            self.rebuild();
        }
    }
}

/// Keyed hash of (seed, x) — ring points and function keys. One
/// SplitMix64 step over a seed-offset state; for a fixed `seed` this is
/// injective in `x`, so ring points never collide.
fn mix(seed: u64, x: u64) -> u64 {
    SplitMix64::new(seed.rotate_left(32).wrapping_add(x)).next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(depths: &[usize]) -> Vec<ShardLoad> {
        depths
            .iter()
            .map(|&d| ShardLoad {
                pending: d,
                ..Default::default()
            })
            .collect()
    }

    fn loads_cap(rows: &[(usize, f64)]) -> Vec<ShardLoad> {
        rows.iter()
            .map(|&(d, c)| ShardLoad {
                pending: d,
                capacity: c,
                ..Default::default()
            })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let r = RouterKind::RoundRobin.build(3, 1.25, 0, &[]);
        let l = loads(&[0, 0, 0]);
        let picks: Vec<usize> = (0..6).map(|_| r.route(FuncId(0), &l)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn random_is_deterministic_and_in_range() {
        let l = loads(&[0; 5]);
        let a = RouterKind::Random.build(5, 1.25, 9, &[]);
        let b = RouterKind::Random.build(5, 1.25, 9, &[]);
        for i in 0..100 {
            let pa = a.route(FuncId(i), &l);
            assert_eq!(pa, b.route(FuncId(i), &l));
            assert!(pa < 5);
        }
    }

    #[test]
    fn least_loaded_picks_min_with_low_index_ties() {
        let r = RouterKind::LeastLoaded.build(4, 1.25, 0, &[]);
        assert_eq!(r.route(FuncId(0), &loads(&[3, 1, 2, 1])), 1);
        assert_eq!(r.route(FuncId(0), &loads(&[0, 0, 0, 0])), 0);
    }

    #[test]
    fn least_loaded_normalizes_by_capacity() {
        let r = RouterKind::LeastLoaded.build(2, 1.25, 0, &[]);
        // Depth 4 on a 4×-capacity shard (norm 1.0) beats depth 2 on a
        // 1× shard (norm 2.0).
        assert_eq!(r.route(FuncId(0), &loads_cap(&[(2, 1.0), (4, 4.0)])), 1);
        // Equal normalized depth: lowest index wins.
        assert_eq!(r.route(FuncId(0), &loads_cap(&[(1, 1.0), (4, 4.0)])), 0);
    }

    #[test]
    fn sticky_home_is_stable_and_spread() {
        let s = StickyCh::new(8, 1.25, 7);
        // Stability: the home does not depend on load.
        for f in 0..32 {
            assert_eq!(s.home(FuncId(f)), s.home(FuncId(f)));
        }
        // Spread: 256 functions should not all hash to one shard.
        let mut hit = [false; 8];
        for f in 0..256 {
            hit[s.home(FuncId(f))] = true;
        }
        assert!(hit.iter().all(|&h| h), "some shard owns no functions");
    }

    #[test]
    fn sticky_routes_home_when_under_capacity() {
        let s = StickyCh::new(4, 2.0, 3);
        let home = s.home(FuncId(5));
        let l = loads(&[0, 0, 0, 0]);
        assert_eq!(s.route(FuncId(5), &l), home);
        assert_eq!(s.spills(), 0);
    }

    #[test]
    fn sticky_spills_when_home_overloaded() {
        let s = StickyCh::new(4, 1.25, 3);
        let home = s.home(FuncId(5));
        // Home far above the mean; everyone else empty.
        let mut d = vec![0usize; 4];
        d[home] = 40;
        let picked = s.route(FuncId(5), &loads(&d));
        assert_ne!(picked, home, "should spill off the hot home shard");
        assert_eq!(s.spills(), 1);
        // Spill target is deterministic.
        let s2 = StickyCh::new(4, 1.25, 3);
        assert_eq!(s2.route(FuncId(5), &loads(&d)), picked);
    }

    #[test]
    fn sticky_stays_home_under_uniform_overload() {
        let s = StickyCh::new(4, 1.25, 3);
        let home = s.home(FuncId(5));
        // Every shard equally deep: cap < depth everywhere ⇒ stay home.
        assert_eq!(s.route(FuncId(5), &loads(&[50, 50, 50, 50])), home);
    }

    #[test]
    fn router_kind_parse_roundtrip() {
        for k in ALL_ROUTERS.into_iter().chain([RouterKind::StickyChBlind]) {
            assert_eq!(RouterKind::parse(k.name()), Some(k));
        }
        assert_eq!(RouterKind::parse("rr"), Some(RouterKind::RoundRobin));
        assert_eq!(RouterKind::parse("sticky"), Some(RouterKind::StickyCh));
        assert_eq!(RouterKind::parse("blind"), Some(RouterKind::StickyChBlind));
        assert_eq!(RouterKind::parse("nope"), None);
    }

    #[test]
    fn single_shard_routers_all_pick_zero() {
        let l = loads(&[3]);
        for k in ALL_ROUTERS.into_iter().chain([RouterKind::StickyChBlind]) {
            let r = k.build(1, 1.25, 11, &[1.0]);
            for f in 0..8 {
                assert_eq!(r.route(FuncId(f), &l), 0, "{}", k.name());
            }
        }
    }

    #[test]
    fn every_router_skips_unroutable_shards() {
        for k in ALL_ROUTERS.into_iter().chain([RouterKind::StickyChBlind]) {
            let r = k.build(4, 1.25, 7, &[]);
            let mut l = loads(&[0, 0, 0, 0]);
            l[2].routable = false;
            for f in 0..64 {
                let picked = r.route(FuncId(f), &l);
                assert_ne!(picked, 2, "{} routed to a drained shard", k.name());
                assert!(picked < 4);
            }
        }
        // Round-robin keeps cycling over the survivors.
        let rr = RouterKind::RoundRobin.build(3, 1.25, 0, &[]);
        let mut l = loads(&[0, 0, 0]);
        l[1].routable = false;
        let picks: Vec<usize> = (0..4).map(|_| rr.route(FuncId(0), &l)).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
    }

    #[test]
    fn sticky_heal_rehomes_and_rejoin_restores_exact_ring() {
        let mut s = StickyCh::new(4, 1.25, 7);
        let original_ring = s.ring.clone();
        let f = FuncId(5);
        let victim = s.home(f);
        let homes_before: Vec<usize> = (0..256).map(|g| s.home(FuncId(g))).collect();

        s.on_shard_removed(victim);
        // The victim owns no ring points: nothing homes there, and the
        // observed function re-homes deterministically.
        let new_home = s.home(f);
        assert_ne!(new_home, victim);
        for g in 0..256 {
            assert_ne!(s.home(FuncId(g)), victim, "ring not healed for {g}");
        }
        // Consistent hashing: functions homed elsewhere are untouched.
        for (g, &h) in homes_before.iter().enumerate() {
            if h != victim {
                assert_eq!(s.home(FuncId(g as u32)), h, "home of {g} moved");
            }
        }
        // Shares renormalize over the 3 survivors.
        let live_total: f64 = s.shares.iter().sum();
        assert!((live_total - 1.0).abs() < 1e-12);
        assert_eq!(s.shares[victim], 0.0);

        // Rejoin restores the construction ring bit-for-bit.
        s.on_shard_added(victim);
        assert_eq!(s.ring, original_ring);
        assert_eq!(s.home(f), victim);
        for (g, &h) in homes_before.iter().enumerate() {
            assert_eq!(s.home(FuncId(g as u32)), h);
        }
    }

    #[test]
    fn sticky_heal_is_capacity_weighted() {
        // Kill the fat shard of a weighted ring: its ~4/7 arc re-homes
        // across the survivors in proportion to *their* weights, and
        // the surviving shares renormalize over live capacity.
        let caps = [4.0, 1.0, 1.0, 1.0];
        let mut s = StickyCh::weighted(4, 1.25, 7, &caps);
        s.on_shard_removed(0);
        let mut owned = [0usize; 4];
        for f in 0..4096 {
            owned[s.home(FuncId(f))] += 1;
        }
        assert_eq!(owned[0], 0);
        assert!((s.shares[1] - 1.0 / 3.0).abs() < 1e-12);
        for o in &owned[1..] {
            assert!(*o > 0);
        }
    }

    #[test]
    fn weighted_ring_with_equal_capacities_matches_blind() {
        // The uniform-fleet equivalence backbone: equal capacities must
        // reproduce the blind ring bit-for-bit — homes, routes, spills.
        let caps = vec![1.25f64; 8];
        let weighted = StickyCh::weighted(8, 1.25, 7, &caps);
        let blind = StickyCh::new(8, 1.25, 7);
        assert_eq!(weighted.ring, blind.ring);
        for f in 0..256 {
            assert_eq!(weighted.home(FuncId(f)), blind.home(FuncId(f)));
        }
        let w = RouterKind::StickyCh.build(4, 1.25, 3, &[2.0; 4]);
        let b = RouterKind::StickyChBlind.build(4, 1.25, 3, &[2.0; 4]);
        let mut d = vec![0usize; 4];
        for f in 0..64 {
            let l = loads(&d);
            let pw = w.route(FuncId(f), &l);
            assert_eq!(pw, b.route(FuncId(f), &l));
            d[pw] += 1; // build up skewed depths as we go
        }
        assert_eq!(w.spills(), b.spills());
    }

    #[test]
    fn weighted_ring_skews_homes_toward_fat_shards() {
        // 4× capacity on shard 0: it should own roughly 4/7 of the
        // function space instead of 1/4.
        let caps = [4.0, 1.0, 1.0, 1.0];
        let s = StickyCh::weighted(4, 1.25, 7, &caps);
        let mut owned = [0usize; 4];
        let n_funcs = 4096;
        for f in 0..n_funcs {
            owned[s.home(FuncId(f))] += 1;
        }
        let fat_share = owned[0] as f64 / n_funcs as f64;
        assert!(
            (0.45..0.70).contains(&fat_share),
            "fat shard owns {fat_share:.3}, expected ≈ 4/7"
        );
        for (i, &o) in owned.iter().enumerate().skip(1) {
            assert!(o > 0, "shard {i} owns nothing");
            assert!(o < owned[0], "shard {i} out-owns the fat shard");
        }
    }

    #[test]
    fn weighted_bound_protects_small_shards() {
        // Weighted StickyCh spills off a *small* home sooner than the
        // blind mean-depth bound would: depth 6 on a 1/8-capacity home
        // exceeds its weighted bound but sits below the blind mean.
        let caps = [4.0, 2.0, 1.0, 1.0];
        let s = StickyCh::weighted(4, 1.25, 7, &caps);
        // Find a function homed on a small shard (share 1/8) under
        // *both* rings, so the comparison isolates the bound.
        let blind_ring = StickyCh::new(4, 1.25, 7);
        let f = (0..1024)
            .map(FuncId)
            .find(|&f| s.home(f) >= 2 && blind_ring.home(f) == s.home(f))
            .expect("some function homes on a small shard in both rings");
        let home = s.home(f);
        let mut d = [8usize, 8, 0, 0];
        d[home] = 6; // total ≈ 22 ⇒ weighted bound ≈ ceil(1.25·23/8) = 4
        let l = loads_cap(&[
            (d[0], 4.0),
            (d[1], 2.0),
            (d[2], 1.0),
            (d[3], 1.0),
        ]);
        let picked = s.route(f, &l);
        assert_ne!(picked, home, "small overloaded home must shed load");
        assert_eq!(s.spills(), 1);
        // Blind bound: ceil(1.25·23/4) = 8 > 6 ⇒ stays home.
        let blind = RouterKind::StickyChBlind.build(4, 1.25, 7, &[]);
        assert_eq!(blind.route(f, &l), home);
    }
}
