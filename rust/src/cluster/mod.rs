//! Sharded multi-server control plane with locality-aware routing.
//!
//! MQFQ-Sticky (§5) exploits warm locality *within* one server; this
//! module scales that out: a [`Cluster`] is N independent
//! [`ControlPlane`] shards — each with its own MQFQ-Sticky dispatcher,
//! device pool and container warm pool — behind a pluggable front-end
//! [`Router`]. Nothing is shared between shards (no cross-shard queue,
//! no shared pool), exactly like independent servers behind a load
//! balancer; the *only* cluster-level decision is which shard an
//! arrival lands on.
//!
//! # Heterogeneous shards
//!
//! Shards need not be identical hardware: [`ClusterConfig::shard_planes`]
//! gives every shard its own [`PlaneConfig`] (fleet of
//! [`crate::gpu::DeviceSpec`]s, D level, pool size, ...), so a 4×V100
//! server can sit behind the same front end as a single MIG-sliced A30.
//! Each shard's static service capacity
//! ([`PlaneConfig::fleet_capacity`], V100-equivalents) is exposed to
//! the router through [`router::ShardLoad::capacity`]; the fig10
//! heterogeneity sweep (`experiments::hetero`) measures how much
//! capacity-aware routing buys on skewed fleets.
//!
//! # Routing policies
//!
//! * [`router::RoundRobin`] — cycle shards; load- and locality-blind.
//! * [`router::Random`] — seeded uniform choice; the classic stateless
//!   load balancer.
//! * [`router::LeastLoaded`] — smallest capacity-normalized
//!   `pending() + in_flight()` depth; load-aware but locality-blind.
//! * [`router::StickyCh`] — capacity-weighted consistent hashing with
//!   bounded loads: every function has a load-independent *home shard*
//!   (warm locality) on a ring where a shard's arc scales with its
//!   capacity, spilling clockwise only while the home's depth is
//!   at/above its capacity share of `load_factor ×` the cluster depth.
//!   This is the cluster-level analog of the paper's per-GPU sticky
//!   placement, and the reason the fig9 sweep shows it with a lower
//!   cold-start ratio than the spray routers.
//! * [`router::RouterKind::StickyChBlind`] — the same ring with
//!   capacities ignored; the ablation baseline the fig10 gate compares
//!   against (identical to StickyCh when shards are uniform).
//!
//! # Elastic membership
//!
//! The fleet is *not* fixed at startup. Shard indices are — `n_shards`
//! is capacity, never renumbered — but each slot carries a
//! [`crate::api::ShardHealth`] that membership verbs flip in place:
//!
//! * **drain** ([`Cluster::drain_shard`]) — the shard stops receiving
//!   new work (its [`ShardLoad::routable`] flag drops and, for
//!   [`router::StickyCh`], its capacity-weighted vnodes leave the ring
//!   so its arc re-homes deterministically); queued and in-flight
//!   invocations run to completion on the draining plane.
//! * **join** ([`Cluster::join_shard`]) — a drained or dead shard
//!   rejoins: exactly its original vnodes are reinserted, so every
//!   function homed elsewhere keeps its home (the consistent-hashing
//!   guarantee); a previously dead shard comes back with a cold plane
//!   and rebuilds warm locality from scratch.
//! * **kill** ([`Cluster::kill_shard`]) — abrupt failure: the shard's
//!   plane is discarded (its still-queued/in-flight invocations are
//!   *lost*, reported back to the caller — never silently requeued),
//!   its completed-invocation records are preserved in a graveyard
//!   recorder, and its **epoch** is bumped.
//!
//! The per-shard epoch is the replay-safety device: a rebuilt plane
//! restarts invocation ids at 0, so a completion event scheduled before
//! the kill could otherwise be delivered to an unrelated new invocation
//! with the same id. Drivers stamp every scheduled completion with
//! [`Cluster::shard_epoch`] at schedule time and drop events whose
//! epoch no longer matches. The wall-clock serving analog
//! ([`crate::server::RtCluster`]) applies the same rule under its
//! timer, and additionally resolves every stranded ticket to
//! [`crate::api::ApiError::ShardLost`].
//!
//! The last live shard can be neither drained nor killed: a cluster
//! that cannot accept work would turn every submit into an error with
//! no recovery path short of a join that could no longer be requested
//! through a (now dead) serving surface.
//!
//! # Determinism contract
//!
//! A cluster replay is a pure function of (workload, trace,
//! [`ClusterConfig`]): routers are seeded PRNG/state machines, shards
//! are deterministic control planes, and the discrete-event engine
//! ([`crate::sim::replay_cluster`]) orders same-instant events by a
//! stable (time, sequence) key on one global virtual clock — per-shard
//! completions and monitor ticks interleave identically across runs.
//! Monitor ticks fire on the global cadence and are delivered to every
//! shard that has work (idle shards are skipped, as in the single-plane
//! engine). With `n_shards == 1` every router degenerates to shard 0
//! and the replay is event-for-event identical to [`crate::sim::replay`]
//! (property-tested in `rust/tests/prop_cluster.rs`). Membership events
//! extend the contract: they are part of the input script (the elastic
//! harness drives them at fixed virtual times), so a storm replays
//! bit-identically too.

pub mod router;

pub use router::{Router, RouterKind, ShardLoad, ALL_ROUTERS};

use std::sync::Arc;

use crate::api::ShardHealth;
use crate::container::pool::PoolStats;
use crate::fault::{AdmitError, FaultFate, FaultStats};
use crate::metrics::{InvRecord, Recorder};
use crate::plane::{ControlPlane, PlaneConfig};
use crate::sim::{ShardDispatch, SimTarget};
use crate::telemetry::{EventKind, Telemetry, TraceEvent};
use crate::types::{FuncId, GpuId, InvocationId, Nanos};
use crate::workload::Workload;

/// Cluster-level configuration: shard count, routing policy, and the
/// shard hardware — one shared plane config, or one per shard.
#[derive(Clone)]
pub struct ClusterConfig {
    pub n_shards: usize,
    pub router: RouterKind,
    /// Control-plane config every shard clones when [`Self::shard_planes`]
    /// is empty (policy, fleet, pool, ...).
    pub plane: PlaneConfig,
    /// Heterogeneous cluster: explicit per-shard plane configs (must
    /// hold exactly `n_shards` entries). Empty ⇒ a uniform cluster of
    /// [`Self::plane`] clones.
    pub shard_planes: Vec<PlaneConfig>,
    /// [`router::StickyCh`] bounded-load spill factor (≥ 1.0 keeps some
    /// locality; large values never spill). Ignored by other routers.
    pub load_factor: f64,
    /// Seed for the Random router and the StickyCh ring layout.
    pub seed: u64,
    /// Bound on the kill graveyard ([`Cluster::merged_recorder`]'s
    /// salvage of completed records from killed shards). A long-lived
    /// cluster riding repeated kills would otherwise grow the graveyard
    /// without limit; past the cap the *oldest* records (by completion
    /// time) are evicted and counted in
    /// [`Cluster::graveyard_evicted`]. The default is far above any
    /// harness's completed-work volume, so record-conservation
    /// assertions (e.g. the elastic storm's `records_match`) never see
    /// an eviction.
    pub graveyard_cap: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            n_shards: 4,
            router: RouterKind::StickyCh,
            plane: PlaneConfig::default(),
            shard_planes: Vec::new(),
            load_factor: 1.25,
            seed: 0,
            graveyard_cap: 65_536,
        }
    }
}

impl ClusterConfig {
    /// The plane config shard `shard` runs.
    pub fn plane_for(&self, shard: usize) -> &PlaneConfig {
        if self.shard_planes.is_empty() {
            &self.plane
        } else {
            &self.shard_planes[shard]
        }
    }

    /// Per-shard static service capacity (V100-equivalents), the
    /// weights behind capacity-aware routing.
    pub fn shard_capacities(&self) -> Vec<f64> {
        (0..self.n_shards)
            .map(|s| self.plane_for(s).fleet_capacity())
            .collect()
    }
}

/// N independent control-plane shards behind one front-end router.
///
/// Entry points mirror [`ControlPlane`]'s clock-agnostic API, with a
/// shard index added wherever an invocation must be identified
/// (invocation ids are per-shard; `(shard, InvocationId)` is the
/// cluster-unique key).
pub struct Cluster {
    pub cfg: ClusterConfig,
    pub shards: Vec<ControlPlane>,
    router: Box<dyn Router>,
    /// Per-shard fleet capacity (V100-equivalents), precomputed for the
    /// router's [`ShardLoad`] snapshots.
    capacities: Vec<f64>,
    /// Arrivals routed to each shard (routing-skew diagnostics).
    pub routed: Vec<u64>,
    /// Kept for plane rebuilds after a kill (every shard registers the
    /// full workload).
    workload: Workload,
    /// Per-shard lifecycle state (see module docs, *Elastic membership*).
    health: Vec<ShardHealth>,
    /// Per-shard kill counter: completion events stamped with an older
    /// epoch must be dropped by the driver, not delivered.
    epochs: Vec<u64>,
    /// Completed-invocation records salvaged from killed shards, merged
    /// into [`Self::merged_recorder`] so kills never un-count finished
    /// work. Bounded by [`ClusterConfig::graveyard_cap`].
    graveyard: Recorder,
    /// Oldest-first records evicted from the graveyard once it
    /// overflowed [`ClusterConfig::graveyard_cap`] — the exact count of
    /// completed invocations [`Self::merged_recorder`] no longer holds.
    pub graveyard_evicted: u64,
    /// Shared telemetry (None when not attached). Every shard plane
    /// holds a [`crate::telemetry::ShardSink`] onto the same instance.
    tel: Option<Arc<Telemetry>>,
    /// Router spill count at the last arrival, so each arrival can tag
    /// its `route` event with "did *this* decision spill".
    last_spills: u64,
    /// Timestamp of the last clock-bearing call; membership verbs have
    /// no `now` parameter, so their trace events are stamped with this.
    last_now: Nanos,
}

impl Cluster {
    /// Build `cfg.n_shards` shards, each registering the full workload
    /// (any function may run anywhere — placement is the router's call).
    pub fn new(workload: Workload, cfg: ClusterConfig) -> Self {
        assert!(cfg.n_shards >= 1, "cluster needs at least one shard");
        assert!(
            cfg.shard_planes.is_empty() || cfg.shard_planes.len() == cfg.n_shards,
            "shard_planes must be empty or hold one config per shard"
        );
        let capacities = cfg.shard_capacities();
        let router = cfg
            .router
            .build(cfg.n_shards, cfg.load_factor, cfg.seed, &capacities);
        let shards: Vec<ControlPlane> = (0..cfg.n_shards)
            .map(|s| ControlPlane::new(workload.clone(), cfg.plane_for(s).clone()))
            .collect();
        Self {
            routed: vec![0; cfg.n_shards],
            capacities,
            router,
            shards,
            health: vec![ShardHealth::Up; cfg.n_shards],
            epochs: vec![0; cfg.n_shards],
            graveyard: Recorder::new(),
            graveyard_evicted: 0,
            tel: None,
            last_spills: 0,
            last_now: 0,
            workload,
            cfg,
        }
    }

    /// Attach a shared telemetry instance: every shard plane gets a
    /// [`crate::telemetry::ShardSink`] carrying its index, and the
    /// cluster itself emits `route`/`epoch` events. Pure observation —
    /// routing and scheduling are unchanged.
    pub fn attach_telemetry(&mut self, tel: Arc<Telemetry>) {
        for (s, plane) in self.shards.iter_mut().enumerate() {
            plane.attach_telemetry(tel.clone(), s as u32);
        }
        self.last_spills = self.router.spills();
        self.tel = Some(tel);
    }

    pub fn telemetry(&self) -> Option<&Arc<Telemetry>> {
        self.tel.as_ref()
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn router_name(&self) -> &'static str {
        self.router.name()
    }

    /// Invocations routed off their home shard (StickyCh only; 0 else).
    pub fn spills(&self) -> u64 {
        self.router.spills()
    }

    /// Queued (undispatched) invocations across all shards.
    pub fn pending(&self) -> usize {
        self.shards.iter().map(|p| p.pending()).sum()
    }

    /// Executing invocations across all shards.
    pub fn in_flight(&self) -> usize {
        self.shards.iter().map(|p| p.in_flight()).sum()
    }

    /// Per-shard fleet capacities (V100-equivalents).
    pub fn capacities(&self) -> &[f64] {
        &self.capacities
    }

    fn loads(&self) -> Vec<ShardLoad> {
        self.shards
            .iter()
            .enumerate()
            .map(|(s, p)| ShardLoad {
                pending: p.pending(),
                in_flight: p.in_flight(),
                capacity: self.capacities[s],
                routable: self.health[s] == ShardHealth::Up,
            })
            .collect()
    }

    // --- elastic membership -----------------------------------------

    pub fn shard_health(&self, shard: usize) -> ShardHealth {
        self.health[shard]
    }

    /// Current kill epoch of `shard`. Drivers stamp scheduled
    /// completions with this and drop events whose stamp no longer
    /// matches at delivery time (see module docs).
    pub fn shard_epoch(&self, shard: usize) -> u64 {
        self.epochs[shard]
    }

    fn live_count(&self) -> usize {
        self.health
            .iter()
            .filter(|&&h| h == ShardHealth::Up)
            .count()
    }

    /// Stop routing new work to `shard`; its queued/in-flight
    /// invocations run to completion. Idempotent on an already-draining
    /// shard; refused for a dead shard or the last live one.
    pub fn drain_shard(&mut self, shard: usize) -> Result<(), String> {
        if shard >= self.shards.len() {
            return Err(format!("no shard {shard}"));
        }
        match self.health[shard] {
            ShardHealth::Draining => Ok(()),
            ShardHealth::Dead => Err(format!("shard {shard} is dead; join it first")),
            ShardHealth::Up => {
                if self.live_count() <= 1 {
                    return Err("cannot drain the last live shard".into());
                }
                self.health[shard] = ShardHealth::Draining;
                self.router.on_shard_removed(shard);
                Ok(())
            }
        }
    }

    /// (Re)insert `shard` into the routable set. A drained shard
    /// resumes with its warm pool intact; a killed shard comes back
    /// cold (its plane was rebuilt at kill time). Idempotent on an Up
    /// shard.
    pub fn join_shard(&mut self, shard: usize) -> Result<(), String> {
        if shard >= self.shards.len() {
            return Err(format!("no shard {shard}"));
        }
        if self.health[shard] != ShardHealth::Up {
            self.health[shard] = ShardHealth::Up;
            self.router.on_shard_added(shard);
        }
        Ok(())
    }

    /// Abrupt failure of `shard`: every invocation still queued or
    /// in flight there is lost (the count is returned — the caller
    /// decides whether to resubmit; nothing is requeued silently), its
    /// completed-invocation records move to the graveyard recorder, its
    /// plane is rebuilt cold, and its epoch is bumped so stale
    /// completion events are dropped rather than delivered to id-reusing
    /// new invocations. Refused for the last live shard.
    pub fn kill_shard(&mut self, shard: usize) -> Result<usize, String> {
        if shard >= self.shards.len() {
            return Err(format!("no shard {shard}"));
        }
        if self.health[shard] == ShardHealth::Dead {
            return Err(format!("shard {shard} is already dead"));
        }
        if self.health[shard] == ShardHealth::Up && self.live_count() <= 1 {
            return Err("cannot kill the last live shard".into());
        }
        let lost = self.shards[shard].pending() + self.shards[shard].in_flight();
        let mut fresh = ControlPlane::new(
            self.workload.clone(),
            self.cfg.plane_for(shard).clone(),
        );
        if let Some(tel) = &self.tel {
            fresh.attach_telemetry(tel.clone(), shard as u32);
        }
        let dead = std::mem::replace(&mut self.shards[shard], fresh);
        self.graveyard.merge(&dead.recorder);
        if self.graveyard.len() > self.cfg.graveyard_cap {
            // Bound the salvage: keep the newest `graveyard_cap`
            // records by completion time, count exactly what was lost.
            self.graveyard.sort_by_time();
            let excess = self.graveyard.len() - self.cfg.graveyard_cap;
            self.graveyard.records.drain(..excess);
            self.graveyard_evicted += excess as u64;
        }
        let was_up = self.health[shard] == ShardHealth::Up;
        self.health[shard] = ShardHealth::Dead;
        self.epochs[shard] += 1;
        if let Some(tel) = &self.tel {
            tel.emit(
                TraceEvent::new(self.last_now, EventKind::Epoch, shard as u32)
                    .a(self.epochs[shard] as i64)
                    .b(lost as i64),
            );
        }
        if was_up {
            self.router.on_shard_removed(shard);
        }
        Ok(lost)
    }

    /// Route and ingest one arrival. Returns the chosen shard, the
    /// shard-local invocation id, and any dispatches it unlocked.
    pub fn on_arrival(
        &mut self,
        func: FuncId,
        now: Nanos,
    ) -> (usize, InvocationId, Vec<ShardDispatch>) {
        self.last_now = now;
        let loads = self.loads();
        let shard = self.router.route(func, &loads);
        debug_assert!(shard < self.shards.len(), "router out of range");
        self.routed[shard] += 1;
        if let Some(tel) = &self.tel {
            let spills = self.router.spills();
            let spilled = spills > self.last_spills;
            self.last_spills = spills;
            if spilled {
                tel.registry.shard(shard as u32).spills.inc();
            }
            tel.emit(
                TraceEvent::new(now, EventKind::Route, shard as u32)
                    .func(func.0)
                    .a(self.epochs[shard] as i64)
                    .b(spilled as i64),
            );
        }
        let (id, ds) = self.shards[shard].on_arrival(func, now);
        (shard, id, tag(shard, ds))
    }

    /// An invocation completed on `shard` at `now`. Returns the
    /// completed invocation's own [`InvRecord`] (the wall-clock driver's
    /// completion-matching handle — see [`ControlPlane::on_complete`])
    /// plus any dispatches it unlocked.
    pub fn on_complete(
        &mut self,
        shard: usize,
        inv: InvocationId,
        now: Nanos,
    ) -> (Option<InvRecord>, Vec<ShardDispatch>) {
        self.last_now = now;
        let (rec, ds) = self.shards[shard].on_complete(inv, now);
        (rec, tag(shard, ds))
    }

    /// Attempt-stamped completion (see
    /// [`ControlPlane::on_complete_attempt`]): a completion whose
    /// attempt no longer matches the live in-flight attempt — the
    /// invocation was evacuated off a failed device or re-queued after
    /// a fault — is dropped rather than mis-settled.
    pub fn on_complete_attempt(
        &mut self,
        shard: usize,
        inv: InvocationId,
        attempt: u32,
        now: Nanos,
    ) -> (Option<InvRecord>, Vec<ShardDispatch>) {
        self.last_now = now;
        let (rec, ds) = self.shards[shard].on_complete_attempt(inv, attempt, now);
        (rec, tag(shard, ds))
    }

    // --- fault-tolerance pass-throughs ------------------------------

    /// Admission gate for `shard` (breaker + overload shed); a no-op
    /// `Ok(())` when the shard has no fault plan.
    pub fn try_admit(
        &mut self,
        shard: usize,
        func: FuncId,
        now: Nanos,
    ) -> Result<(), AdmitError> {
        self.shards[shard].try_admit(func, now)
    }

    /// Drop one device out of `shard`'s pool (operator-driven fault
    /// injection; scheduled failures in a [`crate::fault::FaultConfig`]
    /// fire from each shard's own monitor tick instead).
    pub fn fail_device(&mut self, shard: usize, gpu: GpuId, now: Nanos) -> Vec<ShardDispatch> {
        self.last_now = now;
        let ds = self.shards[shard].fail_device(gpu, now);
        tag(shard, ds)
    }

    /// Return a failed device on `shard` to service (cold: its warm
    /// pool died with it).
    pub fn heal_device(&mut self, shard: usize, gpu: GpuId, now: Nanos) -> Vec<ShardDispatch> {
        self.last_now = now;
        let ds = self.shards[shard].heal_device(gpu, now);
        tag(shard, ds)
    }

    /// Drain every shard's resolved retry-exhaustions, tagged with the
    /// shard they died on.
    pub fn drain_fault_fates(&mut self) -> Vec<(usize, FaultFate)> {
        let mut out = Vec::new();
        for (s, p) in self.shards.iter_mut().enumerate() {
            out.extend(p.drain_fault_fates().into_iter().map(|f| (s, f)));
        }
        out
    }

    /// Field-wise sum of every shard's fault counters.
    pub fn fault_stats(&self) -> FaultStats {
        let mut t = FaultStats::default();
        for p in &self.shards {
            let s = p.fault_stats();
            t.faults_device += s.faults_device;
            t.faults_transient += s.faults_transient;
            t.faults_straggler += s.faults_straggler;
            t.retries += s.retries;
            t.retry_exhausted += s.retry_exhausted;
            t.breaker_trips += s.breaker_trips;
            t.breaker_probes += s.breaker_probes;
            t.quarantined += s.quarantined;
            t.shed += s.shed;
        }
        t
    }

    /// Global monitor tick: delivered to every shard that has work
    /// (pending or in flight), in shard order.
    pub fn on_monitor_tick(&mut self, now: Nanos) -> Vec<ShardDispatch> {
        self.last_now = now;
        let mut out = Vec::new();
        for (s, plane) in self.shards.iter_mut().enumerate() {
            if plane.pending() > 0 || plane.in_flight() > 0 {
                out.extend(tag(s, plane.on_monitor_tick(now)));
            }
        }
        out
    }

    /// Exact utilization-integral touch on one shard (sim engine).
    pub fn touch(&mut self, shard: usize, now: Nanos) {
        self.shards[shard].touch(now);
    }

    /// Summed warm-pool stats across shards (cluster cold-start ratio).
    pub fn pool_stats(&self) -> PoolStats {
        let mut total = PoolStats::default();
        for p in &self.shards {
            let s = p.pool_stats();
            total.cold += s.cold;
            total.host_warm += s.host_warm;
            total.gpu_warm += s.gpu_warm;
        }
        total
    }

    /// Mean device utilization across every shard's devices at `now`.
    pub fn mean_utilization(&mut self, now: Nanos) -> f64 {
        if self.shards.is_empty() {
            return 0.0;
        }
        let sum: f64 = self
            .shards
            .iter_mut()
            .map(|p| p.mean_utilization(now))
            .sum();
        sum / self.shards.len() as f64
    }

    /// Cluster-level recorder: every shard's records merged — plus the
    /// graveyard salvaged from killed shards, so a kill never un-counts
    /// finished work — sorted by completion time (stable: same-instant
    /// ties keep shard order).
    pub fn merged_recorder(&self) -> Recorder {
        let mut out = Recorder::new();
        out.merge(&self.graveyard);
        for p in &self.shards {
            out.merge(&p.recorder);
        }
        out.sort_by_time();
        out
    }

    /// Largest per-shard share of arrivals relative to a perfectly even
    /// split (1.0 = balanced; n = everything on one shard of n).
    pub fn routing_imbalance(&self) -> f64 {
        let total: u64 = self.routed.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let max = *self.routed.iter().max().unwrap() as f64;
        max / (total as f64 / self.routed.len() as f64)
    }
}

/// Tag a shard's dispatches with its index (shared with the sim
/// engine's single-plane target, which tags everything shard 0).
pub(crate) fn tag(shard: usize, ds: Vec<crate::plane::Dispatch>) -> Vec<ShardDispatch> {
    ds.into_iter()
        .map(|dispatch| ShardDispatch { shard, dispatch })
        .collect()
}

impl SimTarget for Cluster {
    fn busy(&self) -> bool {
        self.shards
            .iter()
            .any(|p| p.pending() > 0 || p.in_flight() > 0)
    }

    fn sim_arrival(&mut self, func: FuncId, now: Nanos) -> Vec<ShardDispatch> {
        let (_, _, ds) = self.on_arrival(func, now);
        ds
    }

    fn sim_complete(
        &mut self,
        shard: usize,
        inv: InvocationId,
        attempt: u32,
        now: Nanos,
    ) -> Vec<ShardDispatch> {
        self.on_complete_attempt(shard, inv, attempt, now).1
    }

    fn sim_tick(&mut self, now: Nanos) -> Vec<ShardDispatch> {
        self.on_monitor_tick(now)
    }

    fn sim_touch(&mut self, shard: usize, now: Nanos) {
        self.touch(shard, now);
    }

    fn sim_load(&self) -> (usize, usize) {
        (self.pending(), self.in_flight())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{secs, SEC};
    use crate::workload::catalog::by_name;

    fn workload3() -> Workload {
        let mut w = Workload::default();
        w.register(by_name("fft").unwrap(), 0, 1.0);
        w.register(by_name("imagenet").unwrap(), 0, 2.0);
        w.register(by_name("lud").unwrap(), 0, 1.0);
        w
    }

    fn cluster(n: usize, router: RouterKind) -> Cluster {
        Cluster::new(
            workload3(),
            ClusterConfig {
                n_shards: n,
                router,
                ..Default::default()
            },
        )
    }

    #[test]
    fn round_robin_spreads_arrivals() {
        let mut c = cluster(3, RouterKind::RoundRobin);
        for i in 0..6 {
            c.on_arrival(FuncId(0), i * SEC);
        }
        assert_eq!(c.routed, vec![2, 2, 2]);
        assert!((c.routing_imbalance() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sticky_concentrates_a_function() {
        let mut c = cluster(4, RouterKind::StickyCh);
        let mut shards_used = std::collections::HashSet::new();
        for i in 0..8 {
            let (s, _, ds) = c.on_arrival(FuncId(1), secs(i as f64 * 30.0));
            shards_used.insert(s);
            // Drain before the next arrival so every routing decision
            // sees an idle cluster (light load never spills).
            for sd in ds {
                c.on_complete(sd.shard, sd.dispatch.inv, sd.dispatch.complete_at);
            }
        }
        assert_eq!(shards_used.len(), 1, "light load must stay on the home shard");
        assert_eq!(c.spills(), 0);
        assert_eq!(c.routed.iter().filter(|&&n| n > 0).count(), 1);
    }

    #[test]
    fn completion_flows_back_through_the_right_shard() {
        let mut c = cluster(2, RouterKind::RoundRobin);
        let (s, _, ds) = c.on_arrival(FuncId(0), 0);
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].shard, s);
        assert_eq!(c.in_flight(), 1);
        let d = ds[0].dispatch;
        let (rec, more) = c.on_complete(s, d.inv, d.complete_at);
        assert_eq!(rec.unwrap().inv, d.inv);
        assert!(more.is_empty());
        assert_eq!(c.in_flight(), 0);
        assert_eq!(c.merged_recorder().len(), 1);
    }

    #[test]
    fn tick_skips_idle_shards() {
        let mut c = cluster(2, RouterKind::RoundRobin);
        c.on_arrival(FuncId(0), 0); // lands on shard 0
        c.on_monitor_tick(200 * crate::types::MS);
        assert_eq!(c.shards[0].recorder.util_timeline.len(), 1);
        assert!(c.shards[1].recorder.util_timeline.is_empty());
    }

    #[test]
    fn pool_stats_sum_over_shards() {
        let mut c = cluster(2, RouterKind::RoundRobin);
        // Same function on both shards: two cold starts cluster-wide.
        c.on_arrival(FuncId(0), 0);
        c.on_arrival(FuncId(0), 1);
        assert_eq!(c.pool_stats().cold, 2);
    }

    #[test]
    fn per_shard_planes_build_mixed_hardware() {
        use crate::gpu::{uniform_fleet, MultiplexMode, A30, V100};
        let planes = vec![
            PlaneConfig::uniform(2, V100, MultiplexMode::Plain),
            PlaneConfig::uniform(1, A30, MultiplexMode::Mig(2)),
        ];
        let mut c = Cluster::new(
            workload3(),
            ClusterConfig {
                n_shards: 2,
                router: RouterKind::LeastLoaded,
                shard_planes: planes,
                ..Default::default()
            },
        );
        // Capacities: 2×V100 = 2.0; one MIG-sliced A30 = 1/0.92.
        assert!((c.capacities()[0] - 2.0).abs() < 1e-12);
        assert!((c.capacities()[1] - 1.0 / 0.92).abs() < 1e-12);
        // LeastLoaded on an idle cluster: lowest index first, and the
        // MIG shard really exposes two slice vGPUs.
        let (s, _, ds) = c.on_arrival(FuncId(0), 0);
        assert_eq!(s, 0);
        assert_eq!(ds.len(), 1);
        assert_eq!(c.shards[1].device_utilizations(1).len(), 2);
        // Uniform default still applies when shard_planes is empty.
        let u = Cluster::new(
            workload3(),
            ClusterConfig {
                n_shards: 3,
                ..Default::default()
            },
        );
        assert_eq!(u.capacities(), &[1.0, 1.0, 1.0]);
        assert_eq!(
            u.cfg.plane_for(2).devices,
            uniform_fleet(1, V100, MultiplexMode::Plain)
        );
    }

    #[test]
    #[should_panic(expected = "shard_planes")]
    fn mismatched_shard_planes_rejected() {
        let cfg = ClusterConfig {
            n_shards: 3,
            shard_planes: vec![PlaneConfig::default()],
            ..Default::default()
        };
        Cluster::new(workload3(), cfg);
    }

    #[test]
    fn drain_stops_arrivals_and_rejoin_resumes_them() {
        let mut c = cluster(3, RouterKind::RoundRobin);
        c.drain_shard(1).unwrap();
        assert_eq!(c.shard_health(1), ShardHealth::Draining);
        for i in 0..6 {
            c.on_arrival(FuncId(0), i * SEC);
        }
        assert_eq!(c.routed[1], 0, "draining shard must receive nothing");
        assert_eq!(c.routed[0] + c.routed[2], 6);
        // Drain is idempotent; rejoin restores routing.
        c.drain_shard(1).unwrap();
        c.join_shard(1).unwrap();
        assert_eq!(c.shard_health(1), ShardHealth::Up);
        for i in 0..6 {
            c.on_arrival(FuncId(0), (6 + i) * SEC);
        }
        assert!(c.routed[1] > 0, "rejoined shard must route again");
    }

    #[test]
    fn kill_loses_queued_work_bumps_epoch_and_keeps_graveyard() {
        let mut c = cluster(2, RouterKind::RoundRobin);
        // Complete one invocation on shard 0, then queue another there.
        let (s0, _, ds) = c.on_arrival(FuncId(0), 0);
        assert_eq!(s0, 0);
        let d = ds[0].dispatch;
        c.on_complete(0, d.inv, d.complete_at);
        c.on_arrival(FuncId(0), d.complete_at + SEC); // shard 1 (RR)
        let (s2, _, _) = c.on_arrival(FuncId(0), d.complete_at + 2 * SEC);
        assert_eq!(s2, 0);
        assert_eq!(c.shards[0].recorder.len(), 1);

        let lost = c.kill_shard(0).unwrap();
        assert_eq!(lost, 1, "the queued invocation is lost");
        assert_eq!(c.shard_health(0), ShardHealth::Dead);
        assert_eq!(c.shard_epoch(0), 1);
        assert_eq!(c.shards[0].pending() + c.shards[0].in_flight(), 0);
        // Finished work survives the kill via the graveyard.
        assert_eq!(c.shards[0].recorder.len(), 0);
        assert_eq!(c.merged_recorder().len(), 1);
        // Dead shards take no traffic; double-kill and drain are refused.
        for i in 0..4 {
            let (s, _, _) = c.on_arrival(FuncId(0), secs(100.0 + i as f64));
            assert_eq!(s, 1);
        }
        assert!(c.kill_shard(0).is_err());
        assert!(c.drain_shard(0).is_err());
        // Rejoin brings it back (cold) and routable.
        c.join_shard(0).unwrap();
        assert_eq!(c.shard_health(0), ShardHealth::Up);
        assert_eq!(c.shard_epoch(0), 1, "join does not bump the epoch");
        let before = c.routed[0];
        for i in 0..4 {
            c.on_arrival(FuncId(0), secs(200.0 + i as f64));
        }
        assert!(c.routed[0] > before);
    }

    #[test]
    fn graveyard_is_bounded_and_evicts_oldest_first() {
        let mut c = Cluster::new(
            workload3(),
            ClusterConfig {
                n_shards: 3,
                router: RouterKind::RoundRobin,
                graveyard_cap: 1,
                ..Default::default()
            },
        );
        // One completed record per shard, at strictly increasing times
        // (RR: arrival i lands on shard i).
        let mut completions = Vec::new();
        for i in 0..3u64 {
            let (s, _, ds) = c.on_arrival(FuncId(0), i * SEC);
            assert_eq!(s, i as usize);
            let d = ds[0].dispatch;
            c.on_complete(s, d.inv, d.complete_at);
            completions.push(d.complete_at);
        }
        // First kill fits under the cap; the second overflows it and
        // must evict exactly the older record.
        c.kill_shard(0).unwrap();
        assert_eq!(c.graveyard_evicted, 0);
        assert_eq!(c.merged_recorder().len(), 3);
        c.kill_shard(1).unwrap();
        assert_eq!(c.graveyard_evicted, 1, "exact eviction count");
        let merged = c.merged_recorder();
        assert_eq!(merged.len(), 2, "cap keeps one salvaged + one live record");
        // The survivor in the graveyard is the *newest* killed record.
        assert!(merged.records.iter().any(|r| r.completed == completions[1]));
        assert!(
            merged.records.iter().all(|r| r.completed != completions[0]),
            "oldest record must be the one evicted"
        );
        // Default cap is effectively unbounded for harness volumes.
        assert_eq!(ClusterConfig::default().graveyard_cap, 65_536);
    }

    #[test]
    fn last_live_shard_is_protected() {
        let mut c = cluster(2, RouterKind::RoundRobin);
        c.drain_shard(0).unwrap();
        assert!(c.drain_shard(1).is_err());
        assert!(c.kill_shard(1).is_err());
        // Draining shards still count as killable (they are not live).
        c.kill_shard(0).unwrap();
        assert!(c.kill_shard(1).is_err(), "shard 1 is the only live one");
        assert!(c.join_shard(0).is_ok());
        assert!(c.kill_shard(1).is_ok(), "shard 0 is live again");
    }

    #[test]
    fn membership_verbs_reject_out_of_range_shards() {
        let mut c = cluster(2, RouterKind::StickyCh);
        assert!(c.drain_shard(2).is_err());
        assert!(c.join_shard(9).is_err());
        assert!(c.kill_shard(7).is_err());
    }

    #[test]
    fn sticky_rehomes_off_a_drained_shard() {
        let mut c = cluster(4, RouterKind::StickyCh);
        let (home, _, ds) = c.on_arrival(FuncId(1), 0);
        for sd in ds {
            c.on_complete(sd.shard, sd.dispatch.inv, sd.dispatch.complete_at);
        }
        c.drain_shard(home).unwrap();
        let (s, _, _) = c.on_arrival(FuncId(1), secs(60.0));
        assert_ne!(s, home, "ring healing must re-home off the drained arc");
        // Rejoin restores the original home (exact-vnode reinsertion).
        c.join_shard(home).unwrap();
        let (s2, _, _) = c.on_arrival(FuncId(1), secs(6000.0));
        assert_eq!(s2, home);
    }

    #[test]
    fn telemetry_emits_route_and_epoch_events() {
        let mut c = cluster(2, RouterKind::RoundRobin);
        let (classes, _) = crate::telemetry::workload_classes(&c.workload);
        let devs: Vec<usize> = (0..c.n_shards())
            .map(|s| c.cfg.plane_for(s).n_devices())
            .collect();
        let tel = Arc::new(Telemetry::new(&devs, &classes));
        c.attach_telemetry(tel.clone());
        let (s0, _, ds) = c.on_arrival(FuncId(0), SEC); // shard 0 (RR)
        for sd in ds {
            c.on_complete(sd.shard, sd.dispatch.inv, sd.dispatch.complete_at);
        }
        c.on_arrival(FuncId(0), 2 * SEC); // shard 1 (RR)
        c.kill_shard(1).unwrap();
        assert_eq!(tel.registry.shard(0).submitted.get(), 1);
        assert_eq!(tel.registry.shard(1).submitted.get(), 1);
        assert_eq!(tel.registry.shard(0).completed.get(), 1);
        let evs = tel.trace.drain(100_000);
        let routes: Vec<_> = evs.iter().filter(|e| e.kind == EventKind::Route).collect();
        assert_eq!(routes.len(), 2);
        assert_eq!(routes[0].shard, s0 as u32);
        assert_eq!(routes[0].func, 0);
        assert_eq!(routes[0].a, 0, "pre-kill epoch is 0");
        let epochs: Vec<_> = evs.iter().filter(|e| e.kind == EventKind::Epoch).collect();
        assert_eq!(epochs.len(), 1);
        assert_eq!(epochs[0].shard, 1);
        assert_eq!(epochs[0].a, 1, "epoch bumped to 1");
        assert_eq!(epochs[0].b, 1, "one invocation lost");
        assert_eq!(epochs[0].at, 2 * SEC, "stamped with the last clocked call");
        // The rebuilt plane is re-instrumented: new work still counts.
        c.join_shard(1).unwrap();
        c.drain_shard(0).unwrap();
        c.on_arrival(FuncId(0), 3 * SEC);
        assert_eq!(tel.registry.shard(1).submitted.get(), 2);
    }

    #[test]
    fn single_shard_pending_in_flight_match_plane() {
        let mut c = cluster(1, RouterKind::LeastLoaded);
        for i in 0..5 {
            c.on_arrival(FuncId(0), i);
        }
        assert_eq!(c.pending(), c.shards[0].pending());
        assert_eq!(c.in_flight(), c.shards[0].in_flight());
    }
}
