//! Sharded multi-server control plane with locality-aware routing.
//!
//! MQFQ-Sticky (§5) exploits warm locality *within* one server; this
//! module scales that out: a [`Cluster`] is N independent
//! [`ControlPlane`] shards — each with its own MQFQ-Sticky dispatcher,
//! device pool and container warm pool — behind a pluggable front-end
//! [`Router`]. Nothing is shared between shards (no cross-shard queue,
//! no shared pool), exactly like independent servers behind a load
//! balancer; the *only* cluster-level decision is which shard an
//! arrival lands on.
//!
//! # Routing policies
//!
//! * [`router::RoundRobin`] — cycle shards; load- and locality-blind.
//! * [`router::Random`] — seeded uniform choice; the classic stateless
//!   load balancer.
//! * [`router::LeastLoaded`] — smallest `pending() + in_flight()`
//!   depth; load-aware but locality-blind.
//! * [`router::StickyCh`] — consistent hashing with bounded loads:
//!   every function has a load-independent *home shard* (warm
//!   locality), spilling clockwise along the hash ring only while the
//!   home's depth is at/above `load_factor ×` the cluster-mean depth.
//!   This is the cluster-level analog of the paper's per-GPU sticky
//!   placement, and the reason the fig9 sweep shows it with a lower
//!   cold-start ratio than the spray routers.
//!
//! # Determinism contract
//!
//! A cluster replay is a pure function of (workload, trace,
//! [`ClusterConfig`]): routers are seeded PRNG/state machines, shards
//! are deterministic control planes, and the discrete-event engine
//! ([`crate::sim::replay_cluster`]) orders same-instant events by a
//! stable (time, sequence) key on one global virtual clock — per-shard
//! completions and monitor ticks interleave identically across runs.
//! Monitor ticks fire on the global cadence and are delivered to every
//! shard that has work (idle shards are skipped, as in the single-plane
//! engine). With `n_shards == 1` every router degenerates to shard 0
//! and the replay is event-for-event identical to [`crate::sim::replay`]
//! (property-tested in `rust/tests/prop_cluster.rs`).

pub mod router;

pub use router::{Router, RouterKind, ShardLoad, ALL_ROUTERS};

use crate::container::pool::PoolStats;
use crate::metrics::Recorder;
use crate::plane::{ControlPlane, PlaneConfig};
use crate::sim::{ShardDispatch, SimTarget};
use crate::types::{FuncId, InvocationId, Nanos};
use crate::workload::Workload;

/// Cluster-level configuration: shard count, routing policy, and the
/// per-shard plane config (every shard is identical hardware).
#[derive(Clone)]
pub struct ClusterConfig {
    pub n_shards: usize,
    pub router: RouterKind,
    /// Per-shard control-plane config (policy, GPUs, pool, ...).
    pub plane: PlaneConfig,
    /// [`router::StickyCh`] bounded-load spill factor (≥ 1.0 keeps some
    /// locality; large values never spill). Ignored by other routers.
    pub load_factor: f64,
    /// Seed for the Random router and the StickyCh ring layout.
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            n_shards: 4,
            router: RouterKind::StickyCh,
            plane: PlaneConfig::default(),
            load_factor: 1.25,
            seed: 0,
        }
    }
}

/// N independent control-plane shards behind one front-end router.
///
/// Entry points mirror [`ControlPlane`]'s clock-agnostic API, with a
/// shard index added wherever an invocation must be identified
/// (invocation ids are per-shard; `(shard, InvocationId)` is the
/// cluster-unique key).
pub struct Cluster {
    pub cfg: ClusterConfig,
    pub shards: Vec<ControlPlane>,
    router: Box<dyn Router>,
    /// Arrivals routed to each shard (routing-skew diagnostics).
    pub routed: Vec<u64>,
}

impl Cluster {
    /// Build `cfg.n_shards` shards, each registering the full workload
    /// (any function may run anywhere — placement is the router's call).
    pub fn new(workload: Workload, cfg: ClusterConfig) -> Self {
        assert!(cfg.n_shards >= 1, "cluster needs at least one shard");
        let router = cfg.router.build(cfg.n_shards, cfg.load_factor, cfg.seed);
        let shards: Vec<ControlPlane> = (0..cfg.n_shards)
            .map(|_| ControlPlane::new(workload.clone(), cfg.plane.clone()))
            .collect();
        Self {
            routed: vec![0; cfg.n_shards],
            router,
            shards,
            cfg,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn router_name(&self) -> &'static str {
        self.router.name()
    }

    /// Invocations routed off their home shard (StickyCh only; 0 else).
    pub fn spills(&self) -> u64 {
        self.router.spills()
    }

    /// Queued (undispatched) invocations across all shards.
    pub fn pending(&self) -> usize {
        self.shards.iter().map(|p| p.pending()).sum()
    }

    /// Executing invocations across all shards.
    pub fn in_flight(&self) -> usize {
        self.shards.iter().map(|p| p.in_flight()).sum()
    }

    fn loads(&self) -> Vec<ShardLoad> {
        self.shards
            .iter()
            .map(|p| ShardLoad {
                pending: p.pending(),
                in_flight: p.in_flight(),
            })
            .collect()
    }

    /// Route and ingest one arrival. Returns the chosen shard, the
    /// shard-local invocation id, and any dispatches it unlocked.
    pub fn on_arrival(
        &mut self,
        func: FuncId,
        now: Nanos,
    ) -> (usize, InvocationId, Vec<ShardDispatch>) {
        let loads = self.loads();
        let shard = self.router.route(func, &loads);
        debug_assert!(shard < self.shards.len(), "router out of range");
        self.routed[shard] += 1;
        let (id, ds) = self.shards[shard].on_arrival(func, now);
        (shard, id, tag(shard, ds))
    }

    /// An invocation completed on `shard` at `now`.
    pub fn on_complete(
        &mut self,
        shard: usize,
        inv: InvocationId,
        now: Nanos,
    ) -> Vec<ShardDispatch> {
        tag(shard, self.shards[shard].on_complete(inv, now))
    }

    /// Global monitor tick: delivered to every shard that has work
    /// (pending or in flight), in shard order.
    pub fn on_monitor_tick(&mut self, now: Nanos) -> Vec<ShardDispatch> {
        let mut out = Vec::new();
        for (s, plane) in self.shards.iter_mut().enumerate() {
            if plane.pending() > 0 || plane.in_flight() > 0 {
                out.extend(tag(s, plane.on_monitor_tick(now)));
            }
        }
        out
    }

    /// Exact utilization-integral touch on one shard (sim engine).
    pub fn touch(&mut self, shard: usize, now: Nanos) {
        self.shards[shard].touch(now);
    }

    /// Summed warm-pool stats across shards (cluster cold-start ratio).
    pub fn pool_stats(&self) -> PoolStats {
        let mut total = PoolStats::default();
        for p in &self.shards {
            let s = p.pool_stats();
            total.cold += s.cold;
            total.host_warm += s.host_warm;
            total.gpu_warm += s.gpu_warm;
        }
        total
    }

    /// Mean device utilization across every shard's devices at `now`.
    pub fn mean_utilization(&mut self, now: Nanos) -> f64 {
        if self.shards.is_empty() {
            return 0.0;
        }
        let sum: f64 = self
            .shards
            .iter_mut()
            .map(|p| p.mean_utilization(now))
            .sum();
        sum / self.shards.len() as f64
    }

    /// Cluster-level recorder: every shard's records merged, sorted by
    /// completion time (stable: same-instant ties keep shard order).
    pub fn merged_recorder(&self) -> Recorder {
        let mut out = Recorder::new();
        for p in &self.shards {
            out.merge(&p.recorder);
        }
        out.sort_by_time();
        out
    }

    /// Largest per-shard share of arrivals relative to a perfectly even
    /// split (1.0 = balanced; n = everything on one shard of n).
    pub fn routing_imbalance(&self) -> f64 {
        let total: u64 = self.routed.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let max = *self.routed.iter().max().unwrap() as f64;
        max / (total as f64 / self.routed.len() as f64)
    }
}

/// Tag a shard's dispatches with its index (shared with the sim
/// engine's single-plane target, which tags everything shard 0).
pub(crate) fn tag(shard: usize, ds: Vec<crate::plane::Dispatch>) -> Vec<ShardDispatch> {
    ds.into_iter()
        .map(|dispatch| ShardDispatch { shard, dispatch })
        .collect()
}

impl SimTarget for Cluster {
    fn busy(&self) -> bool {
        self.shards
            .iter()
            .any(|p| p.pending() > 0 || p.in_flight() > 0)
    }

    fn sim_arrival(&mut self, func: FuncId, now: Nanos) -> Vec<ShardDispatch> {
        let (_, _, ds) = self.on_arrival(func, now);
        ds
    }

    fn sim_complete(&mut self, shard: usize, inv: InvocationId, now: Nanos) -> Vec<ShardDispatch> {
        self.on_complete(shard, inv, now)
    }

    fn sim_tick(&mut self, now: Nanos) -> Vec<ShardDispatch> {
        self.on_monitor_tick(now)
    }

    fn sim_touch(&mut self, shard: usize, now: Nanos) {
        self.touch(shard, now);
    }

    fn sim_load(&self) -> (usize, usize) {
        (self.pending(), self.in_flight())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{secs, SEC};
    use crate::workload::catalog::by_name;

    fn workload3() -> Workload {
        let mut w = Workload::default();
        w.register(by_name("fft").unwrap(), 0, 1.0);
        w.register(by_name("imagenet").unwrap(), 0, 2.0);
        w.register(by_name("lud").unwrap(), 0, 1.0);
        w
    }

    fn cluster(n: usize, router: RouterKind) -> Cluster {
        Cluster::new(
            workload3(),
            ClusterConfig {
                n_shards: n,
                router,
                ..Default::default()
            },
        )
    }

    #[test]
    fn round_robin_spreads_arrivals() {
        let mut c = cluster(3, RouterKind::RoundRobin);
        for i in 0..6 {
            c.on_arrival(FuncId(0), i * SEC);
        }
        assert_eq!(c.routed, vec![2, 2, 2]);
        assert!((c.routing_imbalance() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sticky_concentrates_a_function() {
        let mut c = cluster(4, RouterKind::StickyCh);
        let mut shards_used = std::collections::HashSet::new();
        for i in 0..8 {
            let (s, _, ds) = c.on_arrival(FuncId(1), secs(i as f64 * 30.0));
            shards_used.insert(s);
            // Drain before the next arrival so every routing decision
            // sees an idle cluster (light load never spills).
            for sd in ds {
                c.on_complete(sd.shard, sd.dispatch.inv, sd.dispatch.complete_at);
            }
        }
        assert_eq!(shards_used.len(), 1, "light load must stay on the home shard");
        assert_eq!(c.spills(), 0);
        assert_eq!(c.routed.iter().filter(|&&n| n > 0).count(), 1);
    }

    #[test]
    fn completion_flows_back_through_the_right_shard() {
        let mut c = cluster(2, RouterKind::RoundRobin);
        let (s, _, ds) = c.on_arrival(FuncId(0), 0);
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].shard, s);
        assert_eq!(c.in_flight(), 1);
        let d = ds[0].dispatch;
        let more = c.on_complete(s, d.inv, d.complete_at);
        assert!(more.is_empty());
        assert_eq!(c.in_flight(), 0);
        assert_eq!(c.merged_recorder().len(), 1);
    }

    #[test]
    fn tick_skips_idle_shards() {
        let mut c = cluster(2, RouterKind::RoundRobin);
        c.on_arrival(FuncId(0), 0); // lands on shard 0
        c.on_monitor_tick(200 * crate::types::MS);
        assert_eq!(c.shards[0].recorder.util_timeline.len(), 1);
        assert!(c.shards[1].recorder.util_timeline.is_empty());
    }

    #[test]
    fn pool_stats_sum_over_shards() {
        let mut c = cluster(2, RouterKind::RoundRobin);
        // Same function on both shards: two cold starts cluster-wide.
        c.on_arrival(FuncId(0), 0);
        c.on_arrival(FuncId(0), 1);
        assert_eq!(c.pool_stats().cold, 2);
    }

    #[test]
    fn single_shard_pending_in_flight_match_plane() {
        let mut c = cluster(1, RouterKind::LeastLoaded);
        for i in 0..5 {
            c.on_arrival(FuncId(0), i);
        }
        assert_eq!(c.pending(), c.shards[0].pending());
        assert_eq!(c.in_flight(), c.shards[0].in_flight());
    }
}
