//! Paella-style fair SJF (§6 "Queueing Policies"): dispatch the function
//! with the shortest expected running time, run-to-completion.
//!
//! Paella [60] schedules individual CUDA kernels by expected shortest
//! remaining time with a fairness limiter; the paper adapts it to whole
//! invocations: "we adapt and reimplement its scheduling approach, and
//! choose the shortest function, running the invocation to completion."
//!
//! The fairness limiter deprioritizes functions whose accrued service
//! exceeds the leader's by a slack factor — without it SJF starves long
//! functions entirely; with it they still suffer head-of-line blocking,
//! which is exactly the behaviour Fig 6 measures (8–20× worse latency).

use std::collections::VecDeque;

use crate::scheduler::{Invocation, Policy, PolicyCtx, QState};
use crate::types::{to_secs, DurNanos, FuncId, Nanos};
use crate::util::stats::Ema;

pub struct PaellaSjf {
    queues: Vec<VecDeque<Invocation>>,
    avg_exec: Vec<Ema>,
    /// Accrued GPU service per function (the fairness limiter state).
    service: Vec<f64>,
    changes: Vec<(FuncId, QState)>,
    /// Total queued invocations — keeps `pending()` O(1).
    queued: usize,
    /// A function may be at most this many seconds of service ahead of
    /// the least-served backlogged function before being deprioritized.
    pub fairness_slack_s: f64,
}

impl PaellaSjf {
    pub fn new(n_funcs: usize) -> Self {
        Self {
            queues: (0..n_funcs).map(|_| VecDeque::new()).collect(),
            avg_exec: (0..n_funcs).map(|_| Ema::new(0.3)).collect(),
            service: vec![0.0; n_funcs],
            changes: Vec::new(),
            queued: 0,
            fairness_slack_s: 30.0,
        }
    }

    fn tau(&self, i: usize) -> f64 {
        let v = self.avg_exec[i].get();
        if v > 0.0 {
            v
        } else {
            1.0
        }
    }
}

impl Policy for PaellaSjf {
    fn name(&self) -> &'static str {
        "paella-sjf"
    }

    fn enqueue(&mut self, inv: Invocation, _now: Nanos) {
        self.changes.push((inv.func, QState::Active));
        self.queues[inv.func.0 as usize].push_back(inv);
        self.queued += 1;
    }

    fn dispatch(&mut self, _now: Nanos, _ctx: &PolicyCtx) -> Option<Invocation> {
        let backlogged: Vec<usize> = (0..self.queues.len())
            .filter(|&i| !self.queues[i].is_empty())
            .collect();
        if backlogged.is_empty() {
            return None;
        }
        let min_service = backlogged
            .iter()
            .map(|&i| self.service[i])
            .fold(f64::INFINITY, f64::min);
        // Fairness limiter: prefer within-slack functions; among them,
        // shortest expected runtime (SJF). Note: deliberately ignores
        // in-flight counts — at D>1 this re-dispatches the same shortest
        // function concurrently, forcing extra cold containers (§6.2).
        let eligible: Vec<usize> = backlogged
            .iter()
            .copied()
            .filter(|&i| self.service[i] - min_service <= self.fairness_slack_s)
            .collect();
        let pool = if eligible.is_empty() { &backlogged } else { &eligible };
        let chosen = *pool
            .iter()
            .min_by(|&&a, &&b| {
                self.tau(a)
                    .partial_cmp(&self.tau(b))
                    .unwrap()
                    .then(a.cmp(&b))
            })
            .unwrap();
        let inv = self.queues[chosen].pop_front();
        self.queued -= usize::from(inv.is_some());
        inv
    }

    fn on_complete(&mut self, func: FuncId, service: DurNanos, _now: Nanos) {
        let i = func.0 as usize;
        let s = to_secs(service);
        self.avg_exec[i].push(s);
        self.service[i] += s;
    }

    fn pending(&self) -> usize {
        self.queued
    }

    fn drain_state_changes(&mut self) -> Vec<(FuncId, QState)> {
        std::mem::take(&mut self.changes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::testutil::enqueue_n;
    use crate::types::SEC;

    fn teach(p: &mut PaellaSjf, func: u32, service_s: f64) {
        p.on_complete(FuncId(func), crate::types::secs(service_s), 0);
        p.service[func as usize] = 0.0; // reset limiter state after teaching
    }

    #[test]
    fn shortest_expected_first() {
        let mut p = PaellaSjf::new(2);
        teach(&mut p, 0, 5.0);
        teach(&mut p, 1, 0.5);
        enqueue_n(&mut p, 0, 3, 0, 1);
        enqueue_n(&mut p, 1, 3, 0, 10);
        let inf = [0usize, 0];
        let ctx = PolicyCtx { in_flight: &inf, d: 2 };
        // All short-function items go first: head-of-line blocking.
        let order: Vec<u32> = (0..6)
            .map(|_| {
                let inv = p.dispatch(SEC, &ctx).unwrap();
                p.on_complete(inv.func, SEC / 2, SEC); // keep τ fixed-ish
                inv.func.0
            })
            .collect();
        assert_eq!(&order[..3], &[1, 1, 1]);
    }

    #[test]
    fn fairness_limiter_eventually_unblocks_long() {
        let mut p = PaellaSjf::new(2);
        p.fairness_slack_s = 2.0;
        teach(&mut p, 0, 5.0); // long
        teach(&mut p, 1, 1.0); // short
        enqueue_n(&mut p, 0, 5, 0, 1);
        enqueue_n(&mut p, 1, 50, 0, 100);
        let inf = [0usize, 0];
        let ctx = PolicyCtx { in_flight: &inf, d: 1 };
        let mut saw_long = false;
        for _ in 0..6 {
            let inv = p.dispatch(SEC, &ctx).unwrap();
            let svc = if inv.func.0 == 0 { 5 * SEC } else { SEC };
            p.on_complete(inv.func, svc, SEC);
            if inv.func.0 == 0 {
                saw_long = true;
                break;
            }
        }
        assert!(saw_long, "limiter never let the long function run");
    }
}
