//! EEVDF — "earliest effective virtual deadline first", the
//! state-of-the-art CPU-function policy the paper compares against in
//! §6.4 ("we also compared against the state-of-the-art CPU-specific
//! earliest effective virtual deadline policy [32], which also considers
//! locality and load. Compared to it, MQFQ-Sticky reduces latency by 40%
//! on average").
//!
//! Adaptation of Ilúvatar's EEVDF queue: each flow carries a virtual
//! deadline = max(global VT, flow VT) + τ_f; dispatch picks the earliest
//! effective deadline, where "effective" subtracts a locality bonus for
//! functions with recent executions (warm containers likely). Unlike
//! MQFQ-Sticky there is no over-run batching, no anticipatory TTL, and
//! no in-flight tie-breaking — the gaps §6.4 attributes its loss to.

use std::collections::VecDeque;

use crate::scheduler::{Invocation, Policy, PolicyCtx, QState};
use crate::types::{to_secs, DurNanos, FuncId, Nanos, SEC};
use crate::util::stats::Ema;

pub struct EevdfPolicy {
    queues: Vec<VecDeque<Invocation>>,
    vt: Vec<f64>,
    avg_exec: Vec<Ema>,
    last_exec: Vec<Nanos>,
    changes: Vec<(FuncId, QState)>,
    /// Total queued invocations — keeps `pending()` O(1).
    queued: usize,
    /// Deadline bonus (seconds) for recently-executed (warm) functions.
    pub locality_bonus_s: f64,
    /// Recency window for the bonus.
    pub warm_window: Nanos,
}

impl EevdfPolicy {
    pub fn new(n_funcs: usize) -> Self {
        Self {
            queues: (0..n_funcs).map(|_| VecDeque::new()).collect(),
            vt: vec![0.0; n_funcs],
            avg_exec: (0..n_funcs).map(|_| Ema::new(0.3)).collect(),
            last_exec: vec![0; n_funcs],
            changes: Vec::new(),
            queued: 0,
            locality_bonus_s: 0.5,
            warm_window: 10 * SEC,
        }
    }

    fn tau(&self, i: usize) -> f64 {
        let v = self.avg_exec[i].get();
        if v > 0.0 {
            v
        } else {
            1.0
        }
    }
}

impl Policy for EevdfPolicy {
    fn name(&self) -> &'static str {
        "eevdf"
    }

    fn enqueue(&mut self, inv: Invocation, _now: Nanos) {
        self.changes.push((inv.func, QState::Active));
        let i = inv.func.0 as usize;
        // A flow re-entering the system starts at the global minimum VT.
        if self.queues[i].is_empty() {
            let global = (0..self.queues.len())
                .filter(|&j| !self.queues[j].is_empty() && j != i)
                .map(|j| self.vt[j])
                .fold(f64::INFINITY, f64::min);
            if global.is_finite() {
                self.vt[i] = self.vt[i].max(global);
            }
        }
        self.queues[i].push_back(inv);
        self.queued += 1;
    }

    fn dispatch(&mut self, now: Nanos, _ctx: &PolicyCtx) -> Option<Invocation> {
        let chosen = (0..self.queues.len())
            .filter(|&i| !self.queues[i].is_empty())
            .min_by(|&a, &b| {
                let dl = |i: usize| {
                    let warm = now.saturating_sub(self.last_exec[i]) < self.warm_window;
                    let bonus = if warm { self.locality_bonus_s } else { 0.0 };
                    self.vt[i] + self.tau(i) - bonus
                };
                dl(a).partial_cmp(&dl(b)).unwrap().then(a.cmp(&b))
            })?;
        self.vt[chosen] += self.tau(chosen);
        self.last_exec[chosen] = now;
        let inv = self.queues[chosen].pop_front();
        self.queued -= usize::from(inv.is_some());
        inv
    }

    fn on_complete(&mut self, func: FuncId, service: DurNanos, now: Nanos) {
        let i = func.0 as usize;
        self.avg_exec[i].push(to_secs(service));
        self.last_exec[i] = now;
    }

    fn pending(&self) -> usize {
        self.queued
    }

    fn drain_state_changes(&mut self) -> Vec<(FuncId, QState)> {
        std::mem::take(&mut self.changes)
    }

    fn queue_vt(&self, func: FuncId) -> Option<f64> {
        Some(self.vt[func.0 as usize])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::testutil::enqueue_n;

    #[test]
    fn earliest_deadline_wins() {
        let mut p = EevdfPolicy::new(2);
        // fn0 expensive (τ=5), fn1 cheap (τ=1): fn1's deadline is earlier.
        p.on_complete(FuncId(0), 5 * SEC, 0);
        p.on_complete(FuncId(1), SEC, 0);
        enqueue_n(&mut p, 0, 2, 0, 1);
        enqueue_n(&mut p, 1, 2, 0, 10);
        let inf = [0usize, 0];
        let ctx = PolicyCtx { in_flight: &inf, d: 1 };
        // Disable the warm bonus for determinism here.
        p.locality_bonus_s = 0.0;
        assert_eq!(p.dispatch(20 * SEC, &ctx).unwrap().func.0, 1);
    }

    #[test]
    fn locality_bonus_prefers_recent_function() {
        let mut p = EevdfPolicy::new(2);
        p.on_complete(FuncId(0), SEC, 0);
        p.on_complete(FuncId(1), SEC, 0);
        enqueue_n(&mut p, 0, 2, 0, 1);
        enqueue_n(&mut p, 1, 2, 0, 10);
        let inf = [0usize, 0];
        let ctx = PolicyCtx { in_flight: &inf, d: 1 };
        // fn1 executed recently (warm bonus); fn0's window has expired.
        p.last_exec[1] = 14 * SEC;
        let got = p.dispatch(15 * SEC, &ctx).unwrap();
        assert_eq!(got.func.0, 1);
    }

    #[test]
    fn vt_keeps_functions_within_share() {
        let mut p = EevdfPolicy::new(2);
        p.locality_bonus_s = 0.0;
        p.on_complete(FuncId(0), SEC, 0);
        p.on_complete(FuncId(1), SEC, 0);
        enqueue_n(&mut p, 0, 10, 0, 1);
        enqueue_n(&mut p, 1, 10, 0, 100);
        let inf = [0usize, 0];
        let ctx = PolicyCtx { in_flight: &inf, d: 1 };
        let mut counts = [0; 2];
        for _ in 0..10 {
            let inv = p.dispatch(30 * SEC, &ctx).unwrap();
            counts[inv.func.0 as usize] += 1;
            p.on_complete(inv.func, SEC, 30 * SEC);
        }
        assert_eq!(counts, [5, 5]);
    }
}
