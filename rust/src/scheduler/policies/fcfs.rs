//! FCFS — the OpenWhisk-style baseline (§2.1): one global queue,
//! invocations dispatched strictly in arrival order.

use std::collections::VecDeque;

use crate::scheduler::{Invocation, Policy, PolicyCtx, QState};
use crate::types::{DurNanos, FuncId, Nanos};

pub struct FcfsPolicy {
    queue: VecDeque<Invocation>,
    changes: Vec<(FuncId, QState)>,
    n_funcs: usize,
}

impl FcfsPolicy {
    pub fn new(n_funcs: usize) -> Self {
        Self {
            queue: VecDeque::new(),
            changes: Vec::new(),
            n_funcs,
        }
    }
}

impl Policy for FcfsPolicy {
    fn name(&self) -> &'static str {
        "fcfs"
    }

    fn enqueue(&mut self, inv: Invocation, _now: Nanos) {
        // Arrival makes the function "active" so the shared memory
        // optimizations (prefetch) apply to every policy (§6).
        self.changes.push((inv.func, QState::Active));
        self.queue.push_back(inv);
    }

    fn dispatch(&mut self, _now: Nanos, _ctx: &PolicyCtx) -> Option<Invocation> {
        self.queue.pop_front()
    }

    fn on_complete(&mut self, _func: FuncId, _service: DurNanos, _now: Nanos) {}

    fn pending(&self) -> usize {
        // Single global queue: `VecDeque::len` is already O(1).
        self.queue.len()
    }

    fn drain_state_changes(&mut self) -> Vec<(FuncId, QState)> {
        let _ = self.n_funcs;
        std::mem::take(&mut self.changes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::testutil::enqueue_n;
    use crate::types::InvocationId;

    #[test]
    fn strict_arrival_order() {
        let mut p = FcfsPolicy::new(2);
        enqueue_n(&mut p, 1, 1, 0, 1);
        enqueue_n(&mut p, 0, 1, 1, 2);
        enqueue_n(&mut p, 1, 1, 2, 3);
        let inf = [0usize, 0];
        let ctx = PolicyCtx { in_flight: &inf, d: 2 };
        assert_eq!(p.dispatch(3, &ctx).unwrap().id, InvocationId(1));
        assert_eq!(p.dispatch(3, &ctx).unwrap().id, InvocationId(2));
        assert_eq!(p.dispatch(3, &ctx).unwrap().id, InvocationId(3));
        assert!(p.dispatch(3, &ctx).is_none());
    }

    #[test]
    fn reports_active_on_arrival() {
        let mut p = FcfsPolicy::new(2);
        enqueue_n(&mut p, 1, 2, 0, 1);
        let ch = p.drain_state_changes();
        assert_eq!(ch.len(), 2);
        assert!(ch.iter().all(|(f, s)| *f == FuncId(1) && *s == QState::Active));
    }
}
