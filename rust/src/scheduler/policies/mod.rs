//! Baseline queueing policies the paper evaluates against (§6):
//! FCFS (OpenWhisk-style), continuous batching, Paella-style fair SJF,
//! and the EEVDF CPU-scheduling baseline from §6.4.

pub mod batch;
pub mod eevdf;
pub mod fcfs;
pub mod sjf;

pub use batch::BatchPolicy;
pub use eevdf::EevdfPolicy;
pub use fcfs::FcfsPolicy;
pub use sjf::PaellaSjf;

use super::{MqfqConfig, MqfqSticky, Policy};

/// Policy selector used by the CLI / experiment harness.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    Fcfs,
    Batch,
    PaellaSjf,
    Eevdf,
    Mqfq,
    /// MQFQ with T=0: classic start-time fair queueing (§6.2 "at D=1,
    /// MQFQ approximates classic SFQ").
    Sfq,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "fcfs" => PolicyKind::Fcfs,
            "batch" => PolicyKind::Batch,
            "sjf" | "paella" | "paella-sjf" => PolicyKind::PaellaSjf,
            "eevdf" => PolicyKind::Eevdf,
            "mqfq" | "mqfq-sticky" => PolicyKind::Mqfq,
            "sfq" => PolicyKind::Sfq,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Fcfs => "fcfs",
            PolicyKind::Batch => "batch",
            PolicyKind::PaellaSjf => "paella-sjf",
            PolicyKind::Eevdf => "eevdf",
            PolicyKind::Mqfq => "mqfq-sticky",
            PolicyKind::Sfq => "sfq",
        }
    }

    /// Instantiate the policy for `n_funcs` registered functions.
    pub fn build(&self, n_funcs: usize) -> Box<dyn Policy> {
        self.build_mqfq(n_funcs, MqfqConfig::default())
    }

    /// Instantiate with explicit MQFQ tunables (ignored by baselines).
    pub fn build_mqfq(&self, n_funcs: usize, cfg: MqfqConfig) -> Box<dyn Policy> {
        match self {
            PolicyKind::Fcfs => Box::new(FcfsPolicy::new(n_funcs)),
            PolicyKind::Batch => Box::new(BatchPolicy::new(n_funcs)),
            PolicyKind::PaellaSjf => Box::new(PaellaSjf::new(n_funcs)),
            PolicyKind::Eevdf => Box::new(EevdfPolicy::new(n_funcs)),
            PolicyKind::Mqfq => Box::new(MqfqSticky::new(n_funcs, cfg)),
            PolicyKind::Sfq => Box::new(MqfqSticky::new(
                n_funcs,
                MqfqConfig {
                    t: 0.0,
                    sticky: false,
                    ..cfg
                },
            )),
        }
    }
}

/// All policies compared in the Fig-6 experiments.
pub const FIG6_POLICIES: [PolicyKind; 4] = [
    PolicyKind::Fcfs,
    PolicyKind::Batch,
    PolicyKind::PaellaSjf,
    PolicyKind::Mqfq,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for k in [
            PolicyKind::Fcfs,
            PolicyKind::Batch,
            PolicyKind::PaellaSjf,
            PolicyKind::Eevdf,
            PolicyKind::Mqfq,
            PolicyKind::Sfq,
        ] {
            assert_eq!(PolicyKind::parse(k.name()), Some(k));
        }
        assert_eq!(PolicyKind::parse("nope"), None);
    }

    #[test]
    fn build_produces_named_policies() {
        assert_eq!(PolicyKind::Fcfs.build(2).name(), "fcfs");
        assert_eq!(PolicyKind::Mqfq.build(2).name(), "mqfq-sticky");
    }
}
