//! Batch — continuous-batching baseline (§6 "Queueing Policies"):
//! per-function queues; dispatch drains the *entire* queue containing
//! the oldest item before moving on ("analogous to continuous batching
//! used in modern LLM serving"). Greedy locality, no fairness.

use std::collections::VecDeque;

use crate::scheduler::{Invocation, Policy, PolicyCtx, QState};
use crate::types::{DurNanos, FuncId, Nanos};

pub struct BatchPolicy {
    queues: Vec<VecDeque<Invocation>>,
    /// The function whose queue is currently being drained, and how many
    /// items remain in the batch (snapshot at batch start — continuous
    /// batching admits *new* requests only into the next batch, keeping
    /// a hot function from monopolizing the device forever).
    current: Option<(FuncId, usize)>,
    changes: Vec<(FuncId, QState)>,
    /// Total queued invocations — keeps `pending()` O(1).
    queued: usize,
}

impl BatchPolicy {
    pub fn new(n_funcs: usize) -> Self {
        Self {
            queues: (0..n_funcs).map(|_| VecDeque::new()).collect(),
            current: None,
            changes: Vec::new(),
            queued: 0,
        }
    }

    /// Function holding the globally oldest queued invocation.
    fn oldest(&self) -> Option<FuncId> {
        self.queues
            .iter()
            .enumerate()
            .filter_map(|(i, q)| q.front().map(|inv| (inv.arrived, inv.id.0, i)))
            .min()
            .map(|(_, _, i)| FuncId(i as u32))
    }
}

impl Policy for BatchPolicy {
    fn name(&self) -> &'static str {
        "batch"
    }

    fn enqueue(&mut self, inv: Invocation, _now: Nanos) {
        self.changes.push((inv.func, QState::Active));
        self.queues[inv.func.0 as usize].push_back(inv);
        self.queued += 1;
    }

    fn dispatch(&mut self, _now: Nanos, _ctx: &PolicyCtx) -> Option<Invocation> {
        // Keep draining the current batch while it has items.
        if let Some((f, remaining)) = self.current {
            if remaining > 0 {
                if let Some(inv) = self.queues[f.0 as usize].pop_front() {
                    self.current = Some((f, remaining - 1));
                    self.queued -= 1;
                    return Some(inv);
                }
            }
            self.current = None;
        }
        let f = self.oldest()?;
        let len = self.queues[f.0 as usize].len();
        self.current = Some((f, len.saturating_sub(1)));
        let inv = self.queues[f.0 as usize].pop_front();
        self.queued -= usize::from(inv.is_some());
        inv
    }

    fn on_complete(&mut self, _func: FuncId, _service: DurNanos, _now: Nanos) {}

    fn pending(&self) -> usize {
        self.queued
    }

    fn drain_state_changes(&mut self) -> Vec<(FuncId, QState)> {
        std::mem::take(&mut self.changes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::testutil::enqueue_n;
    use crate::types::SEC;

    #[test]
    fn drains_oldest_queue_entirely() {
        let mut p = BatchPolicy::new(2);
        enqueue_n(&mut p, 0, 1, 0, 1); // oldest
        enqueue_n(&mut p, 1, 2, SEC, 10);
        enqueue_n(&mut p, 0, 2, 2 * SEC, 2); // more for fn 0 arrive later
        let inf = [0usize, 0];
        let ctx = PolicyCtx { in_flight: &inf, d: 1 };
        // Whole fn-0 queue first (its head is oldest), despite fn-1's
        // items arriving before fn-0's tail.
        let order: Vec<u32> = (0..5)
            .map(|_| p.dispatch(3 * SEC, &ctx).unwrap().func.0)
            .collect();
        assert_eq!(order, vec![0, 0, 0, 1, 1]);
    }

    #[test]
    fn new_arrivals_wait_for_the_next_batch() {
        let mut p = BatchPolicy::new(2);
        enqueue_n(&mut p, 0, 1, 0, 1);
        enqueue_n(&mut p, 1, 1, 1, 10);
        let inf = [0usize, 0];
        let ctx = PolicyCtx { in_flight: &inf, d: 1 };
        assert_eq!(p.dispatch(2, &ctx).unwrap().func.0, 0);
        // A fn-0 arrival after the batch snapshot does NOT jump ahead of
        // fn-1 (snapshot semantics prevent monopolization).
        enqueue_n(&mut p, 0, 1, 3, 2);
        assert_eq!(p.dispatch(4, &ctx).unwrap().func.0, 1);
        assert_eq!(p.dispatch(5, &ctx).unwrap().func.0, 0);
    }
}
