//! Incremental index structures backing the O(log n) dispatch path of
//! [`super::MqfqSticky`] (see the "Dispatch-path complexity" section of
//! the [`super::mqfq`] module docs).
//!
//! All three structures follow the *lazy invalidation* discipline: an
//! index entry is a snapshot `(key, flow)` pushed when the flow's key
//! changed, and it is validated against the flow's live state only when
//! it surfaces at the top of its heap. Stale entries are discarded on
//! pop, so every entry is touched O(1) times and each enqueue/dispatch/
//! complete pays O(log n) amortized instead of the O(n) full scans the
//! naive Algorithm-1 transliteration needs per decision.

use std::cmp::Ordering;

/// `f64` with a total order (via [`f64::total_cmp`]) so virtual times
/// can key a [`std::collections::BinaryHeap`]. VTs are always finite,
/// so the NaN corner of the total order is never exercised.
#[derive(Debug, Clone, Copy)]
pub struct OrdF64(pub f64);

impl PartialEq for OrdF64 {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for OrdF64 {}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// A dense set over a fixed universe `0..n` with O(1) insert, remove,
/// membership, and allocation-free iteration — the eligible-flow index.
/// Iteration order is arbitrary (swap-remove), so consumers must pick by
/// a total order that includes the element id as a tiebreak.
#[derive(Debug, Clone)]
pub struct DenseSet {
    items: Vec<u32>,
    /// Position of each element in `items`, or `ABSENT`.
    pos: Vec<u32>,
}

const ABSENT: u32 = u32::MAX;

impl DenseSet {
    pub fn new(universe: usize) -> Self {
        debug_assert!(universe < ABSENT as usize);
        Self {
            items: Vec::new(),
            pos: vec![ABSENT; universe],
        }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn contains(&self, x: u32) -> bool {
        self.pos[x as usize] != ABSENT
    }

    /// Insert `x`; returns false if it was already present.
    pub fn insert(&mut self, x: u32) -> bool {
        if self.contains(x) {
            return false;
        }
        self.pos[x as usize] = self.items.len() as u32;
        self.items.push(x);
        true
    }

    /// Remove `x` (swap-remove); returns false if it was absent.
    pub fn remove(&mut self, x: u32) -> bool {
        let p = self.pos[x as usize];
        if p == ABSENT {
            return false;
        }
        self.pos[x as usize] = ABSENT;
        let last = self.items.pop().expect("non-empty: x was present");
        if last != x {
            self.items[p as usize] = last;
            self.pos[last as usize] = p;
        }
        true
    }

    pub fn iter(&self) -> impl Iterator<Item = u32> + '_ {
        self.items.iter().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    #[test]
    fn ordf64_total_order() {
        let mut xs = [OrdF64(2.0), OrdF64(-1.0), OrdF64(0.5), OrdF64(0.0)];
        xs.sort();
        let got: Vec<f64> = xs.iter().map(|x| x.0).collect();
        assert_eq!(got, vec![-1.0, 0.0, 0.5, 2.0]);
        assert_eq!(OrdF64(1.5), OrdF64(1.5));
        assert!(OrdF64(-0.0) < OrdF64(0.0)); // total order distinguishes zeros
    }

    #[test]
    fn ordf64_min_heap_pops_in_vt_order() {
        let mut h: BinaryHeap<Reverse<(OrdF64, u32)>> = BinaryHeap::new();
        for (vt, id) in [(3.0, 0u32), (1.0, 1), (2.0, 2)] {
            h.push(Reverse((OrdF64(vt), id)));
        }
        let order: Vec<u32> = std::iter::from_fn(|| h.pop().map(|Reverse((_, i))| i)).collect();
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn dense_set_insert_remove_contains() {
        let mut s = DenseSet::new(8);
        assert!(s.is_empty());
        assert!(s.insert(3));
        assert!(s.insert(5));
        assert!(!s.insert(3), "duplicate insert must be a no-op");
        assert_eq!(s.len(), 2);
        assert!(s.contains(3) && s.contains(5) && !s.contains(0));
        assert!(s.remove(3));
        assert!(!s.remove(3), "double remove must be a no-op");
        assert!(!s.contains(3) && s.contains(5));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn dense_set_swap_remove_keeps_iteration_consistent() {
        let mut s = DenseSet::new(16);
        for x in 0..10u32 {
            s.insert(x);
        }
        for x in [0u32, 9, 4, 7] {
            s.remove(x);
        }
        let mut got: Vec<u32> = s.iter().collect();
        got.sort_unstable();
        assert_eq!(got, vec![1, 2, 3, 5, 6, 8]);
        // Every surviving element is still found via contains().
        for &x in &got {
            assert!(s.contains(x));
        }
    }

    #[test]
    fn dense_set_remove_last_element() {
        let mut s = DenseSet::new(4);
        s.insert(1);
        s.insert(2);
        assert!(s.remove(2)); // `2` sits at the tail: pop-only path
        assert!(s.contains(1));
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![1]);
    }
}
