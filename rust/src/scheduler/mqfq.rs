//! MQFQ-Sticky (§4.2, Algorithm 1): locality-enhanced multi-queue fair
//! queueing for GPU functions.
//!
//! Key mechanisms, all implemented here:
//! * **Per-function fairness** — each dispatch advances the flow's VT by
//!   its historical average execution time τ_f, so short functions get
//!   more invocations but equal wall-clock service.
//! * **Queue over-run (T)** — flows may be dispatched while
//!   `VT < Global_VT + T`, enabling mini-batches and locality; beyond
//!   that they are *Throttled* until Global_VT catches up.
//! * **Anticipatory keep-alive (TTL = α × IAT)** — empty queues stay
//!   Active for a per-function grace period so their warm containers and
//!   device memory survive idle gaps (adapted from anticipatory disk
//!   scheduling [43]).
//! * **Preferential ("sticky") dispatch** — among eligible flows, prefer
//!   the longest queue (batching, backlog drain), tie-broken by fewest
//!   in-flight invocations (avoids concurrent same-function dispatches,
//!   which cause cold starts; keeps multiple flows progressing).
//!
//! Fairness (Eq. 1): because eligible flows always satisfy
//! `VT < Global_VT + T`, MQFQ-Sticky's dispatch choices are a subset of
//! MQFQ's, retaining its bound |S_i/w_i − S_j/w_j| ≤ (D−1)(2T + τ_i − τ_j).
//!
//! ## Dispatch-path complexity
//!
//! A dispatch decision fires every time a D-token frees; at provider
//! scale the registered-function universe is large (thousands) while the
//! *backlogged* subset is sparse (the Azure-trace shape), so the hot
//! path must not touch every registered flow. This implementation keeps
//! incremental indexes ([`super::index`]) instead of per-decision full
//! scans:
//!
//! * **Global_VT** — a lazy min-heap over backlogged flows' VT
//!   snapshots, refreshed in O(log n) amortized on enqueue/dispatch; it
//!   replaces the naive two-full-scans-per-dispatch recompute, and makes
//!   the enqueue catch-up read a *fresh* Global_VT (the naive cached
//!   value could be stale-low after completions, under-catching-up
//!   rejoining flows).
//! * **TTL expiry** — a deadline heap of per-flow keep-alive expiries,
//!   armed when a flow goes idle; expiry costs O(log n) *at expiry
//!   time* instead of an O(n) sweep per decision (the Ilúvatar
//!   timer-wheel idea).
//! * **Eligible set** — a dense O(1) index of Active ∧ non-empty ∧
//!   within-T flows, plus a lazily-invalidated min-heap of throttled
//!   flows keyed by VT that re-admits them as Global_VT advances; the
//!   sticky longest-queue/least-in-flight pick scans only the E
//!   currently-eligible flows, with no candidate `Vec` allocation.
//! * **pending()** — an O(1) counter maintained on enqueue/pop.
//!
//! Net: one decision costs O(E + log n) amortized (E = eligible flows;
//! E ≪ n under sparse activity) versus O(n) for the naive Algorithm-1
//! transliteration. The naive version is kept as
//! [`reference::NaiveMqfq`] — the property-test oracle
//! (`prop_indexed_matches_naive_reference` checks dispatch-sequence,
//! VT, pending, and state-change-stream equality over randomized Zipf
//! traces) and the perf-harness baseline recorded in `BENCH_perf.json`.
//!
//! ## Anticipatory scheduling
//!
//! With [`AnticipateConfig`] (nested in [`MqfqConfig::anticipate`]) the
//! scheduler consumes online per-function estimates from a shared
//! [`CharacteristicsMap`] — EWMA exec time split by warm/cold start
//! kind, inter-arrival rate, cold-start cost, observed concurrency —
//! and three behaviors switch on:
//!
//! * **Grace periods** (`grace_alpha > 0`): the idle keep-alive window
//!   becomes `max(TTL, grace_alpha × predicted_IAT)`. A flow whose
//!   queue empties stays Active (non-work-conserving) through the
//!   window, holding its warm containers, device regions, and sticky
//!   placement for the anticipated next arrival; the TTL deadline heap
//!   arms at the *extended* window, so grace can never be cut short by
//!   the plain-TTL expiry path. Empty Active flows still do not anchor
//!   Global_VT — grace preserves locality, not a service reservation.
//! * **Batch dispatch** (`batch_max > 1`): one dispatch decision pops
//!   up to `batch_max` invocations of the chosen flow. The head is
//!   charged full service; each rider charges
//!   `batch_marginal × estimate` (weights and kernels already
//!   resident), and riders stop early rather than carry the flow's VT
//!   past the over-run bound, so the fairness bound (Eq. 1) is
//!   preserved with τ_f re-read as the batch's aggregate charge.
//! * **Estimated-then-corrected VT** (`estimator`): dispatch advances
//!   VT by the *predicted* exec time; at completion the signed error
//!   (actual − charged) accumulates as per-flow debt repaid by the
//!   next dispatch's τ (the Ilúvatar `budget` idea). Debt is carried
//!   *forward* — VT is never lowered retroactively — so Global_VT
//!   stays monotone and the lazy min-heap stays valid.
//!
//! Eviction interaction: grace only stretches the Active phase of the
//! idle window; expiry past the window still transitions the flow to
//! Inactive, which is what signals the memory manager to evict. All
//! three behaviors are mirrored bit-for-bit in [`reference::NaiveMqfq`]
//! (the shared `CharacteristicsMap` does the arithmetic once), and the
//! all-neutral config (`grace_alpha = 0`, `batch_max = 1`,
//! `estimator = false`) is property-tested to be identical to the
//! pre-anticipation scheduler (`tests/prop_anticipate.rs`).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::estimator::{AnticipateConfig, CharacteristicsMap};
use crate::types::{secs, to_secs, DurNanos, FuncId, Nanos, StartKind};

use super::flowq::{FlowQueue, QState};
use super::index::{DenseSet, OrdF64};
use super::{AnticipationEvent, Invocation, Policy, PolicyCtx};

/// Tunables (Table 2) + the ablation switches of §6.4.
#[derive(Debug, Clone)]
pub struct MqfqConfig {
    /// Queue over-run T, in seconds of virtual time (paper default: 10).
    pub t: f64,
    /// Anticipatory keep-alive scale α: TTL = α × IAT (paper default: 2).
    pub ttl_alpha: f64,
    /// Fig-8b variant: one fixed TTL for every function (seconds),
    /// overriding the per-function α × IAT policy.
    pub fixed_ttl_s: Option<f64>,
    /// Advance VT by wall-time τ_f (true, paper default) or by 1.0 per
    /// invocation (the "1.0" ablation of Fig 8a).
    pub vt_wall_time: bool,
    /// Preferential longest-queue/least-in-flight dispatch (true) vs the
    /// original MQFQ's arbitrary eligible pick, here lowest-VT (§6.4
    /// ablation: disabling costs 1–30% latency).
    pub sticky: bool,
    /// Anticipatory scheduling knobs (grace periods, batch dispatch,
    /// estimated VT). All-neutral by default — see the module docs'
    /// "Anticipatory scheduling" section.
    pub anticipate: AnticipateConfig,
}

impl Default for MqfqConfig {
    fn default() -> Self {
        Self {
            t: 10.0,
            ttl_alpha: 2.0,
            fixed_ttl_s: None,
            vt_wall_time: true,
            sticky: true,
            anticipate: AnticipateConfig::default(),
        }
    }
}

/// TTL for one flow (Table 2: α × IAT, or the fixed global variant).
fn plain_ttl(cfg: &MqfqConfig, flow: &FlowQueue) -> DurNanos {
    match cfg.fixed_ttl_s {
        Some(s) => secs(s),
        None => secs(cfg.ttl_alpha * flow.mean_iat_s()),
    }
}

/// Keep-alive window for an idle flow: the TTL, extended to
/// `grace_alpha × predicted_IAT` when grace periods are on. Shared by
/// the indexed scheduler and the naive oracle so the grace semantics
/// cannot drift between them. With `grace_alpha = 0` this degenerates
/// to the plain TTL exactly.
fn keep_alive(cfg: &MqfqConfig, chars: &CharacteristicsMap, flow: &FlowQueue) -> DurNanos {
    let ttl = plain_ttl(cfg, flow);
    let ga = cfg.anticipate.grace_alpha;
    if ga <= 0.0 {
        return ttl;
    }
    let iat = chars
        .predicted_iat_s(flow.func)
        .unwrap_or_else(|| flow.mean_iat_s());
    ttl.max(secs(ga * iat))
}

/// Virtual-time charge for the head of a dispatch decision. With the
/// estimator on, the predicted exec time plus accumulated correction
/// debt (consumed here); otherwise the flow's trailing average — the
/// legacy path, bit-for-bit.
fn head_tau(cfg: &MqfqConfig, chars: &mut CharacteristicsMap, flow: &FlowQueue) -> f64 {
    if !cfg.vt_wall_time {
        return 1.0;
    }
    let avg = flow.avg_exec_s();
    if cfg.anticipate.estimator {
        chars.take_tau(flow.func, avg)
    } else {
        avg
    }
}

/// Marginal virtual-time charge for one batched rider:
/// `batch_marginal × estimate` (debt-free — debt settles on the head).
fn rider_tau(cfg: &MqfqConfig, chars: &CharacteristicsMap, flow: &FlowQueue) -> f64 {
    let base = if !cfg.vt_wall_time {
        1.0
    } else if cfg.anticipate.estimator {
        chars.estimate_or(flow.func, flow.avg_exec_s())
    } else {
        flow.avg_exec_s()
    };
    cfg.anticipate.batch_marginal * base
}

/// The MQFQ-Sticky policy over a fixed set of registered functions,
/// built around incremental indexes (see the module docs' complexity
/// section). Behaviorally equivalent to [`reference::NaiveMqfq`].
pub struct MqfqSticky {
    cfg: MqfqConfig,
    flows: Vec<FlowQueue>,
    changes: Vec<(FuncId, QState)>,
    /// Cached Global_VT, advanced lazily via `vt_heap` (monotone
    /// non-decreasing; holds its last value while nothing is backlogged,
    /// like the naive recompute).
    global_vt: f64,
    /// Total queued (not yet dispatched) invocations — O(1) `pending()`.
    queued: usize,
    /// Lazy min-heap of (VT, flow) snapshots over backlogged flows; the
    /// top valid entry is `min VT over backlogged` (Algorithm 1 line 2).
    vt_heap: BinaryHeap<Reverse<(OrdF64, u32)>>,
    /// Deadline heap of keep-alive expiries, armed when a flow goes idle
    /// (empty, nothing in flight). TTL inputs are frozen while idle, so
    /// the armed deadline stays exact; entries from superseded idle
    /// periods are discarded lazily.
    ttl_heap: BinaryHeap<Reverse<(Nanos, u32)>>,
    /// Eligible flows: Active ∧ non-empty ∧ within the over-run bound.
    eligible: DenseSet,
    /// Flows past the over-run bound, keyed by VT: re-admitted (and
    /// flipped back to Active) once Global_VT catches up. Also carries
    /// *empty* over-run flows so their Throttled→Active flip matches the
    /// naive per-dispatch sweep. Lazily invalidated.
    throttled: BinaryHeap<Reverse<(OrdF64, u32)>>,
    /// Flows whose state must be re-derived at the next dispatch (the
    /// one-shot stand-in for the naive all-flows UPDATE_STATE sweep:
    /// only flows whose inputs changed since the last decision can
    /// transition, and all such flows are recorded here or covered by
    /// the heaps above).
    reclass: Vec<u32>,
    /// Online per-function characteristics (exec time, IAT, cold cost)
    /// feeding grace windows and estimated VT.
    chars: CharacteristicsMap,
    /// Anticipatory decisions awaiting telemetry drain.
    anticipation: Vec<AnticipationEvent>,
    /// Reusable buffer backing the single-dispatch `Policy::dispatch`
    /// shim over the batch-capable core (steady state allocates
    /// nothing).
    scratch: Vec<Invocation>,
}

impl MqfqSticky {
    pub fn new(n_funcs: usize, cfg: MqfqConfig) -> Self {
        Self {
            cfg,
            flows: (0..n_funcs).map(|i| FlowQueue::new(FuncId(i as u32))).collect(),
            changes: Vec::new(),
            global_vt: 0.0,
            queued: 0,
            vt_heap: BinaryHeap::new(),
            ttl_heap: BinaryHeap::new(),
            eligible: DenseSet::new(n_funcs),
            throttled: BinaryHeap::new(),
            reclass: Vec::new(),
            chars: CharacteristicsMap::new(),
            anticipation: Vec::new(),
            scratch: Vec::new(),
        }
    }

    pub fn config(&self) -> &MqfqConfig {
        &self.cfg
    }

    pub fn flow(&self, func: FuncId) -> &FlowQueue {
        &self.flows[func.0 as usize]
    }

    pub fn global_vt(&self) -> f64 {
        self.global_vt
    }

    /// The online characteristics map (telemetry/introspection).
    pub fn characteristics(&self) -> &CharacteristicsMap {
        &self.chars
    }

    fn set_state(flow: &mut FlowQueue, state: QState, changes: &mut Vec<(FuncId, QState)>) {
        if flow.state != state {
            flow.state = state;
            changes.push((flow.func, state));
        }
    }

    /// Backlogged = has queued or in-flight work. Empty *Active* queues
    /// (anticipatory keep-alive) deliberately do NOT anchor Global_VT:
    /// anticipation preserves a flow's *memory locality* (containers,
    /// device regions — §4.3), not a service reservation. Letting an
    /// idle flow hold the global minimum would throttle every busy flow
    /// after T seconds of over-run and idle the GPU for up to the TTL.
    fn is_backlogged(f: &FlowQueue) -> bool {
        !f.is_empty() || f.in_flight > 0
    }

    /// The naive UPDATE_STATE throttle predicate — kept verbatim so the
    /// indexed path is bit-for-bit equivalent to the reference.
    fn over_run(vt: f64, global: f64, t: f64) -> bool {
        vt - global > t
    }

    /// Exclusion from the candidate set (Algorithm 1 line 6): throttled
    /// state *or* past the non-strict dispatch filter. The two float
    /// comparisons are not identical in rounding corners, so eligibility
    /// applies both, exactly as the naive filter does.
    fn ineligible(vt: f64, global: f64, t: f64) -> bool {
        Self::over_run(vt, global, t) || vt > global + t
    }

    /// `Global_VT ← min over backlogged flows` (Algorithm 1 line 2),
    /// incrementally: pop stale snapshots until the top entry matches a
    /// live backlogged flow. Every backlogged flow always has a snapshot
    /// of its current VT in the heap (pushed on rejoin and on each
    /// dispatch), so the top valid entry *is* the minimum. Holds the
    /// cached value when nothing is backlogged.
    fn refresh_global_vt(&mut self) {
        while let Some(&Reverse((OrdF64(vt), idx))) = self.vt_heap.peek() {
            let f = &self.flows[idx as usize];
            if Self::is_backlogged(f) && f.vt.to_bits() == vt.to_bits() {
                if vt > self.global_vt {
                    self.global_vt = vt;
                }
                return;
            }
            self.vt_heap.pop();
        }
    }

    /// Pop every due keep-alive deadline and expire the flows that are
    /// still idle — the indexed form of the naive sweep's
    /// `empty ∧ idle ∧ now − last_exec ≥ TTL → Inactive` branch.
    fn expire_due(&mut self, now: Nanos) {
        while let Some(&Reverse((at, idx))) = self.ttl_heap.peek() {
            if at > now {
                break;
            }
            self.ttl_heap.pop();
            let i = idx as usize;
            let f = &self.flows[i];
            // Entries are snapshots: the flow must still be idle and this
            // idle period's deadline must actually have passed (stale
            // entries from superseded idle periods are simply dropped —
            // the current period pushed its own entry when it began).
            if f.state == QState::Inactive || Self::is_backlogged(f) {
                continue;
            }
            let due = f.last_exec.saturating_add(keep_alive(&self.cfg, &self.chars, f));
            if due <= now {
                Self::set_state(&mut self.flows[i], QState::Inactive, &mut self.changes);
            } else {
                // Not yet due: the keep-alive window grew since this
                // entry was armed (a grace window over a fresher IAT
                // estimate). Re-arm at the true deadline so the flow
                // still expires when the window ends — dropping the
                // entry would leave it Active forever.
                self.ttl_heap.push(Reverse((due, idx)));
            }
        }
    }

    /// Re-admit throttled flows whose VT fell within the over-run bound
    /// as Global_VT advanced (monotonically), flipping them back to
    /// Active — the indexed form of the naive sweep's un-throttle.
    /// Heap order is VT order and eligibility is downward-closed in VT,
    /// so popping stops at the first beyond-bound entry.
    fn admit_unthrottled(&mut self) {
        let global = self.global_vt;
        let t = self.cfg.t;
        while let Some(&Reverse((OrdF64(vt), idx))) = self.throttled.peek() {
            if Self::ineligible(vt, global, t) {
                break;
            }
            self.throttled.pop();
            let i = idx as usize;
            let stale = self.flows[i].vt.to_bits() != vt.to_bits()
                || self.flows[i].state == QState::Inactive
                || self.eligible.contains(idx);
            if stale {
                continue;
            }
            Self::set_state(&mut self.flows[i], QState::Active, &mut self.changes);
            if !self.flows[i].is_empty() {
                self.eligible.insert(idx);
            }
        }
    }

    /// One-shot per-flow state re-derivation — exactly the naive
    /// UPDATE_STATE body, applied only to flows whose inputs changed
    /// since the last decision.
    fn apply_reclass(&mut self, now: Nanos) {
        if self.reclass.is_empty() {
            return;
        }
        let global = self.global_vt;
        let t = self.cfg.t;
        let pending = std::mem::take(&mut self.reclass);
        for idx in pending {
            let i = idx as usize;
            if self.flows[i].state == QState::Inactive {
                continue; // reactivated only by an arrival
            }
            if self.flows[i].is_empty() && self.flows[i].in_flight == 0 {
                let window = keep_alive(&self.cfg, &self.chars, &self.flows[i]);
                let f = &mut self.flows[i];
                if now.saturating_sub(f.last_exec) >= window {
                    Self::set_state(f, QState::Inactive, &mut self.changes);
                } else {
                    // Anticipatory: stay Active while within the grace
                    // period.
                    Self::set_state(f, QState::Active, &mut self.changes);
                }
                continue;
            }
            let f = &mut self.flows[i];
            if Self::over_run(f.vt, global, t) {
                Self::set_state(f, QState::Throttled, &mut self.changes);
            } else {
                Self::set_state(f, QState::Active, &mut self.changes);
            }
        }
    }

    /// Algorithm 1 DISPATCH over the incremental indexes, batch-capable:
    /// one decision pops the head plus up to `cap - 1` same-flow riders
    /// (see the module docs' "Anticipatory scheduling" section). With
    /// `cap = 1` this is exactly the pre-anticipation single dispatch.
    fn dispatch_impl(&mut self, now: Nanos, ctx: &PolicyCtx, cap: usize, out: &mut Vec<Invocation>) {
        // The naive version recomputes Global_VT and sweeps UPDATE_STATE
        // over every flow here; the indexed equivalents touch only flows
        // whose answer can have changed.
        self.refresh_global_vt();
        self.expire_due(now);
        self.admit_unthrottled();
        self.apply_reclass(now);

        // Line 6 candidate set == `self.eligible` (non-strict: at T=0
        // the minimum-VT queue must stay eligible or classic SFQ would
        // deadlock). The pick keys embed the flow id, so the arbitrary
        // dense-set iteration order cannot change the choice.
        let pick = if self.cfg.sticky {
            // Lines 7–9: longest queue first; under device parallelism,
            // prefer flows with the fewest in-flight invocations.
            if ctx.d != 1 {
                self.eligible.iter().min_by_key(|&i| {
                    (
                        ctx.in_flight[i as usize],
                        Reverse(self.flows[i as usize].len()),
                        i,
                    )
                })
            } else {
                self.eligible
                    .iter()
                    .min_by_key(|&i| (Reverse(self.flows[i as usize].len()), i))
            }
        } else {
            // Original MQFQ: any eligible flow; lowest VT is the natural
            // (classic fair queueing) choice.
            self.eligible.iter().min_by(|&a, &b| {
                self.flows[a as usize]
                    .vt
                    .partial_cmp(&self.flows[b as usize].vt)
                    .expect("VTs are never NaN")
                    .then(a.cmp(&b))
            })
        };
        let Some(chosen) = pick else { return };
        let ci = chosen as usize;

        let estimator = self.cfg.anticipate.estimator;
        let tau = head_tau(&self.cfg, &mut self.chars, &self.flows[ci]);
        let Some(inv) = self.flows[ci].pop_dispatch(tau, now) else {
            return;
        };
        self.queued -= 1;
        if estimator {
            self.chars.on_dispatch(FuncId(chosen), tau, ctx.in_flight[ci]);
        }
        out.push(inv);
        let mut batched = 1usize;
        let mut vt_advance = tau;
        if cap > 1 {
            // Riders coalesce at marginal cost; the over-run guard stops
            // the batch before it would carry the flow's VT past the
            // fairness bound.
            let global = self.global_vt;
            let t = self.cfg.t;
            let marginal = rider_tau(&self.cfg, &self.chars, &self.flows[ci]);
            while batched < cap
                && !self.flows[ci].is_empty()
                && !Self::over_run(self.flows[ci].vt + marginal, global, t)
            {
                let Some(rider) = self.flows[ci].pop_dispatch(marginal, now) else {
                    break;
                };
                self.queued -= 1;
                if estimator {
                    self.chars.on_dispatch(FuncId(chosen), marginal, ctx.in_flight[ci]);
                }
                out.push(rider);
                batched += 1;
                vt_advance += marginal;
            }
        }
        if batched > 1 {
            self.anticipation.push(AnticipationEvent::Batch {
                func: FuncId(chosen),
                size: batched,
                vt_advance: secs(vt_advance),
            });
        }

        let new_vt = self.flows[ci].vt;
        self.vt_heap.push(Reverse((OrdF64(new_vt), chosen)));
        // The dispatch may have advanced the global minimum, pushed the
        // flow over the throttle bound, or emptied it; refresh eagerly
        // so memory management reacts promptly (§4.3).
        self.refresh_global_vt();
        let global = self.global_vt;
        let t = self.cfg.t;
        let throttle = Self::over_run(new_vt, global, t);
        {
            // The chosen flow has in-flight work, so the naive eager
            // UPDATE_STATE lands in its VT branch even if now empty.
            let f = &mut self.flows[ci];
            if throttle {
                Self::set_state(f, QState::Throttled, &mut self.changes);
            } else {
                Self::set_state(f, QState::Active, &mut self.changes);
            }
        }
        if self.flows[ci].is_empty() || Self::ineligible(new_vt, global, t) {
            self.eligible.remove(chosen);
            if Self::ineligible(new_vt, global, t) {
                // Queue for re-admission (state flip + candidate re-entry
                // if still non-empty) once Global_VT catches up.
                self.throttled.push(Reverse((OrdF64(new_vt), chosen)));
            }
        }
    }

    /// A flow just went idle (empty, nothing in flight): arm its
    /// keep-alive deadline and surface a grace hold when anticipation
    /// extended the window. Shared by the completion and fault paths.
    fn arm_idle(&mut self, func: FuncId) {
        let f = &self.flows[func.0 as usize];
        debug_assert!(f.is_empty() && f.in_flight == 0);
        // The flow's window inputs (last_exec, mean IAT, predicted
        // IAT) are frozen until the next arrival or dispatch, so this
        // deadline is exact.
        let window = keep_alive(&self.cfg, &self.chars, f);
        let due = f.last_exec.saturating_add(window);
        self.ttl_heap.push(Reverse((due, func.0)));
        if window > plain_ttl(&self.cfg, f) {
            // Grace actually extended the hold beyond the TTL:
            // surface the non-work-conserving decision.
            let iat = self
                .chars
                .predicted_iat_s(func)
                .unwrap_or_else(|| f.mean_iat_s());
            self.anticipation.push(AnticipationEvent::Grace {
                func,
                window,
                predicted_iat: secs(iat),
            });
        }
        if f.state == QState::Throttled {
            // The naive sweep flips idle Throttled flows to Active
            // (anticipatory) at the next decision regardless of VT.
            self.reclass.push(func.0);
        }
    }
}

impl Policy for MqfqSticky {
    fn name(&self) -> &'static str {
        "mqfq-sticky"
    }

    fn enqueue(&mut self, inv: Invocation, now: Nanos) {
        let idx = inv.func.0 as usize;
        self.chars.on_arrival(inv.func, now);
        let was_empty = self.flows[idx].is_empty();
        if was_empty && self.flows[idx].in_flight == 0 {
            // A flow rejoining the backlogged set starts at the current
            // Global_VT — it gets no credit for its idle past (standard
            // start-time fair queueing). This applies whether it idled
            // as Inactive or as empty-Active (anticipation preserves
            // memory locality, not service credit). Refresh first: the
            // cached Global_VT can be stale-low after completions
            // removed its anchor flow from the backlogged set.
            self.refresh_global_vt();
            let catch_up = self.global_vt.max(self.flows[idx].vt);
            let flow = &mut self.flows[idx];
            flow.vt = catch_up;
            Self::set_state(flow, QState::Active, &mut self.changes);
            self.vt_heap.push(Reverse((OrdF64(catch_up), inv.func.0)));
        }
        self.flows[idx].push(inv, now);
        self.queued += 1;
        if was_empty {
            // Newly non-empty: index into the candidate structures and
            // let the next decision re-derive its state like the naive
            // sweep would.
            let vt = self.flows[idx].vt;
            if Self::ineligible(vt, self.global_vt, self.cfg.t) {
                self.throttled.push(Reverse((OrdF64(vt), inv.func.0)));
            } else {
                self.eligible.insert(inv.func.0);
            }
            self.reclass.push(inv.func.0);
        }
    }

    /// Algorithm 1 DISPATCH, over the incremental indexes (the head-only
    /// view of [`Self::dispatch_impl`]).
    fn dispatch(&mut self, now: Nanos, ctx: &PolicyCtx) -> Option<Invocation> {
        let mut buf = std::mem::take(&mut self.scratch);
        buf.clear();
        self.dispatch_impl(now, ctx, 1, &mut buf);
        let inv = buf.pop();
        self.scratch = buf;
        inv
    }

    fn dispatch_batch(&mut self, now: Nanos, ctx: &PolicyCtx, out: &mut Vec<Invocation>) {
        let cap = self.cfg.anticipate.batch_max.max(1);
        self.dispatch_impl(now, ctx, cap, out);
    }

    fn on_complete(&mut self, func: FuncId, service: DurNanos, now: Nanos) {
        self.on_complete_info(func, service, None, 0, now);
    }

    fn on_complete_info(
        &mut self,
        func: FuncId,
        service: DurNanos,
        start: Option<StartKind>,
        boot: DurNanos,
        now: Nanos,
    ) {
        let i = func.0 as usize;
        self.chars
            .on_complete(func, service, start.unwrap_or(StartKind::GpuWarm), boot);
        self.flows[i].complete(to_secs(service), now);
        let f = &self.flows[i];
        if f.is_empty() && f.in_flight == 0 {
            self.arm_idle(func);
        }
    }

    /// Fault recovery (device loss, transient exec fault, straggler
    /// evacuation): release the attempt's in-flight slot without
    /// learning an exec sample, and — under the retry budget — put the
    /// invocation back at the *head* of its flow. The attempt's VT
    /// advance stands (no double F-advance: the faulty tenant paid for
    /// the service it burned, and the retry charges its own τ), and no
    /// rejoin catch-up applies because a flow with in-flight work was
    /// never Inactive. Mirrored in [`reference::NaiveMqfq`].
    fn on_fault(&mut self, inv: Invocation, now: Nanos, requeue: bool) {
        let i = inv.func.0 as usize;
        if self.cfg.anticipate.estimator {
            // Retire the attempt's charged estimate debt-free — no
            // completion will ever settle it.
            self.chars.on_fault(inv.func);
        }
        self.flows[i].fault(now);
        if requeue {
            let was_empty = self.flows[i].is_empty();
            self.flows[i].requeue_front(inv);
            self.queued += 1;
            if was_empty {
                // Newly non-empty: index into the candidate structures
                // and re-derive state at the next decision — the same
                // moves `enqueue` makes, minus the arrival stats and
                // the VT catch-up (the flow stayed backlogged through
                // the faulted attempt, so it never left the VT frontier).
                let vt = self.flows[i].vt;
                if Self::ineligible(vt, self.global_vt, self.cfg.t) {
                    self.throttled.push(Reverse((OrdF64(vt), inv.func.0)));
                } else {
                    self.eligible.insert(inv.func.0);
                }
                self.reclass.push(inv.func.0);
            }
        } else {
            let f = &self.flows[i];
            if f.is_empty() && f.in_flight == 0 {
                self.arm_idle(inv.func);
            }
        }
    }

    fn drain_anticipation(&mut self) -> Vec<AnticipationEvent> {
        std::mem::take(&mut self.anticipation)
    }

    fn estimated_exec_s(&self, func: FuncId) -> Option<f64> {
        if self.cfg.anticipate.estimator {
            self.chars.predicted_exec_s(func)
        } else {
            None
        }
    }

    fn pending(&self) -> usize {
        self.queued
    }

    fn drain_state_changes(&mut self) -> Vec<(FuncId, QState)> {
        std::mem::take(&mut self.changes)
    }

    fn queue_vt(&self, func: FuncId) -> Option<f64> {
        Some(self.flows[func.0 as usize].vt)
    }

    fn global_vt(&self) -> Option<f64> {
        Some(self.global_vt)
    }
}

pub mod reference {
    //! The naive O(n)-per-decision transliteration of Algorithm 1 — the
    //! original implementation, kept as the behavioral oracle for the
    //! indexed [`MqfqSticky`]: the property suite checks dispatch-
    //! sequence and VT equality against it over randomized traces, and
    //! the perf harness benches it as the pre-refactor baseline for
    //! `BENCH_perf.json`. Not for production use.
    //!
    //! One deliberate difference from the historical code: the enqueue
    //! catch-up recomputes Global_VT first (the historical version read
    //! a value cached at the previous dispatch, which could be stale-low
    //! after completions and under-catch-up a rejoining flow).

    use super::*;

    /// Full-scan MQFQ-Sticky: O(registered flows) per decision.
    pub struct NaiveMqfq {
        cfg: MqfqConfig,
        flows: Vec<FlowQueue>,
        changes: Vec<(FuncId, QState)>,
        global_vt: f64,
        /// Mirrors the indexed scheduler's characteristics map — fed
        /// the same event stream, so grace windows, estimated taus,
        /// and debt evolve identically by construction.
        chars: CharacteristicsMap,
    }

    impl NaiveMqfq {
        pub fn new(n_funcs: usize, cfg: MqfqConfig) -> Self {
            Self {
                cfg,
                flows: (0..n_funcs)
                    .map(|i| FlowQueue::new(FuncId(i as u32)))
                    .collect(),
                changes: Vec::new(),
                global_vt: 0.0,
                chars: CharacteristicsMap::new(),
            }
        }

        pub fn global_vt(&self) -> f64 {
            self.global_vt
        }

        /// `Global_VT ← min over backlogged flows` by full scan.
        fn recompute_global_vt(&mut self) {
            let min = self
                .flows
                .iter()
                .filter(|f| !f.is_empty() || f.in_flight > 0)
                .map(|f| f.vt)
                .fold(f64::INFINITY, f64::min);
            if min.is_finite() {
                self.global_vt = min;
            }
        }

        /// Algorithm 1 UPDATE_STATE for one flow.
        fn update_state(&mut self, idx: usize, now: Nanos) {
            let global = self.global_vt;
            let window = keep_alive(&self.cfg, &self.chars, &self.flows[idx]);
            let t = self.cfg.t;
            let flow = &mut self.flows[idx];
            if flow.state == QState::Inactive {
                return; // reactivated only by an arrival
            }
            if flow.is_empty() && flow.in_flight == 0 {
                if now.saturating_sub(flow.last_exec) >= window {
                    MqfqSticky::set_state(flow, QState::Inactive, &mut self.changes);
                    return;
                }
                MqfqSticky::set_state(flow, QState::Active, &mut self.changes);
                return;
            }
            if flow.vt - global > t {
                MqfqSticky::set_state(flow, QState::Throttled, &mut self.changes);
            } else {
                MqfqSticky::set_state(flow, QState::Active, &mut self.changes);
            }
        }

        /// Full-scan DISPATCH, batch-capable — mirrors
        /// `MqfqSticky::dispatch_impl` decision-for-decision.
        // The candidate `Vec` allocation is part of the historical
        // per-dispatch cost this baseline exists to measure (the index
        // rebuild eliminates it), so it is kept deliberately.
        #[allow(clippy::needless_collect)]
        fn dispatch_impl(
            &mut self,
            now: Nanos,
            ctx: &PolicyCtx,
            cap: usize,
            out: &mut Vec<Invocation>,
        ) {
            self.recompute_global_vt();
            for idx in 0..self.flows.len() {
                self.update_state(idx, now);
            }
            let global = self.global_vt;
            let t = self.cfg.t;

            let cand: Vec<usize> = (0..self.flows.len())
                .filter(|&i| {
                    let f = &self.flows[i];
                    f.state == QState::Active && !f.is_empty() && f.vt <= global + t
                })
                .collect();
            if cand.is_empty() {
                return;
            }
            let pick = if self.cfg.sticky {
                if ctx.d != 1 {
                    cand.into_iter().min_by_key(|&i| {
                        (ctx.in_flight[i], Reverse(self.flows[i].len()), i)
                    })
                } else {
                    cand.into_iter()
                        .min_by_key(|&i| (Reverse(self.flows[i].len()), i))
                }
            } else {
                cand.into_iter().min_by(|&a, &b| {
                    self.flows[a]
                        .vt
                        .partial_cmp(&self.flows[b].vt)
                        .expect("VTs are never NaN")
                        .then(a.cmp(&b))
                })
            };
            let Some(chosen) = pick else { return };

            let estimator = self.cfg.anticipate.estimator;
            let tau = head_tau(&self.cfg, &mut self.chars, &self.flows[chosen]);
            let Some(inv) = self.flows[chosen].pop_dispatch(tau, now) else {
                return;
            };
            if estimator {
                self.chars
                    .on_dispatch(FuncId(chosen as u32), tau, ctx.in_flight[chosen]);
            }
            out.push(inv);
            let mut batched = 1usize;
            if cap > 1 {
                let marginal = rider_tau(&self.cfg, &self.chars, &self.flows[chosen]);
                while batched < cap
                    && !self.flows[chosen].is_empty()
                    && !MqfqSticky::over_run(self.flows[chosen].vt + marginal, global, t)
                {
                    let Some(rider) = self.flows[chosen].pop_dispatch(marginal, now) else {
                        break;
                    };
                    if estimator {
                        self.chars.on_dispatch(
                            FuncId(chosen as u32),
                            marginal,
                            ctx.in_flight[chosen],
                        );
                    }
                    out.push(rider);
                    batched += 1;
                }
            }
            self.recompute_global_vt();
            self.update_state(chosen, now);
        }
    }

    impl Policy for NaiveMqfq {
        fn name(&self) -> &'static str {
            "mqfq-sticky-naive"
        }

        fn enqueue(&mut self, inv: Invocation, now: Nanos) {
            let idx = inv.func.0 as usize;
            self.chars.on_arrival(inv.func, now);
            if self.flows[idx].is_empty() && self.flows[idx].in_flight == 0 {
                self.recompute_global_vt();
                let catch_up = self.global_vt.max(self.flows[idx].vt);
                let flow = &mut self.flows[idx];
                flow.vt = catch_up;
                MqfqSticky::set_state(flow, QState::Active, &mut self.changes);
            }
            self.flows[idx].push(inv, now);
        }

        fn dispatch(&mut self, now: Nanos, ctx: &PolicyCtx) -> Option<Invocation> {
            let mut buf = Vec::with_capacity(1);
            self.dispatch_impl(now, ctx, 1, &mut buf);
            buf.pop()
        }

        fn dispatch_batch(&mut self, now: Nanos, ctx: &PolicyCtx, out: &mut Vec<Invocation>) {
            let cap = self.cfg.anticipate.batch_max.max(1);
            self.dispatch_impl(now, ctx, cap, out);
        }

        fn on_complete(&mut self, func: FuncId, service: DurNanos, now: Nanos) {
            self.on_complete_info(func, service, None, 0, now);
        }

        fn on_complete_info(
            &mut self,
            func: FuncId,
            service: DurNanos,
            start: Option<StartKind>,
            boot: DurNanos,
            now: Nanos,
        ) {
            self.chars
                .on_complete(func, service, start.unwrap_or(StartKind::GpuWarm), boot);
            self.flows[func.0 as usize].complete(to_secs(service), now);
        }

        /// Mirror of [`MqfqSticky::on_fault`]: identical flow-queue and
        /// estimator arithmetic; no index maintenance because the next
        /// decision's full sweep re-derives everything.
        fn on_fault(&mut self, inv: Invocation, now: Nanos, requeue: bool) {
            if self.cfg.anticipate.estimator {
                self.chars.on_fault(inv.func);
            }
            let f = &mut self.flows[inv.func.0 as usize];
            f.fault(now);
            if requeue {
                f.requeue_front(inv);
            }
        }

        fn estimated_exec_s(&self, func: FuncId) -> Option<f64> {
            if self.cfg.anticipate.estimator {
                self.chars.predicted_exec_s(func)
            } else {
                None
            }
        }

        fn pending(&self) -> usize {
            self.flows.iter().map(|f| f.len()).sum()
        }

        fn drain_state_changes(&mut self) -> Vec<(FuncId, QState)> {
            std::mem::take(&mut self.changes)
        }

        fn queue_vt(&self, func: FuncId) -> Option<f64> {
            Some(self.flows[func.0 as usize].vt)
        }

        fn global_vt(&self) -> Option<f64> {
            Some(self.global_vt)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::testutil::enqueue_n;
    use crate::types::{InvocationId, SEC};
    use crate::util::prop::assert_prop;
    use crate::util::rng::zipf_weights;

    fn ctx<'a>(in_flight: &'a [usize], d: usize) -> PolicyCtx<'a> {
        PolicyCtx { in_flight, d }
    }

    fn mk(n: usize) -> MqfqSticky {
        MqfqSticky::new(n, MqfqConfig::default())
    }

    #[test]
    fn dispatches_fifo_within_flow() {
        let mut p = mk(1);
        enqueue_n(&mut p, 0, 3, 0, 1);
        let inf = [0usize];
        let a = p.dispatch(0, &ctx(&inf, 1)).unwrap();
        let b = p.dispatch(0, &ctx(&inf, 1)).unwrap();
        assert_eq!(a.id, InvocationId(1));
        assert_eq!(b.id, InvocationId(2));
        assert_eq!(p.pending(), 1);
    }

    #[test]
    fn sticky_prefers_longest_queue() {
        let mut p = mk(2);
        enqueue_n(&mut p, 0, 1, 0, 1);
        enqueue_n(&mut p, 1, 5, 0, 10);
        let inf = [0usize, 0];
        let got = p.dispatch(0, &ctx(&inf, 1)).unwrap();
        assert_eq!(got.func, FuncId(1), "longest queue should win");
    }

    #[test]
    fn least_in_flight_breaks_ties_at_d_gt_1() {
        let mut p = mk(2);
        enqueue_n(&mut p, 0, 3, 0, 1);
        enqueue_n(&mut p, 1, 3, 0, 10);
        // Flow 0 already has an in-flight invocation; at D=2 flow 1 wins
        // despite equal queue lengths.
        let inf = [1usize, 0];
        let got = p.dispatch(0, &ctx(&inf, 2)).unwrap();
        assert_eq!(got.func, FuncId(1));
    }

    #[test]
    fn throttling_caps_overrun() {
        let cfg = MqfqConfig {
            t: 2.0,
            ..Default::default()
        };
        let mut p = MqfqSticky::new(2, cfg);
        enqueue_n(&mut p, 0, 100, 0, 1);
        enqueue_n(&mut p, 1, 20, 0, 1000);
        // Flow 0's queue is 5× longer, so sticky dispatch prefers it —
        // but with T=2 and τ≈1s it may over-run flow 1's VT by at most 2
        // before throttling forces flow 1 through: both make progress.
        let inf = [0usize, 0];
        let mut f0 = 0;
        let mut f1 = 0;
        for i in 0..16 {
            let inv = p
                .dispatch(i * SEC, &ctx(&inf, 1))
                .expect("backlogged flows must keep dispatching");
            p.on_complete(inv.func, SEC, i * SEC + SEC / 2);
            match inv.func {
                FuncId(0) => f0 += 1,
                _ => f1 += 1,
            }
        }
        assert!(f1 >= 5, "short flow starved: f0={f0} f1={f1}");
        assert!(f0 >= 5, "long flow over-throttled: f0={f0} f1={f1}");
        // The over-run bound holds throughout.
        assert!(
            (p.queue_vt(FuncId(0)).unwrap() - p.queue_vt(FuncId(1)).unwrap()).abs()
                <= 2.0 + 1.0 + 1e-9,
            "VT gap exceeded T+τ"
        );
    }

    #[test]
    fn throttled_flow_resumes_after_global_vt_catches_up() {
        let cfg = MqfqConfig {
            t: 1.0,
            ..Default::default()
        };
        let mut p = MqfqSticky::new(2, cfg);
        enqueue_n(&mut p, 0, 10, 0, 1);
        enqueue_n(&mut p, 1, 10, 0, 100);
        let inf = [0usize, 0];
        // Alternate dispatch+completion; both flows should make steady
        // progress (fair round-robin-ish with τ defaults of 1s).
        let mut counts = [0usize; 2];
        for i in 0..10 {
            let inv = p.dispatch(i * SEC, &ctx(&inf, 1)).unwrap();
            counts[inv.func.0 as usize] += 1;
            p.on_complete(inv.func, SEC, i * SEC);
        }
        assert!(counts[0] >= 4 && counts[1] >= 4, "{counts:?}");
    }

    #[test]
    fn wall_time_vt_gives_short_functions_more_dispatches() {
        let mut p = mk(2);
        enqueue_n(&mut p, 0, 100, 0, 1); // will be slow: 4 s
        enqueue_n(&mut p, 1, 400, 0, 1000); // fast: 0.5 s
        let inf = [0usize, 0];
        // Teach the policy the service times.
        for _ in 0..2 {
            let inv = p.dispatch(0, &ctx(&inf, 1)).unwrap();
            let svc = if inv.func == FuncId(0) { 4 * SEC } else { SEC / 2 };
            p.on_complete(inv.func, svc, 0);
        }
        let mut counts = [0usize; 2];
        for i in 0..100 {
            let Some(inv) = p.dispatch(i * SEC, &ctx(&inf, 1)) else {
                break;
            };
            let svc = if inv.func == FuncId(0) { 4 * SEC } else { SEC / 2 };
            p.on_complete(inv.func, svc, i * SEC);
            counts[inv.func.0 as usize] += 1;
        }
        // Steady state: equal *service*, so dispatch counts scale with
        // 1/τ — the fast flow should see ~8× more invocations (the T=10
        // over-run transient dampens it below the ideal early on).
        assert!(
            counts[1] > 4 * counts[0],
            "fast flow should get far more dispatches: {counts:?}"
        );
    }

    #[test]
    fn ttl_expires_idle_queue_to_inactive() {
        let cfg = MqfqConfig {
            ttl_alpha: 2.0,
            ..Default::default()
        };
        let mut p = MqfqSticky::new(1, cfg);
        // Arrivals 1 s apart → IAT ≈ 1 s → TTL ≈ 2 s.
        p.enqueue(
            Invocation {
                id: InvocationId(1),
                func: FuncId(0),
                arrived: 0,
            },
            0,
        );
        p.enqueue(
            Invocation {
                id: InvocationId(2),
                func: FuncId(0),
                arrived: SEC,
            },
            SEC,
        );
        let inf = [0usize];
        p.dispatch(SEC, &ctx(&inf, 1)).unwrap();
        p.on_complete(FuncId(0), SEC / 2, SEC);
        p.dispatch(SEC, &ctx(&inf, 1)).unwrap();
        p.on_complete(FuncId(0), SEC / 2, 2 * SEC);
        // Within TTL: still Active (anticipatory).
        assert!(p.dispatch(3 * SEC, &ctx(&inf, 1)).is_none());
        assert_eq!(p.flow(FuncId(0)).state, QState::Active);
        // Past TTL: Inactive.
        assert!(p.dispatch(10 * SEC, &ctx(&inf, 1)).is_none());
        assert_eq!(p.flow(FuncId(0)).state, QState::Inactive);
        let changes = p.drain_state_changes();
        assert!(changes.contains(&(FuncId(0), QState::Inactive)));
    }

    #[test]
    fn reactivated_flow_catches_up_to_global_vt() {
        let cfg = MqfqConfig {
            fixed_ttl_s: Some(0.0), // expire immediately when idle
            ..Default::default()
        };
        let mut p = MqfqSticky::new(2, cfg);
        enqueue_n(&mut p, 0, 5, 0, 1);
        let inf = [0usize, 0];
        for i in 0..5 {
            let inv = p.dispatch(i * SEC, &ctx(&inf, 1)).unwrap();
            p.on_complete(inv.func, SEC, i * SEC);
        }
        assert!(p.queue_vt(FuncId(0)).unwrap() >= 5.0 - 1e-9);
        // Flow 1 arrives late; it must start at Global_VT, not 0 —
        // otherwise it would monopolize the GPU to "catch up".
        enqueue_n(&mut p, 1, 1, 6 * SEC, 50);
        assert!(p.queue_vt(FuncId(1)).unwrap() >= p.queue_vt(FuncId(0)).unwrap() - 1e-9);
    }

    #[test]
    fn rejoining_flow_catches_up_to_fresh_global_vt() {
        // Regression for the stale-catch-up bug: the pre-index
        // implementation read a Global_VT cached at the *previous
        // dispatch* during the enqueue catch-up. A completion between
        // that dispatch and the enqueue can remove the minimum-VT flow
        // from the backlogged set, so the cached value is stale-low and
        // the rejoining flow under-catches-up (gaining unearned credit).
        let mut p = mk(3);
        let inf = [0usize, 0, 0];
        // Flow 0: one invocation; dispatching it advances flow 0 to VT=1
        // and leaves it backlogged (in flight), anchoring Global_VT at 1.
        enqueue_n(&mut p, 0, 1, 0, 1);
        assert_eq!(p.dispatch(0, &ctx(&inf, 2)).unwrap().func, FuncId(0));
        // Flow 1 joins at Global_VT=1 and runs ahead to VT=3.
        enqueue_n(&mut p, 1, 3, 0, 10);
        assert_eq!(p.dispatch(0, &ctx(&inf, 2)).unwrap().func, FuncId(1));
        assert_eq!(p.dispatch(0, &ctx(&inf, 2)).unwrap().func, FuncId(1));
        // Flow 0 completes: the only backlogged flow is now flow 1
        // (VT=3, one invocation still queued), so the true Global_VT
        // is 3 — but no dispatch has refreshed any cache since.
        p.on_complete(FuncId(0), SEC, 0);
        // Flow 2 rejoins from idle; it must start at 3, not the stale 1.
        enqueue_n(&mut p, 2, 1, 0, 100);
        let vt2 = p.queue_vt(FuncId(2)).unwrap();
        assert!(vt2 >= 3.0 - 1e-9, "under-catch-up: joined at VT {vt2}");
    }

    #[test]
    fn non_sticky_picks_lowest_vt() {
        let cfg = MqfqConfig {
            sticky: false,
            ..Default::default()
        };
        let mut p = MqfqSticky::new(2, cfg);
        enqueue_n(&mut p, 0, 1, 0, 1);
        enqueue_n(&mut p, 1, 10, 0, 10);
        let inf = [0usize, 0];
        // Equal VTs tie-break by index: flow 0 wins even though flow 1
        // has the (much) longer queue — the sticky heuristic is off.
        let first = p.dispatch(0, &ctx(&inf, 1)).unwrap();
        assert_eq!(first.func, FuncId(0));
        // Flow 0's VT advanced; the lowest-VT pick is now flow 1.
        let second = p.dispatch(0, &ctx(&inf, 1)).unwrap();
        assert_eq!(second.func, FuncId(1));
    }

    #[test]
    fn state_changes_reported_once() {
        let mut p = mk(1);
        enqueue_n(&mut p, 0, 1, 0, 1);
        let changes = p.drain_state_changes();
        assert_eq!(changes, vec![(FuncId(0), QState::Active)]);
        assert!(p.drain_state_changes().is_empty());
    }

    #[test]
    fn dispatch_on_empty_returns_none() {
        let mut p = mk(3);
        let inf = [0usize, 0, 0];
        assert!(p.dispatch(0, &ctx(&inf, 2)).is_none());
    }

    /// Satellite regression: a flow inside its grace window must not be
    /// TTL-expired by the deadline heap. Gappy single-flow trace: the
    /// arrival gap sits past the plain TTL but inside the grace window,
    /// so the graced run stays Active across the gap while the
    /// grace-free run goes Inactive.
    #[test]
    fn grace_window_outlives_ttl_expiry() {
        let run = |grace_alpha: f64| {
            let cfg = MqfqConfig {
                ttl_alpha: 0.5,
                anticipate: AnticipateConfig {
                    grace_alpha,
                    ..Default::default()
                },
                ..Default::default()
            };
            let mut p = MqfqSticky::new(1, cfg);
            let inf = [0usize];
            // Two arrivals 2 s apart: IAT ≈ 2 s, so TTL ≈ 1 s while the
            // grace window (α=3) is ≈ 6 s.
            for (id, t) in [(1u64, 0u64), (2, 2 * SEC)] {
                p.enqueue(
                    Invocation {
                        id: InvocationId(id),
                        func: FuncId(0),
                        arrived: t,
                    },
                    t,
                );
                p.dispatch(t, &ctx(&inf, 1)).unwrap();
                p.on_complete(FuncId(0), SEC / 2, t + SEC / 2);
            }
            // Idle since 2.5 s; probe at 5 s (past TTL, inside grace).
            assert!(p.dispatch(5 * SEC, &ctx(&inf, 1)).is_none());
            p
        };

        let graced = run(3.0);
        assert_eq!(
            graced.flow(FuncId(0)).state,
            QState::Active,
            "grace window must hold the flow Active past the plain TTL"
        );
        let plain = run(0.0);
        assert_eq!(
            plain.flow(FuncId(0)).state,
            QState::Inactive,
            "without grace the TTL path demotes at ≈3.5 s"
        );

        // Past the grace window the flow still expires (grace stretches
        // the hold, it does not cancel eviction).
        let mut graced = graced;
        let inf = [0usize];
        assert!(graced.dispatch(20 * SEC, &ctx(&inf, 1)).is_none());
        assert_eq!(graced.flow(FuncId(0)).state, QState::Inactive);
    }

    #[test]
    fn grace_hold_surfaces_anticipation_event() {
        let cfg = MqfqConfig {
            ttl_alpha: 0.5,
            anticipate: AnticipateConfig {
                grace_alpha: 3.0,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut p = MqfqSticky::new(1, cfg);
        let inf = [0usize];
        for (id, t) in [(1u64, 0u64), (2, 2 * SEC)] {
            p.enqueue(
                Invocation {
                    id: InvocationId(id),
                    func: FuncId(0),
                    arrived: t,
                },
                t,
            );
            p.dispatch(t, &ctx(&inf, 1)).unwrap();
            p.on_complete(FuncId(0), SEC / 2, t + SEC / 2);
        }
        let events = p.drain_anticipation();
        assert!(
            events
                .iter()
                .any(|e| matches!(e, AnticipationEvent::Grace { func: FuncId(0), .. })),
            "idle-with-grace must record a Grace hold: {events:?}"
        );
        assert!(p.drain_anticipation().is_empty(), "drain must consume");
    }

    #[test]
    fn batch_dispatch_coalesces_same_flow() {
        let cfg = MqfqConfig {
            anticipate: AnticipateConfig {
                batch_max: 3,
                batch_marginal: 0.5,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut p = MqfqSticky::new(1, cfg);
        enqueue_n(&mut p, 0, 5, 0, 1);
        let inf = [0usize];
        let mut out = Vec::new();
        p.dispatch_batch(0, &ctx(&inf, 1), &mut out);
        // Head + 2 riders, FIFO order; τ = 1 s (default) for the head
        // and 0.5 s marginal per rider → VT = 2.0.
        assert_eq!(
            out.iter().map(|i| i.id.0).collect::<Vec<_>>(),
            vec![1, 2, 3]
        );
        assert_eq!(p.pending(), 2);
        assert!((p.queue_vt(FuncId(0)).unwrap() - 2.0).abs() < 1e-9);
        assert_eq!(p.flow(FuncId(0)).in_flight, 3);
        let events = p.drain_anticipation();
        assert!(
            events
                .iter()
                .any(|e| matches!(e, AnticipationEvent::Batch { size: 3, .. })),
            "{events:?}"
        );
    }

    #[test]
    fn batch_riders_respect_over_run_bound() {
        // T = 1.0 and τ defaults of 1 s: the head advances VT to 1.0
        // (== Global_VT + T, not over), but any rider at marginal 1.0
        // would over-run — the batch must stop at the head.
        let cfg = MqfqConfig {
            t: 1.0,
            anticipate: AnticipateConfig {
                batch_max: 8,
                batch_marginal: 1.0,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut p = MqfqSticky::new(2, cfg);
        enqueue_n(&mut p, 0, 6, 0, 1);
        enqueue_n(&mut p, 1, 1, 0, 100); // anchors Global_VT at 0
        let inf = [0usize, 0];
        let mut out = Vec::new();
        p.dispatch_batch(0, &ctx(&inf, 1), &mut out);
        assert_eq!(out.len(), 1, "fairness guard must cap the batch: {out:?}");
    }

    #[test]
    fn estimator_vt_charges_prediction_then_repays_debt() {
        let cfg = MqfqConfig {
            anticipate: AnticipateConfig {
                estimator: true,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut p = MqfqSticky::new(1, cfg);
        enqueue_n(&mut p, 0, 2, 0, 1);
        let inf = [0usize];
        // No observation yet: charged at the 1 s black-box default.
        p.dispatch(0, &ctx(&inf, 1)).unwrap();
        assert!((p.queue_vt(FuncId(0)).unwrap() - 1.0).abs() < 1e-9);
        // Actual service 3 s → debt +2 s; the next dispatch charges the
        // refreshed estimate (EWMA seeded at 3.0) plus the debt.
        p.on_complete(FuncId(0), 3 * SEC, SEC);
        p.dispatch(2 * SEC, &ctx(&inf, 1)).unwrap();
        assert!(
            (p.queue_vt(FuncId(0)).unwrap() - 6.0).abs() < 1e-9,
            "vt {}",
            p.queue_vt(FuncId(0)).unwrap()
        );
    }

    #[test]
    fn fault_requeues_at_head_without_double_f_advance() {
        let mut p = mk(2);
        enqueue_n(&mut p, 0, 2, 0, 1); // ids 1, 2
        enqueue_n(&mut p, 1, 1, 0, 10);
        let inf = [0usize, 0];
        let first = p.dispatch(0, &ctx(&inf, 2)).unwrap();
        assert_eq!(first.id, InvocationId(1));
        let vt_after_dispatch = p.queue_vt(FuncId(0)).unwrap();
        assert!(vt_after_dispatch > 0.0);
        // The attempt faults and re-queues: VT unchanged (the charge
        // stands), in-flight released, and the retry sits at the head
        // of its flow ahead of id 2.
        p.on_fault(first, SEC, true);
        assert_eq!(p.queue_vt(FuncId(0)).unwrap(), vt_after_dispatch);
        assert_eq!(p.flow(FuncId(0)).in_flight, 0);
        assert_eq!(p.pending(), 3);
        let retry = p.dispatch(SEC, &ctx(&inf, 2)).unwrap();
        assert_eq!(retry.id, InvocationId(1), "retry preempts newer work");
        // Exhausted budget: the fault drops the invocation instead.
        p.on_fault(retry, 2 * SEC, false);
        assert_eq!(p.pending(), 2);
        assert_eq!(p.flow(FuncId(0)).in_flight, 0);
    }

    #[test]
    fn fault_with_estimator_retires_outstanding_charge() {
        let cfg = MqfqConfig {
            anticipate: AnticipateConfig {
                estimator: true,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut p = MqfqSticky::new(1, cfg);
        enqueue_n(&mut p, 0, 2, 0, 1);
        let inf = [0usize];
        let inv = p.dispatch(0, &ctx(&inf, 1)).unwrap();
        let vt1 = p.queue_vt(FuncId(0)).unwrap();
        p.on_fault(inv, SEC, true);
        // No debt was created: the next dispatch charges a fresh
        // estimate on top of the standing VT, not a corrected one.
        p.dispatch(SEC, &ctx(&inf, 1)).unwrap();
        let vt2 = p.queue_vt(FuncId(0)).unwrap();
        assert!((vt2 - 2.0 * vt1).abs() < 1e-9, "vt1={vt1} vt2={vt2}");
        assert!(p.characteristics().debt_s(FuncId(0)).abs() < 1e-12);
    }

    /// The tentpole guarantee: over randomized Zipf-popularity traces of
    /// interleaved arrivals, dispatches, and completions, the indexed
    /// implementation produces the *identical* dispatch sequence, VTs,
    /// Global_VT, pending count, and per-op state-change stream as the
    /// naive full-scan reference — i.e. the O(E + log n) rewrite
    /// provably preserves Algorithm 1 and the memory-manager interface.
    #[test]
    fn prop_indexed_matches_naive_reference() {
        assert_prop("indexed-vs-naive", 80, |g| {
            let n_flows = g.int(1, 16);
            let cfg = MqfqConfig {
                t: g.f64(0.0, 12.0),
                ttl_alpha: g.f64(0.0, 3.0),
                fixed_ttl_s: if g.bool(0.3) {
                    Some(g.f64(0.0, 4.0))
                } else {
                    None
                },
                vt_wall_time: g.bool(0.8),
                sticky: g.bool(0.8),
                // Half the cases exercise the anticipatory machinery
                // (grace windows, rider batches, estimated-then-
                // corrected taus); the other half stay all-neutral.
                anticipate: AnticipateConfig {
                    grace_alpha: if g.bool(0.5) { g.f64(0.0, 4.0) } else { 0.0 },
                    batch_max: g.int(1, 5),
                    batch_marginal: g.f64(0.1, 1.0),
                    estimator: g.bool(0.5),
                },
            };
            let d = g.int(1, 4);
            let mut fast = MqfqSticky::new(n_flows, cfg.clone());
            let mut oracle = reference::NaiveMqfq::new(n_flows, cfg);
            let weights = zipf_weights(n_flows, 1.2);
            let pick_func = |g: &mut crate::util::prop::Gen| {
                let u = g.f64(0.0, 1.0);
                let mut acc = 0.0;
                for (i, w) in weights.iter().enumerate() {
                    acc += w;
                    if u < acc {
                        return FuncId(i as u32);
                    }
                }
                FuncId((n_flows - 1) as u32)
            };

            // The Active/Throttled/Inactive stream drives the memory
            // manager (plane::apply_state_changes), so it must match
            // too. Compared as a sorted multiset: transitions for
            // *different* flows may interleave differently (the naive
            // sweep walks flows in index order; the indexed path drains
            // its heaps), which the plane does not depend on.
            fn drained(p: &mut dyn Policy) -> Vec<(u32, u8)> {
                let mut v: Vec<(u32, u8)> = p
                    .drain_state_changes()
                    .into_iter()
                    .map(|(f, s)| {
                        (
                            f.0,
                            match s {
                                QState::Active => 0,
                                QState::Throttled => 1,
                                QState::Inactive => 2,
                            },
                        )
                    })
                    .collect();
                v.sort_unstable();
                v
            }

            let mut now: Nanos = 0;
            let mut id = 0u64;
            let mut in_flight = vec![0usize; n_flows];
            let mut outstanding: Vec<Invocation> = Vec::new();
            let steps = g.int(10, 250);
            for step in 0..steps {
                now += secs(g.f64(0.0, 2.5));
                // Op 3 (fault: requeue-at-head or drop) extends the
                // equivalence over fault recovery — PR 10's "no double
                // F-advance, mirrored in NaiveMqfq" requirement.
                match g.int(0, 3) {
                    0 => {
                        for _ in 0..g.int(1, 4) {
                            let inv = Invocation {
                                id: InvocationId(id),
                                func: pick_func(g),
                                arrived: now,
                            };
                            id += 1;
                            fast.enqueue(inv, now);
                            oracle.enqueue(inv, now);
                        }
                    }
                    1 => {
                        let c = ctx(&in_flight, d);
                        let mut a = Vec::new();
                        let mut b = Vec::new();
                        fast.dispatch_batch(now, &c, &mut a);
                        oracle.dispatch_batch(now, &c, &mut b);
                        if a != b {
                            return Err(format!(
                                "step {step}: dispatch diverged: indexed={a:?} naive={b:?}"
                            ));
                        }
                        for inv in a {
                            in_flight[inv.func.0 as usize] += 1;
                            outstanding.push(inv);
                        }
                    }
                    2 => {
                        if !outstanding.is_empty() {
                            let k = g.int(0, outstanding.len() - 1);
                            let inv = outstanding.swap_remove(k);
                            let requeue = g.bool(0.7);
                            fast.on_fault(inv, now, requeue);
                            oracle.on_fault(inv, now, requeue);
                            in_flight[inv.func.0 as usize] -= 1;
                        }
                    }
                    _ => {
                        if !outstanding.is_empty() {
                            let k = g.int(0, outstanding.len() - 1);
                            let inv = outstanding.swap_remove(k);
                            let svc = secs(g.f64(0.01, 4.0));
                            let start = match g.int(0, 3) {
                                0 => None,
                                1 => Some(StartKind::Cold),
                                2 => Some(StartKind::HostWarm),
                                _ => Some(StartKind::GpuWarm),
                            };
                            let boot = secs(g.f64(0.0, 1.0));
                            fast.on_complete_info(inv.func, svc, start, boot, now);
                            oracle.on_complete_info(inv.func, svc, start, boot, now);
                            in_flight[inv.func.0 as usize] -= 1;
                        }
                    }
                }
                if fast.pending() != oracle.pending() {
                    return Err(format!(
                        "step {step}: pending diverged: {} vs {}",
                        fast.pending(),
                        oracle.pending()
                    ));
                }
                let (ca, cb) = (drained(&mut fast), drained(&mut oracle));
                if ca != cb {
                    return Err(format!(
                        "step {step}: state-change stream diverged: indexed={ca:?} naive={cb:?}"
                    ));
                }
            }
            for f in 0..n_flows {
                let (a, b) = (
                    fast.queue_vt(FuncId(f as u32)).unwrap(),
                    oracle.queue_vt(FuncId(f as u32)).unwrap(),
                );
                if a != b {
                    return Err(format!("flow {f}: final VT diverged: {a} vs {b}"));
                }
            }
            // Equal up to laziness: the indexed cache refreshes on the
            // next decision, so compare through one.
            let c = ctx(&in_flight, d);
            let mut a = Vec::new();
            let mut b = Vec::new();
            fast.dispatch_batch(now, &c, &mut a);
            oracle.dispatch_batch(now, &c, &mut b);
            if a != b {
                return Err(format!("final dispatch diverged: {a:?} vs {b:?}"));
            }
            if fast.global_vt() != oracle.global_vt() {
                return Err(format!(
                    "Global_VT diverged: {} vs {}",
                    fast.global_vt(),
                    oracle.global_vt()
                ));
            }
            Ok(())
        });
    }
}
