//! MQFQ-Sticky (§4.2, Algorithm 1): locality-enhanced multi-queue fair
//! queueing for GPU functions.
//!
//! Key mechanisms, all implemented here:
//! * **Per-function fairness** — each dispatch advances the flow's VT by
//!   its historical average execution time τ_f, so short functions get
//!   more invocations but equal wall-clock service.
//! * **Queue over-run (T)** — flows may be dispatched while
//!   `VT < Global_VT + T`, enabling mini-batches and locality; beyond
//!   that they are *Throttled* until Global_VT catches up.
//! * **Anticipatory keep-alive (TTL = α × IAT)** — empty queues stay
//!   Active for a per-function grace period so their warm containers and
//!   device memory survive idle gaps (adapted from anticipatory disk
//!   scheduling [43]).
//! * **Preferential ("sticky") dispatch** — among eligible flows, prefer
//!   the longest queue (batching, backlog drain), tie-broken by fewest
//!   in-flight invocations (avoids concurrent same-function dispatches,
//!   which cause cold starts; keeps multiple flows progressing).
//!
//! Fairness (Eq. 1): because eligible flows always satisfy
//! `VT < Global_VT + T`, MQFQ-Sticky's dispatch choices are a subset of
//! MQFQ's, retaining its bound |S_i/w_i − S_j/w_j| ≤ (D−1)(2T + τ_i − τ_j).

use crate::types::{secs, to_secs, DurNanos, FuncId, Nanos};

use super::flowq::{FlowQueue, QState};
use super::{Invocation, Policy, PolicyCtx};

/// Tunables (Table 2) + the ablation switches of §6.4.
#[derive(Debug, Clone)]
pub struct MqfqConfig {
    /// Queue over-run T, in seconds of virtual time (paper default: 10).
    pub t: f64,
    /// Anticipatory keep-alive scale α: TTL = α × IAT (paper default: 2).
    pub ttl_alpha: f64,
    /// Fig-8b variant: one fixed TTL for every function (seconds),
    /// overriding the per-function α × IAT policy.
    pub fixed_ttl_s: Option<f64>,
    /// Advance VT by wall-time τ_f (true, paper default) or by 1.0 per
    /// invocation (the "1.0" ablation of Fig 8a).
    pub vt_wall_time: bool,
    /// Preferential longest-queue/least-in-flight dispatch (true) vs the
    /// original MQFQ's arbitrary eligible pick, here lowest-VT (§6.4
    /// ablation: disabling costs 1–30% latency).
    pub sticky: bool,
}

impl Default for MqfqConfig {
    fn default() -> Self {
        Self {
            t: 10.0,
            ttl_alpha: 2.0,
            fixed_ttl_s: None,
            vt_wall_time: true,
            sticky: true,
        }
    }
}

/// The MQFQ-Sticky policy over a fixed set of registered functions.
pub struct MqfqSticky {
    cfg: MqfqConfig,
    flows: Vec<FlowQueue>,
    changes: Vec<(FuncId, QState)>,
    /// Cached Global_VT (recomputed each dispatch round).
    global_vt: f64,
}

impl MqfqSticky {
    pub fn new(n_funcs: usize, cfg: MqfqConfig) -> Self {
        Self {
            cfg,
            flows: (0..n_funcs).map(|i| FlowQueue::new(FuncId(i as u32))).collect(),
            changes: Vec::new(),
            global_vt: 0.0,
        }
    }

    pub fn config(&self) -> &MqfqConfig {
        &self.cfg
    }

    pub fn flow(&self, func: FuncId) -> &FlowQueue {
        &self.flows[func.0 as usize]
    }

    pub fn global_vt(&self) -> f64 {
        self.global_vt
    }

    /// TTL for one flow (Table 2: α × IAT, or the fixed global variant).
    fn ttl(&self, flow: &FlowQueue) -> DurNanos {
        match self.cfg.fixed_ttl_s {
            Some(s) => secs(s),
            None => secs(self.cfg.ttl_alpha * flow.mean_iat_s()),
        }
    }

    fn set_state(flow: &mut FlowQueue, state: QState, changes: &mut Vec<(FuncId, QState)>) {
        if flow.state != state {
            flow.state = state;
            changes.push((flow.func, state));
        }
    }

    /// `Global_VT ← min over backlogged flows` (Algorithm 1 line 2).
    ///
    /// Backlogged = has queued or in-flight work. Empty *Active* queues
    /// (anticipatory keep-alive) deliberately do NOT anchor Global_VT:
    /// anticipation preserves a flow's *memory locality* (containers,
    /// device regions — §4.3), not a service reservation. Letting an
    /// idle flow hold the global minimum would throttle every busy flow
    /// after T seconds of over-run and idle the GPU for up to the TTL.
    fn recompute_global_vt(&mut self) {
        let min = self
            .flows
            .iter()
            .filter(|f| !f.is_empty() || f.in_flight > 0)
            .map(|f| f.vt)
            .fold(f64::INFINITY, f64::min);
        if min.is_finite() {
            self.global_vt = min;
        }
    }

    /// Algorithm 1 UPDATE_STATE: expire empty queues past their TTL,
    /// throttle over-run queues, activate the rest.
    fn update_state(&mut self, idx: usize, now: Nanos) {
        let global = self.global_vt;
        let ttl = self.ttl(&self.flows[idx]);
        let t = self.cfg.t;
        let flow = &mut self.flows[idx];
        if flow.state == QState::Inactive {
            return; // reactivated only by an arrival
        }
        if flow.is_empty() && flow.in_flight == 0 {
            if now.saturating_sub(flow.last_exec) >= ttl {
                Self::set_state(flow, QState::Inactive, &mut self.changes);
                return;
            }
            // Anticipatory: stay Active while within the grace period.
            Self::set_state(flow, QState::Active, &mut self.changes);
            return;
        }
        if flow.vt - global > t {
            Self::set_state(flow, QState::Throttled, &mut self.changes);
        } else {
            Self::set_state(flow, QState::Active, &mut self.changes);
        }
    }
}

impl Policy for MqfqSticky {
    fn name(&self) -> &'static str {
        "mqfq-sticky"
    }

    fn enqueue(&mut self, inv: Invocation, now: Nanos) {
        let idx = inv.func.0 as usize;
        // A flow rejoining the backlogged set starts at the current
        // Global_VT — it gets no credit for its idle past (standard
        // start-time fair queueing). This applies whether it idled as
        // Inactive or as empty-Active (anticipation preserves memory
        // locality, not service credit).
        if self.flows[idx].is_empty() && self.flows[idx].in_flight == 0 {
            let catch_up = self.global_vt.max(self.flows[idx].vt);
            let flow = &mut self.flows[idx];
            flow.vt = catch_up;
            Self::set_state(flow, QState::Active, &mut self.changes);
        }
        self.flows[idx].push(inv, now);
    }

    /// Algorithm 1 DISPATCH.
    fn dispatch(&mut self, now: Nanos, ctx: &PolicyCtx) -> Option<Invocation> {
        self.recompute_global_vt();
        for idx in 0..self.flows.len() {
            self.update_state(idx, now);
        }
        let global = self.global_vt;
        let t = self.cfg.t;

        // Line 6: candidate filter. Non-strict: at T=0 the minimum-VT
        // queue (vt == Global_VT) must stay eligible or classic SFQ
        // would deadlock.
        let cand: Vec<usize> = (0..self.flows.len())
            .filter(|&i| {
                let f = &self.flows[i];
                f.state == QState::Active && !f.is_empty() && f.vt <= global + t
            })
            .collect();
        if cand.is_empty() {
            return None;
        }

        let chosen = if self.cfg.sticky {
            // Lines 7–9: longest queue first; under device parallelism,
            // prefer flows with the fewest in-flight invocations. Only
            // the top candidate is dispatched, so a single-pass min
            // selection replaces the full sort (perf: §Perf iteration 2,
            // ~35% off the decision latency at 1000 flows).
            if ctx.d != 1 {
                cand.into_iter()
                    .min_by_key(|&i| {
                        (
                            ctx.in_flight[i],
                            std::cmp::Reverse(self.flows[i].len()),
                            i,
                        )
                    })
                    .unwrap()
            } else {
                cand.into_iter()
                    .min_by_key(|&i| (std::cmp::Reverse(self.flows[i].len()), i))
                    .unwrap()
            }
        } else {
            // Original MQFQ: any eligible flow; lowest VT is the natural
            // (classic fair queueing) choice.
            cand.into_iter()
                .min_by(|&a, &b| {
                    self.flows[a]
                        .vt
                        .partial_cmp(&self.flows[b].vt)
                        .unwrap()
                        .then(a.cmp(&b))
                })
                .unwrap()
        };

        let tau = if self.cfg.vt_wall_time {
            self.flows[chosen].avg_exec_s()
        } else {
            1.0
        };
        let inv = self.flows[chosen].pop_dispatch(tau, now);
        // The dispatch may have pushed the flow over the throttle bound
        // or emptied it; refresh its state (and Global_VT) eagerly so
        // memory management reacts promptly (§4.3).
        self.recompute_global_vt();
        self.update_state(chosen, now);
        inv
    }

    fn on_complete(&mut self, func: FuncId, service: DurNanos, now: Nanos) {
        self.flows[func.0 as usize].complete(to_secs(service), now);
    }

    fn pending(&self) -> usize {
        self.flows.iter().map(|f| f.len()).sum()
    }

    fn drain_state_changes(&mut self) -> Vec<(FuncId, QState)> {
        std::mem::take(&mut self.changes)
    }

    fn queue_vt(&self, func: FuncId) -> Option<f64> {
        Some(self.flows[func.0 as usize].vt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::testutil::enqueue_n;
    use crate::types::{InvocationId, SEC};

    fn ctx<'a>(in_flight: &'a [usize], d: usize) -> PolicyCtx<'a> {
        PolicyCtx { in_flight, d }
    }

    fn mk(n: usize) -> MqfqSticky {
        MqfqSticky::new(n, MqfqConfig::default())
    }

    #[test]
    fn dispatches_fifo_within_flow() {
        let mut p = mk(1);
        enqueue_n(&mut p, 0, 3, 0, 1);
        let inf = [0usize];
        let a = p.dispatch(0, &ctx(&inf, 1)).unwrap();
        let b = p.dispatch(0, &ctx(&inf, 1)).unwrap();
        assert_eq!(a.id, InvocationId(1));
        assert_eq!(b.id, InvocationId(2));
        assert_eq!(p.pending(), 1);
    }

    #[test]
    fn sticky_prefers_longest_queue() {
        let mut p = mk(2);
        enqueue_n(&mut p, 0, 1, 0, 1);
        enqueue_n(&mut p, 1, 5, 0, 10);
        let inf = [0usize, 0];
        let got = p.dispatch(0, &ctx(&inf, 1)).unwrap();
        assert_eq!(got.func, FuncId(1), "longest queue should win");
    }

    #[test]
    fn least_in_flight_breaks_ties_at_d_gt_1() {
        let mut p = mk(2);
        enqueue_n(&mut p, 0, 3, 0, 1);
        enqueue_n(&mut p, 1, 3, 0, 10);
        // Flow 0 already has an in-flight invocation; at D=2 flow 1 wins
        // despite equal queue lengths.
        let inf = [1usize, 0];
        let got = p.dispatch(0, &ctx(&inf, 2)).unwrap();
        assert_eq!(got.func, FuncId(1));
    }

    #[test]
    fn throttling_caps_overrun() {
        let cfg = MqfqConfig {
            t: 2.0,
            ..Default::default()
        };
        let mut p = MqfqSticky::new(2, cfg);
        enqueue_n(&mut p, 0, 100, 0, 1);
        enqueue_n(&mut p, 1, 20, 0, 1000);
        // Flow 0's queue is 5× longer, so sticky dispatch prefers it —
        // but with T=2 and τ≈1s it may over-run flow 1's VT by at most 2
        // before throttling forces flow 1 through: both make progress.
        let inf = [0usize, 0];
        let mut f0 = 0;
        let mut f1 = 0;
        for i in 0..16 {
            let inv = p
                .dispatch(i * SEC, &ctx(&inf, 1))
                .expect("backlogged flows must keep dispatching");
            p.on_complete(inv.func, SEC, i * SEC + SEC / 2);
            match inv.func {
                FuncId(0) => f0 += 1,
                _ => f1 += 1,
            }
        }
        assert!(f1 >= 5, "short flow starved: f0={f0} f1={f1}");
        assert!(f0 >= 5, "long flow over-throttled: f0={f0} f1={f1}");
        // The over-run bound holds throughout.
        assert!(
            (p.queue_vt(FuncId(0)).unwrap() - p.queue_vt(FuncId(1)).unwrap()).abs()
                <= 2.0 + 1.0 + 1e-9,
            "VT gap exceeded T+τ"
        );
    }

    #[test]
    fn throttled_flow_resumes_after_global_vt_catches_up() {
        let cfg = MqfqConfig {
            t: 1.0,
            ..Default::default()
        };
        let mut p = MqfqSticky::new(2, cfg);
        enqueue_n(&mut p, 0, 10, 0, 1);
        enqueue_n(&mut p, 1, 10, 0, 100);
        let inf = [0usize, 0];
        // Alternate dispatch+completion; both flows should make steady
        // progress (fair round-robin-ish with τ defaults of 1s).
        let mut counts = [0usize; 2];
        for i in 0..10 {
            let inv = p.dispatch(i * SEC, &ctx(&inf, 1)).unwrap();
            counts[inv.func.0 as usize] += 1;
            p.on_complete(inv.func, SEC, i * SEC);
        }
        assert!(counts[0] >= 4 && counts[1] >= 4, "{counts:?}");
    }

    #[test]
    fn wall_time_vt_gives_short_functions_more_dispatches() {
        let mut p = mk(2);
        enqueue_n(&mut p, 0, 100, 0, 1); // will be slow: 4 s
        enqueue_n(&mut p, 1, 400, 0, 1000); // fast: 0.5 s
        let inf = [0usize, 0];
        // Teach the policy the service times.
        for _ in 0..2 {
            let inv = p.dispatch(0, &ctx(&inf, 1)).unwrap();
            let svc = if inv.func == FuncId(0) { 4 * SEC } else { SEC / 2 };
            p.on_complete(inv.func, svc, 0);
        }
        let mut counts = [0usize; 2];
        for i in 0..100 {
            let Some(inv) = p.dispatch(i * SEC, &ctx(&inf, 1)) else {
                break;
            };
            let svc = if inv.func == FuncId(0) { 4 * SEC } else { SEC / 2 };
            p.on_complete(inv.func, svc, i * SEC);
            counts[inv.func.0 as usize] += 1;
        }
        // Steady state: equal *service*, so dispatch counts scale with
        // 1/τ — the fast flow should see ~8× more invocations (the T=10
        // over-run transient dampens it below the ideal early on).
        assert!(
            counts[1] > 4 * counts[0],
            "fast flow should get far more dispatches: {counts:?}"
        );
    }

    #[test]
    fn ttl_expires_idle_queue_to_inactive() {
        let cfg = MqfqConfig {
            ttl_alpha: 2.0,
            ..Default::default()
        };
        let mut p = MqfqSticky::new(1, cfg);
        // Arrivals 1 s apart → IAT ≈ 1 s → TTL ≈ 2 s.
        p.enqueue(
            Invocation {
                id: InvocationId(1),
                func: FuncId(0),
                arrived: 0,
            },
            0,
        );
        p.enqueue(
            Invocation {
                id: InvocationId(2),
                func: FuncId(0),
                arrived: SEC,
            },
            SEC,
        );
        let inf = [0usize];
        p.dispatch(SEC, &ctx(&inf, 1)).unwrap();
        p.on_complete(FuncId(0), SEC / 2, SEC);
        p.dispatch(SEC, &ctx(&inf, 1)).unwrap();
        p.on_complete(FuncId(0), SEC / 2, 2 * SEC);
        // Within TTL: still Active (anticipatory).
        assert!(p.dispatch(3 * SEC, &ctx(&inf, 1)).is_none());
        assert_eq!(p.flow(FuncId(0)).state, QState::Active);
        // Past TTL: Inactive.
        assert!(p.dispatch(10 * SEC, &ctx(&inf, 1)).is_none());
        assert_eq!(p.flow(FuncId(0)).state, QState::Inactive);
        let changes = p.drain_state_changes();
        assert!(changes.contains(&(FuncId(0), QState::Inactive)));
    }

    #[test]
    fn reactivated_flow_catches_up_to_global_vt() {
        let cfg = MqfqConfig {
            fixed_ttl_s: Some(0.0), // expire immediately when idle
            ..Default::default()
        };
        let mut p = MqfqSticky::new(2, cfg);
        enqueue_n(&mut p, 0, 5, 0, 1);
        let inf = [0usize, 0];
        for i in 0..5 {
            let inv = p.dispatch(i * SEC, &ctx(&inf, 1)).unwrap();
            p.on_complete(inv.func, SEC, i * SEC);
        }
        assert!(p.queue_vt(FuncId(0)).unwrap() >= 5.0 - 1e-9);
        // Flow 1 arrives late; it must start at Global_VT, not 0 —
        // otherwise it would monopolize the GPU to "catch up".
        enqueue_n(&mut p, 1, 1, 6 * SEC, 50);
        assert!(p.queue_vt(FuncId(1)).unwrap() >= p.queue_vt(FuncId(0)).unwrap() - 1e-9);
    }

    #[test]
    fn non_sticky_picks_lowest_vt() {
        let cfg = MqfqConfig {
            sticky: false,
            ..Default::default()
        };
        let mut p = MqfqSticky::new(2, cfg);
        enqueue_n(&mut p, 0, 1, 0, 1);
        enqueue_n(&mut p, 1, 10, 0, 10);
        let inf = [0usize, 0];
        // Equal VTs tie-break by index: flow 0 wins even though flow 1
        // has the (much) longer queue — the sticky heuristic is off.
        let first = p.dispatch(0, &ctx(&inf, 1)).unwrap();
        assert_eq!(first.func, FuncId(0));
        // Flow 0's VT advanced; the lowest-VT pick is now flow 1.
        let second = p.dispatch(0, &ctx(&inf, 1)).unwrap();
        assert_eq!(second.func, FuncId(1));
    }

    #[test]
    fn state_changes_reported_once() {
        let mut p = mk(1);
        enqueue_n(&mut p, 0, 1, 0, 1);
        let changes = p.drain_state_changes();
        assert_eq!(changes, vec![(FuncId(0), QState::Active)]);
        assert!(p.drain_state_changes().is_empty());
    }

    #[test]
    fn dispatch_on_empty_returns_none() {
        let mut p = mk(3);
        let inf = [0usize, 0, 0];
        assert!(p.dispatch(0, &ctx(&inf, 2)).is_none());
    }
}
