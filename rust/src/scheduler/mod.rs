//! Queueing policies: the paper's MQFQ-Sticky plus every baseline the
//! evaluation compares against (FCFS, continuous batching, Paella-style
//! fair SJF, EEVDF) behind one [`Policy`] trait, and the
//! utilization-driven device concurrency controller (§4.4).

pub mod dtokens;
pub mod flowq;
pub mod index;
pub mod mqfq;
pub mod policies;

pub use dtokens::ConcurrencyController;
pub use flowq::{FlowQueue, QState};
pub use mqfq::{MqfqConfig, MqfqSticky};

use crate::types::{DurNanos, FuncId, InvocationId, Nanos};

/// One queued request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Invocation {
    pub id: InvocationId,
    pub func: FuncId,
    pub arrived: Nanos,
}

/// Read-only dispatch context handed to policies.
#[derive(Debug, Clone, Copy)]
pub struct PolicyCtx<'a> {
    /// In-flight invocations per function (indexed by FuncId).
    pub in_flight: &'a [usize],
    /// Current device-concurrency level D (total concurrent dispatches).
    pub d: usize,
}

/// A queueing policy: owns the pending invocations, decides dispatch
/// order, and reports queue-state transitions so the memory manager can
/// prefetch/evict (§4.3 — *all* evaluated policies get the memory
/// optimizations; only MQFQ-Sticky produces Throttled/Inactive signals).
pub trait Policy: Send {
    fn name(&self) -> &'static str;

    /// A new invocation arrived (open-loop).
    fn enqueue(&mut self, inv: Invocation, now: Nanos);

    /// Pick the next invocation to dispatch, or None to stay idle.
    /// Called whenever a D-token is available.
    fn dispatch(&mut self, now: Nanos, ctx: &PolicyCtx) -> Option<Invocation>;

    /// An invocation of `func` finished after `service` on device.
    fn on_complete(&mut self, func: FuncId, service: DurNanos, now: Nanos);

    /// Total queued (not yet dispatched) invocations. The sim engine and
    /// `plane.try_dispatch` consult this on every event, so every
    /// implementation keeps it O(1) (a counter, or a single queue's
    /// `len()`).
    fn pending(&self) -> usize;

    /// Queue-state transitions since the last call (drained).
    fn drain_state_changes(&mut self) -> Vec<(FuncId, QState)>;

    /// Current virtual time of a function's queue (metrics/debug; only
    /// fair-queueing policies report meaningful values).
    fn queue_vt(&self, _func: FuncId) -> Option<f64> {
        None
    }

    /// Current Global_VT (telemetry; only fair-queueing policies report
    /// meaningful values). Pure observation — callers must not derive
    /// scheduling decisions from it, so instrumented and bare runs stay
    /// behaviorally identical.
    fn global_vt(&self) -> Option<f64> {
        None
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// Feed `n` invocations of `func` at `t`, ids starting at `id0`.
    pub fn enqueue_n(p: &mut dyn Policy, func: u32, n: usize, t: Nanos, id0: u64) {
        for i in 0..n {
            p.enqueue(
                Invocation {
                    id: InvocationId(id0 + i as u64),
                    func: FuncId(func),
                    arrived: t,
                },
                t,
            );
        }
    }
}
