//! Queueing policies: the paper's MQFQ-Sticky plus every baseline the
//! evaluation compares against (FCFS, continuous batching, Paella-style
//! fair SJF, EEVDF) behind one [`Policy`] trait, and the
//! utilization-driven device concurrency controller (§4.4).

pub mod dtokens;
pub mod flowq;
pub mod index;
pub mod mqfq;
pub mod policies;

pub use dtokens::ConcurrencyController;
pub use flowq::{FlowQueue, QState};
pub use mqfq::{MqfqConfig, MqfqSticky};

use crate::types::{DurNanos, FuncId, InvocationId, Nanos, StartKind};

/// One queued request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Invocation {
    pub id: InvocationId,
    pub func: FuncId,
    pub arrived: Nanos,
}

/// Anticipatory-scheduling decisions a policy wants surfaced as
/// telemetry (trace events + counters). Drained by the control plane;
/// purely observational — consumers must not feed them back into
/// scheduling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AnticipationEvent {
    /// A flow went idle but stays Active inside its grace window
    /// (non-work-conserving hold of its sticky device).
    Grace {
        func: FuncId,
        /// Keep-alive window granted (nanos; TTL extended by grace).
        window: DurNanos,
        /// Predicted inter-arrival time the window was derived from.
        predicted_iat: DurNanos,
    },
    /// One dispatch decision coalesced several same-flow invocations
    /// into a single device submission.
    Batch {
        func: FuncId,
        /// Invocations in the batch (head + riders), >= 2.
        size: usize,
        /// Aggregate virtual-time advance charged for the batch.
        vt_advance: DurNanos,
    },
}

/// Read-only dispatch context handed to policies.
#[derive(Debug, Clone, Copy)]
pub struct PolicyCtx<'a> {
    /// In-flight invocations per function (indexed by FuncId).
    pub in_flight: &'a [usize],
    /// Current device-concurrency level D (total concurrent dispatches).
    pub d: usize,
}

/// A queueing policy: owns the pending invocations, decides dispatch
/// order, and reports queue-state transitions so the memory manager can
/// prefetch/evict (§4.3 — *all* evaluated policies get the memory
/// optimizations; only MQFQ-Sticky produces Throttled/Inactive signals).
pub trait Policy: Send {
    fn name(&self) -> &'static str;

    /// A new invocation arrived (open-loop).
    fn enqueue(&mut self, inv: Invocation, now: Nanos);

    /// Pick the next invocation to dispatch, or None to stay idle.
    /// Called whenever a D-token is available.
    fn dispatch(&mut self, now: Nanos, ctx: &PolicyCtx) -> Option<Invocation>;

    /// One dispatch *decision*, which may coalesce several same-flow
    /// invocations into one device submission (anticipatory batching).
    /// Appends the chosen invocations (head first) to `out` — a
    /// caller-owned reusable buffer so the steady state allocates
    /// nothing. Policies without batching inherit this single-dispatch
    /// default.
    fn dispatch_batch(&mut self, now: Nanos, ctx: &PolicyCtx, out: &mut Vec<Invocation>) {
        if let Some(inv) = self.dispatch(now, ctx) {
            out.push(inv);
        }
    }

    /// An invocation of `func` finished after `service` on device.
    fn on_complete(&mut self, func: FuncId, service: DurNanos, now: Nanos);

    /// Completion with provenance: how the invocation started (warm vs
    /// cold) and how long container boot took, so estimating policies
    /// can split their exec-time series by start kind. The control
    /// plane calls this; the default discards the extra context.
    fn on_complete_info(
        &mut self,
        func: FuncId,
        service: DurNanos,
        _start: Option<StartKind>,
        _boot: DurNanos,
        now: Nanos,
    ) {
        self.on_complete(func, service, now);
    }

    /// An in-flight attempt of `func` failed (device loss, transient
    /// exec fault, or straggler evacuation). The policy releases the
    /// attempt's in-flight accounting *without* learning an exec
    /// sample; when `requeue` is true the invocation re-enters the
    /// queue — fair-queueing policies put it at the head of its flow,
    /// and the attempt's virtual-time advance stands (no double
    /// F-advance on retry: the retry dispatch charges its own τ).
    /// Baselines inherit this default: a plain re-enqueue.
    fn on_fault(&mut self, inv: Invocation, now: Nanos, requeue: bool) {
        if requeue {
            self.enqueue(inv, now);
        }
    }

    /// Anticipatory decisions (grace holds, batch coalescing) since the
    /// last call, for telemetry. Default: none.
    fn drain_anticipation(&mut self) -> Vec<AnticipationEvent> {
        Vec::new()
    }

    /// The online exec-time estimate for `func`, seconds — Some only
    /// when the policy runs an estimator (telemetry compares it against
    /// the actual service time at completion).
    fn estimated_exec_s(&self, _func: FuncId) -> Option<f64> {
        None
    }

    /// Total queued (not yet dispatched) invocations. The sim engine and
    /// `plane.try_dispatch` consult this on every event, so every
    /// implementation keeps it O(1) (a counter, or a single queue's
    /// `len()`).
    fn pending(&self) -> usize;

    /// Queue-state transitions since the last call (drained).
    fn drain_state_changes(&mut self) -> Vec<(FuncId, QState)>;

    /// Current virtual time of a function's queue (metrics/debug; only
    /// fair-queueing policies report meaningful values).
    fn queue_vt(&self, _func: FuncId) -> Option<f64> {
        None
    }

    /// Current Global_VT (telemetry; only fair-queueing policies report
    /// meaningful values). Pure observation — callers must not derive
    /// scheduling decisions from it, so instrumented and bare runs stay
    /// behaviorally identical.
    fn global_vt(&self) -> Option<f64> {
        None
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// Feed `n` invocations of `func` at `t`, ids starting at `id0`.
    pub fn enqueue_n(p: &mut dyn Policy, func: u32, n: usize, t: Nanos, id0: u64) {
        for i in 0..n {
            p.enqueue(
                Invocation {
                    id: InvocationId(id0 + i as u64),
                    func: FuncId(func),
                    arrived: t,
                },
                t,
            );
        }
    }
}
