//! Per-function dispatch queue ("flow") with virtual-time accounting —
//! the building block of MQFQ-Sticky (§4.1, Table 2).

use std::collections::VecDeque;

use crate::types::{to_secs, FuncId, Nanos};
use crate::util::stats::Ema;

use super::Invocation;

/// Queue state (§4.1/Algorithm 1): Active queues hold or anticipate
/// invocations; Throttled queues exceeded the over-run bound T;
/// Inactive queues expired their keep-alive TTL.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QState {
    Active,
    Throttled,
    Inactive,
}

/// One function's flow queue.
#[derive(Debug, Clone)]
pub struct FlowQueue {
    pub func: FuncId,
    pub queue: VecDeque<Invocation>,
    /// Virtual time: total service accrued by this queue, in seconds of
    /// GPU service (Table 2 "VT").
    pub vt: f64,
    pub state: QState,
    /// Invocations dispatched but not yet completed.
    pub in_flight: usize,
    /// Last dispatch or completion (drives the anticipatory TTL).
    pub last_exec: Nanos,
    /// Historical average execution time τ_f (EMA, seconds).
    avg_exec: Ema,
    /// Historical mean inter-arrival time (EMA, seconds).
    iat: Ema,
    last_arrival: Option<Nanos>,
    /// Total invocations ever enqueued (metrics).
    pub total_arrivals: u64,
}

impl FlowQueue {
    pub fn new(func: FuncId) -> Self {
        Self {
            func,
            queue: VecDeque::new(),
            vt: 0.0,
            state: QState::Inactive,
            in_flight: 0,
            last_exec: 0,
            avg_exec: Ema::new(0.3),
            iat: Ema::new(0.3),
            last_arrival: None,
            total_arrivals: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// τ_f: the service-time estimate used to advance VT on dispatch.
    /// Defaults to 1 s until the first completion is observed (the
    /// scheduler is black-box; it has no prior on a new function).
    pub fn avg_exec_s(&self) -> f64 {
        let v = self.avg_exec.get();
        if v > 0.0 {
            v
        } else {
            1.0
        }
    }

    /// Mean inter-arrival time estimate (seconds); defaults to 1 s.
    pub fn mean_iat_s(&self) -> f64 {
        let v = self.iat.get();
        if v > 0.0 {
            v
        } else {
            1.0
        }
    }

    /// Record an arrival (updates the IAT estimate and enqueues).
    pub fn push(&mut self, inv: Invocation, now: Nanos) {
        if let Some(prev) = self.last_arrival {
            if now > prev {
                self.iat.push(to_secs(now - prev));
            }
        }
        self.last_arrival = Some(now);
        self.total_arrivals += 1;
        self.queue.push_back(inv);
    }

    /// Pop the head for dispatch; advances VT by `tau` (the caller picks
    /// wall-time τ_f or 1.0 per the Fig-8a ablation) and tracks in-flight.
    pub fn pop_dispatch(&mut self, tau: f64, now: Nanos) -> Option<Invocation> {
        let inv = self.queue.pop_front()?;
        self.vt += tau;
        self.in_flight += 1;
        self.last_exec = now;
        Some(inv)
    }

    /// Record a completion with its observed service time.
    pub fn complete(&mut self, service_s: f64, now: Nanos) {
        self.in_flight = self.in_flight.saturating_sub(1);
        self.avg_exec.push(service_s);
        self.last_exec = now;
    }

    /// Record a failed/evacuated attempt: the in-flight slot is
    /// released but — unlike [`FlowQueue::complete`] — no exec sample
    /// is learned (a crashed or hung run says nothing about τ_f) and
    /// the VT advance charged at dispatch stands.
    pub fn fault(&mut self, now: Nanos) {
        self.in_flight = self.in_flight.saturating_sub(1);
        self.last_exec = now;
    }

    /// Re-queue a faulted invocation at the *head* of the flow (it
    /// already waited its turn; retries preempt newer arrivals of the
    /// same flow). No arrival bookkeeping: the invocation arrived
    /// once.
    pub fn requeue_front(&mut self, inv: Invocation) {
        self.queue.push_front(inv);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{InvocationId, SEC};

    fn inv(id: u64, t: Nanos) -> Invocation {
        Invocation {
            id: InvocationId(id),
            func: FuncId(0),
            arrived: t,
        }
    }

    #[test]
    fn push_tracks_iat() {
        let mut q = FlowQueue::new(FuncId(0));
        assert_eq!(q.mean_iat_s(), 1.0); // default
        q.push(inv(1, 0), 0);
        q.push(inv(2, 2 * SEC), 2 * SEC);
        q.push(inv(3, 4 * SEC), 4 * SEC);
        assert!((q.mean_iat_s() - 2.0).abs() < 1e-9);
        assert_eq!(q.len(), 3);
        assert_eq!(q.total_arrivals, 3);
    }

    #[test]
    fn dispatch_advances_vt_and_inflight() {
        let mut q = FlowQueue::new(FuncId(0));
        q.push(inv(1, 0), 0);
        q.push(inv(2, 0), 0);
        let got = q.pop_dispatch(2.5, SEC).unwrap();
        assert_eq!(got.id, InvocationId(1));
        assert_eq!(q.vt, 2.5);
        assert_eq!(q.in_flight, 1);
        assert_eq!(q.last_exec, SEC);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn complete_updates_avg_exec() {
        let mut q = FlowQueue::new(FuncId(0));
        assert_eq!(q.avg_exec_s(), 1.0); // black-box default
        q.push(inv(1, 0), 0);
        q.pop_dispatch(1.0, 0);
        q.complete(3.0, SEC);
        assert_eq!(q.in_flight, 0);
        assert!((q.avg_exec_s() - 3.0).abs() < 1e-9);
        q.complete(1.0, 2 * SEC); // EMA moves toward 1.0
        assert!(q.avg_exec_s() < 3.0 && q.avg_exec_s() > 1.0);
    }

    #[test]
    fn fault_releases_slot_without_learning() {
        let mut q = FlowQueue::new(FuncId(0));
        q.push(inv(1, 0), 0);
        q.push(inv(2, 0), 0);
        let head = q.pop_dispatch(1.5, SEC).unwrap();
        q.fault(2 * SEC);
        assert_eq!(q.in_flight, 0);
        assert_eq!(q.last_exec, 2 * SEC);
        assert_eq!(q.avg_exec_s(), 1.0, "no exec sample from a fault");
        assert_eq!(q.vt, 1.5, "the dispatch's VT advance stands");
        // Retry goes to the head, ahead of inv 2, with no IAT update.
        let arrivals = q.total_arrivals;
        q.requeue_front(head);
        assert_eq!(q.total_arrivals, arrivals);
        assert_eq!(q.pop_dispatch(1.0, 3 * SEC).unwrap().id, InvocationId(1));
        assert_eq!(q.pop_dispatch(1.0, 3 * SEC).unwrap().id, InvocationId(2));
    }

    #[test]
    fn pop_empty_is_none() {
        let mut q = FlowQueue::new(FuncId(0));
        assert!(q.pop_dispatch(1.0, 0).is_none());
        assert_eq!(q.vt, 0.0);
    }
}
