//! Device concurrency control (§4.4): the D parameter, either fixed or
//! adjusted dynamically from utilization feedback.
//!
//! "We take two input parameters: the device utilization threshold (such
//! as 90%), and the maximum parallelism level. A thread monitors
//! real-time utilization and changes the D level dynamically to ensure
//! the utilization is under the threshold."

/// The D controller: exposes the current per-server concurrency limit.
#[derive(Debug, Clone)]
pub struct ConcurrencyController {
    /// Hard upper bound on D (paper: "max GPU concurrency", QoS class).
    pub max_d: usize,
    /// Utilization threshold (paper example: 0.9).
    pub util_threshold: f64,
    /// Fixed-D mode when false (most experiments sweep fixed D).
    pub dynamic: bool,
    cur_d: usize,
    /// Consecutive samples over/under threshold (hysteresis).
    over: u32,
    under: u32,
}

impl ConcurrencyController {
    /// Fixed D (the Fig-6a sweeps).
    pub fn fixed(d: usize) -> Self {
        assert!(d >= 1);
        Self {
            max_d: d,
            util_threshold: 0.9,
            dynamic: false,
            cur_d: d,
            over: 0,
            under: 0,
        }
    }

    /// Utilization-driven dynamic D in [1, max_d].
    pub fn dynamic(max_d: usize, util_threshold: f64) -> Self {
        assert!(max_d >= 1);
        Self {
            max_d,
            util_threshold,
            dynamic: true,
            cur_d: 1.max(max_d / 2),
            over: 0,
            under: 0,
        }
    }

    /// Current D level.
    pub fn limit(&self) -> usize {
        self.cur_d
    }

    /// Feed one utilization sample (monitor tick, 200 ms cadence).
    /// Raising D requires sustained headroom; lowering reacts faster
    /// (interference hurts more than queueing, §6.2).
    pub fn on_sample(&mut self, util: f64) {
        if !self.dynamic {
            return;
        }
        if util > self.util_threshold {
            self.over += 1;
            self.under = 0;
            if self.over >= 2 && self.cur_d > 1 {
                self.cur_d -= 1;
                self.over = 0;
            }
        } else if util < self.util_threshold * 0.75 {
            self.under += 1;
            self.over = 0;
            if self.under >= 5 && self.cur_d < self.max_d {
                self.cur_d += 1;
                self.under = 0;
            }
        } else {
            self.over = 0;
            self.under = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_never_moves() {
        let mut c = ConcurrencyController::fixed(2);
        for _ in 0..100 {
            c.on_sample(1.0);
        }
        assert_eq!(c.limit(), 2);
    }

    #[test]
    fn dynamic_backs_off_under_saturation() {
        let mut c = ConcurrencyController::dynamic(4, 0.9);
        let d0 = c.limit();
        for _ in 0..4 {
            c.on_sample(0.99);
        }
        assert!(c.limit() < d0, "D should drop: {} -> {}", d0, c.limit());
        // Never below 1.
        for _ in 0..100 {
            c.on_sample(1.0);
        }
        assert_eq!(c.limit(), 1);
    }

    #[test]
    fn dynamic_grows_with_headroom() {
        let mut c = ConcurrencyController::dynamic(4, 0.9);
        for _ in 0..100 {
            c.on_sample(0.2);
        }
        assert_eq!(c.limit(), 4);
    }

    #[test]
    fn dynamic_holds_in_band() {
        let mut c = ConcurrencyController::dynamic(4, 0.9);
        let d0 = c.limit();
        for _ in 0..100 {
            c.on_sample(0.8); // between 0.675 and 0.9: hold
        }
        assert_eq!(c.limit(), d0);
    }
}
