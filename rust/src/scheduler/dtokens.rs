//! Device concurrency control (§4.4): the D parameter — fixed, adjusted
//! dynamically from utilization feedback, or adaptively from a
//! Little's-law completion tracker.
//!
//! "We take two input parameters: the device utilization threshold (such
//! as 90%), and the maximum parallelism level. A thread monitors
//! real-time utilization and changes the D level dynamically to ensure
//! the utilization is under the threshold."
//!
//! The Little's-law mode closes the Ilúvatar exemplar's
//! "TODO: Little's law" loop: each monitor tick drains the per-device
//! completion windows into a concurrency-demand estimate
//! L = λ·W (see `gpu::Device::littles_demand`) and steps D one level
//! toward `clamp(ceil(L), min_d, max_d)` — one step per tick, so a
//! noisy window cannot slam the concurrency level.

/// The D controller: exposes the current per-server concurrency limit.
#[derive(Debug, Clone)]
pub struct ConcurrencyController {
    /// Hard upper bound on D (paper: "max GPU concurrency", QoS class).
    pub max_d: usize,
    /// Lower bound on D in Little's-law adaptive mode.
    pub min_d: usize,
    /// Utilization threshold (paper example: 0.9).
    pub util_threshold: f64,
    /// Fixed-D mode when false (most experiments sweep fixed D).
    pub dynamic: bool,
    /// Little's-law adaptive mode: D follows the completion-tracker
    /// demand estimate instead of utilization hysteresis.
    pub littles: bool,
    cur_d: usize,
    /// Consecutive samples over/under threshold (hysteresis).
    over: u32,
    under: u32,
}

impl ConcurrencyController {
    /// Fixed D (the Fig-6a sweeps).
    pub fn fixed(d: usize) -> Self {
        assert!(d >= 1);
        Self {
            max_d: d,
            min_d: d,
            util_threshold: 0.9,
            dynamic: false,
            littles: false,
            cur_d: d,
            over: 0,
            under: 0,
        }
    }

    /// Utilization-driven dynamic D in [1, max_d].
    pub fn dynamic(max_d: usize, util_threshold: f64) -> Self {
        assert!(max_d >= 1);
        Self {
            max_d,
            min_d: 1,
            util_threshold,
            dynamic: true,
            littles: false,
            cur_d: 1.max(max_d / 2),
            over: 0,
            under: 0,
        }
    }

    /// Little's-law adaptive D in [min_d, max_d], starting at min_d
    /// (concurrency is granted on demonstrated demand, not assumed).
    pub fn littles(min_d: usize, max_d: usize) -> Self {
        assert!(min_d >= 1 && min_d <= max_d);
        Self {
            max_d,
            min_d,
            util_threshold: 0.9,
            dynamic: false,
            littles: true,
            cur_d: min_d,
            over: 0,
            under: 0,
        }
    }

    /// Current D level.
    pub fn limit(&self) -> usize {
        self.cur_d
    }

    /// Feed one Little's-law demand estimate (monitor tick; `None` when
    /// the window saw no completions ⇒ hold). Steps D one level toward
    /// `clamp(ceil(demand), min_d, max_d)`; returns the old D when the
    /// level changed (for telemetry).
    pub fn on_littles_estimate(&mut self, demand: Option<f64>) -> Option<usize> {
        if !self.littles {
            return None;
        }
        let demand = demand?;
        let target = (demand.ceil().max(0.0) as usize).clamp(self.min_d, self.max_d);
        let old = self.cur_d;
        if target > self.cur_d {
            self.cur_d += 1;
        } else if target < self.cur_d {
            self.cur_d -= 1;
        }
        (self.cur_d != old).then_some(old)
    }

    /// Feed one utilization sample (monitor tick, 200 ms cadence).
    /// Raising D requires sustained headroom; lowering reacts faster
    /// (interference hurts more than queueing, §6.2).
    pub fn on_sample(&mut self, util: f64) {
        if !self.dynamic {
            return;
        }
        if util > self.util_threshold {
            self.over += 1;
            self.under = 0;
            if self.over >= 2 && self.cur_d > 1 {
                self.cur_d -= 1;
                self.over = 0;
            }
        } else if util < self.util_threshold * 0.75 {
            self.under += 1;
            self.over = 0;
            if self.under >= 5 && self.cur_d < self.max_d {
                self.cur_d += 1;
                self.under = 0;
            }
        } else {
            self.over = 0;
            self.under = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_never_moves() {
        let mut c = ConcurrencyController::fixed(2);
        for _ in 0..100 {
            c.on_sample(1.0);
        }
        assert_eq!(c.limit(), 2);
    }

    #[test]
    fn dynamic_backs_off_under_saturation() {
        let mut c = ConcurrencyController::dynamic(4, 0.9);
        let d0 = c.limit();
        for _ in 0..4 {
            c.on_sample(0.99);
        }
        assert!(c.limit() < d0, "D should drop: {} -> {}", d0, c.limit());
        // Never below 1.
        for _ in 0..100 {
            c.on_sample(1.0);
        }
        assert_eq!(c.limit(), 1);
    }

    #[test]
    fn dynamic_grows_with_headroom() {
        let mut c = ConcurrencyController::dynamic(4, 0.9);
        for _ in 0..100 {
            c.on_sample(0.2);
        }
        assert_eq!(c.limit(), 4);
    }

    #[test]
    fn dynamic_holds_in_band() {
        let mut c = ConcurrencyController::dynamic(4, 0.9);
        let d0 = c.limit();
        for _ in 0..100 {
            c.on_sample(0.8); // between 0.675 and 0.9: hold
        }
        assert_eq!(c.limit(), d0);
    }

    #[test]
    fn littles_steps_toward_demand_within_bounds() {
        let mut c = ConcurrencyController::littles(1, 4);
        assert_eq!(c.limit(), 1);
        // Demand 3.2 → target 4, one step per tick.
        assert_eq!(c.on_littles_estimate(Some(3.2)), Some(1));
        assert_eq!(c.limit(), 2);
        assert_eq!(c.on_littles_estimate(Some(3.2)), Some(2));
        assert_eq!(c.on_littles_estimate(Some(3.2)), Some(3));
        assert_eq!(c.limit(), 4);
        // Clamped at max_d even under huge demand.
        assert_eq!(c.on_littles_estimate(Some(50.0)), None);
        assert_eq!(c.limit(), 4);
        // Empty window holds; low demand steps back down to min_d.
        assert_eq!(c.on_littles_estimate(None), None);
        assert_eq!(c.limit(), 4);
        for _ in 0..10 {
            c.on_littles_estimate(Some(0.1));
        }
        assert_eq!(c.limit(), 1);
        // Utilization samples are ignored in Little's mode.
        for _ in 0..10 {
            c.on_sample(1.0);
        }
        assert_eq!(c.limit(), 1);
    }

    #[test]
    fn non_littles_controllers_ignore_estimates() {
        let mut c = ConcurrencyController::fixed(2);
        assert_eq!(c.on_littles_estimate(Some(10.0)), None);
        assert_eq!(c.limit(), 2);
    }
}
