//! Online per-function characteristics estimation (anticipatory
//! scheduling, §4.5 of the reproduction roadmap).
//!
//! The paper's scheduler is *anticipatory*: instead of treating exec
//! times, arrival rates, and cold-start costs as static workload
//! parameters, it learns them online from completion events and lets
//! three scheduler behaviors consume the predictions:
//!
//! 1. **Grace periods** — a flow whose queue just emptied stays Active
//!    (non-work-conserving) for `grace_alpha x predicted_iat`, holding
//!    its sticky device for the anticipated next arrival.
//! 2. **Batch dispatch** — up to `batch_max` queued invocations of one
//!    flow coalesce into a single device submission; riders cost
//!    `batch_marginal x predicted_exec` each (kernels and weights are
//!    already resident).
//! 3. **Estimated-then-corrected virtual time** — when `estimator` is
//!    on, a dispatch advances VT by the *predicted* service time and
//!    the prediction error is settled later as a per-flow debt (the
//!    Iluvatar `budget` idea, re-cast so Global_VT stays monotone: VT
//!    is never lowered retroactively; instead the signed error is
//!    carried forward into the next dispatch's tau).
//!
//! [`CharacteristicsMap`] is the shared state machine. Both the
//! indexed `MqfqSticky` and the `NaiveMqfq` oracle embed one and feed
//! it the same event stream, so the equivalence property holds by
//! construction rather than by duplicated arithmetic.

use std::collections::VecDeque;

use crate::types::{DurNanos, FuncId, StartKind};
use crate::util::stats::Ema;

/// EWMA smoothing for all estimator series. Matches the flow-queue
/// EMAs so predictions and the legacy `avg_exec_s` path converge on
/// the same steady state.
const EST_ALPHA: f64 = 0.3;

/// Knobs for the anticipatory scheduling subsystem. The defaults are
/// all-neutral: with `grace_alpha = 0`, `batch_max = 1`, and
/// `estimator = false`, the scheduler is bit-identical to the
/// pre-anticipation dispatch core (property-tested in
/// `tests/prop_anticipate.rs`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AnticipateConfig {
    /// Grace window multiplier over the predicted inter-arrival time.
    /// 0.0 disables grace periods (keep-alive degenerates to the TTL).
    pub grace_alpha: f64,
    /// Max same-flow invocations coalesced per dispatch decision.
    /// 1 disables batching.
    pub batch_max: usize,
    /// Marginal service-cost fraction for each batched rider relative
    /// to the head invocation (model: weights/kernels already
    /// resident, so riders skip setup).
    pub batch_marginal: f64,
    /// Drive virtual-time advances from the online exec-time estimate
    /// (with debt correction) instead of the flow's trailing average.
    pub estimator: bool,
}

impl Default for AnticipateConfig {
    fn default() -> Self {
        Self {
            grace_alpha: 0.0,
            batch_max: 1,
            batch_marginal: 0.6,
            estimator: false,
        }
    }
}

impl AnticipateConfig {
    /// True when any anticipatory behavior is switched on.
    pub fn enabled(&self) -> bool {
        self.grace_alpha > 0.0 || self.batch_max > 1 || self.estimator
    }
}

/// Online estimates for one function, fed by arrival and completion
/// events.
#[derive(Debug, Clone)]
pub struct FuncEstimate {
    /// EWMA exec time of warm starts (GPU-warm or host-warm), seconds.
    warm_exec: Ema,
    /// EWMA exec time of cold starts, seconds.
    cold_exec: Ema,
    /// EWMA extra cost a cold start pays over the warm estimate,
    /// seconds (boot + init; >= 0).
    cold_cost: Ema,
    /// EWMA inter-arrival time, seconds.
    iat: Ema,
    /// EWMA of in-flight count observed at dispatch instants.
    concurrency: Ema,
    /// Last arrival timestamp (nanos) for IAT deltas.
    last_arrival: Option<u64>,
    /// Estimated service charged at dispatch, awaiting correction at
    /// completion (FIFO approximation of dispatch->completion pairing).
    outstanding: VecDeque<f64>,
    /// Signed accumulated prediction error (actual - estimated),
    /// seconds, carried forward into the next dispatch's tau.
    vt_debt: f64,
    arrivals: u64,
    warm_completions: u64,
    cold_completions: u64,
}

impl FuncEstimate {
    fn new() -> Self {
        Self {
            warm_exec: Ema::new(EST_ALPHA),
            cold_exec: Ema::new(EST_ALPHA),
            cold_cost: Ema::new(EST_ALPHA),
            iat: Ema::new(EST_ALPHA),
            concurrency: Ema::new(EST_ALPHA),
            last_arrival: None,
            outstanding: VecDeque::new(),
            vt_debt: 0.0,
            arrivals: 0,
            warm_completions: 0,
            cold_completions: 0,
        }
    }

    fn completions(&self) -> u64 {
        self.warm_completions + self.cold_completions
    }
}

/// Per-function online characteristics, keyed densely by `FuncId`.
///
/// Determinism: every update is a fixed sequence of f64 ops on the
/// event stream, so replaying the same trace reproduces the same
/// estimates bit-for-bit (property-tested).
#[derive(Debug, Clone, Default)]
pub struct CharacteristicsMap {
    funcs: Vec<FuncEstimate>,
}

impl CharacteristicsMap {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, func: FuncId) -> &mut FuncEstimate {
        let idx = func.0 as usize;
        while self.funcs.len() <= idx {
            self.funcs.push(FuncEstimate::new());
        }
        &mut self.funcs[idx]
    }

    fn get(&self, func: FuncId) -> Option<&FuncEstimate> {
        self.funcs.get(func.0 as usize)
    }

    /// Feed an arrival: updates the IAT estimate. Same-instant arrivals
    /// (a burst) contribute no gap sample, matching the flow-queue IAT
    /// semantics.
    pub fn on_arrival(&mut self, func: FuncId, now: u64) {
        let e = self.ensure(func);
        if let Some(prev) = e.last_arrival {
            if now > prev {
                e.iat.push(now.saturating_sub(prev) as f64 / 1e9);
            }
        }
        e.last_arrival = Some(now);
        e.arrivals += 1;
    }

    /// Feed a dispatch: records the estimate charged to virtual time
    /// (for later debt correction) and the observed concurrency.
    pub fn on_dispatch(&mut self, func: FuncId, charged_est_s: f64, in_flight: usize) {
        let e = self.ensure(func);
        e.outstanding.push_back(charged_est_s);
        e.concurrency.push(in_flight as f64);
    }

    /// Feed a completion: updates the warm/cold exec-time split, the
    /// cold-start cost, and settles the oldest outstanding dispatch
    /// estimate into the debt accumulator.
    pub fn on_complete(&mut self, func: FuncId, service: DurNanos, start: StartKind, boot: DurNanos) {
        let service_s = service as f64 / 1e9;
        let e = self.ensure(func);
        match start {
            StartKind::Cold => {
                e.cold_exec.push(service_s);
                e.cold_completions += 1;
                let warm = if e.warm_completions > 0 {
                    e.warm_exec.get()
                } else {
                    service_s
                };
                let extra = (service_s - warm).max(0.0) + boot as f64 / 1e9;
                e.cold_cost.push(extra);
            }
            StartKind::GpuWarm | StartKind::HostWarm => {
                e.warm_exec.push(service_s);
                e.warm_completions += 1;
            }
        }
        if let Some(est) = e.outstanding.pop_front() {
            e.vt_debt += service_s - est;
        }
    }

    /// Predicted execution time (seconds): warm estimate when one
    /// exists, else the cold estimate, else None (never observed).
    pub fn predicted_exec_s(&self, func: FuncId) -> Option<f64> {
        let e = self.get(func)?;
        if e.warm_completions > 0 {
            Some(e.warm_exec.get())
        } else if e.cold_completions > 0 {
            Some(e.cold_exec.get())
        } else {
            None
        }
    }

    /// Predicted inter-arrival time (seconds); None before two
    /// arrivals have been seen.
    pub fn predicted_iat_s(&self, func: FuncId) -> Option<f64> {
        let e = self.get(func)?;
        if e.arrivals >= 2 {
            Some(e.iat.get())
        } else {
            None
        }
    }

    /// Predicted extra cost of a cold start (seconds), if observed.
    pub fn cold_cost_s(&self, func: FuncId) -> Option<f64> {
        let e = self.get(func)?;
        if e.cold_completions > 0 {
            Some(e.cold_cost.get())
        } else {
            None
        }
    }

    /// Observed mean concurrency at dispatch instants.
    pub fn observed_concurrency(&self, func: FuncId) -> f64 {
        self.get(func).map(|e| e.concurrency.get()).unwrap_or(0.0)
    }

    /// Completions observed for `func` (both start kinds).
    pub fn completions(&self, func: FuncId) -> u64 {
        self.get(func).map(|e| e.completions()).unwrap_or(0)
    }

    /// Virtual-time charge (seconds) for the next dispatch of `func`:
    /// the predicted exec time plus accumulated correction debt,
    /// clamped at zero with any negative remainder carried forward so
    /// VT never runs backwards (Global_VT stays monotone for the
    /// indexed scheduler's lazy min-heap).
    ///
    /// `fallback` is charged (and recorded as the outstanding
    /// estimate) before the first completion is observed — callers
    /// pass the flow's trailing `avg_exec_s`, so the estimator path
    /// starts where the legacy path would.
    pub fn take_tau(&mut self, func: FuncId, fallback: f64) -> f64 {
        let est = self.predicted_exec_s(func).unwrap_or(fallback);
        let e = self.ensure(func);
        let raw = est + e.vt_debt;
        if raw >= 0.0 {
            e.vt_debt = 0.0;
            raw
        } else {
            e.vt_debt = raw;
            0.0
        }
    }

    /// Feed a failed/evacuated attempt: the dispatch never completes,
    /// so its outstanding charged estimate is retired *without* a debt
    /// update — the attempt's VT advance stands (the faulty tenant
    /// paid for the service it consumed) and no exec sample is learned
    /// from a crashed or hung run.
    pub fn on_fault(&mut self, func: FuncId) {
        let e = self.ensure(func);
        e.outstanding.pop_front();
    }

    /// Estimate (without debt) for telemetry / marginal-cost modeling.
    pub fn estimate_or(&self, func: FuncId, fallback: f64) -> f64 {
        self.predicted_exec_s(func).unwrap_or(fallback)
    }

    /// Current signed debt for a function (test/introspection).
    pub fn debt_s(&self, func: FuncId) -> f64 {
        self.get(func).map(|e| e.vt_debt).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SEC;

    const F: FuncId = FuncId(3);

    #[test]
    fn iat_needs_two_arrivals() {
        let mut m = CharacteristicsMap::new();
        assert_eq!(m.predicted_iat_s(F), None);
        m.on_arrival(F, 0);
        assert_eq!(m.predicted_iat_s(F), None);
        m.on_arrival(F, 2 * SEC);
        assert!((m.predicted_iat_s(F).unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn warm_cold_split() {
        let mut m = CharacteristicsMap::new();
        m.on_complete(F, 10 * SEC, StartKind::Cold, SEC);
        // Only cold observed: prediction falls back to the cold series.
        assert!((m.predicted_exec_s(F).unwrap() - 10.0).abs() < 1e-9);
        m.on_complete(F, 2 * SEC, StartKind::GpuWarm, 0);
        // Warm observation takes over.
        assert!((m.predicted_exec_s(F).unwrap() - 2.0).abs() < 1e-9);
        // Cold cost: first cold saw no warm baseline, so extra = boot.
        assert!((m.cold_cost_s(F).unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn debt_carries_forward_and_clamps() {
        let mut m = CharacteristicsMap::new();
        // Seed the warm estimate at 1.0s.
        m.on_complete(F, SEC, StartKind::GpuWarm, 0);
        // Dispatch charged at the estimate; actual runs 3.0s.
        let tau = m.take_tau(F, 99.0);
        assert!((tau - 1.0).abs() < 1e-9);
        m.on_dispatch(F, tau, 1);
        m.on_complete(F, 3 * SEC, StartKind::GpuWarm, 0);
        // Debt = +2.0 (under-charged); next tau repays it on top of
        // the refreshed estimate (ewma 1.0 -> 1.6).
        let est = m.predicted_exec_s(F).unwrap();
        let tau2 = m.take_tau(F, 99.0);
        assert!((tau2 - (est + 2.0)).abs() < 1e-9);
        assert!((m.debt_s(F)).abs() < 1e-12);

        // Over-charge massively, then verify the negative remainder is
        // clamped at zero and carried, never rewinding VT.
        m.on_dispatch(F, 50.0, 1);
        m.on_complete(F, SEC, StartKind::GpuWarm, 0);
        let tau3 = m.take_tau(F, 99.0);
        assert_eq!(tau3, 0.0);
        assert!(m.debt_s(F) < 0.0);
    }

    #[test]
    fn fault_retires_outstanding_without_debt() {
        let mut m = CharacteristicsMap::new();
        m.on_complete(F, SEC, StartKind::GpuWarm, 0);
        let tau = m.take_tau(F, 99.0);
        m.on_dispatch(F, tau, 1);
        m.on_fault(F);
        // The charged estimate is retired with no debt: the faulted
        // attempt's VT advance stands.
        assert_eq!(m.debt_s(F), 0.0);
        // The next completion settles against its own dispatch, not a
        // stale entry from the faulted attempt.
        let tau2 = m.take_tau(F, 99.0);
        m.on_dispatch(F, tau2, 1);
        m.on_complete(F, SEC, StartKind::GpuWarm, 0);
        assert!((m.debt_s(F) - (1.0 - tau2)).abs() < 1e-9);
    }

    #[test]
    fn fallback_used_before_observation() {
        let mut m = CharacteristicsMap::new();
        assert!((m.take_tau(F, 7.5) - 7.5).abs() < 1e-9);
        assert!((m.estimate_or(F, 1.25) - 1.25).abs() < 1e-9);
    }

    #[test]
    fn deterministic_under_replay() {
        let feed = |m: &mut CharacteristicsMap| {
            for i in 0..50u64 {
                m.on_arrival(F, i * SEC / 3);
                let tau = m.take_tau(F, 1.0);
                m.on_dispatch(F, tau, (i % 4) as usize);
                let kind = if i % 5 == 0 {
                    StartKind::Cold
                } else {
                    StartKind::GpuWarm
                };
                m.on_complete(F, (i % 7 + 1) * SEC / 2, kind, SEC / 10);
            }
        };
        let mut a = CharacteristicsMap::new();
        let mut b = CharacteristicsMap::new();
        feed(&mut a);
        feed(&mut b);
        assert_eq!(a.predicted_exec_s(F), b.predicted_exec_s(F));
        assert_eq!(a.predicted_iat_s(F), b.predicted_iat_s(F));
        assert_eq!(a.debt_s(F).to_bits(), b.debt_s(F).to_bits());
        assert_eq!(a.observed_concurrency(F), b.observed_concurrency(F));
    }
}
