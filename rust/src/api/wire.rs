//! Protocol v1 framing: one JSON document per line, both directions,
//! with the legacy word protocol (`invoke <fn>` / `stats` / `quit`)
//! kept as aliases on the server side.
//!
//! ```text
//! > {"cmd":"hello","v":1}
//! < {"ok":true,"type":"hello","proto":1,"server":"rt-cluster"}
//! > {"cmd":"invoke","func":"fft-0","mode":"sync","deadline_ms":5000}
//! < {"ok":true,"type":"done","ticket":0,"func":"fft-0","shard":2,
//!    "gpu":0,"start":"cold","latency_ms":412.0,"exec_ms":9.1}
//! > {"cmd":"invoke","func":"fft-0","mode":"async"}
//! < {"ok":true,"type":"ticket","ticket":1}
//! > {"cmd":"poll","ticket":1}
//! < {"ok":true,"type":"pending","ticket":1}
//! > {"cmd":"wait","ticket":1}
//! < {"ok":true,"type":"done", ...}
//! > {"cmd":"stats"}
//! < {"ok":true,"type":"stats","invocations":2, ...}
//! > bogus
//! < {"ok":false,"type":"error","error":"bad-request","detail":"..."}
//! ```
//!
//! A line starting with `{` is a v1 request; anything else is parsed as
//! a legacy command and answered in the legacy `ok ...`/`err ...` line
//! format, so pre-v1 scripts keep working unchanged.
//!
//! # Steady-state allocation discipline
//!
//! The connection loop is built to stop allocating once warm, because
//! at cluster scale the envelope around the (microsecond) scheduler is
//! what bounds throughput:
//!
//! * **Parsing is zero-copy.** [`parse_jval`] produces a borrowed
//!   [`JVal`] whose strings are `&str` slices of the input line
//!   (`Cow::Owned` only when a string actually contains escapes), and
//!   the server decodes requests into a borrowed view, so hot fields
//!   (`func`, `mode`) never round-trip through `to_string`. The owned
//!   [`parse_json`]/[`crate::util::json::Json`] form remains for
//!   clients and tools that want a tree.
//! * **Encoding is writer-based.** [`encode_response_into`] /
//!   [`encode_request_into`] append directly to a caller-owned buffer —
//!   no `String`-keyed `Json::Obj` tree per message (byte-identical
//!   output; pinned by tests against tree rendering).
//! * **Buffers are per-connection.** [`serve_connection`] reuses one
//!   read and one write buffer across all requests on a connection.
//!
//! Number grammar note: integral numbers without exponent/fraction
//! decode as [`JVal::Int`]; everything else numeric as [`JVal::Num`].
//! The scanner classifies while it walks the digits, so each number is
//! parsed exactly once (the old reader tried `i64` and then re-parsed
//! the same text as `f64`).

use std::borrow::Cow;
use std::fmt::Write as _;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use super::types::{
    ApiError, DescribeInfo, InvokeMode, InvokeOutcome, MembershipInfo, MetricsFormat, Request,
    Response, ShardHealth, ShardInfo, ShardStatsRow, StatsSnapshot, Ticket, PROTOCOL_VERSION,
};
use super::Frontend;
use crate::telemetry::{EventKind, TraceEvent, NO_FUNC, NO_INV};
use crate::types::StartKind;
use crate::util::json::{self, Json};

// ---------------------------------------------------------------------
// Borrowed JSON values + the single parser.
// ---------------------------------------------------------------------

/// A parsed JSON value borrowing from the input line. Escape-free
/// strings (the overwhelmingly common case on this protocol) are
/// `Cow::Borrowed` slices of the input; only strings containing
/// escapes are decoded into owned buffers.
#[derive(Debug, Clone, PartialEq)]
pub enum JVal<'a> {
    Null,
    Bool(bool),
    Int(i64),
    Num(f64),
    Str(Cow<'a, str>),
    Arr(Vec<JVal<'a>>),
    Obj(Vec<(Cow<'a, str>, JVal<'a>)>),
}

impl<'a> JVal<'a> {
    /// Field lookup on an object (None for non-objects/missing keys).
    pub fn get(&self, key: &str) -> Option<&JVal<'a>> {
        match self {
            JVal::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        match self.get(key) {
            Some(JVal::Str(s)) => Some(s.as_ref()),
            _ => None,
        }
    }

    pub fn get_u64(&self, key: &str) -> Option<u64> {
        match self.get(key) {
            Some(JVal::Int(i)) if *i >= 0 => Some(*i as u64),
            Some(JVal::Num(x)) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        match self.get(key) {
            Some(JVal::Int(i)) => Some(*i as f64),
            Some(JVal::Num(x)) => Some(*x),
            _ => None,
        }
    }

    pub fn get_i64(&self, key: &str) -> Option<i64> {
        match self.get(key) {
            Some(JVal::Int(i)) => Some(*i),
            Some(JVal::Num(x)) if x.fract() == 0.0 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        match self.get(key) {
            Some(JVal::Bool(b)) => Some(*b),
            _ => None,
        }
    }
}

/// Parse one JSON document into the borrowed form (the zero-copy fast
/// path the serving loop runs on).
pub fn parse_jval(s: &str) -> Result<JVal<'_>, String> {
    let mut p = Parser {
        s,
        b: s.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

/// Parse one JSON document into the owned [`Json`] tree (clients,
/// tools, tests). Same grammar as [`parse_jval`].
pub fn parse_json(s: &str) -> Result<Json, String> {
    parse_jval(s).map(to_owned_json)
}

fn to_owned_json(v: JVal) -> Json {
    match v {
        JVal::Null => Json::Null,
        JVal::Bool(b) => Json::Bool(b),
        JVal::Int(i) => Json::Int(i),
        JVal::Num(x) => Json::Num(x),
        JVal::Str(s) => Json::Str(s.into_owned()),
        JVal::Arr(xs) => Json::Arr(xs.into_iter().map(to_owned_json).collect()),
        JVal::Obj(fields) => Json::Obj(
            fields
                .into_iter()
                .map(|(k, v)| (k.into_owned(), to_owned_json(v)))
                .collect(),
        ),
    }
}

struct Parser<'a> {
    s: &'a str,
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.i) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.i += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}",
                c as char, self.i
            ))
        }
    }

    fn value(&mut self) -> Result<JVal<'a>, String> {
        match self.peek() {
            None => Err("unexpected end of input".into()),
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JVal::Str(self.string()?)),
            Some(b't') => self.literal("true", JVal::Bool(true)),
            Some(b'f') => self.literal("false", JVal::Bool(false)),
            Some(b'n') => self.literal("null", JVal::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected '{}' at byte {}", c as char, self.i)),
        }
    }

    fn literal(&mut self, word: &str, v: JVal<'a>) -> Result<JVal<'a>, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    /// One pass over the digits classifies the number (int vs float,
    /// sign, magnitude, overflow) so at most one string parse ever runs
    /// — and only on the float / overflow / malformed fallback path.
    fn number(&mut self) -> Result<JVal<'a>, String> {
        let start = self.i;
        let mut float = false;
        // `simple` = optional leading '-' plus digits only; a stray
        // sign mid-run falls through to the f64 parse, which rejects it
        // exactly like the old double-parse path did.
        let mut simple = true;
        let mut neg = false;
        let mut digits = 0usize;
        let mut mag: u64 = 0;
        let mut overflow = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => {
                    match mag
                        .checked_mul(10)
                        .and_then(|m| m.checked_add((c - b'0') as u64))
                    {
                        Some(m) => mag = m,
                        None => overflow = true,
                    }
                    digits += 1;
                    self.i += 1;
                }
                b'-' if self.i == start => {
                    neg = true;
                    self.i += 1;
                }
                b'-' | b'+' => {
                    simple = false;
                    self.i += 1;
                }
                b'.' | b'e' | b'E' => {
                    float = true;
                    self.i += 1;
                }
                _ => break,
            }
        }
        if !float && simple && !overflow && digits > 0 {
            // In-range integer, already accumulated: no string parse.
            let limit = if neg { 1u64 << 63 } else { i64::MAX as u64 };
            if mag <= limit {
                let i = if neg {
                    (mag as i64).wrapping_neg()
                } else {
                    mag as i64
                };
                return Ok(JVal::Int(i));
            }
        }
        // Floats, huge magnitudes, and malformed runs: one f64 parse,
        // which also produces the error for garbage like "1-2".
        let text = &self.s[start..self.i];
        text.parse::<f64>()
            .map(JVal::Num)
            .map_err(|_| format!("bad number {text}"))
    }

    fn string(&mut self) -> Result<Cow<'a, str>, String> {
        self.eat(b'"')?;
        let start = self.i;
        // Fast path: scan to the closing quote; escape-free strings are
        // borrowed slices of the input (zero-copy). Multibyte UTF-8
        // bytes are all >= 0x80 and cannot collide with '"' or '\\'.
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    let out = &self.s[start..self.i];
                    self.i += 1;
                    return Ok(Cow::Borrowed(out));
                }
                Some(b'\\') => break,
                Some(_) => self.i += 1,
            }
        }
        // Slow path: the string contains escapes — copy the clean
        // prefix, then decode the rest into an owned buffer.
        let mut out = String::from(&self.s[start..self.i]);
        loop {
            let Some(c) = self.peek() else {
                return Err("unterminated string".into());
            };
            self.i += 1;
            match c {
                b'"' => return Ok(Cow::Owned(out)),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        return Err("unterminated escape".into());
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pair: a high surrogate must be
                            // followed by \uDC00..DFFF.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo.wrapping_sub(0xDC00) & 0x3FF);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape \\{}", e as char)),
                    }
                }
                _ => {
                    // Copy one whole character: the input is a `&str`,
                    // so the width implied by the lead byte is exact.
                    let w = utf8_len(c);
                    out.push_str(&self.s[self.i - 1..self.i - 1 + w]);
                    self.i += w - 1;
                }
            }
        }
    }

    /// Four hex digits after `\u`. Byte-wise (never `from_utf8`): the
    /// 4-byte window of a malformed escape may clip a multibyte UTF-8
    /// character, which must be a decode error, not a panic.
    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("truncated \\u escape".into());
        }
        let mut v: u32 = 0;
        for k in 0..4 {
            let c = self.b[self.i + k];
            let digit = match c {
                b'0'..=b'9' => c - b'0',
                b'a'..=b'f' => c - b'a' + 10,
                b'A'..=b'F' => c - b'A' + 10,
                _ => return Err(format!("bad \\u escape at byte {}", self.i + k)),
            };
            v = v * 16 + digit as u32;
        }
        self.i += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<JVal<'a>, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(JVal::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(JVal::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<JVal<'a>, String> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(JVal::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(JVal::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------
// Accessors over owned documents (kept for clients/tools/tests).
// ---------------------------------------------------------------------

/// Field lookup on an object (None for non-objects/missing keys).
pub fn get<'a>(v: &'a Json, key: &str) -> Option<&'a Json> {
    match v {
        Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
        _ => None,
    }
}

pub fn get_str<'a>(v: &'a Json, key: &str) -> Option<&'a str> {
    match get(v, key) {
        Some(Json::Str(s)) => Some(s),
        _ => None,
    }
}

pub fn get_u64(v: &Json, key: &str) -> Option<u64> {
    match get(v, key) {
        Some(Json::Int(i)) if *i >= 0 => Some(*i as u64),
        Some(Json::Num(x)) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
        _ => None,
    }
}

pub fn get_f64(v: &Json, key: &str) -> Option<f64> {
    match get(v, key) {
        Some(Json::Int(i)) => Some(*i as f64),
        Some(Json::Num(x)) => Some(*x),
        _ => None,
    }
}

// ---------------------------------------------------------------------
// Direct-writer primitives (bytes identical to tree rendering).
// ---------------------------------------------------------------------

/// `,"key":` — keys on this protocol are static ASCII identifiers, so
/// they never need escaping and the quoted form matches
/// [`crate::util::json`]'s escaper byte for byte.
fn push_key(out: &mut String, key: &str) {
    out.push_str(",\"");
    out.push_str(key);
    out.push_str("\":");
}

fn push_str_field(out: &mut String, key: &str, val: &str) {
    push_key(out, key);
    json::write_escaped(out, val);
}

fn push_int_field(out: &mut String, key: &str, val: i64) {
    push_key(out, key);
    let _ = write!(out, "{val}");
}

fn push_num_field(out: &mut String, key: &str, val: f64) {
    push_key(out, key);
    json::write_f64(out, val);
}

/// The shared field block of `done` and `push` replies (field order is
/// part of the pinned wire bytes).
fn push_outcome_fields(out: &mut String, o: &InvokeOutcome) {
    push_int_field(out, "ticket", o.ticket.0 as i64);
    push_str_field(out, "func", &o.func);
    push_int_field(out, "shard", o.shard as i64);
    push_int_field(out, "gpu", o.gpu as i64);
    push_key(out, "start");
    let _ = write!(out, "\"{}\"", o.start_kind);
    push_num_field(out, "latency_ms", o.latency_ms);
    push_num_field(out, "exec_ms", o.exec_ms);
}

// ---------------------------------------------------------------------
// Request codec.
// ---------------------------------------------------------------------

/// Borrowed decode of one request: the server routes straight off this
/// view, so the function name never round-trips through a `String`.
enum ReqRef<'a> {
    Hello {
        version: u32,
    },
    Describe,
    Invoke {
        func: &'a str,
        mode: InvokeMode,
        deadline_ms: Option<u64>,
        push: bool,
    },
    Wait {
        ticket: Ticket,
        deadline_ms: Option<u64>,
    },
    Poll {
        ticket: Ticket,
    },
    Stats,
    Metrics {
        format: MetricsFormat,
    },
    Trace {
        max: usize,
    },
    Drain {
        shard: usize,
    },
    Join {
        shard: usize,
    },
    Kill {
        shard: usize,
    },
    Membership,
    Shutdown,
}

fn decode_request_ref<'b>(v: &'b JVal<'_>) -> Result<ReqRef<'b>, ApiError> {
    let bad = |detail: String| ApiError::BadRequest { detail };
    let cmd = v.get_str("cmd").ok_or_else(|| bad("missing \"cmd\"".into()))?;
    let ticket = |v: &JVal| -> Result<Ticket, ApiError> {
        v.get_u64("ticket")
            .map(Ticket)
            .ok_or_else(|| bad("missing \"ticket\"".into()))
    };
    Ok(match cmd {
        "hello" => {
            let version = match v.get("v") {
                // Absent version ⇒ the client wants whatever is current.
                None => PROTOCOL_VERSION as u64,
                // Present but malformed (string, fractional, negative)
                // must NOT silently negotiate to the default.
                Some(_) => v.get_u64("v").ok_or_else(|| {
                    bad("hello: \"v\" must be a non-negative integer".into())
                })?,
            };
            ReqRef::Hello {
                // Saturate instead of truncating: 2^32+1 must read as
                // "far future" and be rejected, not wrap to v1.
                version: u32::try_from(version).unwrap_or(u32::MAX),
            }
        }
        "describe" => ReqRef::Describe,
        "invoke" => {
            let func = v
                .get_str("func")
                .ok_or_else(|| bad("invoke: missing \"func\"".into()))?;
            let mode = match v.get_str("mode") {
                None => InvokeMode::Sync,
                Some(m) => InvokeMode::parse(m)
                    .ok_or_else(|| bad(format!("invoke: unknown mode {m}")))?,
            };
            let push = v.get_bool("push").unwrap_or(false);
            // A push subscription needs a ticket to notify on; sync
            // invokes already block for their outcome. Reject rather
            // than silently downgrade.
            if push && matches!(mode, InvokeMode::Sync) {
                return Err(bad("invoke: push requires mode \"async\"".into()));
            }
            ReqRef::Invoke {
                func,
                mode,
                deadline_ms: v.get_u64("deadline_ms"),
                push,
            }
        }
        "wait" => ReqRef::Wait {
            ticket: ticket(v)?,
            deadline_ms: v.get_u64("deadline_ms"),
        },
        "poll" => ReqRef::Poll { ticket: ticket(v)? },
        "stats" => ReqRef::Stats,
        "metrics" => {
            let format = match v.get_str("format") {
                None => MetricsFormat::Prom,
                Some(f) => MetricsFormat::parse(f)
                    .ok_or_else(|| bad(format!("metrics: unknown format {f}")))?,
            };
            ReqRef::Metrics { format }
        }
        "trace" => ReqRef::Trace {
            // Absent ⇒ drain everything buffered (the ring is bounded,
            // so "everything" is at most its capacity).
            max: v.get_u64("max").unwrap_or(u32::MAX as u64) as usize,
        },
        "drain" | "join" | "kill" => {
            let shard = v
                .get_u64("shard")
                .ok_or_else(|| bad(format!("{cmd}: missing \"shard\"")))?
                as usize;
            match cmd {
                "drain" => ReqRef::Drain { shard },
                "join" => ReqRef::Join { shard },
                _ => ReqRef::Kill { shard },
            }
        }
        "membership" => ReqRef::Membership,
        "quit" | "shutdown" => ReqRef::Shutdown,
        other => return Err(bad(format!("unknown command {other}"))),
    })
}

/// Encode one request onto `out` as a single wire line (no trailing
/// newline) — writer-based, no intermediate tree.
pub fn encode_request_into(req: &Request, out: &mut String) {
    let cmd = |out: &mut String, c: &str| {
        out.push_str("{\"cmd\":\"");
        out.push_str(c);
        out.push('"');
    };
    match req {
        Request::Hello { version } => {
            cmd(out, "hello");
            push_int_field(out, "v", *version as i64);
        }
        Request::Describe => cmd(out, "describe"),
        Request::Invoke {
            func,
            mode,
            deadline_ms,
            push,
        } => {
            cmd(out, "invoke");
            push_str_field(out, "func", func);
            push_str_field(out, "mode", mode.name());
            if let Some(d) = deadline_ms {
                push_int_field(out, "deadline_ms", *d as i64);
            }
            // Emitted only when set: non-push invoke lines (the only
            // kind legacy lockstep clients send) are byte-unchanged.
            if *push {
                push_key(out, "push");
                out.push_str("true");
            }
        }
        Request::Wait {
            ticket,
            deadline_ms,
        } => {
            cmd(out, "wait");
            push_int_field(out, "ticket", ticket.0 as i64);
            if let Some(d) = deadline_ms {
                push_int_field(out, "deadline_ms", *d as i64);
            }
        }
        Request::Poll { ticket } => {
            cmd(out, "poll");
            push_int_field(out, "ticket", ticket.0 as i64);
        }
        Request::Stats => cmd(out, "stats"),
        Request::Metrics { format } => {
            cmd(out, "metrics");
            push_str_field(out, "format", format.name());
        }
        Request::Trace { max } => {
            cmd(out, "trace");
            push_int_field(out, "max", *max as i64);
        }
        Request::Drain { shard } => {
            cmd(out, "drain");
            push_int_field(out, "shard", *shard as i64);
        }
        Request::Join { shard } => {
            cmd(out, "join");
            push_int_field(out, "shard", *shard as i64);
        }
        Request::Kill { shard } => {
            cmd(out, "kill");
            push_int_field(out, "shard", *shard as i64);
        }
        Request::Membership => cmd(out, "membership"),
        Request::Shutdown => cmd(out, "quit"),
    }
    out.push('}');
}

/// Encode one request as a single wire line (no trailing newline).
pub fn encode_request(req: &Request) -> String {
    let mut out = String::new();
    encode_request_into(req, &mut out);
    out
}

/// Encode one request with a leading client-chosen `"id"` field — the
/// pipelining correlation tag the server echoes back on the matching
/// reply, so responses can be consumed out of order.
pub fn encode_request_tagged_into(req: &Request, id: u64, out: &mut String) {
    out.push_str("{\"id\":");
    let _ = write!(out, "{id}");
    let start = out.len();
    encode_request_into(req, out);
    // The plain encoder opened its own object; fold the two together
    // (both bytes are single ASCII chars, so this is an in-place swap).
    out.replace_range(start..start + 1, ",");
}

/// Decode one v1 request line (must start with `{`) into the owned
/// [`Request`]. The server's own loop uses the borrowed decode and
/// never materializes this form.
pub fn decode_request(line: &str) -> Result<Request, ApiError> {
    let v = parse_jval(line).map_err(|e| ApiError::BadRequest {
        detail: format!("bad JSON: {e}"),
    })?;
    Ok(match decode_request_ref(&v)? {
        ReqRef::Hello { version } => Request::Hello { version },
        ReqRef::Describe => Request::Describe,
        ReqRef::Invoke {
            func,
            mode,
            deadline_ms,
            push,
        } => Request::Invoke {
            func: func.to_string(),
            mode,
            deadline_ms,
            push,
        },
        ReqRef::Wait {
            ticket,
            deadline_ms,
        } => Request::Wait {
            ticket,
            deadline_ms,
        },
        ReqRef::Poll { ticket } => Request::Poll { ticket },
        ReqRef::Stats => Request::Stats,
        ReqRef::Metrics { format } => Request::Metrics { format },
        ReqRef::Trace { max } => Request::Trace { max },
        ReqRef::Drain { shard } => Request::Drain { shard },
        ReqRef::Join { shard } => Request::Join { shard },
        ReqRef::Kill { shard } => Request::Kill { shard },
        ReqRef::Membership => Request::Membership,
        ReqRef::Shutdown => Request::Shutdown,
    })
}

// ---------------------------------------------------------------------
// Response codec.
// ---------------------------------------------------------------------

/// Encode one response onto `out` as a single wire line (no trailing
/// newline). Writer-based: field order and bytes are identical to the
/// old `Json::Obj` tree rendering (pinned by a test), with zero
/// intermediate allocation.
pub fn encode_response_into(resp: &Response, out: &mut String) {
    out.push_str(if matches!(resp, Response::Error(_)) {
        "{\"ok\":false"
    } else {
        "{\"ok\":true"
    });
    match resp {
        Response::Hello { proto, server } => {
            push_str_field(out, "type", "hello");
            push_int_field(out, "proto", *proto as i64);
            push_str_field(out, "server", server);
        }
        Response::Described(d) => {
            push_str_field(out, "type", "describe");
            push_int_field(out, "proto", d.proto as i64);
            push_str_field(out, "server", &d.server);
            push_str_field(out, "policy", &d.policy);
            push_int_field(out, "shards", d.shards as i64);
            push_str_field(out, "router", &d.router);
            push_key(out, "functions");
            out.push('[');
            for (i, name) in d.functions.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                json::write_escaped(out, name);
            }
            out.push(']');
        }
        Response::Accepted { ticket } => {
            push_str_field(out, "type", "ticket");
            push_int_field(out, "ticket", ticket.0 as i64);
        }
        Response::Done(o) => {
            push_str_field(out, "type", "done");
            push_outcome_fields(out, o);
        }
        Response::Push(o) => {
            push_str_field(out, "type", "push");
            push_outcome_fields(out, o);
        }
        Response::Pending { ticket } => {
            push_str_field(out, "type", "pending");
            push_int_field(out, "ticket", ticket.0 as i64);
        }
        Response::Stats(s) => {
            push_str_field(out, "type", "stats");
            push_int_field(out, "invocations", s.invocations as i64);
            push_num_field(out, "mean_latency_ms", s.mean_latency_ms);
            push_num_field(out, "cold_ratio", s.cold_ratio);
            push_int_field(out, "pending", s.pending as i64);
            push_int_field(out, "in_flight", s.in_flight as i64);
            // Appended after the aggregate fields, so the line's prefix
            // bytes are unchanged from the pre-breakdown protocol.
            push_key(out, "shards");
            out.push('[');
            for (i, row) in s.shards.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str("{\"shard\":");
                let _ = write!(out, "{}", row.shard);
                push_int_field(out, "pending", row.pending as i64);
                push_int_field(out, "in_flight", row.in_flight as i64);
                push_int_field(out, "completed", row.completed as i64);
                push_num_field(out, "cold_ratio", row.cold_ratio);
                push_str_field(out, "state", row.health.name());
                push_int_field(out, "epoch", row.epoch as i64);
                out.push('}');
            }
            out.push(']');
        }
        Response::Metrics { format, body } => {
            push_str_field(out, "type", "metrics");
            push_str_field(out, "format", format.name());
            push_str_field(out, "body", body);
        }
        Response::Trace { dropped, events } => {
            push_str_field(out, "type", "trace");
            push_int_field(out, "dropped", *dropped as i64);
            push_int_field(out, "count", events.len() as i64);
            push_key(out, "events");
            out.push('[');
            for (i, ev) in events.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                // Each event is one compact JSON object — the same
                // rendering the sim's JSONL sink writes per line.
                ev.render_jsonl_into(out);
            }
            out.push(']');
        }
        Response::Membership(m) => {
            push_str_field(out, "type", "membership");
            push_int_field(out, "epoch", m.epoch as i64);
            push_int_field(out, "accepted", m.accepted as i64);
            push_int_field(out, "completed", m.completed as i64);
            push_int_field(out, "failed", m.failed as i64);
            push_int_field(out, "rejected", m.rejected as i64);
            push_int_field(out, "stale_drops", m.stale_drops as i64);
            push_key(out, "shards");
            out.push('[');
            for (i, s) in m.shards.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str("{\"shard\":");
                let _ = write!(out, "{}", s.shard);
                push_str_field(out, "state", s.health.name());
                push_int_field(out, "epoch", s.epoch as i64);
                push_int_field(out, "pending", s.pending as i64);
                push_int_field(out, "in_flight", s.in_flight as i64);
                push_num_field(out, "capacity", s.capacity);
                out.push('}');
            }
            out.push(']');
        }
        Response::Bye => push_str_field(out, "type", "bye"),
        Response::Error(e) => {
            push_str_field(out, "type", "error");
            push_str_field(out, "error", e.code());
            push_str_field(out, "detail", &e.detail());
            // Structured extras for errors clients branch on beyond the
            // code alone.
            match e {
                // Deadline-tripped work keeps running: surface its
                // ticket so clients can redeem it later.
                ApiError::DeadlineExceeded {
                    ticket: Some(t), ..
                } => push_int_field(out, "ticket", t.0 as i64),
                // Which shard died, and which ticket it stranded.
                ApiError::ShardLost { shard, ticket } => {
                    push_int_field(out, "shard", *shard as i64);
                    push_int_field(out, "ticket", ticket.0 as i64);
                }
                // Evicted-vs-never-existed is a real distinction: the
                // first means "your result aged out", the second a bug.
                ApiError::UnknownTicket { ticket, evicted } => {
                    push_int_field(out, "ticket", ticket.0 as i64);
                    push_key(out, "evicted");
                    out.push_str(if *evicted { "true" } else { "false" });
                }
                // Retry-budget exhaustion: the ticket that died and how
                // many attempts it burned.
                ApiError::ExecFailed { ticket, attempts } => {
                    push_int_field(out, "ticket", ticket.0 as i64);
                    push_int_field(out, "attempts", *attempts as i64);
                }
                // Breaker rejection: the quarantined function and the
                // server's backoff hint.
                ApiError::Quarantined {
                    func,
                    retry_after_ms,
                } => {
                    push_str_field(out, "func", func);
                    push_int_field(out, "retry_after_ms", *retry_after_ms as i64);
                }
                // Backpressure / shed: counts plus the backoff hint.
                ApiError::Overloaded {
                    pending,
                    limit,
                    retry_after_ms,
                } => {
                    push_int_field(out, "pending", *pending as i64);
                    push_int_field(out, "limit", *limit as i64);
                    push_int_field(out, "retry_after_ms", *retry_after_ms as i64);
                }
                _ => {}
            }
        }
    }
    out.push('}');
}

/// Encode one response as a single wire line (no trailing newline).
pub fn encode_response(resp: &Response) -> String {
    let mut out = String::new();
    encode_response_into(resp, &mut out);
    out
}

/// Encode one response, echoing the request's correlation `id` right
/// after the `ok` flag. `id: None` produces bytes identical to
/// [`encode_response_into`] — untagged (lockstep) requests get
/// untagged replies.
pub fn encode_response_tagged_into(resp: &Response, id: Option<u64>, out: &mut String) {
    let base = out.len();
    encode_response_into(resp, out);
    let Some(id) = id else { return };
    let prefix = if matches!(resp, Response::Error(_)) {
        "{\"ok\":false".len()
    } else {
        "{\"ok\":true".len()
    };
    // Format the tag on the stack, then splice once: no heap traffic
    // beyond the (amortized) reply buffer itself.
    let mut buf = [0u8; 32];
    let tag = {
        use std::io::Write as _;
        let mut cur = std::io::Cursor::new(&mut buf[..]);
        let _ = write!(cur, ",\"id\":{id}");
        let len = cur.position() as usize;
        std::str::from_utf8(&buf[..len]).expect("ascii tag")
    };
    out.insert_str(base + prefix, tag);
}

/// Client-side decode of a possibly-tagged response line: the echoed
/// correlation id (None on lockstep replies and server-push lines)
/// plus the response itself.
pub fn decode_response_tagged(line: &str) -> Result<(Option<u64>, Response), String> {
    let v = parse_jval(line)?;
    let id = v.get_u64("id");
    decode_response(line).map(|r| (id, r))
}

/// The shared outcome body of `done` and `push` replies.
fn decode_outcome(v: &JVal<'_>) -> Result<InvokeOutcome, String> {
    Ok(InvokeOutcome {
        ticket: v
            .get_u64("ticket")
            .map(Ticket)
            .ok_or("missing \"ticket\"")?,
        func: v.get_str("func").unwrap_or("").to_string(),
        shard: v.get_u64("shard").unwrap_or(0) as usize,
        gpu: v.get_u64("gpu").unwrap_or(0) as u32,
        start_kind: v
            .get_str("start")
            .and_then(StartKind::parse)
            .ok_or("bad \"start\"")?,
        latency_ms: v.get_f64("latency_ms").ok_or("missing \"latency_ms\"")?,
        exec_ms: v.get_f64("exec_ms").unwrap_or(0.0),
    })
}

/// Decode one response line (client side).
pub fn decode_response(line: &str) -> Result<Response, String> {
    let v = parse_jval(line)?;
    if let Some(JVal::Bool(false)) = v.get("ok") {
        let code = v.get_str("error").unwrap_or("bad-request");
        let detail = v.get_str("detail").unwrap_or("");
        let mut err = ApiError::from_wire(code, detail);
        // Structured extras override the best-effort detail parse.
        match &mut err {
            // The still-running invocation's ticket.
            ApiError::DeadlineExceeded { ticket, .. } => {
                *ticket = v.get_u64("ticket").map(Ticket);
            }
            ApiError::ShardLost { shard, ticket } => {
                if let Some(s) = v.get_u64("shard") {
                    *shard = s as usize;
                }
                if let Some(t) = v.get_u64("ticket") {
                    *ticket = Ticket(t);
                }
            }
            ApiError::UnknownTicket { ticket, evicted } => {
                if let Some(t) = v.get_u64("ticket") {
                    *ticket = Ticket(t);
                }
                if let Some(JVal::Bool(b)) = v.get("evicted") {
                    *evicted = *b;
                }
            }
            ApiError::ExecFailed { ticket, attempts } => {
                if let Some(t) = v.get_u64("ticket") {
                    *ticket = Ticket(t);
                }
                if let Some(a) = v.get_u64("attempts") {
                    *attempts = a as u32;
                }
            }
            ApiError::Quarantined {
                func,
                retry_after_ms,
            } => {
                if let Some(f) = v.get_str("func") {
                    *func = f.to_string();
                }
                if let Some(r) = v.get_u64("retry_after_ms") {
                    *retry_after_ms = r;
                }
            }
            ApiError::Overloaded {
                pending,
                limit,
                retry_after_ms,
            } => {
                if let Some(p) = v.get_u64("pending") {
                    *pending = p as usize;
                }
                if let Some(l) = v.get_u64("limit") {
                    *limit = l as usize;
                }
                if let Some(r) = v.get_u64("retry_after_ms") {
                    *retry_after_ms = r;
                }
            }
            _ => {}
        }
        return Ok(Response::Error(err));
    }
    let ty = v.get_str("type").ok_or("missing \"type\"")?;
    let ticket = |v: &JVal| v.get_u64("ticket").map(Ticket).ok_or("missing \"ticket\"");
    Ok(match ty {
        "hello" => Response::Hello {
            proto: v.get_u64("proto").ok_or("missing \"proto\"")? as u32,
            server: v.get_str("server").unwrap_or("").to_string(),
        },
        "describe" => Response::Described(DescribeInfo {
            proto: v.get_u64("proto").ok_or("missing \"proto\"")? as u32,
            server: v.get_str("server").unwrap_or("").to_string(),
            policy: v.get_str("policy").unwrap_or("").to_string(),
            shards: v.get_u64("shards").unwrap_or(1) as usize,
            router: v.get_str("router").unwrap_or("").to_string(),
            functions: match v.get("functions") {
                Some(JVal::Arr(xs)) => xs
                    .iter()
                    .filter_map(|x| match x {
                        JVal::Str(s) => Some(s.as_ref().to_string()),
                        _ => None,
                    })
                    .collect(),
                _ => Vec::new(),
            },
        }),
        "ticket" => Response::Accepted { ticket: ticket(&v)? },
        "done" => Response::Done(decode_outcome(&v)?),
        "push" => Response::Push(decode_outcome(&v)?),
        "pending" => Response::Pending { ticket: ticket(&v)? },
        "stats" => Response::Stats(StatsSnapshot {
            invocations: v.get_u64("invocations").unwrap_or(0) as usize,
            mean_latency_ms: v.get_f64("mean_latency_ms").unwrap_or(0.0),
            cold_ratio: v.get_f64("cold_ratio").unwrap_or(0.0),
            pending: v.get_u64("pending").unwrap_or(0) as usize,
            in_flight: v.get_u64("in_flight").unwrap_or(0) as usize,
            shards: match v.get("shards") {
                Some(JVal::Arr(xs)) => xs
                    .iter()
                    .map(|x| ShardStatsRow {
                        shard: x.get_u64("shard").unwrap_or(0) as usize,
                        pending: x.get_u64("pending").unwrap_or(0) as usize,
                        in_flight: x.get_u64("in_flight").unwrap_or(0) as usize,
                        completed: x.get_u64("completed").unwrap_or(0),
                        cold_ratio: x.get_f64("cold_ratio").unwrap_or(0.0),
                        health: x
                            .get_str("state")
                            .and_then(ShardHealth::parse)
                            .unwrap_or(ShardHealth::Up),
                        epoch: x.get_u64("epoch").unwrap_or(0),
                    })
                    .collect(),
                // Pre-breakdown servers: aggregate-only reply.
                _ => Vec::new(),
            },
        }),
        "metrics" => Response::Metrics {
            format: v
                .get_str("format")
                .and_then(MetricsFormat::parse)
                .unwrap_or(MetricsFormat::Prom),
            body: v.get_str("body").unwrap_or("").to_string(),
        },
        "trace" => Response::Trace {
            dropped: v.get_u64("dropped").unwrap_or(0),
            events: match v.get("events") {
                Some(JVal::Arr(xs)) => xs
                    .iter()
                    .filter_map(|x| {
                        Some(TraceEvent {
                            seq: x.get_u64("seq")?,
                            at: x.get_u64("at")?,
                            kind: EventKind::parse(x.get_str("kind")?)?,
                            shard: x.get_u64("shard").unwrap_or(0) as u32,
                            inv: x.get_u64("inv").unwrap_or(NO_INV),
                            func: x.get_u64("func").unwrap_or(NO_FUNC as u64) as u32,
                            a: x.get_i64("a").unwrap_or(0),
                            b: x.get_i64("b").unwrap_or(0),
                            c: x.get_i64("c").unwrap_or(0),
                        })
                    })
                    .collect(),
                _ => Vec::new(),
            },
        },
        "membership" => Response::Membership(MembershipInfo {
            epoch: v.get_u64("epoch").unwrap_or(0),
            accepted: v.get_u64("accepted").unwrap_or(0),
            completed: v.get_u64("completed").unwrap_or(0),
            failed: v.get_u64("failed").unwrap_or(0),
            rejected: v.get_u64("rejected").unwrap_or(0),
            stale_drops: v.get_u64("stale_drops").unwrap_or(0),
            shards: match v.get("shards") {
                Some(JVal::Arr(xs)) => xs
                    .iter()
                    .map(|x| ShardInfo {
                        shard: x.get_u64("shard").unwrap_or(0) as usize,
                        health: x
                            .get_str("state")
                            .and_then(ShardHealth::parse)
                            .unwrap_or(ShardHealth::Up),
                        epoch: x.get_u64("epoch").unwrap_or(0),
                        pending: x.get_u64("pending").unwrap_or(0) as usize,
                        in_flight: x.get_u64("in_flight").unwrap_or(0) as usize,
                        capacity: x.get_f64("capacity").unwrap_or(1.0),
                    })
                    .collect(),
                _ => Vec::new(),
            },
        }),
        "bye" => Response::Bye,
        other => return Err(format!("unknown response type {other}")),
    })
}

// ---------------------------------------------------------------------
// Connection loop: v1 lines + legacy aliases over one Frontend.
// ---------------------------------------------------------------------

/// Serve one TCP connection over `frontend` until the client quits or
/// the stream errors. Shared by [`crate::server::RtServer`] and
/// [`crate::server::RtCluster`] — the protocol never sees which one it
/// is talking to, only the [`Frontend`] contract.
///
/// One read buffer and one write buffer live for the whole connection;
/// in steady state the loop performs no per-request allocation beyond
/// what the frontend's own reply values need.
pub fn serve_connection(frontend: &dyn Frontend, stream: TcpStream) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut line = String::with_capacity(256);
    let mut out = String::with_capacity(256);
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
        let req = line.trim();
        if req.is_empty() {
            continue;
        }
        out.clear();
        let close = if req.starts_with('{') {
            handle_v1(frontend, req, &mut out)
        } else {
            handle_legacy(frontend, req, &mut out)
        };
        if !out.is_empty() {
            out.push('\n');
            if writer.write_all(out.as_bytes()).is_err() {
                break;
            }
        }
        if close {
            break;
        }
    }
}

/// Deadline option → `Duration` (ms granularity, as on the wire).
fn deadline(ms: Option<u64>) -> Option<Duration> {
    ms.map(Duration::from_millis)
}

/// The verbs whose reply needs no waiting — shared between the
/// blocking loop and the event loop. `None` for the verbs whose
/// handling differs between the two (`invoke`, `wait`, `quit`).
fn handle_v1_immediate(frontend: &dyn Frontend, req: &ReqRef<'_>) -> Option<Response> {
    Some(match *req {
        ReqRef::Hello { version } => {
            if version == 0 || version > PROTOCOL_VERSION {
                Response::Error(ApiError::UnsupportedVersion {
                    requested: version,
                    supported: PROTOCOL_VERSION,
                })
            } else {
                Response::Hello {
                    proto: version,
                    server: frontend.describe().server,
                }
            }
        }
        ReqRef::Describe => Response::Described(frontend.describe()),
        ReqRef::Poll { ticket } => match frontend.poll(ticket) {
            Ok(Some(o)) => Response::Done(o),
            Ok(None) => Response::Pending { ticket },
            Err(e) => Response::Error(e),
        },
        ReqRef::Stats => Response::Stats(frontend.stats()),
        ReqRef::Metrics { format } => match frontend.metrics(format) {
            Ok(body) => Response::Metrics { format, body },
            Err(e) => Response::Error(e),
        },
        ReqRef::Trace { max } => match frontend.trace(max) {
            Ok((dropped, events)) => Response::Trace { dropped, events },
            Err(e) => Response::Error(e),
        },
        ReqRef::Drain { shard } => match frontend.drain(shard) {
            Ok(m) => Response::Membership(m),
            Err(e) => Response::Error(e),
        },
        ReqRef::Join { shard } => match frontend.join(shard) {
            Ok(m) => Response::Membership(m),
            Err(e) => Response::Error(e),
        },
        ReqRef::Kill { shard } => match frontend.kill(shard) {
            Ok(m) => Response::Membership(m),
            Err(e) => Response::Error(e),
        },
        ReqRef::Membership => match frontend.membership() {
            Ok(m) => Response::Membership(m),
            Err(e) => Response::Error(e),
        },
        ReqRef::Invoke { .. } | ReqRef::Wait { .. } | ReqRef::Shutdown => return None,
    })
}

/// Handle one v1 line, appending the reply to `out`. Returns whether
/// the connection should close. Decodes through the borrowed view, so
/// the hot invoke path hands `func` to the frontend without copying it.
fn handle_v1(frontend: &dyn Frontend, line: &str, out: &mut String) -> bool {
    let parsed = parse_jval(line).map_err(|e| ApiError::BadRequest {
        detail: format!("bad JSON: {e}"),
    });
    let req = match &parsed {
        Err(e) => Err(e.clone()),
        Ok(v) => decode_request_ref(v),
    };
    let resp = match req {
        Err(e) => Response::Error(e),
        Ok(req) => match req {
            // Blocking loop: sync invoke and wait park this
            // connection's thread in the frontend. (`push` is an
            // event-loop feature — there is no unsolicited write slot
            // on a lockstep connection — so it is ignored here.)
            ReqRef::Invoke {
                func,
                mode,
                deadline_ms,
                push: _,
            } => match frontend.submit(func) {
                Err(e) => Response::Error(e),
                Ok(ticket) => match mode {
                    InvokeMode::Async => Response::Accepted { ticket },
                    InvokeMode::Sync => {
                        match frontend.wait(ticket, deadline(deadline_ms)) {
                            Ok(o) => Response::Done(o),
                            Err(e) => Response::Error(e),
                        }
                    }
                },
            },
            ReqRef::Wait {
                ticket,
                deadline_ms,
            } => match frontend.wait(ticket, deadline(deadline_ms)) {
                Ok(o) => Response::Done(o),
                Err(e) => Response::Error(e),
            },
            ReqRef::Shutdown => {
                encode_response_into(&Response::Bye, out);
                return true;
            }
            ref other => handle_v1_immediate(frontend, other).expect("immediate verb"),
        },
    };
    encode_response_into(&resp, out);
    false
}

/// Legacy aliases: the pre-v1 word protocol, answered in its original
/// reply format (scripts from before the redesign keep working).
/// Appends the reply to `out` (nothing for `quit`); returns whether the
/// connection should close.
fn handle_legacy(frontend: &dyn Frontend, line: &str, out: &mut String) -> bool {
    let mut parts = line.split_whitespace();
    match parts.next() {
        Some("invoke") => match parts.next() {
            None => out.push_str("err unknown function"),
            Some(name) => match frontend.invoke(name, None) {
                Ok(o) => encode_legacy_outcome_into(&o, out),
                Err(e) => encode_legacy_error_into(&e, out),
            },
        },
        Some("stats") => {
            let s = frontend.stats();
            let _ = write!(
                out,
                "ok invocations={} mean_latency_ms={:.1} cold_ratio={:.3}",
                s.invocations, s.mean_latency_ms, s.cold_ratio
            );
        }
        Some("quit") | None => return true,
        Some(other) => {
            let _ = write!(out, "err unknown command {other}");
        }
    }
    false
}

/// The legacy `ok ...` completion line (no trailing newline). Factored
/// out so the event loop's deferred path emits byte-identical replies
/// to the blocking loop — the legacy-compat pin covers both.
pub fn encode_legacy_outcome_into(o: &InvokeOutcome, out: &mut String) {
    let _ = write!(
        out,
        "ok {:.1} {:.1} {} gpu{}",
        o.latency_ms, o.exec_ms, o.start_kind, o.gpu
    );
}

/// The legacy `err ...` line for a failed invoke (no trailing newline).
pub fn encode_legacy_error_into(e: &ApiError, out: &mut String) {
    match e {
        ApiError::UnknownFunction { .. } => out.push_str("err unknown function"),
        e => {
            let _ = write!(out, "err {}", e.code());
        }
    }
}

// ---------------------------------------------------------------------
// Deferred dispatch: the event loop's per-line entry point.
// ---------------------------------------------------------------------

/// How a deferred reply should be rendered when its ticket resolves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplyFormat {
    /// v1 JSON line, echoing the request's correlation id (if any).
    V1 { id: Option<u64> },
    /// Legacy `ok ...` / `err ...` word line.
    Legacy,
}

/// What the event loop must do after dispatching one request line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoopAction {
    /// The reply (possibly empty, e.g. legacy `quit`) is already in
    /// `out`; optionally close after flushing it.
    Replied { close: bool },
    /// Nothing written yet: subscribe to `ticket` and render the reply
    /// in `format` when it resolves (or when `deadline` fires).
    AwaitCompletion {
        ticket: Ticket,
        deadline: Option<Duration>,
        format: ReplyFormat,
    },
    /// The `Accepted` reply is already in `out`; additionally
    /// subscribe to `ticket` and emit a `push` notification line
    /// (tagged `id`) when it completes.
    Subscribe { ticket: Ticket, id: Option<u64> },
}

/// Dispatch one request line without ever blocking: the nonblocking
/// twin of the `handle_v1`/`handle_legacy` pair, sharing their codecs
/// and verb handlers so replies are byte-identical. Blocking verbs
/// (sync `invoke`, `wait`, legacy `invoke`) return
/// [`LoopAction::AwaitCompletion`] instead of parking the thread.
pub fn handle_line_deferred(frontend: &dyn Frontend, line: &str, out: &mut String) -> LoopAction {
    if line.starts_with('{') {
        handle_v1_deferred(frontend, line, out)
    } else {
        handle_legacy_deferred(frontend, line, out)
    }
}

fn handle_v1_deferred(frontend: &dyn Frontend, line: &str, out: &mut String) -> LoopAction {
    let parsed = parse_jval(line).map_err(|e| ApiError::BadRequest {
        detail: format!("bad JSON: {e}"),
    });
    let (id, req) = match &parsed {
        Err(e) => (None, Err(e.clone())),
        Ok(v) => (v.get_u64("id"), decode_request_ref(v)),
    };
    let resp = match req {
        Err(e) => Response::Error(e),
        Ok(req) => match req {
            ReqRef::Invoke {
                func,
                mode,
                deadline_ms,
                push,
            } => match frontend.submit(func) {
                Err(e) => Response::Error(e),
                Ok(ticket) => match mode {
                    InvokeMode::Sync => {
                        return LoopAction::AwaitCompletion {
                            ticket,
                            deadline: deadline(deadline_ms),
                            format: ReplyFormat::V1 { id },
                        }
                    }
                    InvokeMode::Async => {
                        encode_response_tagged_into(&Response::Accepted { ticket }, id, out);
                        if push {
                            return LoopAction::Subscribe { ticket, id };
                        }
                        return LoopAction::Replied { close: false };
                    }
                },
            },
            ReqRef::Wait {
                ticket,
                deadline_ms,
            } => {
                return LoopAction::AwaitCompletion {
                    ticket,
                    deadline: deadline(deadline_ms),
                    format: ReplyFormat::V1 { id },
                }
            }
            ReqRef::Shutdown => {
                encode_response_tagged_into(&Response::Bye, id, out);
                return LoopAction::Replied { close: true };
            }
            ref other => handle_v1_immediate(frontend, other).expect("immediate verb"),
        },
    };
    encode_response_tagged_into(&resp, id, out);
    LoopAction::Replied { close: false }
}

fn handle_legacy_deferred(frontend: &dyn Frontend, line: &str, out: &mut String) -> LoopAction {
    let mut parts = line.split_whitespace();
    match parts.next() {
        // Legacy invoke is sync-with-no-deadline: defer the `ok` line
        // to completion time instead of blocking the loop.
        Some("invoke") => match parts.next() {
            None => out.push_str("err unknown function"),
            Some(name) => match frontend.submit(name) {
                Ok(ticket) => {
                    return LoopAction::AwaitCompletion {
                        ticket,
                        deadline: None,
                        format: ReplyFormat::Legacy,
                    }
                }
                Err(e) => encode_legacy_error_into(&e, out),
            },
        },
        Some("stats") => {
            let s = frontend.stats();
            let _ = write!(
                out,
                "ok invocations={} mean_latency_ms={:.1} cold_ratio={:.3}",
                s.invocations, s.mean_latency_ms, s.cold_ratio
            );
        }
        Some("quit") | None => return LoopAction::Replied { close: true },
        Some(other) => {
            let _ = write!(out, "err unknown command {other}");
        }
    }
    LoopAction::Replied { close: false }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_parser_roundtrips_rendered_documents() {
        let doc = Json::Obj(vec![
            ("s".into(), Json::str("a\"b\\c\nd — ü")),
            ("i".into(), Json::Int(-42)),
            ("x".into(), Json::Num(1.5)),
            ("b".into(), Json::Bool(true)),
            ("n".into(), Json::Null),
            (
                "arr".into(),
                Json::Arr(vec![Json::Int(1), Json::str("two"), Json::Null]),
            ),
            ("obj".into(), Json::Obj(vec![("k".into(), Json::Int(7))])),
            ("empty".into(), Json::Arr(vec![])),
        ]);
        for text in [doc.render(), doc.render_compact()] {
            let back = parse_json(&text).unwrap();
            assert_eq!(get_str(&back, "s"), Some("a\"b\\c\nd — ü"));
            assert_eq!(get_u64(&back, "i"), None); // negative
            assert_eq!(get_f64(&back, "i"), Some(-42.0));
            assert_eq!(get_f64(&back, "x"), Some(1.5));
            assert!(matches!(get(&back, "b"), Some(Json::Bool(true))));
            assert!(matches!(get(&back, "n"), Some(Json::Null)));
            let Some(Json::Arr(xs)) = get(&back, "arr") else {
                panic!("arr")
            };
            assert_eq!(xs.len(), 3);
            assert_eq!(get_u64(get(&back, "obj").unwrap(), "k"), Some(7));
        }
    }

    #[test]
    fn json_parser_handles_escapes_and_unicode() {
        let v = parse_json(r#"{"u":"é€","sp":"😀","t":"\t"}"#).unwrap();
        assert_eq!(get_str(&v, "u"), Some("é€"));
        assert_eq!(get_str(&v, "sp"), Some("😀"));
        assert_eq!(get_str(&v, "t"), Some("\t"));
    }

    #[test]
    fn borrowed_parse_borrows_escape_free_strings() {
        // The zero-copy contract: strings without escapes are slices of
        // the input line; escaped strings (and escaped keys) decode to
        // owned buffers with identical contents.
        let line = r#"{"cmd":"invoke","func":"fft-0","note":"a\nb","sp":"😀"}"#;
        let v = parse_jval(line).unwrap();
        assert!(matches!(v.get("cmd"), Some(JVal::Str(Cow::Borrowed("invoke")))));
        assert!(matches!(v.get("func"), Some(JVal::Str(Cow::Borrowed("fft-0")))));
        assert!(matches!(v.get("sp"), Some(JVal::Str(Cow::Borrowed("😀")))));
        assert!(matches!(v.get("note"), Some(JVal::Str(Cow::Owned(_)))));
        assert_eq!(v.get_str("note"), Some("a\nb"));
        // Escapes mid-string keep the clean prefix + suffix intact.
        let v = parse_jval(r#"{"s":"pre\t💠post"}"#).unwrap();
        assert_eq!(v.get_str("s"), Some("pre\t💠post"));
    }

    #[test]
    fn number_scanner_classifies_in_one_pass() {
        // Integers in range (including both extremes) decode as Int.
        for (text, want) in [
            ("0", 0i64),
            ("42", 42),
            ("-7", -7),
            ("9223372036854775807", i64::MAX),
            ("-9223372036854775808", i64::MIN),
            ("0123", 123), // leniency preserved from the old reader
        ] {
            match parse_jval(text).unwrap() {
                JVal::Int(i) => assert_eq!(i, want, "{text}"),
                other => panic!("{text} decoded as {other:?}"),
            }
        }
        // Floats, exponents, and i64-overflowing magnitudes are Num.
        for (text, want) in [
            ("1.5", 1.5f64),
            ("-2.25", -2.25),
            ("1e3", 1000.0),
            ("9223372036854775808", 9.223372036854776e18),
            ("-9223372036854775809", -9.223372036854776e18),
        ] {
            match parse_jval(text).unwrap() {
                JVal::Num(x) => assert!((x - want).abs() <= want.abs() * 1e-12, "{text}"),
                other => panic!("{text} decoded as {other:?}"),
            }
        }
        // Garbage digit runs still error (via the single fallback parse).
        for bad in ["1-2", "--5", "5+3", "1.2.3", "1ee5"] {
            assert!(parse_jval(bad).is_err(), "{bad} should be rejected");
        }
    }

    #[test]
    fn json_parser_rejects_malformed_input() {
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\":}",
            "{\"a\" 1}",
            "tru",
            "\"unterminated",
            "{\"a\":1} extra",
            "{'a':1}",
        ] {
            assert!(parse_json(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn request_codec_roundtrips() {
        let reqs = [
            Request::Hello { version: 1 },
            Request::Describe,
            Request::Invoke {
                func: "fft-0".into(),
                mode: InvokeMode::Sync,
                deadline_ms: Some(5000),
                push: false,
            },
            Request::Invoke {
                func: "lud-0".into(),
                mode: InvokeMode::Async,
                deadline_ms: None,
                push: false,
            },
            Request::Invoke {
                func: "lud-0".into(),
                mode: InvokeMode::Async,
                deadline_ms: None,
                push: true,
            },
            Request::Wait {
                ticket: Ticket(7),
                deadline_ms: None,
            },
            Request::Poll { ticket: Ticket(8) },
            Request::Stats,
            Request::Metrics {
                format: MetricsFormat::Prom,
            },
            Request::Metrics {
                format: MetricsFormat::Json,
            },
            Request::Trace { max: 512 },
            Request::Drain { shard: 2 },
            Request::Join { shard: 2 },
            Request::Kill { shard: 1 },
            Request::Membership,
            Request::Shutdown,
        ];
        for req in reqs {
            let line = encode_request(&req);
            assert!(!line.contains('\n'));
            assert_eq!(decode_request(&line).unwrap(), req, "{line}");
        }
    }

    #[test]
    fn request_decode_defaults_and_errors() {
        // mode defaults to sync; hello without v means "current".
        assert_eq!(
            decode_request(r#"{"cmd":"invoke","func":"f"}"#).unwrap(),
            Request::Invoke {
                func: "f".into(),
                mode: InvokeMode::Sync,
                deadline_ms: None,
                push: false,
            }
        );
        assert_eq!(
            decode_request(r#"{"cmd":"hello"}"#).unwrap(),
            Request::Hello {
                version: PROTOCOL_VERSION
            }
        );
        for bad in [
            "{not json",
            r#"{"v":1}"#,
            r#"{"cmd":"warp"}"#,
            r#"{"cmd":"invoke"}"#,
            r#"{"cmd":"invoke","func":"f","mode":"batch"}"#,
            r#"{"cmd":"invoke","func":"f","mode":"sync","push":true}"#,
            r#"{"cmd":"invoke","func":"f","push":true}"#,
            r#"{"cmd":"wait"}"#,
            // A present-but-malformed hello version must not silently
            // negotiate to the default.
            r#"{"cmd":"hello","v":"2"}"#,
            r#"{"cmd":"hello","v":1.5}"#,
            r#"{"cmd":"hello","v":-1}"#,
        ] {
            let err = decode_request(bad).unwrap_err();
            assert_eq!(err.code(), "bad-request", "{bad}");
        }
        // Out-of-range versions saturate (rejected by the handshake as
        // "far future") instead of truncating into an accepted version.
        assert_eq!(
            decode_request(r#"{"cmd":"hello","v":4294967297}"#).unwrap(),
            Request::Hello { version: u32::MAX }
        );
        // Malformed \u escapes are decode errors, never panics.
        assert_eq!(
            decode_request("{\"cmd\":\"hello\",\"s\":\"\\u00zz\"}")
                .unwrap_err()
                .code(),
            "bad-request"
        );
        assert_eq!(
            decode_request("{\"cmd\":\"hello\",\"s\":\"\\u000é\"}")
                .unwrap_err()
                .code(),
            "bad-request"
        );
    }

    #[test]
    fn response_codec_roundtrips() {
        let resps = [
            Response::Hello {
                proto: 1,
                server: "rt-server".into(),
            },
            Response::Described(DescribeInfo {
                proto: 1,
                server: "rt-cluster".into(),
                policy: "mqfq-sticky".into(),
                shards: 4,
                router: "sticky-ch".into(),
                functions: vec!["fft-0".into(), "lud-0".into()],
            }),
            Response::Accepted { ticket: Ticket(3) },
            Response::Done(InvokeOutcome {
                ticket: Ticket(3),
                func: "fft-0".into(),
                shard: 2,
                gpu: 1,
                start_kind: StartKind::HostWarm,
                latency_ms: 412.25,
                exec_ms: 9.5,
            }),
            Response::Push(InvokeOutcome {
                ticket: Ticket(5),
                func: "lud-0".into(),
                shard: 0,
                gpu: 0,
                start_kind: StartKind::GpuWarm,
                latency_ms: 3.5,
                exec_ms: 1.25,
            }),
            Response::Pending { ticket: Ticket(4) },
            Response::Stats(StatsSnapshot {
                invocations: 10,
                mean_latency_ms: 51.5,
                cold_ratio: 0.2,
                pending: 1,
                in_flight: 2,
                shards: vec![
                    ShardStatsRow {
                        shard: 0,
                        pending: 1,
                        in_flight: 2,
                        completed: 6,
                        cold_ratio: 0.5,
                        health: ShardHealth::Up,
                        epoch: 0,
                    },
                    ShardStatsRow {
                        shard: 1,
                        pending: 0,
                        in_flight: 0,
                        completed: 4,
                        cold_ratio: 0.0,
                        health: ShardHealth::Dead,
                        epoch: 2,
                    },
                ],
            }),
            Response::Metrics {
                format: MetricsFormat::Prom,
                body: "# TYPE mqfq_completed_total counter\nmqfq_completed_total{shard=\"0\"} 3\n"
                    .into(),
            },
            Response::Trace {
                dropped: 2,
                events: vec![
                    TraceEvent::new(5, EventKind::Submit, 0).inv(9).func(1),
                    TraceEvent {
                        seq: 1,
                        at: 8,
                        kind: EventKind::DTokens,
                        shard: 3,
                        inv: NO_INV,
                        func: NO_FUNC,
                        a: -1,
                        b: 16,
                        c: 0,
                    },
                ],
            },
            Response::Bye,
        ];
        for resp in resps {
            let line = encode_response(&resp);
            assert!(!line.contains('\n'));
            assert_eq!(decode_response(&line).unwrap(), resp, "{line}");
        }
    }

    #[test]
    fn direct_writers_match_tree_rendering_byte_for_byte() {
        // The writer-based encoders replaced `Json::Obj` construction;
        // the wire bytes must not have moved. Rebuild the old trees
        // here and compare.
        let done = Response::Done(InvokeOutcome {
            ticket: Ticket(12),
            func: "fft-0".into(),
            shard: 3,
            gpu: 1,
            start_kind: StartKind::Cold,
            latency_ms: 412.0,
            exec_ms: 9.125,
        });
        let done_tree = Json::Obj(vec![
            ("ok".into(), Json::Bool(true)),
            ("type".into(), Json::str("done")),
            ("ticket".into(), Json::Int(12)),
            ("func".into(), Json::str("fft-0")),
            ("shard".into(), Json::Int(3)),
            ("gpu".into(), Json::Int(1)),
            ("start".into(), Json::str("cold")),
            ("latency_ms".into(), Json::Num(412.0)),
            ("exec_ms".into(), Json::Num(9.125)),
        ]);
        assert_eq!(encode_response(&done), done_tree.render_compact());

        let stats = Response::Stats(StatsSnapshot {
            invocations: 7,
            mean_latency_ms: 3.5,
            cold_ratio: 0.25,
            pending: 2,
            in_flight: 1,
            shards: vec![ShardStatsRow {
                shard: 0,
                pending: 2,
                in_flight: 1,
                completed: 7,
                cold_ratio: 0.25,
                health: ShardHealth::Up,
                epoch: 0,
            }],
        });
        let stats_tree = Json::Obj(vec![
            ("ok".into(), Json::Bool(true)),
            ("type".into(), Json::str("stats")),
            ("invocations".into(), Json::Int(7)),
            ("mean_latency_ms".into(), Json::Num(3.5)),
            ("cold_ratio".into(), Json::Num(0.25)),
            ("pending".into(), Json::Int(2)),
            ("in_flight".into(), Json::Int(1)),
            (
                "shards".into(),
                Json::Arr(vec![Json::Obj(vec![
                    ("shard".into(), Json::Int(0)),
                    ("pending".into(), Json::Int(2)),
                    ("in_flight".into(), Json::Int(1)),
                    ("completed".into(), Json::Int(7)),
                    ("cold_ratio".into(), Json::Num(0.25)),
                    ("state".into(), Json::str("up")),
                    ("epoch".into(), Json::Int(0)),
                ])]),
            ),
        ]);
        assert_eq!(encode_response(&stats), stats_tree.render_compact());

        let err = Response::Error(ApiError::UnknownFunction { name: "gh\"ost".into() });
        let err_tree = Json::Obj(vec![
            ("ok".into(), Json::Bool(false)),
            ("type".into(), Json::str("error")),
            ("error".into(), Json::str("unknown-function")),
            ("detail".into(), Json::str("gh\"ost")),
        ]);
        assert_eq!(encode_response(&err), err_tree.render_compact());

        let req = Request::Invoke {
            func: "fft-0".into(),
            mode: InvokeMode::Sync,
            deadline_ms: Some(5000),
            push: false,
        };
        let req_tree = Json::Obj(vec![
            ("cmd".into(), Json::str("invoke")),
            ("func".into(), Json::str("fft-0")),
            ("mode".into(), Json::str("sync")),
            ("deadline_ms".into(), Json::Int(5000)),
        ]);
        assert_eq!(encode_request(&req), req_tree.render_compact());
    }

    #[test]
    fn membership_response_roundtrips() {
        let m = Response::Membership(MembershipInfo {
            epoch: 3,
            shards: vec![
                ShardInfo {
                    shard: 0,
                    health: ShardHealth::Up,
                    epoch: 0,
                    pending: 2,
                    in_flight: 1,
                    capacity: 1.0,
                },
                ShardInfo {
                    shard: 1,
                    health: ShardHealth::Dead,
                    epoch: 2,
                    pending: 0,
                    in_flight: 0,
                    capacity: 2.5,
                },
            ],
            accepted: 10,
            completed: 7,
            failed: 2,
            rejected: 1,
            stale_drops: 4,
        });
        let line = encode_response(&m);
        assert!(!line.contains('\n'));
        assert_eq!(decode_response(&line).unwrap(), m, "{line}");
        // Admin requests missing their shard are rejected, not defaulted.
        for bad in [r#"{"cmd":"drain"}"#, r#"{"cmd":"kill"}"#, r#"{"cmd":"join"}"#] {
            assert_eq!(decode_request(bad).unwrap_err().code(), "bad-request");
        }
    }

    #[test]
    fn shard_lost_and_evicted_errors_carry_structured_fields() {
        let lost = ApiError::ShardLost {
            shard: 2,
            ticket: Ticket(41),
        };
        let line = encode_response(&Response::Error(lost.clone()));
        let Response::Error(back) = decode_response(&line).unwrap() else {
            panic!("expected error: {line}");
        };
        assert_eq!(back, lost, "{line}");

        for evicted in [false, true] {
            let e = ApiError::UnknownTicket {
                ticket: Ticket(9),
                evicted,
            };
            let line = encode_response(&Response::Error(e.clone()));
            let Response::Error(back) = decode_response(&line).unwrap() else {
                panic!("expected error: {line}");
            };
            assert_eq!(back, e, "{line}");
        }
    }

    #[test]
    fn fault_errors_carry_structured_fields() {
        // The exact-once / breaker / shed errors round-trip their
        // load-bearing fields (not just the code) — clients back off or
        // give up based on them.
        for e in [
            ApiError::ExecFailed {
                ticket: Ticket(31),
                attempts: 4,
            },
            ApiError::Quarantined {
                func: "fft-0".into(),
                retry_after_ms: 2000,
            },
            ApiError::Overloaded {
                pending: 64,
                limit: 32,
                retry_after_ms: 750,
            },
        ] {
            let line = encode_response(&Response::Error(e.clone()));
            let Response::Error(back) = decode_response(&line).unwrap() else {
                panic!("expected error: {line}");
            };
            assert_eq!(back, e, "{line}");
        }
    }

    #[test]
    fn error_responses_roundtrip_their_code() {
        for e in [
            ApiError::UnknownFunction { name: "ghost".into() },
            ApiError::ShuttingDown,
            ApiError::Overloaded {
                pending: 9,
                limit: 8,
                retry_after_ms: 0,
            },
            ApiError::DeadlineExceeded {
                waited_ms: 5,
                ticket: Some(Ticket(12)),
            },
        ] {
            let line = encode_response(&Response::Error(e.clone()));
            let Response::Error(back) = decode_response(&line).unwrap() else {
                panic!("expected error, got {line}");
            };
            assert_eq!(back.code(), e.code());
        }
        // The deadline error's ticket survives the wire: clients can
        // redeem the still-running invocation.
        let line = encode_response(&Response::Error(ApiError::DeadlineExceeded {
            waited_ms: 5,
            ticket: Some(Ticket(12)),
        }));
        let Response::Error(ApiError::DeadlineExceeded {
            ticket: Some(t), ..
        }) = decode_response(&line).unwrap()
        else {
            panic!("ticket lost: {line}");
        };
        assert_eq!(t, Ticket(12));
    }

    #[test]
    fn tagged_codecs_correlate_and_stay_byte_identical_untagged() {
        // Untagged encode is the plain encode, byte for byte.
        let resp = Response::Accepted { ticket: Ticket(9) };
        let mut untagged = String::new();
        encode_response_tagged_into(&resp, None, &mut untagged);
        assert_eq!(untagged, encode_response(&resp));
        // Tagged: the id rides right after the ok flag and round-trips.
        let mut tagged = String::new();
        encode_response_tagged_into(&resp, Some(41), &mut tagged);
        assert!(tagged.starts_with("{\"ok\":true,\"id\":41,"), "{tagged}");
        assert_eq!(decode_response_tagged(&tagged).unwrap(), (Some(41), resp));
        // Errors keep their false prefix in front of the id.
        let err = Response::Error(ApiError::ShuttingDown);
        let mut line = String::new();
        encode_response_tagged_into(&err, Some(7), &mut line);
        assert!(line.starts_with("{\"ok\":false,\"id\":7,"), "{line}");
        assert_eq!(decode_response_tagged(&line).unwrap(), (Some(7), err));
        // Requests: same correlation field, still a decodable request.
        let req = Request::Invoke {
            func: "fft-0".into(),
            mode: InvokeMode::Async,
            deadline_ms: None,
            push: true,
        };
        let mut rline = String::new();
        encode_request_tagged_into(&req, 3, &mut rline);
        assert!(rline.starts_with("{\"id\":3,\"cmd\":\"invoke\""), "{rline}");
        assert_eq!(decode_request(&rline).unwrap(), req);
    }

    /// Minimal deferred-dispatch frontend: one known function whose
    /// submissions never complete on their own (so nothing blocks).
    struct StubFrontend;

    impl Frontend for StubFrontend {
        fn describe(&self) -> DescribeInfo {
            DescribeInfo {
                proto: PROTOCOL_VERSION,
                server: "stub".into(),
                policy: "none".into(),
                shards: 1,
                router: "single".into(),
                functions: vec!["fft-0".into()],
            }
        }

        fn submit(&self, func: &str) -> Result<Ticket, ApiError> {
            if func == "fft-0" {
                Ok(Ticket(77))
            } else {
                Err(ApiError::UnknownFunction { name: func.into() })
            }
        }

        fn wait(
            &self,
            _t: Ticket,
            _d: Option<Duration>,
        ) -> Result<InvokeOutcome, ApiError> {
            unreachable!("deferred dispatch must not block in wait")
        }

        fn poll(&self, t: Ticket) -> Result<Option<InvokeOutcome>, ApiError> {
            Err(ApiError::UnknownTicket {
                ticket: t,
                evicted: false,
            })
        }

        fn stats(&self) -> StatsSnapshot {
            StatsSnapshot::default()
        }

        fn shutdown(&self) {}
    }

    #[test]
    fn deferred_dispatch_never_blocks_and_tags_replies() {
        let f = StubFrontend;
        let mut out = String::new();
        // Sync invoke: no bytes yet, a deferred v1 reply carrying the id.
        let a = handle_line_deferred(&f, r#"{"id":4,"cmd":"invoke","func":"fft-0"}"#, &mut out);
        assert_eq!(
            a,
            LoopAction::AwaitCompletion {
                ticket: Ticket(77),
                deadline: None,
                format: ReplyFormat::V1 { id: Some(4) },
            }
        );
        assert!(out.is_empty(), "{out}");
        // Async + push: Accepted written now, subscription requested.
        let a = handle_line_deferred(
            &f,
            r#"{"id":5,"cmd":"invoke","func":"fft-0","mode":"async","push":true}"#,
            &mut out,
        );
        assert_eq!(
            a,
            LoopAction::Subscribe {
                ticket: Ticket(77),
                id: Some(5),
            }
        );
        assert_eq!(
            decode_response_tagged(&out).unwrap(),
            (Some(5), Response::Accepted { ticket: Ticket(77) })
        );
        // Wait defers too; sync deadline_ms rides along.
        out.clear();
        let a = handle_line_deferred(&f, r#"{"cmd":"wait","ticket":77,"deadline_ms":250}"#, &mut out);
        assert_eq!(
            a,
            LoopAction::AwaitCompletion {
                ticket: Ticket(77),
                deadline: Some(Duration::from_millis(250)),
                format: ReplyFormat::V1 { id: None },
            }
        );
        // Legacy invoke defers in the legacy reply format.
        out.clear();
        let a = handle_line_deferred(&f, "invoke fft-0", &mut out);
        assert_eq!(
            a,
            LoopAction::AwaitCompletion {
                ticket: Ticket(77),
                deadline: None,
                format: ReplyFormat::Legacy,
            }
        );
        assert!(out.is_empty());
        // Immediate verbs answer inline, errors carry the id, quits close.
        out.clear();
        let a = handle_line_deferred(&f, r#"{"id":9,"cmd":"invoke","func":"ghost"}"#, &mut out);
        assert_eq!(a, LoopAction::Replied { close: false });
        let (id, resp) = decode_response_tagged(&out).unwrap();
        assert_eq!(id, Some(9));
        assert!(matches!(
            resp,
            Response::Error(ApiError::UnknownFunction { .. })
        ));
        out.clear();
        assert_eq!(
            handle_line_deferred(&f, r#"{"cmd":"quit"}"#, &mut out),
            LoopAction::Replied { close: true }
        );
        assert_eq!(decode_response(&out).unwrap(), Response::Bye);
        out.clear();
        assert_eq!(
            handle_line_deferred(&f, "quit", &mut out),
            LoopAction::Replied { close: true }
        );
        assert!(out.is_empty(), "legacy quit is silent");
    }

    #[test]
    fn legacy_outcome_encoder_matches_the_blocking_loop() {
        let o = InvokeOutcome {
            ticket: Ticket(1),
            func: "fft-0".into(),
            shard: 0,
            gpu: 2,
            start_kind: StartKind::Cold,
            latency_ms: 412.04,
            exec_ms: 9.16,
        };
        let mut out = String::new();
        encode_legacy_outcome_into(&o, &mut out);
        assert_eq!(out, "ok 412.0 9.2 cold gpu2");
        out.clear();
        encode_legacy_error_into(
            &ApiError::UnknownFunction { name: "x".into() },
            &mut out,
        );
        assert_eq!(out, "err unknown function");
        out.clear();
        encode_legacy_error_into(&ApiError::ShuttingDown, &mut out);
        assert_eq!(out, "err shutting-down");
    }
}
