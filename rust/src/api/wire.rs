//! Protocol v1 framing: one JSON document per line, both directions,
//! with the legacy word protocol (`invoke <fn>` / `stats` / `quit`)
//! kept as aliases on the server side.
//!
//! ```text
//! > {"cmd":"hello","v":1}
//! < {"ok":true,"type":"hello","proto":1,"server":"rt-cluster"}
//! > {"cmd":"invoke","func":"fft-0","mode":"sync","deadline_ms":5000}
//! < {"ok":true,"type":"done","ticket":0,"func":"fft-0","shard":2,
//!    "gpu":0,"start":"cold","latency_ms":412.0,"exec_ms":9.1}
//! > {"cmd":"invoke","func":"fft-0","mode":"async"}
//! < {"ok":true,"type":"ticket","ticket":1}
//! > {"cmd":"poll","ticket":1}
//! < {"ok":true,"type":"pending","ticket":1}
//! > {"cmd":"wait","ticket":1}
//! < {"ok":true,"type":"done", ...}
//! > {"cmd":"stats"}
//! < {"ok":true,"type":"stats","invocations":2, ...}
//! > bogus
//! < {"ok":false,"type":"error","error":"bad-request","detail":"..."}
//! ```
//!
//! A line starting with `{` is a v1 request; anything else is parsed as
//! a legacy command and answered in the legacy `ok ...`/`err ...` line
//! format, so pre-v1 scripts keep working unchanged. The serde-free
//! JSON layer reuses [`crate::util::json::Json`] for encoding and adds
//! the matching parser here.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use super::types::{
    ApiError, DescribeInfo, InvokeMode, InvokeOutcome, Request, Response, StatsSnapshot,
    Ticket, PROTOCOL_VERSION,
};
use super::Frontend;
use crate::types::StartKind;
use crate::util::json::Json;

// ---------------------------------------------------------------------
// JSON parsing (the write side lives in util::json).
// ---------------------------------------------------------------------

/// Parse one JSON document. Integral numbers without exponent/fraction
/// decode as [`Json::Int`]; everything else numeric as [`Json::Num`].
pub fn parse_json(s: &str) -> Result<Json, String> {
    let mut p = Parser {
        b: s.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.i) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.i += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}",
                c as char, self.i
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            None => Err("unexpected end of input".into()),
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected '{}' at byte {}", c as char, self.i)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        let mut float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' | b'-' | b'+' => self.i += 1,
                b'.' | b'e' | b'E' => {
                    float = true;
                    self.i += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        if float {
            text.parse::<f64>()
                .map(Json::Num)
                .map_err(|_| format!("bad number {text}"))
        } else {
            // i64 first (counters, tickets); huge magnitudes fall back
            // to f64 like every other JSON reader.
            match text.parse::<i64>() {
                Ok(i) => Ok(Json::Int(i)),
                Err(_) => text
                    .parse::<f64>()
                    .map(Json::Num)
                    .map_err(|_| format!("bad number {text}")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                return Err("unterminated string".into());
            };
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        return Err("unterminated escape".into());
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pair: a high surrogate must be
                            // followed by \uDC00..DFFF.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo.wrapping_sub(0xDC00) & 0x3FF);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape \\{}", e as char)),
                    }
                }
                _ => {
                    // Re-sync to the char boundary: strings are UTF-8.
                    let s = &self.b[self.i - 1..];
                    let w = utf8_len(c);
                    if s.len() < w {
                        return Err("truncated UTF-8".into());
                    }
                    let chunk = std::str::from_utf8(&s[..w])
                        .map_err(|_| "invalid UTF-8 in string".to_string())?;
                    out.push_str(chunk);
                    self.i += w - 1;
                }
            }
        }
    }

    /// Four hex digits after `\u`. Byte-wise (never `from_utf8`): the
    /// 4-byte window of a malformed escape may clip a multibyte UTF-8
    /// character, which must be a decode error, not a panic.
    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("truncated \\u escape".into());
        }
        let mut v: u32 = 0;
        for k in 0..4 {
            let c = self.b[self.i + k];
            let digit = match c {
                b'0'..=b'9' => c - b'0',
                b'a'..=b'f' => c - b'a' + 10,
                b'A'..=b'F' => c - b'A' + 10,
                _ => return Err(format!("bad \\u escape at byte {}", self.i + k)),
            };
            v = v * 16 + digit as u32;
        }
        self.i += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------
// Accessors over parsed documents.
// ---------------------------------------------------------------------

/// Field lookup on an object (None for non-objects/missing keys).
pub fn get<'a>(v: &'a Json, key: &str) -> Option<&'a Json> {
    match v {
        Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
        _ => None,
    }
}

pub fn get_str<'a>(v: &'a Json, key: &str) -> Option<&'a str> {
    match get(v, key) {
        Some(Json::Str(s)) => Some(s),
        _ => None,
    }
}

pub fn get_u64(v: &Json, key: &str) -> Option<u64> {
    match get(v, key) {
        Some(Json::Int(i)) if *i >= 0 => Some(*i as u64),
        Some(Json::Num(x)) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
        _ => None,
    }
}

pub fn get_f64(v: &Json, key: &str) -> Option<f64> {
    match get(v, key) {
        Some(Json::Int(i)) => Some(*i as f64),
        Some(Json::Num(x)) => Some(*x),
        _ => None,
    }
}

// ---------------------------------------------------------------------
// Request codec.
// ---------------------------------------------------------------------

/// Encode one request as a single wire line (no trailing newline).
pub fn encode_request(req: &Request) -> String {
    let mut f: Vec<(String, Json)> = Vec::new();
    let cmd = |c: &str| ("cmd".to_string(), Json::str(c));
    match req {
        Request::Hello { version } => {
            f.push(cmd("hello"));
            f.push(("v".into(), Json::Int(*version as i64)));
        }
        Request::Describe => f.push(cmd("describe")),
        Request::Invoke {
            func,
            mode,
            deadline_ms,
        } => {
            f.push(cmd("invoke"));
            f.push(("func".into(), Json::str(func.clone())));
            f.push(("mode".into(), Json::str(mode.name())));
            if let Some(d) = deadline_ms {
                f.push(("deadline_ms".into(), Json::Int(*d as i64)));
            }
        }
        Request::Wait {
            ticket,
            deadline_ms,
        } => {
            f.push(cmd("wait"));
            f.push(("ticket".into(), Json::Int(ticket.0 as i64)));
            if let Some(d) = deadline_ms {
                f.push(("deadline_ms".into(), Json::Int(*d as i64)));
            }
        }
        Request::Poll { ticket } => {
            f.push(cmd("poll"));
            f.push(("ticket".into(), Json::Int(ticket.0 as i64)));
        }
        Request::Stats => f.push(cmd("stats")),
        Request::Shutdown => f.push(cmd("quit")),
    }
    Json::Obj(f).render_compact()
}

/// Decode one v1 request line (must start with `{`).
pub fn decode_request(line: &str) -> Result<Request, ApiError> {
    let bad = |detail: String| ApiError::BadRequest { detail };
    let v = parse_json(line).map_err(|e| bad(format!("bad JSON: {e}")))?;
    let cmd = get_str(&v, "cmd").ok_or_else(|| bad("missing \"cmd\"".into()))?;
    let ticket = |v: &Json| -> Result<Ticket, ApiError> {
        get_u64(v, "ticket")
            .map(Ticket)
            .ok_or_else(|| bad("missing \"ticket\"".into()))
    };
    Ok(match cmd {
        "hello" => {
            let version = match get(&v, "v") {
                // Absent version ⇒ the client wants whatever is current.
                None => PROTOCOL_VERSION as u64,
                // Present but malformed (string, fractional, negative)
                // must NOT silently negotiate to the default.
                Some(_) => get_u64(&v, "v").ok_or_else(|| {
                    bad("hello: \"v\" must be a non-negative integer".into())
                })?,
            };
            Request::Hello {
                // Saturate instead of truncating: 2^32+1 must read as
                // "far future" and be rejected, not wrap to v1.
                version: u32::try_from(version).unwrap_or(u32::MAX),
            }
        }
        "describe" => Request::Describe,
        "invoke" => {
            let func = get_str(&v, "func")
                .ok_or_else(|| bad("invoke: missing \"func\"".into()))?
                .to_string();
            let mode = match get_str(&v, "mode") {
                None => InvokeMode::Sync,
                Some(m) => InvokeMode::parse(m)
                    .ok_or_else(|| bad(format!("invoke: unknown mode {m}")))?,
            };
            Request::Invoke {
                func,
                mode,
                deadline_ms: get_u64(&v, "deadline_ms"),
            }
        }
        "wait" => Request::Wait {
            ticket: ticket(&v)?,
            deadline_ms: get_u64(&v, "deadline_ms"),
        },
        "poll" => Request::Poll { ticket: ticket(&v)? },
        "stats" => Request::Stats,
        "quit" | "shutdown" => Request::Shutdown,
        other => return Err(bad(format!("unknown command {other}"))),
    })
}

// ---------------------------------------------------------------------
// Response codec.
// ---------------------------------------------------------------------

/// Encode one response as a single wire line (no trailing newline).
pub fn encode_response(resp: &Response) -> String {
    let mut f: Vec<(String, Json)> = vec![(
        "ok".into(),
        Json::Bool(!matches!(resp, Response::Error(_))),
    )];
    let ty = |t: &str| ("type".to_string(), Json::str(t));
    match resp {
        Response::Hello { proto, server } => {
            f.push(ty("hello"));
            f.push(("proto".into(), Json::Int(*proto as i64)));
            f.push(("server".into(), Json::str(server.clone())));
        }
        Response::Described(d) => {
            f.push(ty("describe"));
            f.push(("proto".into(), Json::Int(d.proto as i64)));
            f.push(("server".into(), Json::str(d.server.clone())));
            f.push(("policy".into(), Json::str(d.policy.clone())));
            f.push(("shards".into(), Json::Int(d.shards as i64)));
            f.push(("router".into(), Json::str(d.router.clone())));
            f.push((
                "functions".into(),
                Json::Arr(d.functions.iter().map(|name| Json::str(name.clone())).collect()),
            ));
        }
        Response::Accepted { ticket } => {
            f.push(ty("ticket"));
            f.push(("ticket".into(), Json::Int(ticket.0 as i64)));
        }
        Response::Done(o) => {
            f.push(ty("done"));
            f.push(("ticket".into(), Json::Int(o.ticket.0 as i64)));
            f.push(("func".into(), Json::str(o.func.clone())));
            f.push(("shard".into(), Json::Int(o.shard as i64)));
            f.push(("gpu".into(), Json::Int(o.gpu as i64)));
            f.push(("start".into(), Json::str(o.start_kind.to_string())));
            f.push(("latency_ms".into(), Json::Num(o.latency_ms)));
            f.push(("exec_ms".into(), Json::Num(o.exec_ms)));
        }
        Response::Pending { ticket } => {
            f.push(ty("pending"));
            f.push(("ticket".into(), Json::Int(ticket.0 as i64)));
        }
        Response::Stats(s) => {
            f.push(ty("stats"));
            f.push(("invocations".into(), Json::Int(s.invocations as i64)));
            f.push(("mean_latency_ms".into(), Json::Num(s.mean_latency_ms)));
            f.push(("cold_ratio".into(), Json::Num(s.cold_ratio)));
            f.push(("pending".into(), Json::Int(s.pending as i64)));
            f.push(("in_flight".into(), Json::Int(s.in_flight as i64)));
        }
        Response::Bye => f.push(ty("bye")),
        Response::Error(e) => {
            f.push(ty("error"));
            f.push(("error".into(), Json::str(e.code())));
            f.push(("detail".into(), Json::str(e.detail())));
            // Deadline-tripped work keeps running: surface its ticket
            // as a structured field so clients can redeem it later.
            if let ApiError::DeadlineExceeded {
                ticket: Some(t), ..
            } = e
            {
                f.push(("ticket".into(), Json::Int(t.0 as i64)));
            }
        }
    }
    Json::Obj(f).render_compact()
}

/// Decode one response line (client side).
pub fn decode_response(line: &str) -> Result<Response, String> {
    let v = parse_json(line)?;
    if let Some(Json::Bool(false)) = get(&v, "ok") {
        let code = get_str(&v, "error").unwrap_or("bad-request");
        let detail = get_str(&v, "detail").unwrap_or("");
        let mut err = ApiError::from_wire(code, detail);
        // Structured extra: the still-running invocation's ticket.
        if let ApiError::DeadlineExceeded { ticket, .. } = &mut err {
            *ticket = get_u64(&v, "ticket").map(Ticket);
        }
        return Ok(Response::Error(err));
    }
    let ty = get_str(&v, "type").ok_or("missing \"type\"")?;
    let ticket = |v: &Json| get_u64(v, "ticket").map(Ticket).ok_or("missing \"ticket\"");
    Ok(match ty {
        "hello" => Response::Hello {
            proto: get_u64(&v, "proto").ok_or("missing \"proto\"")? as u32,
            server: get_str(&v, "server").unwrap_or("").to_string(),
        },
        "describe" => Response::Described(DescribeInfo {
            proto: get_u64(&v, "proto").ok_or("missing \"proto\"")? as u32,
            server: get_str(&v, "server").unwrap_or("").to_string(),
            policy: get_str(&v, "policy").unwrap_or("").to_string(),
            shards: get_u64(&v, "shards").unwrap_or(1) as usize,
            router: get_str(&v, "router").unwrap_or("").to_string(),
            functions: match get(&v, "functions") {
                Some(Json::Arr(xs)) => xs
                    .iter()
                    .filter_map(|x| match x {
                        Json::Str(s) => Some(s.clone()),
                        _ => None,
                    })
                    .collect(),
                _ => Vec::new(),
            },
        }),
        "ticket" => Response::Accepted { ticket: ticket(&v)? },
        "done" => Response::Done(InvokeOutcome {
            ticket: ticket(&v)?,
            func: get_str(&v, "func").unwrap_or("").to_string(),
            shard: get_u64(&v, "shard").unwrap_or(0) as usize,
            gpu: get_u64(&v, "gpu").unwrap_or(0) as u32,
            start_kind: get_str(&v, "start")
                .and_then(StartKind::parse)
                .ok_or("bad \"start\"")?,
            latency_ms: get_f64(&v, "latency_ms").ok_or("missing \"latency_ms\"")?,
            exec_ms: get_f64(&v, "exec_ms").unwrap_or(0.0),
        }),
        "pending" => Response::Pending { ticket: ticket(&v)? },
        "stats" => Response::Stats(StatsSnapshot {
            invocations: get_u64(&v, "invocations").unwrap_or(0) as usize,
            mean_latency_ms: get_f64(&v, "mean_latency_ms").unwrap_or(0.0),
            cold_ratio: get_f64(&v, "cold_ratio").unwrap_or(0.0),
            pending: get_u64(&v, "pending").unwrap_or(0) as usize,
            in_flight: get_u64(&v, "in_flight").unwrap_or(0) as usize,
        }),
        "bye" => Response::Bye,
        other => return Err(format!("unknown response type {other}")),
    })
}

// ---------------------------------------------------------------------
// Connection loop: v1 lines + legacy aliases over one Frontend.
// ---------------------------------------------------------------------

/// Serve one TCP connection over `frontend` until the client quits or
/// the stream errors. Shared by [`crate::server::RtServer`] and
/// [`crate::server::RtCluster`] — the protocol never sees which one it
/// is talking to, only the [`Frontend`] contract.
pub fn serve_connection(frontend: &dyn Frontend, stream: TcpStream) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (reply, close) = if line.starts_with('{') {
            handle_v1(frontend, line)
        } else {
            handle_legacy(frontend, line)
        };
        if let Some(reply) = reply {
            if writer.write_all((reply + "\n").as_bytes()).is_err() {
                break;
            }
        }
        if close {
            break;
        }
    }
}

/// Deadline option → `Duration` (ms granularity, as on the wire).
fn deadline(ms: Option<u64>) -> Option<Duration> {
    ms.map(Duration::from_millis)
}

fn handle_v1(frontend: &dyn Frontend, line: &str) -> (Option<String>, bool) {
    let resp = match decode_request(line) {
        Err(e) => Response::Error(e),
        Ok(req) => match req {
            Request::Hello { version } => {
                if version == 0 || version > PROTOCOL_VERSION {
                    Response::Error(ApiError::UnsupportedVersion {
                        requested: version,
                        supported: PROTOCOL_VERSION,
                    })
                } else {
                    Response::Hello {
                        proto: version,
                        server: frontend.describe().server,
                    }
                }
            }
            Request::Describe => Response::Described(frontend.describe()),
            Request::Invoke {
                func,
                mode,
                deadline_ms,
            } => match frontend.submit(&func) {
                Err(e) => Response::Error(e),
                Ok(ticket) => match mode {
                    InvokeMode::Async => Response::Accepted { ticket },
                    InvokeMode::Sync => {
                        match frontend.wait(ticket, deadline(deadline_ms)) {
                            Ok(o) => Response::Done(o),
                            Err(e) => Response::Error(e),
                        }
                    }
                },
            },
            Request::Wait {
                ticket,
                deadline_ms,
            } => match frontend.wait(ticket, deadline(deadline_ms)) {
                Ok(o) => Response::Done(o),
                Err(e) => Response::Error(e),
            },
            Request::Poll { ticket } => match frontend.poll(ticket) {
                Ok(Some(o)) => Response::Done(o),
                Ok(None) => Response::Pending { ticket },
                Err(e) => Response::Error(e),
            },
            Request::Stats => Response::Stats(frontend.stats()),
            Request::Shutdown => {
                return (Some(encode_response(&Response::Bye)), true)
            }
        },
    };
    (Some(encode_response(&resp)), false)
}

/// Legacy aliases: the pre-v1 word protocol, answered in its original
/// reply format (scripts from before the redesign keep working).
fn handle_legacy(frontend: &dyn Frontend, line: &str) -> (Option<String>, bool) {
    let mut parts = line.split_whitespace();
    let reply = match parts.next() {
        Some("invoke") => match parts.next() {
            None => "err unknown function".to_string(),
            Some(name) => match frontend.invoke(name, None) {
                Ok(o) => format!(
                    "ok {:.1} {:.1} {} gpu{}",
                    o.latency_ms, o.exec_ms, o.start_kind, o.gpu
                ),
                Err(ApiError::UnknownFunction { .. }) => "err unknown function".into(),
                Err(e) => format!("err {}", e.code()),
            },
        },
        Some("stats") => {
            let s = frontend.stats();
            format!(
                "ok invocations={} mean_latency_ms={:.1} cold_ratio={:.3}",
                s.invocations, s.mean_latency_ms, s.cold_ratio
            )
        }
        Some("quit") | None => return (None, true),
        Some(other) => format!("err unknown command {other}"),
    };
    (Some(reply), false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_parser_roundtrips_rendered_documents() {
        let doc = Json::Obj(vec![
            ("s".into(), Json::str("a\"b\\c\nd — ü")),
            ("i".into(), Json::Int(-42)),
            ("x".into(), Json::Num(1.5)),
            ("b".into(), Json::Bool(true)),
            ("n".into(), Json::Null),
            (
                "arr".into(),
                Json::Arr(vec![Json::Int(1), Json::str("two"), Json::Null]),
            ),
            ("obj".into(), Json::Obj(vec![("k".into(), Json::Int(7))])),
            ("empty".into(), Json::Arr(vec![])),
        ]);
        for text in [doc.render(), doc.render_compact()] {
            let back = parse_json(&text).unwrap();
            assert_eq!(get_str(&back, "s"), Some("a\"b\\c\nd — ü"));
            assert_eq!(get_u64(&back, "i"), None); // negative
            assert_eq!(get_f64(&back, "i"), Some(-42.0));
            assert_eq!(get_f64(&back, "x"), Some(1.5));
            assert!(matches!(get(&back, "b"), Some(Json::Bool(true))));
            assert!(matches!(get(&back, "n"), Some(Json::Null)));
            let Some(Json::Arr(xs)) = get(&back, "arr") else {
                panic!("arr")
            };
            assert_eq!(xs.len(), 3);
            assert_eq!(get_u64(get(&back, "obj").unwrap(), "k"), Some(7));
        }
    }

    #[test]
    fn json_parser_handles_escapes_and_unicode() {
        let v = parse_json(r#"{"u":"é€","sp":"😀","t":"\t"}"#).unwrap();
        assert_eq!(get_str(&v, "u"), Some("é€"));
        assert_eq!(get_str(&v, "sp"), Some("😀"));
        assert_eq!(get_str(&v, "t"), Some("\t"));
    }

    #[test]
    fn json_parser_rejects_malformed_input() {
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\":}",
            "{\"a\" 1}",
            "tru",
            "\"unterminated",
            "{\"a\":1} extra",
            "{'a':1}",
        ] {
            assert!(parse_json(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn request_codec_roundtrips() {
        let reqs = [
            Request::Hello { version: 1 },
            Request::Describe,
            Request::Invoke {
                func: "fft-0".into(),
                mode: InvokeMode::Sync,
                deadline_ms: Some(5000),
            },
            Request::Invoke {
                func: "lud-0".into(),
                mode: InvokeMode::Async,
                deadline_ms: None,
            },
            Request::Wait {
                ticket: Ticket(7),
                deadline_ms: None,
            },
            Request::Poll { ticket: Ticket(8) },
            Request::Stats,
            Request::Shutdown,
        ];
        for req in reqs {
            let line = encode_request(&req);
            assert!(!line.contains('\n'));
            assert_eq!(decode_request(&line).unwrap(), req, "{line}");
        }
    }

    #[test]
    fn request_decode_defaults_and_errors() {
        // mode defaults to sync; hello without v means "current".
        assert_eq!(
            decode_request(r#"{"cmd":"invoke","func":"f"}"#).unwrap(),
            Request::Invoke {
                func: "f".into(),
                mode: InvokeMode::Sync,
                deadline_ms: None
            }
        );
        assert_eq!(
            decode_request(r#"{"cmd":"hello"}"#).unwrap(),
            Request::Hello {
                version: PROTOCOL_VERSION
            }
        );
        for bad in [
            "{not json",
            r#"{"v":1}"#,
            r#"{"cmd":"warp"}"#,
            r#"{"cmd":"invoke"}"#,
            r#"{"cmd":"invoke","func":"f","mode":"batch"}"#,
            r#"{"cmd":"wait"}"#,
            // A present-but-malformed hello version must not silently
            // negotiate to the default.
            r#"{"cmd":"hello","v":"2"}"#,
            r#"{"cmd":"hello","v":1.5}"#,
            r#"{"cmd":"hello","v":-1}"#,
        ] {
            let err = decode_request(bad).unwrap_err();
            assert_eq!(err.code(), "bad-request", "{bad}");
        }
        // Out-of-range versions saturate (rejected by the handshake as
        // "far future") instead of truncating into an accepted version.
        assert_eq!(
            decode_request(r#"{"cmd":"hello","v":4294967297}"#).unwrap(),
            Request::Hello { version: u32::MAX }
        );
        // Malformed \u escapes are decode errors, never panics.
        assert_eq!(
            decode_request("{\"cmd\":\"hello\",\"s\":\"\\u00zz\"}")
                .unwrap_err()
                .code(),
            "bad-request"
        );
        assert_eq!(
            decode_request("{\"cmd\":\"hello\",\"s\":\"\\u000é\"}")
                .unwrap_err()
                .code(),
            "bad-request"
        );
    }

    #[test]
    fn response_codec_roundtrips() {
        let resps = [
            Response::Hello {
                proto: 1,
                server: "rt-server".into(),
            },
            Response::Described(DescribeInfo {
                proto: 1,
                server: "rt-cluster".into(),
                policy: "mqfq-sticky".into(),
                shards: 4,
                router: "sticky-ch".into(),
                functions: vec!["fft-0".into(), "lud-0".into()],
            }),
            Response::Accepted { ticket: Ticket(3) },
            Response::Done(InvokeOutcome {
                ticket: Ticket(3),
                func: "fft-0".into(),
                shard: 2,
                gpu: 1,
                start_kind: StartKind::HostWarm,
                latency_ms: 412.25,
                exec_ms: 9.5,
            }),
            Response::Pending { ticket: Ticket(4) },
            Response::Stats(StatsSnapshot {
                invocations: 10,
                mean_latency_ms: 51.5,
                cold_ratio: 0.2,
                pending: 1,
                in_flight: 2,
            }),
            Response::Bye,
        ];
        for resp in resps {
            let line = encode_response(&resp);
            assert!(!line.contains('\n'));
            assert_eq!(decode_response(&line).unwrap(), resp, "{line}");
        }
    }

    #[test]
    fn error_responses_roundtrip_their_code() {
        for e in [
            ApiError::UnknownFunction { name: "ghost".into() },
            ApiError::ShuttingDown,
            ApiError::Overloaded {
                pending: 9,
                limit: 8,
            },
            ApiError::DeadlineExceeded {
                waited_ms: 5,
                ticket: Some(Ticket(12)),
            },
        ] {
            let line = encode_response(&Response::Error(e.clone()));
            let Response::Error(back) = decode_response(&line).unwrap() else {
                panic!("expected error, got {line}");
            };
            assert_eq!(back.code(), e.code());
        }
        // The deadline error's ticket survives the wire: clients can
        // redeem the still-running invocation.
        let line = encode_response(&Response::Error(ApiError::DeadlineExceeded {
            waited_ms: 5,
            ticket: Some(Ticket(12)),
        }));
        let Response::Error(ApiError::DeadlineExceeded {
            ticket: Some(t), ..
        }) = decode_response(&line).unwrap()
        else {
            panic!("ticket lost: {line}");
        };
        assert_eq!(t, Ticket(12));
    }
}
