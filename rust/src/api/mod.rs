//! The serving API: a versioned, typed wire protocol and the
//! [`Frontend`] contract every real-traffic server implements.
//!
//! The paper's system is a *serving* system — MQFQ-Sticky schedules
//! live invocations arriving over RPC — so the serving surface is a
//! first-class, versioned API rather than an ad-hoc debug socket,
//! following the front-end/backend split of OpenWhisk-style FaaS
//! stacks:
//!
//! * [`types`] — protocol v1 vocabulary: [`types::Request`] /
//!   [`types::Response`] enums, async [`types::Ticket`]s, per-request
//!   deadlines, and the structured [`types::ApiError`] taxonomy.
//! * [`wire`] — JSON-lines framing with a `hello` version handshake;
//!   the pre-v1 word protocol (`invoke <fn>`/`stats`/`quit`) is kept
//!   as legacy aliases.
//! * [`client`] — blocking Rust client ([`client::ApiClient`]) used by
//!   the CLI `invoke` subcommand, the examples, and the conformance
//!   tests.
//! * [`Frontend`] — the server-side contract, implemented by the
//!   single-plane [`crate::server::RtServer`] and the sharded
//!   [`crate::server::RtCluster`]; [`wire::serve_connection`] speaks
//!   the protocol over any of them.

pub mod client;
pub mod types;
pub mod wire;

pub use client::{ApiClient, RetryPolicy};
pub use types::{
    ApiError, DescribeInfo, InvokeMode, InvokeOutcome, MembershipInfo, MetricsFormat, Request,
    Response, ShardHealth, ShardInfo, ShardStatsRow, StatsSnapshot, Ticket, PROTOCOL_VERSION,
};

use std::sync::Arc;
use std::time::Duration;

/// Where a subscribed ticket's completion is delivered. Implemented by
/// the event loop's completion bus; called from executor threads at
/// ticket-resolution time, so implementations must be cheap and
/// nonblocking (the bus is a short mutex push + eventfd kick).
///
/// `conn`/`tag` are opaque subscriber-chosen routing words (the loop
/// packs a generation-stamped connection token and a per-connection
/// reply tag); the sink echoes them back so the subscriber can route
/// the completion without a lookup.
pub trait CompletionSink: Send + Sync {
    fn complete(
        &self,
        conn: u64,
        tag: u64,
        ticket: Ticket,
        result: Result<InvokeOutcome, ApiError>,
    );
}

/// A serving frontend: submit work, redeem tickets, observe stats.
///
/// Submission and retrieval are decoupled so one contract covers both
/// invoke modes: a sync invoke is `submit` + `wait` on the server side
/// of one request, an async invoke returns the [`Ticket`] to the client
/// and lets it `wait`/`poll` later (possibly on another connection —
/// tickets are frontend-scoped, not connection-scoped).
///
/// Implementations are shared-state handles (`&self` everywhere) so one
/// frontend serves many connections concurrently.
pub trait Frontend: Send + Sync {
    /// What this frontend is and what it serves.
    fn describe(&self) -> DescribeInfo;

    /// Admit one invocation of the named function. Errors are the
    /// admission taxonomy: [`ApiError::UnknownFunction`],
    /// [`ApiError::Overloaded`] (backpressure), [`ApiError::ShuttingDown`].
    fn submit(&self, func: &str) -> Result<Ticket, ApiError>;

    /// Block until the ticket's invocation completes. A `deadline`
    /// bounds the wait ([`ApiError::DeadlineExceeded`] on expiry — the
    /// invocation itself runs to completion and can be waited again).
    /// Completed tickets are reclaimed on delivery: every waiter
    /// blocked at completion time is served, after which the ticket is
    /// forgotten and further waits return [`ApiError::UnknownTicket`].
    fn wait(&self, ticket: Ticket, deadline: Option<Duration>) -> Result<InvokeOutcome, ApiError>;

    /// Non-blocking check: `Ok(Some)` consumes the ticket (same
    /// reclamation rule as [`Self::wait`]), `Ok(None)` means still
    /// running.
    fn poll(&self, ticket: Ticket) -> Result<Option<InvokeOutcome>, ApiError>;

    /// Aggregate serving stats across all shards.
    fn stats(&self) -> StatsSnapshot;

    /// Stop admitting work ([`Self::submit`] returns
    /// [`ApiError::ShuttingDown`]) and wind down background threads.
    /// In-flight invocations run to completion.
    fn shutdown(&self);

    /// Sync convenience: submit and wait in one call.
    fn invoke(&self, func: &str, deadline: Option<Duration>) -> Result<InvokeOutcome, ApiError> {
        let ticket = self.submit(func)?;
        self.wait(ticket, deadline)
    }

    /// Register a completion subscription: when `ticket` resolves,
    /// deliver the outcome to `sink` (echoing the opaque `conn`/`tag`
    /// routing words) instead of blocking a thread in [`Self::wait`].
    /// An already-resolved ticket is delivered immediately *without*
    /// claiming it — the claim happens on the subscriber's side once
    /// the reply actually reaches a live connection, preserving the
    /// redeem-after-deadline and redeem-after-disconnect guarantees.
    ///
    /// Default rejects: a frontend without push support (e.g. a test
    /// mock) makes subscription a client error, not a panic.
    fn subscribe(
        &self,
        _ticket: Ticket,
        _sink: Arc<dyn CompletionSink>,
        _conn: u64,
        _tag: u64,
    ) -> Result<(), ApiError> {
        Err(ApiError::BadRequest {
            detail: "this frontend does not support push completions".into(),
        })
    }

    // --- elastic membership (admin verbs) ---------------------------
    //
    // Default implementations reject: a frontend without dynamic
    // membership (e.g. a test mock) is a fixed fleet, and admin verbs
    // against it are a client error, not a panic.

    /// Stop routing new work to `shard`; in-flight work finishes.
    fn drain(&self, _shard: usize) -> Result<MembershipInfo, ApiError> {
        Err(ApiError::BadRequest {
            detail: "this frontend does not support membership changes".into(),
        })
    }

    /// (Re)insert `shard` into the routable set.
    fn join(&self, _shard: usize) -> Result<MembershipInfo, ApiError> {
        Err(ApiError::BadRequest {
            detail: "this frontend does not support membership changes".into(),
        })
    }

    /// Abrupt shard failure: every ticket homed on `shard` resolves to
    /// [`ApiError::ShardLost`] immediately; the routing ring heals.
    fn kill(&self, _shard: usize) -> Result<MembershipInfo, ApiError> {
        Err(ApiError::BadRequest {
            detail: "this frontend does not support membership changes".into(),
        })
    }

    /// Membership snapshot: per-shard health/epoch + conservation
    /// counters.
    fn membership(&self) -> Result<MembershipInfo, ApiError> {
        Err(ApiError::BadRequest {
            detail: "this frontend does not support membership changes".into(),
        })
    }

    // --- telemetry (observability verbs) -----------------------------
    //
    // Default implementations reject: a frontend without an attached
    // telemetry subsystem has nothing to export, and asking it is a
    // client error, not a panic.

    /// Render the metrics registry in the requested format.
    fn metrics(&self, _format: MetricsFormat) -> Result<String, ApiError> {
        Err(ApiError::BadRequest {
            detail: "this frontend does not export telemetry".into(),
        })
    }

    /// Drain up to `max` lifecycle events from the trace ring, plus the
    /// ring's cumulative overflow-drop counter.
    fn trace(&self, _max: usize) -> Result<(u64, Vec<crate::telemetry::TraceEvent>), ApiError> {
        Err(ApiError::BadRequest {
            detail: "this frontend does not export telemetry".into(),
        })
    }
}
