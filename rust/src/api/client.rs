//! Blocking protocol-v1 client: one TCP connection, JSON-lines framing,
//! `hello` handshake on connect. Used by the CLI `invoke` subcommand,
//! `examples/e2e_serving.rs`, and the wire-protocol conformance tests.
//!
//! Optional bounded retry ([`RetryPolicy`], off by default): transient
//! failures — `overloaded` backpressure and transport errors — are
//! retried with jittered exponential backoff; an I/O failure
//! reconnects and re-handshakes before the resend. What may be resent
//! depends on the verb: *idempotent* reads (`describe`, `stats`,
//! `membership`, `poll`, `metrics`) retry both backpressure and
//! transport faults, while `invoke` retries backpressure only — an
//! `overloaded` reply proves the server refused the work, but a dead
//! connection proves nothing (the first copy may already be running,
//! and a blind resend would double-invoke). Non-transient errors
//! (unknown function, shard lost, quarantined, bad request, ...) are
//! never retried: they are answers, not weather.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::types::{
    ApiError, DescribeInfo, InvokeMode, InvokeOutcome, MembershipInfo, MetricsFormat, Request,
    Response, StatsSnapshot, Ticket, PROTOCOL_VERSION,
};
use super::wire;
use crate::util::rng::Rng;

/// Bounded-retry policy for transient errors ([`ApiError::Overloaded`],
/// [`ApiError::Io`]). Delay for retry *k* (0-based) is drawn uniformly
/// from `[d/2, d]` with `d = min(base · 2^k, max)` — exponential
/// backoff with jitter, so a herd of clients bounced by the same
/// overload spike does not re-arrive in lockstep.
///
/// The default policy is **off** (`attempts == 0`): retrying a submit
/// over a dropped connection can double-invoke (the server may have
/// accepted the first copy before the transport died), so opting in is
/// the caller's statement that its traffic tolerates that.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Retries after the initial attempt; 0 disables retrying.
    pub attempts: u32,
    /// First backoff delay (doubled each retry).
    pub base_delay: Duration,
    /// Backoff ceiling.
    pub max_delay: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self::off()
    }
}

impl RetryPolicy {
    /// No retrying: the first error is the answer.
    pub fn off() -> Self {
        Self {
            attempts: 0,
            base_delay: Duration::from_millis(10),
            max_delay: Duration::from_secs(1),
        }
    }

    /// Retry transient errors up to `attempts` times with the default
    /// 10 ms base / 1 s cap backoff.
    pub fn new(attempts: u32) -> Self {
        Self {
            attempts,
            ..Self::off()
        }
    }

    /// Is this error worth retrying *at all*? Backpressure and
    /// transport faults are transient; everything else — including
    /// `quarantined` (the server told you to stay away) and
    /// `shard-lost` (the work is gone; resubmitting is the caller's
    /// decision) — is a real answer. Whether a transient `io` may
    /// actually be retried additionally depends on the verb's
    /// idempotency; see [`ApiClient`]'s call paths.
    pub fn transient(e: &ApiError) -> bool {
        matches!(e, ApiError::Overloaded { .. } | ApiError::Io { .. })
    }

    /// Jittered backoff before retry `attempt` (0-based): uniform in
    /// `[d/2, d]`, `d = min(base · 2^attempt, max)`.
    fn backoff(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let exp = self
            .base_delay
            .saturating_mul(2u32.saturating_pow(attempt.min(20)));
        let d = exp.min(self.max_delay);
        Duration::from_secs_f64(rng.range(d.as_secs_f64() / 2.0, d.as_secs_f64()))
    }
}

/// A connected, version-negotiated client. The classic surface is
/// lockstep — one request in flight at a time — and stays byte-
/// identical on the wire. Against an event-loop server the client can
/// additionally *pipeline* ([`Self::pipeline_invoke_async`]: many
/// tagged requests, one flush, out-of-order tagged replies) and
/// subscribe to *push* completions ([`Self::invoke_push`] +
/// [`Self::wait_push`]: the server sends the completion unsolicited,
/// no polling round trips). Unsolicited push lines that interleave
/// with other replies are parked internally until asked for.
///
/// The request and reply line buffers live for the whole connection,
/// so a tight invoke loop (the serving load generator, the CLI `--n`
/// client) does not allocate per round trip on the wire path.
pub struct ApiClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    proto: u32,
    /// Reused request-line buffer (encoded request + trailing newline).
    wbuf: String,
    /// Reused reply-line buffer.
    rbuf: String,
    /// Push completions that arrived interleaved with other replies,
    /// parked until their [`Self::wait_push`].
    pushed: Vec<InvokeOutcome>,
    /// Transient-error retry policy; [`RetryPolicy::off`] by default.
    retry: RetryPolicy,
    /// Remembered peer for reconnect-on-I/O-failure retries.
    peer: Option<SocketAddr>,
    /// Backoff jitter source (deterministic seed; jitter decorrelates
    /// clients through their independent retry counts and timing, not
    /// through entropy).
    rng: Rng,
}

fn io_err<E: std::fmt::Display>(e: E) -> ApiError {
    ApiError::Io {
        detail: e.to_string(),
    }
}

impl ApiClient {
    /// Connect and negotiate the protocol version (hello handshake).
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Self, ApiError> {
        let stream = TcpStream::connect(addr).map_err(io_err)?;
        let peer = stream.peer_addr().ok();
        let writer = stream.try_clone().map_err(io_err)?;
        let seed = 0x9E37_79B9_7F4A_7C15 ^ peer.map_or(0, |p| p.port() as u64);
        let mut client = Self {
            reader: BufReader::new(stream),
            writer,
            proto: 0,
            wbuf: String::with_capacity(128),
            rbuf: String::with_capacity(256),
            pushed: Vec::new(),
            retry: RetryPolicy::off(),
            peer,
            rng: Rng::new(seed),
        };
        match client.call_once(&Request::Hello {
            version: PROTOCOL_VERSION,
        })? {
            Response::Hello { proto, .. } => {
                client.proto = proto;
                Ok(client)
            }
            other => Err(unexpected("hello", &other)),
        }
    }

    /// Negotiated protocol version.
    pub fn proto(&self) -> u32 {
        self.proto
    }

    /// Opt into bounded transient-error retries (see [`RetryPolicy`]).
    pub fn set_retry(&mut self, policy: RetryPolicy) {
        self.retry = policy;
    }

    /// Bound how long any single reply may take (e.g. sync invokes on a
    /// loaded server). `None` restores fully blocking reads.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> Result<(), ApiError> {
        self.writer.set_read_timeout(timeout).map_err(io_err)
    }

    /// One round trip under the retry policy for a **non-idempotent**
    /// verb (submits): only `overloaded` — which proves the server
    /// refused the work — is retried. A transport fault is surfaced
    /// immediately: the request may already have been accepted, and a
    /// blind resend would double-invoke.
    fn call(&mut self, req: &Request) -> Result<Response, ApiError> {
        self.call_with(req, false)
    }

    /// One round trip under the retry policy for an **idempotent**
    /// verb (`describe`, `stats`, `membership`, `poll`, `metrics`):
    /// both backpressure and transport faults back off and retry up to
    /// `retry.attempts` times; an I/O failure reconnects first.
    fn call_idempotent(&mut self, req: &Request) -> Result<Response, ApiError> {
        self.call_with(req, true)
    }

    fn call_with(&mut self, req: &Request, idempotent: bool) -> Result<Response, ApiError> {
        let mut attempt = 0;
        loop {
            let err = match self.call_once(req) {
                Ok(resp) => return Ok(resp),
                Err(e) => e,
            };
            let retryable = match &err {
                // Backpressure / shed: refused before any state change.
                ApiError::Overloaded { .. } => true,
                // Transport fault: resend only when a duplicate is
                // harmless.
                ApiError::Io { .. } => idempotent,
                // Everything else — `quarantined`, `shard-lost`,
                // `exec-failed`, ... — is an answer, never retried.
                _ => false,
            };
            if attempt >= self.retry.attempts || !retryable {
                return Err(err);
            }
            std::thread::sleep(self.retry.backoff(attempt, &mut self.rng));
            if matches!(err, ApiError::Io { .. }) {
                // The connection is gone; a resend needs a fresh one.
                // A failed reconnect is itself the (transport) answer.
                self.reconnect()?;
            }
            attempt += 1;
        }
    }

    /// Reconnect to the remembered peer and redo the hello handshake.
    fn reconnect(&mut self) -> Result<(), ApiError> {
        let Some(peer) = self.peer else {
            return Err(ApiError::Io {
                detail: "no remembered peer address to reconnect to".into(),
            });
        };
        let stream = TcpStream::connect(peer).map_err(io_err)?;
        let writer = stream.try_clone().map_err(io_err)?;
        self.reader = BufReader::new(stream);
        self.writer = writer;
        // Old-connection subscriptions died with the socket; parked
        // pushes from it would otherwise satisfy a new wait_push.
        self.pushed.clear();
        match self.call_once(&Request::Hello {
            version: PROTOCOL_VERSION,
        })? {
            Response::Hello { proto, .. } => {
                self.proto = proto;
                Ok(())
            }
            other => Err(unexpected("hello", &other)),
        }
    }

    /// One request/reply round trip. Server-side failures come back as
    /// `Err` with the decoded [`ApiError`]; transport failures as
    /// [`ApiError::Io`].
    fn call_once(&mut self, req: &Request) -> Result<Response, ApiError> {
        self.wbuf.clear();
        wire::encode_request_into(req, &mut self.wbuf);
        self.wbuf.push('\n');
        self.writer
            .write_all(self.wbuf.as_bytes())
            .map_err(io_err)?;
        loop {
            match self.read_response()? {
                // Unsolicited push completions may interleave with any
                // reply; park them for wait_push and keep reading.
                Response::Push(o) => self.pushed.push(o),
                Response::Error(e) => return Err(e),
                resp => return Ok(resp),
            }
        }
    }

    /// Read and decode one reply line.
    fn read_response(&mut self) -> Result<Response, ApiError> {
        self.rbuf.clear();
        let n = self.reader.read_line(&mut self.rbuf).map_err(io_err)?;
        if n == 0 {
            return Err(ApiError::Io {
                detail: "server closed the connection".into(),
            });
        }
        wire::decode_response(self.rbuf.trim()).map_err(io_err)
    }

    pub fn describe(&mut self) -> Result<DescribeInfo, ApiError> {
        match self.call_idempotent(&Request::Describe)? {
            Response::Described(d) => Ok(d),
            other => Err(unexpected("describe", &other)),
        }
    }

    /// Sync invoke: blocks until the invocation completes (or the
    /// server-side `deadline_ms` expires).
    pub fn invoke(
        &mut self,
        func: &str,
        deadline_ms: Option<u64>,
    ) -> Result<InvokeOutcome, ApiError> {
        match self.call(&Request::Invoke {
            func: func.to_string(),
            mode: InvokeMode::Sync,
            deadline_ms,
            push: false,
        })? {
            Response::Done(o) => Ok(o),
            other => Err(unexpected("invoke", &other)),
        }
    }

    /// Async invoke: returns the completion ticket immediately.
    pub fn invoke_async(&mut self, func: &str) -> Result<Ticket, ApiError> {
        match self.call(&Request::Invoke {
            func: func.to_string(),
            mode: InvokeMode::Async,
            deadline_ms: None,
            push: false,
        })? {
            Response::Accepted { ticket } => Ok(ticket),
            other => Err(unexpected("invoke async", &other)),
        }
    }

    /// Async invoke with a push subscription (event-loop servers):
    /// the server sends an unsolicited `push` completion on this
    /// connection when the invocation finishes — no polling round
    /// trips. Redeem with [`Self::wait_push`].
    pub fn invoke_push(&mut self, func: &str) -> Result<Ticket, ApiError> {
        match self.call(&Request::Invoke {
            func: func.to_string(),
            mode: InvokeMode::Async,
            deadline_ms: None,
            push: true,
        })? {
            Response::Accepted { ticket } => Ok(ticket),
            other => Err(unexpected("invoke push", &other)),
        }
    }

    /// Block until `ticket`'s push completion arrives. Parked arrivals
    /// (pushes that interleaved with earlier replies) are consumed
    /// first; pushes for *other* tickets encountered while waiting are
    /// parked in turn, so waits may be issued in any order.
    pub fn wait_push(&mut self, ticket: Ticket) -> Result<InvokeOutcome, ApiError> {
        loop {
            if let Some(i) = self.pushed.iter().position(|o| o.ticket == ticket) {
                return Ok(self.pushed.swap_remove(i));
            }
            match self.read_response()? {
                Response::Push(o) => self.pushed.push(o),
                Response::Error(e) => return Err(e),
                other => return Err(unexpected("push", &other)),
            }
        }
    }

    /// Pipelined async submit (event-loop servers): encode every
    /// invoke tagged `"id":0..n` into one buffer, flush once, then
    /// read the tagged replies — which the server may deliver out of
    /// order — and return the tickets in input order. The first
    /// structured error aborts the batch, but only after the batch's
    /// remaining replies are drained, so the connection stays usable.
    pub fn pipeline_invoke_async(&mut self, funcs: &[&str]) -> Result<Vec<Ticket>, ApiError> {
        self.wbuf.clear();
        for (i, func) in funcs.iter().enumerate() {
            let req = Request::Invoke {
                func: func.to_string(),
                mode: InvokeMode::Async,
                deadline_ms: None,
                push: false,
            };
            wire::encode_request_tagged_into(&req, i as u64, &mut self.wbuf);
            self.wbuf.push('\n');
        }
        self.writer
            .write_all(self.wbuf.as_bytes())
            .map_err(io_err)?;
        let mut tickets: Vec<Option<Ticket>> = vec![None; funcs.len()];
        let mut first_err: Option<ApiError> = None;
        let mut seen = 0usize;
        while seen < funcs.len() {
            self.rbuf.clear();
            let n = self.reader.read_line(&mut self.rbuf).map_err(io_err)?;
            if n == 0 {
                return Err(ApiError::Io {
                    detail: "server closed the connection".into(),
                });
            }
            let (id, resp) =
                wire::decode_response_tagged(self.rbuf.trim()).map_err(io_err)?;
            match (id, resp) {
                // Unsolicited pushes may interleave with the batch.
                (_, Response::Push(o)) => self.pushed.push(o),
                (Some(i), Response::Accepted { ticket }) if (i as usize) < funcs.len() => {
                    tickets[i as usize] = Some(ticket);
                    seen += 1;
                }
                (Some(_), Response::Error(e)) => {
                    first_err.get_or_insert(e);
                    seen += 1;
                }
                (_, other) => return Err(unexpected("pipeline", &other)),
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(tickets
            .into_iter()
            .map(|t| t.expect("every batch id answered"))
            .collect())
    }

    /// Redeem a ticket, blocking until completion (optionally bounded).
    pub fn wait(
        &mut self,
        ticket: Ticket,
        deadline_ms: Option<u64>,
    ) -> Result<InvokeOutcome, ApiError> {
        match self.call(&Request::Wait { ticket, deadline_ms })? {
            Response::Done(o) => Ok(o),
            other => Err(unexpected("wait", &other)),
        }
    }

    /// Non-blocking completion check: `Some` consumes the ticket.
    pub fn poll(&mut self, ticket: Ticket) -> Result<Option<InvokeOutcome>, ApiError> {
        match self.call_idempotent(&Request::Poll { ticket })? {
            Response::Done(o) => Ok(Some(o)),
            Response::Pending { .. } => Ok(None),
            other => Err(unexpected("poll", &other)),
        }
    }

    pub fn stats(&mut self) -> Result<StatsSnapshot, ApiError> {
        match self.call_idempotent(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => Err(unexpected("stats", &other)),
        }
    }

    /// Telemetry: the server's metrics registry rendered in `format`
    /// (Prometheus text or the `mqfq-metrics/v1` JSON document).
    pub fn metrics(&mut self, format: MetricsFormat) -> Result<String, ApiError> {
        match self.call_idempotent(&Request::Metrics { format })? {
            Response::Metrics { body, .. } => Ok(body),
            other => Err(unexpected("metrics", &other)),
        }
    }

    /// Telemetry: drain up to `max` lifecycle events from the server's
    /// trace ring. Returns `(dropped, events)` — `dropped` is the
    /// ring's cumulative overflow-drop counter. Consuming: repeated
    /// calls page through the stream.
    pub fn trace(
        &mut self,
        max: usize,
    ) -> Result<(u64, Vec<crate::telemetry::TraceEvent>), ApiError> {
        match self.call(&Request::Trace { max })? {
            Response::Trace { dropped, events } => Ok((dropped, events)),
            other => Err(unexpected("trace", &other)),
        }
    }

    /// Admin: stop routing new work to `shard` (in-flight finishes).
    pub fn drain(&mut self, shard: usize) -> Result<MembershipInfo, ApiError> {
        self.membership_verb(&Request::Drain { shard }, "drain")
    }

    /// Admin: (re)insert `shard` into the routable set.
    pub fn join(&mut self, shard: usize) -> Result<MembershipInfo, ApiError> {
        self.membership_verb(&Request::Join { shard }, "join")
    }

    /// Admin: abrupt shard failure — stranded tickets resolve to
    /// `shard-lost`, the routing ring heals.
    pub fn kill(&mut self, shard: usize) -> Result<MembershipInfo, ApiError> {
        self.membership_verb(&Request::Kill { shard }, "kill")
    }

    /// Admin: per-shard health/epoch snapshot + conservation counters.
    pub fn membership(&mut self) -> Result<MembershipInfo, ApiError> {
        self.membership_verb(&Request::Membership, "membership")
    }

    fn membership_verb(
        &mut self,
        req: &Request,
        what: &str,
    ) -> Result<MembershipInfo, ApiError> {
        // The membership *query* is a pure read; drain/join/kill mutate
        // cluster state and must not be blindly resent over a dead
        // connection.
        let resp = if matches!(req, Request::Membership) {
            self.call_idempotent(req)?
        } else {
            self.call(req)?
        };
        match resp {
            Response::Membership(m) => Ok(m),
            other => Err(unexpected(what, &other)),
        }
    }

    /// Close the connection gracefully (server replies `bye`).
    pub fn quit(mut self) {
        let _ = self.call_once(&Request::Shutdown);
    }
}

fn unexpected(what: &str, got: &Response) -> ApiError {
    ApiError::Io {
        detail: format!("unexpected {what} reply: {got:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::thread;

    /// A deliberately flaky protocol server on a real TCP socket: the
    /// first `overloads` counted requests get an `overloaded` error,
    /// the next `drops` get their connection cut before the reply (the
    /// client sees a transport error), and everything after that
    /// succeeds. Counts every stats/invoke request it sees; invoking
    /// `"poison"` always answers `quarantined`, any other invoke
    /// `bad-request`.
    fn flaky_server(overloads: usize, drops: usize) -> (SocketAddr, Arc<AtomicUsize>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let seen = Arc::new(AtomicUsize::new(0));
        let seen_srv = Arc::clone(&seen);
        thread::spawn(move || {
            let mut overloads = overloads;
            let mut drops = drops;
            for stream in listener.incoming() {
                let Ok(stream) = stream else { break };
                let mut reader = BufReader::new(match stream.try_clone() {
                    Ok(s) => s,
                    Err(_) => continue,
                });
                let mut writer = stream;
                let mut line = String::new();
                'conn: loop {
                    line.clear();
                    if reader.read_line(&mut line).unwrap_or(0) == 0 {
                        break;
                    }
                    let Ok(req) = wire::decode_request(line.trim()) else {
                        break;
                    };
                    let resp = match req {
                        Request::Hello { .. } => Response::Hello {
                            proto: PROTOCOL_VERSION,
                            server: "flaky-mock".to_string(),
                        },
                        Request::Stats | Request::Invoke { .. } => {
                            seen_srv.fetch_add(1, Ordering::SeqCst);
                            if overloads > 0 {
                                overloads -= 1;
                                Response::Error(ApiError::Overloaded {
                                    pending: 9,
                                    limit: 1,
                                    retry_after_ms: 0,
                                })
                            } else if drops > 0 {
                                drops -= 1;
                                // Cut the connection instead of replying.
                                break 'conn;
                            } else {
                                match req {
                                    Request::Stats => Response::Stats(StatsSnapshot::default()),
                                    Request::Invoke { func, .. } if func == "poison" => {
                                        Response::Error(ApiError::Quarantined {
                                            func,
                                            retry_after_ms: 5,
                                        })
                                    }
                                    _ => Response::Error(ApiError::BadRequest {
                                        detail: "mock serves stats only".to_string(),
                                    }),
                                }
                            }
                        }
                        _ => Response::Bye,
                    };
                    let mut out = String::new();
                    wire::encode_response_into(&resp, &mut out);
                    out.push('\n');
                    if writer.write_all(out.as_bytes()).is_err() {
                        break;
                    }
                    if matches!(resp, Response::Bye) {
                        break;
                    }
                }
            }
        });
        (addr, seen)
    }

    #[test]
    fn backoff_is_jittered_exponential_and_capped() {
        let p = RetryPolicy {
            attempts: 8,
            base_delay: Duration::from_millis(10),
            max_delay: Duration::from_millis(60),
        };
        let mut rng = Rng::new(7);
        for attempt in 0..8 {
            let uncapped = 10u64 << attempt; // ms
            let d = p.backoff(attempt, &mut rng).as_secs_f64() * 1e3;
            let ceil = (uncapped as f64).min(60.0);
            assert!(
                d >= ceil / 2.0 - 1e-9 && d <= ceil + 1e-9,
                "attempt {attempt}: {d} ms outside [{}, {ceil}]",
                ceil / 2.0
            );
        }
        // Transience taxonomy: backpressure and transport only. The
        // fault-tolerance errors are answers — never retry fodder.
        assert!(RetryPolicy::transient(&ApiError::Overloaded {
            pending: 1,
            limit: 1,
            retry_after_ms: 0,
        }));
        assert!(RetryPolicy::transient(&ApiError::Io { detail: "x".into() }));
        assert!(!RetryPolicy::transient(&ApiError::ShuttingDown));
        assert!(!RetryPolicy::transient(&ApiError::ShardLost {
            shard: 0,
            ticket: Ticket(1),
        }));
        assert!(!RetryPolicy::transient(&ApiError::Quarantined {
            func: "f".into(),
            retry_after_ms: 100,
        }));
        assert!(!RetryPolicy::transient(&ApiError::ExecFailed {
            ticket: Ticket(2),
            attempts: 3,
        }));
    }

    #[test]
    fn retry_is_off_by_default() {
        let (addr, seen) = flaky_server(2, 0);
        let mut c = ApiClient::connect(addr).unwrap();
        assert_eq!(c.stats().unwrap_err().code(), "overloaded");
        assert_eq!(seen.load(Ordering::SeqCst), 1, "no retry without opt-in");
    }

    #[test]
    fn retry_rides_through_transient_overload() {
        let (addr, seen) = flaky_server(2, 0);
        let mut c = ApiClient::connect(addr).unwrap();
        c.set_retry(RetryPolicy {
            attempts: 3,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(5),
        });
        assert_eq!(c.stats().unwrap(), StatsSnapshot::default());
        assert_eq!(seen.load(Ordering::SeqCst), 3, "two overloads + success");
    }

    #[test]
    fn retry_exhaustion_returns_the_transient_error() {
        let (addr, _seen) = flaky_server(10, 0);
        let mut c = ApiClient::connect(addr).unwrap();
        c.set_retry(RetryPolicy {
            attempts: 2,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(2),
        });
        assert_eq!(c.stats().unwrap_err().code(), "overloaded");
    }

    #[test]
    fn io_failure_reconnects_and_resends() {
        let (addr, seen) = flaky_server(0, 1);
        let mut c = ApiClient::connect(addr).unwrap();
        c.set_retry(RetryPolicy {
            attempts: 2,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(5),
        });
        // First stats gets its connection cut → reconnect + handshake
        // on a fresh connection → resend succeeds.
        assert_eq!(c.stats().unwrap(), StatsSnapshot::default());
        assert_eq!(c.proto(), PROTOCOL_VERSION);
        assert_eq!(seen.load(Ordering::SeqCst), 2, "dropped + resent");
        // Non-transient server answers are never retried.
        assert_eq!(c.invoke("f", None).unwrap_err().code(), "bad-request");
    }

    #[test]
    fn invoke_is_never_resent_over_a_dropped_connection() {
        // A submit whose connection died may already be running on the
        // server: the transport error must surface immediately, with no
        // reconnect-and-resend (which would double-invoke).
        let (addr, seen) = flaky_server(0, 1);
        let mut c = ApiClient::connect(addr).unwrap();
        c.set_retry(RetryPolicy {
            attempts: 3,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(5),
        });
        assert_eq!(c.invoke("f", None).unwrap_err().code(), "io");
        assert_eq!(seen.load(Ordering::SeqCst), 1, "submit must not be resent");
    }

    #[test]
    fn invoke_retries_backpressure_but_quarantine_is_final() {
        let (addr, seen) = flaky_server(2, 0);
        let mut c = ApiClient::connect(addr).unwrap();
        c.set_retry(RetryPolicy {
            attempts: 5,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(5),
        });
        // Two `overloaded` rejections are retried (the server refused
        // the work; resending cannot duplicate it) — then the breaker's
        // answer comes through on the third attempt and is final.
        let err = c.invoke("poison", None).unwrap_err();
        assert_eq!(err.code(), "quarantined");
        assert_eq!(
            seen.load(Ordering::SeqCst),
            3,
            "two overloads retried, quarantine surfaced immediately"
        );
        let ApiError::Quarantined { func, retry_after_ms } = err else {
            panic!("structured quarantine fields lost");
        };
        assert_eq!(func, "poison");
        assert_eq!(retry_after_ms, 5);
    }
}
