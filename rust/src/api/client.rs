//! Blocking protocol-v1 client: one TCP connection, JSON-lines framing,
//! `hello` handshake on connect. Used by the CLI `invoke` subcommand,
//! `examples/e2e_serving.rs`, and the wire-protocol conformance tests.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::types::{
    ApiError, DescribeInfo, InvokeMode, InvokeOutcome, Request, Response, StatsSnapshot,
    Ticket, PROTOCOL_VERSION,
};
use super::wire;

/// A connected, version-negotiated client. One request in flight at a
/// time (the protocol is strictly request/reply per connection); async
/// concurrency comes from tickets, not pipelining.
///
/// The request and reply line buffers live for the whole connection,
/// so a tight invoke loop (the serving load generator, the CLI `--n`
/// client) does not allocate per round trip on the wire path.
pub struct ApiClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    proto: u32,
    /// Reused request-line buffer (encoded request + trailing newline).
    wbuf: String,
    /// Reused reply-line buffer.
    rbuf: String,
}

fn io_err<E: std::fmt::Display>(e: E) -> ApiError {
    ApiError::Io {
        detail: e.to_string(),
    }
}

impl ApiClient {
    /// Connect and negotiate the protocol version (hello handshake).
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Self, ApiError> {
        let stream = TcpStream::connect(addr).map_err(io_err)?;
        let writer = stream.try_clone().map_err(io_err)?;
        let mut client = Self {
            reader: BufReader::new(stream),
            writer,
            proto: 0,
            wbuf: String::with_capacity(128),
            rbuf: String::with_capacity(256),
        };
        match client.call(&Request::Hello {
            version: PROTOCOL_VERSION,
        })? {
            Response::Hello { proto, .. } => {
                client.proto = proto;
                Ok(client)
            }
            other => Err(unexpected("hello", &other)),
        }
    }

    /// Negotiated protocol version.
    pub fn proto(&self) -> u32 {
        self.proto
    }

    /// Bound how long any single reply may take (e.g. sync invokes on a
    /// loaded server). `None` restores fully blocking reads.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> Result<(), ApiError> {
        self.writer.set_read_timeout(timeout).map_err(io_err)
    }

    /// One request/reply round trip. Server-side failures come back as
    /// `Err` with the decoded [`ApiError`]; transport failures as
    /// [`ApiError::Io`].
    fn call(&mut self, req: &Request) -> Result<Response, ApiError> {
        self.wbuf.clear();
        wire::encode_request_into(req, &mut self.wbuf);
        self.wbuf.push('\n');
        self.writer
            .write_all(self.wbuf.as_bytes())
            .map_err(io_err)?;
        self.rbuf.clear();
        let n = self.reader.read_line(&mut self.rbuf).map_err(io_err)?;
        if n == 0 {
            return Err(ApiError::Io {
                detail: "server closed the connection".into(),
            });
        }
        match wire::decode_response(self.rbuf.trim()).map_err(io_err)? {
            Response::Error(e) => Err(e),
            resp => Ok(resp),
        }
    }

    pub fn describe(&mut self) -> Result<DescribeInfo, ApiError> {
        match self.call(&Request::Describe)? {
            Response::Described(d) => Ok(d),
            other => Err(unexpected("describe", &other)),
        }
    }

    /// Sync invoke: blocks until the invocation completes (or the
    /// server-side `deadline_ms` expires).
    pub fn invoke(
        &mut self,
        func: &str,
        deadline_ms: Option<u64>,
    ) -> Result<InvokeOutcome, ApiError> {
        match self.call(&Request::Invoke {
            func: func.to_string(),
            mode: InvokeMode::Sync,
            deadline_ms,
        })? {
            Response::Done(o) => Ok(o),
            other => Err(unexpected("invoke", &other)),
        }
    }

    /// Async invoke: returns the completion ticket immediately.
    pub fn invoke_async(&mut self, func: &str) -> Result<Ticket, ApiError> {
        match self.call(&Request::Invoke {
            func: func.to_string(),
            mode: InvokeMode::Async,
            deadline_ms: None,
        })? {
            Response::Accepted { ticket } => Ok(ticket),
            other => Err(unexpected("invoke async", &other)),
        }
    }

    /// Redeem a ticket, blocking until completion (optionally bounded).
    pub fn wait(
        &mut self,
        ticket: Ticket,
        deadline_ms: Option<u64>,
    ) -> Result<InvokeOutcome, ApiError> {
        match self.call(&Request::Wait { ticket, deadline_ms })? {
            Response::Done(o) => Ok(o),
            other => Err(unexpected("wait", &other)),
        }
    }

    /// Non-blocking completion check: `Some` consumes the ticket.
    pub fn poll(&mut self, ticket: Ticket) -> Result<Option<InvokeOutcome>, ApiError> {
        match self.call(&Request::Poll { ticket })? {
            Response::Done(o) => Ok(Some(o)),
            Response::Pending { .. } => Ok(None),
            other => Err(unexpected("poll", &other)),
        }
    }

    pub fn stats(&mut self) -> Result<StatsSnapshot, ApiError> {
        match self.call(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => Err(unexpected("stats", &other)),
        }
    }

    /// Close the connection gracefully (server replies `bye`).
    pub fn quit(mut self) {
        let _ = self.call(&Request::Shutdown);
    }
}

fn unexpected(what: &str, got: &Response) -> ApiError {
    ApiError::Io {
        detail: format!("unexpected {what} reply: {got:?}"),
    }
}
