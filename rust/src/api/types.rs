//! Protocol v1 surface types: versioned requests/responses, async
//! completion tickets, and the structured [`ApiError`] taxonomy.
//!
//! These are the *semantic* types — [`super::wire`] maps them onto the
//! JSON-lines framing, [`super::client`] speaks them over TCP, and the
//! [`super::Frontend`] trait serves them from a control plane (single
//! [`crate::server::RtServer`] or sharded [`crate::server::RtCluster`]).
//! Keeping the enum layer separate from the framing is what lets the
//! legacy line protocol (`invoke <fn>` / `stats` / `quit`) coexist as
//! aliases: both framings decode into the same [`Request`]s.

use std::fmt;

use crate::types::StartKind;

/// The wire-protocol version this build speaks. Bump on any change to
/// the request/response vocabulary that an old client could misread;
/// the `hello` handshake negotiates down to the client's version while
/// `min(client, server)` is still a language both sides speak.
pub const PROTOCOL_VERSION: u32 = 1;

/// Handle for an accepted asynchronous invocation. Server-unique for
/// the lifetime of one frontend (tickets are never reused).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Ticket(pub u64);

impl fmt::Display for Ticket {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "#{}", self.0)
    }
}

/// How an `invoke` wants its reply: block until done, or return a
/// [`Ticket`] immediately (Shahrad et al.'s production traces are
/// dominated by async triggers — queues, timers — so async submission
/// is first-class, not an afterthought).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum InvokeMode {
    #[default]
    Sync,
    Async,
}

impl InvokeMode {
    pub fn name(&self) -> &'static str {
        match self {
            InvokeMode::Sync => "sync",
            InvokeMode::Async => "async",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "sync" => InvokeMode::Sync,
            "async" => InvokeMode::Async,
            _ => return None,
        })
    }
}

/// One client request. The legacy line protocol decodes into the same
/// vocabulary: `invoke <fn>` ⇒ sync [`Request::Invoke`], `stats` ⇒
/// [`Request::Stats`], `quit` ⇒ [`Request::Shutdown`].
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Version handshake; the first request a v1 client sends.
    Hello { version: u32 },
    /// What does this frontend serve? (functions, policy, shards, router)
    Describe,
    /// Submit one invocation of a registered function.
    Invoke {
        func: String,
        mode: InvokeMode,
        /// Sync mode: bound end-to-end (queueing + execution) waiting;
        /// exceeded ⇒ [`ApiError::DeadlineExceeded`] (the invocation
        /// itself still runs to completion — no preemption, §4.4).
        deadline_ms: Option<u64>,
        /// Async mode, event-loop servers only: subscribe at submit.
        /// The `Accepted` reply is followed — on this connection,
        /// whenever the invocation finishes — by an unsolicited
        /// [`Response::Push`] completion notification, replacing
        /// wait-with-deadline polling.
        push: bool,
    },
    /// Block until the ticket's invocation completes (optionally bounded).
    Wait {
        ticket: Ticket,
        deadline_ms: Option<u64>,
    },
    /// Non-blocking completion check.
    Poll { ticket: Ticket },
    /// Aggregate serving stats (plus per-shard breakdown rows).
    Stats,
    /// Telemetry snapshot: the full metrics registry, rendered as
    /// Prometheus text exposition or structured JSON.
    Metrics { format: MetricsFormat },
    /// Drain up to `max` buffered lifecycle events from the trace ring
    /// (consuming; repeated calls page through the stream).
    Trace { max: usize },
    /// Admin: stop routing new work to a shard; in-flight finishes.
    Drain { shard: usize },
    /// Admin: (re)insert a shard into the routable set.
    Join { shard: usize },
    /// Admin: abrupt shard failure — every ticket homed there resolves
    /// to [`ApiError::ShardLost`]; the ring heals around it.
    Kill { shard: usize },
    /// Admin: per-shard health/epoch snapshot + conservation counters.
    Membership,
    /// Close this connection (the server keeps running; stopping the
    /// server is the owning process's call, not a network client's).
    Shutdown,
}

/// Completion record of one served invocation, as reported to clients.
#[derive(Debug, Clone, PartialEq)]
pub struct InvokeOutcome {
    pub ticket: Ticket,
    /// Registered function name (e.g. `fft-0`).
    pub func: String,
    /// Shard that served it (always 0 on a single-plane server).
    pub shard: usize,
    pub gpu: u32,
    pub start_kind: StartKind,
    /// End-to-end latency: arrival to completion, wall-clock ms.
    pub latency_ms: f64,
    /// Measured on-device execution time (PJRT wall time in real mode,
    /// the scaled modeled service in model mode), ms.
    pub exec_ms: f64,
}

/// `describe` reply: what this frontend is and what it serves.
#[derive(Debug, Clone, PartialEq)]
pub struct DescribeInfo {
    pub proto: u32,
    /// Frontend kind: `rt-server` (single plane) or `rt-cluster`.
    pub server: String,
    /// Scheduling policy on the shards (e.g. `mqfq-sticky`).
    pub policy: String,
    pub shards: usize,
    /// Router name (`single` on a single-plane server).
    pub router: String,
    /// Registered function names, invocable via [`Request::Invoke`].
    pub functions: Vec<String>,
}

/// Export format of a `metrics` reply.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MetricsFormat {
    /// Prometheus text exposition (scrape-ready).
    #[default]
    Prom,
    /// Structured JSON (`mqfq-metrics/v1` schema).
    Json,
}

impl MetricsFormat {
    pub fn name(&self) -> &'static str {
        match self {
            MetricsFormat::Prom => "prom",
            MetricsFormat::Json => "json",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "prom" => MetricsFormat::Prom,
            "json" => MetricsFormat::Json,
            _ => return None,
        })
    }
}

/// Per-shard row of a `stats` reply: the serving breakdown a load
/// balancer or dashboard reads without scraping full telemetry. Built
/// entirely from already-maintained lock-free counters — no new locks.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ShardStatsRow {
    pub shard: usize,
    /// Queued (not yet dispatched) on this shard.
    pub pending: usize,
    /// Executing on this shard's devices.
    pub in_flight: usize,
    /// Completions served by this shard.
    pub completed: u64,
    /// Cold starts / completions on this shard (0 when none completed).
    pub cold_ratio: f64,
    pub health: ShardHealth,
    /// Kill epoch (see [`ShardInfo::epoch`]).
    pub epoch: u64,
}

impl Default for ShardHealth {
    fn default() -> Self {
        ShardHealth::Up
    }
}

/// `stats` reply: aggregate serving counters across all shards, plus
/// one [`ShardStatsRow`] per shard.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StatsSnapshot {
    pub invocations: usize,
    pub mean_latency_ms: f64,
    pub cold_ratio: f64,
    /// Queued (not yet dispatched) across all shards.
    pub pending: usize,
    /// Executing on devices across all shards.
    pub in_flight: usize,
    /// Per-shard breakdown (single-plane servers report one row).
    pub shards: Vec<ShardStatsRow>,
}

/// Lifecycle state of one shard in an elastic cluster. Shard *indices*
/// are stable for the life of the server — membership changes flip
/// health in place, they never renumber (`n_shards` is capacity, not
/// live count).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardHealth {
    /// Routable and serving.
    Up,
    /// No new work routed; in-flight invocations run to completion.
    Draining,
    /// Failed or retired: plane state discarded, tickets resolved to
    /// [`ApiError::ShardLost`], ring healed around it.
    Dead,
}

impl ShardHealth {
    pub fn name(&self) -> &'static str {
        match self {
            ShardHealth::Up => "up",
            ShardHealth::Draining => "draining",
            ShardHealth::Dead => "dead",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "up" => ShardHealth::Up,
            "draining" => ShardHealth::Draining,
            "dead" => ShardHealth::Dead,
            _ => return None,
        })
    }
}

/// Per-shard row of a `membership` reply.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardInfo {
    pub shard: usize,
    pub health: ShardHealth,
    /// Bumped on every kill: work items stamped with an older epoch are
    /// dropped instead of touching the (rebuilt) plane.
    pub epoch: u64,
    pub pending: usize,
    pub in_flight: usize,
    pub capacity: f64,
}

/// `membership` reply: cluster epoch, per-shard health, and the
/// invocation-conservation counters. The conservation invariant —
/// every accepted invocation has exactly one fate — reads as
/// `accepted == completed + failed + Σ(pending + in_flight)`,
/// i.e. `accepted == completed + failed` at quiescence.
#[derive(Debug, Clone, PartialEq)]
pub struct MembershipInfo {
    /// Bumped on every drain/join/kill (cluster-wide change counter).
    pub epoch: u64,
    pub shards: Vec<ShardInfo>,
    /// Submissions that were admitted (ticket issued, plane arrival).
    pub accepted: u64,
    /// Accepted invocations that completed and fulfilled their ticket.
    pub completed: u64,
    /// Accepted invocations resolved to a structured error (shard lost).
    pub failed: u64,
    /// Submissions rejected at admission (no ticket outstanding).
    pub rejected: u64,
    /// Late work items from a retired shard epoch, dropped not counted.
    pub stale_drops: u64,
}

impl MembershipInfo {
    /// Accepted invocations still in the system (no fate yet).
    pub fn outstanding(&self) -> u64 {
        self.accepted - self.completed - self.failed
    }

    /// Conservation check at a quiescent instant (no pending/in-flight
    /// work anywhere): every accepted invocation reached exactly one
    /// terminal fate.
    pub fn conserved_at_quiescence(&self) -> bool {
        let live: usize = self
            .shards
            .iter()
            .map(|s| s.pending + s.in_flight)
            .sum();
        live == 0 && self.accepted == self.completed + self.failed
    }
}

/// One server reply. Every response carries `ok` on the wire; errors
/// are a first-class variant, not a stringly-typed prefix.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Hello { proto: u32, server: String },
    Described(DescribeInfo),
    /// Async invoke accepted; redeem with `wait`/`poll`.
    Accepted { ticket: Ticket },
    /// Sync invoke / `wait` / successful `poll` completion.
    Done(InvokeOutcome),
    /// `poll` on a still-running invocation.
    Pending { ticket: Ticket },
    Stats(StatsSnapshot),
    /// `metrics` reply: the registry rendered in the requested format.
    /// The body is carried as an opaque string (Prometheus text, or a
    /// compact-rendered JSON document) — the wire layer escapes it like
    /// any other string field.
    Metrics { format: MetricsFormat, body: String },
    /// `trace` reply: lifecycle events drained from the ring
    /// (oldest-first), plus the ring's cumulative overflow-drop count.
    Trace {
        dropped: u64,
        events: Vec<crate::telemetry::TraceEvent>,
    },
    /// Reply to `drain`/`join`/`kill`/`membership`: the post-change
    /// membership snapshot.
    Membership(MembershipInfo),
    /// Server-push completion notification for a ticket submitted with
    /// `push: true` — arrives unsolicited (not paired to a request
    /// line), tagged by its ticket. Event-loop servers only.
    Push(InvokeOutcome),
    /// Connection-close acknowledgement.
    Bye,
    Error(ApiError),
}

/// Structured error taxonomy. `code()` is the stable wire identifier;
/// `Display` adds human detail.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiError {
    /// Hello requested a protocol this server cannot speak.
    UnsupportedVersion { requested: u32, supported: u32 },
    UnknownFunction { name: String },
    /// No such ticket. `evicted: true` means the ticket *did* complete
    /// but its unclaimed result aged out of the bounded done-table —
    /// distinguishable from a ticket that never existed.
    UnknownTicket { ticket: Ticket, evicted: bool },
    /// Admission control: queued work is at/over the backpressure bound,
    /// or the deadline-aware shed predicts queueing past the configured
    /// deadline. `retry_after_ms` is the server's backoff hint (0 when
    /// the plain backpressure bound tripped, which carries no estimate).
    Overloaded {
        pending: usize,
        limit: usize,
        retry_after_ms: u64,
    },
    /// The shard holding this ticket's invocation died before
    /// completing it. The invocation is *not* silently requeued; the
    /// caller decides whether to resubmit. Waiters (even those blocked
    /// with a deadline) wake immediately when the shard is killed.
    ShardLost { shard: usize, ticket: Ticket },
    /// A sync invoke or `wait` outlived its deadline. The invocation
    /// keeps running (run-to-completion); `ticket` is its handle, so
    /// even a deadline-tripped *sync* invoke can be redeemed with a
    /// later `wait`/`poll`.
    DeadlineExceeded {
        waited_ms: u64,
        ticket: Option<Ticket>,
    },
    ShuttingDown,
    /// The client stopped reading its socket while replies kept
    /// queueing; past the per-connection outbound high-water mark the
    /// event loop cuts the connection (a stalled reader must not pin
    /// server memory). Delivery of this error is best-effort — the
    /// receiver is, by definition, not reading.
    SlowConsumer { queued: usize, limit: usize },
    /// The invocation kept faulting until its retry budget was
    /// exhausted; every attempt (the first run plus each re-queue)
    /// counted. Terminal — the server will not run it again.
    ExecFailed { ticket: Ticket, attempts: u32 },
    /// The function's circuit breaker is open (its rolling failure
    /// rate marked it poison); submissions are refused until a
    /// half-open probe succeeds. Not transient for *this* call — retry
    /// no sooner than `retry_after_ms`.
    Quarantined {
        func: String,
        retry_after_ms: u64,
    },
    /// Malformed request (bad JSON, missing field, unknown command).
    BadRequest { detail: String },
    /// Client-side transport failure (connect/read/write).
    Io { detail: String },
}

impl ApiError {
    /// Stable wire identifier for this error class.
    pub fn code(&self) -> &'static str {
        match self {
            ApiError::UnsupportedVersion { .. } => "unsupported-version",
            ApiError::UnknownFunction { .. } => "unknown-function",
            ApiError::UnknownTicket { .. } => "unknown-ticket",
            ApiError::Overloaded { .. } => "overloaded",
            ApiError::ShardLost { .. } => "shard-lost",
            ApiError::DeadlineExceeded { .. } => "deadline-exceeded",
            ApiError::ShuttingDown => "shutting-down",
            ApiError::SlowConsumer { .. } => "slow-consumer",
            ApiError::ExecFailed { .. } => "exec-failed",
            ApiError::Quarantined { .. } => "quarantined",
            ApiError::BadRequest { .. } => "bad-request",
            ApiError::Io { .. } => "io",
        }
    }

    /// Human-readable detail (the part `Display` appends to the code).
    pub fn detail(&self) -> String {
        match self {
            ApiError::UnsupportedVersion {
                requested,
                supported,
            } => format!("client asked for v{requested}, server speaks up to v{supported}"),
            ApiError::UnknownFunction { name } => name.clone(),
            ApiError::UnknownTicket { ticket, evicted } => {
                if *evicted {
                    format!("{ticket} completed but its unclaimed result was evicted")
                } else {
                    ticket.to_string()
                }
            }
            ApiError::Overloaded {
                pending,
                limit,
                retry_after_ms,
            } => {
                if *retry_after_ms > 0 {
                    format!("{pending} pending >= limit {limit}; retry after {retry_after_ms} ms")
                } else {
                    format!("{pending} pending >= limit {limit}")
                }
            }
            ApiError::ShardLost { shard, ticket } => {
                format!("shard {shard} died holding {ticket}")
            }
            ApiError::DeadlineExceeded { waited_ms, ticket } => match ticket {
                Some(t) => format!("waited {waited_ms} ms ({t} still running)"),
                None => format!("waited {waited_ms} ms"),
            },
            ApiError::ShuttingDown => "server is shutting down".into(),
            ApiError::SlowConsumer { queued, limit } => {
                format!("{queued} outbound bytes queued > limit {limit}")
            }
            ApiError::ExecFailed { ticket, attempts } => {
                format!("{ticket} failed after {attempts} attempts")
            }
            ApiError::Quarantined {
                func,
                retry_after_ms,
            } => format!("{func} breaker open; retry after {retry_after_ms} ms"),
            ApiError::BadRequest { detail } => detail.clone(),
            ApiError::Io { detail } => detail.clone(),
        }
    }

    /// Rebuild from a wire `(code, detail)` pair — the client-side
    /// inverse of [`Self::code`]/[`Self::detail`]. Structured fields
    /// that do not survive the trip (counts, versions) decode to zero;
    /// the code is what clients should branch on.
    pub fn from_wire(code: &str, detail: &str) -> ApiError {
        match code {
            "unsupported-version" => ApiError::UnsupportedVersion {
                requested: 0,
                supported: 0,
            },
            "unknown-function" => ApiError::UnknownFunction {
                name: detail.to_string(),
            },
            "unknown-ticket" => ApiError::UnknownTicket {
                // Best-effort: the ticket number leads the detail; the
                // structured `ticket`/`evicted` wire extras (when
                // present) overwrite both fields after this call.
                ticket: Ticket(
                    detail
                        .split_whitespace()
                        .next()
                        .unwrap_or("")
                        .trim_start_matches('#')
                        .parse()
                        .unwrap_or(0),
                ),
                evicted: detail.contains("evicted"),
            },
            "overloaded" => ApiError::Overloaded {
                pending: 0,
                limit: 0,
                // Best-effort from "...; retry after N ms"; the
                // structured `retry_after_ms` extra overwrites this.
                retry_after_ms: detail
                    .rsplit("retry after ")
                    .next()
                    .filter(|_| detail.contains("retry after"))
                    .and_then(|w| w.split_whitespace().next())
                    .and_then(|w| w.parse().ok())
                    .unwrap_or(0),
            },
            "shard-lost" => ApiError::ShardLost {
                // Best-effort from "shard N died holding #T"; the
                // structured `shard`/`ticket` extras overwrite these.
                shard: detail
                    .split_whitespace()
                    .nth(1)
                    .and_then(|w| w.parse().ok())
                    .unwrap_or(0),
                ticket: Ticket(
                    detail
                        .rsplit('#')
                        .next()
                        .and_then(|w| w.trim().parse().ok())
                        .unwrap_or(0),
                ),
            },
            "deadline-exceeded" => ApiError::DeadlineExceeded {
                waited_ms: 0,
                ticket: None,
            },
            "shutting-down" => ApiError::ShuttingDown,
            "exec-failed" => ApiError::ExecFailed {
                // Best-effort from "#T failed after N attempts"; the
                // structured `ticket`/`attempts` extras overwrite these.
                ticket: Ticket(
                    detail
                        .split_whitespace()
                        .next()
                        .unwrap_or("")
                        .trim_start_matches('#')
                        .parse()
                        .unwrap_or(0),
                ),
                attempts: detail
                    .split_whitespace()
                    .rev()
                    .nth(1)
                    .and_then(|w| w.parse().ok())
                    .unwrap_or(0),
            },
            "quarantined" => ApiError::Quarantined {
                // Best-effort from "<func> breaker open; retry after N ms".
                func: detail.split_whitespace().next().unwrap_or("").to_string(),
                retry_after_ms: detail
                    .split_whitespace()
                    .rev()
                    .nth(1)
                    .and_then(|w| w.parse().ok())
                    .unwrap_or(0),
            },
            "slow-consumer" => ApiError::SlowConsumer {
                queued: detail
                    .split_whitespace()
                    .next()
                    .and_then(|w| w.parse().ok())
                    .unwrap_or(0),
                limit: detail
                    .rsplit(' ')
                    .next()
                    .and_then(|w| w.parse().ok())
                    .unwrap_or(0),
            },
            "io" => ApiError::Io {
                detail: detail.to_string(),
            },
            _ => ApiError::BadRequest {
                detail: detail.to_string(),
            },
        }
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code(), self.detail())
    }
}

impl std::error::Error for ApiError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_codes_are_stable_and_distinct() {
        let all = [
            ApiError::UnsupportedVersion {
                requested: 9,
                supported: 1,
            },
            ApiError::UnknownFunction { name: "x".into() },
            ApiError::UnknownTicket {
                ticket: Ticket(7),
                evicted: false,
            },
            ApiError::Overloaded {
                pending: 4,
                limit: 4,
                retry_after_ms: 0,
            },
            ApiError::ShardLost {
                shard: 2,
                ticket: Ticket(5),
            },
            ApiError::DeadlineExceeded {
                waited_ms: 10,
                ticket: Some(Ticket(3)),
            },
            ApiError::ShuttingDown,
            ApiError::SlowConsumer {
                queued: 300_000,
                limit: 262_144,
            },
            ApiError::ExecFailed {
                ticket: Ticket(11),
                attempts: 3,
            },
            ApiError::Quarantined {
                func: "fft-0".into(),
                retry_after_ms: 250,
            },
            ApiError::BadRequest { detail: "d".into() },
            ApiError::Io { detail: "d".into() },
        ];
        let codes: std::collections::HashSet<_> =
            all.iter().map(|e| e.code()).collect();
        assert_eq!(codes.len(), all.len());
        for e in &all {
            // Code survives the wire round-trip (detail is advisory).
            assert_eq!(ApiError::from_wire(e.code(), &e.detail()).code(), e.code());
            assert!(e.to_string().starts_with(e.code()));
        }
    }

    #[test]
    fn unknown_wire_code_degrades_to_bad_request() {
        assert_eq!(ApiError::from_wire("warp-failure", "x").code(), "bad-request");
    }

    #[test]
    fn shard_lost_and_evicted_survive_detail_roundtrip() {
        // Structured extras carry these on the real wire; the detail
        // string alone must still rebuild the load-bearing fields.
        let e = ApiError::ShardLost {
            shard: 2,
            ticket: Ticket(5),
        };
        assert_eq!(ApiError::from_wire(e.code(), &e.detail()), e);
        let ev = ApiError::UnknownTicket {
            ticket: Ticket(9),
            evicted: true,
        };
        assert_eq!(ApiError::from_wire(ev.code(), &ev.detail()), ev);
        let sc = ApiError::SlowConsumer {
            queued: 300_000,
            limit: 262_144,
        };
        assert_eq!(ApiError::from_wire(sc.code(), &sc.detail()), sc);
        let ef = ApiError::ExecFailed {
            ticket: Ticket(11),
            attempts: 3,
        };
        assert_eq!(ApiError::from_wire(ef.code(), &ef.detail()), ef);
        let q = ApiError::Quarantined {
            func: "fft-0".into(),
            retry_after_ms: 250,
        };
        assert_eq!(ApiError::from_wire(q.code(), &q.detail()), q);
        let ov = ApiError::Overloaded {
            pending: 0,
            limit: 0,
            retry_after_ms: 750,
        };
        assert_eq!(ApiError::from_wire(ov.code(), &ov.detail()), ov);
    }

    #[test]
    fn shard_health_roundtrip() {
        for h in [ShardHealth::Up, ShardHealth::Draining, ShardHealth::Dead] {
            assert_eq!(ShardHealth::parse(h.name()), Some(h));
        }
        assert_eq!(ShardHealth::parse("zombie"), None);
    }

    #[test]
    fn conservation_identity_at_quiescence() {
        let mk = |pending, accepted, completed, failed| MembershipInfo {
            epoch: 3,
            shards: vec![ShardInfo {
                shard: 0,
                health: ShardHealth::Up,
                epoch: 0,
                pending,
                in_flight: 0,
                capacity: 1.0,
            }],
            accepted,
            completed,
            failed,
            rejected: 1,
            stale_drops: 0,
        };
        assert!(mk(0, 10, 8, 2).conserved_at_quiescence());
        // Work still queued: not quiescent, identity not checkable.
        assert!(!mk(1, 10, 8, 1).conserved_at_quiescence());
        // Quiescent but an invocation vanished without a fate.
        assert!(!mk(0, 10, 8, 1).conserved_at_quiescence());
        assert_eq!(mk(0, 10, 8, 1).outstanding(), 1);
    }

    #[test]
    fn metrics_format_roundtrip() {
        for f in [MetricsFormat::Prom, MetricsFormat::Json] {
            assert_eq!(MetricsFormat::parse(f.name()), Some(f));
        }
        assert_eq!(MetricsFormat::parse("xml"), None);
        assert_eq!(MetricsFormat::default(), MetricsFormat::Prom);
    }

    #[test]
    fn invoke_mode_roundtrip() {
        for m in [InvokeMode::Sync, InvokeMode::Async] {
            assert_eq!(InvokeMode::parse(m.name()), Some(m));
        }
        assert_eq!(InvokeMode::parse("batch"), None);
        assert_eq!(InvokeMode::default(), InvokeMode::Sync);
    }
}
