//! Protocol v1 surface types: versioned requests/responses, async
//! completion tickets, and the structured [`ApiError`] taxonomy.
//!
//! These are the *semantic* types — [`super::wire`] maps them onto the
//! JSON-lines framing, [`super::client`] speaks them over TCP, and the
//! [`super::Frontend`] trait serves them from a control plane (single
//! [`crate::server::RtServer`] or sharded [`crate::server::RtCluster`]).
//! Keeping the enum layer separate from the framing is what lets the
//! legacy line protocol (`invoke <fn>` / `stats` / `quit`) coexist as
//! aliases: both framings decode into the same [`Request`]s.

use std::fmt;

use crate::types::StartKind;

/// The wire-protocol version this build speaks. Bump on any change to
/// the request/response vocabulary that an old client could misread;
/// the `hello` handshake negotiates down to the client's version while
/// `min(client, server)` is still a language both sides speak.
pub const PROTOCOL_VERSION: u32 = 1;

/// Handle for an accepted asynchronous invocation. Server-unique for
/// the lifetime of one frontend (tickets are never reused).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Ticket(pub u64);

impl fmt::Display for Ticket {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "#{}", self.0)
    }
}

/// How an `invoke` wants its reply: block until done, or return a
/// [`Ticket`] immediately (Shahrad et al.'s production traces are
/// dominated by async triggers — queues, timers — so async submission
/// is first-class, not an afterthought).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum InvokeMode {
    #[default]
    Sync,
    Async,
}

impl InvokeMode {
    pub fn name(&self) -> &'static str {
        match self {
            InvokeMode::Sync => "sync",
            InvokeMode::Async => "async",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "sync" => InvokeMode::Sync,
            "async" => InvokeMode::Async,
            _ => return None,
        })
    }
}

/// One client request. The legacy line protocol decodes into the same
/// vocabulary: `invoke <fn>` ⇒ sync [`Request::Invoke`], `stats` ⇒
/// [`Request::Stats`], `quit` ⇒ [`Request::Shutdown`].
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Version handshake; the first request a v1 client sends.
    Hello { version: u32 },
    /// What does this frontend serve? (functions, policy, shards, router)
    Describe,
    /// Submit one invocation of a registered function.
    Invoke {
        func: String,
        mode: InvokeMode,
        /// Sync mode: bound end-to-end (queueing + execution) waiting;
        /// exceeded ⇒ [`ApiError::DeadlineExceeded`] (the invocation
        /// itself still runs to completion — no preemption, §4.4).
        deadline_ms: Option<u64>,
    },
    /// Block until the ticket's invocation completes (optionally bounded).
    Wait {
        ticket: Ticket,
        deadline_ms: Option<u64>,
    },
    /// Non-blocking completion check.
    Poll { ticket: Ticket },
    /// Aggregate serving stats.
    Stats,
    /// Close this connection (the server keeps running; stopping the
    /// server is the owning process's call, not a network client's).
    Shutdown,
}

/// Completion record of one served invocation, as reported to clients.
#[derive(Debug, Clone, PartialEq)]
pub struct InvokeOutcome {
    pub ticket: Ticket,
    /// Registered function name (e.g. `fft-0`).
    pub func: String,
    /// Shard that served it (always 0 on a single-plane server).
    pub shard: usize,
    pub gpu: u32,
    pub start_kind: StartKind,
    /// End-to-end latency: arrival to completion, wall-clock ms.
    pub latency_ms: f64,
    /// Measured on-device execution time (PJRT wall time in real mode,
    /// the scaled modeled service in model mode), ms.
    pub exec_ms: f64,
}

/// `describe` reply: what this frontend is and what it serves.
#[derive(Debug, Clone, PartialEq)]
pub struct DescribeInfo {
    pub proto: u32,
    /// Frontend kind: `rt-server` (single plane) or `rt-cluster`.
    pub server: String,
    /// Scheduling policy on the shards (e.g. `mqfq-sticky`).
    pub policy: String,
    pub shards: usize,
    /// Router name (`single` on a single-plane server).
    pub router: String,
    /// Registered function names, invocable via [`Request::Invoke`].
    pub functions: Vec<String>,
}

/// `stats` reply: aggregate serving counters across all shards.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StatsSnapshot {
    pub invocations: usize,
    pub mean_latency_ms: f64,
    pub cold_ratio: f64,
    /// Queued (not yet dispatched) across all shards.
    pub pending: usize,
    /// Executing on devices across all shards.
    pub in_flight: usize,
}

/// One server reply. Every response carries `ok` on the wire; errors
/// are a first-class variant, not a stringly-typed prefix.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Hello { proto: u32, server: String },
    Described(DescribeInfo),
    /// Async invoke accepted; redeem with `wait`/`poll`.
    Accepted { ticket: Ticket },
    /// Sync invoke / `wait` / successful `poll` completion.
    Done(InvokeOutcome),
    /// `poll` on a still-running invocation.
    Pending { ticket: Ticket },
    Stats(StatsSnapshot),
    /// Connection-close acknowledgement.
    Bye,
    Error(ApiError),
}

/// Structured error taxonomy. `code()` is the stable wire identifier;
/// `Display` adds human detail.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiError {
    /// Hello requested a protocol this server cannot speak.
    UnsupportedVersion { requested: u32, supported: u32 },
    UnknownFunction { name: String },
    UnknownTicket { ticket: Ticket },
    /// Admission control: queued work is at/over the backpressure bound.
    Overloaded { pending: usize, limit: usize },
    /// A sync invoke or `wait` outlived its deadline. The invocation
    /// keeps running (run-to-completion); `ticket` is its handle, so
    /// even a deadline-tripped *sync* invoke can be redeemed with a
    /// later `wait`/`poll`.
    DeadlineExceeded {
        waited_ms: u64,
        ticket: Option<Ticket>,
    },
    ShuttingDown,
    /// Malformed request (bad JSON, missing field, unknown command).
    BadRequest { detail: String },
    /// Client-side transport failure (connect/read/write).
    Io { detail: String },
}

impl ApiError {
    /// Stable wire identifier for this error class.
    pub fn code(&self) -> &'static str {
        match self {
            ApiError::UnsupportedVersion { .. } => "unsupported-version",
            ApiError::UnknownFunction { .. } => "unknown-function",
            ApiError::UnknownTicket { .. } => "unknown-ticket",
            ApiError::Overloaded { .. } => "overloaded",
            ApiError::DeadlineExceeded { .. } => "deadline-exceeded",
            ApiError::ShuttingDown => "shutting-down",
            ApiError::BadRequest { .. } => "bad-request",
            ApiError::Io { .. } => "io",
        }
    }

    /// Human-readable detail (the part `Display` appends to the code).
    pub fn detail(&self) -> String {
        match self {
            ApiError::UnsupportedVersion {
                requested,
                supported,
            } => format!("client asked for v{requested}, server speaks up to v{supported}"),
            ApiError::UnknownFunction { name } => name.clone(),
            ApiError::UnknownTicket { ticket } => ticket.to_string(),
            ApiError::Overloaded { pending, limit } => {
                format!("{pending} pending >= limit {limit}")
            }
            ApiError::DeadlineExceeded { waited_ms, ticket } => match ticket {
                Some(t) => format!("waited {waited_ms} ms ({t} still running)"),
                None => format!("waited {waited_ms} ms"),
            },
            ApiError::ShuttingDown => "server is shutting down".into(),
            ApiError::BadRequest { detail } => detail.clone(),
            ApiError::Io { detail } => detail.clone(),
        }
    }

    /// Rebuild from a wire `(code, detail)` pair — the client-side
    /// inverse of [`Self::code`]/[`Self::detail`]. Structured fields
    /// that do not survive the trip (counts, versions) decode to zero;
    /// the code is what clients should branch on.
    pub fn from_wire(code: &str, detail: &str) -> ApiError {
        match code {
            "unsupported-version" => ApiError::UnsupportedVersion {
                requested: 0,
                supported: 0,
            },
            "unknown-function" => ApiError::UnknownFunction {
                name: detail.to_string(),
            },
            "unknown-ticket" => ApiError::UnknownTicket {
                ticket: Ticket(
                    detail.trim_start_matches('#').parse().unwrap_or(0),
                ),
            },
            "overloaded" => ApiError::Overloaded {
                pending: 0,
                limit: 0,
            },
            "deadline-exceeded" => ApiError::DeadlineExceeded {
                waited_ms: 0,
                ticket: None,
            },
            "shutting-down" => ApiError::ShuttingDown,
            "io" => ApiError::Io {
                detail: detail.to_string(),
            },
            _ => ApiError::BadRequest {
                detail: detail.to_string(),
            },
        }
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code(), self.detail())
    }
}

impl std::error::Error for ApiError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_codes_are_stable_and_distinct() {
        let all = [
            ApiError::UnsupportedVersion {
                requested: 9,
                supported: 1,
            },
            ApiError::UnknownFunction { name: "x".into() },
            ApiError::UnknownTicket { ticket: Ticket(7) },
            ApiError::Overloaded {
                pending: 4,
                limit: 4,
            },
            ApiError::DeadlineExceeded {
                waited_ms: 10,
                ticket: Some(Ticket(3)),
            },
            ApiError::ShuttingDown,
            ApiError::BadRequest { detail: "d".into() },
            ApiError::Io { detail: "d".into() },
        ];
        let codes: std::collections::HashSet<_> =
            all.iter().map(|e| e.code()).collect();
        assert_eq!(codes.len(), all.len());
        for e in &all {
            // Code survives the wire round-trip (detail is advisory).
            assert_eq!(ApiError::from_wire(e.code(), &e.detail()).code(), e.code());
            assert!(e.to_string().starts_with(e.code()));
        }
    }

    #[test]
    fn unknown_wire_code_degrades_to_bad_request() {
        assert_eq!(ApiError::from_wire("warp-failure", "x").code(), "bad-request");
    }

    #[test]
    fn invoke_mode_roundtrip() {
        for m in [InvokeMode::Sync, InvokeMode::Async] {
            assert_eq!(InvokeMode::parse(m.name()), Some(m));
        }
        assert_eq!(InvokeMode::parse("batch"), None);
        assert_eq!(InvokeMode::default(), InvokeMode::Sync);
    }
}
