//! # MQFQ-Sticky: Fair Queueing For Serverless GPU Functions
//!
//! A from-scratch reproduction of the CS.DC 2025 paper as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the MQFQ-Sticky locality-enhanced fair
//!   queueing scheduler with integrated GPU memory management, plus every
//!   substrate it needs: a GPU device model (V100/A30, MPS/MIG/multi-GPU),
//!   a CUDA/UVM interposition-shim model, container lifecycle + warm pool,
//!   workload generators (Zipfian + Azure-style samples), a metrics stack,
//!   a discrete-event simulator and a real-time driver, an invocation
//!   server, and a benchmark harness regenerating every table and figure
//!   of the paper's evaluation.
//! * **Layer 2/1 (python/, build-time only)** — the function bodies as JAX
//!   graphs whose hot-spots are Pallas kernels, AOT-lowered to HLO text in
//!   `artifacts/` and executed by [`runtime`] through the PJRT CPU client.
//!
//! Start with [`plane::ControlPlane`] (the per-server control plane),
//! [`sim::replay`] (trace replay used by the experiment harness), or
//! [`cluster::Cluster`] (the sharded multi-server control plane with
//! locality-aware routing); the scheduling policies live in
//! [`scheduler::policies`]. Real traffic enters through [`api`] — the
//! versioned wire protocol and [`api::Frontend`] contract served by
//! [`server::RtServer`] (one plane) and [`server::RtCluster`] (N shards
//! behind a live router). Observability lives in [`telemetry`]: a
//! lock-free metrics registry and lifecycle trace ring shared by sim
//! and wire runs, exported over the `metrics`/`trace` verbs. Device-
//! and invocation-level fault tolerance (seeded injection, exactly-once
//! retry, circuit breakers, overload shedding) lives in [`fault`].

pub mod api;
pub mod cli;
pub mod clock;
pub mod cluster;
pub mod container;
pub mod estimator;
pub mod experiments;
pub mod fault;
pub mod gpu;
pub mod memory;
pub mod metrics;
pub mod plane;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod shim;
pub mod sim;
pub mod telemetry;
pub mod types;
pub mod util;
pub mod workload;
