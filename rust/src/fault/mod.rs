//! Fault model + deterministic injection: device failures, transient
//! execution faults, stragglers, poison-function circuit breakers, and
//! deadline-aware overload shedding.
//!
//! # Failure model
//!
//! Three fault kinds, all driven from one seeded [`FaultConfig`]:
//!
//! * **Device** ([`FaultKind::Device`]) — a GPU drops out of the pool
//!   mid-flight at a scheduled instant. Every invocation in flight on
//!   the device is evacuated and re-queued (forced cold — its container
//!   died with the device); the device takes no further placements
//!   until an optional scheduled recovery.
//! * **Transient** ([`FaultKind::Transient`]) — the attempt's container
//!   crashes (modeled ECC/OOM): detected when the execution would have
//!   completed, the attempt's service is thrown away and the
//!   invocation retries cold under its budget.
//! * **Straggler** ([`FaultKind::Straggler`]) — the execution hangs:
//!   its completion never arrives, the device slot and D-token stay
//!   burned until the watchdog (fed from the estimator's per-function
//!   exec predictions) evacuates it after `straggler_k`× the expected
//!   execution time.
//!
//! Injection is **deterministic and clock-agnostic**: whether an
//! attempt faults is a pure hash of `(seed, kind, invocation,
//! attempt)` — never of wall time — so the virtual-time sim and the
//! real TCP serving path inject the *same* faults for the same seed,
//! and a re-run reproduces a storm bit-for-bit.
//!
//! # Exactly-once retry semantics
//!
//! Each invocation carries an attempt counter. A failed attempt either
//! re-queues at the head of its flow (attempts remaining) or resolves
//! the invocation with a structured `exec-failed` error carrying the
//! attempt count — every submit resolves exactly once, enforced by
//! attempt-stamped completions (a late completion from a superseded
//! attempt is dropped, never double-counted).
//!
//! # Circuit breaker (poison functions)
//!
//! A per-function [`Breaker`] tracks a rolling window of attempt
//! outcomes. Tripping (failure fraction ≥ threshold over ≥
//! `min_samples`) opens the breaker: admission rejects the function
//! with `quarantined` until the cooldown elapses, then a bounded
//! number of half-open probes re-test it — probe failures re-open,
//! enough successes close it fresh.
//!
//! # Overload shedding
//!
//! When the estimator-implied queue wait says a new invocation cannot
//! meet the configured deadline, admission sheds it with
//! `overloaded` + `retry_after_ms` instead of queueing doomed work.
//! Hysteresis (`enter`/`exit` fractions of the deadline) keeps the
//! shedder from oscillating at the boundary.
//!
//! The zero-fault config ([`FaultConfig::is_neutral`]) is inert by
//! construction: the control plane only consults fault state behind an
//! `Option`, so "no plan" and "neutral plan" produce bit-identical
//! dispatch streams.

use std::collections::HashMap;

use crate::types::{DurNanos, FuncId, GpuId, InvocationId, Nanos, SEC};

/// The fault taxonomy. Payload code (`TraceEvent.a` of a `fault`
/// event) is [`FaultKind::code`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultKind {
    /// GPU dropped out of the pool; in-flight work evacuated.
    Device,
    /// Container crash / modeled ECC or OOM: attempt lost at what
    /// would have been its completion.
    Transient,
    /// Execution hung; evacuated by the watchdog after k× the
    /// estimated execution time.
    Straggler,
}

impl FaultKind {
    pub fn code(&self) -> i64 {
        match self {
            FaultKind::Device => 0,
            FaultKind::Transient => 1,
            FaultKind::Straggler => 2,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Device => "device",
            FaultKind::Transient => "transient",
            FaultKind::Straggler => "straggler",
        }
    }
}

/// Poison-function circuit-breaker tuning.
#[derive(Debug, Clone, PartialEq)]
pub struct BreakerConfig {
    /// Rolling outcome window (attempts), capped at 64 (one bit each).
    pub window: usize,
    /// Failure fraction over the window that trips the breaker Open.
    pub trip_threshold: f64,
    /// Minimum outcomes observed before the breaker may trip.
    pub min_samples: u32,
    /// Open → half-open after this long without admissions.
    pub cooldown: DurNanos,
    /// Half-open probe budget: successes needed to close; concurrent
    /// probes admitted are bounded by the same number.
    pub probes: u32,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        Self {
            window: 32,
            trip_threshold: 0.5,
            min_samples: 8,
            cooldown: 30 * SEC,
            probes: 3,
        }
    }
}

/// Deadline-aware overload-shedding tuning.
#[derive(Debug, Clone, PartialEq)]
pub struct ShedConfig {
    /// The deadline admitted work is expected to meet (seconds).
    pub deadline_s: f64,
    /// Start shedding when predicted wait > `enter` × deadline.
    pub enter: f64,
    /// Stop shedding when predicted wait ≤ `exit` × deadline
    /// (`exit < enter` gives the hysteresis band).
    pub exit: f64,
    /// Hint returned to shed clients.
    pub retry_after_ms: u64,
}

impl Default for ShedConfig {
    fn default() -> Self {
        Self {
            deadline_s: 30.0,
            enter: 1.0,
            exit: 0.7,
            retry_after_ms: 250,
        }
    }
}

/// The seeded fault plan: rates, schedules, budgets, and the optional
/// breaker/shed layers. `Default` is the neutral plan (inject
/// nothing, never reject).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Seed of the injection oracle; same seed ⇒ same faults, in
    /// either clock.
    pub seed: u64,
    /// Baseline per-attempt transient-fault probability (every
    /// function).
    pub transient_rate: f64,
    /// Per-function overrides (poison tenants): `(func, rate)`.
    pub poison: Vec<(FuncId, f64)>,
    /// Per-attempt straggler (hang) probability.
    pub straggler_rate: f64,
    /// Watchdog multiple: evacuate a hung attempt after
    /// `straggler_k × max(estimated, modeled) exec time`.
    pub straggler_k: f64,
    /// Cap on injected exec faults (transient + straggler); 0 means
    /// unbounded. A cap lets a storm have a recovery phase.
    pub max_faults: u64,
    /// Max attempts per invocation (≥1; the first run counts).
    pub retry_budget: u32,
    /// Scheduled device failures `(at, gpu)`.
    pub device_failures: Vec<(Nanos, GpuId)>,
    /// Scheduled device recoveries `(at, gpu)` — the device rejoins
    /// empty and cold.
    pub device_recoveries: Vec<(Nanos, GpuId)>,
    pub breaker: Option<BreakerConfig>,
    pub shed: Option<ShedConfig>,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self {
            seed: 0,
            transient_rate: 0.0,
            poison: Vec::new(),
            straggler_rate: 0.0,
            straggler_k: 3.0,
            max_faults: 0,
            retry_budget: 3,
            device_failures: Vec::new(),
            device_recoveries: Vec::new(),
            breaker: None,
            shed: None,
        }
    }
}

impl FaultConfig {
    /// True when the plan can never inject a fault nor reject an
    /// admission — the control plane with a neutral plan behaves
    /// bit-identically to one with no plan at all (property-tested).
    pub fn is_neutral(&self) -> bool {
        self.transient_rate <= 0.0
            && self.straggler_rate <= 0.0
            && self.poison.iter().all(|(_, r)| *r <= 0.0)
            && self.device_failures.is_empty()
            && self.breaker.is_none()
            && self.shed.is_none()
    }

    /// Effective per-attempt exec-fault rate for `func`.
    fn transient_rate_of(&self, func: FuncId) -> f64 {
        self.poison
            .iter()
            .find(|(f, _)| *f == func)
            .map(|(_, r)| *r)
            .unwrap_or(self.transient_rate)
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic uniform roll in `[0, 1)` keyed on (seed, salt,
/// invocation, attempt) — *never* on time, so sim and wall-clock runs
/// inject identically.
pub fn roll(seed: u64, salt: u64, inv: InvocationId, attempt: u32) -> f64 {
    let h = splitmix64(seed ^ splitmix64(salt ^ splitmix64(inv.0 ^ ((attempt as u64) << 48))));
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// Circuit-breaker state machine states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

impl BreakerState {
    /// Payload code (`TraceEvent.a` of a `breaker_state` event).
    pub fn code(&self) -> i64 {
        match self {
            BreakerState::Closed => 0,
            BreakerState::Open => 1,
            BreakerState::HalfOpen => 2,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

/// Admission decision from [`Breaker::admit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerAdmit {
    /// Closed: normal admission.
    Allowed,
    /// Half-open: admitted as a probe.
    Probe,
    /// Open (or probe budget exhausted): reject, retry after the hint.
    Rejected { retry_after_ms: u64 },
}

/// Per-function rolling-window circuit breaker (one bit per outcome,
/// so a 64-deep window fits a single word — zero-alloc by
/// construction).
#[derive(Debug, Clone)]
pub struct Breaker {
    pub state: BreakerState,
    /// Outcome ring, bit 0 = newest (1 = failure).
    ring: u64,
    len: u32,
    opened_at: Nanos,
    probe_successes: u32,
    probes_out: u32,
}

impl Default for Breaker {
    fn default() -> Self {
        Self {
            state: BreakerState::Closed,
            ring: 0,
            len: 0,
            opened_at: 0,
            probe_successes: 0,
            probes_out: 0,
        }
    }
}

impl Breaker {
    fn window_mask(cfg: &BreakerConfig) -> u64 {
        let w = cfg.window.clamp(1, 64);
        if w == 64 {
            u64::MAX
        } else {
            (1u64 << w) - 1
        }
    }

    /// Record one attempt outcome. Returns the new state when the
    /// outcome caused a transition.
    pub fn record(
        &mut self,
        cfg: &BreakerConfig,
        failed: bool,
        now: Nanos,
    ) -> Option<BreakerState> {
        match self.state {
            BreakerState::Closed => {
                let mask = Self::window_mask(cfg);
                self.ring = ((self.ring << 1) | u64::from(failed)) & mask;
                self.len = (self.len + 1).min(cfg.window.clamp(1, 64) as u32);
                let fails = self.ring.count_ones();
                if self.len >= cfg.min_samples.max(1)
                    && fails as f64 / self.len as f64 >= cfg.trip_threshold
                {
                    self.state = BreakerState::Open;
                    self.opened_at = now;
                    self.probe_successes = 0;
                    self.probes_out = 0;
                    return Some(BreakerState::Open);
                }
                None
            }
            BreakerState::HalfOpen => {
                self.probes_out = self.probes_out.saturating_sub(1);
                if failed {
                    self.state = BreakerState::Open;
                    self.opened_at = now;
                    self.probe_successes = 0;
                    self.probes_out = 0;
                    Some(BreakerState::Open)
                } else {
                    self.probe_successes += 1;
                    if self.probe_successes >= cfg.probes.max(1) {
                        self.state = BreakerState::Closed;
                        self.ring = 0;
                        self.len = 0;
                        Some(BreakerState::Closed)
                    } else {
                        None
                    }
                }
            }
            // A stale outcome from before the trip: no state change.
            BreakerState::Open => None,
        }
    }

    /// Admission check; may transition Open → HalfOpen when the
    /// cooldown has elapsed (returned as the second tuple slot for
    /// telemetry).
    pub fn admit(&mut self, cfg: &BreakerConfig, now: Nanos) -> (BreakerAdmit, Option<BreakerState>) {
        let mut transition = None;
        if self.state == BreakerState::Open && now >= self.opened_at + cfg.cooldown {
            self.state = BreakerState::HalfOpen;
            self.probe_successes = 0;
            self.probes_out = 0;
            transition = Some(BreakerState::HalfOpen);
        }
        let d = match self.state {
            BreakerState::Closed => BreakerAdmit::Allowed,
            BreakerState::HalfOpen => {
                if self.probes_out < cfg.probes.max(1) {
                    self.probes_out += 1;
                    BreakerAdmit::Probe
                } else {
                    // Probe slots all occupied: back off briefly.
                    BreakerAdmit::Rejected {
                        retry_after_ms: (cfg.cooldown / 1_000_000).max(1) / 4 + 1,
                    }
                }
            }
            BreakerState::Open => {
                let remaining = (self.opened_at + cfg.cooldown).saturating_sub(now);
                BreakerAdmit::Rejected {
                    retry_after_ms: (remaining / 1_000_000).max(1),
                }
            }
        };
        (d, transition)
    }
}

/// Fault counters surfaced through the telemetry registry and the
/// conservation checks of the property suites.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    pub faults_device: u64,
    pub faults_transient: u64,
    pub faults_straggler: u64,
    /// Attempts re-queued under the retry budget.
    pub retries: u64,
    /// Invocations that exhausted the budget (resolved `exec-failed`).
    pub retry_exhausted: u64,
    pub breaker_trips: u64,
    pub breaker_probes: u64,
    /// Admissions rejected by an open breaker.
    pub quarantined: u64,
    /// Admissions shed by the overload policy.
    pub shed: u64,
}

/// Terminal failure of an invocation (budget exhausted): exactly one
/// per failed submit, drained by the serving layer to fail the ticket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultFate {
    pub inv: InvocationId,
    pub func: FuncId,
    /// Attempts consumed (≥1).
    pub attempts: u32,
}

/// Admission rejection reasons produced by the fault layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitError {
    /// Function quarantined by its circuit breaker.
    Quarantined { retry_after_ms: u64 },
    /// Shed: the backlog implies the deadline cannot be met.
    Overloaded { retry_after_ms: u64 },
}

/// Live fault-injection state owned by one control plane.
#[derive(Debug, Clone)]
pub struct FaultState {
    pub cfg: FaultConfig,
    /// Attempts already consumed per live invocation (absent = 0).
    attempts: HashMap<InvocationId, u32>,
    /// Fault planned for the invocation's *current* attempt.
    pending: HashMap<InvocationId, FaultKind>,
    breakers: HashMap<FuncId, Breaker>,
    shedding: bool,
    /// Exec faults injected so far (vs `max_faults`).
    injected: u64,
    next_failure: usize,
    next_recovery: usize,
    pub stats: FaultStats,
    /// Exhausted-budget fates awaiting the serving layer.
    pub fates: Vec<FaultFate>,
}

impl FaultState {
    pub fn new(mut cfg: FaultConfig) -> Self {
        cfg.device_failures.sort_by_key(|(t, _)| *t);
        cfg.device_recoveries.sort_by_key(|(t, _)| *t);
        Self {
            cfg,
            attempts: HashMap::new(),
            pending: HashMap::new(),
            breakers: HashMap::new(),
            shedding: false,
            injected: 0,
            next_failure: 0,
            next_recovery: 0,
            stats: FaultStats::default(),
            fates: Vec::new(),
        }
    }

    /// Attempt index the invocation's next dispatch runs as.
    pub fn attempt_of(&self, inv: InvocationId) -> u32 {
        self.attempts.get(&inv).copied().unwrap_or(0)
    }

    pub fn retry_budget(&self) -> u32 {
        self.cfg.retry_budget.max(1)
    }

    /// Roll the oracle for a dispatching attempt; remembers and
    /// returns the planned fault, honoring the `max_faults` cap.
    pub fn plan_attempt(
        &mut self,
        inv: InvocationId,
        func: FuncId,
        attempt: u32,
    ) -> Option<FaultKind> {
        if self.cfg.max_faults > 0 && self.injected >= self.cfg.max_faults {
            return None;
        }
        let kind = if roll(self.cfg.seed, 1, inv, attempt) < self.cfg.transient_rate_of(func) {
            FaultKind::Transient
        } else if roll(self.cfg.seed, 2, inv, attempt) < self.cfg.straggler_rate {
            FaultKind::Straggler
        } else {
            return None;
        };
        self.injected += 1;
        self.pending.insert(inv, kind);
        Some(kind)
    }

    /// The fault planned for the invocation's current attempt, if any.
    pub fn pending_kind(&self, inv: InvocationId) -> Option<FaultKind> {
        self.pending.get(&inv).copied()
    }

    pub fn clear_pending(&mut self, inv: InvocationId) -> Option<FaultKind> {
        self.pending.remove(&inv)
    }

    /// Successful completion: drop the retry bookkeeping.
    pub fn on_success(&mut self, inv: InvocationId) {
        self.attempts.remove(&inv);
        self.pending.remove(&inv);
    }

    /// A failed attempt consumed `attempts_done` total attempts.
    /// Returns true when the invocation should re-queue (budget
    /// remaining); false records the terminal fate.
    pub fn on_attempt_failed(
        &mut self,
        inv: InvocationId,
        func: FuncId,
        attempts_done: u32,
    ) -> bool {
        self.pending.remove(&inv);
        if attempts_done < self.retry_budget() {
            self.attempts.insert(inv, attempts_done);
            self.stats.retries += 1;
            true
        } else {
            self.attempts.remove(&inv);
            self.stats.retry_exhausted += 1;
            self.fates.push(FaultFate {
                inv,
                func,
                attempts: attempts_done,
            });
            false
        }
    }

    /// Device failures scheduled at or before `now` (each returned
    /// once).
    pub fn due_device_failures(&mut self, now: Nanos) -> Vec<GpuId> {
        let mut out = Vec::new();
        while self.next_failure < self.cfg.device_failures.len()
            && self.cfg.device_failures[self.next_failure].0 <= now
        {
            out.push(self.cfg.device_failures[self.next_failure].1);
            self.next_failure += 1;
        }
        out
    }

    /// Device recoveries scheduled at or before `now`.
    pub fn due_device_recoveries(&mut self, now: Nanos) -> Vec<GpuId> {
        let mut out = Vec::new();
        while self.next_recovery < self.cfg.device_recoveries.len()
            && self.cfg.device_recoveries[self.next_recovery].0 <= now
        {
            out.push(self.cfg.device_recoveries[self.next_recovery].1);
            self.next_recovery += 1;
        }
        out
    }

    /// Watchdog threshold for one attempt: hung when
    /// `now ≥ exec_start + straggler_k × max(estimate, modeled exec)`.
    pub fn straggler_deadline(&self, exec_start: Nanos, est_exec: DurNanos) -> Nanos {
        let k = self.cfg.straggler_k.max(1.0);
        exec_start + (est_exec as f64 * k) as DurNanos
    }

    /// Breaker admission for `func`. Emits no telemetry itself; the
    /// caller turns the returned transition into a `breaker_state`
    /// event.
    pub fn breaker_admit(
        &mut self,
        func: FuncId,
        now: Nanos,
    ) -> (BreakerAdmit, Option<BreakerState>) {
        let Some(cfg) = self.cfg.breaker.clone() else {
            return (BreakerAdmit::Allowed, None);
        };
        let b = self.breakers.entry(func).or_default();
        let (d, tr) = b.admit(&cfg, now);
        match d {
            BreakerAdmit::Probe => self.stats.breaker_probes += 1,
            BreakerAdmit::Rejected { .. } => self.stats.quarantined += 1,
            BreakerAdmit::Allowed => {}
        }
        (d, tr)
    }

    /// Record an attempt outcome into the function's breaker.
    pub fn breaker_record(
        &mut self,
        func: FuncId,
        failed: bool,
        now: Nanos,
    ) -> Option<BreakerState> {
        let cfg = self.cfg.breaker.clone()?;
        let b = self.breakers.entry(func).or_default();
        let tr = b.record(&cfg, failed, now);
        if tr == Some(BreakerState::Open) {
            self.stats.breaker_trips += 1;
        }
        tr
    }

    pub fn breaker_state(&self, func: FuncId) -> BreakerState {
        self.breakers
            .get(&func)
            .map(|b| b.state)
            .unwrap_or(BreakerState::Closed)
    }

    /// Overload shedder: given the estimator-implied queue wait,
    /// decide (with hysteresis) whether to shed this admission.
    /// Returns the rejection when shedding.
    pub fn shed_eval(&mut self, predicted_wait_s: f64) -> Option<AdmitError> {
        let cfg = self.cfg.shed.as_ref()?;
        if self.shedding {
            if predicted_wait_s <= cfg.exit * cfg.deadline_s {
                self.shedding = false;
            }
        } else if predicted_wait_s > cfg.enter * cfg.deadline_s {
            self.shedding = true;
        }
        if self.shedding {
            self.stats.shed += 1;
            Some(AdmitError::Overloaded {
                retry_after_ms: cfg.retry_after_ms,
            })
        } else {
            None
        }
    }

    /// Currently in the shedding regime?
    pub fn is_shedding(&self) -> bool {
        self.shedding
    }

    /// Take the accumulated terminal fates (serving layer fails the
    /// tickets; sim harnesses count them for conservation).
    pub fn drain_fates(&mut self) -> Vec<FaultFate> {
        std::mem::take(&mut self.fates)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::MS;

    #[test]
    fn roll_is_deterministic_and_uniformish() {
        let a = roll(7, 1, InvocationId(42), 0);
        let b = roll(7, 1, InvocationId(42), 0);
        assert_eq!(a, b);
        assert!(roll(7, 1, InvocationId(42), 1) != a, "attempt changes the roll");
        assert!(roll(8, 1, InvocationId(42), 0) != a, "seed changes the roll");
        // Coarse uniformity: mean of many rolls near 0.5.
        let n = 10_000;
        let sum: f64 = (0..n).map(|i| roll(3, 9, InvocationId(i), 0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
        assert!((0..n).all(|i| {
            let r = roll(3, 9, InvocationId(i), 0);
            (0.0..1.0).contains(&r)
        }));
    }

    #[test]
    fn neutral_config_detects_itself() {
        assert!(FaultConfig::default().is_neutral());
        let storm = FaultConfig {
            transient_rate: 0.1,
            ..Default::default()
        };
        assert!(!storm.is_neutral());
        let poison = FaultConfig {
            poison: vec![(FuncId(3), 0.9)],
            ..Default::default()
        };
        assert!(!poison.is_neutral());
        let zero_poison = FaultConfig {
            poison: vec![(FuncId(3), 0.0)],
            ..Default::default()
        };
        assert!(zero_poison.is_neutral());
        assert!(!FaultConfig {
            breaker: Some(BreakerConfig::default()),
            ..Default::default()
        }
        .is_neutral());
    }

    #[test]
    fn plan_respects_rates_poison_and_cap() {
        let mut s = FaultState::new(FaultConfig {
            seed: 11,
            transient_rate: 0.0,
            poison: vec![(FuncId(1), 1.0)],
            max_faults: 2,
            ..Default::default()
        });
        // Healthy func never faults.
        for i in 0..50 {
            assert_eq!(s.plan_attempt(InvocationId(i), FuncId(0), 0), None);
        }
        // Poison func faults every attempt — until the cap.
        assert_eq!(
            s.plan_attempt(InvocationId(100), FuncId(1), 0),
            Some(FaultKind::Transient)
        );
        assert_eq!(
            s.plan_attempt(InvocationId(101), FuncId(1), 0),
            Some(FaultKind::Transient)
        );
        assert_eq!(s.plan_attempt(InvocationId(102), FuncId(1), 0), None, "cap");
        assert_eq!(s.pending_kind(InvocationId(101)), Some(FaultKind::Transient));
        assert_eq!(s.pending_kind(InvocationId(102)), None);
    }

    #[test]
    fn retry_budget_requeues_then_exhausts() {
        let mut s = FaultState::new(FaultConfig {
            retry_budget: 2,
            ..Default::default()
        });
        let inv = InvocationId(9);
        assert_eq!(s.attempt_of(inv), 0);
        assert!(s.on_attempt_failed(inv, FuncId(0), 1), "attempt 1 of 2 retries");
        assert_eq!(s.attempt_of(inv), 1);
        assert_eq!(s.stats.retries, 1);
        assert!(!s.on_attempt_failed(inv, FuncId(0), 2), "budget exhausted");
        assert_eq!(s.stats.retry_exhausted, 1);
        let fates = s.drain_fates();
        assert_eq!(
            fates,
            vec![FaultFate {
                inv,
                func: FuncId(0),
                attempts: 2
            }]
        );
        assert!(s.drain_fates().is_empty(), "fates drain once");
        assert_eq!(s.attempt_of(inv), 0, "bookkeeping cleared");
    }

    #[test]
    fn device_schedules_fire_once_in_order() {
        let mut s = FaultState::new(FaultConfig {
            device_failures: vec![(5 * MS, GpuId(1)), (2 * MS, GpuId(0))],
            device_recoveries: vec![(9 * MS, GpuId(0))],
            ..Default::default()
        });
        assert!(s.due_device_failures(MS).is_empty());
        assert_eq!(s.due_device_failures(6 * MS), vec![GpuId(0), GpuId(1)]);
        assert!(s.due_device_failures(100 * MS).is_empty(), "each fires once");
        assert_eq!(s.due_device_recoveries(9 * MS), vec![GpuId(0)]);
        assert!(s.due_device_recoveries(10 * MS).is_empty());
    }

    #[test]
    fn breaker_trips_cools_probes_and_closes() {
        let cfg = BreakerConfig {
            window: 8,
            trip_threshold: 0.5,
            min_samples: 4,
            cooldown: SEC,
            probes: 2,
        };
        let mut b = Breaker::default();
        // Not enough samples yet.
        assert_eq!(b.record(&cfg, true, 0), None);
        assert_eq!(b.record(&cfg, true, 0), None);
        assert_eq!(b.record(&cfg, false, 0), None);
        // 4th sample: 3/4 failures ≥ 0.5 → trips.
        assert_eq!(b.record(&cfg, true, 10), Some(BreakerState::Open));
        assert_eq!(b.state, BreakerState::Open);
        // Open rejects with a retry hint until the cooldown elapses.
        let (d, tr) = b.admit(&cfg, 10 + SEC / 2);
        assert!(matches!(d, BreakerAdmit::Rejected { retry_after_ms } if retry_after_ms >= 1));
        assert_eq!(tr, None);
        // Cooldown elapsed: half-open, bounded probes.
        let (d, tr) = b.admit(&cfg, 10 + SEC);
        assert_eq!(d, BreakerAdmit::Probe);
        assert_eq!(tr, Some(BreakerState::HalfOpen));
        let (d, _) = b.admit(&cfg, 10 + SEC);
        assert_eq!(d, BreakerAdmit::Probe);
        let (d, _) = b.admit(&cfg, 10 + SEC);
        assert!(matches!(d, BreakerAdmit::Rejected { .. }), "probe slots full");
        // One probe success is not enough; the second closes it fresh.
        assert_eq!(b.record(&cfg, false, 10 + SEC), None);
        assert_eq!(b.record(&cfg, false, 10 + SEC), Some(BreakerState::Closed));
        assert_eq!(b.state, BreakerState::Closed);
        // A probe failure in half-open re-opens immediately.
        for _ in 0..4 {
            b.record(&cfg, true, 20);
        }
        assert_eq!(b.state, BreakerState::Open);
        let (d, _) = b.admit(&cfg, 20 + SEC);
        assert_eq!(d, BreakerAdmit::Probe);
        assert_eq!(b.record(&cfg, true, 20 + SEC), Some(BreakerState::Open));
    }

    #[test]
    fn shed_hysteresis_enters_and_exits() {
        let mut s = FaultState::new(FaultConfig {
            shed: Some(ShedConfig {
                deadline_s: 10.0,
                enter: 1.0,
                exit: 0.5,
                retry_after_ms: 99,
            }),
            ..Default::default()
        });
        assert_eq!(s.shed_eval(9.0), None, "under the deadline: admit");
        assert!(matches!(
            s.shed_eval(11.0),
            Some(AdmitError::Overloaded { retry_after_ms: 99 })
        ));
        // Hysteresis: 7 s is under enter (10) but above exit (5) —
        // still shedding.
        assert!(s.shed_eval(7.0).is_some());
        assert!(s.is_shedding());
        // Below the exit bound: admission resumes.
        assert_eq!(s.shed_eval(4.0), None);
        assert!(!s.is_shedding());
        assert_eq!(s.stats.shed, 2);
    }

    #[test]
    fn breaker_facade_counts_trips_and_probes() {
        let mut s = FaultState::new(FaultConfig {
            breaker: Some(BreakerConfig {
                window: 4,
                trip_threshold: 0.5,
                min_samples: 2,
                cooldown: SEC,
                probes: 1,
            }),
            ..Default::default()
        });
        let f = FuncId(7);
        assert_eq!(s.breaker_record(f, true, 0), None);
        assert_eq!(s.breaker_record(f, true, 0), Some(BreakerState::Open));
        assert_eq!(s.stats.breaker_trips, 1);
        assert_eq!(s.breaker_state(f), BreakerState::Open);
        let (d, _) = s.breaker_admit(f, 0);
        assert!(matches!(d, BreakerAdmit::Rejected { .. }));
        assert_eq!(s.stats.quarantined, 1);
        let (d, tr) = s.breaker_admit(f, SEC);
        assert_eq!(d, BreakerAdmit::Probe);
        assert_eq!(tr, Some(BreakerState::HalfOpen));
        assert_eq!(s.stats.breaker_probes, 1);
        // Unknown functions are closed (no entry materialized).
        assert_eq!(s.breaker_state(FuncId(99)), BreakerState::Closed);
        let (d, _) = s.breaker_admit(FuncId(99), 0);
        assert_eq!(d, BreakerAdmit::Allowed);
    }
}
