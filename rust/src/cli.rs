//! Hand-rolled CLI (clap is not in the offline vendor set).
//!
//! Subcommands:
//! * `exp <name>|all` — run one (or every) paper experiment.
//! * `trace gen` — generate a Zipfian or Azure-style trace file.
//! * `replay` — replay a trace file through the control plane (sim).
//! * `cluster` — replay through a sharded multi-server cluster.
//! * `hetero` — heterogeneous-fleet sweep (fig10): uniform vs mixed
//!   hardware × router.
//! * `serve` — real-traffic serving over TCP (protocol v1 + legacy
//!   aliases): single plane, or `--shards N --router R` for the
//!   cluster frontend.
//! * `invoke` — protocol-v1 client against a running `serve`.
//! * `admin` — membership verbs (drain/join/kill/membership) against a
//!   running `serve`: elastic resize and fault injection over the wire;
//!   plus the observability verbs (metrics/trace) exporting the live
//!   telemetry registry and lifecycle-trace ring.
//! * `validate` — golden-check every AOT artifact via PJRT.

use std::collections::HashMap;

use crate::cluster::{ClusterConfig, RouterKind};
use crate::fault::{BreakerConfig, FaultConfig, ShedConfig};
use crate::gpu::{uniform_fleet, DeviceSpec, GpuProfile, MultiplexMode};
use crate::memory::MemPolicy;
use crate::plane::PlaneConfig;
use crate::types::{secs, FuncId, GpuId};
use crate::scheduler::policies::PolicyKind;
use crate::scheduler::MqfqConfig;
use crate::workload::azure::AzureConfig;
use crate::workload::zipf::ZipfConfig;
use crate::workload::{zipf, Trace};

/// Parsed `--key value` options + positional args.
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Self, String> {
        let mut positional = Vec::new();
        let mut options = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = argv
                    .get(i + 1)
                    .ok_or_else(|| format!("--{key} needs a value"))?;
                options.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Self {
            positional,
            options,
        })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad number {v}")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad integer {v}")),
        }
    }
}

pub const USAGE: &str = "\
mqfq-sticky — fair queueing for serverless GPU functions (paper reproduction)

USAGE:
  mqfq-sticky exp <name>|all            run paper experiment(s); see `exp list`
  mqfq-sticky trace gen --kind zipf|azure --out FILE
        [--rate R] [--funcs N] [--duration S] [--seed K]        (zipf)
        [--trace-id 0..8] [--duration S] [--scale X]            (azure)
  mqfq-sticky replay --trace FILE
        [--policy fcfs|batch|sjf|eevdf|mqfq|sfq] [--d N] [--gpus N]
        [--mem stock-uvm|madvise|prefetch-only|prefetch+swap]
        [--mode plain|mps|mig:N] [--pool N] [--t SECS] [--alpha A]
        [--grace A] [--batch-max N] [--batch-marginal F]
        [--estimator on|off] [--adaptive-d MIN:MAX]
              anticipatory scheduling (all default off): --grace A keeps
              an emptied flow Active for A x its predicted inter-arrival
              time; --batch-max N coalesces up to N same-flow
              invocations per dispatch (each rider costs
              --batch-marginal x the head); --estimator charges virtual
              time from the online exec-time estimate (budget-corrected
              at completion); --adaptive-d MIN:MAX resizes the
              concurrency tokens between the bounds by Little's law
              (overrides --d)
        [--trace-out FILE]  write the invocation-lifecycle trace
              (JSONL, one event per line; fold it with
              scripts/trace_summarize.py)
        [--fault-seed K] [--fault-rate P] [--poison F:P[,F:P..]]
        [--straggler-rate P] [--straggler-k K] [--retry-budget N]
        [--max-faults N] [--device-fail T:G[,T:G..]]
        [--breaker WINDOW:THRESH:COOLDOWN_S] [--shed DEADLINE_S]
              device-level fault tolerance (all default off; any flag
              installs a seeded deterministic fault plan): transient
              exec faults at rate P per attempt, per-function poison
              overrides (F = numeric function id), stragglers evacuated
              by a K x estimated-exec watchdog, scheduled device
              failures (gpu G drops at T seconds), exactly-once retries
              up to --retry-budget attempts (then `exec-failed`), a
              poison-function circuit breaker (`quarantined` while
              Open, half-open probes re-admit), and deadline-aware
              overload shedding (`overloaded` + retry-after hint)
        [--fleet SPEC[,SPEC..]]  heterogeneous fleet, overrides
              --gpus/--profile/--mode; SPEC = [NX]PROFILE[:mps|:migK][:dD]
              e.g. --fleet 2xv100,a30:mig2,v100:d1
  mqfq-sticky cluster [--shards N]
        [--router rr|random|least|sticky|sticky-blind]
        [--load-factor F] [--seed K] [--trace FILE]
        [--rate R/shard] [--funcs N] [--duration S]   (generated zipf)
        [+ replay options incl. --fleet]  sharded multi-server replay (sim)
  mqfq-sticky hetero [--rate R/V100-equiv] [--duration S] [--funcs N]
        [--seed K] [--load-factor F]     fig10 heterogeneous-fleet sweep:
              uniform vs mixed shard hardware x router, BENCH_hetero.json
  mqfq-sticky serve [--addr HOST:PORT] [--artifacts DIR] [--scale X]
        [--shards N] [--router rr|random|least|sticky|sticky-blind]
        [--load-factor F] [--seed K] [--max-pending N] [--workers W]
        [--max-outbound BYTES]
        [+ plane options incl. --policy/--d/--fleet, the anticipation
         knobs --grace/--batch-max/--adaptive-d, and the fault knobs
         --fault-rate/--poison/--breaker/--shed/...]
              real-traffic TCP serving: protocol v1 (JSON lines, hello
              handshake, sync/async invoke tickets, deadlines, request
              pipelining with id-tagged replies, push completions;
              legacy `invoke <fn>`|`stats`|`quit` lines kept as
              aliases). All connections are multiplexed on one epoll
              event-loop thread — serving threads stay shards x
              workers + O(1) regardless of connection count.
              --shards >1 (or --router) serves an RtCluster: N control
              planes behind the live capacity-weighted router.
              --workers sizes the fixed per-shard executor pool.
              --max-outbound caps a connection's queued reply bytes;
              a slower reader is disconnected past the high-water
              mark (slow-client protection; default 256 KiB).
  mqfq-sticky invoke <fn> [--addr HOST:PORT] [--mode sync|async]
        [--deadline-ms D] [--n N] [--retries K]
        [--push 1] [--pipeline B]   protocol-v1 client:
              run N invocations against a running `serve`, print
              outcomes and aggregate server stats. --retries opts into
              bounded jittered-backoff retries of transient errors
              (overload/transport; off by default — an Io retry can
              double-submit a sync invoke that already executed).
              --push 1 subscribes at submit: completions arrive as
              server-push notifications (no polling round trips).
              --pipeline B submits in pipelined batches of B tagged
              requests per flush (replies may return out of order)
  mqfq-sticky admin drain|join|kill SHARD [--addr HOST:PORT]
  mqfq-sticky admin membership [--addr HOST:PORT]
              elastic membership against a running `serve --shards N`:
              drain (stop routing, finish in-flight), join (rejoin
              cold), kill (abrupt failure: homed tickets fail with
              shard-lost, ring heals); membership prints per-shard
              health/epoch and the ticket-fate conservation counters
  mqfq-sticky admin metrics [--format prom|json] [--addr HOST:PORT]
  mqfq-sticky admin trace [--max N] [--addr HOST:PORT]
              observability against a running `serve`: metrics prints
              the registry (Prometheus text or JSON document); trace
              drains up to N (default all) lifecycle events from the
              server's ring as JSONL — pipe into
              scripts/trace_summarize.py for per-phase latency
  mqfq-sticky validate [--artifacts DIR] golden-check all artifacts
";

/// Parse one `--fleet` device spec: `[NX]PROFILE[:mps|:migK][:dD]`,
/// e.g. `v100`, `2xv100`, `a30:mig2`, `v100:mps:d1`.
fn parse_fleet_spec(s: &str) -> Result<Vec<DeviceSpec>, String> {
    let mut parts = s.split(':');
    let head = parts.next().unwrap_or_default();
    let (count, prof_name) = match head.split_once('x') {
        Some((n, p)) if n.chars().all(|c| c.is_ascii_digit()) && !n.is_empty() => {
            (n.parse::<usize>().map_err(|_| format!("bad count in {s}"))?, p)
        }
        _ => (1, head),
    };
    if count == 0 {
        return Err(format!("fleet spec {s}: count must be >= 1"));
    }
    let profile = parse_profile(prof_name)?;
    let mut spec = DeviceSpec::new(profile, MultiplexMode::Plain);
    for part in parts {
        if part == "mps" {
            spec.mode = MultiplexMode::Mps;
        } else if let Some(k) = part.strip_prefix("mig") {
            let k: u32 = k.parse().map_err(|_| format!("bad MIG slices in {s}"))?;
            if k == 0 {
                return Err(format!("fleet spec {s}: mig slices must be >= 1"));
            }
            spec.mode = MultiplexMode::Mig(k);
        } else if let Some(d) = part.strip_prefix('d') {
            let d: usize = d.parse().map_err(|_| format!("bad D override in {s}"))?;
            if d == 0 {
                return Err(format!("fleet spec {s}: D override must be >= 1"));
            }
            spec = spec.with_d(d);
        } else {
            return Err(format!("fleet spec {s}: unknown qualifier {part}"));
        }
    }
    Ok(vec![spec; count])
}

/// Parse a full `--fleet` description (comma-separated specs).
pub fn parse_fleet(s: &str) -> Result<Vec<DeviceSpec>, String> {
    let mut fleet = Vec::new();
    for part in s.split(',').filter(|p| !p.is_empty()) {
        fleet.extend(parse_fleet_spec(part)?);
    }
    if fleet.is_empty() {
        return Err("--fleet: no device specs given".into());
    }
    Ok(fleet)
}

fn parse_profile(p: &str) -> Result<GpuProfile, String> {
    match p {
        "v100" => Ok(crate::gpu::V100),
        "a30" => Ok(crate::gpu::A30),
        _ => Err(format!("unknown profile {p}")),
    }
}

/// Build a PlaneConfig from common replay/serve options.
pub fn plane_config(args: &Args) -> Result<PlaneConfig, String> {
    let mut cfg = PlaneConfig::default();
    if let Some(p) = args.get("policy") {
        cfg.policy = PolicyKind::parse(p).ok_or_else(|| format!("unknown policy {p}"))?;
    }
    cfg.d = args.get_usize("d", cfg.d)?;
    cfg.pool_size = args.get_usize("pool", cfg.pool_size)?;
    if let Some(m) = args.get("mem") {
        cfg.mem_policy = match m {
            "stock-uvm" => MemPolicy::StockUvm,
            "madvise" => MemPolicy::Madvise,
            "prefetch-only" => MemPolicy::PrefetchOnly,
            "prefetch+swap" => MemPolicy::PrefetchSwap,
            _ => return Err(format!("unknown mem policy {m}")),
        };
    }
    // Fleet description: `--fleet` wins; otherwise the legacy uniform
    // `--gpus/--profile/--mode` triple is assembled into one.
    cfg.devices = if let Some(f) = args.get("fleet") {
        parse_fleet(f)?
    } else {
        let n = args.get_usize("gpus", 1)?;
        if n == 0 {
            return Err("--gpus must be >= 1".into());
        }
        let profile = match args.get("profile") {
            Some(p) => parse_profile(p)?,
            None => crate::gpu::V100,
        };
        let mode = match args.get("mode") {
            None => MultiplexMode::Plain,
            Some("plain") => MultiplexMode::Plain,
            Some("mps") => MultiplexMode::Mps,
            Some(m) => match m.strip_prefix("mig:").and_then(|k| k.parse().ok()) {
                Some(0) => return Err("--mode mig:N needs N >= 1".into()),
                Some(k) => MultiplexMode::Mig(k),
                None => return Err(format!("unknown mode {m}")),
            },
        };
        uniform_fleet(n, profile, mode)
    };
    cfg.mqfq = MqfqConfig {
        t: args.get_f64("t", 10.0)?,
        ttl_alpha: args.get_f64("alpha", 2.0)?,
        ..Default::default()
    };
    // Anticipatory scheduling knobs (scheduler::mqfq module docs,
    // §Anticipatory scheduling). All default off: grace 0, batch-max 1,
    // estimator off, static D — the neutral config is bit-identical to
    // the pre-anticipation scheduler.
    let ant = &mut cfg.mqfq.anticipate;
    ant.grace_alpha = args.get_f64("grace", ant.grace_alpha)?;
    if !(ant.grace_alpha >= 0.0 && ant.grace_alpha.is_finite()) {
        return Err(format!("--grace must be >= 0, got {}", ant.grace_alpha));
    }
    ant.batch_max = args.get_usize("batch-max", ant.batch_max)?;
    if ant.batch_max == 0 {
        return Err("--batch-max must be >= 1 (1 disables batching)".into());
    }
    ant.batch_marginal = args.get_f64("batch-marginal", ant.batch_marginal)?;
    if !(ant.batch_marginal >= 0.0 && ant.batch_marginal.is_finite()) {
        return Err(format!("--batch-marginal must be >= 0, got {}", ant.batch_marginal));
    }
    if let Some(v) = args.get("estimator") {
        ant.estimator = match v {
            "1" | "true" | "yes" | "on" => true,
            "0" | "false" | "no" | "off" => false,
            _ => return Err(format!("--estimator: expected on|off, got {v}")),
        };
    }
    if let Some(spec) = args.get("adaptive-d") {
        cfg.adaptive_d = Some(parse_adaptive_d(spec)?);
    }
    // Device-level fault tolerance (fault module docs). No flag ⇒
    // `faults: None` and every fault branch in the plane is untaken
    // (bit-identical to a faultless build, property-tested).
    cfg.faults = fault_config(args)?;
    Ok(cfg)
}

/// Flags that install a fault plan; absent all of them the plane runs
/// with no plan at all.
const FAULT_KEYS: [&str; 10] = [
    "fault-seed",
    "fault-rate",
    "poison",
    "straggler-rate",
    "straggler-k",
    "retry-budget",
    "max-faults",
    "device-fail",
    "breaker",
    "shed",
];

fn parse_rate(key: &str, v: f64) -> Result<f64, String> {
    if !(0.0..=1.0).contains(&v) {
        return Err(format!("--{key}: probability must be in [0,1], got {v}"));
    }
    Ok(v)
}

/// Build an `Option<FaultConfig>` from the `--fault-*` flag family.
pub fn fault_config(args: &Args) -> Result<Option<FaultConfig>, String> {
    if !FAULT_KEYS.iter().any(|k| args.get(k).is_some()) {
        return Ok(None);
    }
    let mut fc = FaultConfig::default();
    fc.seed = args.get_usize("fault-seed", fc.seed as usize)? as u64;
    fc.transient_rate = parse_rate("fault-rate", args.get_f64("fault-rate", 0.0)?)?;
    if let Some(s) = args.get("poison") {
        for part in s.split(',').filter(|p| !p.is_empty()) {
            let (f, r) = part
                .split_once(':')
                .ok_or_else(|| format!("--poison: expected F:RATE, got {part}"))?;
            let func: u32 = f
                .parse()
                .map_err(|_| format!("--poison: bad function id in {part}"))?;
            let rate: f64 = r
                .parse()
                .map_err(|_| format!("--poison: bad rate in {part}"))?;
            fc.poison.push((FuncId(func), parse_rate("poison", rate)?));
        }
    }
    fc.straggler_rate = parse_rate("straggler-rate", args.get_f64("straggler-rate", 0.0)?)?;
    fc.straggler_k = args.get_f64("straggler-k", fc.straggler_k)?;
    if !(fc.straggler_k > 0.0 && fc.straggler_k.is_finite()) {
        return Err(format!("--straggler-k must be > 0, got {}", fc.straggler_k));
    }
    let budget = args.get_usize("retry-budget", fc.retry_budget as usize)?;
    if budget == 0 {
        return Err("--retry-budget must be >= 1 (the first run counts)".into());
    }
    fc.retry_budget = budget as u32;
    fc.max_faults = args.get_usize("max-faults", fc.max_faults as usize)? as u64;
    if let Some(s) = args.get("device-fail") {
        for part in s.split(',').filter(|p| !p.is_empty()) {
            let (t, g) = part
                .split_once(':')
                .ok_or_else(|| format!("--device-fail: expected T_S:GPU, got {part}"))?;
            let t_s: f64 = t
                .parse()
                .map_err(|_| format!("--device-fail: bad time in {part}"))?;
            if !(t_s >= 0.0 && t_s.is_finite()) {
                return Err(format!("--device-fail: time must be >= 0 in {part}"));
            }
            let gpu: u32 = g
                .parse()
                .map_err(|_| format!("--device-fail: bad gpu in {part}"))?;
            fc.device_failures.push((secs(t_s), GpuId(gpu)));
        }
    }
    if let Some(s) = args.get("breaker") {
        let parts: Vec<&str> = s.split(':').collect();
        let [w, th, cd] = parts[..] else {
            return Err(format!("--breaker: expected WINDOW:THRESH:COOLDOWN_S, got {s}"));
        };
        let window: usize = w
            .parse()
            .map_err(|_| format!("--breaker: bad window in {s}"))?;
        let thresh: f64 = th
            .parse()
            .map_err(|_| format!("--breaker: bad threshold in {s}"))?;
        let cooldown_s: f64 = cd
            .parse()
            .map_err(|_| format!("--breaker: bad cooldown in {s}"))?;
        if window == 0 || window > 64 {
            return Err(format!("--breaker: window must be 1..=64, got {window}"));
        }
        if !(thresh > 0.0 && thresh <= 1.0) {
            return Err(format!("--breaker: threshold must be in (0,1], got {thresh}"));
        }
        if !(cooldown_s > 0.0 && cooldown_s.is_finite()) {
            return Err(format!("--breaker: cooldown must be > 0 s, got {cooldown_s}"));
        }
        fc.breaker = Some(BreakerConfig {
            window,
            trip_threshold: thresh,
            cooldown: secs(cooldown_s),
            ..Default::default()
        });
    }
    if let Some(s) = args.get("shed") {
        let deadline_s: f64 = s
            .parse()
            .map_err(|_| format!("--shed: bad deadline {s}"))?;
        if !(deadline_s > 0.0 && deadline_s.is_finite()) {
            return Err(format!("--shed: deadline must be > 0 s, got {deadline_s}"));
        }
        fc.shed = Some(ShedConfig {
            deadline_s,
            ..Default::default()
        });
    }
    Ok(Some(fc))
}

/// Parse `--adaptive-d MIN:MAX` (or a single `N`, meaning `N:N`): the
/// Little's-law concurrency-controller bounds. Takes precedence over
/// the static `--d`.
fn parse_adaptive_d(s: &str) -> Result<(usize, usize), String> {
    let (lo, hi) = match s.split_once(':') {
        Some((lo, hi)) => (
            lo.parse::<usize>().map_err(|_| format!("--adaptive-d: bad MIN in {s}"))?,
            hi.parse::<usize>().map_err(|_| format!("--adaptive-d: bad MAX in {s}"))?,
        ),
        None => {
            let n = s.parse::<usize>().map_err(|_| format!("--adaptive-d: bad bound {s}"))?;
            (n, n)
        }
    };
    if lo == 0 || hi < lo {
        return Err(format!("--adaptive-d: need 1 <= MIN <= MAX, got {s}"));
    }
    Ok((lo, hi))
}

/// Entry point called by main(). Returns process exit code.
pub fn run(argv: Vec<String>) -> i32 {
    match dispatch(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{USAGE}");
            1
        }
    }
}

fn dispatch(argv: Vec<String>) -> Result<(), String> {
    let Some((cmd, rest)) = argv.split_first() else {
        return Err("no subcommand".into());
    };
    let args = Args::parse(rest)?;
    match cmd.as_str() {
        "exp" => cmd_exp(&args),
        "trace" => cmd_trace(&args),
        "replay" => cmd_replay(&args),
        "cluster" => cmd_cluster(&args),
        "hetero" => cmd_hetero(&args),
        "serve" => cmd_serve(&args),
        "invoke" => cmd_invoke(&args),
        "admin" => cmd_admin(&args),
        "validate" => cmd_validate(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown subcommand {other}")),
    }
}

fn cmd_exp(args: &Args) -> Result<(), String> {
    let name = args
        .positional
        .first()
        .ok_or("exp: which experiment? (or `all`, `list`)")?;
    match name.as_str() {
        "list" => {
            for (n, _) in crate::experiments::ALL {
                println!("{n}");
            }
            Ok(())
        }
        "all" => {
            for (n, f) in crate::experiments::ALL {
                println!("\n### {n}");
                f();
            }
            Ok(())
        }
        n => match crate::experiments::by_name(n) {
            Some(f) => {
                f();
                Ok(())
            }
            None => Err(format!("unknown experiment {n} (try `exp list`)")),
        },
    }
}

fn cmd_trace(args: &Args) -> Result<(), String> {
    if args.positional.first().map(|s| s.as_str()) != Some("gen") {
        return Err("trace: only `trace gen` is supported".into());
    }
    let out = args.get("out").ok_or("trace gen: --out FILE required")?;
    let (workload, trace) = match args.get("kind").unwrap_or("zipf") {
        "zipf" => zipf::generate(&ZipfConfig {
            n_funcs: args.get_usize("funcs", 24)?,
            total_rate: args.get_f64("rate", 2.0)?,
            duration_s: args.get_f64("duration", 600.0)?,
            seed: args.get_usize("seed", 0)? as u64,
            ..Default::default()
        }),
        "azure" => crate::workload::azure::generate(&AzureConfig {
            trace_id: args.get_usize("trace-id", 4)?,
            duration_s: args.get_f64("duration", 600.0)?,
            load_scale: args.get_f64("scale", 1.0)?,
        }),
        k => return Err(format!("unknown trace kind {k}")),
    };
    trace
        .save(&workload, out)
        .map_err(|e| format!("saving {out}: {e}"))?;
    println!(
        "wrote {} events / {} functions ({:.2} req/s) to {out}",
        trace.len(),
        workload.len(),
        trace.req_per_sec()
    );
    Ok(())
}

fn cmd_replay(args: &Args) -> Result<(), String> {
    let path = args.get("trace").ok_or("replay: --trace FILE required")?;
    let (workload, trace) =
        Trace::load(path).map_err(|e| format!("loading {path}: {e}"))?;
    let cfg = plane_config(args)?;
    let label = format!("{} D={}", cfg.policy.name(), cfg.d);
    // `--trace-out FILE`: attach a telemetry instance and sink the
    // lifecycle trace as JSONL. The ring is sized to the trace so a sim
    // replay never drops events (determinism makes the file a property:
    // same trace + config ⇒ byte-identical output).
    let tel = args.get("trace-out").map(|_| {
        let cap = trace
            .len()
            .saturating_mul(32)
            .max(crate::telemetry::DEFAULT_RING_CAPACITY);
        let (classes, _) = crate::telemetry::workload_classes(&workload);
        std::sync::Arc::new(crate::telemetry::Telemetry::with_ring_capacity(
            &[cfg.n_devices()],
            &classes,
            cap,
        ))
    });
    let t0 = std::time::Instant::now();
    let (summary, r) =
        crate::experiments::run_traced(&label, workload, &trace, cfg, tel.clone());
    let wall = t0.elapsed();
    print!(
        "{}",
        crate::experiments::summary_table(std::slice::from_ref(&summary)).render()
    );
    println!(
        "replayed {} events in {wall:.2?} ({:.0} events/s of sim time)",
        r.events,
        r.events as f64 / wall.as_secs_f64().max(1e-9)
    );
    if let (Some(out), Some(tel)) = (args.get("trace-out"), tel) {
        let events = tel.trace.drain(usize::MAX);
        let mut buf = String::with_capacity(events.len() * 96);
        for ev in &events {
            ev.render_jsonl_into(&mut buf);
            buf.push('\n');
        }
        std::fs::write(out, buf).map_err(|e| format!("writing {out}: {e}"))?;
        println!(
            "wrote {} trace events to {out} ({} dropped by the ring)",
            events.len(),
            tel.dropped_events()
        );
    }
    Ok(())
}

/// Build a ClusterConfig from `cluster` options (per-shard plane
/// options are shared with `replay`).
pub fn cluster_config(args: &Args) -> Result<ClusterConfig, String> {
    let defaults = ClusterConfig::default();
    let n_shards = args.get_usize("shards", defaults.n_shards)?;
    if n_shards == 0 {
        return Err("--shards must be >= 1".into());
    }
    if n_shards > 128 {
        return Err("--shards must be <= 128 (StickyCh ring bound)".into());
    }
    let router = match args.get("router") {
        Some(r) => RouterKind::parse(r).ok_or_else(|| format!("unknown router {r}"))?,
        None => defaults.router,
    };
    let load_factor = args.get_f64("load-factor", defaults.load_factor)?;
    if !(load_factor > 0.0 && load_factor.is_finite()) {
        return Err(format!("--load-factor must be a positive number, got {load_factor}"));
    }
    Ok(ClusterConfig {
        n_shards,
        router,
        plane: plane_config(args)?,
        shard_planes: Vec::new(),
        load_factor,
        seed: args.get_usize("seed", defaults.seed as usize)? as u64,
        graveyard_cap: defaults.graveyard_cap,
    })
}

/// Run the fig10 heterogeneous-fleet sweep with optional overrides.
fn cmd_hetero(args: &Args) -> Result<(), String> {
    let defaults = crate::experiments::hetero::SweepConfig::default();
    let load_factor = args.get_f64("load-factor", defaults.load_factor)?;
    if !(load_factor > 0.0 && load_factor.is_finite()) {
        return Err(format!("--load-factor must be a positive number, got {load_factor}"));
    }
    let cfg = crate::experiments::hetero::SweepConfig {
        per_capacity_rate: args.get_f64("rate", defaults.per_capacity_rate)?,
        duration_s: args.get_f64("duration", defaults.duration_s)?,
        n_funcs: args.get_usize("funcs", defaults.n_funcs)?,
        seed: args.get_usize("seed", defaults.seed as usize)? as u64,
        load_factor,
        ..defaults
    };
    crate::experiments::hetero::run(&cfg);
    Ok(())
}

fn cmd_cluster(args: &Args) -> Result<(), String> {
    let cfg = cluster_config(args)?;
    let (workload, trace) = match args.get("trace") {
        Some(path) => Trace::load(path).map_err(|e| format!("loading {path}: {e}"))?,
        None => {
            // Generated zipf trace: --rate is per shard (weak scaling).
            let mut pair = zipf::generate(&ZipfConfig {
                n_funcs: args.get_usize("funcs", 24)?,
                total_rate: args.get_f64("rate", 2.0)?,
                duration_s: args.get_f64("duration", 600.0)?,
                seed: cfg.seed,
                ..Default::default()
            });
            crate::workload::scale_rate(&mut pair.0, &mut pair.1, cfg.n_shards as f64);
            pair
        }
    };
    let t0 = std::time::Instant::now();
    let r = crate::sim::replay_cluster(workload, &trace, cfg.clone());
    let wall = t0.elapsed();
    let row = crate::experiments::cluster::ClusterRow::measure(cfg.router, cfg.n_shards, &r);
    print!(
        "{}",
        crate::experiments::cluster::rows_table(std::slice::from_ref(&row)).render()
    );
    println!("per-shard arrivals: {:?}", r.cluster.routed);
    println!(
        "replayed {} events over {} shards in {wall:.2?} ({:.0} events/s of sim time)",
        r.events,
        cfg.n_shards,
        r.events as f64 / wall.as_secs_f64().max(1e-9)
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let addr = args.get("addr").unwrap_or("127.0.0.1:8077");
    let scale = args.get_f64("scale", 0.02)?;
    let artifacts = args.get("artifacts").map(std::path::Path::new);
    let max_pending = args.get_usize("max-pending", 0)?; // 0 = unlimited
    let workers = args.get_usize("workers", crate::server::DEFAULT_WORKERS)?;
    if workers == 0 {
        return Err("serve: --workers must be >= 1".into());
    }
    // 0 = keep the event loop's default outbound high-water mark.
    let max_outbound = args.get_usize("max-outbound", 0)?;
    let mut loop_cfg = crate::server::event_loop::LoopConfig::default();
    if max_outbound > 0 {
        loop_cfg.max_outbound = max_outbound;
    }
    // Default demo workload: one copy of each catalog function.
    let mut w = crate::workload::Workload::default();
    for class in crate::workload::catalog::CATALOG {
        w.register(class, 0, 10.0);
    }
    let artifacts_label = artifacts
        .map(|p| p.display().to_string())
        .unwrap_or_else(|| "model-only".into());
    // --shards >1 (or an explicit --router) serves the sharded cluster
    // frontend; otherwise the single-plane server.
    let clustered =
        args.get_usize("shards", 1)? > 1 || args.get("router").is_some();
    let local = if clustered {
        let cfg = cluster_config(args)?;
        let srv =
            crate::server::RtCluster::with_workers(w, cfg.clone(), artifacts, scale, workers)
                .map_err(|e| format!("starting cluster server: {e}"))?;
        if max_pending > 0 {
            srv.set_max_pending(max_pending);
        }
        let local = srv
            .serve_cfg(addr, loop_cfg)
            .map_err(|e| format!("binding {addr}: {e}"))?;
        println!(
            "serving rt-cluster on {local}: {} shards, router {}, scale={scale}, \
             artifacts={artifacts_label}",
            cfg.n_shards,
            cfg.router.name()
        );
        std::mem::forget(srv); // keep the guard alive for the process lifetime
        local
    } else {
        let cfg = plane_config(args)?;
        let srv = crate::server::RtServer::with_workers(w, cfg, artifacts, scale, workers)
            .map_err(|e| format!("starting server: {e}"))?;
        if max_pending > 0 {
            srv.set_max_pending(max_pending);
        }
        let local = srv
            .serve_cfg(addr, loop_cfg)
            .map_err(|e| format!("binding {addr}: {e}"))?;
        println!(
            "serving rt-server on {local} (scale={scale}, artifacts={artifacts_label})"
        );
        std::mem::forget(srv);
        local
    };
    println!(
        "protocol v1 (JSON lines): {{\"cmd\":\"hello\",\"v\":1}} | invoke/wait/poll/\
         describe/stats; legacy `invoke <fn>` | `stats` | `quit` kept — \
         try: mqfq-sticky invoke isoneural-0 --addr {local}"
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Protocol-v1 client: drive a running `serve` over TCP.
fn cmd_invoke(args: &Args) -> Result<(), String> {
    let func = args
        .positional
        .first()
        .ok_or("invoke: which function? (see `serve` output or `describe`)")?;
    let addr = args.get("addr").unwrap_or("127.0.0.1:8077");
    let n = args.get_usize("n", 1)?;
    let deadline_ms = match args.get_usize("deadline-ms", 0)? {
        0 => None,
        d => Some(d as u64),
    };
    let retries = args.get_usize("retries", 0)?;
    // `--push 1` subscribes at submit and waits on server-push
    // completions; `--pipeline B` submits in tagged batches of B.
    let push = matches!(args.get("push"), Some("1" | "true" | "yes" | "on"));
    let pipeline = args.get_usize("pipeline", 0)?; // 0 = lockstep
    if push && pipeline > 0 {
        return Err("invoke: --push and --pipeline are mutually exclusive".into());
    }
    let mut client = crate::api::ApiClient::connect(addr)
        .map_err(|e| format!("connecting {addr}: {e}"))?;
    if retries > 0 {
        client.set_retry(crate::api::RetryPolicy::new(retries as u32));
    }
    let print_outcome = |o: &crate::api::InvokeOutcome| {
        println!(
            "{} {}: {} on shard {} gpu{}  latency {:.1} ms  exec {:.1} ms",
            o.ticket, o.func, o.start_kind, o.shard, o.gpu, o.latency_ms, o.exec_ms
        );
    };
    if push {
        let tickets: Vec<_> = (0..n)
            .map(|_| client.invoke_push(func))
            .collect::<Result<_, _>>()
            .map_err(|e| format!("invoke {func}: {e}"))?;
        println!("submitted {n} push-subscribed invocation(s) of {func}");
        for t in tickets {
            let o = client
                .wait_push(t)
                .map_err(|e| format!("wait-push {t}: {e}"))?;
            print_outcome(&o);
        }
    } else if pipeline > 0 {
        let mut done = 0usize;
        while done < n {
            let batch = pipeline.min(n - done);
            let funcs: Vec<&str> = std::iter::repeat(func.as_str()).take(batch).collect();
            let tickets = client
                .pipeline_invoke_async(&funcs)
                .map_err(|e| format!("pipeline invoke {func}: {e}"))?;
            for t in tickets {
                let o = client
                    .wait(t, deadline_ms)
                    .map_err(|e| format!("wait {t}: {e}"))?;
                print_outcome(&o);
            }
            done += batch;
        }
        println!("pipelined {n} invocation(s) of {func} in batches of {pipeline}");
    } else {
        match args.get("mode").unwrap_or("sync") {
            "sync" => {
                for _ in 0..n {
                    let o = client
                        .invoke(func, deadline_ms)
                        .map_err(|e| format!("invoke {func}: {e}"))?;
                    print_outcome(&o);
                }
            }
            "async" => {
                let tickets: Vec<_> = (0..n)
                    .map(|_| client.invoke_async(func))
                    .collect::<Result<_, _>>()
                    .map_err(|e| format!("invoke {func}: {e}"))?;
                println!("submitted {n} async invocation(s) of {func}");
                for t in tickets {
                    let o = client
                        .wait(t, deadline_ms)
                        .map_err(|e| format!("wait {t}: {e}"))?;
                    print_outcome(&o);
                }
            }
            m => return Err(format!("unknown mode {m} (sync|async)")),
        }
    }
    let s = client.stats().map_err(|e| format!("stats: {e}"))?;
    println!(
        "server stats: {} invocations, mean latency {:.1} ms, cold ratio {:.3}, \
         {} pending, {} in flight",
        s.invocations, s.mean_latency_ms, s.cold_ratio, s.pending, s.in_flight
    );
    client.quit();
    Ok(())
}

/// Admin client over the v1 wire protocol: elastic membership
/// (drain/join/kill/membership) and observability (metrics/trace).
fn cmd_admin(args: &Args) -> Result<(), String> {
    let verb = args
        .positional
        .first()
        .ok_or("admin: which verb? (drain|join|kill|membership|metrics|trace)")?
        .as_str();
    let addr = args.get("addr").unwrap_or("127.0.0.1:8077");
    // Observability verbs: print-and-return, no membership snapshot.
    if verb == "metrics" || verb == "trace" {
        let mut client = crate::api::ApiClient::connect(addr)
            .map_err(|e| format!("connecting {addr}: {e}"))?;
        if verb == "metrics" {
            let format = match args.get("format").unwrap_or("prom") {
                "prom" => crate::api::MetricsFormat::Prom,
                "json" => crate::api::MetricsFormat::Json,
                f => return Err(format!("--format: prom|json, got {f}")),
            };
            let body = client
                .metrics(format)
                .map_err(|e| format!("admin metrics: {e}"))?;
            print!("{body}");
            if !body.ends_with('\n') {
                println!();
            }
        } else {
            let max = args.get_usize("max", usize::MAX)?;
            let (dropped, events) = client
                .trace(max)
                .map_err(|e| format!("admin trace: {e}"))?;
            let mut line = String::new();
            for ev in &events {
                line.clear();
                ev.render_jsonl_into(&mut line);
                println!("{line}");
            }
            eprintln!("{} events ({dropped} dropped by the ring)", events.len());
        }
        client.quit();
        return Ok(());
    }
    // Shard index: positional (`admin kill 1`) or `--shard 1`.
    let shard = match args.positional.get(1) {
        Some(s) => Some(
            s.parse::<usize>()
                .map_err(|_| format!("admin {verb}: bad shard {s}"))?,
        ),
        None => match args.get("shard") {
            Some(s) => Some(
                s.parse::<usize>()
                    .map_err(|_| format!("--shard: bad integer {s}"))?,
            ),
            None => None,
        },
    };
    let need = || format!("admin {verb}: shard required (`admin {verb} SHARD` or --shard N)");
    let mut client = crate::api::ApiClient::connect(addr)
        .map_err(|e| format!("connecting {addr}: {e}"))?;
    let m = match verb {
        "drain" => client.drain(shard.ok_or_else(need)?),
        "join" => client.join(shard.ok_or_else(need)?),
        "kill" => client.kill(shard.ok_or_else(need)?),
        "membership" => client.membership(),
        v => {
            return Err(format!(
                "unknown admin verb {v} (drain|join|kill|membership|metrics|trace)"
            ))
        }
    }
    .map_err(|e| format!("admin {verb}: {e}"))?;
    print_membership(&m);
    client.quit();
    Ok(())
}

fn print_membership(m: &crate::api::MembershipInfo) {
    println!("membership epoch {}", m.epoch);
    println!(
        "{:<6} {:<9} {:>6} {:>8} {:>10} {:>9}",
        "shard", "health", "epoch", "pending", "in-flight", "capacity"
    );
    for s in &m.shards {
        println!(
            "{:<6} {:<9} {:>6} {:>8} {:>10} {:>9.2}",
            s.shard,
            s.health.name(),
            s.epoch,
            s.pending,
            s.in_flight,
            s.capacity
        );
    }
    println!(
        "fates: accepted {} = completed {} + failed {} + outstanding {} \
         (rejected {}, stale drops {})",
        m.accepted,
        m.completed,
        m.failed,
        m.outstanding(),
        m.rejected,
        m.stale_drops
    );
}

fn cmd_validate(args: &Args) -> Result<(), String> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let mut rt = crate::runtime::PjrtRuntime::new(dir)
        .map_err(|e| format!("PJRT: {e}"))?;
    let names = rt.load_all().map_err(|e| format!("loading {dir}: {e}"))?;
    println!("platform: {}", rt.platform());
    let mut failed = 0;
    for name in &names {
        match rt.validate(name) {
            Ok(rep) => println!("  ok   {name:<12} ({:?})", rep.elapsed),
            Err(e) => {
                println!("  FAIL {name:<12} {e}");
                failed += 1;
            }
        }
    }
    if failed > 0 {
        return Err(format!("{failed}/{} artifacts failed validation", names.len()));
    }
    println!("all {} artifacts validated against golden outputs", names.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_options_and_positionals() {
        let a = Args::parse(&argv("gen --kind zipf --rate 2.5 extra")).unwrap();
        assert_eq!(a.positional, vec!["gen", "extra"]);
        assert_eq!(a.get("kind"), Some("zipf"));
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize("funcs", 24).unwrap(), 24);
    }

    #[test]
    fn missing_value_rejected() {
        assert!(Args::parse(&argv("--rate")).is_err());
    }

    #[test]
    fn plane_config_parses_modes() {
        let a = Args::parse(&argv("--policy fcfs --d 3 --mode mig:2 --mem madvise")).unwrap();
        let cfg = plane_config(&a).unwrap();
        assert_eq!(cfg.policy, PolicyKind::Fcfs);
        assert_eq!(cfg.d, 3);
        assert_eq!(cfg.devices, uniform_fleet(1, crate::gpu::V100, MultiplexMode::Mig(2)));
        assert_eq!(cfg.mem_policy, MemPolicy::Madvise);
        // Legacy triple: --gpus/--profile/--mode assemble a uniform fleet.
        let a = Args::parse(&argv("--gpus 2 --profile a30 --mode mps")).unwrap();
        let cfg = plane_config(&a).unwrap();
        assert_eq!(cfg.devices, uniform_fleet(2, crate::gpu::A30, MultiplexMode::Mps));
    }

    #[test]
    fn fleet_option_builds_mixed_hardware() {
        let a = Args::parse(&argv("--fleet 2xv100,a30:mig2,v100:mps:d1")).unwrap();
        let cfg = plane_config(&a).unwrap();
        assert_eq!(cfg.devices.len(), 4);
        assert_eq!(cfg.devices[0], DeviceSpec::new(crate::gpu::V100, MultiplexMode::Plain));
        assert_eq!(cfg.devices[1], cfg.devices[0]);
        assert_eq!(
            cfg.devices[2],
            DeviceSpec::new(crate::gpu::A30, MultiplexMode::Mig(2))
        );
        assert_eq!(
            cfg.devices[3],
            DeviceSpec::new(crate::gpu::V100, MultiplexMode::Mps).with_d(1)
        );
        // --fleet wins over the legacy triple.
        let a = Args::parse(&argv("--fleet a30 --gpus 4 --profile v100")).unwrap();
        assert_eq!(
            plane_config(&a).unwrap().devices,
            vec![DeviceSpec::new(crate::gpu::A30, MultiplexMode::Plain)]
        );
    }

    #[test]
    fn bad_fleet_specs_rejected() {
        for bad in [
            "--fleet bogus",
            "--fleet v100:mig0",
            "--fleet v100:d0",
            "--fleet 0xv100",
            "--fleet v100:warp9",
            "--fleet ,",
            "--mode mig:0",
            "--gpus 0",
        ] {
            let a = Args::parse(&argv(bad)).unwrap();
            assert!(plane_config(&a).is_err(), "{bad} should be rejected");
        }
    }

    #[test]
    fn anticipation_flags_parse_into_config() {
        // Defaults: everything off, static D.
        let a = Args::parse(&argv("--policy mqfq")).unwrap();
        let cfg = plane_config(&a).unwrap();
        assert!(!cfg.mqfq.anticipate.enabled());
        assert_eq!(cfg.adaptive_d, None);
        // Full set.
        let a = Args::parse(&argv(
            "--grace 2.0 --batch-max 4 --batch-marginal 0.5 --estimator on \
             --adaptive-d 2:8",
        ))
        .unwrap();
        let cfg = plane_config(&a).unwrap();
        assert_eq!(cfg.mqfq.anticipate.grace_alpha, 2.0);
        assert_eq!(cfg.mqfq.anticipate.batch_max, 4);
        assert_eq!(cfg.mqfq.anticipate.batch_marginal, 0.5);
        assert!(cfg.mqfq.anticipate.estimator);
        assert_eq!(cfg.adaptive_d, Some((2, 8)));
        // Single-bound form pins MIN = MAX.
        let a = Args::parse(&argv("--adaptive-d 4")).unwrap();
        assert_eq!(plane_config(&a).unwrap().adaptive_d, Some((4, 4)));
    }

    #[test]
    fn bad_anticipation_flags_rejected() {
        for bad in [
            "--grace -1",
            "--grace nan",
            "--batch-max 0",
            "--batch-marginal -0.5",
            "--estimator maybe",
            "--adaptive-d 0:4",
            "--adaptive-d 4:2",
            "--adaptive-d a:b",
            "--adaptive-d 0",
        ] {
            let a = Args::parse(&argv(bad)).unwrap();
            assert!(plane_config(&a).is_err(), "{bad} should be rejected");
        }
    }

    #[test]
    fn fault_flags_parse_into_config() {
        // No fault flag at all ⇒ no plan (the bit-identical neutral path).
        let a = Args::parse(&argv("--policy mqfq --d 2")).unwrap();
        assert!(plane_config(&a).unwrap().faults.is_none());
        // Full set.
        let a = Args::parse(&argv(
            "--fault-seed 9 --fault-rate 0.05 --poison 3:0.9,5:1.0 \
             --straggler-rate 0.01 --straggler-k 4 --retry-budget 2 \
             --max-faults 100 --device-fail 30:0,45.5:2 \
             --breaker 16:0.5:10 --shed 5.0",
        ))
        .unwrap();
        let fc = plane_config(&a).unwrap().faults.unwrap();
        assert_eq!(fc.seed, 9);
        assert_eq!(fc.transient_rate, 0.05);
        assert_eq!(fc.poison, vec![(FuncId(3), 0.9), (FuncId(5), 1.0)]);
        assert_eq!(fc.straggler_rate, 0.01);
        assert_eq!(fc.straggler_k, 4.0);
        assert_eq!(fc.retry_budget, 2);
        assert_eq!(fc.max_faults, 100);
        assert_eq!(
            fc.device_failures,
            vec![(secs(30.0), GpuId(0)), (secs(45.5), GpuId(2))]
        );
        let b = fc.breaker.unwrap();
        assert_eq!(b.window, 16);
        assert_eq!(b.trip_threshold, 0.5);
        assert_eq!(b.cooldown, secs(10.0));
        assert_eq!(fc.shed.unwrap().deadline_s, 5.0);
        // A single fault flag installs a plan with defaults elsewhere.
        let a = Args::parse(&argv("--fault-rate 0.1")).unwrap();
        let fc = plane_config(&a).unwrap().faults.unwrap();
        assert_eq!(fc.transient_rate, 0.1);
        assert_eq!(fc.retry_budget, FaultConfig::default().retry_budget);
        assert!(fc.breaker.is_none() && fc.shed.is_none());
    }

    #[test]
    fn bad_fault_flags_rejected() {
        for bad in [
            "--fault-rate 1.5",
            "--fault-rate -0.1",
            "--poison 3",
            "--poison a:0.5",
            "--poison 3:2.0",
            "--straggler-rate 2",
            "--straggler-k 0",
            "--retry-budget 0",
            "--device-fail 30",
            "--device-fail x:0",
            "--device-fail 30:a",
            "--device-fail -1:0",
            "--breaker 16:0.5",
            "--breaker 0:0.5:10",
            "--breaker 99:0.5:10",
            "--breaker 16:0:10",
            "--breaker 16:0.5:0",
            "--shed 0",
            "--shed nope",
        ] {
            let a = Args::parse(&argv(bad)).unwrap();
            assert!(plane_config(&a).is_err(), "{bad} should be rejected");
        }
    }

    #[test]
    fn bad_policy_rejected() {
        let a = Args::parse(&argv("--policy bogus")).unwrap();
        assert!(plane_config(&a).is_err());
    }

    #[test]
    fn cluster_config_parses_router_and_shards() {
        let a = Args::parse(&argv(
            "--shards 8 --router sticky --load-factor 1.5 --seed 7 --policy fcfs",
        ))
        .unwrap();
        let cfg = cluster_config(&a).unwrap();
        assert_eq!(cfg.n_shards, 8);
        assert_eq!(cfg.router, RouterKind::StickyCh);
        assert!((cfg.load_factor - 1.5).abs() < 1e-12);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.plane.policy, PolicyKind::Fcfs);
    }

    #[test]
    fn bad_cluster_options_rejected() {
        for bad in [
            "--router bogus",
            "--shards 0",
            "--shards 200",          // beyond the StickyCh ring bound
            "--load-factor 0",
            "--load-factor -1.5",
        ] {
            let a = Args::parse(&argv(bad)).unwrap();
            assert!(cluster_config(&a).is_err(), "{bad} should be rejected");
        }
    }

    #[test]
    fn cluster_subcommand_runs_small_replay() {
        let a = Args::parse(&argv(
            "--shards 2 --router least --funcs 4 --rate 1.0 --duration 20",
        ))
        .unwrap();
        cmd_cluster(&a).unwrap();
    }

    #[test]
    fn admin_verbs_roundtrip_against_live_cluster() {
        let mut w = crate::workload::Workload::default();
        w.register(
            crate::workload::catalog::by_name("isoneural").unwrap(),
            0,
            1.0,
        );
        let cfg = ClusterConfig {
            n_shards: 3,
            router: RouterKind::RoundRobin,
            plane: PlaneConfig::default(),
            ..Default::default()
        };
        let srv = crate::server::RtCluster::new(w, cfg, None, 1e-6).unwrap();
        let addr = srv.serve("127.0.0.1:0").unwrap();
        for cmd in [
            format!("drain 1 --addr {addr}"),
            format!("join 1 --addr {addr}"),
            format!("kill 2 --addr {addr}"),
            format!("join 2 --addr {addr}"),
            format!("membership --addr {addr}"),
            format!("drain --shard 1 --addr {addr}"), // --shard form
            format!("join 1 --addr {addr}"),
            // Observability verbs ride the same client.
            format!("metrics --addr {addr}"),
            format!("metrics --format json --addr {addr}"),
            format!("trace --max 16 --addr {addr}"),
            format!("trace --addr {addr}"),
        ] {
            let a = Args::parse(&argv(&cmd)).unwrap();
            cmd_admin(&a).unwrap_or_else(|e| panic!("{cmd}: {e}"));
        }
        // Missing shard, bad shard, unknown verb, bad format rejected.
        for bad in [
            format!("drain --addr {addr}"),
            format!("kill nine --addr {addr}"),
            format!("explode 1 --addr {addr}"),
            format!("metrics --format yaml --addr {addr}"),
        ] {
            let a = Args::parse(&argv(&bad)).unwrap();
            assert!(cmd_admin(&a).is_err(), "{bad} should be rejected");
        }
    }

    #[test]
    fn trace_gen_and_replay_roundtrip() {
        let dir = std::env::temp_dir().join("mqfq_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.trace");
        let a = Args::parse(&argv(&format!(
            "gen --kind zipf --funcs 4 --rate 1.0 --duration 30 --out {}",
            path.display()
        )))
        .unwrap();
        cmd_trace(&a).unwrap();
        let b = Args::parse(&argv(&format!("--trace {} --policy mqfq", path.display())))
            .unwrap();
        cmd_replay(&b).unwrap();
        // --trace-out sinks the lifecycle trace as JSONL; determinism
        // makes two runs byte-identical.
        let out1 = dir.join("t1.jsonl");
        let out2 = dir.join("t2.jsonl");
        for out in [&out1, &out2] {
            let c = Args::parse(&argv(&format!(
                "--trace {} --policy mqfq --trace-out {}",
                path.display(),
                out.display()
            )))
            .unwrap();
            cmd_replay(&c).unwrap();
        }
        let j1 = std::fs::read_to_string(&out1).unwrap();
        let j2 = std::fs::read_to_string(&out2).unwrap();
        assert!(!j1.is_empty());
        assert_eq!(j1, j2, "sim trace must be deterministic");
        assert!(j1.lines().all(|l| l.starts_with("{\"seq\":")));
        assert!(j1.contains("\"kind\":\"submit\""));
        assert!(j1.contains("\"kind\":\"complete\""));
        std::fs::remove_dir_all(&dir).ok();
    }
}
