//! Workload + trace representation and file IO.
//!
//! A [`Workload`] is a set of registered functions (copies of catalog
//! classes, each with its own arrival process — the paper runs e.g. 24
//! function copies per experiment, §6). A [`Trace`] is the open-loop
//! invocation timeline generated from it: invocations fire at
//! pre-determined timestamps regardless of completion (as in §6.2).

use anyhow::{anyhow, Context, Result};
use std::path::Path;

use crate::types::{secs, to_secs, FuncId, Nanos};
use crate::workload::catalog::{self, FuncClass};

/// One registered function: a catalog class plus workload identity.
#[derive(Debug, Clone)]
pub struct WorkloadFunc {
    pub id: FuncId,
    /// Unique registered name, e.g. `fft-3` (third copy of fft).
    pub name: String,
    pub class: &'static FuncClass,
    /// Mean inter-arrival time used to generate this function's arrivals.
    pub mean_iat_s: f64,
}

/// A set of registered functions.
#[derive(Debug, Clone, Default)]
pub struct Workload {
    pub funcs: Vec<WorkloadFunc>,
}

impl Workload {
    pub fn func(&self, id: FuncId) -> &WorkloadFunc {
        &self.funcs[id.0 as usize]
    }

    pub fn len(&self) -> usize {
        self.funcs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.funcs.is_empty()
    }

    /// Register a new function copy of `class`; returns its id.
    pub fn register(&mut self, class: &'static FuncClass, copy: usize, mean_iat_s: f64) -> FuncId {
        let id = FuncId(self.funcs.len() as u32);
        self.funcs.push(WorkloadFunc {
            id,
            name: format!("{}-{copy}", class.name),
            class,
            mean_iat_s,
        });
        id
    }
}

/// One open-loop arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub at: Nanos,
    pub func: FuncId,
}

/// An open-loop trace: arrivals sorted by timestamp.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn duration(&self) -> Nanos {
        self.events.last().map(|e| e.at).unwrap_or(0)
    }

    /// Mean offered load in requests/second.
    pub fn req_per_sec(&self) -> f64 {
        if self.events.len() < 2 {
            return 0.0;
        }
        self.events.len() as f64 / to_secs(self.duration()).max(1e-9)
    }

    pub fn sort(&mut self) {
        self.events.sort_by_key(|e| (e.at, e.func));
    }

    /// Per-function invocation counts.
    pub fn counts(&self, nfuncs: usize) -> Vec<usize> {
        let mut counts = vec![0usize; nfuncs];
        for e in &self.events {
            counts[e.func.0 as usize] += 1;
        }
        counts
    }

    /// Serialize workload + trace to a simple text format:
    /// `func <class> <copy> <mean_iat_s>` lines, then `ev <t_s> <fid>`.
    pub fn save<P: AsRef<Path>>(&self, workload: &Workload, path: P) -> Result<()> {
        let mut out = String::new();
        for f in &workload.funcs {
            let copy = f
                .name
                .rsplit('-')
                .next()
                .and_then(|s| s.parse::<usize>().ok())
                .unwrap_or(0);
            out.push_str(&format!(
                "func {} {} {:.9}\n",
                f.class.name, copy, f.mean_iat_s
            ));
        }
        for e in &self.events {
            out.push_str(&format!("ev {:.9} {}\n", to_secs(e.at), e.func.0));
        }
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&path, out)
            .with_context(|| format!("writing {}", path.as_ref().display()))?;
        Ok(())
    }

    /// Load a workload + trace saved by [`Self::save`].
    pub fn load<P: AsRef<Path>>(path: P) -> Result<(Workload, Trace)> {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        let mut workload = Workload::default();
        let mut trace = Trace::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let ctx = || format!("trace line {}", lineno + 1);
            match parts.next().unwrap() {
                "func" => {
                    let class_name = parts.next().ok_or_else(|| anyhow!("{}: class", ctx()))?;
                    let copy: usize = parts
                        .next()
                        .ok_or_else(|| anyhow!("{}: copy", ctx()))?
                        .parse()?;
                    let iat: f64 = parts
                        .next()
                        .ok_or_else(|| anyhow!("{}: iat", ctx()))?
                        .parse()?;
                    let class = catalog::by_name(class_name)
                        .ok_or_else(|| anyhow!("{}: unknown class {class_name}", ctx()))?;
                    workload.register(class, copy, iat);
                }
                "ev" => {
                    let t: f64 = parts
                        .next()
                        .ok_or_else(|| anyhow!("{}: time", ctx()))?
                        .parse()?;
                    let fid: u32 = parts
                        .next()
                        .ok_or_else(|| anyhow!("{}: func id", ctx()))?
                        .parse()?;
                    if fid as usize >= workload.len() {
                        return Err(anyhow!("{}: func id {fid} out of range", ctx()));
                    }
                    trace.events.push(TraceEvent {
                        at: secs(t),
                        func: FuncId(fid),
                    });
                }
                other => return Err(anyhow!("{}: unknown tag {other}", ctx())),
            }
        }
        trace.sort();
        Ok((workload, trace))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> (Workload, Trace) {
        let mut w = Workload::default();
        let a = w.register(catalog::by_name("fft").unwrap(), 0, 1.0);
        let b = w.register(catalog::by_name("imagenet").unwrap(), 0, 2.0);
        let mut t = Trace::default();
        t.events.push(TraceEvent { at: secs(0.5), func: a });
        t.events.push(TraceEvent { at: secs(0.1), func: b });
        t.events.push(TraceEvent { at: secs(1.5), func: a });
        t.sort();
        (w, t)
    }

    #[test]
    fn sort_orders_by_time() {
        let (_, t) = tiny();
        assert_eq!(t.events[0].func, FuncId(1));
        assert!(t.events.windows(2).all(|w| w[0].at <= w[1].at));
    }

    #[test]
    fn counts_per_function() {
        let (w, t) = tiny();
        assert_eq!(t.counts(w.len()), vec![2, 1]);
    }

    #[test]
    fn req_per_sec_sane() {
        let (_, t) = tiny();
        assert!((t.req_per_sec() - 3.0 / 1.5).abs() < 1e-9);
    }

    #[test]
    fn save_load_roundtrip() {
        let (w, t) = tiny();
        let path = std::env::temp_dir().join("mqfq_trace_test/trace.txt");
        t.save(&w, &path).unwrap();
        let (w2, t2) = Trace::load(&path).unwrap();
        assert_eq!(w2.len(), w.len());
        assert_eq!(t2.events, t.events);
        assert_eq!(w2.funcs[0].class.name, "fft");
        assert!((w2.funcs[0].mean_iat_s - 1.0).abs() < 1e-9);
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn load_rejects_bad_func_id() {
        let path = std::env::temp_dir().join("mqfq_trace_test2/bad.txt");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, "func fft 0 1.0\nev 0.5 7\n").unwrap();
        assert!(Trace::load(&path).is_err());
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }
}
