//! Zipfian open-loop workload generator (§6 "Zipfian" class).
//!
//! The paper: "the inter-arrival-times of each function are
//! exponentially distributed, and the average arrival rates of different
//! functions are zipfian (parameter=1.5)", with 24 function copies drawn
//! from the Table-1 catalog.

use crate::types::{secs, FuncId};
use crate::util::rng::{zipf_weights, Rng};
use crate::workload::catalog::{self, FuncClass};
use crate::workload::trace::{Trace, TraceEvent, Workload};

/// Parameters of a Zipfian workload.
#[derive(Debug, Clone)]
pub struct ZipfConfig {
    /// Number of function copies (paper default: 24).
    pub n_funcs: usize,
    /// Zipf exponent over function popularity (paper: 1.5).
    pub s: f64,
    /// Total offered arrival rate across all functions, req/s.
    pub total_rate: f64,
    /// Trace duration, seconds.
    pub duration_s: f64,
    /// RNG seed.
    pub seed: u64,
    /// Optional filter over catalog classes (e.g. "large functions only",
    /// Fig 5c's warm-exec > some threshold variant).
    pub class_filter: Option<fn(&FuncClass) -> bool>,
}

impl Default for ZipfConfig {
    fn default() -> Self {
        Self {
            n_funcs: 24,
            s: 1.5,
            total_rate: 2.0,
            duration_s: 600.0,
            seed: 0,
            class_filter: None,
        }
    }
}

/// Generate the workload (function copies + zipf rates) and its trace.
pub fn generate(cfg: &ZipfConfig) -> (Workload, Trace) {
    let mut rng = Rng::new(cfg.seed);
    let classes: Vec<&'static FuncClass> = catalog::CATALOG
        .iter()
        .filter(|c| cfg.class_filter.map(|f| f(c)).unwrap_or(true))
        .collect();
    assert!(!classes.is_empty(), "class filter excluded everything");

    let weights = zipf_weights(cfg.n_funcs, cfg.s);
    let mut workload = Workload::default();
    let mut copies = vec![0usize; classes.len()];
    // Popular functions skew short (the web/ML-inference workloads this
    // class represents; also the Azure trace's signature — §2.1 "the
    // original Azure trace … is dominated by extremely short-running
    // functions"): popularity rank anti-correlates with execution time,
    // with noise so the correlation isn't perfect.
    let order = super::shortness_biased_assignment(&classes, cfg.n_funcs, &mut rng);
    for (rank, class_idx) in order.iter().enumerate() {
        let class = classes[*class_idx];
        let rate = weights[rank] * cfg.total_rate;
        let mean_iat = 1.0 / rate.max(1e-9);
        workload.register(class, copies[*class_idx], mean_iat);
        copies[*class_idx] += 1;
    }

    let trace = open_loop_poisson(&workload, cfg.duration_s, &mut rng);
    (workload, trace)
}

/// Parameters of the bursty on/off variant ([`generate_bursty`]).
#[derive(Debug, Clone)]
pub struct BurstyConfig {
    /// The Zipf population the bursts modulate.
    pub base: ZipfConfig,
    /// On-phase length, seconds.
    pub burst_s: f64,
    /// Off-phase (idle) length, seconds.
    pub idle_s: f64,
    /// Rate multiplier inside a burst (the off phase emits nothing), so
    /// a function's burst rate is `burst_factor × its zipf rate`.
    pub burst_factor: f64,
}

impl Default for BurstyConfig {
    fn default() -> Self {
        Self {
            base: ZipfConfig::default(),
            burst_s: 10.0,
            idle_s: 20.0,
            burst_factor: 6.0,
        }
    }
}

/// Generate a bursty on/off trace over the Zipf population: each
/// function cycles through `burst_s` seconds of Poisson arrivals at
/// `burst_factor ×` its zipf rate followed by `idle_s` seconds of
/// silence, with a per-function random phase shift so bursts overlap
/// partially rather than in lockstep. This is the anticipation
/// stress-shape: the idle gaps sit near the TTL boundary (grace
/// periods decide whether flows stay resident) and the on-phases queue
/// several same-flow invocations (batch dispatch gets coalescing
/// opportunities).
pub fn generate_bursty(cfg: &BurstyConfig) -> (Workload, Trace) {
    let (workload, _) = generate(&cfg.base);
    let mut rng = Rng::new(cfg.base.seed ^ 0x6275_7273_7479); // "bursty"
    let period = cfg.burst_s + cfg.idle_s;
    let mut trace = Trace::default();
    for f in &workload.funcs {
        let burst_rate = cfg.burst_factor / f.mean_iat_s.max(1e-9);
        let phase = rng.f64() * period;
        let mut t = rng.exp(1.0 / burst_rate);
        while t < cfg.base.duration_s {
            // Position within this function's phase-shifted cycle.
            let pos = (t + phase) % period;
            if pos < cfg.burst_s {
                trace.events.push(TraceEvent {
                    at: secs(t),
                    func: FuncId(f.id.0),
                });
                t += rng.exp(1.0 / burst_rate);
            } else {
                // Skip the off phase to the start of the next burst.
                t += period - pos + rng.exp(1.0 / burst_rate);
            }
        }
    }
    trace.sort();
    (workload, trace)
}

/// Build an open-loop trace with exponential IATs from per-function means.
pub fn open_loop_poisson(workload: &Workload, duration_s: f64, rng: &mut Rng) -> Trace {
    let mut trace = Trace::default();
    for f in &workload.funcs {
        let mut t = rng.exp(f.mean_iat_s); // random phase start
        while t < duration_s {
            trace.events.push(TraceEvent {
                at: secs(t),
                func: FuncId(f.id.0),
            });
            t += rng.exp(f.mean_iat_s);
        }
    }
    trace.sort();
    trace
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_shape() {
        let cfg = ZipfConfig {
            duration_s: 300.0,
            total_rate: 3.0,
            ..Default::default()
        };
        let (w, t) = generate(&cfg);
        assert_eq!(w.len(), 24);
        // Offered load should be near the configured total rate.
        let rps = t.len() as f64 / cfg.duration_s;
        assert!((rps - 3.0).abs() < 0.6, "rps {rps}");
    }

    #[test]
    fn popularity_is_heavy_tailed() {
        let cfg = ZipfConfig {
            duration_s: 2000.0,
            total_rate: 2.0,
            seed: 7,
            ..Default::default()
        };
        let (w, t) = generate(&cfg);
        let mut counts = t.counts(w.len());
        counts.sort_unstable_by(|a, b| b.cmp(a));
        // Top function should dominate the tail decisively (zipf 1.5).
        let top: usize = counts[0];
        let tail: usize = counts[12..].iter().sum();
        assert!(top > tail, "top {top} vs tail {tail}");
    }

    #[test]
    fn deterministic_for_seed() {
        let cfg = ZipfConfig::default();
        let (_, t1) = generate(&cfg);
        let (_, t2) = generate(&cfg);
        assert_eq!(t1.events, t2.events);
    }

    #[test]
    fn bursty_trace_has_gaps_and_bursts() {
        let cfg = BurstyConfig {
            base: ZipfConfig {
                n_funcs: 4,
                total_rate: 2.0,
                duration_s: 300.0,
                seed: 3,
                ..Default::default()
            },
            burst_s: 10.0,
            idle_s: 20.0,
            burst_factor: 6.0,
        };
        let (w, t) = generate_bursty(&cfg);
        assert_eq!(w.len(), 4);
        assert!(!t.events.is_empty());
        // Duty cycle 1/3 at 6× rate ⇒ offered load ≈ 2× the base rate.
        let rps = t.len() as f64 / cfg.base.duration_s;
        assert!(rps > 1.0 && rps < 10.0, "rps {rps}");
        // The most popular function's arrival stream must show real
        // silence (≥ half the idle phase) — the grace-period stressor.
        let f0: Vec<u64> = t
            .events
            .iter()
            .filter(|e| e.func == FuncId(0))
            .map(|e| e.at)
            .collect();
        assert!(f0.len() >= 8, "popular function arrivals: {}", f0.len());
        let max_gap = f0.windows(2).map(|p| p[1] - p[0]).max().unwrap();
        assert!(
            max_gap > secs(cfg.idle_s / 2.0),
            "max gap {max_gap} too small for idle_s {}",
            cfg.idle_s
        );
        // And bursts: some gap far below the burst-phase mean IAT.
        let min_gap = f0.windows(2).map(|p| p[1] - p[0]).min().unwrap();
        assert!(min_gap < secs(2.0), "min gap {min_gap}");
        // Deterministic for a seed.
        let (_, t2) = generate_bursty(&cfg);
        assert_eq!(t.events, t2.events);
    }

    #[test]
    fn class_filter_respected() {
        let cfg = ZipfConfig {
            class_filter: Some(|c: &FuncClass| c.gpu_warm_s > 1.0),
            ..Default::default()
        };
        let (w, _) = generate(&cfg);
        for f in &w.funcs {
            assert!(f.class.gpu_warm_s > 1.0, "{}", f.name);
        }
    }
}
