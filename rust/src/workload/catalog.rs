//! The serverless function catalog, calibrated from the paper.
//!
//! Sources (all from the paper):
//! * **Table 1** — warm/cold × GPU/CPU latencies per function (V100 +
//!   48-core Xeon 8160 baseline).
//! * **Figure 3** — CUDA-interposition/UVM shim overhead per function
//!   (negligible for most, ~30% for srad).
//! * **Figure 7b** — per-function slowdown on a half-GPU MIG slice
//!   (RNN/SRAD/FFT hit hardest).
//!
//! Memory footprints and compute intensities are not tabulated in the
//! paper; they are set to magnitudes consistent with its narrative (FFT
//! uses 1.5 GB in the Fig-4 experiment; V100 holds "only" 16 GB; ML
//! frameworks allocate GBs; utilization at trace 4 averages ~70%).

use crate::types::{secs, DurNanos};

/// Static calibration record for one function class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FuncClass {
    pub name: &'static str,
    /// Warm execution time on a full V100 (Table 1 "GPU [W]"), seconds.
    pub gpu_warm_s: f64,
    /// Warm execution time on one CPU core (Table 1 "CPU [W]"), seconds.
    pub cpu_warm_s: f64,
    /// Extra latency of a cold GPU-container start (Table 1 C−W), seconds.
    pub gpu_cold_extra_s: f64,
    /// Extra latency of a cold CPU-container start (Table 1 C−W), seconds.
    pub cpu_cold_extra_s: f64,
    /// Device memory footprint (CUDA allocations via the shim), MB.
    pub mem_mb: u64,
    /// Fractional execution-time overhead of the UVM shim (Figure 3).
    pub shim_overhead: f64,
    /// Execution-time multiplier on a half-GPU MIG slice (Figure 7b).
    pub mig_slowdown: f64,
    /// Fraction of GPU compute consumed while running (drives the
    /// utilization monitor and the interference model).
    pub intensity: f64,
}

impl FuncClass {
    pub fn gpu_warm(&self) -> DurNanos {
        secs(self.gpu_warm_s)
    }

    pub fn cpu_warm(&self) -> DurNanos {
        secs(self.cpu_warm_s)
    }

    pub fn gpu_cold_extra(&self) -> DurNanos {
        secs(self.gpu_cold_extra_s)
    }

    pub fn cpu_cold_extra(&self) -> DurNanos {
        secs(self.cpu_cold_extra_s)
    }

    /// Table-1 style cold latency (warm + cold extra).
    pub fn gpu_cold_s(&self) -> f64 {
        self.gpu_warm_s + self.gpu_cold_extra_s
    }

    pub fn cpu_cold_s(&self) -> f64 {
        self.cpu_warm_s + self.cpu_cold_extra_s
    }
}

/// The full catalog: Table 1's eight functions plus `cupy` (Fig 5a),
/// `rnn` and `srad` (Figs 3 and 7b).
pub const CATALOG: &[FuncClass] = &[
    FuncClass {
        name: "imagenet",
        gpu_warm_s: 2.253,
        cpu_warm_s: 5.477,
        gpu_cold_extra_s: 9.033, // 11.286 - 2.253
        cpu_cold_extra_s: 4.626, // 10.103 - 5.477
        mem_mb: 2200,
        shim_overhead: 0.02,
        mig_slowdown: 1.30,
        intensity: 0.55,
    },
    FuncClass {
        name: "roberta",
        gpu_warm_s: 0.268,
        cpu_warm_s: 5.162,
        gpu_cold_extra_s: 15.213, // 15.481 - 0.268
        cpu_cold_extra_s: 9.210,  // 14.372 - 5.162
        mem_mb: 1800,
        shim_overhead: 0.03,
        mig_slowdown: 1.20,
        intensity: 0.35,
    },
    FuncClass {
        name: "ffmpeg",
        gpu_warm_s: 4.483,
        cpu_warm_s: 32.997,
        gpu_cold_extra_s: 0.129, // 4.612 - 4.483
        cpu_cold_extra_s: 1.263, // 34.260 - 32.997
        mem_mb: 900,
        shim_overhead: 0.01,
        mig_slowdown: 1.15,
        intensity: 0.70,
    },
    FuncClass {
        name: "fft",
        gpu_warm_s: 0.897,
        cpu_warm_s: 11.584,
        gpu_cold_extra_s: 2.425, // 3.322 - 0.897
        cpu_cold_extra_s: 1.489, // 13.073 - 11.584
        mem_mb: 1500,            // matches the Fig-4 oversubscription setup
        shim_overhead: 0.04,
        mig_slowdown: 1.90,
        intensity: 0.50,
    },
    FuncClass {
        name: "isoneural",
        gpu_warm_s: 0.026,
        cpu_warm_s: 0.501,
        gpu_cold_extra_s: 9.937, // 9.963 - 0.026
        cpu_cold_extra_s: 0.933, // 1.434 - 0.501
        mem_mb: 400,
        shim_overhead: 0.05,
        mig_slowdown: 1.10,
        intensity: 0.10,
    },
    FuncClass {
        name: "lud",
        gpu_warm_s: 2.050,
        cpu_warm_s: 70.915,
        gpu_cold_extra_s: 0.309,  // 2.359 - 2.050
        cpu_cold_extra_s: 39.580, // 110.495 - 70.915
        mem_mb: 700,
        shim_overhead: 0.02,
        mig_slowdown: 1.25,
        intensity: 0.75,
    },
    FuncClass {
        name: "needle",
        gpu_warm_s: 1.979,
        cpu_warm_s: 144.639,
        gpu_cold_extra_s: 0.198,  // 2.177 - 1.979
        cpu_cold_extra_s: 78.667, // 223.306 - 144.639
        mem_mb: 650,
        shim_overhead: 0.01,
        mig_slowdown: 1.15,
        intensity: 0.70,
    },
    FuncClass {
        name: "pathfinder",
        gpu_warm_s: 1.472,
        cpu_warm_s: 134.358,
        gpu_cold_extra_s: 0.325, // 1.797 - 1.472
        // Table 1 has cold CPU *faster* than warm (106.667 vs 134.358 —
        // trial noise in the paper); we clamp the extra at zero.
        cpu_cold_extra_s: 0.0,
        mem_mb: 500,
        shim_overhead: 0.02,
        mig_slowdown: 1.10,
        intensity: 0.65,
    },
    FuncClass {
        name: "cupy",
        gpu_warm_s: 1.200,
        cpu_warm_s: 18.000,
        gpu_cold_extra_s: 4.100,
        cpu_cold_extra_s: 2.000,
        mem_mb: 600,
        shim_overhead: 0.02,
        mig_slowdown: 1.20,
        intensity: 0.50,
    },
    FuncClass {
        name: "rnn",
        gpu_warm_s: 0.520,
        cpu_warm_s: 7.800,
        gpu_cold_extra_s: 11.200,
        cpu_cold_extra_s: 5.100,
        mem_mb: 800,
        shim_overhead: 0.06,
        mig_slowdown: 2.60,
        intensity: 0.40,
    },
    FuncClass {
        name: "srad",
        gpu_warm_s: 0.810,
        cpu_warm_s: 24.500,
        gpu_cold_extra_s: 0.410,
        cpu_cold_extra_s: 3.200,
        mem_mb: 750,
        shim_overhead: 0.30, // the Fig-3 outlier
        mig_slowdown: 2.20,
        intensity: 0.60,
    },
];

/// Look up a catalog class by name.
pub fn by_name(name: &str) -> Option<&'static FuncClass> {
    CATALOG.iter().find(|c| c.name == name)
}

/// The Table-1 subset (the eight functions the paper tabulates).
pub fn table1() -> Vec<&'static FuncClass> {
    ["imagenet", "roberta", "ffmpeg", "fft", "isoneural", "lud", "needle", "pathfinder"]
        .iter()
        .map(|n| by_name(n).unwrap())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_matches_table1_values() {
        let img = by_name("imagenet").unwrap();
        assert!((img.gpu_cold_s() - 11.286).abs() < 1e-9);
        assert!((img.cpu_cold_s() - 10.103).abs() < 1e-9);
        let rob = by_name("roberta").unwrap();
        assert!((rob.gpu_cold_s() - 15.481).abs() < 1e-9);
        let lud = by_name("lud").unwrap();
        assert!((lud.cpu_cold_s() - 110.495).abs() < 1e-9);
    }

    #[test]
    fn catalog_has_eleven_classes() {
        assert_eq!(CATALOG.len(), 11);
        assert_eq!(table1().len(), 8);
    }

    #[test]
    fn srad_is_the_shim_outlier() {
        let max = CATALOG
            .iter()
            .max_by(|a, b| a.shim_overhead.partial_cmp(&b.shim_overhead).unwrap())
            .unwrap();
        assert_eq!(max.name, "srad");
        assert!((max.shim_overhead - 0.30).abs() < 1e-12);
    }

    #[test]
    fn rnn_is_the_mig_outlier() {
        let max = CATALOG
            .iter()
            .max_by(|a, b| a.mig_slowdown.partial_cmp(&b.mig_slowdown).unwrap())
            .unwrap();
        assert_eq!(max.name, "rnn");
    }

    #[test]
    fn intensities_are_fractions() {
        for c in CATALOG {
            assert!(c.intensity > 0.0 && c.intensity <= 1.0, "{}", c.name);
            assert!(c.mem_mb > 0);
            assert!(c.gpu_warm_s > 0.0);
        }
    }

    #[test]
    fn gpu_accelerates_heavy_functions() {
        // The paper's premise: GPU warm is far faster than CPU warm for
        // the compute-heavy classes.
        for name in ["needle", "pathfinder", "lud", "fft"] {
            let c = by_name(name).unwrap();
            assert!(c.cpu_warm_s / c.gpu_warm_s > 5.0, "{name}");
        }
    }
}
