//! Workloads: the function catalog (Table 1 calibration) and the trace
//! generators (Zipfian + Azure-style samples) used by every experiment.

pub mod azure;
pub mod catalog;
pub mod trace;
pub mod zipf;

pub use catalog::{FuncClass, CATALOG};
pub use trace::{Trace, TraceEvent, Workload, WorkloadFunc};

use crate::util::rng::Rng;

/// Assign catalog classes to popularity ranks (rank 0 = most popular)
/// such that popular functions skew *short* — the Azure production
/// trace's signature (invocation frequency anti-correlates with
/// duration) — with multiplicative noise so the correlation is loose.
pub fn shortness_biased_assignment(
    classes: &[&'static FuncClass],
    n_funcs: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    // Class indices sorted by warm time ascending, cycled to length.
    let mut by_warm: Vec<usize> = (0..classes.len()).collect();
    by_warm.sort_by(|&a, &b| {
        classes[a]
            .gpu_warm_s
            .partial_cmp(&classes[b].gpu_warm_s)
            .unwrap()
    });
    let mut order: Vec<usize> = (0..n_funcs)
        .map(|r| by_warm[(r * by_warm.len()) / n_funcs.max(1)])
        .collect();
    // Local noise: swap each slot with a neighbour within a window of 3
    // so ordering is biased, not deterministic.
    for i in 0..order.len() {
        let j = (i + rng.below(3)).min(order.len() - 1);
        order.swap(i, j);
    }
    order
}

#[cfg(test)]
mod assignment_tests {
    use super::*;

    #[test]
    fn popular_ranks_are_shorter_on_average() {
        let classes: Vec<&'static FuncClass> = catalog::CATALOG.iter().collect();
        let mut rng = Rng::new(1);
        let order = shortness_biased_assignment(&classes, 24, &mut rng);
        assert_eq!(order.len(), 24);
        let warm = |r: &[usize]| {
            r.iter().map(|&i| classes[i].gpu_warm_s).sum::<f64>() / r.len() as f64
        };
        let head = warm(&order[..8]);
        let tail = warm(&order[16..]);
        assert!(
            head < tail,
            "popular (head) should be shorter: {head:.2} vs {tail:.2}"
        );
    }
}
