//! Workloads: the function catalog (Table 1 calibration) and the trace
//! generators (Zipfian + Azure-style samples) used by every experiment.

pub mod azure;
pub mod catalog;
pub mod trace;
pub mod zipf;

pub use catalog::{FuncClass, CATALOG};
pub use trace::{Trace, TraceEvent, Workload, WorkloadFunc};

use crate::types::Nanos;
use crate::util::rng::Rng;

/// Uniformly rescale the offered load of a workload + trace by `factor`
/// (> 1 compresses time, multiplying the request rate; < 1 stretches
/// it). Burst structure and per-function popularity are preserved —
/// only the global rate shifts — which is how the cluster sweep turns
/// one calibrated single-server trace into an N-shard offered load
/// (weak scaling: rate × N against N× the hardware).
pub fn scale_rate(workload: &mut Workload, trace: &mut trace::Trace, factor: f64) {
    assert!(factor > 0.0 && factor.is_finite(), "bad rate factor {factor}");
    for e in &mut trace.events {
        e.at = (e.at as f64 / factor).round() as Nanos;
    }
    for f in &mut workload.funcs {
        f.mean_iat_s /= factor;
    }
    // Division preserves time order, but rounding can collapse distinct
    // instants into ties — re-sort to restore the canonical (at, func)
    // order every replay assumes.
    trace.sort();
}

/// Assign catalog classes to popularity ranks (rank 0 = most popular)
/// such that popular functions skew *short* — the Azure production
/// trace's signature (invocation frequency anti-correlates with
/// duration) — with multiplicative noise so the correlation is loose.
pub fn shortness_biased_assignment(
    classes: &[&'static FuncClass],
    n_funcs: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    // Class indices sorted by warm time ascending, cycled to length.
    let mut by_warm: Vec<usize> = (0..classes.len()).collect();
    by_warm.sort_by(|&a, &b| {
        classes[a]
            .gpu_warm_s
            .partial_cmp(&classes[b].gpu_warm_s)
            .unwrap()
    });
    let mut order: Vec<usize> = (0..n_funcs)
        .map(|r| by_warm[(r * by_warm.len()) / n_funcs.max(1)])
        .collect();
    // Local noise: swap each slot with a neighbour within a window of 3
    // so ordering is biased, not deterministic.
    for i in 0..order.len() {
        let j = (i + rng.below(3)).min(order.len() - 1);
        order.swap(i, j);
    }
    order
}

#[cfg(test)]
mod scale_rate_tests {
    use super::*;
    use crate::types::secs;
    use crate::workload::trace::TraceEvent;

    #[test]
    fn doubling_rate_halves_duration_and_keeps_counts() {
        let (mut w, mut t) = {
            let mut w = Workload::default();
            let a = w.register(catalog::by_name("fft").unwrap(), 0, 2.0);
            let b = w.register(catalog::by_name("lud").unwrap(), 0, 4.0);
            let mut t = trace::Trace::default();
            for i in 0..40 {
                t.events.push(TraceEvent {
                    at: secs(i as f64 * 0.7),
                    func: if i % 3 == 0 { b } else { a },
                });
            }
            t.sort();
            (w, t)
        };
        let before_counts = t.counts(w.len());
        let before_dur = t.duration();
        let before_rps = t.req_per_sec();
        scale_rate(&mut w, &mut t, 2.0);
        assert_eq!(t.counts(w.len()), before_counts);
        assert_eq!(t.duration(), before_dur / 2);
        assert!((t.req_per_sec() - 2.0 * before_rps).abs() < 1e-6);
        assert!((w.funcs[0].mean_iat_s - 1.0).abs() < 1e-12);
        assert!((w.funcs[1].mean_iat_s - 2.0).abs() < 1e-12);
        // Canonical order preserved.
        assert!(t
            .events
            .windows(2)
            .all(|p| (p[0].at, p[0].func) <= (p[1].at, p[1].func)));
    }

    #[test]
    fn identity_factor_is_a_noop() {
        let mut w = Workload::default();
        let a = w.register(catalog::by_name("fft").unwrap(), 0, 1.5);
        let mut t = trace::Trace::default();
        t.events.push(TraceEvent { at: secs(3.2), func: a });
        let orig = t.events.clone();
        scale_rate(&mut w, &mut t, 1.0);
        assert_eq!(t.events, orig);
        assert!((w.funcs[0].mean_iat_s - 1.5).abs() < 1e-12);
    }

    #[test]
    fn scaling_preserves_func_ids_in_range() {
        let (mut w, mut t) = zipf::generate(&zipf::ZipfConfig {
            duration_s: 60.0,
            ..Default::default()
        });
        scale_rate(&mut w, &mut t, 8.0);
        assert!(t.events.iter().all(|e| (e.func.0 as usize) < w.len()));
    }
}

#[cfg(test)]
mod assignment_tests {
    use super::*;

    #[test]
    fn popular_ranks_are_shorter_on_average() {
        let classes: Vec<&'static FuncClass> = catalog::CATALOG.iter().collect();
        let mut rng = Rng::new(1);
        let order = shortness_biased_assignment(&classes, 24, &mut rng);
        assert_eq!(order.len(), 24);
        let warm = |r: &[usize]| {
            r.iter().map(|&i| classes[i].gpu_warm_s).sum::<f64>() / r.len() as f64
        };
        let head = warm(&order[..8]);
        let tail = warm(&order[16..]);
        assert!(
            head < tail,
            "popular (head) should be shorter: {head:.2} vs {tail:.2}"
        );
    }
}
