//! Azure-style workload sampler (§6 "Azure" class, Table 3).
//!
//! The paper samples and scales the IAT distribution of the Azure 2019
//! production trace [71], producing nine samples (trace ids 0–8) with
//! different function mixes and invocation-frequency distributions. The
//! original trace is not shipped here (hardware/data substitution — see
//! DESIGN.md §1), so we synthesize samples with the trace's published
//! shape: heavy-tailed per-function rates spanning orders of magnitude
//! (Pareto-distributed), bursty arrivals (log-normal IATs with CV > 1),
//! and the per-sample function counts / utilization bands of Table 3.

use crate::types::{secs, FuncId};
use crate::util::rng::Rng;
use crate::workload::catalog;
use crate::workload::trace::{Trace, TraceEvent, Workload};

/// Target mean GPU utilization per Table-3 trace id (column "GPU Util %").
pub const TABLE3_UTIL: [f64; 9] = [37.9, 44.3, 48.8, 67.0, 77.1, 43.2, 79.9, 44.9, 54.2];

/// Function-copy counts per sample; trace 4 is the 19-function
/// "medium-intensity" workload used throughout §6.2.
pub const TABLE3_NFUNCS: [usize; 9] = [24, 22, 20, 23, 19, 21, 24, 20, 22];

/// Parameters of an Azure-style sample.
#[derive(Debug, Clone)]
pub struct AzureConfig {
    /// Which Table-3 sample (0–8); drives n_funcs, util target and seed.
    pub trace_id: usize,
    /// Trace duration, seconds (paper experiments run tens of minutes).
    pub duration_s: f64,
    /// Scale the offered load (1.0 = calibrated to the Table-3 util).
    pub load_scale: f64,
}

impl Default for AzureConfig {
    fn default() -> Self {
        Self {
            trace_id: 4,
            duration_s: 600.0,
            load_scale: 1.0,
        }
    }
}

/// Generate one Azure-style sample.
pub fn generate(cfg: &AzureConfig) -> (Workload, Trace) {
    assert!(cfg.trace_id < 9, "trace_id must be 0..9");
    let mut rng = Rng::new(0xA2_0000 + cfg.trace_id as u64);
    let n_funcs = TABLE3_NFUNCS[cfg.trace_id];
    let util_target = TABLE3_UTIL[cfg.trace_id] / 100.0 * cfg.load_scale;

    // Heavy-tailed relative rates (Pareto shape ~1.1: a few dominant
    // functions, long rare tail — the Azure trace's signature), sorted
    // so rank 0 is the most popular.
    let mut rel_rates: Vec<f64> = (0..n_funcs).map(|_| rng.pareto(1.0, 1.1)).collect();
    rel_rates.sort_by(|a, b| b.partial_cmp(a).unwrap());
    // Popular functions skew short, as in the production trace
    // ("dominated by extremely short-running functions", §6).
    let classes: Vec<&'static catalog::FuncClass> = catalog::CATALOG.iter().collect();
    let class_of = crate::workload::shortness_biased_assignment(&classes, n_funcs, &mut rng);

    // Scale rates so the expected *busy-time* demand hits the
    // utilization target (NVML utilization is the busy-time fraction):
    //   Σ rate_i × gpu_warm_i = util_target  (one-GPU-seconds/second)
    // The 1.12 divisor compensates for the execution-time inflation the
    // model adds on top of warm times (interference overlap at D≥2,
    // shim, memory movement) so *measured* utilization lands near the
    // Table-3 targets.
    let demand: f64 = (0..n_funcs)
        .map(|i| {
            let c = classes[class_of[i]];
            rel_rates[i] * c.gpu_warm_s
        })
        .sum();
    let scale = util_target / 1.12 / demand.max(1e-12);

    let mut workload = Workload::default();
    let mut copies = vec![0usize; classes.len()];
    let mut sigmas = Vec::with_capacity(n_funcs);
    for i in 0..n_funcs {
        let class = classes[class_of[i]];
        let rate = rel_rates[i] * scale;
        workload.register(class, copies[class_of[i]], 1.0 / rate.max(1e-12));
        copies[class_of[i]] += 1;
        // Burstiness varies per function (CV > 1 for most Azure apps).
        sigmas.push(rng.range(0.8, 1.8));
    }

    let mut trace = Trace::default();
    for (i, f) in workload.funcs.iter().enumerate() {
        let sigma: f64 = sigmas[i];
        // Log-normal with mean = mean_iat: mu = ln(mean) - sigma^2/2.
        let mu = f.mean_iat_s.ln() - sigma * sigma / 2.0;
        let mut t = rng.log_normal(mu, sigma);
        while t < cfg.duration_s {
            trace.events.push(TraceEvent {
                at: secs(t),
                func: FuncId(f.id.0),
            });
            t += rng.log_normal(mu, sigma);
        }
    }
    trace.sort();

    // Heavy-tailed sampling makes the *realized* demand deviate widely
    // from the expectation; normalize by uniformly stretching/shrinking
    // time so the sample actually offers the Table-3 load (burst
    // structure is preserved, only the global rate shifts).
    let realized = offered_demand(&workload, &trace);
    let target = util_target / 1.12;
    if realized > 1e-9 {
        let factor = realized / target;
        for e in &mut trace.events {
            e.at = (e.at as f64 * factor) as crate::types::Nanos;
        }
        for f in &mut workload.funcs {
            f.mean_iat_s *= factor;
        }
    }
    (workload, trace)
}

/// Offered busy-time demand of a workload+trace in one-GPU-seconds per
/// second (Σ invocations × warm-time / duration).
pub fn offered_demand(workload: &Workload, trace: &Trace) -> f64 {
    if trace.is_empty() {
        return 0.0;
    }
    let total: f64 = trace
        .events
        .iter()
        .map(|e| workload.func(e.func).class.gpu_warm_s)
        .sum();
    total / crate::types::to_secs(trace.duration()).max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nine_samples_generate() {
        for id in 0..9 {
            let (w, t) = generate(&AzureConfig {
                trace_id: id,
                duration_s: 300.0,
                load_scale: 1.0,
            });
            assert_eq!(w.len(), TABLE3_NFUNCS[id], "trace {id}");
            assert!(t.len() > 10, "trace {id} too sparse: {}", t.len());
        }
    }

    #[test]
    fn demand_tracks_util_target() {
        for id in [0, 4, 6] {
            let (w, t) = generate(&AzureConfig {
                trace_id: id,
                duration_s: 3000.0,
                load_scale: 1.0,
            });
            let demand = offered_demand(&w, &t);
            let target = TABLE3_UTIL[id] / 100.0;
            // Log-normal sampling noise is real; stay within ~40%.
            assert!(
                (demand - target).abs() / target < 0.4,
                "trace {id}: demand {demand:.3} vs target {target:.3}"
            );
        }
    }

    #[test]
    fn rates_are_heavy_tailed() {
        let (w, t) = generate(&AzureConfig {
            trace_id: 0,
            duration_s: 2000.0,
            load_scale: 1.0,
        });
        let mut counts = t.counts(w.len());
        counts.sort_unstable_by(|a, b| b.cmp(a));
        assert!(counts[0] > 10 * counts[counts.len() - 1].max(1) / 2);
    }

    #[test]
    fn deterministic_per_trace_id() {
        let cfg = AzureConfig::default();
        let (_, a) = generate(&cfg);
        let (_, b) = generate(&cfg);
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn load_scale_scales() {
        let lo = generate(&AzureConfig {
            trace_id: 2,
            duration_s: 1000.0,
            load_scale: 0.5,
        });
        let hi = generate(&AzureConfig {
            trace_id: 2,
            duration_s: 1000.0,
            load_scale: 2.0,
        });
        assert!(hi.1.len() > 2 * lo.1.len());
    }
}
